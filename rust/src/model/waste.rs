//! Closed-form waste expressions — Eqs. (1), (3), (4), (5), (6) of the
//! paper, as functions of the regular period T.
//!
//! These must match `python/compile/kernels/ref.py` bit-for-bit in
//! structure: the integration tests compare the HLO planner output
//! against this module.

use super::{Params, StrategyKind};

/// Eq. (1) with general trust probability q: the exact-date model.
/// WASTE = C/T + (1/mu) [ (1-rq) T/2 + D + R + (qr/p) C ].
pub fn waste_exact_q(p: &Params, t: f64, q: f64) -> f64 {
    let rq = p.recall * q;
    p.c / t + (1.0 / p.mu) * ((1.0 - rq) * t / 2.0 + p.dr() + rq / p.precision.max(1e-12) * p.c)
}

/// Young's baseline: Eq. (1) at q = 0.
pub fn waste_young(p: &Params, t: f64) -> f64 {
    p.c / t + (t / 2.0 + p.dr()) / p.mu
}

/// Eq. (5): Instant — window start treated as an exact prediction date.
pub fn waste_instant(p: &Params, t: f64) -> f64 {
    waste_exact_q(p, t, 1.0) + p.recall / p.mu * p.ef.min(t / 2.0)
}

/// Eq. (6) at q = 1: NoCkptI — work through the window unprotected.
pub fn waste_nockpt(p: &Params, t: f64) -> f64 {
    let inv_mup = p.inv_mu_p();
    let inv_munp = p.inv_mu_np();
    let frac_reg = p.frac_reg();
    (frac_reg / t + inv_mup) * p.c
        + p.precision * inv_mup * p.ef
        + frac_reg * inv_munp * t / 2.0
        + (p.precision * inv_mup + frac_reg * inv_munp) * p.dr()
}

/// Eq. (4) at q = 1: WithCkptI — proactive checkpoints with period `tp`
/// inside the window.
pub fn waste_withckpt(p: &Params, t: f64, tp: f64) -> f64 {
    let inv_mup = p.inv_mu_p();
    let inv_munp = p.inv_mu_np();
    let frac_reg = p.frac_reg();
    (frac_reg / t + p.i1() * inv_mup / tp + inv_mup) * p.c
        + p.precision * inv_mup * tp
        + frac_reg * inv_munp * t / 2.0
        + (p.precision * inv_mup + frac_reg * inv_munp) * p.dr()
}

/// Eq. (3): prediction + preventive migration, general q.
pub fn waste_migration_q(p: &Params, t: f64, q: f64) -> f64 {
    let rq = p.recall * q;
    p.c / t
        + (1.0 / p.mu)
            * ((1.0 - rq) * (t / 2.0 + p.dr()) + rq / p.precision.max(1e-12) * p.m)
}

/// Waste of `kind` at period `t` with q = 1 (q = 0 for Young); `tp` is
/// only read by WithCkptI.
pub fn waste_of(p: &Params, kind: StrategyKind, t: f64, tp: f64) -> f64 {
    match kind {
        StrategyKind::Young => waste_young(p, t),
        StrategyKind::ExactPrediction => waste_exact_q(p, t, 1.0),
        StrategyKind::Instant => waste_instant(p, t),
        StrategyKind::NoCkptI => waste_nockpt(p, t),
        StrategyKind::WithCkptI => waste_withckpt(p, t, tp),
        StrategyKind::Migration => waste_migration_q(p, t, 1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::util::approx_eq;

    fn params(recall: f64, precision: f64, window: f64) -> Params {
        let pred = if window > 0.0 {
            Predictor::windowed(recall, precision, window)
        } else {
            Predictor::exact(recall, precision)
        };
        Params::from_scenario(&Scenario::paper(1 << 16, pred))
    }

    #[test]
    fn q_interpolation_is_affine() {
        // §3.3: WASTE(q) is affine in q — the basis for the q ∈ {0,1}
        // endpoint theorem. Check midpoint = average of endpoints.
        let p = params(0.7, 0.4, 0.0);
        for t in [1000.0, 5000.0, 12000.0] {
            let w0 = waste_exact_q(&p, t, 0.0);
            let w1 = waste_exact_q(&p, t, 1.0);
            let wh = waste_exact_q(&p, t, 0.5);
            assert!(approx_eq(wh, 0.5 * (w0 + w1), 1e-12), "t={t}");
            let m0 = waste_migration_q(&p, t, 0.0);
            let m1 = waste_migration_q(&p, t, 1.0);
            let mh = waste_migration_q(&p, t, 0.5);
            assert!(approx_eq(mh, 0.5 * (m0 + m1), 1e-12), "t={t}");
        }
    }

    #[test]
    fn young_is_exact_q0() {
        let p = params(0.85, 0.82, 0.0);
        for t in [800.0, 3000.0, 9000.0] {
            assert!(approx_eq(waste_young(&p, t), waste_exact_q(&p, t, 0.0), 1e-12));
        }
    }

    #[test]
    fn instant_reduces_to_exact_when_window_zero() {
        // §4.2: I = 0 ⇒ E_I^f = 0 ⇒ WASTE_INSTANT = WASTE_EXACT(q=1).
        let p = params(0.85, 0.82, 0.0);
        for t in [800.0, 3000.0, 9000.0] {
            assert!(approx_eq(waste_instant(&p, t), waste_exact_q(&p, t, 1.0), 1e-12));
        }
    }

    #[test]
    fn nockpt_equals_instant_when_window_zero() {
        // §4.2: Eqs. (5) and (6) coincide at I = 0.
        let p = params(0.85, 0.82, 0.0);
        for t in [800.0, 3000.0, 9000.0] {
            assert!(
                approx_eq(waste_nockpt(&p, t), waste_instant(&p, t), 1e-9),
                "t={t}: {} vs {}",
                waste_nockpt(&p, t),
                waste_instant(&p, t)
            );
        }
    }

    #[test]
    fn withckpt_minus_nockpt_matches_eq11() {
        // Eq. (11): the difference depends only on T_P, not on T_R.
        let p = params(0.7, 0.4, 3000.0);
        let tp = 1500.0;
        let d1 = waste_withckpt(&p, 2000.0, tp) - waste_nockpt(&p, 2000.0);
        let d2 = waste_withckpt(&p, 9000.0, tp) - waste_nockpt(&p, 9000.0);
        assert!(approx_eq(d1, d2, 1e-9));
        let expect = p.recall / p.mu
            * (p.i1() / p.precision * p.c / tp + tp - p.ef);
        assert!(approx_eq(d1, expect, 1e-9), "{d1} vs {expect}");
    }

    #[test]
    fn convexity_numeric() {
        let p = params(0.85, 0.82, 3000.0);
        let tp = 1500.0;
        for kind in StrategyKind::ALL {
            let f = |t: f64| waste_of(&p, kind, t, tp);
            for t in [1500.0f64, 4000.0, 10000.0] {
                let h = 1.0;
                let second = f(t + h) - 2.0 * f(t) + f(t - h);
                assert!(second >= -1e-12, "{kind} at {t}: {second}");
            }
        }
    }

    #[test]
    fn zero_recall_degenerates_to_young() {
        let p = params(0.0, 0.9, 0.0);
        for t in [1000.0, 4000.0] {
            for kind in [StrategyKind::ExactPrediction, StrategyKind::Instant, StrategyKind::NoCkptI] {
                assert!(approx_eq(waste_of(&p, kind, t, 600.0), waste_young(&p, t), 1e-12));
            }
        }
    }
}
