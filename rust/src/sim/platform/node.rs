//! Node components: one fault/prediction stream per node.
//!
//! Each node runs its own [`TraceGen`] over an *individual* failure law
//! whose MTBF is `K × mu` (K nodes, platform MTBF `mu = mu_ind / N`) —
//! the Poisson-superposition discipline: merging the K per-node streams
//! reproduces the aggregate platform rate exactly, for every K, so the
//! closed form evaluated at `mu_ind / N` stays the reference for the
//! uncorrelated-exponential platform (pinned by the `verify` grid and
//! the superposition property test). The per-node false-prediction
//! interval scales by the same K, keeping the aggregate predictor rate
//! at the §5 value.
//!
//! Seeding follows the existing `rng` discipline: node 0 uses the
//! scenario seed *unchanged* — same `"fault"/"mark"/"win"/"false"`
//! substreams of `(seed, rep)` as the single-stream engine — which is
//! what makes the 1-node platform bit-identical to [`crate::sim::Engine`]
//! over a plain [`TraceGen`] by construction. Nodes `i > 0` derive
//! their own seeds through [`SplitMix64`].
//!
//! Fault ids are remapped `id_global = id_local · K + node` so the K
//! per-node counters interleave into one collision-free id space (the
//! identity map at K = 1), keeping true predictions linked to their
//! faults across the merge.

use crate::config::Scenario;
use crate::rng::SplitMix64;
use crate::trace::{EventSource, Fault, Prediction, TraceGen};

use super::PlatformSpec;

/// Per-node seed: node 0 keeps the scenario seed (the bit-identity
/// anchor); other nodes get a SplitMix64-derived substream seed.
pub fn node_seed(seed: u64, node: u64) -> u64 {
    if node == 0 {
        seed
    } else {
        SplitMix64::new(seed ^ node.wrapping_mul(0x9E3779B97F4A7C15)).next_u64()
    }
}

/// One node's fault/prediction component: a [`TraceGen`] over the
/// K-scaled individual law, with fault ids remapped into the global
/// `id · K + node` space.
#[derive(Debug)]
pub struct NodeStream {
    gen: TraceGen,
    node: u64,
    stride: u64,
}

impl NodeStream {
    /// Build node `node` of a `spec.nodes`-node platform for one
    /// replication. `lead` is the consumer's proactive lead, exactly as
    /// in [`TraceGen::new`].
    pub fn new(
        scenario: &Scenario,
        spec: &PlatformSpec,
        lead: f64,
        seed: u64,
        rep: u64,
        node: u64,
    ) -> anyhow::Result<NodeStream> {
        let k = spec.nodes as f64;
        let mu = scenario.mu();
        let pred = &scenario.predictor;
        let fault_dist = scenario.fault_dist.dist()?.with_mean(mu * k);
        // Infinite stays infinite under the K-scaling (never-firing
        // predictors stay never-firing on every node).
        let false_interval = pred.false_pred_interval(mu) * k;
        let false_dist = if false_interval.is_finite() {
            Some(scenario.false_dist_spec().dist()?.with_mean(false_interval))
        } else {
            None
        };
        let gen = TraceGen::from_dists(
            fault_dist,
            false_dist,
            pred.recall,
            pred.window,
            lead,
            node_seed(seed, node),
            rep,
        );
        Ok(NodeStream { gen, node, stride: spec.nodes })
    }

    /// Rewind to replication `rep` of `seed` (same contract as
    /// [`TraceGen::reset`]; the node re-derives its own substream seed).
    pub fn reset(&mut self, seed: u64, rep: u64) {
        self.gen.reset(node_seed(seed, self.node), rep);
    }

    /// Next fault on this node, id remapped to the global space. The
    /// generator is infinite, so this always yields.
    pub fn next_fault(&mut self) -> Option<Fault> {
        self.gen.next_fault().map(|mut f| {
            f.id = f.id * self.stride + self.node;
            f
        })
    }

    /// Next prediction announced on this node (avail-monotone within
    /// the node), true-positive links remapped alongside the faults.
    pub fn next_prediction(&mut self) -> Option<Prediction> {
        self.gen.next_prediction().map(|mut p| {
            p.fault_id = p.fault_id.map(|id| id * self.stride + self.node);
            p
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;

    fn scenario() -> Scenario {
        let mut s = Scenario::paper(1 << 16, Predictor::windowed(0.85, 0.82, 300.0));
        s.fault_dist = crate::dist::DistSpec::Exp;
        s.work = 2.0e5;
        s
    }

    #[test]
    fn node_zero_is_the_plain_tracegen() {
        // The bit-identity anchor: node 0 of a 1-node platform emits
        // exactly the single-stream generator's events.
        let s = scenario();
        let spec = PlatformSpec::default();
        let mut node = NodeStream::new(&s, &spec, 600.0, s.seed, 0, 0).unwrap();
        let mut plain = TraceGen::new(&s, 600.0, s.seed, 0).unwrap();
        for _ in 0..200 {
            assert_eq!(node.next_fault(), plain.next_fault());
        }
        for _ in 0..50 {
            assert_eq!(node.next_prediction(), plain.next_prediction());
        }
    }

    #[test]
    fn node_seeds_are_distinct_and_stable() {
        let s0 = node_seed(42, 0);
        assert_eq!(s0, 42);
        let mut seen = std::collections::HashSet::new();
        for node in 0..64 {
            assert!(seen.insert(node_seed(42, node)), "seed collision at node {node}");
            assert_eq!(node_seed(42, node), node_seed(42, node));
        }
    }

    #[test]
    fn ids_interleave_without_collision() {
        let s = scenario();
        let spec = PlatformSpec { nodes: 4, ..PlatformSpec::default() };
        let mut seen = std::collections::HashSet::new();
        for node in 0..4 {
            let mut ns = NodeStream::new(&s, &spec, 600.0, 7, 0, node).unwrap();
            for _ in 0..100 {
                let f = ns.next_fault().unwrap();
                assert_eq!(f.id % 4, node, "remap must encode the node");
                assert!(seen.insert(f.id), "global id collision: {}", f.id);
            }
        }
    }

    #[test]
    fn per_node_mean_scales_with_k() {
        let s = scenario();
        let spec = PlatformSpec { nodes: 8, ..PlatformSpec::default() };
        let mut ns = NodeStream::new(&s, &spec, 600.0, 3, 0, 2).unwrap();
        let n = 4000;
        let mut last = 0.0;
        for _ in 0..n {
            last = ns.next_fault().unwrap().t;
        }
        let emp = last / n as f64;
        let want = s.mu() * 8.0;
        assert!((emp - want).abs() / want < 0.1, "per-node MTBF {emp} vs {want}");
    }

    #[test]
    fn reset_matches_fresh_node() {
        let s = scenario();
        let spec = PlatformSpec { nodes: 3, ..PlatformSpec::default() };
        let mut reused = NodeStream::new(&s, &spec, 600.0, 11, 0, 1).unwrap();
        for rep in [4u64, 0, 9] {
            reused.reset(11, rep);
            let mut fresh = NodeStream::new(&s, &spec, 600.0, 11, rep, 1).unwrap();
            for _ in 0..80 {
                assert_eq!(reused.next_fault(), fresh.next_fault());
            }
            for _ in 0..20 {
                assert_eq!(reused.next_prediction(), fresh.next_prediction());
            }
        }
    }
}
