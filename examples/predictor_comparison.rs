//! Predictor shopping guide (Table 3 extension): evaluate every fault
//! predictor from the literature survey with the analytical planner,
//! then stress the paper's recall-vs-precision conclusion by simulation.
//!
//! ```bash
//! cargo run --release --example predictor_comparison
//! ```

use ckptfp::config::{predictor_catalog, Predictor, Scenario};
use ckptfp::experiments::{sim_waste, ExpOptions};
use ckptfp::model::{plan, Capping, Params, StrategyKind};
use ckptfp::report::Table;

fn main() -> anyhow::Result<()> {
    // --- Table 3, evaluated: what each published predictor is worth. ---
    println!("=== predictor catalog on the 2^19-proc platform (mu = 125 mn) ===\n");
    let mut t = Table::new(["predictor", "p", "r", "waste", "vs Young", "winner"]);
    let base = Scenario::paper(1 << 19, Predictor::none());
    let py = Params::from_scenario(&base);
    let young = plan(&py, Capping::Uncapped, false);
    let wy = young.waste[StrategyKind::Young as usize];
    for entry in predictor_catalog() {
        let s = Scenario::paper(1 << 19, entry.predictor(0.0));
        let p = Params::from_scenario(&s);
        let best = plan(&p, Capping::Uncapped, false);
        let gain = 100.0 * (1.0 - (1.0 - wy) / (1.0 - best.winner_waste().min(0.999)));
        t.row([
            entry.source.to_string(),
            format!("{:.0}%", entry.precision * 100.0),
            format!("{:.0}%", entry.recall * 100.0),
            format!("{:.3}", best.winner_waste()),
            format!("{gain:+.0}%"),
            best.winner.name().to_string(),
        ]);
    }
    print!("{t}");
    println!("(Young baseline waste: {wy:.3})");

    // --- Recall vs precision, by simulation (the §5.2 conclusion). ---
    println!("\n=== recall vs precision, simulated (Weibull k=0.7, N=2^19, I=300 s) ===\n");
    let opts = ExpOptions { reps: 12, ..ExpOptions::default() };
    let mut t2 = Table::new(["predictor (r, p)", "sim waste", "note"]);
    let cases = [
        (0.9, 0.4, "high recall, poor precision"),
        (0.4, 0.9, "poor recall, high precision"),
        (0.9, 0.9, "both high"),
        (0.4, 0.4, "both poor"),
    ];
    let mut results = Vec::new();
    for (r, p, note) in cases {
        let mut s = Scenario::paper(1 << 19, Predictor::windowed(r, p, 300.0));
        s.fault_dist = ckptfp::dist::DistSpec::weibull(0.7);
        let w = sim_waste(&s, StrategyKind::NoCkptI, &opts).mean();
        results.push((r, p, w));
        t2.row([format!("r={r}, p={p}"), format!("{w:.3}"), note.to_string()]);
    }
    print!("{t2}");
    let high_recall = results[0].2;
    let high_precision = results[1].2;
    println!(
        "\nhigh-recall waste {high_recall:.3} vs high-precision waste {high_precision:.3} — \
         recall wins: better safe than sorry."
    );
    Ok(())
}
