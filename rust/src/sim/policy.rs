//! The pluggable checkpoint-policy layer.
//!
//! [`crate::sim::Engine`] is a thin discrete-event *core*: it owns time
//! and segment accounting, the fault/prediction stream plumbing, and
//! the outcome bookkeeping. Everything strategic is delegated to a
//! [`Policy`], which answers the core's three questions:
//!
//! 1. **When is the next regular checkpoint due?** —
//!    [`Policy::ckpt_rule`] returns a `(measured, boundary)` pair; the
//!    core checkpoints when `measured >= boundary - EPS` and never
//!    plans a work slice longer than `boundary - measured`.
//! 2. **Trust this prediction?** — [`Policy::trust`], drawing from the
//!    core's trust RNG exactly when a probabilistic decision is needed
//!    (so replications stay bit-reproducible).
//! 3. **What to do inside an open prediction window?** —
//!    [`Policy::window_action`] (the [`ProactiveMode`] vocabulary).
//!
//! Like [`crate::dist::Dist`], `Policy` is a monomorphized enum — no
//! `Box<dyn>` on the per-segment hot path. The paper's entire strategy
//! space is the [`Policy::Paper`] variant (fixed period, fixed trust
//! probability, fixed window response); the other variants are
//! policies the pre-refactor monolithic engine could not express:
//!
//! * [`Policy::AdaptivePeriod`] re-derives the Young period online
//!   from the *observed* fault rate (prior MTBF blended with the
//!   empirical one, one pseudo-observation of weight `mu0`);
//! * [`Policy::RiskThreshold`] watches the *unprotected* (volatile)
//!   work instead of the regular-mode period accounting: under a
//!   constant hazard `1/mu`, the expected loss of `v` seconds of
//!   unprotected work accrues as `v^2 / (2 mu)`, so checkpointing when
//!   it reaches `kappa * C` means checkpointing at
//!   `v = sqrt(2 kappa mu C)` of volatile work — a rule that resets on
//!   *proactive* checkpoints too, which no `(t_r, W_reg)` accounting
//!   can emulate.
//!
//! Invariants the core guarantees to policies (see DESIGN.md § Policy
//! layer): `ckpt_rule` is consulted once per planning round with a
//! fresh [`PolicyCtx`]; `boundary` must stay >= 1 s so progress is
//! always possible (every constructor enforces the floor); `trust` is
//! called exactly once per arriving prediction, in trace order.

use crate::rng::Pcg64;
use crate::strategies::{ProactiveMode, StrategySpec};

/// The core's read-only execution state, snapshotted for one policy
/// consultation.
#[derive(Debug, Clone, Copy)]
pub struct PolicyCtx {
    /// Current simulated time (s).
    pub now: f64,
    /// Unprotected (volatile) work since the last persisted state (s).
    pub vol: f64,
    /// Regular-mode work accumulated toward the current period (s).
    pub w_reg: f64,
    /// Faults observed so far this replication.
    pub n_faults: u64,
    /// Checkpoint duration C (s).
    pub c: f64,
}

/// A checkpoint policy, monomorphized for the simulation hot loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Fixed regular period `t_r`, trust probability `q` and window
    /// response — the paper's §3/§4 strategy space
    /// ([`StrategySpec`] made executable).
    Paper { t_r: f64, q: f64, proactive: ProactiveMode },
    /// Young's period re-derived online from the observed fault rate:
    /// `mu_hat = (mu0 + now) / (1 + n_faults)` (the prior MTBF `mu0`
    /// enters as one pseudo-observation), `T_R = gain * sqrt(2 mu_hat C)`.
    AdaptivePeriod { mu0: f64, gain: f64, q: f64, proactive: ProactiveMode },
    /// Checkpoint when the volatile work reaches `w_star =
    /// sqrt(2 kappa mu C)` — i.e. when the accumulated risk
    /// `vol^2 / (2 mu)` exceeds `kappa * C`.
    RiskThreshold { w_star: f64, q: f64, proactive: ProactiveMode },
}

impl Policy {
    /// The executable form of a paper [`StrategySpec`]. Applies the
    /// engine's classic period floor (`t_r >= C + 1`) so a policy-built
    /// engine is bit-identical to a spec-built one.
    pub fn from_spec(spec: &StrategySpec, c: f64) -> Policy {
        Policy::Paper { t_r: spec.t_r.max(c + 1.0), q: spec.q, proactive: spec.proactive }
    }

    /// Enforce the progress floors on a directly-constructed policy
    /// (`Paper`: `t_r >= C + 1`; `RiskThreshold`: `w_star >= 1`;
    /// `AdaptivePeriod` floors per-consultation already). The engine
    /// applies this at construction so a degenerate hand-built policy
    /// (zero or NaN boundary) cannot stall the core — `f64::max`
    /// discards NaN, so even `t_r = NaN` lands on the floor. Idempotent
    /// over [`Policy::from_spec`] / `resolve_policy` output, so
    /// sanitizing never perturbs a legitimately built policy.
    pub fn sanitized(self, c: f64) -> Policy {
        match self {
            Policy::Paper { t_r, q, proactive } => {
                Policy::Paper { t_r: t_r.max(c + 1.0), q, proactive }
            }
            Policy::AdaptivePeriod { .. } => self,
            Policy::RiskThreshold { w_star, q, proactive } => {
                Policy::RiskThreshold { w_star: w_star.max(1.0), q, proactive }
            }
        }
    }

    #[inline]
    fn q_and_mode(&self) -> (f64, ProactiveMode) {
        match *self {
            Policy::Paper { q, proactive, .. }
            | Policy::AdaptivePeriod { q, proactive, .. }
            | Policy::RiskThreshold { q, proactive, .. } => (q, proactive),
        }
    }

    /// Q3 — the response when a trusted prediction's window opens.
    #[inline]
    pub fn window_action(&self) -> ProactiveMode {
        self.q_and_mode().1
    }

    /// The lead time the policy needs ahead of a predicted date
    /// (mirrors [`StrategySpec::required_lead`]).
    pub fn required_lead(&self, c: f64) -> f64 {
        match self.window_action() {
            ProactiveMode::Migrate { m } => m.max(c),
            _ => c,
        }
    }

    /// Q2 — trust this prediction? Consumes one Bernoulli draw exactly
    /// when `0 < q < 1` and predictions are not ignored — the same RNG
    /// consumption pattern as the pre-refactor engine, so outcomes stay
    /// bit-identical.
    #[inline]
    pub fn trust(&self, rng: &mut Pcg64) -> bool {
        let (q, proactive) = self.q_and_mode();
        let ignore = matches!(proactive, ProactiveMode::Ignore);
        !ignore && q > 0.0 && (q >= 1.0 || rng.bernoulli(q))
    }

    /// [`Policy::trust`] against a *pre-sampled* uniform — the trace-
    /// bank replay path, where the per-prediction uniform was drawn at
    /// materialization time from the same stream the engine's RNG
    /// would have produced. Same decision table: `Ignore` and the q
    /// extremes never look at `u`, a fractional q compares against it
    /// exactly as `bernoulli` would.
    #[inline]
    pub fn trust_with(&self, u: f64) -> bool {
        let (q, proactive) = self.q_and_mode();
        let ignore = matches!(proactive, ProactiveMode::Ignore);
        !ignore && q > 0.0 && (q >= 1.0 || u < q)
    }

    /// Q1 — the regular-checkpoint rule as a `(measured, boundary)`
    /// pair: a regular checkpoint is due when
    /// `measured >= boundary - EPS`, and the next work slice is capped
    /// at `boundary - measured` seconds of work. Every variant keeps
    /// `boundary >= 1` so the core always makes progress.
    #[inline]
    pub fn ckpt_rule(&self, ctx: &PolicyCtx) -> (f64, f64) {
        match *self {
            Policy::Paper { t_r, .. } => (ctx.w_reg, t_r - ctx.c),
            Policy::AdaptivePeriod { mu0, gain, .. } => {
                let mu_hat = (mu0 + ctx.now) / (1.0 + ctx.n_faults as f64);
                let t_r = (gain * (2.0 * mu_hat * ctx.c).sqrt()).max(ctx.c + 1.0);
                (ctx.w_reg, t_r - ctx.c)
            }
            Policy::RiskThreshold { w_star, .. } => (ctx.vol, w_star),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(now: f64, vol: f64, w_reg: f64, n_faults: u64) -> PolicyCtx {
        PolicyCtx { now, vol, w_reg, n_faults, c: 10.0 }
    }

    #[test]
    fn paper_rule_matches_fixed_period() {
        let p = Policy::Paper { t_r: 110.0, q: 0.0, proactive: ProactiveMode::Ignore };
        let (m, b) = p.ckpt_rule(&ctx(500.0, 30.0, 40.0, 2));
        assert_eq!(m, 40.0); // measured on W_reg
        assert_eq!(b, 100.0); // T_R - C
    }

    #[test]
    fn from_spec_applies_the_period_floor() {
        let spec =
            StrategySpec { name: "t".into(), t_r: 3.0, q: 0.0, proactive: ProactiveMode::Ignore };
        match Policy::from_spec(&spec, 10.0) {
            Policy::Paper { t_r, .. } => assert_eq!(t_r, 11.0),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn adaptive_boundary_tracks_the_observed_rate() {
        let p = Policy::AdaptivePeriod {
            mu0: 500.0,
            gain: 1.0,
            q: 0.0,
            proactive: ProactiveMode::Ignore,
        };
        // Prior only: T_R = sqrt(2 * 500 * 10) = 100, boundary 90.
        let (_, b0) = p.ckpt_rule(&ctx(0.0, 0.0, 0.0, 0));
        assert!((b0 - 90.0).abs() < 1e-9, "b0 = {b0}");
        // Long fault-free run: the estimated MTBF grows, so does the period.
        let (_, b_calm) = p.ckpt_rule(&ctx(10_000.0, 0.0, 0.0, 0));
        // Fault storm: the estimate shrinks, the policy checkpoints sooner.
        let (_, b_storm) = p.ckpt_rule(&ctx(10_000.0, 0.0, 0.0, 50));
        assert!(b_storm < b0 && b0 < b_calm, "{b_storm} < {b0} < {b_calm}");
        // The floor keeps progress possible under any storm.
        let (_, b_floor) = p.ckpt_rule(&ctx(1.0, 0.0, 0.0, 1_000_000));
        assert!(b_floor >= 1.0);
    }

    #[test]
    fn adaptive_gain_scales_the_period() {
        let mk = |gain| Policy::AdaptivePeriod {
            mu0: 500.0,
            gain,
            q: 0.0,
            proactive: ProactiveMode::Ignore,
        };
        let (_, b1) = mk(1.0).ckpt_rule(&ctx(0.0, 0.0, 0.0, 0));
        let (_, b2) = mk(2.0).ckpt_rule(&ctx(0.0, 0.0, 0.0, 0));
        assert!((b2 - (2.0 * 100.0 - 10.0)).abs() < 1e-9);
        assert!(b2 > b1);
    }

    #[test]
    fn risk_rule_measures_volatile_work() {
        let p =
            Policy::RiskThreshold { w_star: 100.0, q: 1.0, proactive: ProactiveMode::CkptBefore };
        // W_reg is irrelevant; only the unprotected work counts.
        let (m, b) = p.ckpt_rule(&ctx(1e6, 42.0, 9999.0, 7));
        assert_eq!(m, 42.0);
        assert_eq!(b, 100.0);
    }

    #[test]
    fn trust_honors_ignore_and_extremes_without_rng_draws() {
        // Ignore mode and the q extremes must not consume a draw — the
        // engine's bit-reproducibility contract depends on it.
        let mut rng = Pcg64::new(1, 2);
        let mut twin = Pcg64::new(1, 2);
        let ignore = Policy::Paper { t_r: 100.0, q: 1.0, proactive: ProactiveMode::Ignore };
        assert!(!ignore.trust(&mut rng));
        let distrust = Policy::Paper { t_r: 100.0, q: 0.0, proactive: ProactiveMode::CkptBefore };
        assert!(!distrust.trust(&mut rng));
        let certain = Policy::Paper { t_r: 100.0, q: 1.0, proactive: ProactiveMode::CkptBefore };
        assert!(certain.trust(&mut rng));
        assert_eq!(rng.next_u64(), twin.next_u64(), "no draw may have been consumed");
        // A fractional q does draw.
        let coin = Policy::Paper { t_r: 100.0, q: 0.5, proactive: ProactiveMode::CkptBefore };
        let _ = coin.trust(&mut rng);
        assert_ne!(rng.next_u64(), twin.next_u64());
    }

    #[test]
    fn trust_with_matches_the_rng_decision_table() {
        // trust_with(u) must agree with trust(rng) whenever the rng's
        // next uniform is u — the bank replay bit-identity hinge.
        for q in [0.0, 0.3, 0.5, 0.99, 1.0] {
            let policy = Policy::Paper { t_r: 100.0, q, proactive: ProactiveMode::CkptBefore };
            let mut rng = Pcg64::new(11, 7);
            let mut probe = Pcg64::new(11, 7);
            for _ in 0..50 {
                let u = probe.next_f64();
                let via_rng = policy.trust(&mut rng);
                assert_eq!(policy.trust_with(u), via_rng, "q={q} u={u}");
                // Keep the probe aligned: trust consumes a draw only
                // for fractional q.
                if !(q > 0.0 && q < 1.0) {
                    probe = rng.clone();
                }
            }
        }
        let ignore = Policy::Paper { t_r: 100.0, q: 1.0, proactive: ProactiveMode::Ignore };
        assert!(!ignore.trust_with(0.0));
    }

    #[test]
    fn required_lead_mirrors_spec() {
        let mig = Policy::Paper { t_r: 100.0, q: 1.0, proactive: ProactiveMode::Migrate { m: 900.0 } };
        assert_eq!(mig.required_lead(600.0), 900.0);
        let ckpt = Policy::Paper { t_r: 100.0, q: 1.0, proactive: ProactiveMode::CkptBefore };
        assert_eq!(ckpt.required_lead(600.0), 600.0);
    }
}
