//! Blocking typed client for the job service: encodes [`JobRequest`]s
//! as protocol-v2 JSONL over TCP and decodes typed responses. One
//! request in flight per connection (the protocol is strictly
//! line-for-line); open more clients for concurrency — the service is
//! one thread per connection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use super::types::*;
use super::wire;

pub struct ServiceClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ServiceClient {
    pub fn connect(addr: &str) -> anyhow::Result<ServiceClient> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(ServiceClient { reader: BufReader::new(stream), writer })
    }

    /// Send one job, wait for its response. Server-reported failures
    /// come back as `Ok(JobResponse::Error(_))`; transport failures as
    /// `Err`.
    pub fn call(&mut self, req: &JobRequest) -> anyhow::Result<JobResponse> {
        let line = wire::encode_request(req);
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        anyhow::ensure!(!resp.is_empty(), "server closed the connection");
        wire::decode_response(resp.trim()).map_err(Into::into)
    }

    pub fn plan(&mut self, job: PlanJob) -> anyhow::Result<PlanResult> {
        match self.call(&JobRequest::Plan(job))? {
            JobResponse::Plan(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to plan: {other:?}"),
        }
    }

    pub fn simulate(&mut self, job: SimulateJob) -> anyhow::Result<SimulateResult> {
        match self.call(&JobRequest::Simulate(job))? {
            JobResponse::Simulate(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to simulate: {other:?}"),
        }
    }

    pub fn best_period(&mut self, job: BestPeriodJob) -> anyhow::Result<BestPeriodOutcome> {
        match self.call(&JobRequest::BestPeriod(job))? {
            JobResponse::BestPeriod(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to best_period: {other:?}"),
        }
    }

    pub fn sweep(&mut self, job: SweepJob) -> anyhow::Result<SweepResult> {
        match self.call(&JobRequest::Sweep(job))? {
            JobResponse::Sweep(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to sweep: {other:?}"),
        }
    }

    pub fn verify(&mut self, job: VerifyJob) -> anyhow::Result<crate::verify::VerifyReport> {
        match self.call(&JobRequest::Verify(job))? {
            JobResponse::Verify(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to verify: {other:?}"),
        }
    }

    pub fn stats(&mut self) -> anyhow::Result<ServiceStats> {
        match self.call(&JobRequest::Stats)? {
            JobResponse::Stats(s) => Ok(s),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to stats: {other:?}"),
        }
    }

    pub fn ping(&mut self) -> anyhow::Result<()> {
        match self.call(&JobRequest::Ping)? {
            JobResponse::Pong => Ok(()),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to ping: {other:?}"),
        }
    }
}
