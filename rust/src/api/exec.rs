//! The job executor: one implementation of every [`JobRequest`],
//! shared verbatim by the TCP service, the CLI and in-process callers —
//! local and remote execution are the same code path.
//!
//! * `Plan`/`Sweep` ride the HLO batcher when one is attached
//!   ([`Executor::with_batcher`]) and fall back to the closed-form
//!   model otherwise, so a service without PJRT artifacts still
//!   answers every job.
//! * `Simulate`/`BestPeriod` run on the worker pool with per-worker
//!   [`crate::sim::SimSession`] reuse and streaming
//!   [`crate::sim::ReplicationAgg`] aggregation — the same hot path as
//!   the experiment harness, at the same throughput.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::types::*;
use crate::coordinator::{available_workers, canon, Batcher, Metrics, PlanCache, PoolPanic};
use crate::experiments::scenario_for;
use crate::model::{self, Params, StrategyKind};
use crate::sim::{run_replication_range_with_cancel, SimSession};
use crate::util::cancel::CancelToken;
use crate::strategies::{
    best_period_on_platform, best_period_with, best_policy_with, resolve_policy, spec_for,
    BestPeriodOptions, PolicySpec,
};
use crate::verify::{run_conformance_filtered, VerifyOptions, VerifyReport};

/// Tuning for an [`Executor`].
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Default pool width for simulation jobs.
    pub workers: usize,
    /// Default replication count when a job asks for `reps = 0`.
    pub reps_default: u64,
    /// Default best-period grid size when a job asks for
    /// `candidates = 0`.
    pub bp_candidates_default: u64,
    /// Per-request wall-clock budget for simulation jobs, enforced
    /// cooperatively between replications. `None` disables the guard.
    pub deadline: Option<Duration>,
    /// Hard cap on replications per `simulate` job; over-cap requests
    /// are rejected up front as `bad_request` instead of admitted and
    /// later killed by the deadline.
    pub reps_cap: u64,
    /// Bounded LRU capacity for memoized `Plan`/`BestPeriod`/`Sweep`
    /// responses ([`crate::coordinator::PlanCache`]); `0` disables the
    /// cache entirely.
    pub cache_capacity: usize,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            workers: available_workers(),
            reps_default: 100,
            bp_candidates_default: 16,
            deadline: None,
            reps_cap: 10_000_000,
            cache_capacity: 512,
        }
    }
}

/// Cloneable job executor. Cheap to clone (the batcher handle and the
/// metrics registry are shared), so the service hands one to every
/// connection thread.
#[derive(Clone)]
pub struct Executor {
    batcher: Option<Batcher>,
    cfg: ExecutorConfig,
    metrics: Arc<Metrics>,
    cache: Arc<PlanCache>,
}

impl Executor {
    /// Analytic-planner executor with default tuning — the local /
    /// in-process entry point.
    pub fn local() -> Executor {
        Executor::new(ExecutorConfig::default())
    }

    pub fn new(cfg: ExecutorConfig) -> Executor {
        let cache = Arc::new(PlanCache::new(cfg.cache_capacity));
        Executor { batcher: None, cfg, metrics: Arc::new(Metrics::new()), cache }
    }

    /// Executor whose `Plan`/`Sweep` jobs ride the HLO batcher.
    pub fn with_batcher(batcher: Batcher, cfg: ExecutorConfig) -> Executor {
        let cache = Arc::new(PlanCache::new(cfg.cache_capacity));
        Executor { batcher: Some(batcher), cfg, metrics: Arc::new(Metrics::new()), cache }
    }

    pub fn batcher(&self) -> Option<&Batcher> {
        self.batcher.as_ref()
    }

    pub fn config(&self) -> &ExecutorConfig {
        &self.cfg
    }

    /// The shared response cache (one per executor family — clones
    /// share it, so every service connection sees the same entries).
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Canonical cache key for `req`, or `None` when the request is not
    /// cacheable (or the cache is disabled). Defaults are resolved
    /// *before* keying so `reps = 0` and `reps = reps_default` share an
    /// entry.
    fn cache_key(&self, req: &JobRequest) -> Option<String> {
        if !self.cache.enabled() {
            return None;
        }
        let (reps, candidates, workers) = match req {
            JobRequest::BestPeriod(job) => (
                if job.reps == 0 { self.cfg.reps_default } else { job.reps },
                if job.candidates == 0 { self.cfg.bp_candidates_default } else { job.candidates },
                self.resolve_workers(job.workers),
            ),
            _ => (0, 0, 0),
        };
        canon::request_key(req, reps, candidates, workers)
    }

    /// Execute any job; failures become [`JobResponse::Error`], never a
    /// panic or a dropped connection.
    pub fn execute(&self, req: &JobRequest) -> JobResponse {
        self.execute_cancellable(req, &CancelToken::unbounded())
    }

    /// [`Executor::execute`] under a caller-supplied [`CancelToken`]
    /// (the service threads its shutdown flag through here). The
    /// configured per-request deadline, if any, is layered on top as a
    /// child token, so either budget expiry or shutdown stops a
    /// long-running simulation between replications.
    pub fn execute_cancellable(&self, req: &JobRequest, parent: &CancelToken) -> JobResponse {
        let started = Instant::now();
        self.metrics.incr("requests", 1);
        self.metrics.incr(req.op(), 1);
        let key = self.cache_key(req);
        if let Some(k) = &key {
            if let Some(resp) = self.cache.get(k) {
                self.metrics.observe_latency(started.elapsed().as_secs_f64());
                return resp;
            }
        }
        let token = parent.child_with_deadline(self.cfg.deadline);
        let resp = match req {
            JobRequest::Plan(job) => self.plan(job).map(JobResponse::Plan),
            JobRequest::Simulate(job) => {
                self.simulate_cancellable(job, &token).map(JobResponse::Simulate)
            }
            JobRequest::BestPeriod(job) => self.best_period(job).map(JobResponse::BestPeriod),
            JobRequest::Sweep(job) => self.sweep(job).map(JobResponse::Sweep),
            JobRequest::Verify(job) => self.verify(job).map(JobResponse::Verify),
            JobRequest::Stats => Ok(JobResponse::Stats(self.stats())),
            JobRequest::Ping => Ok(JobResponse::Pong),
        };
        self.metrics.observe_latency(started.elapsed().as_secs_f64());
        match resp {
            Ok(r) => {
                // Only successful pure answers are memoized; errors
                // (validation, overload, deadline) always recompute.
                if let Some(k) = key {
                    self.cache.put(k, r.clone());
                }
                r
            }
            Err(e) => {
                self.metrics.incr("errors", 1);
                if e.code == ErrorCode::DeadlineExceeded {
                    self.metrics.incr("service.deadline_exceeded", 1);
                }
                JobResponse::Error(e)
            }
        }
    }

    /// Count a request that failed before reaching [`Executor::execute`]
    /// (malformed line, unsupported version) so `stats` sees it.
    pub fn note_rejected(&self) {
        self.metrics.incr("requests", 1);
        self.metrics.incr("errors", 1);
    }

    /// Count a request the service refused at the admission gate, so
    /// `stats` distinguishes shed load from failed work.
    pub fn note_overloaded(&self) {
        self.metrics.incr("requests", 1);
        self.metrics.incr("errors", 1);
        self.metrics.incr("service.rejected_overloaded", 1);
    }

    /// Count a panic the service contained at a request or connection
    /// boundary (outside [`Executor::execute`]'s own error mapping).
    pub fn note_panic_contained(&self) {
        self.metrics.incr("service.panics_contained", 1);
    }

    /// Map a pool-layer failure: a contained worker panic becomes
    /// `internal` (and is counted), anything else keeps the existing
    /// `bad_request` mapping for validation errors.
    fn classify_pool_error(&self, e: anyhow::Error) -> ApiError {
        if let Some(pp) = e.downcast_ref::<PoolPanic>() {
            self.metrics.incr("service.panics_contained", 1);
            ApiError::new(ErrorCode::Internal, format!("replication worker panicked: {pp}"))
        } else {
            ApiError::from_invalid(e)
        }
    }

    pub fn plan(&self, job: &PlanJob) -> Result<PlanResult, ApiError> {
        job.scenario.validate().map_err(ApiError::from_invalid)?;
        // A policy restriction: paper strategies force the winner; the
        // non-paper policies have no closed-form waste model.
        let forced = match &job.policy {
            None => None,
            Some(PolicySpec::Strategy(kind)) => Some(*kind),
            Some(other) => {
                return Err(ApiError::new(
                    ErrorCode::Unsupported,
                    format!("policy '{other}' has no closed-form plan; use the simulate job"),
                ))
            }
        };
        let params = Params::from_scenario(&job.scenario);
        let mut out = if let Some(b) = &self.batcher {
            let out = b.plan(params).map_err(ApiError::from_internal)?;
            PlanResult {
                waste: out.waste,
                period: out.period,
                winner: out.winner,
                winner_waste: out.winner_waste,
                winner_period: out.winner_period,
                q: u8::from(out.winner != StrategyKind::Young),
                via_hlo: true,
            }
        } else {
            // The batched evaluator on a one-row grid — bit-identical to
            // the scalar `model::plan` (pinned in model::batched tests).
            let p = model::plan_batched(std::slice::from_ref(&params), job.capping, true)
                .pop()
                .expect("one row in, one plan out");
            PlanResult {
                waste: p.waste,
                period: p.period,
                winner: p.winner,
                winner_waste: p.winner_waste(),
                winner_period: p.winner_period(),
                q: p.q,
                via_hlo: false,
            }
        };
        if let Some(kind) = forced {
            out.winner = kind;
            out.winner_waste = out.waste[kind as usize];
            out.winner_period = out.period[kind as usize];
            out.q = u8::from(kind != StrategyKind::Young);
        }
        Ok(out)
    }

    pub fn simulate(&self, job: &SimulateJob) -> Result<SimulateResult, ApiError> {
        self.simulate_cancellable(job, &CancelToken::unbounded())
    }

    /// [`Executor::simulate`] under a [`CancelToken`]: replications stop
    /// folding once the token trips. A tripped *deadline* with work left
    /// over becomes a structured `deadline_exceeded` error reporting the
    /// partial progress; a tripped shutdown flag returns the partial
    /// aggregate as-is (the drain path wants whatever finished).
    pub fn simulate_cancellable(
        &self,
        job: &SimulateJob,
        cancel: &CancelToken,
    ) -> Result<SimulateResult, ApiError> {
        let workers = self.resolve_workers(job.workers);
        let reps = if job.reps == 0 { self.cfg.reps_default } else { job.reps };
        if reps > self.cfg.reps_cap {
            return Err(ApiError::bad_request(format!(
                "reps = {reps} exceeds the service cap of {} replications",
                self.cfg.reps_cap
            )));
        }
        // The additive platform field: a non-`single` spec swaps the
        // session factory to the multi-node engine; `single` (or no
        // platform at all) keeps the classic path bit-identical.
        let platform = job.platform.as_ref().filter(|p| !p.is_single());
        let (name, agg) = match &job.policy {
            // The policy layer: resolve against the scenario and run on
            // the same pool path. A Strategy(...) policy is
            // bit-identical to the classic strategy field (pinned in
            // tests/test_policies.rs).
            Some(pspec) => {
                let rp = resolve_policy(pspec, &job.scenario).map_err(ApiError::from_invalid)?;
                let agg = run_replication_range_with_cancel(0, reps, workers, cancel, || {
                    match platform {
                        Some(p) => SimSession::on_platform(&rp.scenario, rp.policy, p),
                        None => SimSession::from_policy(&rp.scenario, rp.policy),
                    }
                })
                .map_err(|e| self.classify_pool_error(e))?;
                (rp.name, agg)
            }
            // EXACTPREDICTION runs against the exact-date variant of the
            // trace, per the §5 protocol — same rule as the experiments.
            None => {
                let s = scenario_for(job.strategy, &job.scenario);
                let spec = spec_for(job.strategy, &s, model::Capping::Uncapped);
                let agg = run_replication_range_with_cancel(0, reps, workers, cancel, || {
                    match platform {
                        Some(p) => SimSession::new_on_platform(&s, &spec, p),
                        None => SimSession::new(&s, &spec),
                    }
                })
                .map_err(|e| self.classify_pool_error(e))?;
                (spec.name, agg)
            }
        };
        if cancel.deadline_exceeded() && agg.n_reps < reps {
            return Err(ApiError::deadline_exceeded(format!(
                "simulate finished {} of {reps} replications before the deadline",
                agg.n_reps
            )));
        }
        Ok(SimulateResult {
            strategy: name,
            reps,
            workers: workers as u64,
            mean_waste: agg.waste.mean(),
            waste_ci95: agg.waste.ci95(),
            mean_makespan: agg.makespan.mean(),
            completion_rate: agg.completion_rate(),
            n_faults: agg.n_faults,
            n_preds: agg.n_preds,
            n_ckpts: agg.n_ckpts,
            n_proactive_ckpts: agg.n_proactive_ckpts,
            sim_seconds: agg.sim_seconds,
        })
    }

    pub fn best_period(&self, job: &BestPeriodJob) -> Result<BestPeriodOutcome, ApiError> {
        let workers = self.resolve_workers(job.workers);
        let reps = if job.reps == 0 { self.cfg.reps_default } else { job.reps };
        let candidates =
            if job.candidates == 0 { self.cfg.bp_candidates_default } else { job.candidates };
        if candidates < 2 {
            return Err(ApiError::bad_request("best_period needs at least 2 candidates"));
        }
        let opts =
            BestPeriodOptions { workers, prune: job.prune, replay: true, ..Default::default() };
        let platform = job.platform.as_ref().filter(|p| !p.is_single());
        let (name, res) = match (&job.policy, platform) {
            (Some(pspec), None) => {
                let res = best_policy_with(&job.scenario, pspec, reps, candidates as usize, &opts)
                    .map_err(ApiError::from_invalid)?;
                (pspec.to_string(), res)
            }
            // A platform search sweeps a strategy's period; the
            // non-paper policies have no platform search (their tuning
            // parameter is entangled with the single-stream hazard).
            (Some(PolicySpec::Strategy(kind)), Some(p)) => {
                let s = scenario_for(*kind, &job.scenario);
                let spec = spec_for(*kind, &s, model::Capping::Uncapped);
                let res =
                    best_period_on_platform(&s, &spec, p, reps, candidates as usize, &opts)
                        .map_err(ApiError::from_invalid)?;
                (spec.name, res)
            }
            (Some(other), Some(p)) => {
                return Err(ApiError::new(
                    ErrorCode::Unsupported,
                    format!("policy '{other}' cannot be searched on platform '{p}'"),
                ))
            }
            (None, _) => {
                let s = scenario_for(job.strategy, &job.scenario);
                let spec = spec_for(job.strategy, &s, model::Capping::Uncapped);
                let res = match platform {
                    Some(p) => best_period_on_platform(&s, &spec, p, reps, candidates as usize, &opts),
                    None => best_period_with(&s, &spec, reps, candidates as usize, &opts),
                }
                .map_err(ApiError::from_invalid)?;
                (spec.name, res)
            }
        };
        Ok(BestPeriodOutcome {
            strategy: name,
            t_r: res.t_r,
            waste: res.waste,
            n_pruned: res.n_pruned as u64,
            sweep: res.sweep,
            reps,
            candidates,
            workers: workers as u64,
            reps_used: res.reps_used,
        })
    }

    pub fn sweep(&self, job: &SweepJob) -> Result<SweepResult, ApiError> {
        if job.n_procs.is_empty() {
            return Err(ApiError::bad_request("sweep needs at least one n_procs entry"));
        }
        let mut scenarios = Vec::with_capacity(job.n_procs.len());
        for &n in &job.n_procs {
            let mut s = job.base.clone();
            s.platform.n_procs = n;
            s.validate()
                .map_err(|e| ApiError::bad_request(format!("sweep n_procs = {n}: {e:#}")))?;
            scenarios.push(s);
        }
        let params: Vec<Params> = scenarios.iter().map(Params::from_scenario).collect();
        let (outs, via_hlo) = if let Some(b) = &self.batcher {
            let outs = b.plan_many(params).map_err(ApiError::from_internal)?;
            let rows = outs
                .into_iter()
                .map(|o| (o.winner, o.winner_waste, o.winner_period))
                .collect::<Vec<_>>();
            (rows, true)
        } else {
            // One vectorized pass over the whole parameter grid instead
            // of a per-row scalar plan; bit-identical (model::batched).
            let rows = model::plan_batched(&params, job.capping, true)
                .into_iter()
                .map(|plan| (plan.winner, plan.winner_waste(), plan.winner_period()))
                .collect::<Vec<_>>();
            (rows, false)
        };
        let rows = scenarios
            .iter()
            .zip(outs)
            .map(|(s, (winner, winner_waste, winner_period))| SweepRow {
                n_procs: s.platform.n_procs,
                mu: s.mu(),
                winner,
                winner_waste,
                winner_period,
            })
            .collect();
        Ok(SweepResult { rows, via_hlo })
    }

    /// Evaluate the full (strategy × scenario) waste grid, riding the
    /// HLO batcher when one is attached. The PJRT artifacts bake in
    /// the uncapped closed forms, so only `Capping::Uncapped` grids
    /// are eligible for the accelerator; capped grids (and every grid
    /// on a batcher-less executor) take the vectorized CPU pass —
    /// which also stays the bit-equality reference, because the HLO
    /// pipeline computes in f32. Returns the grid plus whether the
    /// accelerator served it.
    pub fn waste_grid(
        &self,
        params: &[Params],
        capping: model::Capping,
    ) -> Result<(model::WasteGrid, bool), ApiError> {
        if capping == model::Capping::Uncapped {
            if let Some(b) = &self.batcher {
                let grid = b.waste_grid(params.to_vec()).map_err(ApiError::from_internal)?;
                return Ok((grid, true));
            }
        }
        Ok((model::waste_grid_batched(params, capping), false))
    }

    /// Run the conformance grid (the `verify` subsystem) on the worker
    /// pool. Deterministic for a fixed `(grid, reps, budget, workers)`
    /// tuple — a TCP-served `Verify` is bit-identical to the in-process
    /// run (pinned in `tests/test_verify.rs`).
    pub fn verify(&self, job: &VerifyJob) -> Result<VerifyReport, ApiError> {
        let workers = self.resolve_workers(job.workers);
        let (d_reps, d_budget) = job.grid.default_budget();
        let reps0 = if job.reps == 0 { d_reps } else { job.reps };
        let budget = if job.budget == 0 { d_budget.max(reps0) } else { job.budget.max(reps0) };
        let opts = VerifyOptions { reps0, budget, workers, ..Default::default() };
        run_conformance_filtered(job.grid, job.policy.as_ref(), job.platform.as_ref(), &opts)
            .map_err(ApiError::from_invalid)
    }

    pub fn stats(&self) -> ServiceStats {
        let (p50, p95, p99, n) = self.metrics.latency_quantiles();
        let finite = |x: f64| if x.is_finite() { x } else { 0.0 };
        let bank = crate::trace::bank::counters();
        let batch = crate::sim::batch::counters();
        let wide = crate::sim::wide::counters();
        let cache = self.cache.snapshot();
        ServiceStats {
            requests: self.metrics.get("requests"),
            errors: self.metrics.get("errors"),
            plans: self.metrics.get("plan"),
            simulates: self.metrics.get("simulate"),
            best_periods: self.metrics.get("best_period"),
            sweeps: self.metrics.get("sweep"),
            verifies: self.metrics.get("verify"),
            lat_p50_s: finite(p50),
            lat_p95_s: finite(p95),
            lat_p99_s: finite(p99),
            lat_n: n as u64,
            banks_built: bank.banks_built,
            bank_replays: bank.replays_served,
            bank_fallbacks: bank.fallbacks_taken,
            bank_bytes_resident: bank.bytes_resident,
            rejected_overloaded: self.metrics.get("service.rejected_overloaded"),
            deadline_exceeded: self.metrics.get("service.deadline_exceeded"),
            panics_contained: self.metrics.get("service.panics_contained"),
            client_retries: super::client::client_retries(),
            batch_lanes_run: batch.lanes_run,
            batch_lane_fallbacks: batch.lane_fallbacks,
            wide_lanes_run: wide.lanes_run,
            wide_evictions: wide.evictions,
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            cache_evictions: cache.evictions,
            cache_entries: cache.entries,
            batcher: self.batcher.as_ref().map(|b| {
                let s = b.stats();
                BatcherSnapshot {
                    requests: s.requests,
                    batches: s.batches,
                    max_batch: s.max_batch_seen,
                }
            }),
        }
    }

    fn resolve_workers(&self, requested: Option<u64>) -> usize {
        match requested {
            Some(w) => (w as usize).max(1),
            None => self.cfg.workers.max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::dist::DistSpec;
    use crate::model::Capping;
    use crate::sim::run_replications_parallel;

    fn small_scenario() -> Scenario {
        let mut s = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
        s.fault_dist = DistSpec::Exp;
        s.work = 2.0e5;
        s
    }

    #[test]
    fn plan_falls_back_to_analytic() {
        let exec = Executor::local();
        let res = exec.plan(&PlanJob::new(small_scenario())).unwrap();
        assert!(!res.via_hlo);
        assert!(res.winner_waste > 0.0 && res.winner_waste < 1.0);
        // ExactPrediction beats Young under a good exact predictor.
        assert!(res.waste[StrategyKind::ExactPrediction as usize] < res.waste[StrategyKind::Young as usize]);
        assert_eq!(res.q, u8::from(res.winner != StrategyKind::Young));
    }

    #[test]
    fn plan_rejects_invalid_scenario() {
        let mut s = small_scenario();
        s.work = -1.0;
        let err = Executor::local().plan(&PlanJob::new(s)).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn simulate_matches_direct_pool_run() {
        let exec = Executor::local();
        let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
        job.reps = 8;
        job.workers = Some(2);
        let res = exec.simulate(&job).unwrap();
        assert_eq!(res.reps, 8);
        assert_eq!(res.workers, 2);
        let spec = spec_for(StrategyKind::Young, &small_scenario(), Capping::Uncapped);
        let direct = run_replications_parallel(&small_scenario(), &spec, 8, 2).unwrap();
        assert_eq!(res.mean_waste.to_bits(), direct.agg.waste.mean().to_bits());
        assert_eq!(res.n_faults, direct.agg.n_faults);
    }

    #[test]
    fn simulate_resolves_defaults() {
        let exec = Executor::new(ExecutorConfig { reps_default: 3, ..Default::default() });
        let res = exec.simulate(&SimulateJob::new(small_scenario(), StrategyKind::Young)).unwrap();
        assert_eq!(res.reps, 3);
    }

    #[test]
    fn best_period_guards_degenerate_grid() {
        let exec = Executor::local();
        let mut job = BestPeriodJob::new(small_scenario(), StrategyKind::Young);
        job.candidates = 1;
        assert_eq!(exec.best_period(&job).unwrap_err().code, ErrorCode::BadRequest);
    }

    #[test]
    fn sweep_rows_follow_mu() {
        let exec = Executor::local();
        let res = exec
            .sweep(&SweepJob {
                base: small_scenario(),
                n_procs: vec![1 << 16, 1 << 19],
                capping: Capping::Uncapped,
            })
            .unwrap();
        assert_eq!(res.rows.len(), 2);
        assert!(res.rows[0].mu > res.rows[1].mu, "MTBF shrinks with N");
        assert!(res.rows[0].winner_waste < res.rows[1].winner_waste);
        assert!(exec.sweep(&SweepJob {
            base: small_scenario(),
            n_procs: vec![],
            capping: Capping::Uncapped
        })
        .is_err());
    }

    #[test]
    fn plan_with_paper_policy_forces_the_winner() {
        let exec = Executor::local();
        let mut job = PlanJob::new(small_scenario());
        job.policy = Some(PolicySpec::Strategy(StrategyKind::Young));
        let res = exec.plan(&job).unwrap();
        assert_eq!(res.winner, StrategyKind::Young);
        assert_eq!(res.winner_waste, res.waste[StrategyKind::Young as usize]);
        assert_eq!(res.winner_period, res.period[StrategyKind::Young as usize]);
        assert_eq!(res.q, 0);
        // The per-strategy arrays are the full plan, unchanged.
        let free = exec.plan(&PlanJob::new(small_scenario())).unwrap();
        assert_eq!(res.waste, free.waste);
    }

    #[test]
    fn plan_rejects_non_paper_policies_as_unsupported() {
        let exec = Executor::local();
        let mut job = PlanJob::new(small_scenario());
        job.policy = Some(PolicySpec::RiskThreshold { kappa: 1.0 });
        let err = exec.plan(&job).unwrap_err();
        assert_eq!(err.code, ErrorCode::Unsupported);
        assert!(err.message.contains("risk:1"), "{}", err.message);
    }

    #[test]
    fn simulate_policy_strategy_matches_strategy_field() {
        // `policy: "exactprediction"` and `strategy: ExactPrediction`
        // are the same execution, bit for bit — including the
        // exact-date trace rule.
        let exec = Executor::local();
        let mut classic = SimulateJob::new(small_scenario(), StrategyKind::ExactPrediction);
        classic.reps = 6;
        classic.workers = Some(2);
        let mut via_policy = classic.clone();
        via_policy.policy = Some(PolicySpec::Strategy(StrategyKind::ExactPrediction));
        let a = exec.simulate(&classic).unwrap();
        let b = exec.simulate(&via_policy).unwrap();
        assert_eq!(a.strategy, b.strategy);
        assert_eq!(a.mean_waste.to_bits(), b.mean_waste.to_bits());
        assert_eq!(a.n_faults, b.n_faults);
        assert_eq!(a.n_ckpts, b.n_ckpts);
    }

    #[test]
    fn simulate_runs_non_paper_policies_end_to_end() {
        let exec = Executor::local();
        for policy in [
            PolicySpec::AdaptivePeriod { gain: 1.0 },
            PolicySpec::RiskThreshold { kappa: 1.0 },
        ] {
            let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
            job.reps = 6;
            job.workers = Some(2);
            job.policy = Some(policy);
            let res = exec.simulate(&job).unwrap();
            assert_eq!(res.strategy, policy.to_string());
            assert_eq!(res.completion_rate, 1.0, "{policy}");
            assert!(res.mean_waste > 0.0 && res.mean_waste < 1.0, "{policy}");
            assert!(res.n_ckpts > 0, "{policy}");
        }
    }

    #[test]
    fn best_period_sweeps_policy_parameters() {
        let exec = Executor::local();
        let mut job = BestPeriodJob::new(small_scenario(), StrategyKind::Young);
        job.reps = 4;
        job.candidates = 4;
        job.workers = Some(2);
        job.policy = Some(PolicySpec::RiskThreshold { kappa: 1.0 });
        let res = exec.best_period(&job).unwrap();
        assert_eq!(res.strategy, "risk:1");
        assert_eq!(res.sweep.len(), 4);
        assert!(res.t_r >= 0.25 && res.t_r <= 4.0, "kappa {}", res.t_r);
    }

    #[test]
    fn verify_resolves_defaults_and_filters() {
        let exec = Executor::local();
        let mut job = VerifyJob::new(crate::verify::GridKind::Quick);
        job.policy = Some(PolicySpec::RiskThreshold { kappa: 1.0 });
        job.reps = 2;
        job.budget = 2;
        job.workers = Some(2);
        let r = exec.verify(&job).unwrap();
        assert_eq!(r.workers, 2);
        assert!(!r.cases.is_empty());
        assert!(r.cases.iter().all(|c| c.policy == "risk:1"));
        // A filter with no grid presence is a bad request, not an
        // empty (vacuously green) report.
        job.policy = Some(PolicySpec::AdaptivePeriod { gain: 9.0 });
        assert_eq!(exec.verify(&job).unwrap_err().code, ErrorCode::BadRequest);
    }

    #[test]
    fn simulate_on_a_single_platform_is_the_classic_path() {
        // platform: "single" must not perturb a bit of the classic result.
        let exec = Executor::local();
        let mut classic = SimulateJob::new(small_scenario(), StrategyKind::Young);
        classic.reps = 6;
        classic.workers = Some(2);
        let mut on_platform = classic.clone();
        on_platform.platform = Some(crate::sim::PlatformSpec::default());
        let a = exec.simulate(&classic).unwrap();
        let b = exec.simulate(&on_platform).unwrap();
        assert_eq!(a.mean_waste.to_bits(), b.mean_waste.to_bits());
        assert_eq!(a.n_faults, b.n_faults);
        assert_eq!(a.n_ckpts, b.n_ckpts);
    }

    #[test]
    fn simulate_runs_multi_node_platforms_end_to_end() {
        let exec = Executor::local();
        let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
        job.reps = 6;
        job.workers = Some(2);
        job.platform = Some("nodes=4".parse().unwrap());
        let res = exec.simulate(&job).unwrap();
        assert_eq!(res.completion_rate, 1.0);
        assert!(res.mean_waste > 0.0 && res.mean_waste < 1.0);
        assert!(res.n_faults > 0);
        // The policy path reaches the platform engine too.
        job.policy = Some(PolicySpec::RiskThreshold { kappa: 1.0 });
        let res = exec.simulate(&job).unwrap();
        assert!(res.mean_waste > 0.0 && res.mean_waste < 1.0);
    }

    #[test]
    fn best_period_platform_rejects_non_strategy_policies() {
        let exec = Executor::local();
        let mut job = BestPeriodJob::new(small_scenario(), StrategyKind::Young);
        job.reps = 2;
        job.candidates = 3;
        job.platform = Some("nodes=4".parse().unwrap());
        job.policy = Some(PolicySpec::RiskThreshold { kappa: 1.0 });
        let err = exec.best_period(&job).unwrap_err();
        assert_eq!(err.code, ErrorCode::Unsupported);
        assert!(err.message.contains("nodes=4"), "{}", err.message);
        // A Strategy(...) policy (and the plain strategy field) search fine.
        job.policy = Some(PolicySpec::Strategy(StrategyKind::Young));
        assert!(exec.best_period(&job).is_ok());
    }

    #[test]
    fn simulate_rejects_over_cap_reps() {
        let exec = Executor::new(ExecutorConfig { reps_cap: 10, ..Default::default() });
        let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
        job.reps = 11;
        let err = exec.simulate(&job).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("cap"), "{}", err.message);
        job.reps = 10;
        assert!(exec.simulate(&job).is_ok());
    }

    #[test]
    fn expired_deadline_reports_partial_progress() {
        // A zero wall-clock budget trips before the first replication,
        // so the guard fires deterministically regardless of host speed.
        let exec = Executor::new(ExecutorConfig {
            deadline: Some(std::time::Duration::ZERO),
            ..Default::default()
        });
        let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
        job.reps = 4;
        match exec.execute(&JobRequest::Simulate(job)) {
            JobResponse::Error(e) => {
                assert_eq!(e.code, ErrorCode::DeadlineExceeded);
                assert!(e.message.contains("0 of 4"), "{}", e.message);
            }
            other => panic!("expected deadline error, got {other:?}"),
        }
        let stats = exec.stats();
        assert_eq!(stats.deadline_exceeded, 1);
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn shutdown_flag_returns_partial_results_not_an_error() {
        // A tripped shutdown flag (no deadline) is a drain, not a
        // failure: the partial aggregate comes back as a success.
        let exec = Executor::local();
        let flag = Arc::new(std::sync::atomic::AtomicBool::new(true));
        let mut job = SimulateJob::new(small_scenario(), StrategyKind::Young);
        job.reps = 4;
        let res = exec
            .simulate_cancellable(&job, &CancelToken::with_flag(flag))
            .unwrap();
        assert_eq!(res.reps, 4);
        assert_eq!(res.n_faults, 0, "no replication ran under a pre-tripped flag");
    }

    #[test]
    fn repeat_plans_are_served_from_cache_bit_identically() {
        let exec = Executor::local();
        let req = JobRequest::Plan(PlanJob::new(small_scenario()));
        let cold = exec.execute(&req);
        let hot = exec.execute(&req);
        // The acceptance pin: a cached response is byte-for-byte the
        // uncached one on the wire, not merely approximately equal.
        assert_eq!(
            crate::api::wire::encode_response(&cold, false),
            crate::api::wire::encode_response(&hot, false),
        );
        let stats = exec.stats();
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(stats.cache_misses, 1);
        assert_eq!(stats.cache_entries, 1);
        assert_eq!(stats.requests, 2, "a hit still counts as a request");
    }

    #[test]
    fn cache_keys_resolve_defaults_before_keying() {
        // `reps = 0` (use the default) and an explicit `reps =
        // reps_default` are the same computation, so they must share a
        // cache entry.
        let exec = Executor::new(ExecutorConfig {
            reps_default: 2,
            bp_candidates_default: 2,
            ..Default::default()
        });
        let implicit = BestPeriodJob::new(small_scenario(), StrategyKind::Young);
        let mut explicit = implicit.clone();
        explicit.reps = 2;
        explicit.candidates = 2;
        explicit.workers = Some(exec.config().workers as u64);
        let a = exec.execute(&JobRequest::BestPeriod(implicit));
        let b = exec.execute(&JobRequest::BestPeriod(explicit));
        assert_eq!(a, b);
        assert_eq!(exec.stats().cache_hits, 1);
    }

    #[test]
    fn zero_cache_capacity_recomputes_every_request() {
        let exec = Executor::new(ExecutorConfig { cache_capacity: 0, ..Default::default() });
        let req = JobRequest::Plan(PlanJob::new(small_scenario()));
        assert_eq!(exec.execute(&req), exec.execute(&req));
        let stats = exec.stats();
        assert_eq!(stats.cache_hits, 0);
        assert_eq!(stats.cache_misses, 0);
        assert_eq!(stats.cache_entries, 0);
    }

    #[test]
    fn errors_and_impure_jobs_are_never_cached() {
        let exec = Executor::local();
        let mut bad = small_scenario();
        bad.work = -1.0;
        exec.execute(&JobRequest::Plan(PlanJob::new(bad)));
        // Simulate is seeded per-replication but reports wall-clock
        // time, so it is deliberately uncacheable.
        let mut sim = SimulateJob::new(small_scenario(), StrategyKind::Young);
        sim.reps = 2;
        exec.execute(&JobRequest::Simulate(sim));
        assert_eq!(exec.stats().cache_entries, 0);
    }

    #[test]
    fn overload_notes_show_up_in_stats() {
        let exec = Executor::local();
        exec.note_overloaded();
        exec.note_overloaded();
        let stats = exec.stats();
        assert_eq!(stats.rejected_overloaded, 2);
        assert_eq!(stats.requests, 2, "a shed request still counts as a request");
        assert_eq!(stats.errors, 2);
    }

    #[test]
    fn execute_counts_requests_and_errors() {
        let exec = Executor::local();
        assert_eq!(exec.execute(&JobRequest::Ping), JobResponse::Pong);
        let mut bad = small_scenario();
        bad.work = -1.0;
        match exec.execute(&JobRequest::Plan(PlanJob::new(bad))) {
            JobResponse::Error(e) => assert_eq!(e.code, ErrorCode::BadRequest),
            other => panic!("expected error, got {other:?}"),
        }
        let stats = exec.stats();
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.errors, 1);
        assert!(stats.batcher.is_none());
        match exec.execute(&JobRequest::Stats) {
            JobResponse::Stats(s) => assert_eq!(s.requests, 3),
            other => panic!("expected stats, got {other:?}"),
        }
    }
}
