//! The statistical comparator: CI-aware verdicts over the parallel
//! replication runner, with automatic replication escalation.
//!
//! A case is judged by where the 95% confidence interval of its
//! simulated mean waste lands relative to the oracle band:
//!
//! * CI entirely **inside** the band → [`Verdict::Pass`];
//! * CI entirely **outside** the band → [`Verdict::Fail`];
//! * CI **straddles** a band edge → the sample is not yet decisive:
//!   the comparator doubles the replication count (extending the
//!   existing aggregate — earlier replications are never re-simulated)
//!   until the verdict resolves or the budget is exhausted, in which
//!   case the case reports [`Verdict::Inconclusive`].
//!
//! No magic epsilons anywhere: the only tolerances are the oracle's
//! stated band and the sample's own confidence interval. The whole
//! procedure is deterministic for a fixed `(reps0, budget, workers)` —
//! the property the TCP-vs-in-process acceptance pin relies on.

use std::sync::Arc;

use super::grid::ConformanceCase;
use super::oracle::{oracle_for, Domain};
use crate::coordinator::available_workers;
use crate::sim::{
    run_replication_range_batched, run_replication_range_with, BatchEngine, BatchOptions,
    BatchRunner, ReplicationAgg, SimSession, WideKernel,
};
use crate::strategies::resolve_policy;
use crate::trace::TraceBank;

/// Comparator tuning. `reps0` is the first batch; escalation doubles
/// the total until it reaches `budget`. `batch` sets the lockstep lane
/// width for bank-backed escalation rounds (pinned bit-identical to the
/// scalar replay path; `BatchOptions::scalar()` pins the scalar path).
#[derive(Debug, Clone)]
pub struct VerifyOptions {
    pub reps0: u64,
    pub budget: u64,
    pub workers: usize,
    pub batch: BatchOptions,
}

impl Default for VerifyOptions {
    fn default() -> Self {
        VerifyOptions {
            reps0: 32,
            budget: 256,
            workers: available_workers(),
            batch: BatchOptions::default(),
        }
    }
}

/// Outcome of one conformance case.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// The simulated CI lies inside the oracle band.
    Pass,
    /// The simulated CI lies outside the oracle band (or replications
    /// hit the makespan guard).
    Fail,
    /// The CI still straddles a band edge after the full budget.
    Inconclusive,
}

impl Verdict {
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Fail => "fail",
            Verdict::Inconclusive => "inconclusive",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Verdict> {
        match s {
            "pass" => Ok(Verdict::Pass),
            "fail" => Ok(Verdict::Fail),
            "inconclusive" => Ok(Verdict::Inconclusive),
            other => anyhow::bail!("unknown verdict '{other}'"),
        }
    }
}

impl std::fmt::Display for Verdict {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The judged result of one case — everything `CONFORMANCE.json`
/// records about it.
#[derive(Debug, Clone, PartialEq)]
pub struct CaseVerdict {
    pub name: String,
    /// Display form of the subject policy spec.
    pub policy: String,
    pub domain: Domain,
    /// The oracle's analytic prediction (or out-of-domain reference).
    pub analytic: f64,
    /// The oracle band the CI was tested against.
    pub band: (f64, f64),
    pub sim_mean: f64,
    pub sim_ci95: f64,
    pub completion_rate: f64,
    /// Replications actually spent (after escalation).
    pub reps: u64,
    pub verdict: Verdict,
}

/// Classify one aggregate against a band. Replications that hit the
/// makespan guard poison the waste mean, so any incompletion is an
/// immediate failure.
fn classify(agg: &ReplicationAgg, band: (f64, f64)) -> Verdict {
    if agg.n_completed < agg.n_reps {
        return Verdict::Fail;
    }
    let mean = agg.waste.mean();
    let ci = agg.waste.ci95();
    let (lo, hi) = (mean - ci, mean + ci);
    if lo >= band.0 && hi <= band.1 {
        Verdict::Pass
    } else if hi < band.0 || lo > band.1 {
        Verdict::Fail
    } else {
        Verdict::Inconclusive
    }
}

/// Judge one conformance case: oracle, replication batches with
/// escalation, final verdict.
///
/// Replication batches replay a per-case [`TraceBank`] when one fits:
/// each escalation round *extends* the bank to the new target (new
/// reps are materialized once; earlier reps' arenas are untouched)
/// instead of re-sampling anything — the common-random-numbers
/// discipline applied to the doubling. Outcomes are bit-identical to
/// the live path, so verdicts are unchanged by the bank's presence
/// (underruns and oversized cases transparently run live).
pub fn judge_case(case: &ConformanceCase, opts: &VerifyOptions) -> anyhow::Result<CaseVerdict> {
    let oracle = oracle_for(case)?;
    let rp = resolve_policy(&case.subject, &case.scenario)?;
    let reps0 = opts.reps0.max(2);
    let budget = opts.budget.max(reps0);

    // Reserve the bank against the full escalation budget (a bank that
    // would blow the arena cap at the deepest doubling is declined up
    // front), but materialize lazily, one round at a time. Multi-node
    // platform cases always run live: the bank stores one flat trace
    // per replication, not K per-node substreams.
    let lead = rp.policy.required_lead(rp.scenario.platform.c);
    let mut bank = if case.platform.is_single() {
        TraceBank::try_reserve(&rp.scenario, lead, budget)?
    } else {
        None
    };

    let mut agg = ReplicationAgg::default();
    let mut done = 0u64;
    let verdict = loop {
        let target = if done == 0 { reps0 } else { (done * 2).min(budget) };
        if let Some(b) = &mut bank {
            b.ensure_reps(target);
        }
        // Workers share the bank read-only for the round; it is handed
        // back for extension once the round's sessions are gone.
        let shared = bank.take().map(Arc::new);
        let chunk = match &shared {
            // Bank-backed rounds advance in batch chunks by default —
            // the wide SoA kernel unless the caller opted back to the
            // per-lane lockstep engines; both bit-identical to the
            // scalar replay fold below.
            Some(b) if opts.batch.lanes > 0 && opts.batch.wide => {
                run_replication_range_batched(done, target, opts.workers, || {
                    Ok(BatchRunner::Wide(WideKernel::new(
                        b.clone(),
                        &rp.scenario,
                        rp.policy,
                        opts.batch.lanes,
                    )?))
                })?
            }
            Some(b) if opts.batch.lanes > 0 => {
                run_replication_range_batched(done, target, opts.workers, || {
                    Ok(BatchRunner::Lockstep(BatchEngine::new(
                        b.clone(),
                        &rp.scenario,
                        rp.policy,
                        opts.batch.lanes,
                    )?))
                })?
            }
            _ => run_replication_range_with(done, target, opts.workers, || match &shared {
                Some(b) => SimSession::replay(b.clone(), &rp.scenario, rp.policy),
                None if !case.platform.is_single() => {
                    SimSession::on_platform(&rp.scenario, rp.policy, &case.platform)
                        .expect("platform spec validated when the grid was built")
                }
                None => SimSession::from_policy(&rp.scenario, rp.policy),
            })?,
        };
        bank = shared.and_then(|a| Arc::try_unwrap(a).ok());
        agg = agg.merge(chunk);
        done = target;
        let v = classify(&agg, oracle.band);
        if v != Verdict::Inconclusive || done >= budget {
            break v;
        }
    };

    Ok(CaseVerdict {
        name: case.name.clone(),
        policy: case.subject.to_string(),
        domain: oracle.domain,
        analytic: oracle.analytic,
        band: oracle.band,
        sim_mean: agg.waste.mean(),
        sim_ci95: agg.waste.ci95(),
        completion_rate: agg.completion_rate(),
        reps: done,
        verdict,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;
    use crate::verify::grid::{conformance_grid, GridKind};

    fn agg_of(values: &[f64], completed: bool) -> ReplicationAgg {
        let mut agg = ReplicationAgg::default();
        for &v in values {
            agg.waste.push(v);
            agg.makespan.push(1.0);
            agg.n_reps += 1;
            agg.n_completed += completed as u64;
        }
        agg
    }

    #[test]
    fn classify_pass_fail_inconclusive() {
        // Tight sample inside the band.
        let inside = agg_of(&[0.10, 0.101, 0.099, 0.1, 0.1005, 0.0995], true);
        assert_eq!(classify(&inside, (0.08, 0.12)), Verdict::Pass);
        // Tight sample far outside.
        let outside = agg_of(&[0.30, 0.301, 0.299, 0.3, 0.3005, 0.2995], true);
        assert_eq!(classify(&outside, (0.08, 0.12)), Verdict::Fail);
        // Sample whose CI straddles the upper edge.
        let straddle = agg_of(&[0.08, 0.16, 0.09, 0.15, 0.10, 0.14], true);
        let s = Summary::from_iter([0.08, 0.16, 0.09, 0.15, 0.10, 0.14]);
        assert!(s.mean() - s.ci95() < 0.12 && s.mean() + s.ci95() > 0.12);
        assert_eq!(classify(&straddle, (0.02, 0.12)), Verdict::Inconclusive);
    }

    #[test]
    fn incomplete_replications_fail_outright() {
        let agg = agg_of(&[0.1, 0.1, 0.1], false);
        assert_eq!(classify(&agg, (0.0, 1.0)), Verdict::Fail);
    }

    #[test]
    fn verdict_names_round_trip() {
        for v in [Verdict::Pass, Verdict::Fail, Verdict::Inconclusive] {
            assert_eq!(Verdict::parse(v.name()).unwrap(), v);
        }
        assert!(Verdict::parse("maybe").is_err());
    }

    #[test]
    fn judge_respects_the_budget_and_is_deterministic() {
        let case = conformance_grid(GridKind::Quick)
            .into_iter()
            .find(|c| c.name == "exp-n16-none-Young")
            .unwrap();
        let opts = VerifyOptions { reps0: 4, budget: 13, workers: 2, ..Default::default() };
        let a = judge_case(&case, &opts).unwrap();
        // Escalation path is 4 -> 8 -> 13; whatever the verdict, the
        // spend never exceeds the budget.
        assert!(a.reps == 4 || a.reps == 8 || a.reps == 13, "reps {}", a.reps);
        assert_eq!(a.completion_rate, 1.0);
        let b = judge_case(&case, &opts).unwrap();
        assert_eq!(a, b, "judgement must be deterministic for fixed options");
    }

    #[test]
    fn judge_runs_platform_cases_live() {
        // The multi-node case declines the trace bank and still judges
        // deterministically; Poisson superposition keeps it in the same
        // first-order band as its single-stream twin, so with a real
        // budget it must not confidently fail.
        let case = conformance_grid(GridKind::Quick)
            .into_iter()
            .find(|c| c.name == "exp-n16-none-Young@nodes=4")
            .unwrap();
        let opts = VerifyOptions { reps0: 16, budget: 64, workers: 2, ..Default::default() };
        let a = judge_case(&case, &opts).unwrap();
        assert_ne!(a.verdict, Verdict::Fail, "{a:?}");
        assert_eq!(a.completion_rate, 1.0);
        let b = judge_case(&case, &opts).unwrap();
        assert_eq!(a, b, "platform judgement must be deterministic");
    }

    #[test]
    fn judge_in_domain_case_does_not_fail() {
        // The headline conformance property on one cheap case: Young on
        // Exponential faults agrees with Eq. (1) — at worst the small
        // budget leaves it inconclusive, it must never confidently fail.
        let case = conformance_grid(GridKind::Quick)
            .into_iter()
            .find(|c| c.name == "exp-n16-none-Young")
            .unwrap();
        let opts = VerifyOptions { reps0: 24, budget: 96, workers: 2, ..Default::default() };
        let v = judge_case(&case, &opts).unwrap();
        assert_ne!(v.verdict, Verdict::Fail, "{v:?}");
        assert!(v.sim_mean > 0.0 && v.sim_mean < 1.0);
        assert!(v.domain.is_first_order());
    }
}
