//! Integration tests of the `sim::platform` subsystem through the
//! public API: the 1-node golden equivalence against the classic
//! engine, multi-node sanity, and error paths.

use ckptfp::config::{Predictor, Scenario};
use ckptfp::model::{Capping, StrategyKind};
use ckptfp::sim::{Outcome, PlatformSpec, SimSession};
use ckptfp::strategies::spec_for;

fn scenario(window: f64) -> Scenario {
    let pred = if window > 0.0 {
        Predictor::windowed(0.85, 0.82, window)
    } else {
        Predictor::exact(0.85, 0.82)
    };
    let mut s = Scenario::paper(1 << 16, pred);
    s.fault_dist = ckptfp::dist::DistSpec::Exp;
    s.work = 2.0e5;
    s
}

/// Every Outcome field except the wall-clock `sim_seconds` timer.
fn fields(o: &Outcome) -> Vec<u64> {
    vec![
        o.makespan.to_bits(),
        o.work.to_bits(),
        o.completed as u64,
        o.n_faults,
        o.n_faults_unpredicted,
        o.n_preds,
        o.n_true_preds,
        o.n_trusted,
        o.n_ckpts,
        o.n_proactive_ckpts,
        o.n_migrations,
        o.n_faults_avoided,
        o.lost_work.to_bits(),
        o.n_segments,
    ]
}

#[test]
fn golden_one_node_platform_is_bit_identical_to_the_classic_engine() {
    // The ISSUE's acceptance pin: at the default (single) spec the
    // platform layer must be the identity — every Outcome field, every
    // strategy, several replications.
    for kind in StrategyKind::ALL {
        let s = ckptfp::experiments::scenario_for(kind, &scenario(300.0));
        let spec = spec_for(kind, &s, Capping::Uncapped);
        let mut classic = SimSession::new(&s, &spec).unwrap();
        let mut platform =
            SimSession::new_on_platform(&s, &spec, &PlatformSpec::default()).unwrap();
        assert!(platform.is_platform());
        for rep in [0u64, 1, 5, 2] {
            let a = classic.run(rep);
            let b = platform.run(rep);
            assert_eq!(fields(&a), fields(&b), "{} rep {rep}", kind.name());
        }
    }
}

#[test]
fn multi_node_uncorrelated_platform_matches_the_single_stream_statistically() {
    // Poisson superposition at the outcome level: waste on K merged
    // per-node streams tracks the classic single-stream waste.
    let s = scenario(0.0);
    let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let reps = 24;
    let mean = |session: &mut SimSession| -> f64 {
        (0..reps).map(|r| session.run(r).waste()).sum::<f64>() / reps as f64
    };
    let mut classic = SimSession::new(&s, &spec).unwrap();
    let w1 = mean(&mut classic);
    let pspec: PlatformSpec = "nodes=8".parse().unwrap();
    let mut platform = SimSession::new_on_platform(&s, &spec, &pspec).unwrap();
    let w8 = mean(&mut platform);
    assert!(w1 > 0.0 && w8 > 0.0);
    assert!(
        (w1 - w8).abs() < 0.35 * w1.max(w8),
        "classic waste {w1} vs 8-node {w8}"
    );
}

#[test]
fn commit_contention_raises_waste() {
    // A store whose commit cost scales with K makes checkpoints more
    // expensive, so waste at the same period must go up.
    let s = scenario(0.0);
    let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let reps = 16;
    let run = |p: &PlatformSpec| -> f64 {
        let mut session = SimSession::new_on_platform(&s, &spec, p).unwrap();
        (0..reps).map(|r| session.run(r).waste()).sum::<f64>() / reps as f64
    };
    let flat = run(&"nodes=8".parse().unwrap());
    let contended = run(&"nodes=8,commit=0.5".parse().unwrap());
    assert!(
        contended > flat,
        "contended waste {contended} <= flat {flat}"
    );
}

#[test]
fn correlated_platform_wastes_more_than_uncorrelated() {
    // Spatially-correlated failures inject extra (unpredicted) faults,
    // which can only hurt.
    let s = scenario(0.0);
    let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let reps = 16;
    let run = |p: &PlatformSpec| -> f64 {
        let mut session = SimSession::new_on_platform(&s, &spec, p).unwrap();
        (0..reps).map(|r| session.run(r).waste()).sum::<f64>() / reps as f64
    };
    let flat = run(&"nodes=8".parse().unwrap());
    let corr = run(&"nodes=8,group=4,spatial=0.5,cascade=0.2".parse().unwrap());
    assert!(corr > flat, "correlated waste {corr} <= uncorrelated {flat}");
}

#[test]
fn bad_platform_specs_error_through_the_public_api() {
    let s = scenario(0.0);
    let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let err = SimSession::new_on_platform(
        &s,
        &spec,
        &PlatformSpec { nodes: 0, ..PlatformSpec::default() },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("at least one node"), "{err}");
    assert!("nodes=4,spatial=1.5".parse::<PlatformSpec>().is_err());
    assert!("nodes=4,restart=half".parse::<PlatformSpec>().is_err());
    assert!("bogus".parse::<PlatformSpec>().is_err());
}

#[test]
fn platform_spec_round_trips_through_display() {
    for raw in [
        "single",
        "nodes=4",
        "nodes=8,commit=0.05",
        "nodes=8,restart=partial",
        "nodes=8,group=4,spatial=0.25,cascade=0.1",
        "nodes=16,commit=0.1,restart=partial,group=4,spatial=0.25,cascade=0.1,delta=120",
    ] {
        let spec: PlatformSpec = raw.parse().unwrap();
        assert_eq!(spec.to_string(), raw, "canonical form");
        let again: PlatformSpec = spec.to_string().parse().unwrap();
        assert_eq!(again, spec);
    }
}
