"""AOT lowering: JAX planner -> HLO *text* artifacts for the Rust runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects;
the text parser reassigns ids and round-trips cleanly.

Run via ``make artifacts`` (equivalently ``python -m compile.aot --out-dir
../artifacts`` from ``python/``).  Never imported at serving time.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

G_DEFAULT = 512

# name -> (entry function, batch size)
ARTIFACTS = {
    "planner_b1": (model.plan, 1),
    "planner_b64": (model.plan, 64),
    "surface_b16": (model.surfaces, 16),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name: str, g: int = G_DEFAULT) -> str:
    entry, b = ARTIFACTS[name]
    raw = jax.ShapeDtypeStruct((b, model.NRAW), jnp.float32)
    u = jax.ShapeDtypeStruct((g,), jnp.float32)
    return to_hlo_text(jax.jit(entry).lower(raw, u))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--grid", type=int, default=G_DEFAULT)
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only or list(ARTIFACTS)
    manifest = []
    for name in names:
        text = lower_artifact(name, args.grid)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        _, b = ARTIFACTS[name]
        entry = "plan" if ARTIFACTS[name][0] is model.plan else "surface"
        manifest.append(f"{name} entry={entry} b={b} g={args.grid} nraw={model.NRAW}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
