//! The paper's §5 evaluation, experiment by experiment.
//!
//! Every figure (4–11) and table (1–3) has a regeneration function
//! here; the bench harness (`cargo bench --bench paper`) and the
//! `ckptfp experiment` command are thin wrappers around this module.

pub mod ablations;
pub mod catalog;
pub mod figures;
pub mod sweep;
pub mod tables;

use crate::config::Scenario;
use crate::coordinator::{available_workers, run_parallel};
use crate::model::{Capping, StrategyKind};
use crate::sim::simulate_once;
use crate::strategies::{exactify, spec_for};
use crate::util::stats::Summary;

/// Knobs shared by all experiments.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Simulation replications per point (paper: 100).
    pub reps: u64,
    /// Worker threads.
    pub workers: usize,
    /// Also compute the BestPeriod counterpart of each heuristic
    /// (brute-force search — expensive).
    pub best_period: bool,
    /// Replications per BestPeriod candidate.
    pub bp_reps: u64,
    /// BestPeriod grid size.
    pub bp_candidates: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            reps: 40,
            workers: available_workers(),
            best_period: false,
            bp_reps: 10,
            bp_candidates: 16,
        }
    }
}

impl ExpOptions {
    /// Reduced settings for smoke tests and quick bench runs.
    pub fn quick() -> Self {
        ExpOptions { reps: 8, bp_reps: 4, bp_candidates: 8, ..Default::default() }
    }
}

/// The heuristics the paper simulates for a given window size
/// (WithCkptI needs room for one in-window checkpoint: I >= C).
pub fn paper_heuristics(i_window: f64, c: f64) -> Vec<StrategyKind> {
    let mut v = vec![
        StrategyKind::Young,
        StrategyKind::ExactPrediction,
        StrategyKind::Instant,
        StrategyKind::NoCkptI,
    ];
    if i_window >= c {
        v.push(StrategyKind::WithCkptI);
    }
    v
}

/// The scenario a heuristic actually runs against: EXACTPREDICTION gets
/// exact-date predictions for the same faults (§5's definition).
pub fn scenario_for(kind: StrategyKind, scenario: &Scenario) -> Scenario {
    if kind == StrategyKind::ExactPrediction {
        exactify(scenario)
    } else {
        scenario.clone()
    }
}

/// Mean simulated waste of `kind` on `scenario`: `reps` paired
/// replications, parallelized over the worker pool.
pub fn sim_waste(scenario: &Scenario, kind: StrategyKind, opts: &ExpOptions) -> Summary {
    let s = scenario_for(kind, scenario);
    s.validate().expect("invalid scenario");
    let spec = spec_for(kind, &s, Capping::Uncapped);
    let reps: Vec<u64> = (0..opts.reps).collect();
    let wastes = run_parallel(reps, opts.workers, |rep| {
        simulate_once(&s, &spec, *rep).expect("simulation failed").waste()
    });
    Summary::from_iter(wastes)
}

/// Mean simulated execution time (seconds) of `kind` on `scenario`.
pub fn sim_makespan(scenario: &Scenario, kind: StrategyKind, opts: &ExpOptions) -> Summary {
    let s = scenario_for(kind, scenario);
    s.validate().expect("invalid scenario");
    let spec = spec_for(kind, &s, Capping::Uncapped);
    let reps: Vec<u64> = (0..opts.reps).collect();
    let spans = run_parallel(reps, opts.workers, |rep| {
        simulate_once(&s, &spec, *rep).expect("simulation failed").makespan
    });
    Summary::from_iter(spans)
}

/// Result bundle an experiment hands back to the CLI / bench harness.
#[derive(Debug, Clone, Default)]
pub struct ExperimentResult {
    pub figures: Vec<crate::report::FigureData>,
    pub tables: Vec<(String, crate::report::Table)>,
}

impl ExperimentResult {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for fig in &self.figures {
            out.push_str(&fig.render());
            out.push('\n');
        }
        for (name, t) in &self.tables {
            out.push_str(&format!("# {name}\n{}\n", t.render()));
        }
        out
    }

    /// Write figure CSVs under `dir`.
    pub fn write_csvs(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        for fig in &self.figures {
            crate::report::write_figure_csv(&dir.join(format!("{}.csv", fig.name)), fig)?;
        }
        Ok(())
    }
}

/// Registry: run an experiment by its paper id.
pub fn run_experiment(id: &str, opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    match id {
        "fig4" | "fig5" | "fig6" | "fig7" => figures::figure_waste(id, opts),
        "fig8" | "fig9" | "fig10" | "fig11" => sweep::figure_sweep(id, opts),
        "tab1" => tables::table_exec(0.7, opts),
        "tab2" => tables::table_exec(0.5, opts),
        "tab3" => catalog::table_catalog(opts),
        "abl-q" => ablations::ablation_q(opts),
        "abl-daly" => ablations::ablation_daly(opts),
        "abl-lead" => ablations::ablation_lead(opts),
        "abl-cap" => ablations::ablation_cap(opts),
        other => anyhow::bail!(
            "unknown experiment '{other}' (expected fig4..fig11 | tab1..tab3 | abl-q | abl-daly | abl-lead | abl-cap)"
        ),
    }
}

/// Paper experiment ids, in paper order.
pub fn paper_experiments() -> Vec<&'static str> {
    vec!["fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "tab1", "tab2", "tab3"]
}

/// Everything: the paper's figures/tables plus the ablations.
pub fn all_experiments() -> Vec<&'static str> {
    let mut v = paper_experiments();
    v.extend(["abl-q", "abl-daly", "abl-lead", "abl-cap"]);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;

    #[test]
    fn heuristic_sets() {
        let small = paper_heuristics(300.0, 600.0);
        assert!(!small.contains(&StrategyKind::WithCkptI));
        assert_eq!(small.len(), 4);
        let large = paper_heuristics(3000.0, 600.0);
        assert!(large.contains(&StrategyKind::WithCkptI));
    }

    #[test]
    fn scenario_for_exactifies() {
        let s = Scenario::paper(1 << 16, Predictor::windowed(0.85, 0.82, 300.0));
        let e = scenario_for(StrategyKind::ExactPrediction, &s);
        assert_eq!(e.predictor.window, 0.0);
        let i = scenario_for(StrategyKind::Instant, &s);
        assert_eq!(i.predictor.window, 300.0);
    }

    #[test]
    fn registry_rejects_unknown() {
        assert!(run_experiment("fig99", &ExpOptions::quick()).is_err());
    }

    #[test]
    fn experiment_ids_complete() {
        // One per figure and table of §5 — the (d) deliverable checklist —
        // plus the four ablations.
        assert_eq!(paper_experiments().len(), 11);
        assert_eq!(all_experiments().len(), 15);
    }
}
