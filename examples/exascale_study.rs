//! End-to-end driver (the EXPERIMENTS.md headline run): a full
//! fault-prediction checkpointing study on a realistic workload.
//!
//! Pipeline, all layers composing:
//!   1. AOT XLA planner (Pallas kernel -> JAX -> HLO -> PJRT) plans all
//!      platform sizes in one batched execution;
//!   2. the closed-form Rust model cross-checks the artifact numerics;
//!   3. the discrete-event simulator replays every strategy against
//!      Weibull(k=0.7) failure traces (the paper's real-platform model)
//!      on the Jaguar-scale job, across the worker pool;
//!   4. the report compares analytic vs simulated waste and the time
//!      gained over Young — the paper's headline metric.
//!
//! ```bash
//! make artifacts && cargo run --release --example exascale_study
//! ```

use ckptfp::config::{paper_proc_counts, predictor_yu, Scenario};
use ckptfp::coordinator::run_parallel;
use ckptfp::experiments::scenario_for;
use ckptfp::model::{optimize, Capping, Params, StrategyKind};
use ckptfp::report::Table;
use ckptfp::runtime::HloPlanner;
use ckptfp::sim::simulate_once;
use ckptfp::strategies::spec_for;
use ckptfp::util::stats::Summary;
use ckptfp::util::units::{to_days, MIN};

const REPS: u64 = 30;

fn main() -> anyhow::Result<()> {
    let i_window = 300.0;
    println!("=== exascale fault-prediction study ===");
    println!("predictor: Yu et al. [12] (r = 0.85, p = 0.82, I = {i_window} s)");
    println!("platform:  mu_ind = 125 y, C = R = 10 mn, D = 1 mn, Weibull k = 0.7");

    // --- 1. Batched AOT planning for every platform size. ---
    let scenarios: Vec<Scenario> = paper_proc_counts()
        .into_iter()
        .map(|n| Scenario::paper(n, predictor_yu(i_window)))
        .collect();
    let params: Vec<Params> = scenarios.iter().map(Params::from_scenario).collect();
    let hlo_plans = match HloPlanner::open_default() {
        Ok(mut planner) => {
            let t0 = std::time::Instant::now();
            let plans = planner.plan_batch(&params)?;
            println!(
                "\nAOT planner ({}): {} configs planned in {:.2} ms",
                planner.platform_name(),
                plans.len(),
                t0.elapsed().as_secs_f64() * 1e3
            );
            Some(plans)
        }
        Err(e) => {
            println!("\n[!] AOT planner unavailable ({e}); falling back to closed forms");
            None
        }
    };

    // --- 2. Cross-check against the closed-form model. ---
    if let Some(plans) = &hlo_plans {
        let mut worst = 0.0f64;
        for (p, out) in params.iter().zip(plans) {
            for kind in StrategyKind::ALL {
                let (_, w) = optimize(p, kind, Capping::Capped);
                let diff = (w - out.waste[kind as usize]).abs();
                worst = worst.max(diff);
            }
        }
        println!("HLO vs closed-form: max |waste delta| = {worst:.2e} (grid resolution)");
    }

    // --- 3+4. Simulate each strategy at each scale. ---
    let kinds = [
        StrategyKind::Young,
        StrategyKind::ExactPrediction,
        StrategyKind::Instant,
        StrategyKind::NoCkptI,
    ];
    struct Task {
        si: usize,
        kind: StrategyKind,
        rep: u64,
    }
    let mut tasks = Vec::new();
    for si in 0..scenarios.len() {
        for kind in kinds {
            for rep in 0..REPS {
                tasks.push(Task { si, kind, rep });
            }
        }
    }
    let mut cache = std::collections::HashMap::new();
    for (si, s) in scenarios.iter().enumerate() {
        for kind in kinds {
            let sk = scenario_for(kind, s);
            let spec = spec_for(kind, &sk, Capping::Uncapped);
            cache.insert((si, kind as usize), (sk, spec));
        }
    }
    let t0 = std::time::Instant::now();
    let results = run_parallel(tasks, ckptfp::coordinator::available_workers(), |t| {
        let (s, spec) = &cache[&(t.si, t.kind as usize)];
        let o = simulate_once(s, spec, t.rep).expect("sim");
        (t.si, t.kind as usize, o.makespan, o.waste(), o.n_segments)
    });
    let wall = t0.elapsed().as_secs_f64();
    let total_events: u64 = results.iter().map(|r| r.4).sum();
    println!(
        "simulated {} runs in {wall:.1}s ({:.2} M engine-segments/s)",
        results.len(),
        total_events as f64 / wall / 1e6
    );

    let mut agg: std::collections::HashMap<(usize, usize), (Summary, Summary)> =
        std::collections::HashMap::new();
    for (si, k, span, waste, _) in results {
        let e = agg.entry((si, k)).or_default();
        e.0.push(span);
        e.1.push(waste);
    }

    let mut t = Table::new([
        "N".to_string(),
        "mu (mn)".to_string(),
        "Young days".to_string(),
        "best strategy".to_string(),
        "best days".to_string(),
        "gain".to_string(),
        "sim waste".to_string(),
        "analytic".to_string(),
    ]);
    println!();
    for (si, s) in scenarios.iter().enumerate() {
        let young_days = to_days(agg[&(si, StrategyKind::Young as usize)].0.mean());
        let (mut best_days, mut best_kind, mut best_waste) = (f64::INFINITY, kinds[0], 0.0);
        for kind in kinds.iter().skip(1) {
            let (span, waste) = &agg[&(si, *kind as usize)];
            if to_days(span.mean()) < best_days {
                best_days = to_days(span.mean());
                best_kind = *kind;
                best_waste = waste.mean();
            }
        }
        let p = Params::from_scenario(&scenario_for(best_kind, s));
        let (_, analytic) = optimize(&p, best_kind, Capping::Uncapped);
        t.row([
            format!("2^{}", s.platform.n_procs.trailing_zeros()),
            format!("{:.0}", s.mu() / MIN),
            format!("{young_days:.1}"),
            best_kind.name().to_string(),
            format!("{best_days:.1}"),
            format!("{:.0}%", 100.0 * (1.0 - best_days / young_days)),
            format!("{best_waste:.3}"),
            format!("{analytic:.3}"),
        ]);
    }
    print!("{t}");
    println!("\nheadline: prediction-aware checkpointing cuts execution time at every");
    println!("scale, growing with N — the paper's central claim, end to end.");
    Ok(())
}
