//! Bounded LRU memoization of pure job responses.
//!
//! Plan, BestPeriod and Sweep answers are pure functions of their
//! canonicalized request ([`super::canon`]): the closed forms are
//! deterministic arithmetic, and the Monte Carlo searches are seeded
//! and keyed on every reproducibility knob (seed, reps, fold width).
//! **Staleness is therefore impossible** — a cached response can never
//! disagree with a recomputed one — so the only thing this cache
//! manages is capacity. Eviction is plain least-recently-used.
//!
//! The store is hash-partitioned into [`SHARDS`] independently locked
//! shards once the capacity is large enough ([`SHARD_MIN_CAPACITY`])
//! for the split to make sense: concurrent connection threads then
//! contend only when their keys land in the same shard. Small caches
//! keep a single shard, which is byte-for-byte the original global
//! LRU. Recency and eviction are per shard (the victim is the least
//! recently used entry *in the key's shard*), but the counters are
//! global atomics, so hits + misses + evictions + entries sum
//! identically however the keys scatter.
//!
//! Shared across [`crate::api::Executor`] clones (one cache per
//! service), panic-safe (a poisoned inner lock is taken over rather
//! than propagated, like every other coordinator lock), and counted:
//! hits, misses and evictions feed `ServiceStats` and the CLI.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::metrics::lock_unpoisoned;
use crate::api::JobResponse;

/// Shard count for large caches (power of two, but selection is by
/// modulo so nothing depends on that).
const SHARDS: usize = 8;

/// Below this capacity the cache stays single-sharded: splitting a
/// tiny capacity across 8 locks would leave shards of a handful of
/// entries each, where partitioned LRU visibly diverges from the
/// global order and lock contention is a non-problem anyway.
const SHARD_MIN_CAPACITY: usize = 64;

/// Point-in-time cache counters, as reported on `stats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: u64,
}

struct Entry {
    resp: JobResponse,
    /// Logical timestamp of the last touch; the smallest one is the
    /// LRU victim.
    used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    /// Monotone logical clock for recency stamps (per shard).
    tick: u64,
}

/// One independently locked partition of the store.
struct Shard {
    inner: Mutex<Inner>,
    /// This shard's slice of the total capacity bound.
    capacity: usize,
}

/// The memoized response store. `capacity == 0` disables it: every
/// lookup misses without counting, every insert is dropped.
pub struct PlanCache {
    shards: Vec<Shard>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        let n = if capacity >= SHARD_MIN_CAPACITY { SHARDS } else { 1 };
        // Distribute the bound exactly: base everywhere, the remainder
        // spread one-per-shard, so shard capacities sum to `capacity`.
        let (base, rem) = (capacity / n, capacity % n);
        let shards = (0..n)
            .map(|i| Shard {
                inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
                capacity: base + usize::from(i < rem),
            })
            .collect();
        PlanCache {
            shards,
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    fn shard(&self, key: &str) -> &Shard {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Look one key up, refreshing its recency on a hit. Counts the
    /// hit or miss (a disabled cache counts nothing — it is absent,
    /// not cold).
    pub fn get(&self, key: &str) -> Option<JobResponse> {
        if !self.enabled() {
            return None;
        }
        let mut inner = lock_unpoisoned(&self.shard(key).inner);
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.resp.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) one entry, evicting the least-recently-used
    /// entry in the key's shard if its capacity slice would be
    /// exceeded.
    pub fn put(&self, key: String, resp: JobResponse) {
        if !self.enabled() {
            return;
        }
        let shard = self.shard(&key);
        let mut inner = lock_unpoisoned(&shard.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if !inner.map.contains_key(&key) && inner.map.len() >= shard.capacity {
            // O(n) victim scan: evictions only happen on misses past
            // capacity, and each shard is small (dozens of entries), so
            // a scan beats the bookkeeping of an intrusive LRU list.
            if let Some(victim) =
                inner.map.iter().min_by_key(|(_, e)| e.used).map(|(k, _)| k.clone())
            {
                inner.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.map.insert(key, Entry { resp, used: tick });
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        let entries: u64 =
            self.shards.iter().map(|s| lock_unpoisoned(&s.inner).map.len() as u64).sum();
        CacheSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(tag: &str) -> JobResponse {
        JobResponse::Error(crate::api::ApiError::bad_request(tag))
    }

    #[test]
    fn hit_returns_the_inserted_response_and_counts() {
        let c = PlanCache::new(4);
        assert!(c.get("a").is_none());
        c.put("a".into(), resp("a"));
        assert_eq!(c.get("a"), Some(resp("a")));
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (1, 1, 0, 1));
    }

    #[test]
    fn eviction_removes_the_least_recently_used_entry() {
        let c = PlanCache::new(2);
        c.put("a".into(), resp("a"));
        c.put("b".into(), resp("b"));
        // Touch "a" so "b" becomes the LRU victim.
        assert!(c.get("a").is_some());
        c.put("c".into(), resp("c"));
        assert!(c.get("a").is_some(), "recently used survives");
        assert!(c.get("b").is_none(), "LRU entry evicted");
        assert!(c.get("c").is_some());
        let s = c.snapshot();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn refreshing_an_existing_key_does_not_evict() {
        let c = PlanCache::new(2);
        c.put("a".into(), resp("a"));
        c.put("b".into(), resp("b"));
        c.put("a".into(), resp("a2"));
        let s = c.snapshot();
        assert_eq!(s.evictions, 0);
        assert_eq!(s.entries, 2);
        assert_eq!(c.get("a"), Some(resp("a2")), "refresh replaces the payload");
    }

    #[test]
    fn zero_capacity_disables_everything() {
        let c = PlanCache::new(0);
        c.put("a".into(), resp("a"));
        assert!(c.get("a").is_none());
        assert_eq!(c.snapshot(), CacheSnapshot::default());
    }

    #[test]
    fn small_capacities_stay_single_sharded() {
        let c = PlanCache::new(SHARD_MIN_CAPACITY - 1);
        assert_eq!(c.shards.len(), 1);
        assert_eq!(c.shards[0].capacity, SHARD_MIN_CAPACITY - 1);
    }

    #[test]
    fn shard_capacities_sum_to_the_configured_bound() {
        for cap in [64usize, 65, 100, 512, 513] {
            let c = PlanCache::new(cap);
            assert_eq!(c.shards.len(), SHARDS, "capacity {cap}");
            assert_eq!(c.shards.iter().map(|s| s.capacity).sum::<usize>(), cap);
            // The remainder spreads evenly: no shard is more than one
            // entry larger than another.
            let caps: Vec<usize> = c.shards.iter().map(|s| s.capacity).collect();
            let (lo, hi) = (caps.iter().min().unwrap(), caps.iter().max().unwrap());
            assert!(hi - lo <= 1, "capacity {cap}: uneven split {caps:?}");
        }
    }

    #[test]
    fn sharded_cache_counts_and_bounds_like_the_global_one() {
        let cap = 64;
        let c = PlanCache::new(cap);
        // Twice the capacity of distinct keys: every put misses first,
        // and the resident total never exceeds the configured bound.
        let keys: Vec<String> = (0..cap * 2).map(|i| format!("key-{i}")).collect();
        for k in &keys {
            assert!(c.get(k).is_none());
            c.put(k.clone(), resp(k));
        }
        let s = c.snapshot();
        assert_eq!(s.misses, (cap * 2) as u64);
        assert!(s.entries <= cap as u64, "resident {} > capacity {cap}", s.entries);
        // Per-shard conservation: inserts = resident + evicted, whatever
        // the hash scatter did.
        assert_eq!(s.entries + s.evictions, (cap * 2) as u64);
        // Everything still resident hits and round-trips its payload.
        let mut hits = 0;
        for k in &keys {
            if let Some(got) = c.get(k) {
                assert_eq!(got, resp(k), "payload survived sharding for {k}");
                hits += 1;
            }
        }
        assert_eq!(hits, s.entries, "snapshot agrees with rescan");
        assert_eq!(c.snapshot().hits, hits);
    }
}
