//! The correlation layer: spatially correlated failure groups and a
//! cascade kernel.
//!
//! Real platforms fail in bursts — a PSU, a rack switch, a cooling
//! loop takes neighbors down together — which is exactly the regime
//! where the independent-exponential closed form stops applying. The
//! model here is deliberately small:
//!
//! * nodes are partitioned into *groups* of `group` consecutive
//!   indices (a rack);
//! * when a fault strikes node `j` at time `t`, every other node `k`
//!   in `j`'s group draws once from `j`'s per-node `"corr"` substream:
//!   with probability `spatial` an *induced* fault is scheduled on `k`
//!   at `t + v·delta`, `v` uniform — the cascade kernel's boosted
//!   hazard for a Δt after a neighbor's fault, collapsed to the
//!   induced event itself;
//! * induced faults can propagate further with probability `cascade`
//!   per hop, chain depth capped at [`MAX_CHAIN`] so a hot group
//!   cannot recurse forever.
//!
//! Induced faults are *unpredicted* (the §5 predictor is trained on
//! the base hazard, not on failure propagation) and carry ids from a
//! disjoint high range so they can never collide with — or be linked
//! to — the natural streams' predictions.
//!
//! Determinism: draws happen at the instant the triggering fault is
//! *emitted*, iterating group members in ascending node order, from
//! per-node substreams derived by the existing `rng` discipline. With
//! `spatial = 0` (the default) the layer performs **zero** RNG draws —
//! part of the 1-node/uncorrelated bit-identity contract.

use crate::rng::{substream, Pcg64};

use super::node::node_seed;
use super::PlatformSpec;

/// Maximum fault-chain depth (natural fault = depth 0); propagation
/// stops here even at `cascade` close to 1.
pub const MAX_CHAIN: u32 = 4;

/// An induced (correlated) fault waiting to strike.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Induced {
    /// Strike time (> the trigger's time).
    pub t: f64,
    /// Victim node.
    pub node: u64,
    /// Chain depth: 1 for spatially induced, +1 per cascade hop.
    pub depth: u32,
}

/// The correlation component: per-node draw streams plus the queue of
/// induced faults not yet emitted, kept sorted by strike time (FIFO
/// within a tie — insertion order is deterministic).
#[derive(Debug)]
pub struct Correlator {
    spatial: f64,
    cascade: f64,
    delta: f64,
    group: u64,
    nodes: u64,
    rngs: Vec<Pcg64>,
    queue: Vec<Induced>,
}

impl Correlator {
    pub fn new(spec: &PlatformSpec, seed: u64, rep: u64) -> Correlator {
        Correlator {
            spatial: spec.spatial,
            cascade: spec.cascade,
            delta: spec.delta,
            group: spec.group.max(1),
            nodes: spec.nodes,
            rngs: Self::draw_streams(spec.nodes, seed, rep),
            queue: Vec::new(),
        }
    }

    fn draw_streams(nodes: u64, seed: u64, rep: u64) -> Vec<Pcg64> {
        (0..nodes).map(|j| substream(node_seed(seed, j), "corr", rep)).collect()
    }

    /// Rewind to replication `rep` of `seed`.
    pub fn reset(&mut self, seed: u64, rep: u64) {
        self.rngs = Self::draw_streams(self.nodes, seed, rep);
        self.queue.clear();
    }

    /// React to a fault striking `node` at `t`. `depth` is the chain
    /// depth of the striking fault (0 = natural). Draws once per other
    /// group member, in ascending node order, from the *striking*
    /// node's stream.
    pub fn on_fault(&mut self, node: u64, t: f64, depth: u32) {
        if depth >= MAX_CHAIN {
            return;
        }
        let prob = if depth == 0 { self.spatial } else { self.cascade };
        if prob <= 0.0 {
            return;
        }
        let lo = (node / self.group) * self.group;
        let hi = (lo + self.group).min(self.nodes);
        for k in lo..hi {
            if k == node {
                continue;
            }
            let rng = &mut self.rngs[node as usize];
            if rng.next_f64() < prob {
                let v = rng.next_f64();
                let induced = Induced { t: t + v * self.delta, node: k, depth: depth + 1 };
                // Insert keeping the queue sorted by strike time,
                // stable for ties.
                let pos = self
                    .queue
                    .iter()
                    .position(|q| q.t > induced.t)
                    .unwrap_or(self.queue.len());
                self.queue.insert(pos, induced);
            }
        }
    }

    /// Strike time of the earliest pending induced fault.
    pub fn peek_time(&self) -> Option<f64> {
        self.queue.first().map(|q| q.t)
    }

    /// Emit the earliest pending induced fault.
    pub fn pop(&mut self) -> Option<Induced> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.queue.remove(0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(nodes: u64, group: u64, spatial: f64, cascade: f64) -> PlatformSpec {
        PlatformSpec { nodes, group, spatial, cascade, delta: 120.0, ..PlatformSpec::default() }
    }

    #[test]
    fn zero_spatial_never_queues() {
        let mut c = Correlator::new(&spec(8, 4, 0.0, 0.5), 1, 0);
        for j in 0..8 {
            c.on_fault(j, 1000.0, 0);
        }
        assert_eq!(c.peek_time(), None);
    }

    #[test]
    fn induced_faults_stay_in_the_group_and_after_the_trigger() {
        let mut c = Correlator::new(&spec(8, 4, 1.0, 0.0), 2, 0);
        // Node 5 lives in group {4..8}; spatial = 1 hits every neighbor.
        c.on_fault(5, 500.0, 0);
        let mut victims = Vec::new();
        while let Some(i) = c.pop() {
            assert!(i.t > 500.0 && i.t <= 500.0 + 120.0, "delay in (0, delta]: {}", i.t);
            assert_eq!(i.depth, 1);
            victims.push(i.node);
        }
        victims.sort_unstable();
        assert_eq!(victims, [4, 6, 7]);
    }

    #[test]
    fn queue_pops_in_time_order() {
        let mut c = Correlator::new(&spec(6, 3, 1.0, 0.0), 3, 0);
        c.on_fault(0, 900.0, 0);
        c.on_fault(4, 100.0, 0);
        let mut last = f64::NEG_INFINITY;
        while let Some(i) = c.pop() {
            assert!(i.t >= last);
            last = i.t;
        }
    }

    #[test]
    fn chain_depth_is_capped() {
        let mut c = Correlator::new(&spec(2, 2, 1.0, 1.0), 4, 0);
        // At the cap nothing propagates, below it everything does.
        c.on_fault(0, 10.0, MAX_CHAIN);
        assert_eq!(c.peek_time(), None);
        c.on_fault(0, 10.0, MAX_CHAIN - 1);
        let i = c.pop().unwrap();
        assert_eq!(i.depth, MAX_CHAIN);
    }

    #[test]
    fn draws_are_reproducible_across_reset() {
        let s = spec(8, 4, 0.4, 0.2, );
        let mut a = Correlator::new(&s, 9, 3);
        let mut b = Correlator::new(&s, 9, 3);
        for j in [1u64, 6, 2, 5] {
            a.on_fault(j, 50.0 * j as f64, 0);
            b.on_fault(j, 50.0 * j as f64, 0);
        }
        let qa: Vec<Induced> = std::iter::from_fn(|| a.pop()).collect();
        let qb: Vec<Induced> = std::iter::from_fn(|| b.pop()).collect();
        assert_eq!(qa, qb);
        // Reset rewinds to the same stream.
        a.reset(9, 3);
        for j in [1u64, 6, 2, 5] {
            a.on_fault(j, 50.0 * j as f64, 0);
        }
        let qa2: Vec<Induced> = std::iter::from_fn(|| a.pop()).collect();
        assert_eq!(qa, qa2);
    }
}
