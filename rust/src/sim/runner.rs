//! Replicated simulation: run a strategy against `reps` independent
//! traces and aggregate.

use super::{Engine, Outcome, SimConfig};
use crate::config::Scenario;
use crate::strategies::StrategySpec;
use crate::trace::TraceGen;
use crate::util::stats::Summary;

/// Aggregated result of a replication batch.
#[derive(Debug, Clone)]
pub struct ReplicationReport {
    pub strategy: String,
    pub waste: Summary,
    pub makespan: Summary,
    pub outcomes: Vec<Outcome>,
}

impl ReplicationReport {
    pub fn mean_waste(&self) -> f64 {
        self.waste.mean()
    }

    pub fn mean_makespan(&self) -> f64 {
        self.makespan.mean()
    }

    /// Fraction of replications that finished under the guard.
    pub fn completion_rate(&self) -> f64 {
        let done = self.outcomes.iter().filter(|o| o.completed).count();
        done as f64 / self.outcomes.len().max(1) as f64
    }
}

/// One replication: trace `rep` of `scenario.seed`, executed under `spec`.
pub fn simulate_once(
    scenario: &Scenario,
    spec: &StrategySpec,
    rep: u64,
) -> anyhow::Result<Outcome> {
    let cfg = SimConfig::from_scenario(scenario);
    cfg.validate()?;
    let lead = spec.required_lead(cfg.c);
    let source = TraceGen::new(scenario, lead, scenario.seed, rep)?;
    let started = std::time::Instant::now();
    let mut out = Engine::new(&cfg, spec, source, scenario.seed ^ (rep << 17) ^ 0xA5).run();
    out.sim_seconds = started.elapsed().as_secs_f64();
    Ok(out)
}

/// Run `reps` replications sequentially. (The coordinator parallelizes
/// across replications and scenarios; this is the single-thread core.)
pub fn run_replications(
    scenario: &Scenario,
    spec: &StrategySpec,
    reps: u64,
) -> anyhow::Result<ReplicationReport> {
    let mut waste = Summary::new();
    let mut makespan = Summary::new();
    let mut outcomes = Vec::with_capacity(reps as usize);
    for rep in 0..reps {
        let o = simulate_once(scenario, spec, rep)?;
        waste.push(o.waste());
        makespan.push(o.makespan);
        outcomes.push(o);
    }
    Ok(ReplicationReport { strategy: spec.name.clone(), waste, makespan, outcomes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::model::{waste_young, Params};
    use crate::strategies::spec_for;
    use crate::model::{Capping, StrategyKind};

    fn small_scenario() -> Scenario {
        // Modest platform + small job so the test stays fast.
        let mut s = Scenario::paper(1 << 16, Predictor::none());
        s.fault_dist = "exp".into();
        s.work = 3.0e5; // ~3.5 days of work, mu = 60000 s
        s
    }

    #[test]
    fn young_simulation_matches_analysis_exponential() {
        // The headline validation: simulated waste under Exponential
        // faults must match Eq. (1) at q = 0 within a few percent.
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let report = run_replications(&s, &spec, 60).unwrap();
        assert!(report.completion_rate() == 1.0);
        let p = Params::from_scenario(&s);
        let analytic = waste_young(&p, spec.t_r);
        let sim = report.mean_waste();
        assert!(
            (sim - analytic).abs() / analytic < 0.08,
            "sim {sim} vs analytic {analytic}"
        );
    }

    #[test]
    fn exact_prediction_beats_young_in_simulation() {
        let mut s = small_scenario();
        s.predictor = Predictor::exact(0.85, 0.82);
        let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let exact = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        let wy = run_replications(&s, &young, 40).unwrap().mean_waste();
        let we = run_replications(&s, &exact, 40).unwrap().mean_waste();
        assert!(we < wy, "exact {we} vs young {wy}");
    }

    #[test]
    fn replications_are_reproducible() {
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let a = run_replications(&s, &spec, 5).unwrap();
        let b = run_replications(&s, &spec, 5).unwrap();
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.makespan, y.makespan);
            assert_eq!(x.n_faults, y.n_faults);
        }
    }

    #[test]
    fn same_trace_across_strategies() {
        // Strategies with the same required lead see identical fault
        // streams — the §5 comparison is paired.
        let mut s = small_scenario();
        s.predictor = Predictor::windowed(0.7, 0.4, 300.0);
        let a = spec_for(StrategyKind::Instant, &s, Capping::Uncapped);
        let b = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
        let oa = simulate_once(&s, &a, 3).unwrap();
        let ob = simulate_once(&s, &b, 3).unwrap();
        assert_eq!(oa.n_preds, ob.n_preds);
        // Fault counts can differ (different makespans expose different
        // trace prefixes) but the prediction stream prefix is shared.
    }

    #[test]
    fn q_zero_equals_young() {
        let mut s = small_scenario();
        s.predictor = Predictor::exact(0.85, 0.82);
        let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let mut distrust = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        distrust.q = 0.0;
        distrust.t_r = young.t_r;
        let wy = simulate_once(&s, &young, 1).unwrap();
        let wd = simulate_once(&s, &distrust, 1).unwrap();
        assert_eq!(wy.makespan, wd.makespan);
    }

    #[test]
    fn outcome_counters_consistent() {
        let mut s = small_scenario();
        s.predictor = Predictor::exact(0.7, 0.4);
        let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        let o = simulate_once(&s, &spec, 0).unwrap();
        assert!(o.n_true_preds <= o.n_preds);
        assert!(o.n_faults_unpredicted <= o.n_faults);
        assert!(o.completed);
        assert!(o.n_segments > 0);
    }
}
