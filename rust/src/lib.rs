//! # ckptfp — fault-prediction-aware checkpointing
//!
//! A reproduction-grade implementation of *"Impact of fault prediction on
//! checkpointing strategies"* (Aupy, Robert, Vivien, Zaidouni, 2012) as a
//! deployable framework:
//!
//! * [`model`] — the paper's analytical waste model (Eqs. 1–12) and the
//!   §3.3/§4.3 optimal-period case analysis, in closed form;
//! * [`runtime`] — the AOT path: loads the JAX/Pallas-compiled planner
//!   (`artifacts/*.hlo.txt`) through PJRT and evaluates waste surfaces /
//!   grid-argmin plans natively;
//! * [`trace`] — stochastic fault + predictor simulation (recall,
//!   precision, exact dates or prediction windows, lead time);
//! * [`sim`] — the discrete-event execution core plus the pluggable
//!   checkpoint-policy layer ([`sim::Policy`]): the core replays a
//!   policy against a trace, the policy answers when to checkpoint,
//!   whether to trust a prediction, and what to do inside a window;
//! * [`strategies`] — Young, Daly, ExactPrediction, Instant, NoCkptI,
//!   WithCkptI, Migration (as fixed-period policies), the non-paper
//!   policies (`adaptive`, `risk` via [`strategies::PolicySpec`]) and
//!   the brute-force BestPeriod / policy-parameter search;
//! * [`coordinator`] — leader/worker pools, a dynamic batcher for
//!   planning requests and the TCP/JSONL job service;
//! * [`api`] — the crate's one public job surface: typed
//!   [`api::JobRequest`]/[`api::JobResponse`] pairs, the versioned
//!   JSONL v2 wire encoding (with a v1 adapter), the shared
//!   [`api::Executor`] and the blocking [`api::ServiceClient`] —
//!   the CLI, the experiments and the TCP service all execute jobs
//!   through this one entry point;
//! * [`experiments`] — the §5 evaluation scenarios (every figure & table);
//! * [`verify`] — the conformance subsystem: the paper's "analysis
//!   corroborated by simulation" claim as an executable test layer
//!   (scenario grid × analytic oracle × CI-aware comparator, reported
//!   as `CONFORMANCE.json` and served as the `verify` job).
//!
//! Substrate modules ([`rng`], [`dist`], [`util`], [`config`], [`cli`],
//! [`report`], [`verify::testkit`]) are implemented from scratch — the build is
//! fully offline and depends only on `anyhow` (plus the optional `xla`
//! PJRT bindings behind the `pjrt` feature; without it the [`runtime`]
//! module keeps its API surface but reports the missing backend, and
//! the job service falls back to the closed-form planner).

pub mod api;
#[cfg(any(test, feature = "chaos"))]
pub mod chaos;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod experiments;
pub mod model;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod strategies;
pub mod trace;
pub mod util;
pub mod verify;

/// The property harness moved into [`verify`]; this alias keeps the
/// historical `ckptfp::testkit` path working.
pub use verify::testkit;

/// Convenient glob import for examples and binaries.
pub mod prelude {
    pub use crate::api::{
        ApiError, BestPeriodJob, ErrorCode, Executor, ExecutorConfig, JobRequest, JobResponse,
        PlanJob, ServiceClient, SimulateJob, SweepJob, VerifyJob,
    };
    pub use crate::config::{Platform, Predictor, Scenario};
    pub use crate::dist::{Dist, DistSpec, Distribution, Exponential, Uniform, Weibull};
    pub use crate::model::{Capping, OptimalPlan, StrategyKind};
    pub use crate::rng::Pcg64;
    pub use crate::sim::{
        Outcome, PlatformSource, PlatformSpec, Policy, PolicyCtx, RestartScope, SimConfig,
        SimSession,
    };
    pub use crate::strategies::{
        resolve_policy, PolicySpec, ProactiveMode, ResolvedPolicy, StrategySpec,
    };
    pub use crate::trace::{ReplaySource, TraceBank};
    pub use crate::util::stats::{PairedDiff, Summary};
    pub use crate::verify::{
        conformance_grid, run_conformance, CaseVerdict, ConformanceCase, GridKind, Verdict,
        VerifyOptions, VerifyReport,
    };
}
