//! Command-line argument parsing (substrate: no `clap` offline).
//!
//! Conventions: `binary <command> [positional...] [--flag value]
//! [--switch]`. Flags may be `--key value` or `--key=value`; switches
//! are bare `--key`. Unknown flags are an error at `finish()` so typos
//! do not silently fall back to defaults.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Clone)]
pub struct Args {
    command: Option<String>,
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: BTreeSet<String>,
    consumed: BTreeSet<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — the first item is the
    /// first *argument*, not the binary name.
    pub fn parse<I, S>(items: I) -> anyhow::Result<Args>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut command = None;
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut switches = BTreeSet::new();
        let mut iter = items.into_iter().map(Into::into).peekable();
        while let Some(item) = iter.next() {
            if let Some(name) = item.strip_prefix("--") {
                anyhow::ensure!(!name.is_empty(), "bare '--' is not supported");
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags.insert(name.to_string(), iter.next().unwrap());
                } else {
                    switches.insert(name.to_string());
                }
            } else if command.is_none() {
                command = Some(item);
            } else {
                positional.push(item);
            }
        }
        Ok(Args { command, positional, flags, switches, consumed: BTreeSet::new() })
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn command(&self) -> Option<&str> {
        self.command.as_deref()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Typed flag lookup with default.
    pub fn get<T: std::str::FromStr>(&mut self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {raw}: {e}")),
        }
    }

    /// Optional typed flag.
    pub fn get_opt<T: std::str::FromStr>(&mut self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(None),
            Some(raw) => raw
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {raw}: {e}")),
        }
    }

    /// String flag with default.
    pub fn get_str(&mut self, key: &str, default: &str) -> String {
        self.consumed.insert(key.to_string());
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Boolean switch (present/absent).
    pub fn switch(&mut self, key: &str) -> bool {
        self.consumed.insert(key.to_string());
        self.switches.contains(key)
    }

    /// Error on unconsumed flags — call after all lookups.
    pub fn finish(&self) -> anyhow::Result<()> {
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !self.consumed.contains(k.as_str()))
            .collect();
        anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commands_and_flags() {
        let mut a = Args::parse(["simulate", "tracefile", "--reps", "40", "--verbose",
                                 "--dist=weibull:0.7"]).unwrap();
        assert_eq!(a.command(), Some("simulate"));
        assert_eq!(a.positional(), &["tracefile".to_string()]);
        assert_eq!(a.get::<u64>("reps", 10).unwrap(), 40);
        assert_eq!(a.get_str("dist", "exp"), "weibull:0.7");
        assert!(a.switch("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults() {
        let mut a = Args::parse(["plan"]).unwrap();
        assert_eq!(a.get::<f64>("recall", 0.85).unwrap(), 0.85);
        assert!(!a.switch("json"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flags_rejected() {
        let mut a = Args::parse(["plan", "--tyop", "3"]).unwrap();
        let _ = a.get::<u64>("reps", 1).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_type() {
        let mut a = Args::parse(["plan", "--reps", "many"]).unwrap();
        assert!(a.get::<u64>("reps", 1).is_err());
    }

    #[test]
    fn flag_value_looks_positional() {
        // "--out file.csv" consumes the next token as the value.
        let mut a = Args::parse(["report", "--out", "file.csv", "extra"]).unwrap();
        assert_eq!(a.get_str("out", ""), "file.csv");
        assert_eq!(a.positional(), &["extra".to_string()]);
    }
}
