//! Integration: analytical waste model vs discrete-event simulation.
//!
//! The paper's validity claim (§5.1, "good correspondence between
//! analytical results and simulations") — for Exponential faults the
//! simulator must land on the closed forms for every strategy.

use ckptfp::config::{Predictor, Scenario};
use ckptfp::experiments::scenario_for;
use ckptfp::model::{tp_opt, waste_of, Capping, Params, StrategyKind};
use ckptfp::sim::run_replications;
use ckptfp::strategies::spec_for;

/// A mid-size platform where the uncapped optimum is interior and the
/// one-fault-per-period assumption holds comfortably.
fn scenario(window: f64) -> Scenario {
    let pred = if window > 0.0 {
        Predictor::windowed(0.85, 0.82, window)
    } else {
        Predictor::exact(0.85, 0.82)
    };
    let mut s = Scenario::paper(1 << 16, pred);
    s.fault_dist = ckptfp::dist::DistSpec::Exp;
    s.work = 6.0e5;
    s
}

fn check(kind: StrategyKind, window: f64, reps: u64, tol: f64) {
    let s0 = scenario(window);
    let s = scenario_for(kind, &s0);
    let spec = spec_for(kind, &s, Capping::Uncapped);
    let report = run_replications(&s, &spec, reps).unwrap();
    assert_eq!(report.completion_rate(), 1.0, "{}", kind.name());
    let p = Params::from_scenario(&s);
    let analytic = waste_of(&p, kind, spec.t_r, tp_opt(&p));
    let sim = report.mean_waste();
    assert!(
        (sim - analytic).abs() / analytic < tol,
        "{} (I={window}): sim {sim:.4} vs analytic {analytic:.4}",
        kind.name()
    );
}

#[test]
fn young_matches() {
    check(StrategyKind::Young, 0.0, 40, 0.08);
}

#[test]
fn exact_prediction_matches() {
    check(StrategyKind::ExactPrediction, 0.0, 40, 0.12);
}

#[test]
fn instant_matches_small_window() {
    check(StrategyKind::Instant, 300.0, 40, 0.12);
}

#[test]
fn nockpt_matches_small_window() {
    check(StrategyKind::NoCkptI, 300.0, 40, 0.12);
}

#[test]
fn nockpt_matches_large_window() {
    check(StrategyKind::NoCkptI, 3000.0, 40, 0.15);
}

#[test]
fn withckpt_matches_large_window() {
    // Eq. (4) over-approximates T_lost by T_P, so the simulation should
    // come in at or below the analytic value; accept a wider band.
    let s0 = scenario(3000.0);
    let spec = spec_for(StrategyKind::WithCkptI, &s0, Capping::Uncapped);
    let report = run_replications(&s0, &spec, 40).unwrap();
    let p = Params::from_scenario(&s0);
    let analytic = waste_of(&p, StrategyKind::WithCkptI, spec.t_r, tp_opt(&p));
    let sim = report.mean_waste();
    assert!(
        sim < analytic * 1.10 && sim > analytic * 0.5,
        "sim {sim:.4} vs upper-bound analytic {analytic:.4}"
    );
}

#[test]
fn migration_matches() {
    check(StrategyKind::Migration, 0.0, 40, 0.15);
}

#[test]
fn paper_ordering_small_window() {
    // I = 300 s: ExactPrediction <= NoCkptI ~= Instant < Young (§5.1).
    let reps = 40;
    let mut wastes = std::collections::HashMap::new();
    for kind in [
        StrategyKind::Young,
        StrategyKind::ExactPrediction,
        StrategyKind::Instant,
        StrategyKind::NoCkptI,
    ] {
        let s0 = scenario(300.0);
        let s = scenario_for(kind, &s0);
        let spec = spec_for(kind, &s, Capping::Uncapped);
        wastes.insert(kind as usize, run_replications(&s, &spec, reps).unwrap().mean_waste());
    }
    let y = wastes[&(StrategyKind::Young as usize)];
    let e = wastes[&(StrategyKind::ExactPrediction as usize)];
    let i = wastes[&(StrategyKind::Instant as usize)];
    let n = wastes[&(StrategyKind::NoCkptI as usize)];
    assert!(e < y, "exact {e} < young {y}");
    assert!(i < y && n < y, "window strategies beat young: {i}, {n} vs {y}");
    assert!(e <= i * 1.05, "exact {e} ~<= instant {i}");
    assert!((i - n).abs() / i < 0.10, "instant {i} ~= nockpt {n} at I=300");
}

#[test]
fn weibull_waste_higher_variance_but_bounded() {
    // Weibull k = 0.7 isn't covered by the closed forms; the §5 claim
    // is only that prediction still helps. Check exactly that.
    let mut s = scenario(0.0);
    s.fault_dist = ckptfp::dist::DistSpec::weibull(0.7);
    let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let exact = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
    let wy = run_replications(&s, &young, 30).unwrap().mean_waste();
    let we = run_replications(&s, &exact, 30).unwrap().mean_waste();
    assert!(we < wy, "prediction must help under Weibull too: {we} vs {wy}");
}
