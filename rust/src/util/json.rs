//! Tiny JSON parser/serializer (substrate: no serde_json offline).
//!
//! Supports the full JSON data model with the restrictions that matter
//! for the planner-service protocol: numbers are f64, strings are UTF-8
//! without surrogate-pair escapes beyond the BMP handling below.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Fetch a numeric field or fall back.
    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting accepted by [`parse`]. The recursive-descent
/// parser uses one stack frame per level, so unbounded depth would let a
/// hostile line (`[[[[…`) overflow the thread stack instead of returning a
/// structured error.
const MAX_DEPTH: usize = 512;

pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.bytes.len(), "trailing characters at byte {}", p.pos);
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn enter(&mut self) -> anyhow::Result<()> {
        self.depth += 1;
        anyhow::ensure!(self.depth <= MAX_DEPTH, "nesting deeper than {} levels", MAX_DEPTH);
        Ok(())
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow::anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        anyhow::ensure!(got == b, "expected '{}' got '{}' at {}", b as char, got as char, self.pos);
        Ok(())
    }

    fn literal(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(s.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos += s.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump()?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    e => anyhow::bail!("bad escape '\\{}'", e as char),
                },
                c if c < 0x20 => anyhow::bail!("control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    anyhow::ensure!(start + len <= self.bytes.len(), "truncated utf8");
                    s.push_str(std::str::from_utf8(&self.bytes[start..start + len])?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                c => anyhow::bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => {
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                c => anyhow::bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_object() {
        let src = r#"{"mu": 7500.0, "name": "jaguar", "ok": true, "xs": [1, 2.5, null]}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.num_or("mu", 0.0), 7500.0);
        assert_eq!(v.get("name").unwrap().as_str(), Some("jaguar"));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": {"b": [{"c": 1}]}}"#).unwrap();
        let inner = v.get("a").unwrap().get("b").unwrap();
        match inner {
            Json::Arr(xs) => assert_eq!(xs[0].num_or("c", 0.0), 1.0),
            _ => panic!(),
        }
    }

    #[test]
    fn nonfinite_serializes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn deep_nesting_is_rejected_not_overflowed() {
        let hostile = "[".repeat(10_000) + &"]".repeat(10_000);
        let err = parse(&hostile).unwrap_err();
        assert!(err.to_string().contains("nesting"), "{err}");
        // Reasonable nesting still parses.
        let sane = "[".repeat(64) + &"]".repeat(64);
        assert!(parse(&sane).is_ok());
    }
}
