//! Wide SoA replay kernel: one struct-of-arrays engine advancing a
//! whole chunk of replications together over the [`TraceBank`] arena.
//!
//! The lockstep engine ([`crate::sim::batch::BatchEngine`]) keeps
//! `lanes` *scalar* engines side by side — the batch win there is
//! locality and dispatch, not data layout. This module goes the rest
//! of the way: all per-lane execution state (clock, persisted and
//! volatile work, period accounting, arena cursors, the cached
//! next-fault/next-prediction heads, pending proactive actions and the
//! [`Outcome`] accumulators) lives in contiguous columns, and the
//! inner loop sweeps every lane one *event-phase* at a time under a
//! lane mask: completion/guard checks, prediction intake, proactive
//! dispatch, the regular-checkpoint rule, slice planning, the fault
//! cut, and finally one tight columnar pass that advances every
//! surviving lane's clock and accumulators at once. Fault, prediction
//! and trust events are read straight out of the shared bank columns
//! by index — no per-lane source object, no virtual dispatch.
//!
//! ## Bit-identity contract
//!
//! Replications are independent by construction (every per-rep stream
//! is re-derived from `(seed, rep)`), so only the *per-lane* f64
//! operation sequence matters — and each phase handler here is a
//! verbatim transcription of the scalar engine's corresponding step
//! (`sim::engine`), with `self.field` become `self.field[lane]`. A
//! sweep executes exactly one scalar loop iteration per running lane;
//! interleaving across lanes is unobservable. The identity is pinned
//! at every width in `tests/test_batch.rs`.
//!
//! ## Eviction rule
//!
//! A lane that hits a state the wide kernel does not express —
//! un-materialized rep, bank underrun (fault or prediction span
//! exhausted mid-run) — is *evicted*: its partial state is abandoned
//! and the replication re-runs on the shared live fallback engine,
//! exactly the scalar replay session's underrun rule. Evicting early
//! is always safe: the scalar path discards the replayed outcome on
//! any underrun and re-runs live anyway, so eviction timing affects
//! counters and wall-clock only, never results.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::{Engine, Outcome, Policy, PolicyCtx, SimConfig};
use crate::config::Scenario;
use crate::rng::trust_seed;
use crate::strategies::ProactiveMode;
use crate::trace::{bank, Fault, Prediction, TraceBank, TraceGen};

/// Numerical slack on work comparisons (seconds) — the same constant
/// as the scalar engine; the two must agree for bit-identity.
const EPS: f64 = 1e-6;

// Crate-wide wide-kernel counters, surfaced on the service `stats` op
// next to the lockstep counters (same pattern as `sim::batch`).
static WIDE_LANES_RUN: AtomicU64 = AtomicU64::new(0);
static WIDE_EVICTIONS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the wide-kernel counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WideCounters {
    /// Replications advanced through a wide chunk (served or evicted).
    pub lanes_run: u64,
    /// Lanes evicted to the live fallback engine (un-materialized rep
    /// or bank underrun mid-run).
    pub evictions: u64,
}

/// Read the crate-wide wide-kernel counters.
pub fn counters() -> WideCounters {
    WideCounters {
        lanes_run: WIDE_LANES_RUN.load(Ordering::Relaxed),
        evictions: WIDE_EVICTIONS.load(Ordering::Relaxed),
    }
}

/// Per-lane lifecycle within one chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lane {
    Running,
    Done,
    Evicted,
}

/// Control-flow token: the lane asked past the bank's horizon (or hit
/// a state the kernel does not express) and must re-run live.
struct Evict;

type Step<T> = Result<T, Evict>;

enum Seg {
    Completed,
    Faulted(Fault),
}

/// The wide SoA kernel: `width` lanes of columnar engine state over
/// one shared bank arena.
///
/// Construction mirrors [`crate::sim::batch::BatchEngine::new`]'s
/// validation — the bank must match the scenario's seed and the
/// policy's required lead — and the per-lane eviction mirrors the
/// scalar replay underrun rule, so every replication's outcome is
/// bit-identical to the scalar replay path.
pub struct WideKernel {
    bank: Arc<TraceBank>,
    scenario: Box<Scenario>,
    /// Sanitized at construction (idempotent), exactly what
    /// [`Engine::with_policy`] would apply — the kernel consults
    /// `ckpt_rule`/`trust_with`/`window_action` directly.
    policy: Policy,
    cfg: SimConfig,
    lead: f64,
    seed: u64,
    width: usize,
    preds_never_fire: bool,

    // --- SoA lane state: one slot per lane, contiguous per field ---
    reps: Vec<u64>,
    status: Vec<Lane>,
    /// Current simulated time (s).
    now: Vec<f64>,
    /// Work persisted by checkpoints (survives faults).
    saved: Vec<f64>,
    /// Work since the last persisted state (lost on fault).
    vol: Vec<f64>,
    /// Regular-mode work accumulated toward the current period.
    w_reg: Vec<f64>,
    /// Arena cursors into the bank's fault column.
    fi: Vec<usize>,
    fhi: Vec<usize>,
    /// Arena cursors into the bank's prediction/trust columns.
    pi: Vec<usize>,
    phi: Vec<usize>,
    next_fault: Vec<Option<Fault>>,
    next_pred: Vec<Option<Prediction>>,
    /// Trust uniform of the most recently served prediction, consumed
    /// at drain time (the `ReplaySource::pending_trust` discipline).
    next_trust: Vec<Option<f64>>,
    /// Trusted predictions awaiting their action point, sorted by t0.
    pending: Vec<VecDeque<Prediction>>,
    /// Fault ids neutralized by completed migrations.
    neutralized: Vec<Vec<u64>>,
    out: Vec<Outcome>,

    // --- sweep scratch: the lane mask and per-phase columns ---
    mask: Vec<bool>,
    measured: Vec<f64>,
    boundary: Vec<f64>,
    ends: Vec<f64>,

    /// Live fallback engine, built on first eviction, shared by all
    /// lanes (evicted reps re-run one at a time, in chunk order).
    fallback: Option<Box<Engine<TraceGen>>>,
}

impl WideKernel {
    /// Build a wide kernel of `lanes.max(1)` lanes over `bank`.
    /// Rejects bank/scenario seed mismatches and bank/policy lead
    /// mismatches, exactly like [`crate::sim::batch::BatchEngine::new`].
    pub fn new(
        bank: Arc<TraceBank>,
        scenario: &Scenario,
        policy: Policy,
        lanes: usize,
    ) -> anyhow::Result<WideKernel> {
        let cfg = SimConfig::from_scenario(scenario);
        cfg.validate()?;
        let policy = policy.sanitized(cfg.c);
        let lead = policy.required_lead(cfg.c);
        anyhow::ensure!(
            bank.seed() == scenario.seed,
            "trace bank was built for seed {} but the scenario uses seed {}",
            bank.seed(),
            scenario.seed
        );
        anyhow::ensure!(
            bank.lead() == lead,
            "trace bank was built with lead {} but the policy requires lead {}",
            bank.lead(),
            lead
        );
        let width = lanes.max(1);
        Ok(WideKernel {
            preds_never_fire: bank.preds_never_fire(),
            seed: scenario.seed,
            scenario: Box::new(scenario.clone()),
            bank,
            policy,
            cfg,
            lead,
            width,
            reps: Vec::with_capacity(width),
            status: vec![Lane::Evicted; width],
            now: vec![0.0; width],
            saved: vec![0.0; width],
            vol: vec![0.0; width],
            w_reg: vec![0.0; width],
            fi: vec![0; width],
            fhi: vec![0; width],
            pi: vec![0; width],
            phi: vec![0; width],
            next_fault: vec![None; width],
            next_pred: vec![None; width],
            next_trust: vec![None; width],
            pending: vec![VecDeque::new(); width],
            neutralized: vec![Vec::new(); width],
            out: vec![Outcome::default(); width],
            mask: vec![false; width],
            measured: vec![0.0; width],
            boundary: vec![0.0; width],
            ends: vec![0.0; width],
            fallback: None,
        })
    }

    /// Chunk width (the `lanes` this kernel was built with).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Advance one chunk of at most `width` replications and hand each
    /// `(rep, outcome)` to `sink` in chunk order.
    ///
    /// Three phases over the lane block: point every lane at its
    /// arena span, sweep all running lanes phase-by-phase until each
    /// is done or evicted, then collect in chunk order with evicted
    /// lanes re-run on the shared live fallback engine.
    pub(crate) fn run_chunk<F: FnMut(u64, &Outcome)>(&mut self, reps: &[u64], sink: &mut F) {
        debug_assert!(reps.len() <= self.width, "chunk wider than the kernel");
        if reps.is_empty() {
            return;
        }
        self.reps.clear();
        self.reps.extend_from_slice(reps);
        let n = reps.len();
        // Phase 1: point every lane at its replication's arena span.
        for (l, &rep) in reps.iter().enumerate() {
            self.reset_lane(l, rep);
        }
        // Phase 2: sweep until every lane is done or evicted. Each
        // sweep runs exactly one scalar loop iteration per lane.
        let started = Instant::now();
        while self.sweep(n) {}
        let share = started.elapsed().as_secs_f64() / n as f64;
        // Phase 3: collect in chunk order; evicted lanes re-run live.
        for l in 0..n {
            let rep = self.reps[l];
            match self.status[l] {
                Lane::Done => {
                    self.out[l].sim_seconds = share;
                    bank::note_replay_served();
                    let out = std::mem::take(&mut self.out[l]);
                    sink(rep, &out);
                }
                _ => {
                    WIDE_EVICTIONS.fetch_add(1, Ordering::Relaxed);
                    bank::note_fallback_taken();
                    let started = Instant::now();
                    let fallback = &mut self.fallback;
                    let live = match fallback {
                        Some(live) => live,
                        None => {
                            let cfg = SimConfig::from_scenario(&self.scenario);
                            let source =
                                TraceGen::new(&self.scenario, self.lead, self.seed, rep)
                                    .expect("scenario validated at kernel build");
                            fallback
                                .insert(Box::new(Engine::with_policy(&cfg, self.policy, source, 0)))
                        }
                    };
                    live.source_mut().reset(self.seed, rep);
                    live.reset(trust_seed(self.seed, rep));
                    let mut out = live.run_to_completion();
                    out.sim_seconds = started.elapsed().as_secs_f64();
                    sink(rep, &out);
                }
            }
        }
        WIDE_LANES_RUN.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Zero lane `l`'s columns and point its cursors at `rep`'s arena
    /// span. A missing span (or a chaos-forced underrun, consumed here
    /// exactly like `ReplaySource::reset`) evicts immediately.
    fn reset_lane(&mut self, l: usize, rep: u64) {
        #[cfg(any(test, feature = "chaos"))]
        let span = if crate::chaos::force_underrun() {
            None
        } else {
            self.bank.span_bounds(rep)
        };
        #[cfg(not(any(test, feature = "chaos")))]
        let span = self.bank.span_bounds(rep);
        self.now[l] = 0.0;
        self.saved[l] = 0.0;
        self.vol[l] = 0.0;
        self.w_reg[l] = 0.0;
        self.next_fault[l] = None;
        self.next_pred[l] = None;
        self.next_trust[l] = None;
        self.pending[l].clear();
        self.neutralized[l].clear();
        self.out[l] = Outcome::default();
        match span {
            Some((fault_lo, fault_hi, pred_lo, pred_hi)) => {
                self.fi[l] = fault_lo;
                self.fhi[l] = fault_hi;
                self.pi[l] = pred_lo;
                self.phi[l] = pred_hi;
                self.status[l] = Lane::Running;
            }
            None => {
                self.fi[l] = 0;
                self.fhi[l] = 0;
                self.pi[l] = 0;
                self.phi[l] = 0;
                self.status[l] = Lane::Evicted;
            }
        }
    }

    /// One masked pass over the lane block: every phase below is the
    /// corresponding step of the scalar engine's main loop, applied to
    /// each running lane in lane order. Returns whether any lane is
    /// still running.
    fn sweep(&mut self, n: usize) -> bool {
        // Phase A: completion and makespan guard — columnar over the
        // work/clock columns.
        for l in 0..n {
            let live = self.status[l] == Lane::Running;
            self.mask[l] = live;
            if !live {
                continue;
            }
            if self.remaining(l) <= EPS {
                self.out[l].completed = true;
                self.finish(l);
                self.mask[l] = false;
            } else if self.now[l] > self.cfg.max_makespan {
                self.out[l].completed = false;
                self.finish(l);
                self.mask[l] = false;
            }
        }
        // Phase B: prediction intake (drain everything known by now).
        for l in 0..n {
            if self.mask[l] && self.drain_predictions(l).is_err() {
                self.evict(l);
            }
        }
        // Phase B2: proactive action due? (Scalar `continue` = drop
        // the lane from the rest of this sweep.)
        for l in 0..n {
            if !self.mask[l] {
                continue;
            }
            if let Some(p) = self.pending[l].front().copied() {
                let start = (p.t0 - self.lead).max(0.0);
                if start <= self.now[l] {
                    self.pending[l].pop_front();
                    match self.handle_proactive(l, p) {
                        Err(Evict) => self.evict(l),
                        Ok(()) => self.mask[l] = false,
                    }
                }
            }
        }
        // Phase C: the regular-checkpoint rule, consulted columnar-ly
        // into the scratch columns, then acted on per due lane.
        for l in 0..n {
            if !self.mask[l] {
                continue;
            }
            let (m, b) = self.policy.ckpt_rule(&self.ctx(l));
            self.measured[l] = m;
            self.boundary[l] = b;
        }
        for l in 0..n {
            if !self.mask[l] || self.measured[l] < self.boundary[l] - EPS {
                continue;
            }
            if self.vol[l] > 0.0 {
                match self.checkpoint(l, false) {
                    Err(Evict) => {
                        self.evict(l);
                        continue;
                    }
                    Ok(Seg::Faulted(f)) => {
                        if self.handle_fault(l, f).is_err() {
                            self.evict(l);
                            continue;
                        }
                    }
                    Ok(Seg::Completed) => {}
                }
            } else {
                self.w_reg[l] = 0.0; // state already persisted
            }
            self.mask[l] = false;
        }
        // Phase D: plan the next work slice, capped at the rule, the
        // pending action point and the next prediction availability.
        for l in 0..n {
            if !self.mask[l] {
                continue;
            }
            let mut end = self.now[l] + self.remaining(l);
            end = end.min(self.now[l] + (self.boundary[l] - self.measured[l]).max(0.0));
            if let Some(p) = self.pending[l].front() {
                end = end.min((p.t0 - self.lead).max(self.now[l]));
            }
            if self.next_pred[l].is_none() {
                if self.refill_pred(l).is_err() {
                    self.evict(l);
                    continue;
                }
            }
            if let Some(pr) = &self.next_pred[l] {
                if pr.avail > self.now[l] {
                    end = end.min(pr.avail);
                }
            }
            if end <= self.now[l] + 1e-9 {
                // Defensive: only reachable through degenerate pending
                // entries; drop the blocker and move on.
                self.pending[l].pop_front();
                self.mask[l] = false;
                continue;
            }
            self.ends[l] = end;
        }
        // Phase D2: open the work segment and check the fault cut per
        // lane (the `work_until` head, with faulted lanes resolved).
        for l in 0..n {
            if !self.mask[l] {
                continue;
            }
            self.out[l].n_segments += 1;
            match self.take_fault_before(l, self.ends[l]) {
                Err(Evict) => self.evict(l),
                Ok(Some(f)) => {
                    let elapsed = (f.t - self.now[l]).max(0.0);
                    self.vol[l] += elapsed;
                    self.w_reg[l] += elapsed;
                    self.now[l] = f.t;
                    match self.handle_fault(l, f) {
                        Err(Evict) => self.evict(l),
                        Ok(()) => self.mask[l] = false,
                    }
                }
                Ok(None) => {}
            }
        }
        // Phase D3: the vectorized advance — every surviving lane
        // moves its clock and accumulators in one tight columnar pass.
        for l in 0..n {
            if !self.mask[l] {
                continue;
            }
            let elapsed = self.ends[l] - self.now[l];
            self.vol[l] += elapsed;
            self.w_reg[l] += elapsed;
            self.now[l] = self.ends[l];
        }
        (0..n).any(|l| self.status[l] == Lane::Running)
    }

    #[inline]
    fn remaining(&self, l: usize) -> f64 {
        (self.cfg.work - (self.saved[l] + self.vol[l])).max(0.0)
    }

    #[inline]
    fn ctx(&self, l: usize) -> PolicyCtx {
        PolicyCtx {
            now: self.now[l],
            vol: self.vol[l],
            w_reg: self.w_reg[l],
            n_faults: self.out[l].n_faults,
            c: self.cfg.c,
        }
    }

    /// Seal lane `l`'s outcome (the scalar loop's exit bookkeeping).
    fn finish(&mut self, l: usize) {
        self.out[l].makespan = self.now[l];
        self.out[l].work = (self.saved[l] + self.vol[l]).min(self.cfg.work);
        self.status[l] = Lane::Done;
    }

    fn evict(&mut self, l: usize) {
        self.status[l] = Lane::Evicted;
        self.mask[l] = false;
    }

    /// Next fault that actually strikes lane `l` (skips migrated-away
    /// ones). Exhausting the arena span means the run outlived the
    /// horizon — live fault streams never end — so the lane evicts.
    fn peek_fault(&mut self, l: usize) -> Step<Fault> {
        loop {
            if self.next_fault[l].is_none() {
                if self.fi[l] < self.fhi[l] {
                    self.next_fault[l] = Some(self.bank.fault_at(self.fi[l]));
                    self.fi[l] += 1;
                } else {
                    return Err(Evict);
                }
            }
            let f = self.next_fault[l].expect("refilled above");
            if let Some(pos) = self.neutralized[l].iter().position(|&id| id == f.id) {
                self.neutralized[l].swap_remove(pos);
                self.out[l].n_faults_avoided += 1;
                self.next_fault[l] = None;
            } else {
                return Ok(f);
            }
        }
    }

    /// Consume and return lane `l`'s next fault if it strikes strictly
    /// before `end`.
    fn take_fault_before(&mut self, l: usize, end: f64) -> Step<Option<Fault>> {
        let f = self.peek_fault(l)?;
        if f.t < end {
            Ok(self.next_fault[l].take())
        } else {
            Ok(None)
        }
    }

    /// Refill lane `l`'s prediction head from the arena. An exhausted
    /// span replays the live `None` faithfully when the predictor can
    /// never fire; otherwise it is an underrun and the lane evicts.
    fn refill_pred(&mut self, l: usize) -> Step<()> {
        if self.pi[l] < self.phi[l] {
            self.next_pred[l] = Some(self.bank.pred_at(self.pi[l]));
            self.next_trust[l] = Some(self.bank.trust_at(self.pi[l]));
            self.pi[l] += 1;
            Ok(())
        } else if self.preds_never_fire {
            Ok(())
        } else {
            Err(Evict)
        }
    }

    /// Process all predictions lane `l` has become aware of by now.
    fn drain_predictions(&mut self, l: usize) -> Step<()> {
        loop {
            if self.next_pred[l].is_none() {
                self.refill_pred(l)?;
            }
            match &self.next_pred[l] {
                Some(p) if p.avail <= self.now[l] => {
                    let p = self.next_pred[l].take().expect("matched Some above");
                    self.out[l].n_preds += 1;
                    if p.is_true_positive() {
                        self.out[l].n_true_preds += 1;
                    }
                    // The arena always carries the prediction's
                    // pre-sampled trust uniform (the k-th uniform of
                    // the engine's own per-rep trust stream).
                    let u = self
                        .next_trust[l]
                        .take()
                        .expect("arena-served prediction carries its trust uniform");
                    let trusted = self.policy.trust_with(u);
                    if trusted && p.t_end() > self.now[l] {
                        self.out[l].n_trusted += 1;
                        let pos = self.pending[l]
                            .iter()
                            .position(|q| q.t0 > p.t0)
                            .unwrap_or(self.pending[l].len());
                        self.pending[l].insert(pos, p);
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    /// Work until `end` (absolute time) on lane `l`.
    fn work_until(&mut self, l: usize, end: f64, count_reg: bool) -> Step<Seg> {
        debug_assert!(end >= self.now[l] - 1e-9);
        self.out[l].n_segments += 1;
        if let Some(f) = self.take_fault_before(l, end)? {
            let elapsed = (f.t - self.now[l]).max(0.0);
            self.vol[l] += elapsed;
            if count_reg {
                self.w_reg[l] += elapsed;
            }
            self.now[l] = f.t;
            return Ok(Seg::Faulted(f));
        }
        let elapsed = end - self.now[l];
        self.vol[l] += elapsed;
        if count_reg {
            self.w_reg[l] += elapsed;
        }
        self.now[l] = end;
        Ok(Seg::Completed)
    }

    /// A non-working segment (checkpoint, downtime, recovery, migration).
    fn passive(&mut self, l: usize, duration: f64) -> Step<Seg> {
        self.out[l].n_segments += 1;
        let end = self.now[l] + duration;
        if let Some(f) = self.take_fault_before(l, end)? {
            self.now[l] = f.t;
            return Ok(Seg::Faulted(f));
        }
        self.now[l] = end;
        Ok(Seg::Completed)
    }

    /// Take a checkpoint on lane `l`; on success the volatile work is
    /// persisted. Regular checkpoints close the period.
    fn checkpoint(&mut self, l: usize, proactive: bool) -> Step<Seg> {
        match self.passive(l, self.cfg.c)? {
            Seg::Faulted(f) => Ok(Seg::Faulted(f)),
            Seg::Completed => {
                self.saved[l] += self.vol[l];
                self.vol[l] = 0.0;
                if proactive {
                    self.out[l].n_proactive_ckpts += 1;
                } else {
                    self.out[l].n_ckpts += 1;
                    self.w_reg[l] = 0.0;
                }
                Ok(Seg::Completed)
            }
        }
    }

    /// Apply a fault on lane `l`: lose volatile work, run downtime +
    /// recovery (themselves interruptible), restart the period.
    fn handle_fault(&mut self, l: usize, mut fault: Fault) -> Step<()> {
        loop {
            self.out[l].n_faults += 1;
            if !fault.predicted {
                self.out[l].n_faults_unpredicted += 1;
            }
            self.out[l].lost_work += self.vol[l];
            self.now[l] = fault.t;
            self.vol[l] = 0.0;
            self.w_reg[l] = 0.0;
            match self.passive(l, self.cfg.d)? {
                Seg::Faulted(f) => {
                    fault = f;
                    continue;
                }
                Seg::Completed => {}
            }
            match self.passive(l, self.cfg.r)? {
                Seg::Faulted(f) => {
                    fault = f;
                    continue;
                }
                Seg::Completed => {}
            }
            break;
        }
        // Predictions whose window already closed are moot now.
        let now = self.now[l];
        self.pending[l].retain(|p| p.t_end() > now);
        Ok(())
    }

    /// Execute the proactive response to a trusted prediction whose
    /// action point has arrived on lane `l`.
    fn handle_proactive(&mut self, l: usize, p: Prediction) -> Step<()> {
        match self.policy.window_action() {
            ProactiveMode::Ignore => Ok(()),
            ProactiveMode::Migrate { m } => self.proactive_migrate(l, p, m),
            ProactiveMode::CkptBefore
            | ProactiveMode::SkipWindow
            | ProactiveMode::CkptDuring { .. } => self.proactive_ckpt_flow(l, p),
        }
    }

    fn proactive_ckpt_flow(&mut self, l: usize, p: Prediction) -> Step<()> {
        // Pre-window: checkpoint completing right at t0 when there is
        // room (Fig. 1a); otherwise extra work up to t0 (Fig. 1b).
        let ckpt_start = p.t0 - self.cfg.c;
        if self.now[l] <= ckpt_start {
            if self.now[l] < ckpt_start {
                let end = ckpt_start.min(self.now[l] + self.remaining(l));
                match self.work_until(l, end, true)? {
                    Seg::Faulted(f) => return self.handle_fault(l, f),
                    Seg::Completed => {}
                }
                if self.remaining(l) <= EPS {
                    return Ok(());
                }
            }
            if self.vol[l] > 0.0 {
                match self.checkpoint(l, true)? {
                    Seg::Faulted(f) => return self.handle_fault(l, f),
                    Seg::Completed => {}
                }
            } else {
                // State already persisted; skip the redundant
                // checkpoint and work through the slot instead.
                let end = p.t0.min(self.now[l] + self.remaining(l));
                match self.work_until(l, end, true)? {
                    Seg::Faulted(f) => return self.handle_fault(l, f),
                    Seg::Completed => {}
                }
                if self.remaining(l) <= EPS {
                    return Ok(());
                }
            }
        } else if self.now[l] < p.t0 {
            let end = p.t0.min(self.now[l] + self.remaining(l));
            match self.work_until(l, end, true)? {
                Seg::Faulted(f) => return self.handle_fault(l, f),
                Seg::Completed => {}
            }
            if self.remaining(l) <= EPS {
                return Ok(());
            }
        }
        if self.now[l] >= p.t_end() && p.window > 0.0 {
            return Ok(()); // window passed entirely during an outage
        }
        // Window phase.
        match self.policy.window_action() {
            ProactiveMode::CkptBefore => {} // back to regular mode at once
            ProactiveMode::SkipWindow => {
                let end = p.t_end().min(self.now[l] + self.remaining(l));
                if end > self.now[l] {
                    if let Seg::Faulted(f) = self.work_until(l, end, false)? {
                        self.handle_fault(l, f)?;
                    }
                }
            }
            ProactiveMode::CkptDuring { t_p } => {
                let t_p = t_p.max(self.cfg.c + 1.0);
                let t_end = p.t_end();
                while self.now[l] < t_end - EPS {
                    let slice_end = (self.now[l] + (t_p - self.cfg.c))
                        .min(t_end)
                        .min(self.now[l] + self.remaining(l));
                    if slice_end > self.now[l] {
                        match self.work_until(l, slice_end, false)? {
                            Seg::Faulted(f) => return self.handle_fault(l, f),
                            Seg::Completed => {}
                        }
                    }
                    if self.remaining(l) <= EPS {
                        return Ok(()); // job finished inside the window
                    }
                    if self.now[l] >= t_end - EPS {
                        break; // window closes; trailing ckpt aligns with it
                    }
                    match self.checkpoint(l, true)? {
                        Seg::Faulted(f) => return self.handle_fault(l, f),
                        Seg::Completed => {}
                    }
                }
            }
            _ => unreachable!("ckpt flow is only entered for checkpoint window modes"),
        }
        Ok(())
    }

    fn proactive_migrate(&mut self, l: usize, p: Prediction, m: f64) -> Step<()> {
        let start = p.t0 - m;
        if self.now[l] > start {
            return Ok(()); // cannot complete before the predicted date
        }
        if self.now[l] < start {
            let end = start.min(self.now[l] + self.remaining(l));
            match self.work_until(l, end, true)? {
                Seg::Faulted(f) => return self.handle_fault(l, f),
                Seg::Completed => {}
            }
            if self.remaining(l) <= EPS {
                return Ok(());
            }
        }
        // Live migration: state (volatile work) moves with the task.
        match self.passive(l, m)? {
            Seg::Faulted(f) => self.handle_fault(l, f),
            Seg::Completed => {
                self.out[l].n_migrations += 1;
                if let Some(id) = p.fault_id {
                    // The fault will strike the abandoned node, not us.
                    // Checks the cached head only — polling the arena
                    // here would desync the cursor from the scalar
                    // engine's stream position.
                    if self.next_fault[l].as_ref().map(|f| f.id) == Some(id) {
                        self.next_fault[l] = None;
                        self.out[l].n_faults_avoided += 1;
                    } else {
                        self.neutralized[l].push(id);
                    }
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::model::{Capping, StrategyKind};
    use crate::sim::runner::ReplicationAgg;
    use crate::sim::SimSession;
    use crate::strategies::spec_for;

    fn scenario() -> Scenario {
        let mut s = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
        s.fault_dist = crate::dist::DistSpec::Exp;
        s.work = 2.0e5;
        s
    }

    fn assert_agg_bit_identical(a: &ReplicationAgg, b: &ReplicationAgg) {
        assert_eq!(a.n_reps, b.n_reps);
        assert_eq!(a.n_completed, b.n_completed);
        assert_eq!(a.n_faults, b.n_faults);
        assert_eq!(a.n_preds, b.n_preds);
        assert_eq!(a.n_trusted, b.n_trusted);
        assert_eq!(a.n_ckpts, b.n_ckpts);
        assert_eq!(a.n_proactive_ckpts, b.n_proactive_ckpts);
        assert_eq!(a.n_segments, b.n_segments);
        assert_eq!(a.lost_work.to_bits(), b.lost_work.to_bits());
        assert_eq!(a.waste.mean().to_bits(), b.waste.mean().to_bits());
        assert_eq!(a.makespan.mean().to_bits(), b.makespan.mean().to_bits());
    }

    #[test]
    fn wide_chunks_match_the_scalar_replay_loop() {
        let s0 = scenario();
        let s = crate::experiments::scenario_for(StrategyKind::ExactPrediction, &s0);
        let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 10).unwrap().expect("bank fits"));
        let mut scalar = ReplicationAgg::default();
        let mut session = SimSession::replay(bank.clone(), &s, policy).unwrap();
        for rep in 0..10 {
            scalar.push(&session.run(rep));
        }
        for lanes in [1usize, 3, 8] {
            let mut agg = ReplicationAgg::default();
            let mut kernel = WideKernel::new(bank.clone(), &s, policy, lanes).unwrap();
            let reps: Vec<u64> = (0..10).collect();
            for chunk in reps.chunks(kernel.width()) {
                kernel.run_chunk(chunk, &mut |_, out| agg.push(out));
            }
            assert_agg_bit_identical(&agg, &scalar);
        }
    }

    #[test]
    fn evicted_lanes_fall_back_mid_chunk() {
        // A bank holding only reps 0..3 evicts the back half of every
        // chunk onto the live fallback — outcomes must still match the
        // scalar replay session (which falls back the same way).
        let s = scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 3).unwrap().expect("bank fits"));
        let before = counters();
        let mut scalar = ReplicationAgg::default();
        let mut session = SimSession::replay(bank.clone(), &s, policy).unwrap();
        for rep in 0..8 {
            scalar.push(&session.run(rep));
        }
        let mut agg = ReplicationAgg::default();
        let mut kernel = WideKernel::new(bank, &s, policy, 4).unwrap();
        let reps: Vec<u64> = (0..8).collect();
        for chunk in reps.chunks(4) {
            kernel.run_chunk(chunk, &mut |_, out| agg.push(out));
        }
        assert_agg_bit_identical(&agg, &scalar);
        let after = counters();
        assert!(after.lanes_run >= before.lanes_run + 8);
        assert!(after.evictions >= before.evictions + 5, "reps 3..8 evicted");
    }

    #[test]
    fn wide_kernel_rejects_mismatched_banks() {
        let s = scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let policy = Policy::from_spec(&spec, s.platform.c);
        let lead = policy.required_lead(s.platform.c);
        let bank = Arc::new(TraceBank::try_build(&s, lead, 1).unwrap().unwrap());
        let mut other = s.clone();
        other.seed += 1;
        assert!(WideKernel::new(bank, &other, policy, 4).is_err());
    }
}
