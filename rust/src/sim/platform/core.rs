//! The platform subsystem's discrete-event core: a deterministic
//! min-heap of `(next_tick, component_id)` pairs.
//!
//! Components (per-node fault streams, the predictor's per-node
//! prediction streams, the correlation layer's induced-fault queue)
//! advertise the time of their next event; the scheduler repeatedly
//! pops the earliest one. Determinism is the whole point:
//!
//! * ordering is `f64::total_cmp` on the tick — no `PartialOrd`
//!   ambiguity, NaN ticks order last instead of poisoning the heap;
//! * ties at a shared tick break on the *component id*, ascending — so
//!   two nodes failing at the identical instant always replay in the
//!   same order regardless of insertion history;
//! * components can be inserted or removed mid-run (node join/leave),
//!   and removal re-establishes the heap invariant in place.
//!
//! The heap is a plain binary sift-up/sift-down array — no allocation
//! after warm-up, O(log n) push/pop, O(n) targeted removal (n is the
//! component count, a handful of nodes, not the event count).

/// A pending component activation: (next_tick, component_id).
pub type Entry = (f64, u64);

/// Deterministic binary min-heap over [`Entry`] with stable
/// tie-breaking (tick first via `total_cmp`, then component id).
#[derive(Debug, Clone, Default)]
pub struct EventHeap {
    entries: Vec<Entry>,
}

/// The scheduler's total order: earliest tick first, component id as
/// the deterministic tiebreaker.
fn before(a: &Entry, b: &Entry) -> bool {
    match a.0.total_cmp(&b.0) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => a.1 < b.1,
    }
}

impl EventHeap {
    pub fn new() -> EventHeap {
        EventHeap { entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop every entry, keeping the allocation for reuse.
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Schedule component `id` at `tick`. A component may appear more
    /// than once; the scheduler does not deduplicate (callers that
    /// reschedule should [`EventHeap::remove`] the stale entry first).
    pub fn push(&mut self, tick: f64, id: u64) {
        self.entries.push((tick, id));
        self.sift_up(self.entries.len() - 1);
    }

    /// The earliest entry without removing it.
    pub fn peek(&self) -> Option<Entry> {
        self.entries.first().copied()
    }

    /// Pop the earliest entry (ties by component id).
    pub fn pop(&mut self) -> Option<Entry> {
        if self.entries.is_empty() {
            return None;
        }
        let last = self.entries.len() - 1;
        self.entries.swap(0, last);
        let top = self.entries.pop();
        if !self.entries.is_empty() {
            self.sift_down(0);
        }
        top
    }

    /// Remove every entry of component `id` mid-run (node leave).
    /// Returns how many entries were dropped.
    pub fn remove(&mut self, id: u64) -> usize {
        let before_len = self.entries.len();
        let mut i = 0;
        while i < self.entries.len() {
            if self.entries[i].1 == id {
                let last = self.entries.len() - 1;
                self.entries.swap(i, last);
                self.entries.pop();
                // The swapped-in entry may violate the invariant in
                // either direction relative to its new position.
                if i < self.entries.len() {
                    self.sift_down(i);
                    self.sift_up(i);
                }
            } else {
                i += 1;
            }
        }
        before_len - self.entries.len()
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if before(&self.entries[i], &self.entries[parent]) {
                self.entries.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut best = i;
            if l < self.entries.len() && before(&self.entries[l], &self.entries[best]) {
                best = l;
            }
            if r < self.entries.len() && before(&self.entries[r], &self.entries[best]) {
                best = r;
            }
            if best == i {
                return;
            }
            self.entries.swap(i, best);
            i = best;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut h = EventHeap::new();
        for (t, id) in [(5.0, 0), (1.0, 1), (3.0, 2), (2.0, 3), (4.0, 4)] {
            h.push(t, id);
        }
        let order: Vec<u64> = std::iter::from_fn(|| h.pop()).map(|(_, id)| id).collect();
        assert_eq!(order, [1, 3, 2, 4, 0]);
        assert!(h.is_empty());
    }

    #[test]
    fn shared_tick_breaks_ties_by_component_id() {
        // The determinism contract: identical ticks pop in ascending
        // component order no matter the insertion order.
        for perm in [[3u64, 1, 2, 0], [0, 1, 2, 3], [2, 0, 3, 1]] {
            let mut h = EventHeap::new();
            for id in perm {
                h.push(100.0, id);
            }
            h.push(50.0, 9);
            let order: Vec<u64> = std::iter::from_fn(|| h.pop()).map(|(_, id)| id).collect();
            assert_eq!(order, [9, 0, 1, 2, 3], "insertion order {perm:?}");
        }
    }

    #[test]
    fn mid_run_insertion_lands_in_order() {
        let mut h = EventHeap::new();
        h.push(10.0, 0);
        h.push(30.0, 1);
        assert_eq!(h.pop(), Some((10.0, 0)));
        // A component joining mid-run with an earlier tick than the
        // survivors is served first.
        h.push(20.0, 2);
        assert_eq!(h.pop(), Some((20.0, 2)));
        assert_eq!(h.pop(), Some((30.0, 1)));
    }

    #[test]
    fn mid_run_removal_keeps_the_invariant() {
        let mut h = EventHeap::new();
        for (t, id) in [(1.0, 0), (2.0, 1), (3.0, 2), (2.5, 1), (4.0, 3)] {
            h.push(t, id);
        }
        // Component 1 leaves: both of its entries go.
        assert_eq!(h.remove(1), 2);
        assert_eq!(h.len(), 3);
        let order: Vec<Entry> = std::iter::from_fn(|| h.pop()).collect();
        assert_eq!(order, [(1.0, 0), (3.0, 2), (4.0, 3)]);
        // Removing an absent component is a no-op.
        assert_eq!(h.remove(42), 0);
    }

    #[test]
    fn heap_agrees_with_a_sorted_reference() {
        // Deterministic pseudo-random workload against sort-by-(t, id).
        let mut h = EventHeap::new();
        let mut reference: Vec<Entry> = Vec::new();
        let mut x: u64 = 0x9E3779B97F4A7C15;
        for i in 0..200u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let t = ((x >> 11) % 50) as f64 * 0.5; // many deliberate ties
            h.push(t, i % 7);
            reference.push((t, i % 7));
        }
        reference.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let drained: Vec<Entry> = std::iter::from_fn(|| h.pop()).collect();
        assert_eq!(drained, reference);
    }

    #[test]
    fn empty_heap_pops_none() {
        let mut h = EventHeap::new();
        assert_eq!(h.pop(), None);
        assert_eq!(h.peek(), None);
    }
}
