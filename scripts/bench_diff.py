#!/usr/bin/env python3
"""Diff two BENCH_perf.json files and report per-metric deltas.

Usage: bench_diff.py BASELINE.json CURRENT.json

Walks the shared numeric leaves of the two perf recordings
(`<bench>.<metric>` keys, schema ckptfp-perf-v1, see EXPERIMENTS.md
§Perf), prints a markdown table of the deltas, and flags metrics that
moved against their good direction by more than the noise threshold.
New benches are picked up automatically once both runs record them —
the trace-bank pair (`bank_replay_vs_live.*`, `best_period_crn.*`)
keys its directions off the standard suffixes: `*_per_s`/`speedup`
higher-better, `*_s` (incl. `bank_build_s`, `live_s`, `replay_s`)
lower-better. The lockstep pair follows the same rule:
`lockstep_vs_scalar.*` reads `reps_per_s_lanes*`/`speedup_lanes*`
higher-better and `abstraction_tax_pct` lower-better (it is a
percentage, caught by the explicit hint below);
`waste_grid_batched.*` reads `rows_per_s_*`/`speedup` higher-better
and `scalar_s`/`batched_s` lower-better. The wide-kernel and
accelerator pair ride the same suffixes: `wide_vs_lockstep.*` reads
`*_reps_per_s`/`wide_reps_per_s_w*`/`speedup_vs_*` higher-better;
`waste_grid_accel.*` reads `rows_per_s_*`/`speedup` higher-better and
`cpu_s`/`hlo_s` lower-better.

A missing, empty, or unparsable baseline (first run on a fresh branch,
or the rolling artifact expired) is not an error: the script prints a
note and exits 0 so the comment job never fails the pipeline.

Warn-only by design: the exit code is always 0. CI runs this as a
bench-regression *comment*, not a gate — perf numbers on shared
runners are noisy, and the session hot path is additionally pinned by
the throughput-shaped tests.
"""

import json
import sys

# Metrics where LOWER is better (latencies, durations).
LOWER_BETTER_SUFFIXES = ("_ms", "_s", "_seconds")
# Metrics where HIGHER is better (throughputs, speedups, efficiencies).
HIGHER_BETTER_HINTS = ("per_s", "speedup", "efficiency", "msegs", "msegments")
# Metrics where LOWER is better by explicit name (no suffix match):
# the lockstep lanes=1 overhead vs the plain scalar path.
LOWER_BETTER_HINTS = ("abstraction_tax",)
# Relative move (on the good-direction axis) below which we stay quiet.
NOISE = 0.10


def flatten(obj, prefix=""):
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}." if prefix else f"{k}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix[:-1]] = float(obj)
    return out


def direction(key):
    leaf = key.rsplit(".", 1)[-1]
    if any(h in leaf for h in HIGHER_BETTER_HINTS):
        return "higher"
    if any(h in leaf for h in LOWER_BETTER_HINTS):
        return "lower"
    if leaf.endswith(LOWER_BETTER_SUFFIXES):
        return "lower"
    return None  # informational only (counters, worker counts)


def main():
    if len(sys.argv) != 3:
        print(__doc__.strip())
        return
    # A fresh branch or an expired rolling artifact has no baseline (or
    # an empty/truncated one) — that is a note, not a failure.
    try:
        with open(sys.argv[1]) as f:
            base = flatten(json.load(f))
    except (FileNotFoundError, json.JSONDecodeError) as e:
        print(f"bench-diff: no usable baseline at {sys.argv[1]} ({e.__class__.__name__}); "
              "skipping comparison")
        return
    try:
        with open(sys.argv[2]) as f:
            cur = flatten(json.load(f))
    except (FileNotFoundError, json.JSONDecodeError) as e:
        print(f"bench-diff: no usable current run at {sys.argv[2]} ({e.__class__.__name__}); "
              "skipping comparison")
        return

    shared = sorted(set(base) & set(cur))
    if not shared:
        print("bench-diff: no shared numeric metrics between baseline and current run")
        return

    regressions = []
    print("### Bench delta vs previous run (warn-only)")
    print()
    print("| metric | baseline | current | delta |")
    print("|---|---:|---:|---:|")
    for key in shared:
        b, c = base[key], cur[key]
        if b == 0:
            delta_txt = "n/a"
        else:
            pct = (c - b) / abs(b) * 100.0
            delta_txt = f"{pct:+.1f}%"
        print(f"| `{key}` | {b:.4g} | {c:.4g} | {delta_txt} |")
        d = direction(key)
        if d and b != 0:
            rel = (c - b) / abs(b)
            if (d == "lower" and rel > NOISE) or (d == "higher" and rel < -NOISE):
                regressions.append((key, rel * 100.0, d))
    print()
    only_base = sorted(set(base) - set(cur))
    only_cur = sorted(set(cur) - set(base))
    if only_cur:
        print(f"new metrics: {', '.join(f'`{k}`' for k in only_cur)}")
    if only_base:
        print(f"dropped metrics: {', '.join(f'`{k}`' for k in only_base)}")
    if regressions:
        print()
        print(f"**possible regressions (> {NOISE:.0%} against the good direction):**")
        for key, pct, d in regressions:
            print(f"- `{key}`: {pct:+.1f}% ({d} is better)")
    else:
        print()
        print(f"no metric moved more than {NOISE:.0%} against its good direction.")


if __name__ == "__main__":
    main()
