//! The planner service: a TCP listener speaking the JSONL protocol,
//! one thread per connection, all requests funneled through the
//! dynamic [`Batcher`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::protocol::{error_response, parse_request, plan_response, Request};
use super::Batcher;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address, e.g. "127.0.0.1:7471". Port 0 picks a free port.
    pub addr: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { addr: "127.0.0.1:7471".into() }
    }
}

/// Running service handle: local address + shutdown flag.
pub struct ServiceHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start serving in background threads. The batcher (and its PJRT
/// planner) is shared across connections.
pub fn serve(batcher: Batcher, cfg: ServiceConfig) -> anyhow::Result<ServiceHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let join = std::thread::Builder::new().name("ckptfp-accept".into()).spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let batcher = batcher.clone();
                    let _ = std::thread::Builder::new()
                        .name("ckptfp-conn".into())
                        .spawn(move || handle_connection(stream, batcher));
                }
                Err(_) => break,
            }
        }
    })?;
    Ok(ServiceHandle { addr, stop, join: Some(join) })
}

fn handle_connection(stream: TcpStream, batcher: Batcher) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let response = match parse_request(&line) {
            Err(e) => error_response(&format!("{e:#}")),
            Ok(Request::Ping) => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]).to_string(),
            Ok(Request::Stats) => {
                let stats = batcher.stats();
                let (p50, p95, p99, n) = batcher.metrics().latency_quantiles();
                Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("requests", Json::Num(stats.requests as f64)),
                    ("batches", Json::Num(stats.batches as f64)),
                    ("max_batch", Json::Num(stats.max_batch_seen as f64)),
                    ("lat_p50_s", Json::Num(p50)),
                    ("lat_p95_s", Json::Num(p95)),
                    ("lat_p99_s", Json::Num(p99)),
                    ("lat_n", Json::Num(n as f64)),
                ])
                .to_string()
            }
            Ok(Request::Plan(params)) => match batcher.plan(params) {
                Ok(out) => plan_response(&out),
                Err(e) => error_response(&format!("{e:#}")),
            },
        };
        if writer.write_all(response.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
            || writer.flush().is_err()
        {
            break;
        }
    }
    let _ = peer; // quiet unused in non-logging builds
}

/// Minimal blocking client for examples and tests.
pub struct PlannerClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl PlannerClient {
    pub fn connect(addr: &str) -> anyhow::Result<PlannerClient> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(PlannerClient { reader: BufReader::new(stream), writer })
    }

    /// Send one JSONL request, read one JSONL response.
    pub fn call(&mut self, request: &str) -> anyhow::Result<Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "server closed the connection");
        crate::util::json::parse(line.trim())
    }
}
