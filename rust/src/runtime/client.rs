//! PJRT client wrapper: compile-once, execute-many.

use std::collections::HashMap;
use std::path::Path;

use super::{ArtifactSpec, Manifest};

/// Owns the PJRT CPU client and the compiled executables.
///
/// Not `Sync`: the coordinator funnels executions through a single
/// owner thread (see [`crate::coordinator::Batcher`]).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load the manifest and lazily compile artifacts on first use.
    pub fn open(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        anyhow::ensure!(!manifest.artifacts.is_empty(), "empty manifest in {}", dir.display());
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, executables: HashMap::new() })
    }

    /// Open from the default artifacts location.
    pub fn open_default() -> anyhow::Result<Runtime> {
        let dir = super::artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("artifacts/ not found — run `make artifacts`"))?;
        Self::open(&dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&mut self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let spec = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let path = spec.hlo_path(&self.manifest.dir);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute an artifact on literal inputs; returns the flattened
    /// tuple elements of the (single-device) result.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        anyhow::ensure!(!result.is_empty() && !result[0].is_empty(), "empty execution result");
        let literal = result[0][0].to_literal_sync()?;
        Ok(literal.to_tuple()?)
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.manifest.find(name)
    }
}
