//! Deterministic load-generator harness for the job service.
//!
//! [`generate`] expands a [`TraceSpec`] into a reproducible synthetic
//! request trace — same seed, same bytes — and [`run`] replays it
//! against a live service address from one client thread per tenant
//! (windowed pipelining, so queues actually form and the stride
//! scheduler has something to arbitrate). A second, sequential bench
//! phase times the same set of expensive `best_period` requests cold
//! and then cache-hot, which is what backs `BENCH_serve.json` and the
//! cache speedup acceptance bound.
//!
//! The *trace* is deterministic; the *timings* of course are not. The
//! invariants the harness checks (every request answered exactly once,
//! identical request lines get byte-identical response lines, no
//! tenant short-changed, cold/hot responses agree byte-for-byte) hold
//! for any interleaving, which is what makes them testable across
//! seeds in `tests/test_load.rs`.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::api::{wire, BestPeriodJob, ErrorCode, JobRequest, JobResponse, PlanJob};
use crate::config::{DistSpec, Predictor, Scenario};
use crate::model::StrategyKind;
use crate::rng::substream;

/// A seeded synthetic workload description. Every field participates
/// in the substream labels, so two specs differing in any knob
/// produce unrelated (but individually reproducible) traces.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Master seed for every substream.
    pub seed: u64,
    /// Total trace requests, split across tenants by weight.
    pub requests: usize,
    /// Tenant names with their traffic weights (also their fair-share
    /// weights when the service is configured to match).
    pub tenants: Vec<(String, u64)>,
    /// Distinct-scenario pool size for repeated (cacheable) requests.
    pub distinct: usize,
    /// Probability a request replays a pool scenario instead of a
    /// fresh one; the cache-hit fraction of the trace, roughly.
    pub repeat_ratio: f64,
    /// Pipelining window per tenant connection: this many requests go
    /// on the wire before the first response is awaited.
    pub window: usize,
    /// Distinct `best_period` requests in the bench phase.
    pub bench_distinct: usize,
    /// Cache-hot replay rounds over the bench set.
    pub bench_rounds: usize,
    /// Replications per candidate for the bench `best_period` jobs —
    /// the knob that makes the cold path expensive.
    pub bench_reps: u64,
    /// Period-grid size for the bench `best_period` jobs.
    pub bench_candidates: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            seed: 42,
            requests: 96,
            tenants: vec![("acme".into(), 3), ("beta".into(), 1), ("solo".into(), 1)],
            distinct: 8,
            repeat_ratio: 0.75,
            window: 8,
            bench_distinct: 6,
            bench_rounds: 3,
            bench_reps: 200,
            bench_candidates: 8,
        }
    }
}

/// One trace element: the wire line (tenant-tagged v2 JSONL) and the
/// tenant it belongs to.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub tenant: String,
    pub line: String,
}

/// What one [`run`] observed. Counters are exact; timings are wall
/// clock.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Trace requests sent.
    pub requests: u64,
    /// Response lines received (the exactly-once invariant is
    /// `answered == requests` plus per-connection ordering).
    pub answered: u64,
    /// Responses that decoded to an error (any code).
    pub errors: u64,
    /// The subset of `errors` that were `overloaded` rejections.
    pub overloaded: u64,
    /// Identical request lines that received differing response
    /// bytes — must be 0: responses are pure and the cache is pinned
    /// bit-identical.
    pub mismatches: u64,
    /// Responses received per tenant, in `TraceSpec::tenants` order.
    pub per_tenant: Vec<(String, u64)>,
    pub elapsed_s: f64,
    pub trace_per_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Bench phase: first pass over the distinct set (cache-cold).
    pub cold_s: f64,
    pub cold_per_s: f64,
    /// Bench phase: replay rounds over the same set (cache-hot).
    pub hit_s: f64,
    pub hit_per_s: f64,
    /// `hit_per_s / cold_per_s` — the headline cache win.
    pub hit_speedup: f64,
    /// Every hot response byte-identical to its cold twin.
    pub bench_bit_identical: bool,
    /// Service-side cache counter deltas across the whole run.
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Draw one synthetic planning scenario. Exponential failure law so
/// the `plan` answer is pure closed-form arithmetic — cheap, exact,
/// and byte-reproducible.
fn scenario(seed: u64, label: &str, index: u64) -> Scenario {
    let mut g = substream(seed, label, index);
    let n_procs = 1u64 << (14 + g.next_u64() % 6);
    let p = 0.5 + 0.4 * g.next_f64();
    let r = 0.5 + 0.4 * g.next_f64();
    let mut s = Scenario::paper(n_procs, Predictor::exact(p, r));
    s.fault_dist = DistSpec::Exp;
    s.work = 1.0e5 * (1.0 + 9.0 * g.next_f64());
    s.seed = g.next_u64();
    s
}

fn tagged(req: &JobRequest, tenant: &str) -> String {
    let meta =
        wire::RequestMeta { tenant: Some(tenant.to_string()), stream: false };
    wire::encode_request_tagged(req, &meta)
}

/// Expand the spec into its trace: a pure function of the spec.
pub fn generate(spec: &TraceSpec) -> Vec<TraceRequest> {
    let total_weight: u64 = spec.tenants.iter().map(|&(_, w)| w.max(1)).sum();
    let pool: Vec<Scenario> = (0..spec.distinct.max(1) as u64)
        .map(|i| scenario(spec.seed, "loadgen-pool", i))
        .collect();
    (0..spec.requests as u64)
        .map(|i| {
            let mut g = substream(spec.seed, "loadgen-trace", i);
            let mut pick = g.next_u64() % total_weight.max(1);
            let mut tenant = &spec.tenants[0].0;
            for (name, w) in &spec.tenants {
                let w = (*w).max(1);
                if pick < w {
                    tenant = name;
                    break;
                }
                pick -= w;
            }
            let s = if g.next_f64() < spec.repeat_ratio {
                pool[(g.next_u64() % pool.len() as u64) as usize].clone()
            } else {
                scenario(spec.seed, "loadgen-fresh", i)
            };
            let req = JobRequest::Plan(PlanJob::new(s));
            TraceRequest { tenant: tenant.clone(), line: tagged(&req, tenant) }
        })
        .collect()
}

/// The bench phase's distinct `best_period` lines: Monte Carlo period
/// searches, expensive enough cold that the cache-hot replay measures
/// the service overhead alone.
pub fn bench_lines(spec: &TraceSpec) -> Vec<String> {
    (0..spec.bench_distinct.max(1) as u64)
        .map(|i| {
            let mut job = BestPeriodJob::new(
                scenario(spec.seed, "loadgen-bench", i),
                StrategyKind::Young,
            );
            job.reps = spec.bench_reps;
            job.candidates = spec.bench_candidates;
            tagged(&JobRequest::BestPeriod(job), "bench")
        })
        .collect()
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    fn send(&mut self, line: &str) -> anyhow::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Ok(line.trim_end().to_string())
    }

    fn call(&mut self, line: &str) -> anyhow::Result<String> {
        self.send(line)?;
        self.recv()
    }
}

/// Replay one tenant's slice of the trace over one connection with
/// windowed pipelining. Returns `(request line, response line,
/// latency)` per request, in order.
fn replay_tenant(
    addr: &str,
    lines: &[String],
    window: usize,
) -> anyhow::Result<Vec<(String, String, f64)>> {
    let mut client = Client::connect(addr)?;
    let mut out = Vec::with_capacity(lines.len());
    for chunk in lines.chunks(window.max(1)) {
        let mut sent_at = Vec::with_capacity(chunk.len());
        for line in chunk {
            client.send(line)?;
            sent_at.push(Instant::now());
        }
        for (i, line) in chunk.iter().enumerate() {
            let resp = client.recv()?;
            let ms = sent_at[i].elapsed().as_secs_f64() * 1e3;
            out.push((line.clone(), resp, ms));
        }
    }
    Ok(out)
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Fetch the service's cache counters via a `stats` round trip.
fn cache_counters(client: &mut Client) -> anyhow::Result<(u64, u64)> {
    let resp = client.call(&wire::encode_request(&JobRequest::Stats))?;
    match wire::decode_stream_event(&resp) {
        Ok(wire::StreamEvent::Final { response: JobResponse::Stats(s), .. }) => {
            Ok((s.cache_hits, s.cache_misses))
        }
        other => anyhow::bail!("stats probe got a non-stats response: {other:?}"),
    }
}

fn is_error(resp: &str) -> (bool, bool) {
    match wire::decode_stream_event(resp) {
        Ok(wire::StreamEvent::Final { response: JobResponse::Error(e), .. }) => {
            (true, e.code == ErrorCode::Overloaded)
        }
        _ => (false, false),
    }
}

/// Generate the trace, replay it, run the cold/hot bench phase, and
/// report. `addr` must be a live service (usually an in-process
/// [`super::serve`] bound to port 0).
pub fn run(addr: &str, spec: &TraceSpec) -> anyhow::Result<LoadReport> {
    let trace = generate(spec);
    let mut per_tenant_lines: Vec<(String, Vec<String>)> =
        spec.tenants.iter().map(|(name, _)| (name.clone(), Vec::new())).collect();
    for tr in &trace {
        if let Some((_, lines)) =
            per_tenant_lines.iter_mut().find(|(name, _)| *name == tr.tenant)
        {
            lines.push(tr.line.clone());
        }
    }

    let mut probe = Client::connect(addr)?;
    let (hits0, misses0) = cache_counters(&mut probe)?;

    let started = Instant::now();
    let mut results: Vec<Vec<(String, String, f64)>> = Vec::new();
    std::thread::scope(|scope| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for (_, lines) in &per_tenant_lines {
            let window = spec.window;
            handles.push(scope.spawn(move || replay_tenant(addr, lines, window)));
        }
        for h in handles {
            results.push(h.join().expect("tenant replay thread panicked")?);
        }
        Ok(())
    })?;
    let elapsed_s = started.elapsed().as_secs_f64().max(1e-9);

    let mut report = LoadReport {
        requests: trace.len() as u64,
        elapsed_s,
        ..LoadReport::default()
    };
    let mut latencies: Vec<f64> = Vec::new();
    let mut canonical: HashMap<&str, &str> = HashMap::new();
    for (tenant_result, (name, _)) in results.iter().zip(&per_tenant_lines) {
        report.per_tenant.push((name.clone(), tenant_result.len() as u64));
        for (line, resp, ms) in tenant_result {
            report.answered += 1;
            latencies.push(*ms);
            let (err, over) = is_error(resp);
            report.errors += err as u64;
            report.overloaded += over as u64;
            match canonical.get(line.as_str()) {
                Some(first) if *first != resp => report.mismatches += 1,
                Some(_) => {}
                None => {
                    canonical.insert(line, resp);
                }
            }
        }
    }
    latencies.sort_by(|a, b| a.total_cmp(b));
    report.trace_per_s = report.answered as f64 / elapsed_s;
    report.p50_ms = percentile(&latencies, 0.50);
    report.p95_ms = percentile(&latencies, 0.95);
    report.p99_ms = percentile(&latencies, 0.99);

    // Bench phase: sequential, one connection, cold pass then hot
    // replay rounds over byte-identical request lines.
    let bench = bench_lines(spec);
    let mut client = Client::connect(addr)?;
    let cold_started = Instant::now();
    let mut cold_resps = Vec::with_capacity(bench.len());
    for line in &bench {
        cold_resps.push(client.call(line)?);
    }
    report.cold_s = cold_started.elapsed().as_secs_f64().max(1e-9);
    report.cold_per_s = bench.len() as f64 / report.cold_s;

    report.bench_bit_identical = true;
    let hot_started = Instant::now();
    for _ in 0..spec.bench_rounds.max(1) {
        for (line, cold) in bench.iter().zip(&cold_resps) {
            let hot = client.call(line)?;
            if hot != *cold {
                report.bench_bit_identical = false;
            }
        }
    }
    report.hit_s = hot_started.elapsed().as_secs_f64().max(1e-9);
    report.hit_per_s =
        (bench.len() * spec.bench_rounds.max(1)) as f64 / report.hit_s;
    report.hit_speedup = report.hit_per_s / report.cold_per_s.max(1e-9);

    let (hits1, misses1) = cache_counters(&mut probe)?;
    report.cache_hits = hits1.saturating_sub(hits0);
    report.cache_misses = misses1.saturating_sub(misses0);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_trace_is_a_pure_function_of_the_spec() {
        let spec = TraceSpec::default();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.len(), spec.requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.line, y.line);
        }
        let c = generate(&TraceSpec { seed: 43, ..TraceSpec::default() });
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.line != y.line),
            "different seeds must produce different traces"
        );
    }

    #[test]
    fn every_trace_line_is_a_valid_tenant_tagged_request() {
        let spec = TraceSpec { requests: 24, ..TraceSpec::default() };
        for tr in generate(&spec) {
            let (decoded, meta) = wire::decode_request_meta(&tr.line)
                .expect("generated lines must decode");
            assert!(!decoded.legacy, "the harness speaks v2");
            assert_eq!(meta.tenant.as_deref(), Some(tr.tenant.as_str()));
            assert!(matches!(decoded.request, JobRequest::Plan(_)));
        }
    }

    #[test]
    fn repeats_reuse_pool_scenarios_byte_for_byte() {
        // With repeat_ratio 1.0 every line comes from the small pool,
        // so at most `distinct` unique lines exist per tenant.
        let spec = TraceSpec {
            requests: 64,
            distinct: 4,
            repeat_ratio: 1.0,
            ..TraceSpec::default()
        };
        let trace = generate(&spec);
        for (tenant, _) in &spec.tenants {
            let unique: std::collections::BTreeSet<&str> = trace
                .iter()
                .filter(|t| &t.tenant == tenant)
                .map(|t| t.line.as_str())
                .collect();
            assert!(
                unique.len() <= spec.distinct,
                "tenant {tenant} saw {} unique lines from a pool of {}",
                unique.len(),
                spec.distinct
            );
        }
    }

    #[test]
    fn bench_lines_are_expensive_distinct_best_period_jobs() {
        let spec = TraceSpec::default();
        let lines = bench_lines(&spec);
        assert_eq!(lines.len(), spec.bench_distinct);
        let unique: std::collections::BTreeSet<&str> =
            lines.iter().map(|s| s.as_str()).collect();
        assert_eq!(unique.len(), lines.len(), "bench jobs must be distinct");
        for line in &lines {
            let (decoded, meta) = wire::decode_request_meta(line).unwrap();
            assert_eq!(meta.tenant.as_deref(), Some("bench"));
            match decoded.request {
                JobRequest::BestPeriod(job) => {
                    assert_eq!(job.reps, spec.bench_reps);
                    assert_eq!(job.candidates, spec.bench_candidates);
                }
                other => panic!("expected best_period, got {other:?}"),
            }
        }
    }
}
