//! Canonical cache keys for memoizable jobs.
//!
//! The plan cache ([`super::cache`]) memoizes responses to *pure*
//! jobs — Plan, BestPeriod and Sweep are deterministic functions of
//! their typed inputs. A cache is only as good as its key: two
//! spellings of the same request ("weibull:0.70" vs "weibull:0.7", a
//! platform spec with defaults elided vs spelled out, fields arriving
//! in a different order) must collapse to one entry, and two requests
//! that can produce different bytes must never share one.
//!
//! The rules that make that hold:
//!
//! * Keys are built from the **typed, validated, default-resolved**
//!   request — never from raw wire text. Wire-level concerns (field
//!   order, elided defaults, number spelling) are gone by the time a
//!   key is built, because `FromStr`/`decode_request` already folded
//!   them into one struct value.
//! * Floats are printed with Rust's shortest-round-trip `Display`,
//!   after normalizing `-0.0` to `0.0` — one spelling per value
//!   ([`fmt_f64`]). NaN never reaches a key: every keyed field is
//!   validated finite first.
//! * Every field that can influence the response is in the key —
//!   including the scenario seed and worker count for Monte Carlo
//!   jobs (parallel means are only bit-reproducible per fold width),
//!   but *excluding* them for closed-form jobs (Plan/Sweep ignore
//!   seed, reps and workers entirely, so keying on them would only
//!   split the keyspace).

use crate::api::{BestPeriodJob, JobRequest, PlanJob, SweepJob};
use crate::config::Scenario;
use crate::dist::DistSpec;
use crate::model::Capping;
use crate::sim::platform::{PlatformSpec, RestartScope};
use crate::strategies::PolicySpec;

/// One canonical spelling per f64 value: shortest-round-trip
/// `Display`, with `-0.0` folded into `0.0`. Callers guarantee
/// finiteness (validated request fields only).
pub fn fmt_f64(x: f64) -> String {
    let x = if x == 0.0 { 0.0 } else { x };
    format!("{x}")
}

/// Canonical distribution spec: same grammar as `Display`, but with
/// the shape run through [`fmt_f64`].
pub fn dist_key(d: &DistSpec) -> String {
    match d {
        DistSpec::Exp => "exp".into(),
        DistSpec::Uniform => "uniform".into(),
        DistSpec::Weibull { shape } => format!("weibull:{}", fmt_f64(*shape)),
    }
}

/// Canonical policy spec. Strategy policies key on the strategy name;
/// parameterized policies key on their normalized parameter, so
/// `adaptive` (parsed default gain 1) and `adaptive:1.0` collide as
/// they must.
pub fn policy_key(p: &PolicySpec) -> String {
    match p {
        PolicySpec::Strategy(k) => format!("strategy:{}", k.name()),
        PolicySpec::AdaptivePeriod { gain } => format!("adaptive:{}", fmt_f64(*gain)),
        PolicySpec::RiskThreshold { kappa } => format!("risk:{}", fmt_f64(*kappa)),
    }
}

/// Canonical platform spec: every field spelled out, defaults
/// included, so `Display`'s default-elision ("nodes=4" vs
/// "nodes=4,commit=0") cannot split the keyspace.
pub fn platform_key(p: &PlatformSpec) -> String {
    format!(
        "nodes={};commit={};restart={};group={};spatial={};cascade={};delta={}",
        p.nodes,
        fmt_f64(p.commit),
        match p.restart {
            RestartScope::Full => "full",
            RestartScope::Partial => "partial",
        },
        p.group,
        fmt_f64(p.spatial),
        fmt_f64(p.cascade),
        fmt_f64(p.delta),
    )
}

/// Canonical scenario: every field, fixed order. `false_pred_dist:
/// None` keys as `-`, which cannot collide with a real dist spec.
pub fn scenario_key(s: &Scenario) -> String {
    format!(
        "n={};mu_ind={};c={};d={};r={};alpha={};work={};rec={};prec={};win={};ef={};fd={};fpd={};mig={};seed={}",
        s.platform.n_procs,
        fmt_f64(s.platform.mu_ind),
        fmt_f64(s.platform.c),
        fmt_f64(s.platform.d),
        fmt_f64(s.platform.r),
        fmt_f64(s.alpha),
        fmt_f64(s.work),
        fmt_f64(s.predictor.recall),
        fmt_f64(s.predictor.precision),
        fmt_f64(s.predictor.window),
        fmt_f64(s.predictor.ef),
        dist_key(&s.fault_dist),
        s.false_pred_dist.as_ref().map(|d| dist_key(d)).unwrap_or_else(|| "-".into()),
        fmt_f64(s.migration),
        s.seed,
    )
}

fn capping_key(c: Capping) -> &'static str {
    match c {
        Capping::Capped => "capped",
        Capping::Uncapped => "uncapped",
    }
}

/// Key for a Plan job. Closed-form: the scenario seed is irrelevant to
/// the answer, so it is *not* excluded — it lives inside
/// [`scenario_key`] and excluding it there would special-case the
/// format. Including it costs hit rate only when callers vary seeds on
/// plan requests, which nothing in the stack does.
pub fn plan_job_key(job: &PlanJob) -> String {
    format!(
        "plan|cap={}|pol={}|scn={}",
        capping_key(job.capping),
        job.policy.as_ref().map(policy_key).unwrap_or_else(|| "-".into()),
        scenario_key(&job.scenario),
    )
}

/// Key for a BestPeriod job, from **resolved** values: callers pass
/// the reps/candidates/workers the executor actually uses (`0` → its
/// defaults), so "default" and "explicitly the default" collide.
/// Monte Carlo: reps, workers and the scenario seed all shape the
/// result bits and are all keyed.
pub fn best_period_job_key(
    job: &BestPeriodJob,
    reps: u64,
    candidates: u64,
    workers: usize,
) -> String {
    format!(
        "best_period|strat={}|reps={reps}|cand={candidates}|workers={workers}|prune={}|pol={}|plat={}|scn={}",
        job.strategy.name(),
        u8::from(job.prune),
        job.policy.as_ref().map(policy_key).unwrap_or_else(|| "-".into()),
        job.platform.as_ref().map(platform_key).unwrap_or_else(|| "-".into()),
        scenario_key(&job.scenario),
    )
}

/// Key for a Sweep job: the base scenario plus the exact row list
/// (order matters — rows come back in request order).
pub fn sweep_job_key(job: &SweepJob) -> String {
    let rows: Vec<String> = job.n_procs.iter().map(|n| n.to_string()).collect();
    format!(
        "sweep|cap={}|rows={}|scn={}",
        capping_key(job.capping),
        rows.join(","),
        scenario_key(&job.base),
    )
}

/// Key for any request the cache may serve; `None` marks the request
/// uncacheable (side-effect-free but nondeterministic-by-design stats,
/// or jobs whose cost profile makes caching pointless). The caller
/// passes resolved defaults for the Monte Carlo knobs.
pub fn request_key(
    req: &JobRequest,
    reps: u64,
    candidates: u64,
    workers: usize,
) -> Option<String> {
    match req {
        JobRequest::Plan(job) => Some(plan_job_key(job)),
        JobRequest::BestPeriod(job) => {
            Some(best_period_job_key(job, reps, candidates, workers))
        }
        JobRequest::Sweep(job) => Some(sweep_job_key(job)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;
    use crate::model::StrategyKind;

    fn scenario() -> Scenario {
        let mut s = Scenario::paper(4096, Predictor::windowed(0.85, 0.82, 300.0));
        s.work = 2.0e5;
        s
    }

    #[test]
    fn negative_zero_folds_into_zero() {
        assert_eq!(fmt_f64(-0.0), "0");
        assert_eq!(fmt_f64(0.0), "0");
        assert_ne!(fmt_f64(-1.0e-300), fmt_f64(0.0));
    }

    #[test]
    fn float_spelling_is_shortest_round_trip() {
        // The same bits always print the same way, and the print
        // round-trips to the same bits.
        for x in [0.1, 0.85, 1.0 / 3.0, 2.0e5, f64::MIN_POSITIVE] {
            let printed = fmt_f64(x);
            assert_eq!(printed.parse::<f64>().unwrap().to_bits(), x.to_bits(), "{printed}");
        }
    }

    #[test]
    fn dist_specs_key_identically_across_spellings() {
        for (a, b) in [
            ("weibull:0.7", "weibull:0.70"),
            ("weibull:0.7", "weibull:.7"),
            ("exp", "exponential"),
        ] {
            let ka = dist_key(&a.parse::<DistSpec>().unwrap());
            let kb = dist_key(&b.parse::<DistSpec>().unwrap());
            assert_eq!(ka, kb, "'{a}' vs '{b}'");
        }
        assert_ne!(
            dist_key(&"weibull:0.7".parse::<DistSpec>().unwrap()),
            dist_key(&"weibull:0.71".parse::<DistSpec>().unwrap()),
        );
    }

    #[test]
    fn policy_default_parameter_collides_with_explicit_default() {
        let implicit = "adaptive".parse::<PolicySpec>().unwrap();
        let explicit = "adaptive:1.0".parse::<PolicySpec>().unwrap();
        assert_eq!(policy_key(&implicit), policy_key(&explicit));
        assert_ne!(
            policy_key(&implicit),
            policy_key(&"adaptive:1.5".parse::<PolicySpec>().unwrap())
        );
        assert_ne!(
            policy_key(&"risk:1".parse::<PolicySpec>().unwrap()),
            policy_key(&"adaptive:1".parse::<PolicySpec>().unwrap()),
            "same parameter, different family"
        );
    }

    #[test]
    fn platform_default_elision_cannot_split_the_keyspace() {
        // "nodes=4" elides every default; the explicit spelling must
        // key identically.
        let elided = "nodes=4".parse::<PlatformSpec>().unwrap();
        let explicit = "nodes=4,commit=0,group=1,spatial=0,cascade=0,delta=300"
            .parse::<PlatformSpec>()
            .unwrap();
        assert_eq!(platform_key(&elided), platform_key(&explicit));
        assert_ne!(
            platform_key(&elided),
            platform_key(&"nodes=4,commit=0.5".parse::<PlatformSpec>().unwrap())
        );
    }

    #[test]
    fn scenario_key_separates_every_field_it_prints() {
        let base = scenario();
        let k = scenario_key(&base);
        // Mutating any keyed field must change the key.
        let mut m = base.clone();
        m.seed = base.seed + 1;
        assert_ne!(scenario_key(&m), k, "seed");
        let mut m = base.clone();
        m.work += 1.0;
        assert_ne!(scenario_key(&m), k, "work");
        let mut m = base.clone();
        m.predictor.recall = 0.86;
        assert_ne!(scenario_key(&m), k, "recall");
        let mut m = base.clone();
        m.false_pred_dist = Some(DistSpec::Exp);
        assert_ne!(scenario_key(&m), k, "false_pred_dist");
    }

    #[test]
    fn resolved_defaults_collide_with_explicit_defaults() {
        use crate::api::BestPeriodJob;
        let mut implicit = BestPeriodJob::new(scenario(), StrategyKind::Young);
        implicit.reps = 0; // "use the default"
        let mut explicit = implicit.clone();
        explicit.reps = 100;
        // The executor resolves reps=0 to its default before keying;
        // both calls arrive here with the same resolved values.
        assert_eq!(
            best_period_job_key(&implicit, 100, 16, 4),
            best_period_job_key(&explicit, 100, 16, 4),
        );
        assert_ne!(
            best_period_job_key(&implicit, 100, 16, 4),
            best_period_job_key(&implicit, 100, 16, 8),
            "fold width changes the bits, so it must change the key"
        );
    }

    #[test]
    fn request_key_covers_exactly_the_cacheable_ops() {
        use crate::api::{PlanJob, SweepJob};
        let s = scenario();
        assert!(request_key(&JobRequest::Plan(PlanJob::new(s.clone())), 0, 0, 0).is_some());
        assert!(request_key(
            &JobRequest::Sweep(SweepJob {
                base: s.clone(),
                n_procs: vec![1 << 14, 1 << 16],
                capping: Capping::Uncapped,
            }),
            0,
            0,
            0
        )
        .is_some());
        assert!(request_key(&JobRequest::Ping, 0, 0, 0).is_none());
        assert!(request_key(&JobRequest::Stats, 0, 0, 0).is_none());
    }
}
