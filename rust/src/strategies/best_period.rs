//! BestPeriod: the §5 brute-force numerical search for the optimal
//! regular period of any strategy, by direct simulation — and its
//! policy-layer generalization [`best_policy_with`], which sweeps
//! whatever scalar a [`PolicySpec`] exposes (T_R for paper strategies,
//! gain for `adaptive`, kappa for `risk`).
//!
//! This is by far the most expensive operation in the study, so it gets
//! the full hot-path treatment: the (candidate × replication) product
//! is strided across the worker pool with per-candidate streaming
//! merges (one reused [`BatchRunner`] per worker per candidate —
//! lockstep chunks over the shared trace bank by default, the scalar
//! [`SimSession`] path behind [`BatchOptions::scalar`]), and an
//! optional coarse pass prunes clearly dominated periods before the
//! fine pass spends the remaining replications on the contenders.

use std::sync::Arc;

use crate::config::Scenario;
use crate::coordinator::available_workers;
use crate::sim::{
    fold_waste_grid, fold_waste_grid_retaining, rep_blocks, BatchEngine, BatchOptions,
    BatchRunner, Policy, SimSession, WideKernel,
};
use crate::strategies::{resolve_policy, PolicySpec, StrategySpec};
use crate::trace::TraceBank;
use crate::util::stats::{PairedDiff, Summary};

/// Result of a brute-force period search.
#[derive(Debug, Clone)]
pub struct BestPeriodResult {
    /// The winning period.
    pub t_r: f64,
    /// Mean waste at the winning period.
    pub waste: f64,
    /// The full sweep: (period, mean waste) per candidate. Pruned
    /// candidates report their coarse-pass mean.
    pub sweep: Vec<(f64, f64)>,
    /// How many candidates the coarse pass eliminated.
    pub n_pruned: usize,
    /// Replications actually simulated for the sweep estimates
    /// (coarse pass × full grid plus fine pass × survivors) — the
    /// honest spend, as opposed to the requested
    /// `reps × n_candidates` budget. The CRN pruning statistics are
    /// computed from wastes *retained* during the coarse pass and add
    /// nothing here.
    pub reps_used: u64,
    /// Per-candidate 95% CI half-width of the *paired* waste
    /// difference against the coarse leader (common random numbers):
    /// `NaN` when the search ran without a trace bank, without
    /// pruning, or past the retained-matrix bound; `0` for the leader
    /// itself. See [`crate::util::stats::PairedDiff`].
    pub paired_ci: Vec<f64>,
}

/// Tuning knobs for the search.
#[derive(Debug, Clone)]
pub struct BestPeriodOptions {
    /// Worker threads for the (candidate × replication) product.
    pub workers: usize,
    /// Coarse-pass pruning: spend ~1/4 of the replications on the full
    /// grid, then drop candidates whose waste is already clearly above
    /// the coarse leader before running the rest. A heuristic — it can
    /// (rarely) prune the true argmin on a noisy coarse mean, and
    /// pruned sweep entries carry coarse-budget means — so it is
    /// opt-in; the expensive figure harness enables it explicitly.
    /// With a trace bank attached ([`BestPeriodOptions::replay`]) the
    /// pruning decision uses the *paired* difference CI against the
    /// coarse leader, which separates candidates with far fewer
    /// replications than the unpaired bands.
    pub prune: bool,
    /// Materialize each replication's trace once in a
    /// [`TraceBank`] and replay it across all candidates (common
    /// random numbers). Bit-identical to live generation — pinned by
    /// golden test — and a large constant-factor win since sampling
    /// dominates the per-candidate cost; the bank declines (and the
    /// search transparently runs live) when its arena would exceed
    /// [`crate::trace::bank::MAX_RESIDENT_BYTES`].
    pub replay: bool,
    /// Batch lane width for bank-backed sweeps: when a trace bank is
    /// attached and `batch.lanes > 0`, each worker advances a chunk of
    /// replications together over the arena — through the wide SoA
    /// kernel ([`crate::sim::WideKernel`]) when `batch.wide` is set
    /// (the default), through per-lane lockstep engines
    /// ([`crate::sim::BatchEngine`]) otherwise. Both pinned
    /// bit-identical to the scalar path; `BatchOptions::scalar()`
    /// selects that path explicitly. Ignored when no bank serves the
    /// sweep (live and platform searches are always scalar).
    pub batch: BatchOptions,
}

impl Default for BestPeriodOptions {
    fn default() -> Self {
        BestPeriodOptions {
            workers: available_workers(),
            prune: false,
            replay: true,
            batch: BatchOptions::default(),
        }
    }
}

/// Build the candidate grid: geometric between `lo` and `hi`.
/// `n == 2` degenerates to the bracket endpoints; a near-degenerate
/// bracket (`hi ≈ lo`) yields a valid, nearly-constant grid.
pub fn period_grid(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(hi > lo && lo > 0.0 && n >= 2);
    let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
    (0..n).map(|i| lo * ratio.powi(i as i32)).collect()
}

/// Brute-force the best T_R for `base` on `scenario`: simulate `reps`
/// replications at each of `n_candidates` periods spanning
/// [C + 1, span_factor * sqrt(2 mu C)] and return the exhaustive
/// argmin. Runs with default [`BestPeriodOptions`] (all cores, no
/// pruning — use [`best_period_with`] to opt into the coarse-pass
/// prune).
pub fn best_period(
    scenario: &Scenario,
    base: &StrategySpec,
    reps: u64,
    n_candidates: usize,
) -> anyhow::Result<BestPeriodResult> {
    best_period_with(scenario, base, reps, n_candidates, &BestPeriodOptions::default())
}

/// [`best_period`] with explicit worker/pruning options.
pub fn best_period_with(
    scenario: &Scenario,
    base: &StrategySpec,
    reps: u64,
    n_candidates: usize,
    opts: &BestPeriodOptions,
) -> anyhow::Result<BestPeriodResult> {
    anyhow::ensure!(reps > 0, "best_period needs at least one replication");
    let c = scenario.platform.c;
    let mu = scenario.mu();
    let formula = (2.0 * mu * c).sqrt();
    // Search a generous bracket around the closed-form optimum. Periods
    // below ~2C are never competitive (waste >= C/T > 1/2) and cost
    // enormous simulated time (one checkpoint per sliver of work), so
    // the bracket floor protects the search from pathological runs.
    let lo = (formula / 6.0).max(2.0 * c);
    let hi = (4.0 * formula).max(lo * 4.0);
    let grid = period_grid(lo, hi, n_candidates);
    let specs: Vec<StrategySpec> =
        grid.iter().map(|&t_r| StrategySpec { t_r, ..base.clone() }).collect();
    // Surface configuration errors once, before any worker runs.
    drop(SimSession::new(scenario, &specs[0])?);

    // All candidates share the base's proactive mode, hence its lead —
    // one bank serves the whole sweep. `None` (declined or replay off)
    // falls through to classic live sessions.
    let bank = if opts.replay {
        TraceBank::try_build(scenario, base.required_lead(c), reps)?.map(Arc::new)
    } else {
        None
    };
    let batch = opts.batch;
    Ok(search_grid(&grid, reps, opts, bank.is_some(), |ci| match &bank {
        Some(b) if batch.lanes > 0 && batch.wide => BatchRunner::Wide(
            WideKernel::new(b.clone(), scenario, Policy::from_spec(&specs[ci], c), batch.lanes)
                .expect("bank lead/seed derived from this scenario"),
        ),
        Some(b) if batch.lanes > 0 => BatchRunner::Lockstep(
            BatchEngine::new(b.clone(), scenario, Policy::from_spec(&specs[ci], c), batch.lanes)
                .expect("bank lead/seed derived from this scenario"),
        ),
        Some(b) => BatchRunner::Scalar(
            SimSession::replay(b.clone(), scenario, Policy::from_spec(&specs[ci], c))
                .expect("bank lead/seed derived from this scenario"),
        ),
        None => BatchRunner::Scalar(
            SimSession::new(scenario, &specs[ci]).expect("scenario validated above"),
        ),
    }))
}

/// [`best_period_with`] on a multi-node platform: the same bracket
/// around `sqrt(2 mu C)` — the platform's aggregate MTBF equals the
/// scenario's `mu` by Poisson superposition, so the closed-form anchor
/// is unchanged — with every candidate simulated through
/// [`SimSession::new_on_platform`]. Platform sessions decline
/// trace-bank replay (a bank materializes one aggregated stream, not K
/// merged per-node streams), so the sweep always runs live and the
/// paired-CI fields stay NaN.
pub fn best_period_on_platform(
    scenario: &Scenario,
    base: &StrategySpec,
    pspec: &crate::sim::PlatformSpec,
    reps: u64,
    n_candidates: usize,
    opts: &BestPeriodOptions,
) -> anyhow::Result<BestPeriodResult> {
    anyhow::ensure!(reps > 0, "best_period needs at least one replication");
    pspec.validate()?;
    let c = scenario.platform.c;
    let mu = scenario.mu();
    let formula = (2.0 * mu * c).sqrt();
    let lo = (formula / 6.0).max(2.0 * c);
    let hi = (4.0 * formula).max(lo * 4.0);
    let grid = period_grid(lo, hi, n_candidates);
    let specs: Vec<StrategySpec> =
        grid.iter().map(|&t_r| StrategySpec { t_r, ..base.clone() }).collect();
    // Surface configuration errors once, before any worker runs.
    drop(SimSession::new_on_platform(scenario, &specs[0], pspec)?);
    Ok(search_grid(&grid, reps, opts, false, |ci| {
        BatchRunner::Scalar(
            SimSession::new_on_platform(scenario, &specs[ci], pspec)
                .expect("platform spec validated above"),
        )
    }))
}

/// Parameter search for a [`PolicySpec`]: the same brute-force
/// machinery as [`best_period_with`], sweeping the policy's natural
/// tuning axis. Paper strategies sweep their regular period T_R
/// (delegating to [`best_period_with`]); `adaptive` sweeps its gain
/// and `risk` its kappa over a geometric `[x/4, 4x]` bracket around
/// the spec's value. The result's `t_r` field and sweep x-axis carry
/// the winning parameter in the policy's own units.
pub fn best_policy_with(
    scenario: &Scenario,
    spec: &PolicySpec,
    reps: u64,
    n_candidates: usize,
    opts: &BestPeriodOptions,
) -> anyhow::Result<BestPeriodResult> {
    anyhow::ensure!(reps > 0, "best_policy needs at least one replication");
    // Validate before the grid construction: a degenerate parameter
    // must surface as an error, not a bracket-assertion panic.
    spec.validate()?;
    match *spec {
        PolicySpec::Strategy(kind) => {
            let rp = resolve_policy(spec, scenario)?;
            let base =
                crate::strategies::spec_for(kind, &rp.scenario, crate::model::Capping::Uncapped);
            best_period_with(&rp.scenario, &base, reps, n_candidates, opts)
        }
        PolicySpec::AdaptivePeriod { gain } => search_policy_param(
            scenario,
            gain,
            n_candidates,
            reps,
            opts,
            |g| PolicySpec::AdaptivePeriod { gain: g },
        ),
        PolicySpec::RiskThreshold { kappa } => search_policy_param(
            scenario,
            kappa,
            n_candidates,
            reps,
            opts,
            |k| PolicySpec::RiskThreshold { kappa: k },
        ),
    }
}

/// Sweep one scalar policy parameter over a geometric bracket around
/// `center`, resolving each candidate against `scenario`.
fn search_policy_param(
    scenario: &Scenario,
    center: f64,
    n_candidates: usize,
    reps: u64,
    opts: &BestPeriodOptions,
    respec: impl Fn(f64) -> PolicySpec,
) -> anyhow::Result<BestPeriodResult> {
    let (lo, hi) = (center / 4.0, center * 4.0);
    // `validate()` admits any finite positive parameter, including
    // denormals whose quarter underflows to 0 and giants whose 4x
    // overflows — either would trip `period_grid`'s bracket assert and
    // panic inside the executor. Refuse them as a plain error instead.
    anyhow::ensure!(
        lo > 0.0 && hi.is_finite() && hi > lo,
        "policy parameter {center:e} is too extreme to bracket a [x/4, 4x] search grid"
    );
    let grid = period_grid(lo, hi, n_candidates.max(2));
    let policies: Vec<Policy> = grid
        .iter()
        .map(|&x| Ok(resolve_policy(&respec(x), scenario)?.policy))
        .collect::<anyhow::Result<_>>()?;
    // Surface configuration errors once, before any worker runs.
    drop(SimSession::from_policy(scenario, policies[0])?);

    // The swept parameter never changes the proactive mode, so every
    // candidate needs the same lead and one bank covers the sweep.
    let c = scenario.platform.c;
    let bank = if opts.replay {
        TraceBank::try_build(scenario, policies[0].required_lead(c), reps)?.map(Arc::new)
    } else {
        None
    };
    let batch = opts.batch;
    Ok(search_grid(&grid, reps, opts, bank.is_some(), |ci| match &bank {
        Some(b) if batch.lanes > 0 && batch.wide => BatchRunner::Wide(
            WideKernel::new(b.clone(), scenario, policies[ci], batch.lanes)
                .expect("bank lead/seed derived from this scenario"),
        ),
        Some(b) if batch.lanes > 0 => BatchRunner::Lockstep(
            BatchEngine::new(b.clone(), scenario, policies[ci], batch.lanes)
                .expect("bank lead/seed derived from this scenario"),
        ),
        Some(b) => BatchRunner::Scalar(
            SimSession::replay(b.clone(), scenario, policies[ci])
                .expect("bank lead/seed derived from this scenario"),
        ),
        None => BatchRunner::Scalar(
            SimSession::from_policy(scenario, policies[ci]).expect("policy validated above"),
        ),
    }))
}

/// The shared search core: per-candidate streaming waste summaries over
/// the (candidate × replication) product, with the optional coarse
/// pruning pass. `make(i)` builds candidate `i`'s session; the sweep
/// x-axis is `grid`. `crn` says the sessions replay a common trace
/// bank, which upgrades the pruning decision to *paired*-difference
/// CIs over wastes retained during the coarse pass (see below) — it
/// never changes the sweep estimates themselves.
fn search_grid<F>(
    grid: &[f64],
    reps: u64,
    opts: &BestPeriodOptions,
    crn: bool,
    make: F,
) -> BestPeriodResult
where
    F: Fn(usize) -> BatchRunner + Sync,
{
    // A pool pass over `candidates × [rep_lo, rep_hi)`: per-candidate
    // streaming waste summaries through the shared product folder
    // (candidate-major rep blocks, one reused runner per block).
    let simulate = |candidates: &[usize], rep_lo: u64, rep_hi: u64| -> Vec<Summary> {
        let tasks = rep_blocks(candidates, rep_lo, rep_hi, opts.workers);
        fold_waste_grid(&tasks, grid.len(), opts.workers, &make)
    };

    let all: Vec<usize> = (0..grid.len()).collect();
    // Coarse pass: a fraction of the budget on the full grid. Only
    // worth it when there are enough replications for the coarse means
    // to rank candidates and enough candidates to prune.
    let coarse_reps =
        if opts.prune && reps >= 8 && grid.len() >= 4 { (reps / 4).max(2) } else { reps };
    // With CRN pruning ahead, the coarse pass *retains* every per-rep
    // waste (one extra f64 per simulation, bounded below) so the
    // paired-difference statistics come free afterwards — nothing is
    // ever simulated twice. The matrix is only worth carrying when a
    // prune will actually read it.
    let retain_matrix = crn
        && coarse_reps < reps
        && grid.len() as u64 * coarse_reps <= (1 << 22);
    let (coarse, coarse_matrix) = if retain_matrix {
        let tasks = rep_blocks(&all, 0, coarse_reps, opts.workers);
        let (sums, matrix) = fold_waste_grid_retaining(
            &tasks,
            grid.len(),
            0,
            coarse_reps,
            opts.workers,
            &make,
        );
        (sums, Some(matrix))
    } else {
        (simulate(&all, 0, coarse_reps), None)
    };
    let mut reps_used = grid.len() as u64 * coarse_reps;
    let mut paired_ci = vec![f64::NAN; grid.len()];

    let (survivors, totals, n_pruned) = if coarse_reps >= reps {
        (all, coarse, 0)
    } else {
        let best_idx = argmin(&coarse);
        let best_mean = coarse[best_idx].mean();
        // Keep everything statistically close to the coarse leader.
        // Without CRN, a candidate survives unless its mean is above
        // the leader's by both a 10% margin and the combined 95% noise
        // bands. With CRN the per-rep wastes retained by the coarse
        // pass pair each candidate with the leader on the same traces,
        // and the decision uses the *paired-difference* CI —
        // dramatically narrower on common random numbers, so
        // genuinely-worse candidates are pruned at replication counts
        // where the unpaired bands still overlap.
        let pairs: Option<Vec<PairedDiff>> = coarse_matrix.map(|matrix| {
            let span = coarse_reps as usize;
            let leader = &matrix[best_idx * span..(best_idx + 1) * span];
            all.iter()
                .map(|&ci| {
                    let mut pd = PairedDiff::new();
                    if ci != best_idx {
                        let row = &matrix[ci * span..(ci + 1) * span];
                        for (a, b) in row.iter().zip(leader) {
                            pd.push(*a, *b);
                        }
                    }
                    pd
                })
                .collect()
        });
        let survivors: Vec<usize> = all
            .iter()
            .copied()
            .filter(|&ci| match &pairs {
                Some(pds) if ci != best_idx => {
                    let slack = (0.10 * best_mean.abs()).max(pds[ci].ci95_paired());
                    pds[ci].mean_diff() <= slack
                }
                _ => {
                    let slack =
                        (0.10 * best_mean.abs()).max(coarse[ci].ci95() + coarse[best_idx].ci95());
                    coarse[ci].mean() <= best_mean + slack
                }
            })
            .collect();
        if let Some(pds) = &pairs {
            for ci in 0..grid.len() {
                paired_ci[ci] = if ci == best_idx { 0.0 } else { pds[ci].ci95_paired() };
            }
        }
        let n_pruned = grid.len() - survivors.len();
        let fine = simulate(&survivors, coarse_reps, reps);
        reps_used += survivors.len() as u64 * (reps - coarse_reps);
        let totals: Vec<Summary> = coarse
            .iter()
            .zip(&fine)
            .map(|(c, f)| c.merge(f))
            .collect();
        (survivors, totals, n_pruned)
    };

    let sweep: Vec<(f64, f64)> =
        grid.iter().zip(&totals).map(|(&t_r, s)| (t_r, s.mean())).collect();
    let mut best = (f64::INFINITY, grid[0]);
    for &ci in &survivors {
        let w = totals[ci].mean();
        if w < best.0 {
            best = (w, grid[ci]);
        }
    }
    BestPeriodResult { t_r: best.1, waste: best.0, sweep, n_pruned, reps_used, paired_ci }
}

fn argmin(sums: &[Summary]) -> usize {
    let mut best = 0;
    for (i, s) in sums.iter().enumerate() {
        if s.mean() < sums[best].mean() {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;
    use crate::model::{Capping, StrategyKind};
    use crate::strategies::spec_for;

    #[test]
    fn grid_is_geometric_and_bounded() {
        let g = period_grid(100.0, 10000.0, 9);
        assert_eq!(g.len(), 9);
        assert!((g[0] - 100.0).abs() < 1e-9);
        assert!((g[8] - 10000.0).abs() < 1e-6);
        let r0 = g[1] / g[0];
        let r1 = g[5] / g[4];
        assert!((r0 - r1).abs() < 1e-9);
    }

    #[test]
    fn grid_two_candidates_is_the_bracket() {
        let g = period_grid(500.0, 2000.0, 2);
        assert_eq!(g.len(), 2);
        assert!((g[0] - 500.0).abs() < 1e-9);
        assert!((g[1] - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn grid_degenerate_bracket_stays_finite_and_monotone() {
        // lo ≈ hi: the ratio is within rounding of 1; every point must
        // stay finite, inside the bracket, and nondecreasing.
        let lo = 1000.0;
        let hi = 1000.0 * (1.0 + 1e-9);
        let g = period_grid(lo, hi, 8);
        assert_eq!(g.len(), 8);
        for w in g.windows(2) {
            assert!(w[0] <= w[1] + 1e-12, "not monotone: {w:?}");
        }
        for &x in &g {
            assert!(x.is_finite() && x >= lo - 1e-9 && x <= hi + 1e-9, "out of bracket: {x}");
        }
    }

    #[test]
    #[should_panic]
    fn grid_rejects_inverted_bracket() {
        let _ = period_grid(2000.0, 500.0, 4);
    }

    fn small_study() -> (crate::config::Scenario, StrategySpec) {
        let mut s = crate::config::Scenario::paper(1 << 16, Predictor::none());
        s.fault_dist = crate::dist::DistSpec::Exp;
        s.work = 2.0e5;
        let base = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        (s, base)
    }

    #[test]
    fn best_period_close_to_formula() {
        // Small Exponential study: the numeric argmin must land near
        // sqrt(2 mu C) — the paper's "BestPeriod ≈ model" observation.
        let (s, base) = small_study();
        let res = best_period(&s, &base, 12, 12).unwrap();
        let formula = (2.0 * s.mu() * s.platform.c).sqrt();
        // Coarse grid + stochastic: within a factor 2 is the guarantee;
        // the recorded experiments use finer settings.
        assert!(
            res.t_r > formula / 2.0 && res.t_r < formula * 2.0,
            "best {} vs formula {formula}",
            res.t_r
        );
        assert_eq!(res.sweep.len(), 12);
        // The winner is the argmin over the surviving (fully sampled)
        // candidates, and it appears in the sweep at its own waste.
        assert!(res
            .sweep
            .iter()
            .any(|&(t, w)| t == res.t_r && (w - res.waste).abs() < 1e-12));
    }

    #[test]
    fn pruned_search_agrees_with_exhaustive_on_the_winner() {
        let (s, base) = small_study();
        let exhaustive = best_period_with(
            &s,
            &base,
            12,
            8,
            &BestPeriodOptions { workers: 2, prune: false, replay: true, ..Default::default() },
        )
        .unwrap();
        let pruned = best_period_with(
            &s,
            &base,
            12,
            8,
            &BestPeriodOptions { workers: 2, prune: true, replay: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(exhaustive.n_pruned, 0);
        // The heuristic does not guarantee the exhaustive argmin
        // survives the coarse pass (a noisy-high coarse mean can prune
        // it), so the contract is on *quality*, not identity: the
        // pruned search's waste must be within noise of the exhaustive
        // optimum, and the basin is shallow enough that the period may
        // only move by one grid neighbor.
        assert!(
            pruned.waste <= exhaustive.waste * 1.05 + 1e-12,
            "pruned optimum {} much worse than exhaustive {}",
            pruned.waste,
            exhaustive.waste
        );
        let ratio = pruned.t_r / exhaustive.t_r;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "pruned winner {} far from exhaustive {}",
            pruned.t_r,
            exhaustive.t_r
        );
        // Survivors share trace streams with the exhaustive run, so if
        // the winner did survive, the waste agrees to reassociation
        // error.
        if pruned.t_r == exhaustive.t_r {
            assert!((pruned.waste - exhaustive.waste).abs() < 1e-9);
        }
    }

    #[test]
    fn policy_search_delegates_for_paper_strategies() {
        // A Strategy(...) policy spec must return the classic T_R
        // search, bit for bit.
        let (s, base) = small_study();
        let opts = BestPeriodOptions { workers: 2, prune: false, replay: true, ..Default::default() };
        let direct = best_period_with(&s, &base, 6, 5, &opts).unwrap();
        let via_policy = best_policy_with(
            &s,
            &PolicySpec::Strategy(crate::model::StrategyKind::Young),
            6,
            5,
            &opts,
        )
        .unwrap();
        assert_eq!(direct.t_r, via_policy.t_r);
        assert_eq!(direct.waste, via_policy.waste);
        assert_eq!(direct.sweep, via_policy.sweep);
    }

    #[test]
    fn policy_search_sweeps_the_risk_kappa() {
        let (s, _) = small_study();
        let opts = BestPeriodOptions { workers: 2, prune: false, replay: true, ..Default::default() };
        let res =
            best_policy_with(&s, &PolicySpec::RiskThreshold { kappa: 1.0 }, 6, 5, &opts).unwrap();
        assert_eq!(res.sweep.len(), 5);
        // The bracket spans [1/4, 4] around kappa = 1.
        assert!((res.sweep[0].0 - 0.25).abs() < 1e-9);
        assert!((res.sweep[4].0 - 4.0).abs() < 1e-6);
        // The winner is a grid point with its own recorded waste.
        assert!(res.sweep.iter().any(|&(k, w)| k == res.t_r && w == res.waste));
        assert!(res.waste > 0.0 && res.waste < 1.0);
    }

    #[test]
    fn policy_search_refuses_unbracketable_parameters() {
        // Denormal kappa: finite and positive (so validate admits it)
        // but kappa/4 underflows to 0 — must be an error, not a panic.
        let (s, _) = small_study();
        let opts = BestPeriodOptions { workers: 2, prune: false, replay: true, ..Default::default() };
        let tiny = PolicySpec::RiskThreshold { kappa: 5e-324 };
        let err = best_policy_with(&s, &tiny, 2, 4, &opts).unwrap_err();
        assert!(err.to_string().contains("too extreme"), "{err:#}");
        let huge = PolicySpec::AdaptivePeriod { gain: f64::MAX };
        assert!(best_policy_with(&s, &huge, 2, 4, &opts).is_err());
    }

    #[test]
    fn replay_search_is_bit_identical_to_live_search() {
        // The CRN tentpole contract at the search level: with pruning
        // off (so both paths run the identical candidate × rep product
        // through the identical fold), a bank-replayed search and a
        // live-generation search agree to the bit.
        let (s, base) = small_study();
        let live = best_period_with(
            &s,
            &base,
            6,
            6,
            &BestPeriodOptions { workers: 2, prune: false, replay: false, ..Default::default() },
        )
        .unwrap();
        let replay = best_period_with(
            &s,
            &base,
            6,
            6,
            &BestPeriodOptions { workers: 2, prune: false, replay: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(live.t_r.to_bits(), replay.t_r.to_bits());
        assert_eq!(live.waste.to_bits(), replay.waste.to_bits());
        assert_eq!(live.sweep.len(), replay.sweep.len());
        for (a, b) in live.sweep.iter().zip(&replay.sweep) {
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
        assert_eq!(live.reps_used, replay.reps_used);
        assert!(live.paired_ci.iter().all(|x| x.is_nan()), "no pairing without pruning");
    }

    #[test]
    fn reps_used_reports_the_honest_spend() {
        let (s, base) = small_study();
        // No pruning: every candidate gets the full budget.
        let full = best_period_with(
            &s,
            &base,
            6,
            5,
            &BestPeriodOptions { workers: 2, prune: false, replay: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(full.reps_used, 6 * 5);
        // Pruning: the coarse pass covers the grid, the fine pass only
        // survivors — never more than the requested budget.
        let pruned = best_period_with(
            &s,
            &base,
            16,
            8,
            &BestPeriodOptions { workers: 2, prune: true, replay: true, ..Default::default() },
        )
        .unwrap();
        let coarse = (16u64 / 4).max(2);
        let expected =
            8 * coarse + (8 - pruned.n_pruned as u64) * (16 - coarse);
        assert_eq!(pruned.reps_used, expected);
        assert!(pruned.reps_used <= 16 * 8);
        // The paired CIs exist exactly when CRN pruning ran.
        assert_eq!(pruned.paired_ci.len(), 8);
        assert!(pruned.paired_ci.iter().any(|x| x.is_finite()));
    }

    #[test]
    fn single_platform_search_matches_the_live_search() {
        // nodes = 1 platform sweeps are the classic live sweep, bit for
        // bit (platform sessions never replay, so compare to replay=false).
        let (s, base) = small_study();
        let opts = BestPeriodOptions { workers: 2, prune: false, replay: false, ..Default::default() };
        let live = best_period_with(&s, &base, 5, 5, &opts).unwrap();
        let platform = best_period_on_platform(
            &s,
            &base,
            &crate::sim::PlatformSpec::default(),
            5,
            5,
            &opts,
        )
        .unwrap();
        assert_eq!(live.t_r.to_bits(), platform.t_r.to_bits());
        assert_eq!(live.waste.to_bits(), platform.waste.to_bits());
        for (a, b) in live.sweep.iter().zip(&platform.sweep) {
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
    }

    #[test]
    fn platform_search_finds_a_sane_optimum_at_n_nodes() {
        // Superposition keeps the aggregate MTBF at mu, so the winner
        // still lands near sqrt(2 mu C) for an uncorrelated platform.
        let (s, base) = small_study();
        let pspec = crate::sim::PlatformSpec { nodes: 4, ..Default::default() };
        let opts = BestPeriodOptions { workers: 2, prune: false, replay: false, ..Default::default() };
        let res = best_period_on_platform(&s, &base, &pspec, 10, 8, &opts).unwrap();
        let formula = (2.0 * s.mu() * s.platform.c).sqrt();
        assert!(
            res.t_r > formula / 2.0 && res.t_r < formula * 2.0,
            "best {} vs formula {formula}",
            res.t_r
        );
        assert!(res.paired_ci.iter().all(|x| x.is_nan()), "no CRN on platforms");
    }

    #[test]
    fn policy_search_is_reproducible() {
        let (s, _) = small_study();
        let opts = BestPeriodOptions { workers: 3, prune: false, replay: true, ..Default::default() };
        let spec = PolicySpec::AdaptivePeriod { gain: 1.0 };
        let a = best_policy_with(&s, &spec, 5, 4, &opts).unwrap();
        let b = best_policy_with(&s, &spec, 5, 4, &opts).unwrap();
        assert_eq!(a.t_r, b.t_r);
        assert_eq!(a.sweep, b.sweep);
    }

    #[test]
    fn parallel_search_is_reproducible() {
        let (s, base) = small_study();
        let opts = BestPeriodOptions { workers: 4, prune: true, replay: true, ..Default::default() };
        let a = best_period_with(&s, &base, 8, 6, &opts).unwrap();
        let b = best_period_with(&s, &base, 8, 6, &opts).unwrap();
        assert_eq!(a.t_r, b.t_r);
        assert_eq!(a.waste, b.waste);
        assert_eq!(a.n_pruned, b.n_pruned);
        for (x, y) in a.sweep.iter().zip(&b.sweep) {
            assert_eq!(x, y);
        }
    }
}
