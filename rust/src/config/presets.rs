//! Canonical parameterizations: the paper's two literature predictors
//! (§5.1) and the full Table 3 predictor catalog (§6).

use super::{Predictor, Scenario};

/// The accurate BlueGene/P predictor of Yu, Zheng, Lan & Coghlan [12]:
/// p = 0.82, r = 0.85.
pub fn predictor_yu(window: f64) -> Predictor {
    Predictor::windowed(0.85, 0.82, window)
}

/// The location/lead-time predictor of Zheng, Lan, Gupta, Coghlan &
/// Beckman [14]: p = 0.4, r = 0.7.
pub fn predictor_zheng(window: f64) -> Predictor {
    Predictor::windowed(0.7, 0.4, window)
}

/// One row of the paper's Table 3 (comparative predictor survey).
#[derive(Debug, Clone)]
pub struct CatalogEntry {
    pub source: &'static str,
    pub lead_time: Option<f64>,
    pub precision: f64,
    pub recall: f64,
    /// Prediction window (s); None = exact-date predictor.
    pub window: Option<f64>,
}

impl CatalogEntry {
    pub fn predictor(&self, default_window: f64) -> Predictor {
        match self.window {
            Some(w) if w > 0.0 => Predictor::windowed(self.recall, self.precision, w),
            Some(_) | None => {
                if default_window > 0.0 {
                    Predictor::windowed(self.recall, self.precision, default_window)
                } else {
                    Predictor::exact(self.recall, self.precision)
                }
            }
        }
    }
}

/// Table 3 of the paper, verbatim. Window "yes (size unknown)" entries
/// carry `Some(0.0)` and inherit the caller's default window.
pub fn predictor_catalog() -> Vec<CatalogEntry> {
    use crate::util::units::HOUR;
    vec![
        CatalogEntry { source: "[14] Zheng et al. (lead 300s)", lead_time: Some(300.0), precision: 0.40, recall: 0.70, window: None },
        CatalogEntry { source: "[14] Zheng et al. (lead 600s)", lead_time: Some(600.0), precision: 0.35, recall: 0.60, window: None },
        CatalogEntry { source: "[12] Yu et al. (lead 2h)", lead_time: Some(2.0 * HOUR), precision: 0.648, recall: 0.652, window: Some(0.0) },
        CatalogEntry { source: "[12] Yu et al. (lead 0)", lead_time: Some(0.0), precision: 0.823, recall: 0.854, window: Some(0.0) },
        CatalogEntry { source: "[6] Gainaru et al.", lead_time: Some(32.0), precision: 0.93, recall: 0.43, window: None },
        CatalogEntry { source: "[5] Fulp et al.", lead_time: None, precision: 0.70, recall: 0.75, window: None },
        CatalogEntry { source: "[9] Liang et al. (1h)", lead_time: None, precision: 0.20, recall: 0.30, window: Some(1.0 * HOUR) },
        CatalogEntry { source: "[9] Liang et al. (4h)", lead_time: None, precision: 0.30, recall: 0.75, window: Some(4.0 * HOUR) },
        CatalogEntry { source: "[9] Liang et al. (6h/90)", lead_time: None, precision: 0.40, recall: 0.90, window: Some(6.0 * HOUR) },
        CatalogEntry { source: "[9] Liang et al. (6h/30)", lead_time: None, precision: 0.50, recall: 0.30, window: Some(6.0 * HOUR) },
        CatalogEntry { source: "[9] Liang et al. (12h)", lead_time: None, precision: 0.60, recall: 0.85, window: Some(12.0 * HOUR) },
    ]
}

/// Platform sizes swept by every §5 figure: N = 2^14 .. 2^19.
pub fn paper_proc_counts() -> Vec<u64> {
    (14..=19).map(|e| 1u64 << e).collect()
}

/// Scenario matrix of §5.1: both predictors × both windows.
pub fn paper_scenarios(n_procs: u64) -> Vec<(String, Scenario)> {
    let mut out = Vec::new();
    for (pname, pred) in [("yu", 0), ("zheng", 1)] {
        for window in [300.0, 3000.0] {
            let predictor = if pred == 0 { predictor_yu(window) } else { predictor_zheng(window) };
            let scenario = Scenario::paper(n_procs, predictor);
            out.push((format!("{pname}-I{window}"), scenario));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_paper_rows() {
        let cat = predictor_catalog();
        assert_eq!(cat.len(), 11);
        assert_eq!(cat[0].precision, 0.40);
        assert_eq!(cat[0].recall, 0.70);
        assert_eq!(cat[10].window, Some(12.0 * 3600.0));
    }

    #[test]
    fn catalog_predictors_validate() {
        for e in predictor_catalog() {
            e.predictor(300.0).validate().unwrap();
            e.predictor(0.0).validate().unwrap();
        }
    }

    #[test]
    fn proc_counts() {
        let n = paper_proc_counts();
        assert_eq!(n.first(), Some(&16384));
        assert_eq!(n.last(), Some(&524288));
        assert_eq!(n.len(), 6);
    }

    #[test]
    fn scenarios_validate() {
        for (_, s) in paper_scenarios(1 << 16) {
            s.validate().unwrap();
        }
    }
}
