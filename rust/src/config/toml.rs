//! Minimal TOML-subset parser (substrate: no `toml` crate offline).
//!
//! Supported: `[section]` / `[a.b]` headers, `key = value` with string,
//! float/int, and bool values, `#` comments, blank lines. That covers
//! every config file the framework ships; anything else is an error
//! rather than silently misparsed.

use std::collections::BTreeMap;

/// Flat view: `"section.key" -> raw value`.
#[derive(Debug, Clone, Default)]
pub struct Table {
    values: BTreeMap<String, Value>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Table {
    pub fn parse(input: &str) -> anyhow::Result<Table> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in input.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                anyhow::ensure!(!name.is_empty(), "line {}: empty section name", lineno + 1);
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            values.insert(full, parse_value(val.trim(), lineno + 1)?);
        }
        Ok(Table { values })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Table> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn num(&self, key: &str) -> Option<f64> {
        match self.get(key)? {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        match self.get(key)? {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.get(key)? {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str, lineno: usize) -> anyhow::Result<Value> {
    if let Some(rest) = raw.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    raw.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow::anyhow!("line {lineno}: cannot parse value '{raw}'"))
}

/// Build a [`crate::config::Scenario`] from a config table, starting
/// from the paper preset and overriding any provided key.
pub fn scenario_from_table(t: &Table) -> anyhow::Result<crate::config::Scenario> {
    use crate::config::{Predictor, Scenario};
    let n = t.num("platform.n_procs").unwrap_or((1 << 16) as f64) as u64;
    let window = t.num("predictor.window").unwrap_or(0.0);
    let recall = t.num("predictor.recall").unwrap_or(0.0);
    let precision = t.num("predictor.precision").unwrap_or(1.0);
    let predictor = if window > 0.0 {
        Predictor::windowed(recall, precision, window)
    } else {
        Predictor::exact(recall, precision)
    };
    let mut s = Scenario::paper(n, predictor);
    if let Some(x) = t.num("platform.mu_ind_years") {
        s.platform.mu_ind = x * crate::util::units::YEAR;
    }
    if let Some(x) = t.num("platform.c") {
        s.platform.c = x;
    }
    if let Some(x) = t.num("platform.d") {
        s.platform.d = x;
    }
    if let Some(x) = t.num("platform.r") {
        s.platform.r = x;
    }
    if let Some(x) = t.num("job.work") {
        s.work = x;
    }
    if let Some(x) = t.num("model.alpha") {
        s.alpha = x;
    }
    if let Some(x) = t.str("faults.dist") {
        s.fault_dist = x.parse().map_err(|e| anyhow::anyhow!("faults.dist: {e}"))?;
    }
    if let Some(x) = t.str("faults.false_pred_dist") {
        s.false_pred_dist =
            Some(x.parse().map_err(|e| anyhow::anyhow!("faults.false_pred_dist: {e}"))?);
    }
    if let Some(x) = t.num("job.migration") {
        s.migration = x;
    }
    if let Some(x) = t.num("seed") {
        s.seed = x as u64;
    }
    s.validate()?;
    Ok(s)
}

/// Read an optional `[policy]` table into a
/// [`crate::strategies::PolicySpec`]. The `kind` key takes any policy
/// spec string (`"Young"`, `"adaptive:0.8"`, `"risk"`, …); the
/// structured `gain` / `kappa` keys override the matching parameter so
/// configs can keep numbers out of strings:
///
/// ```toml
/// [policy]
/// kind = "risk"
/// kappa = 2.0
/// ```
pub fn policy_from_table(t: &Table) -> anyhow::Result<Option<crate::strategies::PolicySpec>> {
    use crate::strategies::PolicySpec;
    let Some(kind) = t.str("policy.kind") else {
        anyhow::ensure!(
            t.num("policy.kappa").is_none() && t.num("policy.gain").is_none(),
            "[policy] parameters need a policy.kind"
        );
        return Ok(None);
    };
    let mut spec: PolicySpec = kind.parse().map_err(|e| anyhow::anyhow!("policy.kind: {e}"))?;
    if let Some(k) = t.num("policy.kappa") {
        match &mut spec {
            PolicySpec::RiskThreshold { kappa } => *kappa = k,
            _ => anyhow::bail!("policy.kappa only applies to the 'risk' policy"),
        }
    }
    if let Some(g) = t.num("policy.gain") {
        match &mut spec {
            PolicySpec::AdaptivePeriod { gain } => *gain = g,
            _ => anyhow::bail!("policy.gain only applies to the 'adaptive' policy"),
        }
    }
    spec.validate()?;
    Ok(Some(spec))
}

/// Read the multi-node keys of the `[platform]` table into a
/// [`crate::sim::PlatformSpec`]. The section is shared with the
/// hardware keys (`n_procs`, `c`, `d`, `r`) consumed by
/// [`scenario_from_table`]; the platform-subsystem keys are additive
/// and gated on `nodes` — naming any of them without `nodes` is an
/// error rather than a silently single-node run:
///
/// ```toml
/// [platform]
/// nodes = 8
/// commit = 0.05
/// restart = "partial"
/// group = 4
/// spatial = 0.25
/// cascade = 0.1
/// delta = 300
/// ```
pub fn platform_from_table(t: &Table) -> anyhow::Result<Option<crate::sim::PlatformSpec>> {
    use crate::sim::{PlatformSpec, RestartScope};
    let Some(nodes) = t.num("platform.nodes") else {
        let orphans = ["commit", "restart", "group", "spatial", "cascade", "delta"];
        for key in orphans {
            anyhow::ensure!(
                t.get(&format!("platform.{key}")).is_none(),
                "platform.{key} needs platform.nodes"
            );
        }
        return Ok(None);
    };
    let mut spec = PlatformSpec { nodes: nodes as u64, ..PlatformSpec::default() };
    if let Some(x) = t.num("platform.commit") {
        spec.commit = x;
    }
    if let Some(x) = t.str("platform.restart") {
        spec.restart = match x {
            "full" => RestartScope::Full,
            "partial" => RestartScope::Partial,
            other => anyhow::bail!("platform.restart must be \"full\" or \"partial\", got '{other}'"),
        };
    }
    if let Some(x) = t.num("platform.group") {
        spec.group = x as u64;
    }
    if let Some(x) = t.num("platform.spatial") {
        spec.spatial = x;
    }
    if let Some(x) = t.num("platform.cascade") {
        spec.cascade = x;
    }
    if let Some(x) = t.num("platform.delta") {
        spec.delta = x;
    }
    spec.validate()?;
    Ok(Some(spec))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
seed = 7

[platform]
n_procs = 65536     # 2^16
c = 600
d = 60
r = 600

[predictor]
recall = 0.85
precision = 0.82
window = 300

[faults]
dist = "weibull:0.7"

[job]
work = 1.0e6
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = Table::parse(SAMPLE).unwrap();
        assert_eq!(t.num("platform.n_procs"), Some(65536.0));
        assert_eq!(t.str("faults.dist"), Some("weibull:0.7"));
        assert_eq!(t.num("seed"), Some(7.0));
        assert_eq!(t.num("job.work"), Some(1.0e6));
    }

    #[test]
    fn builds_scenario() {
        let t = Table::parse(SAMPLE).unwrap();
        let s = scenario_from_table(&t).unwrap();
        assert_eq!(s.platform.n_procs, 65536);
        assert_eq!(s.predictor.window, 300.0);
        assert_eq!(s.predictor.ef, 150.0);
        assert_eq!(s.fault_dist, crate::dist::DistSpec::weibull(0.7));
        assert_eq!(s.seed, 7);
    }

    #[test]
    fn comment_inside_string_survives() {
        let t = Table::parse(r##"k = "a#b" # trailing"##).unwrap();
        assert_eq!(t.str("k"), Some("a#b"));
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(Table::parse("[unterminated").is_err());
        assert!(Table::parse("novalue").is_err());
        assert!(Table::parse("k = 'single'").is_err());
        let err = Table::parse("\n\nk = @").unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn bools() {
        let t = Table::parse("a = true\nb = false").unwrap();
        assert_eq!(t.bool("a"), Some(true));
        assert_eq!(t.bool("b"), Some(false));
    }

    #[test]
    fn policy_table_forms() {
        use crate::strategies::PolicySpec;
        // Absent section: no policy.
        let t = Table::parse(SAMPLE).unwrap();
        assert_eq!(policy_from_table(&t).unwrap(), None);
        // String form.
        let t = Table::parse("[policy]\nkind = \"risk:2\"").unwrap();
        assert_eq!(policy_from_table(&t).unwrap(), Some(PolicySpec::RiskThreshold { kappa: 2.0 }));
        // Structured parameter override.
        let t = Table::parse("[policy]\nkind = \"adaptive\"\ngain = 0.5").unwrap();
        assert_eq!(
            policy_from_table(&t).unwrap(),
            Some(PolicySpec::AdaptivePeriod { gain: 0.5 })
        );
        // Paper strategy by name.
        let t = Table::parse("[policy]\nkind = \"WithCkptI\"").unwrap();
        assert_eq!(
            policy_from_table(&t).unwrap(),
            Some(PolicySpec::Strategy(crate::model::StrategyKind::WithCkptI))
        );
        // Mismatched parameter, bad kind, and orphaned parameters error.
        let t = Table::parse("[policy]\nkind = \"risk\"\ngain = 2").unwrap();
        assert!(policy_from_table(&t).is_err());
        let t = Table::parse("[policy]\nkind = \"bogus\"").unwrap();
        assert!(policy_from_table(&t).is_err());
        let t = Table::parse("[policy]\nkappa = 2").unwrap();
        assert!(policy_from_table(&t).is_err());
    }

    #[test]
    fn platform_table_forms() {
        use crate::sim::{PlatformSpec, RestartScope};
        // Absent keys: no platform (the hardware keys don't count).
        let t = Table::parse(SAMPLE).unwrap();
        assert_eq!(platform_from_table(&t).unwrap(), None);
        // Nodes alone.
        let t = Table::parse("[platform]\nnodes = 4").unwrap();
        assert_eq!(
            platform_from_table(&t).unwrap(),
            Some(PlatformSpec { nodes: 4, ..PlatformSpec::default() })
        );
        // The full key set.
        let t = Table::parse(
            "[platform]\nnodes = 8\ncommit = 0.05\nrestart = \"partial\"\n\
             group = 4\nspatial = 0.25\ncascade = 0.1\ndelta = 120",
        )
        .unwrap();
        let spec = platform_from_table(&t).unwrap().unwrap();
        assert_eq!(spec.nodes, 8);
        assert_eq!(spec.commit, 0.05);
        assert_eq!(spec.restart, RestartScope::Partial);
        assert_eq!(spec.group, 4);
        assert_eq!(spec.spatial, 0.25);
        assert_eq!(spec.cascade, 0.1);
        assert_eq!(spec.delta, 120.0);
        // Orphaned parameters, bad restart, and invalid specs error.
        let t = Table::parse("[platform]\nspatial = 0.5").unwrap();
        assert!(platform_from_table(&t).is_err());
        let t = Table::parse("[platform]\nnodes = 4\nrestart = \"half\"").unwrap();
        assert!(platform_from_table(&t).is_err());
        let t = Table::parse("[platform]\nnodes = 0").unwrap();
        assert!(platform_from_table(&t).is_err());
    }
}
