"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import waste_grid_ref
from compile.kernels.waste_grid import COLS, NPARAM, NSTRAT, waste_grid

MIN = 60.0


def paper_config(mu_mn=1000.0, C=600.0, D=60.0, R=600.0, r=0.85, p=0.82,
                 I=300.0, Ef=None, alpha=0.27, M=300.0):
    """One raw-parameter row in the paper's §5 regime."""
    if Ef is None:
        Ef = I / 2.0
    return [mu_mn * MIN, C, D, R, r, p, I, Ef, alpha, M]


def expand(rows):
    raw = jnp.asarray(np.asarray(rows, dtype=np.float32))
    return raw, model.expand_params(raw)


def grid(g=512):
    return jnp.linspace(0.0, 1.0, g, dtype=jnp.float32)


class TestKernelVsRef:
    def test_paper_regime(self):
        _, kp = expand([paper_config(), paper_config(mu_mn=125.0, r=0.7, p=0.4),
                        paper_config(I=3000.0), paper_config(mu_mn=4000.0)])
        u = grid()
        np.testing.assert_allclose(waste_grid(kp, u), waste_grid_ref(kp, u),
                                   rtol=1e-6, atol=1e-6)

    def test_single_row(self):
        _, kp = expand([paper_config()])
        u = grid(128)
        np.testing.assert_allclose(waste_grid(kp, u), waste_grid_ref(kp, u),
                                   rtol=1e-6, atol=1e-6)

    def test_uneven_tiles_rejected(self):
        _, kp = expand([paper_config()] * 3)
        with pytest.raises(ValueError, match="not divisible"):
            waste_grid(kp, grid(128), bm=2)

    def test_bad_param_count_rejected(self):
        with pytest.raises(ValueError, match="columns"):
            waste_grid(jnp.zeros((2, NPARAM + 1), jnp.float32), grid(128))

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4, 8, 16]),
        g=st.sampled_from([128, 256, 512]),
        mu_mn=st.floats(10.0, 10000.0),
        r=st.floats(0.0, 1.0),
        p=st.floats(0.05, 1.0),
        i_win=st.floats(0.0, 6000.0),
        c=st.floats(30.0, 1800.0),
    )
    def test_hypothesis_sweep(self, b, g, mu_mn, r, p, i_win, c):
        rows = [paper_config(mu_mn=mu_mn * (1 + 0.1 * k), C=c, r=r, p=p, I=i_win)
                for k in range(b)]
        _, kp = expand(rows)
        u = grid(g)
        np.testing.assert_allclose(waste_grid(kp, u), waste_grid_ref(kp, u),
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(bm=st.sampled_from([1, 2, 4, 8]), gn=st.sampled_from([32, 64, 128]))
    def test_tiling_invariance(self, bm, gn):
        """Result must not depend on the BlockSpec tiling."""
        _, kp = expand([paper_config(mu_mn=100.0 * (k + 1)) for k in range(8)])
        u = grid(256)
        base = waste_grid(kp, u)
        np.testing.assert_allclose(waste_grid(kp, u, bm=bm, gn=gn), base,
                                   rtol=1e-6, atol=1e-6)

    def test_dtype_is_f32(self):
        _, kp = expand([paper_config()])
        assert waste_grid(kp, grid(128)).dtype == jnp.float32


class TestKernelMath:
    """Spot-checks of the surfaces against hand-computed closed forms."""

    def test_young_closed_form(self):
        mu, c, d, rr = 60000.0, 600.0, 60.0, 600.0
        _, kp = expand([paper_config(mu_mn=mu / MIN, C=c, D=d, R=rr)])
        u = grid(128)
        w = np.asarray(waste_grid(kp, u))[0, 0]
        tmax = 0.27 * mu
        t = c + np.asarray(u) * (tmax - c)
        expect = c / t + (t / 2 + d + rr) / mu
        np.testing.assert_allclose(w, expect, rtol=1e-5)

    def test_r_zero_collapses_to_young(self):
        """With no predictions, s1/s2/s5-with-M=C reduce to Young-like forms."""
        _, kp = expand([paper_config(r=0.0, I=0.0, M=600.0)])
        u = grid(128)
        w = np.asarray(waste_grid(kp, u))[0]
        np.testing.assert_allclose(w[1], w[0], rtol=1e-6)   # ExactPrediction
        np.testing.assert_allclose(w[2], w[0], rtol=1e-6)   # Instant
        np.testing.assert_allclose(w[5], w[0], rtol=1e-6)   # Migration, M=C
        np.testing.assert_allclose(w[3], w[0], rtol=1e-6)   # NoCkptI

    def test_exact_prediction_beats_young_at_optimum(self):
        """Good predictor => min waste of s1 below min waste of s0."""
        _, kp = expand([paper_config(mu_mn=125.0, r=0.85, p=0.82)])
        w = np.asarray(waste_grid(kp, grid(512)))[0]
        assert w[1].min() < w[0].min()

    def test_instant_dominated_by_exact(self):
        """Eq. (5) adds a nonnegative term to Eq. (1) q=1."""
        _, kp = expand([paper_config(I=3000.0)])
        w = np.asarray(waste_grid(kp, grid(512)))[0]
        assert (w[2] >= w[1] - 1e-7).all()

    def test_convexity_in_t(self):
        """Each waste surface is convex in T (positive second difference)."""
        _, kp = expand([paper_config()])
        w = np.asarray(waste_grid(kp, grid(512)))[0]
        d2 = w[:, 2:] - 2 * w[:, 1:-1] + w[:, :-2]
        assert (d2 >= -1e-6).all()

    def test_surfaces_positive(self):
        _, kp = expand([paper_config(mu_mn=m) for m in (125.0, 500.0, 1000.0, 4000.0)])
        w = np.asarray(waste_grid(kp, grid(512)))
        assert (w > 0).all()
