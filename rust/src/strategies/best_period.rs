//! BestPeriod: the §5 brute-force numerical search for the optimal
//! regular period of any strategy, by direct simulation.

use crate::config::Scenario;
use crate::sim::run_replications;
use crate::strategies::StrategySpec;

/// Result of a brute-force period search.
#[derive(Debug, Clone)]
pub struct BestPeriodResult {
    /// The winning period.
    pub t_r: f64,
    /// Mean waste at the winning period.
    pub waste: f64,
    /// The full sweep: (period, mean waste) per candidate.
    pub sweep: Vec<(f64, f64)>,
}

/// Build the candidate grid: geometric between `lo` and `hi`.
pub fn period_grid(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(hi > lo && lo > 0.0 && n >= 2);
    let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
    (0..n).map(|i| lo * ratio.powi(i as i32)).collect()
}

/// Brute-force the best T_R for `base` on `scenario`: simulate `reps`
/// replications at each of `n_candidates` periods spanning
/// [C + 1, span_factor * sqrt(2 mu C)] and return the argmin.
///
/// This is exactly the paper's BESTPERIOD counterpart; the experiment
/// harness runs it through the coordinator's worker pool because it is
/// by far the most expensive operation in the study.
pub fn best_period(
    scenario: &Scenario,
    base: &StrategySpec,
    reps: u64,
    n_candidates: usize,
) -> anyhow::Result<BestPeriodResult> {
    let c = scenario.platform.c;
    let mu = scenario.mu();
    let formula = (2.0 * mu * c).sqrt();
    // Search a generous bracket around the closed-form optimum. Periods
    // below ~2C are never competitive (waste >= C/T > 1/2) and cost
    // enormous simulated time (one checkpoint per sliver of work), so
    // the bracket floor protects the search from pathological runs.
    let lo = (formula / 6.0).max(2.0 * c);
    let hi = (4.0 * formula).max(lo * 4.0);
    let grid = period_grid(lo, hi, n_candidates);
    let mut sweep = Vec::with_capacity(grid.len());
    let mut best = (f64::INFINITY, grid[0]);
    for &t_r in &grid {
        let spec = StrategySpec { t_r, ..base.clone() };
        let report = run_replications(scenario, &spec, reps)?;
        let w = report.mean_waste();
        sweep.push((t_r, w));
        if w < best.0 {
            best = (w, t_r);
        }
    }
    Ok(BestPeriodResult { t_r: best.1, waste: best.0, sweep })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;
    use crate::model::{Capping, StrategyKind};
    use crate::strategies::spec_for;

    #[test]
    fn grid_is_geometric_and_bounded() {
        let g = period_grid(100.0, 10000.0, 9);
        assert_eq!(g.len(), 9);
        assert!((g[0] - 100.0).abs() < 1e-9);
        assert!((g[8] - 10000.0).abs() < 1e-6);
        let r0 = g[1] / g[0];
        let r1 = g[5] / g[4];
        assert!((r0 - r1).abs() < 1e-9);
    }

    #[test]
    fn best_period_close_to_formula() {
        // Small Exponential study: the numeric argmin must land near
        // sqrt(2 mu C) — the paper's "BestPeriod ≈ model" observation.
        let mut s = crate::config::Scenario::paper(1 << 16, Predictor::none());
        s.fault_dist = "exp".into();
        s.work = 2.0e5;
        let base = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let res = best_period(&s, &base, 12, 12).unwrap();
        let formula = (2.0 * s.mu() * s.platform.c).sqrt();
        // Coarse grid + stochastic: within a factor 2 is the guarantee;
        // the recorded experiments use finer settings.
        assert!(
            res.t_r > formula / 2.0 && res.t_r < formula * 2.0,
            "best {} vs formula {formula}",
            res.t_r
        );
        assert_eq!(res.sweep.len(), 12);
        assert!(res.waste <= res.sweep.iter().map(|p| p.1).fold(f64::INFINITY, f64::min) + 1e-12);
    }
}
