//! Replicated simulation: run a strategy against `reps` independent
//! traces and aggregate — streaming, so a million replications cost
//! O(1) memory unless the caller opts into raw-outcome retention.

use super::{Outcome, SimSession};
use crate::config::Scenario;
use crate::coordinator::{run_parallel_fold, try_run_parallel_fold};
use crate::strategies::StrategySpec;
use crate::util::cancel::CancelToken;
use crate::util::stats::Summary;

/// Streaming accumulator over outcomes: Welford summaries for the
/// continuous statistics plus merged event counters. Merging two
/// accumulators (parallel reduction) gives exactly the counters — and,
/// up to floating-point reassociation, the summaries — of the combined
/// stream.
#[derive(Debug, Clone, Default)]
pub struct ReplicationAgg {
    pub waste: Summary,
    pub makespan: Summary,
    pub n_reps: u64,
    pub n_completed: u64,
    pub n_faults: u64,
    pub n_faults_unpredicted: u64,
    pub n_preds: u64,
    pub n_true_preds: u64,
    pub n_trusted: u64,
    pub n_ckpts: u64,
    pub n_proactive_ckpts: u64,
    pub n_migrations: u64,
    pub n_faults_avoided: u64,
    pub n_segments: u64,
    pub lost_work: f64,
    /// Total engine wall-clock across replications (CPU-seconds).
    pub sim_seconds: f64,
}

impl ReplicationAgg {
    pub fn push(&mut self, o: &Outcome) {
        self.waste.push(o.waste());
        self.makespan.push(o.makespan);
        self.n_reps += 1;
        self.n_completed += o.completed as u64;
        self.n_faults += o.n_faults;
        self.n_faults_unpredicted += o.n_faults_unpredicted;
        self.n_preds += o.n_preds;
        self.n_true_preds += o.n_true_preds;
        self.n_trusted += o.n_trusted;
        self.n_ckpts += o.n_ckpts;
        self.n_proactive_ckpts += o.n_proactive_ckpts;
        self.n_migrations += o.n_migrations;
        self.n_faults_avoided += o.n_faults_avoided;
        self.n_segments += o.n_segments;
        self.lost_work += o.lost_work;
        self.sim_seconds += o.sim_seconds;
    }

    /// Merge a partial accumulator (worker-local) into this one.
    pub fn merge(mut self, other: ReplicationAgg) -> ReplicationAgg {
        self.waste = self.waste.merge(&other.waste);
        self.makespan = self.makespan.merge(&other.makespan);
        self.n_reps += other.n_reps;
        self.n_completed += other.n_completed;
        self.n_faults += other.n_faults;
        self.n_faults_unpredicted += other.n_faults_unpredicted;
        self.n_preds += other.n_preds;
        self.n_true_preds += other.n_true_preds;
        self.n_trusted += other.n_trusted;
        self.n_ckpts += other.n_ckpts;
        self.n_proactive_ckpts += other.n_proactive_ckpts;
        self.n_migrations += other.n_migrations;
        self.n_faults_avoided += other.n_faults_avoided;
        self.n_segments += other.n_segments;
        self.lost_work += other.lost_work;
        self.sim_seconds += other.sim_seconds;
        self
    }

    /// Fraction of replications that finished under the guard.
    pub fn completion_rate(&self) -> f64 {
        self.n_completed as f64 / self.n_reps.max(1) as f64
    }
}

/// What a replication batch keeps per replication.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Retain {
    /// Streaming statistics only (the default — O(1) memory).
    Stats,
    /// Also keep every raw [`Outcome`] (per-replication analysis,
    /// debugging; O(reps) memory).
    Outcomes,
}

/// Aggregated result of a replication batch.
#[derive(Debug, Clone)]
pub struct ReplicationReport {
    pub strategy: String,
    pub agg: ReplicationAgg,
    /// Raw outcomes — empty unless the batch ran with
    /// [`Retain::Outcomes`].
    pub outcomes: Vec<Outcome>,
}

impl ReplicationReport {
    pub fn mean_waste(&self) -> f64 {
        self.agg.waste.mean()
    }

    pub fn mean_makespan(&self) -> f64 {
        self.agg.makespan.mean()
    }

    /// Fraction of replications that finished under the guard.
    pub fn completion_rate(&self) -> f64 {
        self.agg.completion_rate()
    }
}

/// One replication: trace `rep` of `scenario.seed`, executed under
/// `spec`. One-shot wrapper over [`SimSession`]; batch callers should
/// hold a session instead and amortize the setup.
pub fn simulate_once(
    scenario: &Scenario,
    spec: &StrategySpec,
    rep: u64,
) -> anyhow::Result<Outcome> {
    Ok(SimSession::new(scenario, spec)?.run(rep))
}

/// Run `reps` replications sequentially on one session, streaming into
/// the aggregate. (The coordinator parallelizes across replications and
/// scenarios; this is the single-thread core.)
pub fn run_replications(
    scenario: &Scenario,
    spec: &StrategySpec,
    reps: u64,
) -> anyhow::Result<ReplicationReport> {
    run_replications_with(scenario, spec, reps, Retain::Stats)
}

/// [`run_replications`] with explicit retention policy.
pub fn run_replications_with(
    scenario: &Scenario,
    spec: &StrategySpec,
    reps: u64,
    retain: Retain,
) -> anyhow::Result<ReplicationReport> {
    let mut session = SimSession::new(scenario, spec)?;
    let mut agg = ReplicationAgg::default();
    // The retained-outcome count is known exactly up front: one
    // reservation, no doubling-growth churn across a large batch.
    let mut outcomes: Vec<Outcome> = Vec::new();
    if retain == Retain::Outcomes {
        outcomes.reserve_exact(reps as usize);
    }
    for rep in 0..reps {
        let o = session.run(rep);
        agg.push(&o);
        if retain == Retain::Outcomes {
            outcomes.push(o);
        }
    }
    Ok(ReplicationReport { strategy: spec.name.clone(), agg, outcomes })
}

/// Parallel replication batch: replications are strided across
/// `workers` pool threads, each worker owning one reused [`SimSession`]
/// and one worker-local [`ReplicationAgg`]; partials merge at the end
/// (no per-replication result slots). Deterministic for a fixed worker
/// count — counters are exactly order-independent, summaries up to
/// floating-point reassociation of the fixed stride order.
pub fn run_replications_parallel(
    scenario: &Scenario,
    spec: &StrategySpec,
    reps: u64,
    workers: usize,
) -> anyhow::Result<ReplicationReport> {
    run_replications_parallel_with(&spec.name, reps, workers, || SimSession::new(scenario, spec))
}

/// [`run_replications_parallel`] with an explicit session factory —
/// the policy-layer entry point (build sessions with
/// [`SimSession::from_policy`]) and anything else that needs a
/// non-default session. The factory runs once per worker.
pub fn run_replications_parallel_with<M>(
    name: &str,
    reps: u64,
    workers: usize,
    make: M,
) -> anyhow::Result<ReplicationReport>
where
    M: Fn() -> anyhow::Result<SimSession> + Sync,
{
    let agg = run_replication_range_with(0, reps, workers, make)?;
    Ok(ReplicationReport { strategy: name.to_string(), agg, outcomes: Vec::new() })
}

/// The range core under [`run_replications_parallel_with`]: replicate
/// `[rep_lo, rep_hi)` across the pool and return the merged aggregate.
/// The explicit range lets incremental callers (the `verify`
/// conformance comparator's replication escalation) extend an existing
/// aggregate without re-simulating the replications they already have:
/// `agg([lo, mid)) merge agg([mid, hi))` equals one pass over
/// `[lo, hi)` in counters, and differs from it only by floating-point
/// reassociation in the summaries. Deterministic for a fixed worker
/// count, like everything on this path.
///
/// The factory doubles as the *bank provider*: hand it a closure that
/// builds [`SimSession::replay`] sessions over a shared
/// [`crate::trace::TraceBank`] and the whole range replays
/// pre-materialized traces (the comparator extends one bank across its
/// doubling rounds this way) — outcomes are bit-identical to live
/// factories, so callers may switch freely.
pub fn run_replication_range_with<M>(
    rep_lo: u64,
    rep_hi: u64,
    workers: usize,
    make: M,
) -> anyhow::Result<ReplicationAgg>
where
    M: Fn() -> anyhow::Result<SimSession> + Sync,
{
    run_replication_range_with_cancel(rep_lo, rep_hi, workers, &CancelToken::unbounded(), make)
}

/// [`run_replication_range_with`] under a cooperative [`CancelToken`]:
/// each worker re-checks the token before picking up its next
/// replication and simply stops folding once it trips, so a tripped
/// deadline yields the *partial* aggregate of the replications that
/// completed (check `agg.n_reps` against the requested range). Worker
/// panics surface as a structured
/// [`crate::coordinator::PoolPanic`] error (downcastable through the
/// anyhow chain) instead of unwinding the caller.
pub fn run_replication_range_with_cancel<M>(
    rep_lo: u64,
    rep_hi: u64,
    workers: usize,
    cancel: &CancelToken,
    make: M,
) -> anyhow::Result<ReplicationAgg>
where
    M: Fn() -> anyhow::Result<SimSession> + Sync,
{
    // Surface configuration errors here, once, instead of panicking in
    // a worker.
    drop(make()?);
    let rep_ids: Vec<u64> = (rep_lo..rep_hi).collect();
    let (_, agg) = try_run_parallel_fold(
        &rep_ids,
        workers,
        || (None::<SimSession>, ReplicationAgg::default()),
        |(mut session, mut agg), &rep| {
            if cancel.cancelled() {
                return (session, agg);
            }
            let s = session.get_or_insert_with(|| make().expect("session validated above"));
            agg.push(&s.run(rep));
            (session, agg)
        },
        |(_, a), (_, b)| (None, a.merge(b)),
    )
    .map_err(anyhow::Error::new)?;
    Ok(agg)
}

/// Build point-major `(point, rep_lo, rep_hi)` blocks for
/// [`fold_waste_product`]. Blocking is what keeps the per-worker
/// session cache effective regardless of the stride: a flat
/// `(point, rep)` product with `reps < workers` would land every
/// consecutive task of a worker on a *different* point, rebuilding the
/// session per task. Block size targets ~4 tasks per worker across
/// the whole product, clamped to the rep range, so each session build
/// amortizes over a run of replications while load balancing keeps
/// several blocks per worker.
pub fn rep_blocks(
    points: &[usize],
    rep_lo: u64,
    rep_hi: u64,
    workers: usize,
) -> Vec<(usize, u64, u64)> {
    let reps = rep_hi.saturating_sub(rep_lo);
    if reps == 0 || points.is_empty() {
        return Vec::new();
    }
    let total = reps * points.len() as u64;
    let desired_tasks = (workers.max(1) as u64) * 4;
    let block = (total.div_ceil(desired_tasks)).clamp(1, reps);
    let mut tasks = Vec::new();
    for &pi in points {
        let mut lo = rep_lo;
        while lo < rep_hi {
            let hi = (lo + block).min(rep_hi);
            tasks.push((pi, lo, hi));
            lo = hi;
        }
    }
    tasks
}

/// Shared engine for (point × replication) products — the figure grids
/// and the BestPeriod candidate sweep: fold `(point, rep_lo, rep_hi)`
/// blocks (see [`rep_blocks`]) through the pool, one reused session
/// per worker per point (`make(i)` builds point `i`'s session; at
/// worst one build per block, amortized over the block's
/// replications). Returns per-point waste summaries, `n_points` long,
/// merged in deterministic worker order.
pub fn fold_waste_product<F>(
    tasks: &[(usize, u64, u64)],
    n_points: usize,
    workers: usize,
    make: F,
) -> Vec<Summary>
where
    F: Fn(usize) -> SimSession + Sync,
{
    run_parallel_fold(
        tasks,
        workers,
        || (vec![Summary::new(); n_points], None::<(usize, SimSession)>),
        |(mut sums, mut cache), &(pi, rep_lo, rep_hi)| {
            let stale = cache.as_ref().map(|(cached, _)| *cached != pi).unwrap_or(true);
            if stale {
                cache = Some((pi, make(pi)));
            }
            let (_, session) = cache.as_mut().expect("cache filled above");
            for rep in rep_lo..rep_hi {
                sums[pi].push(session.run(rep).waste());
            }
            (sums, cache)
        },
        |(a, _), (b, _)| (a.iter().zip(&b).map(|(x, y)| x.merge(y)).collect(), None),
    )
    .0
}

/// [`fold_waste_product`] that additionally *retains* every
/// per-replication waste in a point-major matrix
/// (`matrix[pi * (rep_hi - rep_lo) + (rep - rep_lo)]`). The summaries
/// are pushed and merged in exactly the same order as the plain fold.
/// This is how the CRN best-period prune gets per-rep values for its
/// paired-difference statistics without simulating anything twice:
/// each `(point, rep)` slot is written exactly once, so the matrix is
/// deterministic regardless of worker scheduling. Costs
/// `n_points × reps × 8` bytes — callers bound that product.
pub fn fold_waste_product_retaining<F>(
    tasks: &[(usize, u64, u64)],
    n_points: usize,
    rep_lo: u64,
    rep_hi: u64,
    workers: usize,
    make: F,
) -> (Vec<Summary>, Vec<f64>)
where
    F: Fn(usize) -> SimSession + Sync,
{
    let span = (rep_hi - rep_lo) as usize;
    let (sums, cells, _) = run_parallel_fold(
        tasks,
        workers,
        || (vec![Summary::new(); n_points], Vec::<(usize, f64)>::new(), None::<(usize, SimSession)>),
        |(mut sums, mut cells, mut cache), &(pi, lo, hi)| {
            let stale = cache.as_ref().map(|(cached, _)| *cached != pi).unwrap_or(true);
            if stale {
                cache = Some((pi, make(pi)));
            }
            let (_, session) = cache.as_mut().expect("cache filled above");
            for rep in lo..hi {
                let w = session.run(rep).waste();
                sums[pi].push(w);
                cells.push((pi * span + (rep - rep_lo) as usize, w));
            }
            (sums, cells, cache)
        },
        |(a, mut ca, _), (b, cb, _)| {
            ca.extend(cb);
            (a.iter().zip(&b).map(|(x, y)| x.merge(y)).collect(), ca, None)
        },
    );
    let mut matrix = vec![f64::NAN; n_points * span];
    for (slot, w) in cells {
        matrix[slot] = w;
    }
    (sums, matrix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::model::{waste_young, Params};
    use crate::strategies::spec_for;
    use crate::model::{Capping, StrategyKind};
    use crate::util::approx_eq;

    fn small_scenario() -> Scenario {
        // Modest platform + small job so the test stays fast.
        let mut s = Scenario::paper(1 << 16, Predictor::none());
        s.fault_dist = crate::dist::DistSpec::Exp;
        s.work = 3.0e5; // ~3.5 days of work, mu = 60000 s
        s
    }

    #[test]
    fn young_simulation_matches_analysis_exponential() {
        // The headline validation: simulated waste under Exponential
        // faults must match Eq. (1) at q = 0 within a few percent.
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let report = run_replications(&s, &spec, 60).unwrap();
        assert!(report.completion_rate() == 1.0);
        let p = Params::from_scenario(&s);
        let analytic = waste_young(&p, spec.t_r);
        let sim = report.mean_waste();
        assert!(
            (sim - analytic).abs() / analytic < 0.08,
            "sim {sim} vs analytic {analytic}"
        );
    }

    #[test]
    fn exact_prediction_beats_young_in_simulation() {
        let mut s = small_scenario();
        s.predictor = Predictor::exact(0.85, 0.82);
        let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let exact = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        let wy = run_replications(&s, &young, 40).unwrap().mean_waste();
        let we = run_replications(&s, &exact, 40).unwrap().mean_waste();
        assert!(we < wy, "exact {we} vs young {wy}");
    }

    #[test]
    fn replications_are_reproducible() {
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let a = run_replications_with(&s, &spec, 5, Retain::Outcomes).unwrap();
        let b = run_replications_with(&s, &spec, 5, Retain::Outcomes).unwrap();
        assert_eq!(a.outcomes.len(), 5);
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.makespan, y.makespan);
            assert_eq!(x.n_faults, y.n_faults);
        }
    }

    #[test]
    fn replications_are_reproducible_under_parallel_fold() {
        // Same worker count => identical stride partition => the merged
        // aggregate is deterministic, counters *and* means.
        let mut s = small_scenario();
        s.predictor = Predictor::exact(0.7, 0.4);
        let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        let a = run_replications_parallel(&s, &spec, 12, 4).unwrap();
        let b = run_replications_parallel(&s, &spec, 12, 4).unwrap();
        assert_eq!(a.agg.n_faults, b.agg.n_faults);
        assert_eq!(a.agg.n_preds, b.agg.n_preds);
        assert_eq!(a.agg.n_segments, b.agg.n_segments);
        assert_eq!(a.agg.makespan.mean(), b.agg.makespan.mean());
        assert_eq!(a.agg.waste.mean(), b.agg.waste.mean());
    }

    #[test]
    fn parallel_fold_matches_sequential_aggregate() {
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let seq = run_replications(&s, &spec, 10).unwrap();
        let par = run_replications_parallel(&s, &spec, 10, 3).unwrap();
        // Counters are order-independent: exact equality.
        assert_eq!(seq.agg.n_reps, par.agg.n_reps);
        assert_eq!(seq.agg.n_faults, par.agg.n_faults);
        assert_eq!(seq.agg.n_ckpts, par.agg.n_ckpts);
        assert_eq!(seq.agg.n_segments, par.agg.n_segments);
        assert_eq!(seq.agg.n_completed, par.agg.n_completed);
        // Summaries differ only by floating-point reassociation.
        assert!(approx_eq(seq.mean_waste(), par.mean_waste(), 1e-12));
        assert!(approx_eq(seq.mean_makespan(), par.mean_makespan(), 1e-12));
        assert!(approx_eq(seq.agg.waste.variance(), par.agg.waste.variance(), 1e-9));
    }

    #[test]
    fn replication_ranges_merge_to_the_full_pass() {
        // agg([0,4)) merge agg([4,10)) == agg([0,10)): exact counters,
        // reassociation-level summaries — the escalation contract the
        // verify comparator builds on.
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let make = || SimSession::new(&s, &spec);
        let full = run_replication_range_with(0, 10, 3, make).unwrap();
        let a = run_replication_range_with(0, 4, 3, make).unwrap();
        let b = run_replication_range_with(4, 10, 3, make).unwrap();
        let merged = a.merge(b);
        assert_eq!(full.n_reps, merged.n_reps);
        assert_eq!(full.n_faults, merged.n_faults);
        assert_eq!(full.n_segments, merged.n_segments);
        assert_eq!(full.n_ckpts, merged.n_ckpts);
        assert!(approx_eq(full.waste.mean(), merged.waste.mean(), 1e-12));
        assert!(approx_eq(full.makespan.mean(), merged.makespan.mean(), 1e-12));
    }

    #[test]
    fn pre_cancelled_token_runs_no_replications() {
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let flag = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(true));
        let cancel = CancelToken::with_flag(flag);
        let agg =
            run_replication_range_with_cancel(0, 50, 3, &cancel, || SimSession::new(&s, &spec))
                .unwrap();
        assert_eq!(agg.n_reps, 0, "a tripped token must stop the fold immediately");
    }

    #[test]
    fn unbounded_cancel_matches_plain_range() {
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let make = || SimSession::new(&s, &spec);
        let plain = run_replication_range_with(0, 8, 3, make).unwrap();
        let cancelled =
            run_replication_range_with_cancel(0, 8, 3, &CancelToken::unbounded(), make).unwrap();
        assert_eq!(plain.n_reps, cancelled.n_reps);
        assert_eq!(plain.n_faults, cancelled.n_faults);
        assert_eq!(plain.waste.mean(), cancelled.waste.mean());
    }

    #[test]
    fn worker_panic_is_a_structured_error() {
        // A panicking session (simulated via a factory that validates
        // once then panics inside the fold) must surface as a PoolPanic
        // error value, not an unwind.
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let calls = std::sync::atomic::AtomicU64::new(0);
        let err = run_replication_range_with(0, 8, 2, || {
            // First call is the up-front validation; later (per-worker)
            // calls panic like a poisoned session build would.
            if calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst) == 0 {
                SimSession::new(&s, &spec)
            } else {
                panic!("chaotic session build");
            }
        })
        .unwrap_err();
        let pp = err
            .downcast_ref::<crate::coordinator::PoolPanic>()
            .expect("error must carry PoolPanic");
        assert!(pp.message.contains("chaotic session build"), "{pp}");
    }

    #[test]
    fn stats_mode_retains_nothing() {
        let s = small_scenario();
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let report = run_replications(&s, &spec, 5).unwrap();
        assert!(report.outcomes.is_empty());
        assert_eq!(report.agg.n_reps, 5);
        assert_eq!(report.agg.waste.count(), 5);
    }

    #[test]
    fn same_trace_across_strategies() {
        // Strategies with the same required lead see identical fault
        // streams — the §5 comparison is paired.
        let mut s = small_scenario();
        s.predictor = Predictor::windowed(0.7, 0.4, 300.0);
        let a = spec_for(StrategyKind::Instant, &s, Capping::Uncapped);
        let b = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
        let oa = simulate_once(&s, &a, 3).unwrap();
        let ob = simulate_once(&s, &b, 3).unwrap();
        assert_eq!(oa.n_preds, ob.n_preds);
        // Fault counts can differ (different makespans expose different
        // trace prefixes) but the prediction stream prefix is shared.
    }

    #[test]
    fn q_zero_equals_young() {
        let mut s = small_scenario();
        s.predictor = Predictor::exact(0.85, 0.82);
        let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let mut distrust = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        distrust.q = 0.0;
        distrust.t_r = young.t_r;
        let wy = simulate_once(&s, &young, 1).unwrap();
        let wd = simulate_once(&s, &distrust, 1).unwrap();
        assert_eq!(wy.makespan, wd.makespan);
    }

    #[test]
    fn outcome_counters_consistent() {
        let mut s = small_scenario();
        s.predictor = Predictor::exact(0.7, 0.4);
        let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        let o = simulate_once(&s, &spec, 0).unwrap();
        assert!(o.n_true_preds <= o.n_preds);
        assert!(o.n_faults_unpredicted <= o.n_faults);
        assert!(o.completed);
        assert!(o.n_segments > 0);
    }

    #[test]
    fn rep_blocks_cover_the_product_exactly_once() {
        // Every (point, rep) pair appears in exactly one block, blocks
        // are point-major, and small products still amortize: with
        // reps < workers the block size stays >= 1 and never explodes
        // the task count past points × reps.
        for (points, lo, hi, workers) in
            [(3usize, 0u64, 8u64, 16usize), (24, 0, 40, 8), (12, 3, 12, 4), (1, 0, 1, 8)]
        {
            let idx: Vec<usize> = (0..points).collect();
            let tasks = rep_blocks(&idx, lo, hi, workers);
            let mut seen = std::collections::HashSet::new();
            for &(pi, a, b) in &tasks {
                assert!(a < b && a >= lo && b <= hi, "bad block {pi} {a}..{b}");
                for rep in a..b {
                    assert!(seen.insert((pi, rep)), "duplicate ({pi}, {rep})");
                }
            }
            assert_eq!(seen.len(), points * (hi - lo) as usize);
            assert!(tasks.len() <= points * (hi - lo) as usize);
        }
        assert!(rep_blocks(&[0, 1], 5, 5, 4).is_empty());
        assert!(rep_blocks(&[], 0, 10, 4).is_empty());
    }

    #[test]
    fn aggregate_counters_sum_over_reps() {
        let mut s = small_scenario();
        s.predictor = Predictor::exact(0.7, 0.4);
        let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        let report = run_replications_with(&s, &spec, 6, Retain::Outcomes).unwrap();
        let faults: u64 = report.outcomes.iter().map(|o| o.n_faults).sum();
        let segs: u64 = report.outcomes.iter().map(|o| o.n_segments).sum();
        assert_eq!(report.agg.n_faults, faults);
        assert_eq!(report.agg.n_segments, segs);
        assert_eq!(report.agg.n_reps, 6);
    }
}
