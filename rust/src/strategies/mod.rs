//! Checkpointing strategies: planners that turn a [`Scenario`] into an
//! executable [`StrategySpec`] for the simulation engine.
//!
//! The *planner* half of each strategy is closed-form (or HLO-compiled,
//! via [`crate::runtime`]); the *executor* half is the shared
//! discrete-event core in [`crate::sim`], parameterized by a
//! [`crate::sim::Policy`]. [`StrategySpec`] (fixed period + trust +
//! [`ProactiveMode`]) describes the paper's strategy space;
//! [`PolicySpec`] is the superset that also names the non-paper
//! policies (`adaptive`, `risk`) and resolves to a runtime policy via
//! [`resolve_policy`].

mod best_period;
mod policy;

pub use best_period::{
    best_period, best_period_on_platform, best_period_with, best_policy_with, period_grid,
    BestPeriodOptions, BestPeriodResult,
};
pub use policy::{resolve_policy, PolicySpec, ResolvedPolicy};

use crate::config::Scenario;
use crate::model::{self, Capping, Params, StrategyKind};

/// What the executor does when a trusted prediction's window opens.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ProactiveMode {
    /// Predictions ignored entirely (Young / Daly; q = 0).
    Ignore,
    /// Checkpoint completing right at t0, then back to regular mode
    /// (§3 ExactPrediction; §4 Instant).
    CkptBefore,
    /// Checkpoint before t0, then work through the window without any
    /// checkpoint, resuming the period at t0 + I (§4 NoCkptI).
    SkipWindow,
    /// Checkpoint before t0, then periodic proactive checkpoints with
    /// period `t_p` inside the window (§4 WithCkptI / Algorithm 1).
    CkptDuring { t_p: f64 },
    /// Preventive migration of duration `m` completing at t0 (§3.4);
    /// the predicted fault is avoided, state survives.
    Migrate { m: f64 },
}

/// Executable description of a strategy.
#[derive(Debug, Clone, PartialEq)]
pub struct StrategySpec {
    pub name: String,
    /// Regular-mode checkpoint period T_R.
    pub t_r: f64,
    /// Probability of trusting a prediction (the paper proves the
    /// optimum is 0 or 1; the simulator accepts anything in [0, 1]).
    pub q: f64,
    pub proactive: ProactiveMode,
}

impl StrategySpec {
    /// The lead time the executor needs ahead of t0.
    pub fn required_lead(&self, c: f64) -> f64 {
        match self.proactive {
            ProactiveMode::Migrate { m } => m.max(c),
            _ => c,
        }
    }
}

/// Build the spec for a paper strategy. Periods follow the §5
/// simulation protocol by default (`Capping::Uncapped`, q = 1):
/// T_R = sqrt(2 mu C / (1 − r q)).
pub fn spec_for(kind: StrategyKind, scenario: &Scenario, capping: Capping) -> StrategySpec {
    let p = Params::from_scenario(scenario);
    let t_r = model::optimal_period(&p, kind, capping);
    match kind {
        StrategyKind::Young => StrategySpec {
            name: "Young".into(),
            t_r,
            q: 0.0,
            proactive: ProactiveMode::Ignore,
        },
        StrategyKind::ExactPrediction => StrategySpec {
            name: "ExactPrediction".into(),
            t_r,
            q: 1.0,
            proactive: ProactiveMode::CkptBefore,
        },
        StrategyKind::Instant => StrategySpec {
            name: "Instant".into(),
            t_r,
            q: 1.0,
            proactive: ProactiveMode::CkptBefore,
        },
        StrategyKind::NoCkptI => StrategySpec {
            name: "NoCkptI".into(),
            t_r,
            q: 1.0,
            proactive: ProactiveMode::SkipWindow,
        },
        StrategyKind::WithCkptI => StrategySpec {
            name: "WithCkptI".into(),
            t_r,
            q: 1.0,
            proactive: ProactiveMode::CkptDuring { t_p: model::tp_opt(&p) },
        },
        StrategyKind::Migration => StrategySpec {
            name: "Migration".into(),
            t_r,
            q: 1.0,
            proactive: ProactiveMode::Migrate { m: scenario.migration },
        },
    }
}

/// Daly's higher-order variant of the no-prediction baseline:
/// T = sqrt(2 (mu + R) C) [2].
pub fn daly_spec(scenario: &Scenario) -> StrategySpec {
    let p = Params::from_scenario(scenario);
    StrategySpec {
        name: "Daly".into(),
        t_r: (2.0 * (p.mu + p.r_rec) * p.c).sqrt().max(p.c),
        q: 0.0,
        proactive: ProactiveMode::Ignore,
    }
}

/// ExactPrediction executed against a *window* trace degenerates to
/// treating t0 as the fault date — which is exactly `Instant`. The §5
/// EXACTPREDICTION heuristic instead gets an exact-date trace (window
/// forced to 0); this helper builds that scenario variant.
pub fn exactify(scenario: &Scenario) -> Scenario {
    let mut s = scenario.clone();
    s.predictor.window = 0.0;
    s.predictor.ef = 0.0;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;
    use crate::util::approx_eq;

    fn scenario() -> Scenario {
        Scenario::paper(1 << 16, Predictor::windowed(0.85, 0.82, 3000.0))
    }

    #[test]
    fn uncapped_periods_match_formula() {
        let s = scenario();
        let mu = s.mu();
        let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        assert!(approx_eq(young.t_r, (2.0 * mu * 600.0).sqrt(), 1e-12));
        let exact = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
        assert!(approx_eq(exact.t_r, (2.0 * mu * 600.0 / 0.15).sqrt(), 1e-12));
        assert_eq!(exact.q, 1.0);
    }

    #[test]
    fn withckpt_carries_tp() {
        let s = scenario();
        let spec = spec_for(StrategyKind::WithCkptI, &s, Capping::Uncapped);
        match spec.proactive {
            ProactiveMode::CkptDuring { t_p } => {
                assert!(t_p >= 600.0);
                let k = 3000.0 / t_p;
                assert!((k - k.round()).abs() < 1e-9);
            }
            _ => panic!("wrong mode"),
        }
    }

    #[test]
    fn migration_lead() {
        let s = scenario();
        let spec = spec_for(StrategyKind::Migration, &s, Capping::Uncapped);
        assert_eq!(spec.required_lead(600.0), 600.0); // M = 300 < C
        let mut s2 = s.clone();
        s2.migration = 900.0;
        let spec2 = spec_for(StrategyKind::Migration, &s2, Capping::Uncapped);
        assert_eq!(spec2.required_lead(600.0), 900.0);
    }

    #[test]
    fn daly_close_to_young_at_large_mu() {
        let s = scenario();
        let daly = daly_spec(&s);
        let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let rel = (daly.t_r - young.t_r) / young.t_r;
        assert!(rel > 0.0 && rel < 0.01, "rel={rel}");
    }

    #[test]
    fn exactify_zeroes_window() {
        let s = exactify(&scenario());
        assert_eq!(s.predictor.window, 0.0);
        assert_eq!(s.predictor.ef, 0.0);
        s.validate().unwrap();
    }
}
