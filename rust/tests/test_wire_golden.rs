//! Golden-file pins for the wire protocol: the canonical JSONL
//! fixtures under `tests/fixtures/` are the protocol's byte-level
//! contract. Every line is checked in *both* directions — the typed
//! value constructed here must encode to the fixture bytes exactly,
//! and the fixture bytes must decode back to the typed value — so any
//! protocol drift (field rename, ordering change, number formatting,
//! new mandatory field) fails loudly here instead of only through the
//! adapter tests.
//!
//! Regenerate after *deliberate* protocol changes with
//! `python3 scripts/gen_wire_fixtures.py` (no Rust toolchain needed);
//! the generator mirrors the canonical encoder.

use ckptfp::api::{
    wire, ApiError, BatcherSnapshot, BestPeriodJob, BestPeriodOutcome, JobRequest, JobResponse,
    PlanJob, PlanResult, ServiceStats, SimulateJob, SimulateResult, SweepJob, SweepResult,
    SweepRow, VerifyJob,
};
use ckptfp::config::{Predictor, Scenario};
use ckptfp::dist::DistSpec;
use ckptfp::model::{Capping, StrategyKind};
use ckptfp::sim::PlatformSpec;
use ckptfp::strategies::PolicySpec;
use ckptfp::verify::{CaseVerdict, Domain, GridKind, Verdict, VerifyReport};

const REQUESTS_V2: &str = include_str!("fixtures/requests_v2.jsonl");
const RESPONSES_V2: &str = include_str!("fixtures/responses_v2.jsonl");
const RESPONSES_V1: &str = include_str!("fixtures/responses_v1.jsonl");
const REQUESTS_V1: &str = include_str!("fixtures/requests_v1.jsonl");
const REQUESTS_TAGGED_V2: &str = include_str!("fixtures/requests_tagged_v2.jsonl");
const STREAM_V2: &str = include_str!("fixtures/stream_v2.jsonl");

fn lines(s: &str) -> Vec<&str> {
    s.lines().filter(|l| !l.trim().is_empty()).collect()
}

/// The golden scenario the fixtures carry: `Scenario::paper(4096, ...)`
/// with a clean platform MTBF (mu_ind = 60000 * 4096), exp faults,
/// work 200000, seed 42.
fn golden_scenario() -> Scenario {
    let mut s = Scenario::paper(4096, Predictor::windowed(0.85, 0.82, 300.0));
    s.platform.mu_ind = 245_760_000.0;
    s.fault_dist = DistSpec::Exp;
    s.work = 200_000.0;
    s.seed = 42;
    s
}

/// The all-optional-fields variant: Weibull faults, uniform
/// false-prediction law, non-default ef/alpha/migration.
fn weibull_scenario() -> Scenario {
    let mut s = golden_scenario();
    s.predictor = Predictor::windowed(0.85, 0.82, 3000.0);
    s.predictor.ef = 1000.0;
    s.fault_dist = DistSpec::weibull(0.7);
    s.false_pred_dist = Some(DistSpec::Uniform);
    s.alpha = 0.3;
    s.migration = 450.0;
    s.seed = 7;
    s
}

fn golden_requests() -> Vec<JobRequest> {
    vec![
        JobRequest::Plan(PlanJob {
            scenario: golden_scenario(),
            capping: Capping::Capped,
            policy: None,
        }),
        JobRequest::Plan(PlanJob {
            scenario: golden_scenario(),
            capping: Capping::Uncapped,
            policy: Some(PolicySpec::Strategy(StrategyKind::NoCkptI)),
        }),
        JobRequest::Simulate(SimulateJob {
            scenario: golden_scenario(),
            strategy: StrategyKind::NoCkptI,
            reps: 17,
            workers: Some(3),
            policy: None,
            platform: None,
        }),
        JobRequest::Simulate(SimulateJob {
            scenario: weibull_scenario(),
            strategy: StrategyKind::Young,
            reps: 5,
            workers: None,
            policy: Some(PolicySpec::RiskThreshold { kappa: 2.5 }),
            platform: Some("nodes=4,commit=0.05".parse::<PlatformSpec>().unwrap()),
        }),
        JobRequest::BestPeriod(BestPeriodJob {
            scenario: golden_scenario(),
            strategy: StrategyKind::Migration,
            reps: 9,
            candidates: 12,
            workers: None,
            prune: true,
            policy: None,
            platform: Some("nodes=8".parse::<PlatformSpec>().unwrap()),
        }),
        JobRequest::BestPeriod(BestPeriodJob {
            scenario: golden_scenario(),
            strategy: StrategyKind::Young,
            reps: 3,
            candidates: 4,
            workers: Some(2),
            prune: false,
            policy: Some(PolicySpec::AdaptivePeriod { gain: 0.75 }),
            platform: None,
        }),
        JobRequest::Sweep(SweepJob {
            base: golden_scenario(),
            n_procs: vec![1 << 14, 1 << 16, 1 << 19],
            capping: Capping::Uncapped,
        }),
        JobRequest::Verify(VerifyJob {
            grid: GridKind::Quick,
            policy: Some(PolicySpec::RiskThreshold { kappa: 1.0 }),
            reps: 32,
            budget: 128,
            workers: Some(2),
            platform: Some("nodes=4".parse::<PlatformSpec>().unwrap()),
        }),
        JobRequest::Stats,
        JobRequest::Ping,
    ]
}

fn golden_plan_result() -> PlanResult {
    PlanResult {
        waste: [0.117, 0.105, 0.11, 0.112, 1.0, 0.09],
        period: [8485.25, 21900.5, 21900.5, 21900.5, 21900.5, 21900.5],
        winner: StrategyKind::ExactPrediction,
        winner_waste: 0.105,
        winner_period: 21900.5,
        q: 1,
        via_hlo: false,
    }
}

fn golden_stats() -> ServiceStats {
    ServiceStats {
        requests: 10,
        errors: 2,
        plans: 3,
        simulates: 4,
        best_periods: 1,
        sweeps: 0,
        verifies: 2,
        lat_p50_s: 0.001,
        lat_p95_s: 0.01,
        lat_p99_s: 0.02,
        lat_n: 8,
        banks_built: 2,
        bank_replays: 1536,
        bank_fallbacks: 3,
        bank_bytes_resident: 1 << 20,
        rejected_overloaded: 5,
        deadline_exceeded: 1,
        panics_contained: 2,
        client_retries: 7,
        batch_lanes_run: 512,
        batch_lane_fallbacks: 4,
        wide_lanes_run: 4096,
        wide_evictions: 9,
        cache_hits: 6,
        cache_misses: 4,
        cache_evictions: 1,
        cache_entries: 3,
        batcher: Some(BatcherSnapshot { requests: 3, batches: 1, max_batch: 3 }),
    }
}

fn golden_responses() -> Vec<JobResponse> {
    vec![
        JobResponse::Plan(golden_plan_result()),
        JobResponse::Simulate(SimulateResult {
            strategy: "NoCkptI".into(),
            reps: 40,
            workers: 4,
            mean_waste: 0.123456789012345,
            waste_ci95: 0.01,
            mean_makespan: 1.0e7,
            completion_rate: 1.0,
            n_faults: 321,
            n_preds: 200,
            n_ckpts: 1000,
            n_proactive_ckpts: 55,
            sim_seconds: 1.25,
        }),
        JobResponse::BestPeriod(BestPeriodOutcome {
            strategy: "Young".into(),
            t_r: 8123.4,
            waste: 0.117,
            n_pruned: 3,
            sweep: vec![(1000.0, 0.2), (2000.0, 0.15), (4000.0, 0.117)],
            reps: 10,
            candidates: 3,
            workers: 8,
            reps_used: 24,
        }),
        JobResponse::Sweep(SweepResult {
            rows: vec![
                SweepRow {
                    n_procs: 1 << 16,
                    mu: 60133.0,
                    winner: StrategyKind::ExactPrediction,
                    winner_waste: 0.11,
                    winner_period: 9000.0,
                },
                SweepRow {
                    n_procs: 1 << 19,
                    mu: 7516.5,
                    winner: StrategyKind::Young,
                    winner_waste: 0.4,
                    winner_period: 3000.0,
                },
            ],
            via_hlo: false,
        }),
        JobResponse::Verify(VerifyReport {
            grid: GridKind::Quick,
            workers: 4,
            n_pass: 1,
            n_fail: 0,
            n_inconclusive: 1,
            cases: vec![
                CaseVerdict {
                    name: "exp-n16-none-Young".into(),
                    policy: "Young".into(),
                    domain: Domain::FirstOrder,
                    analytic: 0.117,
                    band: (0.097, 0.137),
                    sim_mean: 0.1175,
                    sim_ci95: 0.004,
                    completion_rate: 1.0,
                    reps: 48,
                    verdict: Verdict::Pass,
                },
                CaseVerdict {
                    name: "weibull:0.5-n16-none-Young".into(),
                    policy: "Young".into(),
                    domain: Domain::OutOfDomain { reason: "weibull:0.5 faults".into() },
                    analytic: 0.117,
                    band: (0.03, 0.47),
                    sim_mean: 0.46,
                    sim_ci95: 0.02,
                    completion_rate: 1.0,
                    reps: 384,
                    verdict: Verdict::Inconclusive,
                },
            ],
        }),
        JobResponse::Stats(golden_stats()),
        JobResponse::Stats(ServiceStats::default()),
        JobResponse::Pong,
        JobResponse::Error(ApiError::bad_request("work must be positive")),
        JobResponse::Error(ApiError::overloaded(
            "service at capacity (32 jobs in flight); retry after 250 ms",
            250,
        )),
        JobResponse::Error(ApiError::deadline_exceeded(
            "simulate finished 96 of 1000000 replications before the deadline",
        )),
    ]
}

// ---------------------------------------------------------------------------
// v2 requests
// ---------------------------------------------------------------------------

#[test]
fn v2_request_fixtures_pin_both_directions() {
    let fixture = lines(REQUESTS_V2);
    let typed = golden_requests();
    assert_eq!(
        fixture.len(),
        typed.len(),
        "fixture count drifted — regenerate scripts/gen_wire_fixtures.py and update golden_requests()"
    );
    for (i, (line, req)) in fixture.iter().zip(&typed).enumerate() {
        // Typed -> bytes: canonical encoding is pinned exactly.
        let encoded = wire::encode_request(req);
        assert_eq!(&encoded, line, "request {i}: encoding drifted");
        // Bytes -> typed: the fixture decodes to the same value.
        let decoded = wire::decode_request(line)
            .unwrap_or_else(|e| panic!("request {i} failed to decode: {e}"));
        assert!(!decoded.legacy, "request {i}: v2 lines are not legacy");
        assert_eq!(&decoded.request, req, "request {i}: decode drifted");
    }
}

// ---------------------------------------------------------------------------
// v2 responses
// ---------------------------------------------------------------------------

#[test]
fn v2_response_fixtures_pin_both_directions() {
    let fixture = lines(RESPONSES_V2);
    let typed = golden_responses();
    assert_eq!(
        fixture.len(),
        typed.len(),
        "fixture count drifted — regenerate scripts/gen_wire_fixtures.py and update golden_responses()"
    );
    for (i, (line, resp)) in fixture.iter().zip(&typed).enumerate() {
        let encoded = wire::encode_response(resp, false);
        assert_eq!(&encoded, line, "response {i}: encoding drifted");
        let decoded = wire::decode_response(line)
            .unwrap_or_else(|e| panic!("response {i} failed to decode: {e}"));
        assert_eq!(&decoded, resp, "response {i}: decode drifted");
    }
}

// ---------------------------------------------------------------------------
// Service envelope + streaming frames (additive v2)
// ---------------------------------------------------------------------------

#[test]
fn tagged_request_fixtures_pin_the_service_envelope() {
    let fixture = lines(REQUESTS_TAGGED_V2);
    let typed: Vec<(JobRequest, wire::RequestMeta)> = vec![
        (
            JobRequest::Sweep(SweepJob {
                base: golden_scenario(),
                n_procs: vec![1 << 14, 1 << 16, 1 << 19],
                capping: Capping::Uncapped,
            }),
            wire::RequestMeta { tenant: Some("acme".into()), stream: true },
        ),
        (
            JobRequest::Ping,
            wire::RequestMeta { tenant: Some("beta".into()), stream: false },
        ),
    ];
    assert_eq!(fixture.len(), typed.len());
    for (i, (line, (req, meta))) in fixture.iter().zip(&typed).enumerate() {
        let encoded = wire::encode_request_tagged(req, meta);
        assert_eq!(&encoded, line, "tagged request {i}: encoding drifted");
        let (decoded, got_meta) = wire::decode_request_meta(line)
            .unwrap_or_else(|e| panic!("tagged request {i} failed to decode: {e}"));
        assert!(!decoded.legacy, "tagged request {i}: v2 lines are not legacy");
        assert_eq!(&decoded.request, req, "tagged request {i}: request drifted");
        assert_eq!(&got_meta, meta, "tagged request {i}: envelope drifted");
    }
}

#[test]
fn streaming_frame_fixtures_pin_both_directions() {
    let fixture = lines(STREAM_V2);
    assert_eq!(fixture.len(), 3);
    // The streamed response is the golden sweep; its per-row items come
    // from the same `stream_items` hook the service uses.
    let resp = golden_responses()
        .into_iter()
        .find(|r| matches!(r, JobResponse::Sweep(_)))
        .unwrap();
    let (job, items) = wire::stream_items(&resp).expect("sweeps are streamable");
    assert_eq!(job, "sweep");
    assert_eq!(items.len(), 2);

    // Partial frames: typed -> bytes and bytes -> typed, with each
    // `item` byte-identical to the row embedded in the final payload.
    for (seq, (line, item)) in fixture.iter().zip(&items).enumerate() {
        let encoded = wire::encode_stream_partial(job, seq as u64, item.clone());
        assert_eq!(&encoded, line, "partial frame {seq}: encoding drifted");
        match wire::decode_stream_event(line).unwrap() {
            wire::StreamEvent::Partial { job: j, seq: s, item: it } => {
                assert_eq!(j, "sweep");
                assert_eq!(s, seq as u64);
                assert_eq!(&it, item, "partial frame {seq}: item drifted");
            }
            other => panic!("partial frame {seq} decoded to {other:?}"),
        }
    }

    // Final frame: the complete standard payload plus frame/seq markers.
    let final_line = fixture[2];
    assert_eq!(
        wire::encode_stream_final(&resp, items.len() as u64),
        final_line,
        "final frame: encoding drifted"
    );
    match wire::decode_stream_event(final_line).unwrap() {
        wire::StreamEvent::Final { seq, response } => {
            assert_eq!(seq, Some(2));
            assert_eq!(response, resp, "final frame: payload drifted");
        }
        other => panic!("final frame decoded to {other:?}"),
    }

    // Plain (unframed) responses decode as `Final { seq: None, .. }` —
    // the client can read streamed and unstreamed exchanges uniformly.
    let plain = wire::encode_response(&resp, false);
    match wire::decode_stream_event(&plain).unwrap() {
        wire::StreamEvent::Final { seq: None, response } => assert_eq!(response, resp),
        other => panic!("plain response decoded to {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// v1 (legacy) shapes
// ---------------------------------------------------------------------------

#[test]
fn v1_response_fixtures_pin_the_legacy_shape() {
    let fixture = lines(RESPONSES_V1);
    let typed = vec![
        JobResponse::Plan(golden_plan_result()),
        JobResponse::Stats(golden_stats()),
        JobResponse::Pong,
        JobResponse::Error(ApiError::bad_request("work must be positive")),
    ];
    assert_eq!(fixture.len(), typed.len());
    for (i, (line, resp)) in fixture.iter().zip(&typed).enumerate() {
        let encoded = wire::encode_response(resp, true);
        assert_eq!(&encoded, line, "legacy response {i}: encoding drifted");
    }
}

#[test]
fn v1_request_fixtures_decode_through_the_adapter() {
    let fixture = lines(REQUESTS_V1);
    assert_eq!(fixture.len(), 3);
    // Line 0: the flat planner dialect.
    let d = wire::decode_request(fixture[0]).unwrap();
    assert!(d.legacy);
    match d.request {
        JobRequest::Plan(job) => {
            assert_eq!(job.scenario.platform.n_procs, 1);
            assert!((job.scenario.mu() - 60000.0).abs() < 1e-9);
            assert_eq!(job.scenario.predictor.recall, 0.85);
            assert_eq!(job.scenario.predictor.precision, 0.82);
            assert_eq!(job.scenario.predictor.window, 300.0);
            assert_eq!(job.capping, Capping::Uncapped);
            assert_eq!(job.policy, None);
        }
        other => panic!("line 0 decoded to {other:?}"),
    }
    // Lines 1-2: bare verbs.
    assert!(matches!(
        wire::decode_request(fixture[1]).unwrap(),
        wire::Decoded { request: JobRequest::Ping, legacy: true }
    ));
    assert!(matches!(
        wire::decode_request(fixture[2]).unwrap(),
        wire::Decoded { request: JobRequest::Stats, legacy: true }
    ));
}
