//! Planner output types — shared by the real PJRT-backed planner and
//! the no-`pjrt` stub, so the coordinator/service layers compile either
//! way.

use crate::model::StrategyKind;

/// Result of planning one configuration through the HLO path.
#[derive(Debug, Clone)]
pub struct PlanOutput {
    /// Per-strategy optimal waste (clamped to 1.0).
    pub waste: [f64; 6],
    /// Per-strategy optimal period.
    pub period: [f64; 6],
    /// Winning strategy index.
    pub winner: StrategyKind,
    pub winner_waste: f64,
    pub winner_period: f64,
}

/// Raw waste surfaces for figure generation.
#[derive(Debug, Clone)]
pub struct SurfaceOutput {
    /// waste[s][j] for one configuration.
    pub waste: Vec<Vec<f64>>,
    /// The period grid T[j].
    pub periods: Vec<f64>,
}
