//! The paper's analytical waste model, in closed form.
//!
//! This is the native mirror of the AOT-compiled L2 planner
//! (`python/compile/model.py`): identical equations, identical case
//! analysis. It serves three purposes: (i) validation target for the
//! HLO artifacts (the integration tests cross-check both paths), (ii)
//! fallback when `artifacts/` is absent, (iii) the uncapped-period
//! formulas the §5 simulations use directly.

mod batched;
mod optimal;
mod waste;
mod window;

pub use batched::*;
pub use optimal::*;
pub use waste::*;
pub use window::*;

use crate::config::Scenario;

/// Number of strategies on the kernel's `s` axis.
pub const NSTRAT_USIZE: usize = 6;

/// Strategy indices — shared with the Pallas kernel's `s` axis and the
/// planner artifacts; keep in sync with `python/compile/kernels/waste_grid.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum StrategyKind {
    /// Periodic checkpointing, predictions ignored (q = 0) [11].
    Young = 0,
    /// Exact-date predictions, always trusted (§3, q = 1).
    ExactPrediction = 1,
    /// Window treated as an exact date at its start (§4, strategy 1).
    Instant = 2,
    /// No checkpoints inside the prediction window (§4, strategy 2).
    NoCkptI = 3,
    /// Periodic proactive checkpoints inside the window (§4, strategy 3).
    WithCkptI = 4,
    /// Preventive migration instead of proactive checkpoint (§3.4).
    Migration = 5,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 6] = [
        StrategyKind::Young,
        StrategyKind::ExactPrediction,
        StrategyKind::Instant,
        StrategyKind::NoCkptI,
        StrategyKind::WithCkptI,
        StrategyKind::Migration,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Young => "Young",
            StrategyKind::ExactPrediction => "ExactPrediction",
            StrategyKind::Instant => "Instant",
            StrategyKind::NoCkptI => "NoCkptI",
            StrategyKind::WithCkptI => "WithCkptI",
            StrategyKind::Migration => "Migration",
        }
    }

    pub fn from_index(i: usize) -> Option<StrategyKind> {
        StrategyKind::ALL.get(i).copied()
    }
}

impl std::fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for StrategyKind {
    type Err = anyhow::Error;

    /// Case-insensitive strategy lookup by name — the wire edge for the
    /// `simulate`/`best_period` jobs and the CLI `--strategy` flag.
    fn from_str(s: &str) -> anyhow::Result<StrategyKind> {
        StrategyKind::ALL
            .into_iter()
            .find(|k| k.name().eq_ignore_ascii_case(s.trim()))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown strategy '{s}' (expected one of Young, ExactPrediction, Instant, NoCkptI, WithCkptI, Migration)"
                )
            })
    }
}

/// Scalar parameter bundle for the closed forms (built from a
/// [`Scenario`]; mirrors the raw-parameter row of the HLO planner).
#[derive(Debug, Clone, Copy)]
pub struct Params {
    pub mu: f64,
    pub c: f64,
    pub d: f64,
    pub r_rec: f64, // recovery duration R (r is taken by recall below)
    pub recall: f64,
    pub precision: f64,
    pub i: f64,
    pub ef: f64,
    pub alpha: f64,
    pub m: f64,
}

impl Params {
    pub fn from_scenario(s: &Scenario) -> Params {
        Params {
            mu: s.mu(),
            c: s.platform.c,
            d: s.platform.d,
            r_rec: s.platform.r,
            recall: s.predictor.recall,
            precision: s.predictor.precision,
            i: s.predictor.window,
            ef: s.predictor.ef,
            alpha: s.alpha,
            m: s.migration,
        }
    }

    /// D + R, the per-fault fixed cost.
    pub fn dr(&self) -> f64 {
        self.d + self.r_rec
    }

    /// 1 / mu_P = r / (p mu); 0 when the predictor never fires.
    pub fn inv_mu_p(&self) -> f64 {
        if self.recall == 0.0 { 0.0 } else { self.recall / (self.precision * self.mu) }
    }

    /// 1 / mu_NP = (1 - r) / mu.
    pub fn inv_mu_np(&self) -> f64 {
        (1.0 - self.recall) / self.mu
    }

    /// mu_e from §2.3.
    pub fn mu_e(&self) -> f64 {
        let inv = self.inv_mu_p() + self.inv_mu_np();
        if inv == 0.0 { f64::INFINITY } else { 1.0 / inv }
    }

    /// I' at q = 1: (1-p) I + p E_I^(f) (§4.1).
    pub fn i1(&self) -> f64 {
        (1.0 - self.precision) * self.i + self.precision * self.ef
    }

    /// Fraction of time in regular mode at q = 1, clamped to [0, 1].
    pub fn frac_reg(&self) -> f64 {
        (1.0 - self.i1() * self.inv_mu_p()).clamp(0.0, 1.0)
    }

    /// The raw f32 row consumed by the HLO planner artifacts.
    pub fn to_raw_row(&self) -> [f32; 10] {
        [
            self.mu as f32,
            self.c as f32,
            self.d as f32,
            self.r_rec as f32,
            self.recall as f32,
            self.precision as f32,
            self.i as f32,
            self.ef as f32,
            self.alpha as f32,
            self.m as f32,
        ]
    }
}

/// Result of planning one configuration: per-strategy optimum plus the
/// winning strategy.
#[derive(Debug, Clone)]
pub struct OptimalPlan {
    /// Optimal period per strategy (same indexing as [`StrategyKind`]).
    pub period: [f64; 6],
    /// Expected waste per strategy at its optimal period, clamped to 1.
    pub waste: [f64; 6],
    /// Winning strategy.
    pub winner: StrategyKind,
    /// q decision of the winner (0 = ignore predictor, 1 = trust).
    pub q: u8,
}

impl OptimalPlan {
    pub fn winner_waste(&self) -> f64 {
        self.waste[self.winner as usize]
    }

    pub fn winner_period(&self) -> f64 {
        self.period[self.winner as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;
    use crate::util::approx_eq;

    #[test]
    fn params_derived_quantities() {
        let s = Scenario::paper(1 << 16, Predictor::windowed(0.7, 0.4, 3000.0));
        let p = Params::from_scenario(&s);
        assert!(approx_eq(p.inv_mu_p(), 0.7 / (0.4 * p.mu), 1e-12));
        assert!(approx_eq(p.inv_mu_np(), 0.3 / p.mu, 1e-12));
        assert!(approx_eq(p.i1(), 0.6 * 3000.0 + 0.4 * 1500.0, 1e-12));
        assert!(p.frac_reg() > 0.0 && p.frac_reg() < 1.0);
    }

    #[test]
    fn strategy_kind_round_trip() {
        for (i, k) in StrategyKind::ALL.iter().enumerate() {
            assert_eq!(StrategyKind::from_index(i), Some(*k));
            assert_eq!(*k as usize, i);
        }
        assert_eq!(StrategyKind::from_index(6), None);
    }

    #[test]
    fn strategy_kind_parses_by_name() {
        for k in StrategyKind::ALL {
            assert_eq!(k.name().parse::<StrategyKind>().unwrap(), k);
            assert_eq!(k.name().to_lowercase().parse::<StrategyKind>().unwrap(), k);
        }
        assert!("Daly".parse::<StrategyKind>().is_err());
        assert!("".parse::<StrategyKind>().is_err());
    }

    #[test]
    fn raw_row_layout() {
        let s = Scenario::paper(1 << 19, Predictor::windowed(0.85, 0.82, 300.0));
        let row = Params::from_scenario(&s).to_raw_row();
        assert_eq!(row[1], 600.0); // C
        assert_eq!(row[2], 60.0); // D
        assert_eq!(row[4], 0.85); // recall
        assert_eq!(row[8], 0.27); // alpha
    }
}
