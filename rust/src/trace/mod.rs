//! Fault and prediction trace generation (§5's simulation engine
//! front-end).
//!
//! A trace is two monotone event streams: *faults* (times drawn i.i.d.
//! from the failure law, each marked predicted with probability r) and
//! *predictions* (true positives derived from predicted faults, merged
//! with a false-positive stream whose inter-arrival expectation is
//! p mu / (r (1-p)) — §5). Both streams are consumed lazily by the
//! simulation engine through the [`EventSource`] trait.

pub mod bank;
mod event;
mod gen;
pub mod io;

pub use bank::{BankCounters, BankOptions, ReplaySource, TraceBank};
pub use event::{Fault, Prediction};
pub use gen::TraceGen;

/// A source of monotone fault / prediction streams.
///
/// `next_fault` yields faults in nondecreasing time order;
/// `next_prediction` yields predictions in nondecreasing *availability*
/// order. `None` means the stream is exhausted (finite replay sources);
/// generators are infinite.
pub trait EventSource {
    fn next_fault(&mut self) -> Option<Fault>;
    fn next_prediction(&mut self) -> Option<Prediction>;

    /// Pre-sampled trust uniform for the prediction most recently
    /// returned by [`EventSource::next_prediction`]. `None` (the
    /// default for live generators) tells the engine to draw from its
    /// own per-replication trust RNG; replay sources
    /// ([`bank::ReplaySource`]) return the uniform banked for that
    /// prediction, which is bit-identical to what the engine's RNG
    /// would have produced (see [`crate::rng::trust_seed`]).
    fn next_trust_uniform(&mut self) -> Option<f64> {
        None
    }
}

/// Replay of pre-built vectors — test fixture and trace-file playback.
#[derive(Debug, Clone, Default)]
pub struct VecSource {
    faults: std::collections::VecDeque<Fault>,
    preds: std::collections::VecDeque<Prediction>,
}

impl VecSource {
    pub fn new(mut faults: Vec<Fault>, mut preds: Vec<Prediction>) -> Self {
        faults.sort_by(|a, b| a.t.total_cmp(&b.t));
        preds.sort_by(|a, b| a.avail.total_cmp(&b.avail));
        VecSource { faults: faults.into(), preds: preds.into() }
    }
}

impl EventSource for VecSource {
    fn next_fault(&mut self) -> Option<Fault> {
        self.faults.pop_front()
    }

    fn next_prediction(&mut self) -> Option<Prediction> {
        self.preds.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_source_sorts() {
        let mut s = VecSource::new(
            vec![Fault::unpredicted(5.0, 1), Fault::unpredicted(2.0, 0)],
            vec![],
        );
        assert_eq!(s.next_fault().unwrap().t, 2.0);
        assert_eq!(s.next_fault().unwrap().t, 5.0);
        assert!(s.next_fault().is_none());
    }
}
