//! Integration: the conformance subsystem end to end — grid shape,
//! oracle domains, CI-aware verdicts with replication escalation, the
//! `CONFORMANCE.json` document, the wire round-trip of the `verify`
//! job, and the acceptance pin that a TCP-served `Verify` returns a
//! verdict set bit-identical to the in-process run.

use ckptfp::api::{
    wire, Executor, ExecutorConfig, JobRequest, JobResponse, ServiceClient, VerifyJob,
};
use ckptfp::coordinator::{serve, ServiceConfig, ServiceHandle};
use ckptfp::model::StrategyKind;
use ckptfp::strategies::PolicySpec;
use ckptfp::util::json::Json;
use ckptfp::verify::{
    conformance_grid, conformance_json, judge_case, oracle_for, report_from_json,
    run_conformance, Domain, GridKind, Verdict, VerifyOptions, CONFORMANCE_SCHEMA,
};

fn start_local_service() -> (ServiceHandle, String) {
    let executor = Executor::new(ExecutorConfig::default());
    let handle = serve(
        executor,
        ServiceConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

// ---------------------------------------------------------------------------
// Grid and oracle
// ---------------------------------------------------------------------------

#[test]
fn quick_grid_spans_both_domains_and_all_subjects() {
    let cases = conformance_grid(GridKind::Quick);
    assert!(cases.len() >= 18, "quick grid has {} cases", cases.len());
    let mut first_order = 0;
    let mut out_of_domain = 0;
    for case in &cases {
        match oracle_for(case).unwrap().domain {
            Domain::FirstOrder => first_order += 1,
            Domain::OutOfDomain { .. } => out_of_domain += 1,
        }
    }
    assert!(first_order >= 8, "{first_order} in-domain cases");
    assert!(out_of_domain >= 6, "{out_of_domain} out-of-domain cases");
}

#[test]
fn deliberate_regime_case_takes_the_divergence_bound_path() {
    // The acceptance criterion: at least one deliberately out-of-domain
    // case (T ~ mu) demonstrates the divergence-bound path end to end.
    let case = conformance_grid(GridKind::Quick)
        .into_iter()
        .find(|c| c.name == "exp-n16-none-mu4000-Young")
        .expect("the T ~ mu case must be on the quick grid");
    let oracle = oracle_for(&case).unwrap();
    match &oracle.domain {
        Domain::OutOfDomain { reason } => assert!(reason.contains("first-order"), "{reason}"),
        d => panic!("expected out-of-domain, got {d:?}"),
    }
    // The band is a bound, not agreement: it is far wider than the
    // in-domain slack of the same strategy...
    let in_domain = conformance_grid(GridKind::Quick)
        .into_iter()
        .find(|c| c.name == "exp-n16-none-Young")
        .unwrap();
    let od_width = (oracle.band.1 - oracle.band.0) / oracle.analytic;
    let id_oracle = oracle_for(&in_domain).unwrap();
    let id_width = (id_oracle.band.1 - id_oracle.band.0) / id_oracle.analytic;
    assert!(od_width > id_width * 1.5, "od {od_width} vs id {id_width}");
    // ...and judging it works: the simulator diverges from the
    // first-order value (that is the point) yet stays inside the bound.
    let opts = VerifyOptions { reps0: 24, budget: 96, workers: 2, ..Default::default() };
    let v = judge_case(&case, &opts).unwrap();
    assert_ne!(v.verdict, Verdict::Fail, "{v:?}");
    assert_eq!(v.completion_rate, 1.0);
}

// ---------------------------------------------------------------------------
// Verdicts and escalation
// ---------------------------------------------------------------------------

#[test]
fn escalation_extends_rather_than_restarts() {
    // Same case, same workers: a run that escalates must report more
    // reps than its base batch and stay within the budget.
    let case = conformance_grid(GridKind::Quick)
        .into_iter()
        .find(|c| c.name == "exp-n16-yu:exact-ExactPrediction")
        .unwrap();
    let opts = VerifyOptions { reps0: 2, budget: 11, workers: 2, ..Default::default() };
    let v = judge_case(&case, &opts).unwrap();
    assert!(v.reps >= 2 && v.reps <= 11, "reps {}", v.reps);
    // reps follows the doubling schedule 2 -> 4 -> 8 -> 11.
    assert!([2u64, 4, 8, 11].contains(&v.reps), "reps {}", v.reps);
}

#[test]
fn quick_grid_small_budget_has_no_failures() {
    // The CI gate in miniature: a reduced-budget pass over the full
    // quick grid must produce zero `fail` verdicts. (CI runs the same
    // gate at full budget via `ckptfp verify --grid quick`.)
    let opts = VerifyOptions { reps0: 16, budget: 128, workers: 2, ..Default::default() };
    let report = run_conformance(GridKind::Quick, None, &opts).unwrap();
    let failed: Vec<&str> = report
        .cases
        .iter()
        .filter(|c| c.verdict == Verdict::Fail)
        .map(|c| c.name.as_str())
        .collect();
    assert!(failed.is_empty(), "failed cases: {failed:?}");
    assert_eq!(report.n_fail, 0);
    assert_eq!(
        report.n_pass + report.n_inconclusive,
        report.cases.len() as u64
    );
    // The grid must not be vacuously inconclusive either: most cases
    // resolve on this budget.
    assert!(
        report.n_pass as usize * 2 > report.cases.len(),
        "only {} of {} cases passed",
        report.n_pass,
        report.cases.len()
    );
}

// ---------------------------------------------------------------------------
// CONFORMANCE.json and the wire
// ---------------------------------------------------------------------------

#[test]
fn conformance_json_document_round_trips() {
    let opts = VerifyOptions { reps0: 4, budget: 8, workers: 2, ..Default::default() };
    let spec = PolicySpec::Strategy(StrategyKind::Migration);
    let report = run_conformance(GridKind::Quick, Some(&spec), &opts).unwrap();
    let doc = conformance_json(&report).to_string();
    let parsed = ckptfp::util::json::parse(&doc).unwrap();
    assert_eq!(
        parsed.get("schema").and_then(Json::as_str),
        Some(CONFORMANCE_SCHEMA)
    );
    let back = report_from_json(&parsed).unwrap();
    assert_eq!(back, report, "document must round-trip the full report");
}

#[test]
fn verify_job_round_trips_on_the_wire() {
    let jobs = vec![
        VerifyJob::new(GridKind::Quick),
        VerifyJob::new(GridKind::Full),
        VerifyJob {
            grid: GridKind::Quick,
            policy: Some(PolicySpec::RiskThreshold { kappa: 1.0 }),
            reps: 12,
            budget: 48,
            workers: Some(3),
            platform: None,
        },
    ];
    for job in jobs {
        let req = JobRequest::Verify(job);
        let line = wire::encode_request(&req);
        let decoded = wire::decode_request(&line).unwrap();
        assert!(!decoded.legacy);
        assert_eq!(decoded.request, req, "round-trip of {line}");
    }
    // A bare v2 verify defaults to the quick grid.
    match wire::decode_request(r#"{"v": 2, "op": "verify"}"#).unwrap().request {
        JobRequest::Verify(job) => {
            assert_eq!(job.grid, GridKind::Quick);
            assert_eq!(job.policy, None);
        }
        other => panic!("wrong request: {other:?}"),
    }
    // Unknown grids are bad requests naming the offender.
    let err = wire::decode_request(r#"{"v": 2, "op": "verify", "grid": "huge"}"#).unwrap_err();
    assert!(err.message.contains("huge"), "{}", err.message);
}

#[test]
fn verify_response_round_trips_on_the_wire() {
    let opts = VerifyOptions { reps0: 4, budget: 8, workers: 2, ..Default::default() };
    let spec = PolicySpec::AdaptivePeriod { gain: 1.0 };
    let report = run_conformance(GridKind::Quick, Some(&spec), &opts).unwrap();
    let resp = JobResponse::Verify(report);
    let line = wire::encode_response(&resp, false);
    let decoded = wire::decode_response(&line).unwrap();
    assert_eq!(decoded, resp, "round-trip of {line}");
}

// ---------------------------------------------------------------------------
// Acceptance pin: TCP == in-process, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn verify_over_tcp_is_bit_identical_to_in_process() {
    let (handle, addr) = start_local_service();
    // Filter to the Young cases to keep the service call quick; the
    // determinism contract is the same for any filter.
    let job = VerifyJob {
        grid: GridKind::Quick,
        policy: Some(PolicySpec::Strategy(StrategyKind::Young)),
        reps: 8,
        budget: 16,
        workers: Some(2),
        platform: None,
    };
    let mut client = ServiceClient::connect(&addr).unwrap();
    let served = client.verify(job.clone()).unwrap();

    let local = Executor::local().verify(&job).unwrap();

    assert_eq!(served.cases.len(), local.cases.len());
    for (s, l) in served.cases.iter().zip(&local.cases) {
        assert_eq!(s.name, l.name);
        assert_eq!(s.verdict, l.verdict, "{}", s.name);
        assert_eq!(s.reps, l.reps, "{}", s.name);
        assert_eq!(
            s.sim_mean.to_bits(),
            l.sim_mean.to_bits(),
            "{}: served {} vs local {}",
            s.name,
            s.sim_mean,
            l.sim_mean
        );
        assert_eq!(s.sim_ci95.to_bits(), l.sim_ci95.to_bits(), "{}", s.name);
    }
    assert_eq!(served, local, "the full verdict set must be identical");
    handle.stop();
}
