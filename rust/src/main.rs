//! `ckptfp` — the command-line front end. Every planning/simulation
//! command is a thin caller of the same [`ckptfp::api::Executor`] the
//! TCP service dispatches to; `client` drives a remote service over the
//! same typed jobs.
//!
//! ```text
//! ckptfp plan        [--n-procs N | --mu-mn M] [--recall R --precision P --window I] [--policy P] [--hlo] [--json]
//! ckptfp simulate    [--strategy NAME | --policy P] [--platform SPEC] [--n-procs N] [--reps K] [--workers W] [--dist exp|weibull:K]
//! ckptfp best-period [--strategy NAME | --policy P] [--platform SPEC] [--reps K] [--candidates N] [--prune] [scenario flags]
//! ckptfp verify      [--grid quick|full] [--policy P] [--platform SPEC] [--reps K] [--budget B] [--workers W] [--out FILE] [--json]
//! ckptfp experiment  <fig4..fig11|tab1..tab3|policy-comparison|conformance|platform-scaling|all> [--reps K] [--best-period] [--out DIR]
//! ckptfp serve       [--addr HOST:PORT] [--workers W] [--reps-default K] [--max-conns N] [--max-inflight N] [--queue-depth N] [--sched-workers N] [--tenants name=w,name=w] [--deadline-ms MS] [--drain-ms MS]
//! ckptfp client      <plan|simulate|best-period|verify|ping|stats> --addr HOST:PORT [job flags]
//! ckptfp loadgen     [--seed S] [--requests N] [--bench-reps K] [--bench-candidates N] [--addr HOST:PORT] [--out FILE]
//! ckptfp trace       [--out FILE] [--horizon SECONDS] [--n-procs N]
//! ckptfp config      <file.toml> — validate and print a scenario (+ optional [policy] / platform keys)
//! ```
//!
//! `--policy` takes a policy spec: a strategy name (`Young`,
//! `ExactPrediction`, …) or one of the non-paper policies
//! (`adaptive[:gain]`, `risk[:kappa]`).
//!
//! `--platform` takes a platform spec: `single` or comma-separated
//! `key=value` pairs (`nodes=8,commit=0.05,restart=partial,group=4,`
//! `spatial=0.25,cascade=0.1,delta=300`) — see `sim::platform`.

use anyhow::Context;
use ckptfp::api::{
    BestPeriodJob, BestPeriodOutcome, Executor, ExecutorConfig, PlanJob, PlanResult,
    ServiceClient, SimulateJob, SimulateResult, VerifyJob,
};
use ckptfp::cli::Args;
use ckptfp::config::{Predictor, Scenario};
use ckptfp::coordinator::{loadgen, serve, Batcher, BatcherConfig, ServiceConfig, TraceSpec};
use ckptfp::dist::DistSpec;
use ckptfp::experiments::{all_experiments, run_experiment, ExpOptions};
use ckptfp::model::{Capping, Params, StrategyKind};
use ckptfp::report::Table;
use ckptfp::sim::PlatformSpec;
use ckptfp::strategies::PolicySpec;
use ckptfp::trace::TraceGen;
use ckptfp::util::units::MIN;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn scenario_from_args(args: &mut Args) -> anyhow::Result<Scenario> {
    let n_procs: u64 = args.get("n-procs", 1u64 << 16)?;
    let recall: f64 = args.get("recall", 0.85)?;
    let precision: f64 = args.get("precision", 0.82)?;
    let window: f64 = args.get("window", 0.0)?;
    let pred = if window > 0.0 {
        Predictor::windowed(recall, precision, window)
    } else {
        Predictor::exact(recall, precision)
    };
    let mut s = Scenario::paper(n_procs, pred);
    if let Some(mu_mn) = args.get_opt::<f64>("mu-mn")? {
        // Direct platform-MTBF override (minutes), as in the paper text.
        s.platform.mu_ind = mu_mn * MIN * s.platform.n_procs as f64;
    }
    if let Some(c) = args.get_opt::<f64>("c")? {
        s.platform.c = c;
    }
    if let Some(w) = args.get_opt::<f64>("work")? {
        s.work = w;
    }
    if let Some(d) = args.get_opt::<DistSpec>("dist")? {
        s.fault_dist = d;
    }
    s.false_pred_dist = args.get_opt::<DistSpec>("false-dist")?;
    s.seed = args.get("seed", s.seed)?;
    s.validate()?;
    Ok(s)
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    match args.command() {
        Some("plan") => cmd_plan(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("best-period") => cmd_best_period(&mut args),
        Some("verify") => cmd_verify(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("client") => cmd_client(&mut args),
        Some("loadgen") => cmd_loadgen(&mut args),
        Some("trace") => cmd_trace(&mut args),
        Some("config") => cmd_config(&mut args),
        Some(other) => anyhow::bail!("unknown command '{other}' — see `ckptfp help`"),
        None => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
ckptfp — fault-prediction-aware checkpointing (Aupy et al. 2012 reproduction)

commands:
  plan         optimal strategy/period for a platform + predictor
  simulate     discrete-event simulation of one strategy or policy (worker pool)
  best-period  brute-force §5 period search by simulation (--policy sweeps
               a policy's own parameter: T_R, adaptive gain, or risk kappa)
  verify       conformance grid: cross-check the analytic model against the
               simulator with CI-aware verdicts; writes CONFORMANCE.json and
               exits nonzero on any 'fail' verdict
               [--grid quick|full] [--policy P] [--platform SPEC] [--reps N] [--budget N] [--out FILE]
  experiment   regenerate a paper figure/table (fig4..fig11, tab1..tab3,
               policy-comparison, conformance, platform-scaling, all)
  serve        TCP/JSONL job service (protocol v2; v1 planner dialect adapted)
               [--max-conns N] [--max-inflight N] [--queue-depth N]
               [--sched-workers N] [--tenants name=w,name=w]
               [--deadline-ms MS] [--drain-ms MS]
  client       run plan/simulate/best-period/verify jobs against a remote service
  loadgen      replay a seeded synthetic multi-tenant trace against the
               service (in-process unless --addr) and write BENCH_serve.json
  trace        dump a generated fault/prediction trace
  config       validate a TOML scenario file
policies (--policy): a strategy name, adaptive[:gain], or risk[:kappa]
platforms (--platform): 'single' or nodes=K[,commit=F][,restart=full|partial]
               [,group=G][,spatial=P][,cascade=P][,delta=S] — multi-node
               discrete-event platform with coordinated checkpoints
";

fn print_plan(s: &Scenario, out: &PlanResult) {
    let mut t = Table::new(["strategy", "period (s)", "waste"]);
    for k in StrategyKind::ALL {
        t.row([
            k.name().to_string(),
            format!("{:.1}", out.period[k as usize]),
            format!("{:.4}", out.waste[k as usize]),
        ]);
    }
    println!(
        "platform mu = {:.1} mn (N = {}), predictor r = {} p = {} I = {}s",
        s.mu() / MIN,
        s.platform.n_procs,
        s.predictor.recall,
        s.predictor.precision,
        s.predictor.window
    );
    print!("{t}");
    println!(
        "winner: {} (period {:.1} s, waste {:.4}){}",
        out.winner.name(),
        out.winner_period,
        out.winner_waste,
        if out.via_hlo { " [via AOT XLA planner]" } else { "" }
    );
}

fn cmd_plan(args: &mut Args) -> anyhow::Result<()> {
    let use_hlo = args.switch("hlo");
    let as_json = args.switch("json");
    let capped = args.switch("capped");
    let policy = args.get_opt::<PolicySpec>("policy")?;
    let s = scenario_from_args(args)?;
    args.finish()?;

    let executor = if use_hlo {
        let batcher = Batcher::spawn_default(BatcherConfig::default())
            .context("opening HLO planner (is artifacts/ built?)")?;
        Executor::with_batcher(batcher, ExecutorConfig::default())
    } else {
        Executor::local()
    };
    let capping = if capped { Capping::Capped } else { Capping::Uncapped };
    let out = executor.plan(&PlanJob { scenario: s.clone(), capping, policy })?;

    if as_json {
        println!(
            "{}",
            ckptfp::api::wire::encode_response(&ckptfp::api::JobResponse::Plan(out), false)
        );
        return Ok(());
    }
    print_plan(&s, &out);
    Ok(())
}

fn print_simulate(res: &SimulateResult) {
    println!(
        "{}: waste {:.4} ±{:.4} | makespan {:.2} days | completion {:.0}% | {} faults, {} ckpts over {} reps ({:.2} engine-s, {} workers)",
        res.strategy,
        res.mean_waste,
        res.waste_ci95,
        res.mean_makespan / 86400.0,
        res.completion_rate * 100.0,
        res.n_faults,
        res.n_ckpts + res.n_proactive_ckpts,
        res.reps,
        res.sim_seconds,
        res.workers,
    );
}

fn simulate_job_from_args(args: &mut Args) -> anyhow::Result<SimulateJob> {
    let strategy: StrategyKind = args.get_str("strategy", "ExactPrediction").parse()?;
    let policy = args.get_opt::<PolicySpec>("policy")?;
    let reps: u64 = args.get("reps", 20)?;
    let workers = args.get_opt::<u64>("workers")?;
    let platform = args.get_opt::<PlatformSpec>("platform")?;
    let scenario = scenario_from_args(args)?;
    Ok(SimulateJob { scenario, strategy, reps, workers, policy, platform })
}

fn cmd_simulate(args: &mut Args) -> anyhow::Result<()> {
    let job = simulate_job_from_args(args)?;
    args.finish()?;
    let res = Executor::local().simulate(&job)?;
    print_simulate(&res);
    // The analytic comparison line exists only for the closed-form
    // (paper strategy) waste model.
    if job.policy.is_none() {
        let s = ckptfp::experiments::scenario_for(job.strategy, &job.scenario);
        let spec = ckptfp::strategies::spec_for(job.strategy, &s, Capping::Uncapped);
        let p = Params::from_scenario(&s);
        let analytic =
            ckptfp::model::waste_of(&p, job.strategy, spec.t_r, ckptfp::model::tp_opt(&p));
        println!("analytic waste at T_R = {:.1}: {:.4}", spec.t_r, analytic);
    }
    Ok(())
}

fn print_best_period(res: &BestPeriodOutcome) {
    // `reps` is the requested per-candidate budget; `reps_used` is what
    // was actually simulated after pruning — the honest number for
    // bench comparisons. (Old servers report reps_used = 0: omit.)
    println!(
        "{}: best T_R {:.1} s (mean waste {:.4}) over {} candidates x {} reps requested ({} simulated, {} pruned, {} workers)",
        res.strategy,
        res.t_r,
        res.waste,
        res.candidates,
        res.reps,
        if res.reps_used > 0 { res.reps_used.to_string() } else { "?".into() },
        res.n_pruned,
        res.workers,
    );
    for (t, w) in &res.sweep {
        println!("  T_R {t:>10.1}  waste {w:.4}");
    }
}

fn best_period_job_from_args(args: &mut Args) -> anyhow::Result<BestPeriodJob> {
    let strategy: StrategyKind = args.get_str("strategy", "Young").parse()?;
    let policy = args.get_opt::<PolicySpec>("policy")?;
    let reps: u64 = args.get("reps", 10)?;
    let candidates: u64 = args.get("candidates", 16)?;
    let workers = args.get_opt::<u64>("workers")?;
    let prune = args.switch("prune");
    let platform = args.get_opt::<PlatformSpec>("platform")?;
    let scenario = scenario_from_args(args)?;
    Ok(BestPeriodJob { scenario, strategy, reps, candidates, workers, prune, policy, platform })
}

fn cmd_best_period(args: &mut Args) -> anyhow::Result<()> {
    let job = best_period_job_from_args(args)?;
    args.finish()?;
    let res = Executor::local().best_period(&job)?;
    print_best_period(&res);
    Ok(())
}

fn verify_job_from_args(args: &mut Args) -> anyhow::Result<VerifyJob> {
    let grid: ckptfp::verify::GridKind = args.get_str("grid", "quick").parse()?;
    let policy = args.get_opt::<PolicySpec>("policy")?;
    let reps: u64 = args.get("reps", 0)?;
    let budget: u64 = args.get("budget", 0)?;
    let workers = args.get_opt::<u64>("workers")?;
    let platform = args.get_opt::<PlatformSpec>("platform")?;
    Ok(VerifyJob { grid, policy, reps, budget, workers, platform })
}

fn print_verify(report: &ckptfp::verify::VerifyReport) {
    let mut t = Table::new([
        "case", "domain", "analytic", "band", "sim", "ci95", "reps", "verdict",
    ]);
    for c in &report.cases {
        t.row([
            c.name.clone(),
            if c.domain.is_first_order() { "first-order".into() } else { "out-of-domain".into() },
            format!("{:.4}", c.analytic),
            format!("[{:.3}, {:.3}]", c.band.0, c.band.1),
            format!("{:.4}", c.sim_mean),
            format!("{:.4}", c.sim_ci95),
            c.reps.to_string(),
            c.verdict.to_string(),
        ]);
    }
    print!("{t}");
    // Per-case `reps` above and this total are post-escalation spends —
    // what was actually simulated, not the requested budget.
    let consumed: u64 = report.cases.iter().map(|c| c.reps).sum();
    println!(
        "{} grid: {} pass, {} fail, {} inconclusive over {} cases ({} reps consumed, {} workers)",
        report.grid,
        report.n_pass,
        report.n_fail,
        report.n_inconclusive,
        report.cases.len(),
        consumed,
        report.workers,
    );
}

fn cmd_verify(args: &mut Args) -> anyhow::Result<()> {
    let job = verify_job_from_args(args)?;
    let out = args.get_str("out", "CONFORMANCE.json");
    let as_json = args.switch("json");
    args.finish()?;
    let report = Executor::local().verify(&job)?;
    let mut doc = ckptfp::verify::conformance_json(&report).to_string();
    doc.push('\n');
    std::fs::write(&out, doc).with_context(|| format!("writing {out}"))?;
    if as_json {
        println!(
            "{}",
            ckptfp::api::wire::encode_response(
                &ckptfp::api::JobResponse::Verify(report.clone()),
                false
            )
        );
    } else {
        print_verify(&report);
    }
    eprintln!("conformance report written to {out}");
    anyhow::ensure!(
        report.ok(),
        "conformance: {} case(s) FAILED (see {out})",
        report.n_fail
    );
    Ok(())
}

fn cmd_experiment(args: &mut Args) -> anyhow::Result<()> {
    let mut opts = ExpOptions::default();
    opts.reps = args.get("reps", opts.reps)?;
    opts.workers = args.get("workers", opts.workers)?;
    opts.best_period = args.switch("best-period");
    opts.bp_reps = args.get("bp-reps", opts.bp_reps)?;
    opts.bp_candidates = args.get("bp-candidates", opts.bp_candidates)?;
    let out_dir = args.get_str("out", "results");
    let ids: Vec<String> = if args.positional().is_empty() {
        anyhow::bail!("experiment needs an id: {:?} or 'all'", all_experiments());
    } else if args.positional() == ["all"] {
        all_experiments().into_iter().map(String::from).collect()
    } else {
        args.positional().to_vec()
    };
    args.finish()?;
    for id in &ids {
        let started = std::time::Instant::now();
        let result = run_experiment(id, &opts)?;
        print!("{}", result.render());
        result.write_csvs(std::path::Path::new(&out_dir))?;
        eprintln!("[{id}] done in {:.1}s -> {out_dir}/", started.elapsed().as_secs_f64());
    }
    Ok(())
}

/// Parse the `--tenants name=weight,name=weight` flag: per-tenant
/// stride-scheduling weights; unlisted tenants get weight 1.
fn parse_tenant_weights(raw: &str) -> anyhow::Result<Vec<(String, u64)>> {
    let mut weights = Vec::new();
    for part in raw.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (name, w) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--tenants entry '{part}' is not name=weight"))?;
        anyhow::ensure!(
            !name.is_empty() && name.len() <= 64,
            "--tenants name '{name}' must be 1 to 64 bytes"
        );
        let w: u64 =
            w.parse().map_err(|e| anyhow::anyhow!("--tenants weight for '{name}': {e}"))?;
        anyhow::ensure!(w >= 1, "--tenants weight for '{name}' must be at least 1");
        weights.push((name.to_string(), w));
    }
    Ok(weights)
}

fn cmd_serve(args: &mut Args) -> anyhow::Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7471");
    let max_batch: usize = args.get("max-batch", 64)?;
    let max_delay_ms: u64 = args.get("max-delay-ms", 2)?;
    let workers: usize = args.get("workers", ckptfp::coordinator::available_workers())?;
    let reps_default: u64 = args.get("reps-default", 100)?;
    let svc_defaults = ServiceConfig::default();
    let max_conns: usize = args.get("max-conns", svc_defaults.max_conns)?;
    let max_inflight: usize = args.get("max-inflight", svc_defaults.max_inflight)?;
    let queue_depth: usize = args.get("queue-depth", svc_defaults.queue_depth)?;
    let sched_workers: usize = args.get("sched-workers", svc_defaults.sched_workers)?;
    let deadline_ms: u64 = args.get("deadline-ms", 0)?;
    let drain_ms: u64 = args.get("drain-ms", svc_defaults.drain.as_millis() as u64)?;
    let tenant_weights = parse_tenant_weights(&args.get_str("tenants", ""))?;
    args.finish()?;
    let deadline = (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms));
    let exec_cfg = ExecutorConfig { workers, reps_default, deadline, ..Default::default() };
    let executor = match Batcher::spawn_default(BatcherConfig {
        max_batch,
        max_delay: std::time::Duration::from_millis(max_delay_ms),
        eager: max_delay_ms == 0,
        ..Default::default()
    }) {
        Ok(batcher) => {
            println!("plan jobs ride the AOT XLA planner (dynamic batching)");
            Executor::with_batcher(batcher, exec_cfg)
        }
        Err(e) => {
            eprintln!("planner backend unavailable ({e:#}); serving closed-form plans");
            Executor::new(exec_cfg)
        }
    };
    let tenants_desc: Vec<String> =
        tenant_weights.iter().map(|(t, w)| format!("{t}={w}")).collect();
    let handle = serve(
        executor,
        ServiceConfig {
            addr,
            max_conns,
            max_inflight,
            queue_depth,
            sched_workers,
            tenant_weights,
            deadline,
            drain: std::time::Duration::from_millis(drain_ms),
            ..Default::default()
        },
    )?;
    println!("ckptfp job service listening on {}", handle.addr);
    println!("protocol: one JSON object per line (v2; v1 plan dialect accepted) — docs/PROTOCOL.md");
    println!("simulation pool: {workers} workers, default {reps_default} replications");
    println!(
        "guards: {max_conns} connections, {max_inflight} jobs in flight, deadline {}",
        match deadline {
            Some(d) => format!("{} ms", d.as_millis()),
            None => "off".into(),
        }
    );
    if !tenants_desc.is_empty() {
        println!("tenant weights (stride-fair): {}", tenants_desc.join(", "));
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(args: &mut Args) -> anyhow::Result<()> {
    let verb = args
        .positional()
        .first()
        .ok_or_else(|| anyhow::anyhow!("client needs a verb: plan | simulate | best-period | verify | ping | stats"))?
        .clone();
    let addr = args.get_str("addr", "127.0.0.1:7471");
    match verb.as_str() {
        "plan" => {
            let capped = args.switch("capped");
            let policy = args.get_opt::<PolicySpec>("policy")?;
            let scenario = scenario_from_args(args)?;
            args.finish()?;
            let mut client = ServiceClient::connect(&addr)?;
            let capping = if capped { Capping::Capped } else { Capping::Uncapped };
            let out = client.plan(PlanJob { scenario: scenario.clone(), capping, policy })?;
            print_plan(&scenario, &out);
        }
        "simulate" => {
            let job = simulate_job_from_args(args)?;
            args.finish()?;
            let res = ServiceClient::connect(&addr)?.simulate(job)?;
            print_simulate(&res);
        }
        "best-period" => {
            let job = best_period_job_from_args(args)?;
            args.finish()?;
            let res = ServiceClient::connect(&addr)?.best_period(job)?;
            print_best_period(&res);
        }
        "verify" => {
            let job = verify_job_from_args(args)?;
            args.finish()?;
            let report = ServiceClient::connect(&addr)?.verify(job)?;
            print_verify(&report);
            anyhow::ensure!(report.ok(), "conformance: {} case(s) FAILED", report.n_fail);
        }
        "ping" => {
            args.finish()?;
            ServiceClient::connect(&addr)?.ping()?;
            println!("pong from {addr}");
        }
        "stats" => {
            args.finish()?;
            let s = ServiceClient::connect(&addr)?.stats()?;
            println!(
                "requests {} (errors {}) | plan {} simulate {} best_period {} sweep {} verify {}",
                s.requests, s.errors, s.plans, s.simulates, s.best_periods, s.sweeps, s.verifies
            );
            println!(
                "latency p50 {:.4}s p95 {:.4}s p99 {:.4}s over {} samples",
                s.lat_p50_s, s.lat_p95_s, s.lat_p99_s, s.lat_n
            );
            println!(
                "robustness: overloaded {} deadline_exceeded {} panics_contained {} client_retries {}",
                s.rejected_overloaded, s.deadline_exceeded, s.panics_contained, s.client_retries
            );
            if let Some(b) = s.batcher {
                println!(
                    "batcher: {} requests in {} batches (max batch {})",
                    b.requests, b.batches, b.max_batch
                );
            }
            println!(
                "plan cache: {} hits / {} misses ({} entries, {} evictions)",
                s.cache_hits, s.cache_misses, s.cache_entries, s.cache_evictions
            );
            println!(
                "sim lanes: lockstep {} ({} fallbacks) | wide {} ({} evictions)",
                s.batch_lanes_run, s.batch_lane_fallbacks, s.wide_lanes_run, s.wide_evictions
            );
        }
        other => anyhow::bail!("unknown client verb '{other}'"),
    }
    Ok(())
}

fn cmd_loadgen(args: &mut Args) -> anyhow::Result<()> {
    use ckptfp::util::json::Json;
    let defaults = TraceSpec::default();
    let spec = TraceSpec {
        seed: args.get("seed", defaults.seed)?,
        requests: args.get("requests", defaults.requests)?,
        repeat_ratio: args.get("repeat-ratio", defaults.repeat_ratio)?,
        window: args.get("window", defaults.window)?,
        bench_distinct: args.get("bench-distinct", defaults.bench_distinct)?,
        bench_rounds: args.get("bench-rounds", defaults.bench_rounds)?,
        bench_reps: args.get("bench-reps", defaults.bench_reps)?,
        bench_candidates: args.get("bench-candidates", defaults.bench_candidates)?,
        ..defaults
    };
    let out = args.get_str("out", "BENCH_serve.json");
    let addr_flag = args.get_opt::<String>("addr")?;
    args.finish()?;

    // Default: spin the service up in-process (port 0, tenant weights
    // matching the trace) so the harness is self-contained; --addr
    // points it at an already-running service instead.
    let (report, handle) = match addr_flag {
        Some(addr) => (loadgen::run(&addr, &spec)?, None),
        None => {
            let executor = Executor::new(ExecutorConfig::default());
            let handle = serve(
                executor,
                ServiceConfig {
                    addr: "127.0.0.1:0".into(),
                    tenant_weights: spec.tenants.clone(),
                    ..Default::default()
                },
            )?;
            let addr = handle.addr.to_string();
            (loadgen::run(&addr, &spec)?, Some(handle))
        }
    };
    if let Some(h) = handle {
        h.stop();
    }

    println!(
        "trace: {}/{} answered ({} errors, {} overloaded, {} mismatches) in {:.2}s ({:.0} req/s)",
        report.answered,
        report.requests,
        report.errors,
        report.overloaded,
        report.mismatches,
        report.elapsed_s,
        report.trace_per_s,
    );
    for (tenant, n) in &report.per_tenant {
        println!("  tenant {tenant}: {n} answered");
    }
    println!(
        "latency p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms",
        report.p50_ms, report.p95_ms, report.p99_ms
    );
    println!(
        "cache: cold {:.1} req/s, hot {:.1} req/s ({:.1}x, bit-identical: {}) | {} hits / {} misses",
        report.cold_per_s,
        report.hit_per_s,
        report.hit_speedup,
        report.bench_bit_identical,
        report.cache_hits,
        report.cache_misses,
    );

    let doc = Json::obj(vec![
        ("schema", Json::Str("ckptfp-perf-v1".into())),
        (
            "workers_available",
            Json::Num(ckptfp::coordinator::available_workers() as f64),
        ),
        (
            "serve",
            Json::obj(vec![
                ("requests", Json::Num(report.requests as f64)),
                ("answered", Json::Num(report.answered as f64)),
                ("errors", Json::Num(report.errors as f64)),
                ("mismatches", Json::Num(report.mismatches as f64)),
                ("trace_per_s", Json::Num(report.trace_per_s)),
                ("p50_ms", Json::Num(report.p50_ms)),
                ("p95_ms", Json::Num(report.p95_ms)),
                ("p99_ms", Json::Num(report.p99_ms)),
                ("cold_per_s", Json::Num(report.cold_per_s)),
                ("hit_per_s", Json::Num(report.hit_per_s)),
                ("hit_speedup", Json::Num(report.hit_speedup)),
                ("cache_hits", Json::Num(report.cache_hits as f64)),
                ("cache_misses", Json::Num(report.cache_misses as f64)),
            ]),
        ),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(&out, text).with_context(|| format!("writing {out}"))?;
    eprintln!("perf recording written to {out}");

    anyhow::ensure!(
        report.answered == report.requests,
        "exactly-once violated: {}/{} answered",
        report.answered,
        report.requests
    );
    anyhow::ensure!(report.mismatches == 0, "{} repeated requests answered with differing bytes", report.mismatches);
    anyhow::ensure!(report.bench_bit_identical, "cache-hot responses diverged from cold bytes");
    Ok(())
}

fn cmd_trace(args: &mut Args) -> anyhow::Result<()> {
    let out = args.get_str("out", "/dev/stdout");
    let horizon: f64 = args.get("horizon", 1.0e6)?;
    let rep: u64 = args.get("rep", 0)?;
    let s = scenario_from_args(args)?;
    args.finish()?;
    let mut gen = TraceGen::new(&s, s.platform.c, s.seed, rep)?;
    let mut file = std::io::BufWriter::new(std::fs::File::create(&out)?);
    let (nf, np) = ckptfp::trace::io::write_trace(&mut file, &mut gen, horizon)?;
    eprintln!("wrote {nf} faults, {np} predictions to {out}");
    Ok(())
}

fn cmd_config(args: &mut Args) -> anyhow::Result<()> {
    let path = args
        .positional()
        .first()
        .ok_or_else(|| anyhow::anyhow!("config needs a file path"))?
        .clone();
    args.finish()?;
    let table = ckptfp::config::toml::Table::load(std::path::Path::new(&path))?;
    let s = ckptfp::config::toml::scenario_from_table(&table)?;
    println!("{s:#?}");
    println!("platform MTBF: {:.1} mn", s.mu() / MIN);
    if let Some(p) = ckptfp::config::toml::policy_from_table(&table)? {
        let rp = ckptfp::strategies::resolve_policy(&p, &s)?;
        println!("policy: {p} -> {:?}", rp.policy);
    }
    if let Some(p) = ckptfp::config::toml::platform_from_table(&table)? {
        let (c_eff, r_eff) =
            ckptfp::sim::platform::store::effective_costs(&p, s.platform.c, s.platform.r);
        println!("platform: {p} (C_eff {c_eff:.1} s, R_eff {r_eff:.1} s)");
    }
    Ok(())
}
