//! Malformed-wire corpus: hostile request lines against both the
//! decoder (in process) and a live service (over TCP). The contract
//! under test is uniform — every bad line yields a *structured* error
//! in the caller's dialect, and the connection survives to serve the
//! next request. Nothing here panics, hangs, or closes early.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use ckptfp::api::{wire, ErrorCode, Executor, ExecutorConfig, JobRequest, JobResponse};
use ckptfp::coordinator::{serve, ServiceConfig, ServiceHandle};

// ---------------------------------------------------------------------------
// Decoder corpus
// ---------------------------------------------------------------------------

fn decode_err(line: &str) -> ckptfp::api::ApiError {
    wire::decode_request(line).expect_err("hostile line must not decode")
}

#[test]
fn oversized_line_is_rejected_with_the_limit_named() {
    let line = format!(
        "{{\"v\": 2, \"op\": \"ping\", \"pad\": \"{}\"}}",
        "x".repeat(wire::MAX_LINE_BYTES)
    );
    let err = decode_err(&line);
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("exceeds"), "{}", err.message);
    assert!(
        err.message.contains(&wire::MAX_LINE_BYTES.to_string()),
        "the limit must be named: {}",
        err.message
    );
}

#[test]
fn truncated_json_is_invalid_json() {
    for line in ["{\"v\": 2, \"op\":", "{\"v\": 2, \"op\": \"ping\"", "{", "[1, 2", "\"unterminated"] {
        let err = decode_err(line);
        assert_eq!(err.code, ErrorCode::InvalidJson, "{line}");
    }
}

#[test]
fn deep_nesting_is_rejected_not_overflowed() {
    // 10k open brackets: a recursion bomb the parser's depth guard
    // must catch long before the stack does.
    let line = format!("{{\"v\": 2, \"op\": \"plan\", \"scenario\": {}", "[".repeat(10_000));
    let err = decode_err(&line);
    assert_eq!(err.code, ErrorCode::InvalidJson);
    assert!(err.message.contains("nesting"), "{}", err.message);
}

#[test]
fn wrong_typed_fields_are_structured_errors() {
    // A number where the op string belongs.
    let err = decode_err("{\"v\": 2, \"op\": 42}");
    assert_eq!(err.code, ErrorCode::UnknownOp, "{}", err.message);

    // An array where the scenario object belongs.
    let err = decode_err("{\"v\": 2, \"op\": \"plan\", \"scenario\": [1, 2]}");
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("scenario"), "{}", err.message);

    // A scalar at the top level is not a request object at all.
    let err = decode_err("42");
    assert_eq!(err.code, ErrorCode::BadRequest);

    // A future protocol version is refused, not half-parsed.
    let err = decode_err("{\"v\": 3, \"op\": \"ping\"}");
    assert_eq!(err.code, ErrorCode::UnsupportedVersion);
}

// ---------------------------------------------------------------------------
// Live-service corpus: the connection survives every bad line
// ---------------------------------------------------------------------------

struct RawConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl RawConn {
    fn connect(addr: &str) -> RawConn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        RawConn { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    /// Send raw bytes (a trailing newline is appended) and read one
    /// response line.
    fn roundtrip_bytes(&mut self, payload: &[u8]) -> String {
        self.writer.write_all(payload).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut out = String::new();
        self.reader.read_line(&mut out).unwrap();
        assert!(!out.is_empty(), "server closed the connection");
        out.trim_end_matches('\n').to_string()
    }

    fn expect_pong(&mut self) {
        let line = self.roundtrip_bytes(wire::encode_request(&JobRequest::Ping).as_bytes());
        match wire::decode_response(&line).unwrap() {
            JobResponse::Pong => {}
            other => panic!("expected pong, got {other:?}"),
        }
    }
}

fn start_service() -> (ServiceHandle, String) {
    let handle = serve(
        Executor::new(ExecutorConfig::default()),
        ServiceConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

#[test]
fn connection_survives_the_whole_hostile_corpus() {
    let (handle, addr) = start_service();
    let mut conn = RawConn::connect(&addr);

    // Invalid UTF-8: never reaches the decoder, still answered.
    let line = conn.roundtrip_bytes(b"\xff\xfe{\"op\": \"ping\"}");
    match wire::decode_response(&line).unwrap() {
        JobResponse::Error(e) => {
            assert_eq!(e.code, ErrorCode::InvalidJson);
            assert!(e.message.contains("UTF-8"), "{}", e.message);
        }
        other => panic!("expected an error for invalid UTF-8, got {other:?}"),
    }
    conn.expect_pong();

    // Truncated JSON over the wire.
    let line = conn.roundtrip_bytes(b"{\"v\": 2, \"op\":");
    match wire::decode_response(&line).unwrap() {
        JobResponse::Error(e) => assert_eq!(e.code, ErrorCode::InvalidJson),
        other => panic!("expected an error for truncated JSON, got {other:?}"),
    }
    conn.expect_pong();

    // Oversized line: past the wire limit but below the hard cutoff
    // where the service gives up on the connection entirely.
    let big = vec![b'x'; wire::MAX_LINE_BYTES + 10];
    let line = conn.roundtrip_bytes(&big);
    match wire::decode_response(&line).unwrap() {
        JobResponse::Error(e) => {
            assert_eq!(e.code, ErrorCode::BadRequest);
            assert!(e.message.contains("exceeds"), "{}", e.message);
        }
        other => panic!("expected an error for the oversized line, got {other:?}"),
    }
    conn.expect_pong();

    // Wrong-typed op, this time in the legacy dialect: the error comes
    // back in the legacy shape (no "v" marker).
    let line = conn.roundtrip_bytes(b"{\"op\": 42}");
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(!line.contains("\"v\":"), "legacy dialect must not carry 'v': {line}");
    conn.expect_pong();

    // The error tally reflects the corpus.
    let line = conn.roundtrip_bytes(wire::encode_request(&JobRequest::Stats).as_bytes());
    match wire::decode_response(&line).unwrap() {
        JobResponse::Stats(s) => assert!(s.errors >= 4, "stats: {s:?}"),
        other => panic!("expected stats, got {other:?}"),
    }

    drop(conn);
    handle.stop();
}
