//! The AOT runtime: loads the JAX/Pallas-compiled planner artifacts
//! (HLO text) and executes them on the PJRT CPU client.
//!
//! Python never runs here — `make artifacts` produced the HLO once at
//! build time; this module is the only bridge between the Rust
//! coordinator and the compiled L1/L2 stack.

mod artifact;
mod client;
mod planner_exec;

pub use artifact::{ArtifactSpec, Manifest};
pub use client::Runtime;
pub use planner_exec::{HloPlanner, PlanOutput, SurfaceOutput};

/// Locate the artifacts directory: `$CKPTFP_ARTIFACTS`, else
/// `./artifacts`, else walking up from the current directory (so tests
/// and examples work from any workspace subdirectory).
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("CKPTFP_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        return p.is_dir().then_some(p);
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let candidate = cur.join("artifacts");
        if candidate.join("manifest.txt").is_file() {
            return Some(candidate);
        }
        if !cur.pop() {
            return None;
        }
    }
}
