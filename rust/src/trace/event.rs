//! Trace event types.

/// An actual failure of the platform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    /// Strike time (s).
    pub t: f64,
    /// Stable identifier (links true predictions to their fault).
    pub id: u64,
    /// Whether the predictor caught this fault (drawn with prob. r).
    pub predicted: bool,
}

impl Fault {
    pub fn unpredicted(t: f64, id: u64) -> Fault {
        Fault { t, id, predicted: false }
    }

    pub fn predicted(t: f64, id: u64) -> Fault {
        Fault { t, id, predicted: true }
    }
}

/// A prediction emitted by the fault predictor.
///
/// Exact-date predictions have `window == 0` and `t0` equal to the
/// predicted strike time; window predictions cover `[t0, t0 + window]`.
/// The predictor announces the event at `avail <= t0 - lead`, where the
/// lead leaves room for one proactive checkpoint (§3: "at least C
/// seconds in advance").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    /// When the prediction becomes known.
    pub avail: f64,
    /// Predicted date (exact) or window start.
    pub t0: f64,
    /// Window length I (0 = exact).
    pub window: f64,
    /// Id of the true fault this predicts; `None` for false positives.
    pub fault_id: Option<u64>,
}

impl Prediction {
    pub fn exact(t0: f64, lead: f64, fault_id: Option<u64>) -> Prediction {
        Prediction { avail: t0 - lead, t0, window: 0.0, fault_id }
    }

    pub fn windowed(t0: f64, window: f64, lead: f64, fault_id: Option<u64>) -> Prediction {
        Prediction { avail: t0 - lead, t0, window, fault_id }
    }

    pub fn is_true_positive(&self) -> bool {
        self.fault_id.is_some()
    }

    /// Window end (== t0 for exact predictions).
    pub fn t_end(&self) -> f64 {
        self.t0 + self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let p = Prediction::exact(1000.0, 600.0, Some(3));
        assert_eq!(p.avail, 400.0);
        assert_eq!(p.t_end(), 1000.0);
        assert!(p.is_true_positive());

        let w = Prediction::windowed(1000.0, 300.0, 600.0, None);
        assert_eq!(w.t_end(), 1300.0);
        assert!(!w.is_true_positive());
    }
}
