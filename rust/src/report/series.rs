//! Figure data: named series of (x, y) points, one figure per paper plot.

/// One curve of a figure.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Series {
        Series { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// A full figure: multiple series over a shared x axis.
#[derive(Debug, Clone)]
pub struct FigureData {
    pub name: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl FigureData {
    pub fn new(name: impl Into<String>, x_label: impl Into<String>, y_label: impl Into<String>) -> FigureData {
        FigureData {
            name: name.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
        }
    }

    pub fn series_mut(&mut self, label: &str) -> &mut Series {
        if let Some(i) = self.series.iter().position(|s| s.label == label) {
            &mut self.series[i]
        } else {
            self.series.push(Series::new(label));
            self.series.last_mut().unwrap()
        }
    }

    pub fn get(&self, label: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.label == label)
    }

    /// Terminal rendering: a compact value grid (x down, series across).
    pub fn render(&self) -> String {
        let mut t = crate::report::Table::new(
            std::iter::once(self.x_label.clone()).chain(self.series.iter().map(|s| s.label.clone())),
        );
        let mut xs: Vec<f64> = self.series.iter().flat_map(|s| s.points.iter().map(|p| p.0)).collect();
        xs.sort_by(f64::total_cmp);
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        for x in xs {
            let mut cells = vec![format_x(x)];
            for s in &self.series {
                let y = s
                    .points
                    .iter()
                    .find(|p| (p.0 - x).abs() < 1e-9)
                    .map(|p| format!("{:.4}", p.1))
                    .unwrap_or_else(|| "-".into());
                cells.push(y);
            }
            t.row(cells);
        }
        format!("# {} ({} vs {})\n{}", self.name, self.y_label, self.x_label, t.render())
    }
}

fn format_x(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e12 {
        let n = x as i64;
        // Annotate powers of two (the N axis of the paper's figures).
        if n > 0 && (n & (n - 1)) == 0 {
            return format!("{n} (2^{})", n.trailing_zeros());
        }
        format!("{n}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_accumulate() {
        let mut f = FigureData::new("fig4a", "N", "waste");
        f.series_mut("Young").push(16384.0, 0.3);
        f.series_mut("Young").push(32768.0, 0.4);
        f.series_mut("Exact").push(16384.0, 0.2);
        assert_eq!(f.series.len(), 2);
        assert_eq!(f.get("Young").unwrap().points.len(), 2);
        let s = f.render();
        assert!(s.contains("16384 (2^14)"));
        assert!(s.contains("0.3000"));
        assert!(s.contains('-')); // missing Exact at 32768
    }
}
