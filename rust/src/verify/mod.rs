//! The conformance subsystem: the paper's "analysis corroborated by
//! simulation" claim as an executable, statistically-sound test layer.
//!
//! Three pieces (see DESIGN.md §5):
//!
//! * [`grid`] — the scenario-grid generator: the paper's parameter
//!   space (platform sizes, C/D/R, Exponential and Weibull laws, the
//!   recall×precision grid, exact vs window predictions, all five
//!   strategies plus the `adaptive`/`risk` policies) enumerated as
//!   named, seeded [`ConformanceCase`]s;
//! * [`oracle`] — the analytic adapter: evaluates the
//!   `model::{waste, optimal, window}` first-order predictions for a
//!   case and states their validity domain, so out-of-domain cases
//!   assert divergence *bounds* rather than agreement;
//! * [`compare`] — the statistical comparator: CI-aware
//!   pass / fail / inconclusive verdicts over the parallel replication
//!   runner, with automatic replication escalation up to a budget.
//!
//! [`run_conformance`] strings them together into a [`VerifyReport`];
//! [`conformance_json`] renders the machine-readable `CONFORMANCE.json`
//! CI consumes. The report also travels the wire as the v2 `verify`
//! job ([`crate::api::VerifyJob`]), reachable through the CLI
//! (`ckptfp verify --grid quick`), the TCP service and the
//! `conformance` experiment.
//!
//! The module grew out of (and absorbed) the old top-level `testkit`
//! property harness, which lives on as [`testkit`] — re-exported at
//! the crate root so `ckptfp::testkit::check` keeps working.

pub mod compare;
pub mod grid;
pub mod oracle;
pub mod testkit;

pub use compare::{judge_case, CaseVerdict, Verdict, VerifyOptions};
pub use grid::{conformance_grid, ConformanceCase, GridKind};
pub use oracle::{oracle_for, Domain, Oracle, FIRST_ORDER_RATIO_CAP};

use crate::sim::PlatformSpec;
use crate::strategies::PolicySpec;
use crate::util::json::Json;

/// Schema tag of the `CONFORMANCE.json` report.
pub const CONFORMANCE_SCHEMA: &str = "ckptfp-conformance-v1";

/// The judged conformance grid — the payload of `CONFORMANCE.json` and
/// of the wire-level `verify` response.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyReport {
    pub grid: GridKind,
    /// Pool width the verdicts were computed with (they are
    /// bit-reproducible only for a fixed width, so the report echoes it).
    pub workers: u64,
    pub n_pass: u64,
    pub n_fail: u64,
    pub n_inconclusive: u64,
    pub cases: Vec<CaseVerdict>,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.n_fail == 0
    }
}

/// Run the conformance grid. `filter` restricts to cases whose subject
/// equals the given policy spec (the CLI `--policy` flag).
pub fn run_conformance(
    grid: GridKind,
    filter: Option<&PolicySpec>,
    opts: &VerifyOptions,
) -> anyhow::Result<VerifyReport> {
    run_conformance_filtered(grid, filter, None, opts)
}

/// [`run_conformance`] with an additional platform filter: when
/// `platform` is given, only cases pinned to exactly that
/// [`PlatformSpec`] are judged (the CLI `--platform` flag and the wire
/// v2 `verify` field). Both filters compose; an empty selection is an
/// error, not a vacuous pass.
pub fn run_conformance_filtered(
    grid: GridKind,
    policy: Option<&PolicySpec>,
    platform: Option<&PlatformSpec>,
    opts: &VerifyOptions,
) -> anyhow::Result<VerifyReport> {
    let mut cases = conformance_grid(grid);
    if let Some(f) = policy {
        cases.retain(|c| c.subject == *f);
        anyhow::ensure!(
            !cases.is_empty(),
            "no conformance case in the {grid} grid has subject policy '{f}'"
        );
    }
    if let Some(p) = platform {
        cases.retain(|c| c.platform == *p);
        anyhow::ensure!(
            !cases.is_empty(),
            "no conformance case in the {grid} grid runs on platform '{p}'"
        );
    }
    let mut out = Vec::with_capacity(cases.len());
    let (mut n_pass, mut n_fail, mut n_inconclusive) = (0u64, 0u64, 0u64);
    for case in &cases {
        let v = judge_case(case, opts)?;
        match v.verdict {
            Verdict::Pass => n_pass += 1,
            Verdict::Fail => n_fail += 1,
            Verdict::Inconclusive => n_inconclusive += 1,
        }
        out.push(v);
    }
    Ok(VerifyReport {
        grid,
        workers: opts.workers as u64,
        n_pass,
        n_fail,
        n_inconclusive,
        cases: out,
    })
}

// ---------------------------------------------------------------------------
// JSON encoding — shared by CONFORMANCE.json and the wire response
// ---------------------------------------------------------------------------

fn case_to_json(c: &CaseVerdict) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", Json::Str(c.name.clone())),
        ("policy", Json::Str(c.policy.clone())),
        ("analytic", Json::Num(c.analytic)),
        ("band_lo", Json::Num(c.band.0)),
        ("band_hi", Json::Num(c.band.1)),
        ("sim_mean", Json::Num(c.sim_mean)),
        ("sim_ci95", Json::Num(c.sim_ci95)),
        ("completion_rate", Json::Num(c.completion_rate)),
        ("reps", Json::Num(c.reps as f64)),
        ("verdict", Json::Str(c.verdict.name().into())),
    ];
    match &c.domain {
        Domain::FirstOrder => fields.push(("domain", Json::Str("first_order".into()))),
        Domain::OutOfDomain { reason } => {
            fields.push(("domain", Json::Str("out_of_domain".into())));
            fields.push(("domain_reason", Json::Str(reason.clone())));
        }
    }
    Json::obj(fields)
}

fn case_from_json(v: &Json) -> anyhow::Result<CaseVerdict> {
    let str_field = |key: &str| -> anyhow::Result<String> {
        v.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("conformance case missing '{key}'"))
    };
    let domain = match str_field("domain")?.as_str() {
        "first_order" => Domain::FirstOrder,
        "out_of_domain" => Domain::OutOfDomain {
            reason: v
                .get("domain_reason")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        },
        other => anyhow::bail!("unknown conformance domain '{other}'"),
    };
    Ok(CaseVerdict {
        name: str_field("name")?,
        policy: str_field("policy")?,
        domain,
        analytic: v.num_or("analytic", f64::NAN),
        band: (v.num_or("band_lo", f64::NAN), v.num_or("band_hi", f64::NAN)),
        sim_mean: v.num_or("sim_mean", f64::NAN),
        sim_ci95: v.num_or("sim_ci95", f64::NAN),
        completion_rate: v.num_or("completion_rate", f64::NAN),
        reps: v.num_or("reps", 0.0) as u64,
        verdict: Verdict::parse(&str_field("verdict")?)?,
    })
}

/// The report's fields, ready to splice into a JSON object (the wire
/// layer adds its own envelope around these).
pub fn report_fields(r: &VerifyReport) -> Vec<(&'static str, Json)> {
    vec![
        ("grid", Json::Str(r.grid.name().into())),
        ("workers", Json::Num(r.workers as f64)),
        ("n_pass", Json::Num(r.n_pass as f64)),
        ("n_fail", Json::Num(r.n_fail as f64)),
        ("n_inconclusive", Json::Num(r.n_inconclusive as f64)),
        ("cases", Json::Arr(r.cases.iter().map(case_to_json).collect())),
    ]
}

/// Inverse of [`report_fields`] — also reads `CONFORMANCE.json`.
pub fn report_from_json(v: &Json) -> anyhow::Result<VerifyReport> {
    let grid = v
        .get("grid")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("conformance report missing 'grid'"))?
        .parse::<GridKind>()?;
    let cases = match v.get("cases") {
        Some(Json::Arr(xs)) => xs.iter().map(case_from_json).collect::<anyhow::Result<Vec<_>>>()?,
        _ => anyhow::bail!("conformance report missing 'cases' array"),
    };
    Ok(VerifyReport {
        grid,
        workers: v.num_or("workers", 0.0) as u64,
        n_pass: v.num_or("n_pass", 0.0) as u64,
        n_fail: v.num_or("n_fail", 0.0) as u64,
        n_inconclusive: v.num_or("n_inconclusive", 0.0) as u64,
        cases,
    })
}

/// The full `CONFORMANCE.json` document (report plus schema tag).
pub fn conformance_json(r: &VerifyReport) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![("schema", Json::Str(CONFORMANCE_SCHEMA.into()))];
    fields.extend(report_fields(r));
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::StrategyKind;

    fn sample_report() -> VerifyReport {
        VerifyReport {
            grid: GridKind::Quick,
            workers: 4,
            n_pass: 1,
            n_fail: 0,
            n_inconclusive: 1,
            cases: vec![
                CaseVerdict {
                    name: "exp-n16-none-Young".into(),
                    policy: "Young".into(),
                    domain: Domain::FirstOrder,
                    analytic: 0.117,
                    band: (0.097, 0.137),
                    sim_mean: 0.1175,
                    sim_ci95: 0.004,
                    completion_rate: 1.0,
                    reps: 48,
                    verdict: Verdict::Pass,
                },
                CaseVerdict {
                    name: "weibull:0.5-n16-none-Young".into(),
                    policy: "Young".into(),
                    domain: Domain::OutOfDomain { reason: "weibull:0.5 faults".into() },
                    analytic: 0.117,
                    band: (0.03, 0.47),
                    sim_mean: 0.46,
                    sim_ci95: 0.02,
                    completion_rate: 1.0,
                    reps: 384,
                    verdict: Verdict::Inconclusive,
                },
            ],
        }
    }

    #[test]
    fn report_json_round_trips() {
        let r = sample_report();
        let doc = conformance_json(&r);
        let parsed = crate::util::json::parse(&doc.to_string()).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(Json::as_str),
            Some(CONFORMANCE_SCHEMA)
        );
        let back = report_from_json(&parsed).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn run_conformance_filters_by_policy() {
        // Filtered, tiny-budget run: only the risk:1 cases execute.
        let opts = VerifyOptions { reps0: 2, budget: 2, workers: 2, ..Default::default() };
        let spec = PolicySpec::RiskThreshold { kappa: 1.0 };
        let r = run_conformance(GridKind::Quick, Some(&spec), &opts).unwrap();
        assert!(!r.cases.is_empty());
        assert!(r.cases.iter().all(|c| c.policy == "risk:1"));
        assert_eq!(r.n_pass + r.n_fail + r.n_inconclusive, r.cases.len() as u64);
        // A policy with no grid presence is an error, not an empty pass.
        let missing = PolicySpec::AdaptivePeriod { gain: 9.0 };
        assert!(run_conformance(GridKind::Quick, Some(&missing), &opts).is_err());
        // Strategy filters work too.
        let young = PolicySpec::Strategy(StrategyKind::Young);
        let r = run_conformance(GridKind::Quick, Some(&young), &opts).unwrap();
        assert!(r.cases.len() >= 4, "Young appears across laws and tweaks");
    }

    #[test]
    fn run_conformance_filters_by_platform() {
        let opts = VerifyOptions { reps0: 2, budget: 2, workers: 2, ..Default::default() };
        let p: PlatformSpec = "nodes=4".parse().unwrap();
        let r = run_conformance_filtered(GridKind::Quick, None, Some(&p), &opts).unwrap();
        assert!(!r.cases.is_empty());
        assert!(r.cases.iter().all(|c| c.name.ends_with("@nodes=4")), "{:?}", r.cases);
        // A platform absent from the grid is an error, not an empty pass.
        let missing: PlatformSpec = "nodes=77".parse().unwrap();
        assert!(run_conformance_filtered(GridKind::Quick, None, Some(&missing), &opts).is_err());
    }

    #[test]
    fn report_ok_tracks_failures() {
        let mut r = sample_report();
        assert!(r.ok());
        r.n_fail = 1;
        assert!(!r.ok());
    }
}
