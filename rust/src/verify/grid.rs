//! The conformance scenario grid: the paper's parameter space as
//! named, seeded [`ConformanceCase`]s.
//!
//! Each case pairs one [`Scenario`] with one subject [`PolicySpec`]
//! (a paper strategy or one of the non-paper policies). Names are
//! stable identifiers of the form `<law>-<platform>-<predictor>-<subject>`;
//! the scenario seed is derived from the name (FNV-1a), so inserting a
//! case never reshuffles another case's traces.
//!
//! Two grids: [`GridKind::Quick`] is the CI gate (~20 cases covering
//! every strategy, both failure laws, the recall×precision corners and
//! one deliberately out-of-domain regime case); [`GridKind::Full`] is
//! the quick grid plus the platform-size sweep, the Zheng predictor on
//! every window strategy, C/D/R variations, precision/recall extremes
//! and the policy-parameter variants.

use crate::config::{Predictor, Scenario};
use crate::dist::DistSpec;
use crate::model::StrategyKind;
use crate::sim::{PlatformSpec, RestartScope};
use crate::strategies::PolicySpec;

/// Which conformance grid to enumerate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridKind {
    /// The CI gate: every strategy and law once, ~20 cases.
    Quick,
    /// The quick grid plus platform sweep, predictor grid and
    /// parameter variants.
    Full,
}

impl GridKind {
    pub fn name(&self) -> &'static str {
        match self {
            GridKind::Quick => "quick",
            GridKind::Full => "full",
        }
    }

    /// Default (base replications, escalation budget) per case.
    pub fn default_budget(&self) -> (u64, u64) {
        match self {
            GridKind::Quick => (48, 384),
            GridKind::Full => (96, 768),
        }
    }
}

impl std::fmt::Display for GridKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for GridKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<GridKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "quick" => Ok(GridKind::Quick),
            "full" => Ok(GridKind::Full),
            other => anyhow::bail!("unknown conformance grid '{other}' (expected quick | full)"),
        }
    }
}

/// One point of the conformance grid: a scenario and the policy whose
/// simulated waste is checked against the analytic oracle.
#[derive(Debug, Clone, PartialEq)]
pub struct ConformanceCase {
    /// Stable identifier, e.g. `exp-n16-yu:exact-ExactPrediction`;
    /// platform cases carry an `@<platform>` suffix.
    pub name: String,
    pub scenario: Scenario,
    pub subject: PolicySpec,
    /// The simulated platform; `single` for the classic engine cases.
    /// Uncorrelated multi-node platforms keep the aggregate MTBF at the
    /// scenario's `mu` (Poisson superposition), so the oracle's closed
    /// form applies unchanged; correlated or store-contended specs are
    /// judged out-of-domain with divergence bounds.
    pub platform: PlatformSpec,
}

/// FNV-1a over the case name — a stable per-case master seed, so the
/// grid can grow without perturbing existing cases' traces.
fn case_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Predictor shorthand for case names.
#[derive(Clone, Copy)]
enum Pred {
    None,
    YuExact,
    ZhengExact,
    Yu(f64),
    Zheng(f64),
    Custom(&'static str, f64, f64, f64),
}

impl Pred {
    fn label(&self) -> String {
        match self {
            Pred::None => "none".into(),
            Pred::YuExact => "yu:exact".into(),
            Pred::ZhengExact => "zheng:exact".into(),
            Pred::Yu(i) => format!("yu:I{i}"),
            Pred::Zheng(i) => format!("zheng:I{i}"),
            Pred::Custom(tag, _, _, _) => (*tag).into(),
        }
    }

    fn build(&self) -> Predictor {
        match *self {
            Pred::None => Predictor::none(),
            Pred::YuExact => Predictor::exact(0.85, 0.82),
            Pred::ZhengExact => Predictor::exact(0.7, 0.4),
            Pred::Yu(i) => Predictor::windowed(0.85, 0.82, i),
            Pred::Zheng(i) => Predictor::windowed(0.7, 0.4, i),
            Pred::Custom(_, r, p, i) => {
                if i > 0.0 {
                    Predictor::windowed(r, p, i)
                } else {
                    Predictor::exact(r, p)
                }
            }
        }
    }
}

/// A mutation applied to the base paper scenario of a case.
#[derive(Clone, Copy)]
enum Tweak {
    /// No change beyond the case defaults.
    None,
    /// Checkpoint duration C (s).
    C(f64),
    /// Downtime D (s).
    D(f64),
    /// Recovery R (s).
    R(f64),
    /// Direct platform-MTBF override (s) — the deliberate T ~ mu case.
    Mu(f64),
    /// Uniform false-prediction inter-arrival law (Figures 5/7).
    UniformFalse,
}

impl Tweak {
    fn label(&self) -> Option<String> {
        match self {
            Tweak::None => None,
            Tweak::C(c) => Some(format!("C{c}")),
            Tweak::D(d) => Some(format!("D{d}")),
            Tweak::R(r) => Some(format!("R{r}")),
            Tweak::Mu(m) => Some(format!("mu{m}")),
            Tweak::UniformFalse => Some("ufalse".into()),
        }
    }
}

struct GridBuilder {
    cases: Vec<ConformanceCase>,
}

impl GridBuilder {
    fn push(&mut self, dist: DistSpec, n_exp: u32, pred: Pred, tweak: Tweak, subject: PolicySpec) {
        self.push_on(dist, n_exp, pred, tweak, subject, PlatformSpec::default());
    }

    /// Push a case simulated on `platform`; non-`single` specs suffix
    /// the name with `@<platform>` (part of the seed derivation, so a
    /// platform case and its classic twin replay different traces).
    fn push_on(
        &mut self,
        dist: DistSpec,
        n_exp: u32,
        pred: Pred,
        tweak: Tweak,
        subject: PolicySpec,
        platform: PlatformSpec,
    ) {
        let mut name = format!("{dist}-n{n_exp}-{}", pred.label());
        if let Some(t) = tweak.label() {
            name.push('-');
            name.push_str(&t);
        }
        name.push('-');
        name.push_str(&subject.to_string());
        if !platform.is_single() {
            name.push('@');
            name.push_str(&platform.to_string());
        }

        let mut s = Scenario::paper(1u64 << n_exp, pred.build());
        s.fault_dist = dist;
        match tweak {
            Tweak::None => {}
            Tweak::C(c) => s.platform.c = c,
            Tweak::D(d) => s.platform.d = d,
            Tweak::R(r) => s.platform.r = r,
            Tweak::Mu(mu) => s.platform.mu_ind = mu * s.platform.n_procs as f64,
            Tweak::UniformFalse => s.false_pred_dist = Some(DistSpec::Uniform),
        }
        // Enough work for O(10..100) faults per replication without
        // making a single replication expensive: ~10 platform MTBFs,
        // floored so large-mu platforms still see events.
        s.work = (10.0 * s.mu()).max(4.0e5);
        s.seed = case_seed(&name);
        self.cases.push(ConformanceCase { name, scenario: s, subject, platform });
    }
}

/// Enumerate the conformance grid, in a stable order.
pub fn conformance_grid(kind: GridKind) -> Vec<ConformanceCase> {
    use StrategyKind::*;
    let strat = PolicySpec::Strategy;
    let mut b = GridBuilder { cases: Vec::new() };
    let exp = DistSpec::Exp;
    let w07 = DistSpec::weibull(0.7);
    let w05 = DistSpec::weibull(0.5);

    // --- In-domain: Exponential faults, first-order regime ----------
    b.push(exp, 16, Pred::None, Tweak::None, strat(Young));
    b.push(exp, 16, Pred::YuExact, Tweak::None, strat(Young)); // predictions ignored
    b.push(exp, 16, Pred::YuExact, Tweak::None, strat(ExactPrediction));
    b.push(exp, 16, Pred::ZhengExact, Tweak::None, strat(ExactPrediction));
    b.push(exp, 16, Pred::Yu(300.0), Tweak::None, strat(Instant));
    b.push(exp, 16, Pred::Yu(300.0), Tweak::None, strat(NoCkptI));
    b.push(exp, 16, Pred::Yu(3000.0), Tweak::None, strat(NoCkptI));
    b.push(exp, 16, Pred::Yu(3000.0), Tweak::None, strat(WithCkptI));
    b.push(exp, 16, Pred::YuExact, Tweak::None, strat(Migration));
    b.push(exp, 18, Pred::None, Tweak::None, strat(Young));
    // n = 2^18 pushes ExactPrediction's T_R past the first-order cap:
    // the oracle must classify it out-of-domain automatically.
    b.push(exp, 18, Pred::YuExact, Tweak::None, strat(ExactPrediction));
    b.push(exp, 16, Pred::None, Tweak::C(300.0), strat(Young));
    b.push(exp, 16, Pred::None, Tweak::C(1200.0), strat(Young));

    // --- Out-of-domain: the deliberate T ~ mu regime case -----------
    b.push(exp, 16, Pred::None, Tweak::Mu(4000.0), strat(Young));

    // --- Out-of-domain: Weibull failure laws -------------------------
    b.push(w07, 16, Pred::None, Tweak::None, strat(Young));
    b.push(w07, 16, Pred::YuExact, Tweak::None, strat(ExactPrediction));
    b.push(w05, 16, Pred::None, Tweak::None, strat(Young));
    b.push(w05, 16, Pred::YuExact, Tweak::None, strat(ExactPrediction));

    // --- Out-of-domain: the non-paper policies -----------------------
    b.push(exp, 16, Pred::None, Tweak::None, PolicySpec::AdaptivePeriod { gain: 1.0 });
    b.push(exp, 16, Pred::None, Tweak::None, PolicySpec::RiskThreshold { kappa: 1.0 });
    b.push(exp, 16, Pred::YuExact, Tweak::None, PolicySpec::RiskThreshold { kappa: 1.0 });

    // --- Platform cases: the multi-node engine against the closed form.
    // Uncorrelated exponential at nodes=4: Poisson superposition keeps
    // the aggregate MTBF at mu, so the oracle's first-order band
    // applies unchanged — the N-node acceptance criterion.
    b.push_on(exp, 16, Pred::None, Tweak::None, strat(Young), PlatformSpec {
        nodes: 4,
        ..PlatformSpec::default()
    });
    b.push_on(exp, 16, Pred::YuExact, Tweak::None, strat(ExactPrediction), PlatformSpec {
        nodes: 4,
        ..PlatformSpec::default()
    });
    // Correlated failure groups: out of the closed form's domain, the
    // oracle asserts divergence bounds only.
    b.push_on(exp, 16, Pred::None, Tweak::None, strat(Young), PlatformSpec {
        nodes: 8,
        group: 4,
        spatial: 0.25,
        cascade: 0.1,
        ..PlatformSpec::default()
    });

    if kind == GridKind::Quick {
        return b.cases;
    }

    // --- Full grid: platform-size sweep ------------------------------
    for n in [14u32, 17, 19] {
        b.push(exp, n, Pred::None, Tweak::None, strat(Young));
        b.push(exp, n, Pred::YuExact, Tweak::None, strat(ExactPrediction));
    }
    // Zheng predictor over the window strategies (recall×precision grid).
    b.push(exp, 16, Pred::Zheng(300.0), Tweak::None, strat(Instant));
    b.push(exp, 16, Pred::Zheng(300.0), Tweak::None, strat(NoCkptI));
    b.push(exp, 16, Pred::Zheng(3000.0), Tweak::None, strat(NoCkptI));
    b.push(exp, 16, Pred::Zheng(3000.0), Tweak::None, strat(WithCkptI));
    b.push(exp, 16, Pred::Yu(3000.0), Tweak::None, strat(Instant));
    b.push(exp, 16, Pred::ZhengExact, Tweak::None, strat(Migration));
    // Distinct false-prediction law (Figures 5/7 setting).
    b.push(exp, 16, Pred::ZhengExact, Tweak::UniformFalse, strat(ExactPrediction));
    // D/R variations.
    b.push(exp, 16, Pred::None, Tweak::D(0.0), strat(Young));
    b.push(exp, 16, Pred::None, Tweak::R(60.0), strat(Young));
    // Precision/recall extremes.
    b.push(exp, 16, Pred::Custom("r30p90", 0.3, 0.9, 0.0), Tweak::None, strat(ExactPrediction));
    b.push(exp, 16, Pred::Custom("r85p100", 0.85, 1.0, 0.0), Tweak::None, strat(ExactPrediction));
    // Weibull window strategies + a second platform size.
    b.push(w07, 16, Pred::Yu(300.0), Tweak::None, strat(Instant));
    b.push(w07, 16, Pred::Yu(300.0), Tweak::None, strat(NoCkptI));
    b.push(w07, 18, Pred::None, Tweak::None, strat(Young));
    // Policy-parameter variants.
    b.push(exp, 16, Pred::None, Tweak::None, PolicySpec::AdaptivePeriod { gain: 0.5 });
    b.push(exp, 16, Pred::None, Tweak::None, PolicySpec::AdaptivePeriod { gain: 2.0 });
    b.push(exp, 16, Pred::None, Tweak::None, PolicySpec::RiskThreshold { kappa: 0.5 });
    b.push(exp, 16, Pred::None, Tweak::None, PolicySpec::RiskThreshold { kappa: 2.0 });

    // --- Full grid: platform sweep and coordination variants ---------
    // Larger uncorrelated platforms: superposition must hold at every K.
    b.push_on(exp, 16, Pred::None, Tweak::None, strat(Young), PlatformSpec {
        nodes: 16,
        ..PlatformSpec::default()
    });
    b.push_on(exp, 16, Pred::Yu(300.0), Tweak::None, strat(NoCkptI), PlatformSpec {
        nodes: 4,
        ..PlatformSpec::default()
    });
    // Store contention on commits: out of domain (C_eff != C).
    b.push_on(exp, 16, Pred::None, Tweak::None, strat(Young), PlatformSpec {
        nodes: 8,
        commit: 0.1,
        ..PlatformSpec::default()
    });
    // Partial restart under correlated groups.
    b.push_on(exp, 16, Pred::None, Tweak::None, strat(Young), PlatformSpec {
        nodes: 8,
        restart: RestartScope::Partial,
        group: 4,
        spatial: 0.25,
        ..PlatformSpec::default()
    });

    b.cases
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategies::resolve_policy;

    #[test]
    fn grid_kind_round_trips() {
        for kind in [GridKind::Quick, GridKind::Full] {
            assert_eq!(kind.name().parse::<GridKind>().unwrap(), kind);
        }
        assert_eq!("QUICK".parse::<GridKind>().unwrap(), GridKind::Quick);
        assert!("medium".parse::<GridKind>().is_err());
    }

    #[test]
    fn grids_are_stable_and_named_uniquely() {
        for kind in [GridKind::Quick, GridKind::Full] {
            let a = conformance_grid(kind);
            let b = conformance_grid(kind);
            assert_eq!(a, b, "{kind} grid must be deterministic");
            let mut names = std::collections::HashSet::new();
            for c in &a {
                assert!(names.insert(c.name.clone()), "duplicate case name {}", c.name);
            }
        }
    }

    #[test]
    fn quick_is_a_prefix_of_full() {
        let quick = conformance_grid(GridKind::Quick);
        let full = conformance_grid(GridKind::Full);
        assert!(full.len() > quick.len());
        assert_eq!(&full[..quick.len()], &quick[..]);
    }

    #[test]
    fn every_case_resolves_and_validates() {
        for case in conformance_grid(GridKind::Full) {
            case.scenario.validate().unwrap_or_else(|e| panic!("{}: {e:#}", case.name));
            resolve_policy(&case.subject, &case.scenario)
                .unwrap_or_else(|e| panic!("{}: {e:#}", case.name));
        }
    }

    #[test]
    fn quick_covers_the_strategy_space() {
        let quick = conformance_grid(GridKind::Quick);
        for kind in crate::model::StrategyKind::ALL {
            assert!(
                quick.iter().any(|c| c.subject == PolicySpec::Strategy(kind)),
                "quick grid misses {kind}"
            );
        }
        assert!(quick.iter().any(|c| matches!(c.subject, PolicySpec::AdaptivePeriod { .. })));
        assert!(quick.iter().any(|c| matches!(c.subject, PolicySpec::RiskThreshold { .. })));
        assert!(quick.iter().any(|c| c.scenario.fault_dist != DistSpec::Exp));
    }

    #[test]
    fn quick_includes_platform_cases() {
        // The N-node acceptance criterion needs an uncorrelated
        // multi-node case in the CI gate, plus one correlated case for
        // the divergence-bound side; every platform spec must validate.
        let quick = conformance_grid(GridKind::Quick);
        assert!(quick.iter().any(|c| c.platform.nodes > 1 && !c.platform.correlated()));
        assert!(quick.iter().any(|c| c.platform.correlated()));
        for c in conformance_grid(GridKind::Full) {
            c.platform.validate().unwrap_or_else(|e| panic!("{}: {e:#}", c.name));
            if !c.platform.is_single() {
                assert!(c.name.contains('@'), "platform case {} must carry the suffix", c.name);
            }
        }
    }

    #[test]
    fn seeds_derive_from_names() {
        let quick = conformance_grid(GridKind::Quick);
        assert_eq!(quick[0].scenario.seed, case_seed(&quick[0].name));
    }

    #[test]
    fn no_seed_collisions_across_the_full_grid() {
        // Names are the FNV-1a seed source; an FNV collision between
        // two case names would silently correlate their traces. Check
        // the FULL grid (the quick grid is a prefix), name by name.
        let full = conformance_grid(GridKind::Full);
        let mut seen: std::collections::HashMap<u64, &str> = std::collections::HashMap::new();
        for c in &full {
            assert_eq!(c.scenario.seed, case_seed(&c.name), "{}", c.name);
            if let Some(prev) = seen.insert(c.scenario.seed, &c.name) {
                panic!("seed collision: '{}' and '{}' share seed {}", prev, c.name, c.scenario.seed);
            }
        }
    }
}
