//! Configuration types shared by the planner, the simulator and the
//! experiment harness.
//!
//! [`Scenario`] and [`Predictor`] are fully typed — the failure law is
//! a [`DistSpec`], not a string — and both come with builders
//! ([`Scenario::builder`], [`Predictor::builder`]) so callers outside
//! the paper presets can assemble valid configurations without
//! touching raw struct fields. Strings enter only at the wire edge
//! (`api::wire`, the TOML loader, CLI flags).

use crate::dist::DistSpec;
use crate::util::units::{MIN, YEAR};

/// Fault-tolerance characteristics of the platform (§2.1).
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    /// Individual-component MTBF in seconds (paper: 125 years).
    pub mu_ind: f64,
    /// Number of components; platform MTBF mu = mu_ind / n (§2.1).
    pub n_procs: u64,
    /// Checkpoint duration C (s).
    pub c: f64,
    /// Downtime D (s).
    pub d: f64,
    /// Recovery duration R (s).
    pub r: f64,
}

impl Platform {
    /// The paper's §5 platform: C = R = 10 mn, D = 1 mn, mu_ind = 125 y.
    pub fn paper(n_procs: u64) -> Self {
        Platform {
            mu_ind: 125.0 * YEAR,
            n_procs,
            c: 10.0 * MIN,
            d: 1.0 * MIN,
            r: 10.0 * MIN,
        }
    }

    /// Platform MTBF in seconds: mu = mu_ind / N.
    pub fn mu(&self) -> f64 {
        self.mu_ind / self.n_procs as f64
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.mu_ind > 0.0, "mu_ind must be positive");
        anyhow::ensure!(self.n_procs > 0, "n_procs must be positive");
        anyhow::ensure!(self.c >= 0.0 && self.d >= 0.0 && self.r >= 0.0, "C, D, R must be >= 0");
        anyhow::ensure!(self.c > 0.0, "a zero-cost checkpoint makes the optimization degenerate");
        Ok(())
    }
}

/// Fault-prediction system characteristics (§2.2).
#[derive(Debug, Clone, PartialEq)]
pub struct Predictor {
    /// Recall r: fraction of faults predicted.
    pub recall: f64,
    /// Precision p: fraction of predictions that are true.
    pub precision: f64,
    /// Prediction-window length I (s); 0 = exact-date predictions (§3).
    pub window: f64,
    /// Mean in-window fault position E_I^(f); `window / 2` for the
    /// uniform in-window law the paper assumes.
    pub ef: f64,
}

impl Predictor {
    /// Step-by-step construction; [`PredictorBuilder::build`] validates.
    pub fn builder() -> PredictorBuilder {
        PredictorBuilder { p: Predictor::none(), ef_explicit: false }
    }

    /// Exact-date predictor (§3): I = 0.
    pub fn exact(recall: f64, precision: f64) -> Self {
        Predictor { recall, precision, window: 0.0, ef: 0.0 }
    }

    /// Window predictor with uniformly distributed in-window faults (§4).
    pub fn windowed(recall: f64, precision: f64, window: f64) -> Self {
        Predictor { recall, precision, window, ef: window / 2.0 }
    }

    /// No predictor at all (reduces every strategy to Young/Daly).
    pub fn none() -> Self {
        Predictor::exact(0.0, 1.0)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!((0.0..=1.0).contains(&self.recall), "recall in [0,1]");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.precision) && (self.precision > 0.0 || self.recall == 0.0),
            "precision in (0,1] when the predictor predicts anything"
        );
        anyhow::ensure!(self.window >= 0.0, "window >= 0");
        anyhow::ensure!(
            (0.0..=self.window.max(0.0)).contains(&self.ef),
            "E_I^(f) must lie inside the window"
        );
        Ok(())
    }

    /// Mean time between predicted events mu_P = p mu / r (§2.3);
    /// infinite when the predictor never fires.
    pub fn mu_p(&self, mu: f64) -> f64 {
        if self.recall == 0.0 { f64::INFINITY } else { self.precision * mu / self.recall }
    }

    /// Mean time between unpredicted faults mu_NP = mu / (1-r) (§2.3).
    pub fn mu_np(&self, mu: f64) -> f64 {
        if self.recall >= 1.0 { f64::INFINITY } else { mu / (1.0 - self.recall) }
    }

    /// Mean time between events of any kind (§2.3).
    pub fn mu_e(&self, mu: f64) -> f64 {
        let inv = 1.0 / self.mu_p(mu) + 1.0 / self.mu_np(mu);
        if inv == 0.0 { f64::INFINITY } else { 1.0 / inv }
    }

    /// Mean inter-arrival of *false* predictions:
    /// p mu / (r (1-p)) (§5); infinite if p = 1 or r = 0.
    pub fn false_pred_interval(&self, mu: f64) -> f64 {
        if self.recall == 0.0 || self.precision >= 1.0 {
            f64::INFINITY
        } else {
            self.precision * mu / (self.recall * (1.0 - self.precision))
        }
    }

    /// Whether this predictor can never emit a prediction: no true
    /// positives (r = 0) and no false-positive stream either. This is
    /// the one condition under which a live
    /// [`crate::trace::TraceGen`]'s prediction stream legitimately
    /// returns `None` (the generator's own check in
    /// `trace::gen` is the from-parsed-dists form of the same rule),
    /// and therefore the condition under which a
    /// [`crate::trace::TraceBank`]'s empty prediction span is a
    /// faithful replay rather than a truncation — keep the three in
    /// lockstep through this helper.
    pub fn never_fires(&self, mu: f64) -> bool {
        self.recall == 0.0 && !self.false_pred_interval(mu).is_finite()
    }
}

/// Incremental [`Predictor`] construction: recall/precision default to
/// the no-predictor degenerate case (r = 0, p = 1); setting a window
/// re-derives `ef = I/2` (the paper's uniform in-window law) unless an
/// explicit `ef` was given.
#[derive(Debug, Clone)]
pub struct PredictorBuilder {
    p: Predictor,
    ef_explicit: bool,
}

impl PredictorBuilder {
    pub fn recall(mut self, r: f64) -> Self {
        self.p.recall = r;
        self
    }

    pub fn precision(mut self, p: f64) -> Self {
        self.p.precision = p;
        self
    }

    pub fn window(mut self, i: f64) -> Self {
        self.p.window = i;
        if !self.ef_explicit {
            self.p.ef = i / 2.0;
        }
        self
    }

    /// Mean in-window fault position; overrides the `window/2` default.
    pub fn ef(mut self, ef: f64) -> Self {
        self.p.ef = ef;
        self.ef_explicit = true;
        self
    }

    pub fn build(self) -> anyhow::Result<Predictor> {
        self.p.validate()?;
        Ok(self.p)
    }
}

/// A complete experiment scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub platform: Platform,
    pub predictor: Predictor,
    /// Period-cap tuning parameter (§3.2; paper uses 0.27).
    pub alpha: f64,
    /// Total useful work of the job (s).
    pub work: f64,
    /// Failure inter-arrival law.
    pub fault_dist: DistSpec,
    /// False-prediction inter-arrival law (`None` = same as
    /// `fault_dist`).
    pub false_pred_dist: Option<DistSpec>,
    /// Migration duration M for the §3.4 strategy (s).
    pub migration: f64,
    /// Master seed.
    pub seed: u64,
}

impl Scenario {
    /// Step-by-step construction starting from the §5 paper preset;
    /// [`ScenarioBuilder::build`] validates the result.
    pub fn builder() -> ScenarioBuilder {
        ScenarioBuilder { s: Scenario::paper(1 << 16, Predictor::none()), mu: None }
    }

    pub fn paper(n_procs: u64, predictor: Predictor) -> Self {
        Scenario {
            platform: Platform::paper(n_procs),
            predictor,
            alpha: 0.27,
            // Strong scaling, as the paper's Tables 1-2 imply (their
            // 2^19 execution times sit *below* the 2^16 ones, which is
            // only possible when the wall-clock work shrinks with N):
            // a fixed sequential workload W_seq divided over N procs.
            // W_seq calibrated so Young at N = 2^16 under Weibull
            // k = 0.7 lands at the paper's ~81 days (EXPERIMENTS.md).
            work: 3.893e11 / n_procs as f64,
            fault_dist: DistSpec::weibull(0.7),
            false_pred_dist: None,
            migration: 300.0,
            seed: 0x5EED,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::Context as _;
        self.platform.validate()?;
        self.predictor.validate()?;
        anyhow::ensure!(self.alpha > 0.0 && self.alpha <= 1.0, "alpha in (0,1]");
        anyhow::ensure!(self.work > 0.0, "work must be positive");
        // The spec type guarantees the law's *identity*; its parameters
        // (a directly-constructed Weibull shape) still need checking.
        self.fault_dist.validate().context("scenario fault_dist")?;
        if let Some(d) = &self.false_pred_dist {
            d.validate().context("scenario false_pred_dist")?;
        }
        Ok(())
    }

    pub fn mu(&self) -> f64 {
        self.platform.mu()
    }

    /// Effective false-prediction distribution spec.
    pub fn false_dist_spec(&self) -> DistSpec {
        self.false_pred_dist.unwrap_or(self.fault_dist)
    }
}

/// Incremental [`Scenario`] construction. Starts from the §5 paper
/// preset (N = 2^16, no predictor, Weibull k = 0.7 faults) and
/// overrides field by field; `build` validates. A direct platform-MTBF
/// override ([`ScenarioBuilder::mu`]) is resolved against the final
/// processor count, so call order does not matter.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    s: Scenario,
    mu: Option<f64>,
}

impl ScenarioBuilder {
    pub fn platform(mut self, p: Platform) -> Self {
        self.s.platform = p;
        self
    }

    /// Processor count; the platform MTBF is mu_ind / N.
    pub fn n_procs(mut self, n: u64) -> Self {
        self.s.platform.n_procs = n;
        self
    }

    /// Platform MTBF mu in *seconds*, overriding `mu_ind / N`.
    pub fn mu(mut self, mu: f64) -> Self {
        self.mu = Some(mu);
        self
    }

    /// Checkpoint duration C (s).
    pub fn checkpoint(mut self, c: f64) -> Self {
        self.s.platform.c = c;
        self
    }

    /// Downtime D (s).
    pub fn downtime(mut self, d: f64) -> Self {
        self.s.platform.d = d;
        self
    }

    /// Recovery duration R (s).
    pub fn recovery(mut self, r: f64) -> Self {
        self.s.platform.r = r;
        self
    }

    pub fn predictor(mut self, p: Predictor) -> Self {
        self.s.predictor = p;
        self
    }

    pub fn alpha(mut self, alpha: f64) -> Self {
        self.s.alpha = alpha;
        self
    }

    pub fn work(mut self, work: f64) -> Self {
        self.s.work = work;
        self
    }

    pub fn fault_dist(mut self, d: DistSpec) -> Self {
        self.s.fault_dist = d;
        self
    }

    pub fn false_pred_dist(mut self, d: Option<DistSpec>) -> Self {
        self.s.false_pred_dist = d;
        self
    }

    pub fn migration(mut self, m: f64) -> Self {
        self.s.migration = m;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.s.seed = seed;
        self
    }

    pub fn build(mut self) -> anyhow::Result<Scenario> {
        if let Some(mu) = self.mu {
            self.s.platform.mu_ind = mu * self.s.platform.n_procs as f64;
        }
        self.s.validate()?;
        Ok(self.s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;
    use crate::util::units::MIN;

    #[test]
    fn paper_platform_mtbf() {
        // N = 2^19 => mu ≈ 125 mn; N = 2^16 => mu ≈ 1000 mn (paper §5).
        let big = Platform::paper(1 << 19);
        assert!((big.mu() / MIN - 125.0).abs() < 1.0, "mu = {} mn", big.mu() / MIN);
        let mid = Platform::paper(1 << 16);
        assert!((mid.mu() / MIN - 1000.0).abs() < 7.0, "mu = {} mn", mid.mu() / MIN);
    }

    #[test]
    fn rate_relations() {
        // 1/mu_e = 1/mu_P + 1/mu_NP and the §2.3 identities.
        let p = Predictor::windowed(0.85, 0.82, 300.0);
        let mu = 60_000.0;
        assert!(approx_eq(p.mu_p(mu), 0.82 * mu / 0.85, 1e-12));
        assert!(approx_eq(p.mu_np(mu), mu / 0.15, 1e-12));
        let inv = 1.0 / p.mu_p(mu) + 1.0 / p.mu_np(mu);
        assert!(approx_eq(p.mu_e(mu), 1.0 / inv, 1e-12));
    }

    #[test]
    fn degenerate_predictors() {
        let none = Predictor::none();
        assert!(none.mu_p(100.0).is_infinite());
        assert!(none.false_pred_interval(100.0).is_infinite());
        assert!(approx_eq(none.mu_e(100.0), 100.0, 1e-12));

        let perfect = Predictor::exact(1.0, 1.0);
        assert!(perfect.mu_np(100.0).is_infinite());
        assert!(approx_eq(perfect.mu_p(100.0), 100.0, 1e-12));
    }

    #[test]
    fn false_prediction_interval_matches_paper() {
        // §5: expectation p mu / (r (1-p)).
        let p = Predictor::exact(0.7, 0.4);
        assert!(approx_eq(p.false_pred_interval(1000.0), 0.4 * 1000.0 / (0.7 * 0.6), 1e-12));
    }

    #[test]
    fn validation_catches_errors() {
        let mut s = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
        s.validate().unwrap();
        s.alpha = 0.0;
        assert!(s.validate().is_err());
        s.alpha = 0.27;
        s.fault_dist = DistSpec::weibull(-1.0);
        let err = s.validate().unwrap_err();
        assert!(
            format!("{err:#}").contains("weibull:-1"),
            "validation error must name the offending spec: {err:#}"
        );
        s.fault_dist = DistSpec::Exp;
        s.false_pred_dist = Some(DistSpec::weibull(f64::NAN));
        assert!(s.validate().is_err());
        s.false_pred_dist = None;
        s.validate().unwrap();

        let bad = Predictor { recall: 0.5, precision: 0.0, window: 0.0, ef: 0.0 };
        assert!(bad.validate().is_err());
        let bad_ef = Predictor { recall: 0.5, precision: 0.5, window: 10.0, ef: 20.0 };
        assert!(bad_ef.validate().is_err());
    }

    #[test]
    fn no_predictor_degenerate_case_is_valid() {
        // precision = 0 is fine when the predictor never fires — the
        // paper's no-predictor case. The wire layers must accept it too
        // (pinned again in the protocol tests).
        let p = Predictor { recall: 0.0, precision: 0.0, window: 0.0, ef: 0.0 };
        p.validate().unwrap();
    }

    #[test]
    fn scenario_builder_round_trip() {
        let s = Scenario::builder()
            .n_procs(1 << 18)
            .checkpoint(300.0)
            .predictor(Predictor::windowed(0.85, 0.82, 300.0))
            .fault_dist(DistSpec::Exp)
            .work(1.0e6)
            .seed(42)
            .build()
            .unwrap();
        assert_eq!(s.platform.n_procs, 1 << 18);
        assert_eq!(s.platform.c, 300.0);
        assert_eq!(s.fault_dist, DistSpec::Exp);
        assert_eq!(s.seed, 42);
        // Untouched fields keep the paper preset.
        assert_eq!(s.alpha, 0.27);
    }

    #[test]
    fn scenario_builder_mu_override_is_order_independent() {
        let a = Scenario::builder().mu(60_000.0).n_procs(4).build().unwrap();
        let b = Scenario::builder().n_procs(4).mu(60_000.0).build().unwrap();
        assert!(approx_eq(a.mu(), 60_000.0, 1e-9));
        assert!(approx_eq(b.mu(), 60_000.0, 1e-9));
        assert_eq!(a, b);
    }

    #[test]
    fn scenario_builder_rejects_invalid() {
        assert!(Scenario::builder().work(-1.0).build().is_err());
        assert!(Scenario::builder().fault_dist(DistSpec::weibull(0.0)).build().is_err());
    }

    #[test]
    fn predictor_builder_defaults_and_ef() {
        let p = Predictor::builder().recall(0.85).precision(0.82).window(300.0).build().unwrap();
        assert_eq!(p, Predictor::windowed(0.85, 0.82, 300.0));
        let p2 = Predictor::builder()
            .recall(0.7)
            .precision(0.4)
            .ef(100.0)
            .window(300.0)
            .build()
            .unwrap();
        assert_eq!(p2.ef, 100.0, "explicit ef survives a later window()");
        // Defaults are the degenerate no-predictor case.
        assert_eq!(Predictor::builder().build().unwrap(), Predictor::none());
        // Invalid combinations are rejected at build.
        assert!(Predictor::builder().recall(0.5).precision(0.0).build().is_err());
    }
}
