//! Integration: HLO batcher + TCP job service end to end.
//! Requires `make artifacts` and a `pjrt`-enabled build; each test
//! skips (with a notice on stderr) when the planner backend is
//! unavailable, so the tier-1 suite stays green on bare checkouts.
//! (The planner-less service path — analytic plans, simulation jobs —
//! is covered unconditionally in `tests/test_api.rs`.)

use std::time::Duration;

use ckptfp::api::{Executor, ExecutorConfig};
use ckptfp::coordinator::{serve, Batcher, BatcherConfig, PlannerClient, ServiceConfig};
use ckptfp::runtime::HloPlanner;

fn spawn_batcher() -> Option<Batcher> {
    match Batcher::spawn(
        HloPlanner::open_default,
        BatcherConfig { max_batch: 16, max_delay: Duration::from_millis(1), ..Default::default() },
    ) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping service test: {e:#} (run `make artifacts` and build with --features pjrt)");
            None
        }
    }
}

fn start_service() -> Option<(ckptfp::coordinator::ServiceHandle, String, Batcher)> {
    let batcher = spawn_batcher()?;
    let executor = Executor::with_batcher(batcher.clone(), ExecutorConfig::default());
    let handle = serve(
        executor,
        ServiceConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    Some((handle, addr, batcher))
}

#[test]
fn plan_request_round_trip() {
    let Some((handle, addr, _batcher)) = start_service() else { return };
    let mut client = PlannerClient::connect(&addr).unwrap();
    let v = client
        .call(r#"{"mu": 60000, "recall": 0.85, "precision": 0.82, "window": 300}"#)
        .unwrap();
    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
    let waste = v.num_or("winner_waste", f64::NAN);
    assert!(waste > 0.0 && waste < 1.0, "waste {waste}");
    let period = v.num_or("winner_period", f64::NAN);
    assert!(period >= 600.0);
    // All six strategies reported.
    match v.get("strategies") {
        Some(ckptfp::util::json::Json::Arr(xs)) => assert_eq!(xs.len(), 6),
        other => panic!("bad strategies field: {other:?}"),
    }
    // A v1 request gets the v1 response shape: no "v" marker.
    assert!(v.get("v").is_none(), "legacy response must not carry 'v': {v:?}");
    handle.stop();
}

#[test]
fn ping_stats_and_errors() {
    let Some((handle, addr, _batcher)) = start_service() else { return };
    let mut client = PlannerClient::connect(&addr).unwrap();
    let pong = client.call(r#"{"op": "ping"}"#).unwrap();
    assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));

    let err = client.call(r#"{"op": "plan"}"#).unwrap(); // missing mu
    assert_eq!(err.get("ok").and_then(|b| b.as_bool()), Some(false));
    assert!(err.get("error").is_some());

    let garbage = client.call("this is not json").unwrap();
    assert_eq!(garbage.get("ok").and_then(|b| b.as_bool()), Some(false));

    // Connection survives errors: a valid request still works.
    let v = client.call(r#"{"mu": 7500, "recall": 0.7, "precision": 0.4}"#).unwrap();
    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));

    let stats = client.call(r#"{"op": "stats"}"#).unwrap();
    assert!(stats.num_or("requests", 0.0) >= 1.0);
    assert!(stats.num_or("errors", 0.0) >= 2.0);
    handle.stop();
}

#[test]
fn concurrent_clients_batch_together() {
    let Some((handle, addr, batcher)) = start_service() else { return };
    let n_clients = 12;
    std::thread::scope(|scope| {
        for i in 0..n_clients {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut client = PlannerClient::connect(&addr).unwrap();
                let mu = 7500.0 * (1.0 + i as f64 * 0.1);
                let v = client
                    .call(&format!(r#"{{"mu": {mu}, "recall": 0.85, "precision": 0.82}}"#))
                    .unwrap();
                assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));
            });
        }
    });
    let stats = batcher.stats();
    assert_eq!(stats.requests, n_clients as u64);
    // Dynamic batching must have coalesced at least some requests.
    assert!(stats.batches < n_clients as u64, "batches {} for {n_clients} requests", stats.batches);
    handle.stop();
}

#[test]
fn batcher_direct_plan_many() {
    let Some(batcher) = spawn_batcher() else { return };
    let s = ckptfp::config::Scenario::paper(
        1 << 16,
        ckptfp::config::Predictor::windowed(0.85, 0.82, 300.0),
    );
    let p = ckptfp::model::Params::from_scenario(&s);
    let outs = batcher.plan_many(vec![p; 30]).unwrap();
    assert_eq!(outs.len(), 30);
    for o in &outs {
        assert!((o.winner_waste - outs[0].winner_waste).abs() < 1e-9);
    }
    batcher.shutdown();
}

#[test]
fn typed_client_rides_the_hlo_planner() {
    let Some((handle, addr, _batcher)) = start_service() else { return };
    let mut client = ckptfp::api::ServiceClient::connect(&addr).unwrap();
    let scenario = ckptfp::config::Scenario::paper(
        1 << 16,
        ckptfp::config::Predictor::windowed(0.85, 0.82, 300.0),
    );
    let res = client.plan(ckptfp::api::PlanJob::new(scenario)).unwrap();
    assert!(res.via_hlo, "service with a batcher must plan via HLO");
    assert!(res.winner_waste > 0.0 && res.winner_waste < 1.0);
    handle.stop();
}

// ---------------------------------------------------------------------------
// Planner-less concurrency sweep (no pjrt backend needed): admission
// gates, deadlines and client isolation under simultaneous load, plus
// the stop() regression. Panic isolation under load lives with the
// injection gate in tests/test_chaos.rs (`--features chaos`).
// ---------------------------------------------------------------------------

use ckptfp::api::{wire, JobRequest, SimulateJob};
use ckptfp::config::{Predictor, Scenario};
use ckptfp::model::StrategyKind;

fn sim_scenario() -> Scenario {
    let mut s = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
    s.fault_dist = ckptfp::dist::DistSpec::Exp;
    s.work = 2.0e5;
    s
}

#[test]
fn a_hundred_start_stop_cycles_with_zero_connections_return_promptly() {
    // Regression for the loopback-nudge era: stop() used to dial its
    // own listener to wake the accept loop, which could hang a service
    // bound to an address it cannot dial and leaked the nudge
    // connection. The event loop polls its stop flag instead, so a
    // zero-connection stop is immediate — 100 cycles stay well under
    // any accept-timeout multiple.
    let started = std::time::Instant::now();
    for _ in 0..100 {
        let handle = serve(
            Executor::new(ExecutorConfig { workers: 1, ..Default::default() }),
            ServiceConfig {
                addr: "127.0.0.1:0".into(),
                sched_workers: 2,
                ..Default::default()
            },
        )
        .unwrap();
        handle.stop();
    }
    assert!(
        started.elapsed() < Duration::from_secs(60),
        "100 idle start/stop cycles took {:?}",
        started.elapsed()
    );
}

#[test]
fn connections_past_the_gate_get_a_structured_overloaded_reply() {
    let handle = serve(
        Executor::new(ExecutorConfig { workers: 1, ..Default::default() }),
        ServiceConfig { addr: "127.0.0.1:0".into(), max_conns: 1, ..Default::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    let ping = wire::encode_request(&JobRequest::Ping);

    let mut first = PlannerClient::connect(&addr).unwrap();
    assert_eq!(
        first.call(&ping).unwrap().get("pong").and_then(|b| b.as_bool()),
        Some(true)
    );
    let mut second = PlannerClient::connect(&addr).unwrap();
    let v = second.call(&ping).unwrap();
    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false));
    let code = v
        .get("error")
        .and_then(|e| e.get("code"))
        .and_then(|c| c.as_str().map(String::from));
    assert_eq!(code.as_deref(), Some("overloaded"), "{v:?}");
    assert!(v.num_or("retry_after_ms", 0.0) > 0.0, "{v:?}");
    handle.stop();
}

#[test]
fn deadline_expiry_is_structured_under_simultaneous_load() {
    let budget = Duration::from_millis(300);
    let handle = serve(
        Executor::new(ExecutorConfig {
            workers: 2,
            deadline: Some(budget),
            ..Default::default()
        }),
        ServiceConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();

    let mut job = SimulateJob::new(sim_scenario(), StrategyKind::Young);
    job.reps = 1_000_000; // far past a 300 ms budget
    job.workers = Some(2);
    let line = wire::encode_request(&JobRequest::Simulate(job));

    std::thread::scope(|scope| {
        for _ in 0..4 {
            let addr = addr.clone();
            let line = line.clone();
            scope.spawn(move || {
                let v = PlannerClient::connect(&addr).unwrap().call(&line).unwrap();
                assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false));
                let code = v
                    .get("error")
                    .and_then(|e| e.get("code"))
                    .and_then(|c| c.as_str().map(String::from));
                assert_eq!(code.as_deref(), Some("deadline_exceeded"), "{v:?}");
            });
        }
    });

    // Deadline errors are per-request: the service stays healthy.
    let pong = PlannerClient::connect(&addr)
        .unwrap()
        .call(&wire::encode_request(&JobRequest::Ping))
        .unwrap();
    assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));
    handle.stop();
}

#[test]
fn mixed_valid_and_hostile_clients_stay_isolated() {
    let handle = serve(
        Executor::new(ExecutorConfig { workers: 2, reps_default: 4, ..Default::default() }),
        ServiceConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();

    std::thread::scope(|scope| {
        for i in 0..8 {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut client = PlannerClient::connect(&addr).unwrap();
                if i % 2 == 0 {
                    // Hostile neighbors: garbage, then an oversized
                    // line, then proof the connection still works.
                    let v = client.call("this is not json").unwrap();
                    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false));
                    let big = format!("{{\"pad\": \"{}\"}}", "x".repeat(wire::MAX_LINE_BYTES));
                    let v = client.call(&big).unwrap();
                    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(false));
                    let pong =
                        client.call(&wire::encode_request(&JobRequest::Ping)).unwrap();
                    assert_eq!(pong.get("pong").and_then(|b| b.as_bool()), Some(true));
                } else {
                    // Well-behaved v1 neighbors get real plans.
                    let mu = 7500.0 * (1.0 + i as f64 * 0.1);
                    let v = client
                        .call(&format!(
                            r#"{{"mu": {mu}, "recall": 0.85, "precision": 0.82}}"#
                        ))
                        .unwrap();
                    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true), "{v:?}");
                    let waste = v.num_or("winner_waste", f64::NAN);
                    assert!(waste > 0.0 && waste < 1.0, "waste {waste}");
                }
            });
        }
    });
    handle.stop();
}
