//! The paper's §5 evaluation, experiment by experiment.
//!
//! Every figure (4–11) and table (1–3) has a regeneration function
//! here; the bench harness (`cargo bench --bench paper`) and the
//! `ckptfp experiment` command are thin wrappers around this module.

pub mod ablations;
pub mod catalog;
pub mod conformance;
pub mod figures;
pub mod platform;
pub mod policies;
pub mod sweep;
pub mod tables;

use crate::config::Scenario;
use crate::coordinator::{available_workers, run_parallel_fold};
use crate::model::{Capping, StrategyKind};
use crate::sim::{fold_waste_grid, rep_blocks, BatchRunner, Outcome, Policy, SimSession};
use crate::strategies::{exactify, spec_for, StrategySpec};
use crate::util::stats::Summary;

/// Knobs shared by all experiments.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Simulation replications per point (paper: 100).
    pub reps: u64,
    /// Worker threads.
    pub workers: usize,
    /// Also compute the BestPeriod counterpart of each heuristic
    /// (brute-force search — expensive).
    pub best_period: bool,
    /// Replications per BestPeriod candidate.
    pub bp_reps: u64,
    /// BestPeriod grid size.
    pub bp_candidates: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            reps: 40,
            workers: available_workers(),
            best_period: false,
            bp_reps: 10,
            bp_candidates: 16,
        }
    }
}

impl ExpOptions {
    /// Reduced settings for smoke tests and quick bench runs.
    pub fn quick() -> Self {
        ExpOptions { reps: 8, bp_reps: 4, bp_candidates: 8, ..Default::default() }
    }
}

/// The heuristics the paper simulates for a given window size
/// (WithCkptI needs room for one in-window checkpoint: I >= C).
pub fn paper_heuristics(i_window: f64, c: f64) -> Vec<StrategyKind> {
    let mut v = vec![
        StrategyKind::Young,
        StrategyKind::ExactPrediction,
        StrategyKind::Instant,
        StrategyKind::NoCkptI,
    ];
    if i_window >= c {
        v.push(StrategyKind::WithCkptI);
    }
    v
}

/// The scenario a heuristic actually runs against: EXACTPREDICTION gets
/// exact-date predictions for the same faults (§5's definition).
pub fn scenario_for(kind: StrategyKind, scenario: &Scenario) -> Scenario {
    if kind == StrategyKind::ExactPrediction {
        exactify(scenario)
    } else {
        scenario.clone()
    }
}

/// Streaming parallel replication of one (scenario, spec) point: each
/// pool worker owns a reused [`SimSession`] and a worker-local Welford
/// summary of `stat`; partials merge at the end. No spec re-parsing and
/// no per-replication result slots anywhere on the path.
pub fn replicate_stat<F>(
    scenario: &Scenario,
    spec: &StrategySpec,
    reps: u64,
    workers: usize,
    stat: F,
) -> Summary
where
    F: Fn(&Outcome) -> f64 + Sync,
{
    scenario.validate().expect("invalid scenario");
    replicate_stat_with(
        reps,
        workers,
        || SimSession::new(scenario, spec).expect("scenario validated above"),
        stat,
    )
}

/// [`replicate_stat`] with an explicit session factory — for callers
/// that need a non-default session (e.g. the `abl-lead` study's
/// [`SimSession::with_lead`]). The factory runs once per worker.
pub fn replicate_stat_with<M, F>(reps: u64, workers: usize, make: M, stat: F) -> Summary
where
    M: Fn() -> SimSession + Sync,
    F: Fn(&Outcome) -> f64 + Sync,
{
    let rep_ids: Vec<u64> = (0..reps).collect();
    run_parallel_fold(
        &rep_ids,
        workers,
        || (None::<SimSession>, Summary::new()),
        |(mut session, mut sum), &rep| {
            let s = session.get_or_insert_with(&make);
            sum.push(stat(&s.run(rep)));
            (session, sum)
        },
        |(_, a), (_, b)| (None, a.merge(&b)),
    )
    .1
}

/// Simulate a grid of (scenario, spec) points × `reps` through one pool
/// pass — the figure harnesses' workhorse. Tasks are point-major, so a
/// worker's session is rebuilt only when its stride crosses a point
/// boundary; per-point waste summaries come back in input order.
pub fn sim_waste_grid(
    points: &[(Scenario, StrategySpec)],
    reps: u64,
    workers: usize,
) -> Vec<Summary> {
    for (s, _) in points {
        s.validate().expect("invalid scenario");
    }
    waste_grid_with(points.len(), reps, workers, |pi| {
        let (s, spec) = &points[pi];
        SimSession::new(s, spec).expect("scenario validated above")
    })
}

/// Policy-layer analogue of [`sim_waste_grid`]: a grid of
/// (scenario, [`Policy`]) points × `reps` through one pool pass, with
/// per-point session reuse. Resolve specs with
/// [`crate::strategies::resolve_policy`] first.
pub fn sim_policy_grid(points: &[(Scenario, Policy)], reps: u64, workers: usize) -> Vec<Summary> {
    for (s, _) in points {
        s.validate().expect("invalid scenario");
    }
    waste_grid_with(points.len(), reps, workers, |pi| {
        let (s, policy) = &points[pi];
        SimSession::from_policy(s, *policy).expect("scenario validated above")
    })
}

/// The shared grid core: block the (point × rep) product and fold it
/// through the pool, one reused session per worker per point. Routes
/// through the batch fold with scalar-lane runners: each point draws a
/// fresh live trace per replication, so there is no shared arena to
/// advance in lockstep (per-point banks would cost more than they
/// save).
fn waste_grid_with<F>(n_points: usize, reps: u64, workers: usize, make: F) -> Vec<Summary>
where
    F: Fn(usize) -> SimSession + Sync,
{
    let all: Vec<usize> = (0..n_points).collect();
    let tasks = rep_blocks(&all, 0, reps, workers);
    fold_waste_grid(&tasks, n_points, workers, |pi| BatchRunner::Scalar(make(pi)))
}

/// Mean simulated waste of `kind` on `scenario`: `reps` paired
/// replications, parallelized over the worker pool.
pub fn sim_waste(scenario: &Scenario, kind: StrategyKind, opts: &ExpOptions) -> Summary {
    let s = scenario_for(kind, scenario);
    let spec = spec_for(kind, &s, Capping::Uncapped);
    replicate_stat(&s, &spec, opts.reps, opts.workers, Outcome::waste)
}

/// Mean simulated execution time (seconds) of `kind` on `scenario`.
pub fn sim_makespan(scenario: &Scenario, kind: StrategyKind, opts: &ExpOptions) -> Summary {
    let s = scenario_for(kind, scenario);
    let spec = spec_for(kind, &s, Capping::Uncapped);
    replicate_stat(&s, &spec, opts.reps, opts.workers, |o| o.makespan)
}

/// Result bundle an experiment hands back to the CLI / bench harness.
#[derive(Debug, Clone, Default)]
pub struct ExperimentResult {
    pub figures: Vec<crate::report::FigureData>,
    pub tables: Vec<(String, crate::report::Table)>,
}

impl ExperimentResult {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for fig in &self.figures {
            out.push_str(&fig.render());
            out.push('\n');
        }
        for (name, t) in &self.tables {
            out.push_str(&format!("# {name}\n{}\n", t.render()));
        }
        out
    }

    /// Write figure CSVs under `dir`.
    pub fn write_csvs(&self, dir: &std::path::Path) -> anyhow::Result<()> {
        for fig in &self.figures {
            crate::report::write_figure_csv(&dir.join(format!("{}.csv", fig.name)), fig)?;
        }
        Ok(())
    }
}

/// Registry: run an experiment by its paper id.
pub fn run_experiment(id: &str, opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    match id {
        "fig4" | "fig5" | "fig6" | "fig7" => figures::figure_waste(id, opts),
        "fig8" | "fig9" | "fig10" | "fig11" => sweep::figure_sweep(id, opts),
        "tab1" => tables::table_exec(0.7, opts),
        "tab2" => tables::table_exec(0.5, opts),
        "tab3" => catalog::table_catalog(opts),
        "abl-q" => ablations::ablation_q(opts),
        "abl-daly" => ablations::ablation_daly(opts),
        "abl-lead" => ablations::ablation_lead(opts),
        "abl-cap" => ablations::ablation_cap(opts),
        "policy-comparison" | "policy_comparison" => policies::policy_comparison(opts),
        "conformance" => conformance::conformance(opts),
        "platform-scaling" | "platform_scaling" => platform::platform_scaling(opts),
        other => anyhow::bail!(
            "unknown experiment '{other}' (expected fig4..fig11 | tab1..tab3 | abl-q | abl-daly | abl-lead | abl-cap | policy-comparison | conformance | platform-scaling)"
        ),
    }
}

/// Paper experiment ids, in paper order.
pub fn paper_experiments() -> Vec<&'static str> {
    vec!["fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "tab1", "tab2", "tab3"]
}

/// Everything: the paper's figures/tables, the ablations, the
/// policy-layer comparison, the conformance grid, and the platform
/// node-count scaling study.
pub fn all_experiments() -> Vec<&'static str> {
    let mut v = paper_experiments();
    v.extend([
        "abl-q",
        "abl-daly",
        "abl-lead",
        "abl-cap",
        "policy-comparison",
        "conformance",
        "platform-scaling",
    ]);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;

    #[test]
    fn heuristic_sets() {
        let small = paper_heuristics(300.0, 600.0);
        assert!(!small.contains(&StrategyKind::WithCkptI));
        assert_eq!(small.len(), 4);
        let large = paper_heuristics(3000.0, 600.0);
        assert!(large.contains(&StrategyKind::WithCkptI));
    }

    #[test]
    fn scenario_for_exactifies() {
        let s = Scenario::paper(1 << 16, Predictor::windowed(0.85, 0.82, 300.0));
        let e = scenario_for(StrategyKind::ExactPrediction, &s);
        assert_eq!(e.predictor.window, 0.0);
        let i = scenario_for(StrategyKind::Instant, &s);
        assert_eq!(i.predictor.window, 300.0);
    }

    #[test]
    fn waste_grid_matches_single_point_replication() {
        let mut s = Scenario::paper(1 << 16, Predictor::none());
        s.fault_dist = crate::dist::DistSpec::Exp;
        s.work = 2.0e5;
        let spec = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
        let points = vec![(s.clone(), spec.clone()), (s.clone(), spec.clone())];
        let grid = sim_waste_grid(&points, 6, 2);
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[0].count(), 6);
        let single = replicate_stat(&s, &spec, 6, 1, crate::sim::Outcome::waste);
        // Identical point → identical traces per rep → identical means
        // (up to merge-order reassociation).
        assert!(crate::util::approx_eq(grid[0].mean(), single.mean(), 1e-12));
        assert!(crate::util::approx_eq(grid[1].mean(), single.mean(), 1e-12));
    }

    #[test]
    fn registry_rejects_unknown() {
        assert!(run_experiment("fig99", &ExpOptions::quick()).is_err());
    }

    #[test]
    fn experiment_ids_complete() {
        // One per figure and table of §5 — the (d) deliverable checklist —
        // plus the four ablations, the policy comparison, the
        // conformance grid and the platform scaling study.
        assert_eq!(paper_experiments().len(), 11);
        assert_eq!(all_experiments().len(), 18);
        assert!(all_experiments().contains(&"policy-comparison"));
        assert!(all_experiments().contains(&"conformance"));
        assert!(all_experiments().contains(&"platform-scaling"));
    }
}
