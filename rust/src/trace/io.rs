//! Trace (de)serialization — a small CSV dialect so generated traces
//! can be inspected, archived and replayed (`ckptfp trace` command).
//!
//! Format, one event per line:
//! ```text
//! fault,<t>,<id>,<predicted 0|1>
//! pred,<avail>,<t0>,<window>,<fault_id|->
//! ```

use std::io::{BufRead, Write};

use super::{EventSource, Fault, Prediction, VecSource};

/// Write `horizon`-bounded streams of an event source.
pub fn write_trace<W: Write, S: EventSource>(
    out: &mut W,
    source: &mut S,
    horizon: f64,
) -> anyhow::Result<(usize, usize)> {
    let mut nf = 0;
    let mut np = 0;
    writeln!(out, "# ckptfp trace v1, horizon={horizon}")?;
    while let Some(f) = source.next_fault() {
        if f.t > horizon {
            break;
        }
        writeln!(out, "fault,{},{},{}", f.t, f.id, u8::from(f.predicted))?;
        nf += 1;
    }
    while let Some(p) = source.next_prediction() {
        if p.avail > horizon {
            break;
        }
        let fid = p.fault_id.map(|i| i.to_string()).unwrap_or_else(|| "-".into());
        writeln!(out, "pred,{},{},{},{fid}", p.avail, p.t0, p.window)?;
        np += 1;
    }
    Ok((nf, np))
}

/// Read a trace back into a replayable [`VecSource`].
pub fn read_trace<R: BufRead>(input: R) -> anyhow::Result<VecSource> {
    let mut faults = Vec::new();
    let mut preds = Vec::new();
    for (lineno, line) in input.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        let ctx = || format!("trace line {}", lineno + 1);
        match fields[0] {
            "fault" => {
                anyhow::ensure!(fields.len() == 4, "{}: want 4 fields", ctx());
                faults.push(Fault {
                    t: fields[1].parse()?,
                    id: fields[2].parse()?,
                    predicted: fields[3] == "1",
                });
            }
            "pred" => {
                anyhow::ensure!(fields.len() == 5, "{}: want 5 fields", ctx());
                preds.push(Prediction {
                    avail: fields[1].parse()?,
                    t0: fields[2].parse()?,
                    window: fields[3].parse()?,
                    fault_id: if fields[4] == "-" { None } else { Some(fields[4].parse()?) },
                });
            }
            other => anyhow::bail!("{}: unknown record '{other}'", ctx()),
        }
    }
    Ok(VecSource::new(faults, preds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::trace::TraceGen;

    #[test]
    fn round_trip() {
        let s = Scenario::paper(1 << 16, Predictor::windowed(0.85, 0.82, 300.0));
        let mut gen = TraceGen::new(&s, 600.0, 42, 0).unwrap();
        let mut buf = Vec::new();
        let (nf, np) = write_trace(&mut buf, &mut gen, 2e6).unwrap();
        assert!(nf > 5 && np > 3, "nf={nf} np={np}");

        let mut replay = read_trace(std::io::BufReader::new(&buf[..])).unwrap();
        let mut gen2 = TraceGen::new(&s, 600.0, 42, 0).unwrap();
        for _ in 0..nf {
            let a = replay.next_fault().unwrap();
            let b = gen2.next_fault().unwrap();
            assert_eq!(a.id, b.id);
            assert!((a.t - b.t).abs() < 1e-9);
            assert_eq!(a.predicted, b.predicted);
        }
    }

    #[test]
    fn round_trip_is_bit_identical_for_both_prediction_kinds() {
        // Generated trace -> write -> read back: every event must be
        // reproduced *bit for bit* (Rust's f64 Display is shortest
        // round-trip, so the CSV form loses nothing), for window
        // predictions and exact-date predictions alike.
        for window in [0.0, 300.0] {
            let pred = if window > 0.0 {
                Predictor::windowed(0.7, 0.4, window)
            } else {
                Predictor::exact(0.7, 0.4)
            };
            let mut s = Scenario::paper(1 << 16, pred);
            s.fault_dist = crate::dist::DistSpec::weibull(0.7);

            let mut gen = TraceGen::new(&s, 600.0, 11, 2).unwrap();
            let mut buf = Vec::new();
            let (nf, np) = write_trace(&mut buf, &mut gen, 3e6).unwrap();
            assert!(nf > 10 && np > 5, "window {window}: nf={nf} np={np}");

            let mut replay = read_trace(std::io::BufReader::new(&buf[..])).unwrap();
            let mut fresh = TraceGen::new(&s, 600.0, 11, 2).unwrap();
            for i in 0..nf {
                let a = replay.next_fault().expect("replay fault");
                let b = fresh.next_fault().expect("fresh fault");
                assert_eq!(a.t.to_bits(), b.t.to_bits(), "window {window} fault {i}");
                assert_eq!(a, b, "window {window} fault {i}");
            }
            for i in 0..np {
                let a = replay.next_prediction().expect("replay pred");
                let b = fresh.next_prediction().expect("fresh pred");
                assert_eq!(a.avail.to_bits(), b.avail.to_bits(), "window {window} pred {i}");
                assert_eq!(a.t0.to_bits(), b.t0.to_bits(), "window {window} pred {i}");
                assert_eq!(a.window.to_bits(), b.window.to_bits(), "window {window} pred {i}");
                assert_eq!(a.fault_id, b.fault_id, "window {window} pred {i}");
                if window == 0.0 {
                    assert_eq!(a.window, 0.0, "exact predictor must stay exact");
                }
            }
            // The replay source is exhausted exactly at the horizon cut.
            assert!(replay.next_fault().is_none());
            assert!(replay.next_prediction().is_none());
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(read_trace(std::io::BufReader::new("fault,1.0".as_bytes())).is_err());
        assert!(read_trace(std::io::BufReader::new("bogus,1,2,3".as_bytes())).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let src = "# hello\n\nfault,10.0,0,1\n";
        let mut v = read_trace(std::io::BufReader::new(src.as_bytes())).unwrap();
        assert_eq!(v.next_fault().unwrap().t, 10.0);
    }
}
