//! Batched closed-form evaluation: the (strategy × period × scenario)
//! grid in chunked, auto-vectorization-friendly flat passes.
//!
//! [`super::optimize`] answers one `(Params, StrategyKind)` cell at a
//! time; a platform sweep or figure grid calls it thousands of times,
//! re-deriving every shared subexpression per call. This module lays a
//! block of scenarios out as struct-of-arrays columns (one `f64`
//! column per [`Params`] field, same order as
//! [`Params::to_raw_row`], plus the derived columns the waste
//! equations share), then evaluates each strategy's optimal period and
//! waste as flat elementwise loops over the block.
//!
//! Every expression mirrors the scalar path term for term —
//! [`super::t_extr`]/[`super::t_cap`] for the periods,
//! [`super::waste_of`] for the waste, [`super::tp_opt`]'s snapping for
//! the proactive period — so the documented tolerance contract
//! (≤ 1e-12 relative vs. the scalar closed form, f64 throughout,
//! unlike the f32 `to_raw_row` HLO path) holds trivially: in practice
//! the outputs are bit-identical, which the unit tests pin. The
//! `pjrt`-gated HLO batcher remains the preferred backend when
//! artifacts are present ([`crate::api::Executor`] tries it first);
//! this is the fast CPU fallback that replaces the scalar loop.

use super::{Capping, OptimalPlan, Params, StrategyKind, NSTRAT_USIZE};

/// Scenarios evaluated per struct-of-arrays block. Sized so the whole
/// working set (17 columns × 64 lanes × 8 bytes ≈ 9 KB) stays in L1.
pub const GRID_CHUNK: usize = 64;

/// One struct-of-arrays block of scenario parameters: the raw columns
/// in [`Params::to_raw_row`] order plus the derived quantities every
/// waste equation shares, computed once per block in flat loops.
struct ParamsBlock {
    len: usize,
    mu: [f64; GRID_CHUNK],
    c: [f64; GRID_CHUNK],
    d: [f64; GRID_CHUNK],
    r_rec: [f64; GRID_CHUNK],
    recall: [f64; GRID_CHUNK],
    precision: [f64; GRID_CHUNK],
    i: [f64; GRID_CHUNK],
    ef: [f64; GRID_CHUNK],
    alpha: [f64; GRID_CHUNK],
    m: [f64; GRID_CHUNK],
    // Derived columns (same expressions as the `Params` accessors).
    dr: [f64; GRID_CHUNK],
    inv_mu_p: [f64; GRID_CHUNK],
    inv_mu_np: [f64; GRID_CHUNK],
    mu_e: [f64; GRID_CHUNK],
    i1: [f64; GRID_CHUNK],
    frac_reg: [f64; GRID_CHUNK],
    tp: [f64; GRID_CHUNK],
}

impl ParamsBlock {
    fn load(params: &[Params]) -> ParamsBlock {
        debug_assert!(params.len() <= GRID_CHUNK);
        let mut b = ParamsBlock {
            len: params.len(),
            mu: [0.0; GRID_CHUNK],
            c: [0.0; GRID_CHUNK],
            d: [0.0; GRID_CHUNK],
            r_rec: [0.0; GRID_CHUNK],
            recall: [0.0; GRID_CHUNK],
            precision: [0.0; GRID_CHUNK],
            i: [0.0; GRID_CHUNK],
            ef: [0.0; GRID_CHUNK],
            alpha: [0.0; GRID_CHUNK],
            m: [0.0; GRID_CHUNK],
            dr: [0.0; GRID_CHUNK],
            inv_mu_p: [0.0; GRID_CHUNK],
            inv_mu_np: [0.0; GRID_CHUNK],
            mu_e: [0.0; GRID_CHUNK],
            i1: [0.0; GRID_CHUNK],
            frac_reg: [0.0; GRID_CHUNK],
            tp: [0.0; GRID_CHUNK],
        };
        for (l, p) in params.iter().enumerate() {
            b.mu[l] = p.mu;
            b.c[l] = p.c;
            b.d[l] = p.d;
            b.r_rec[l] = p.r_rec;
            b.recall[l] = p.recall;
            b.precision[l] = p.precision;
            b.i[l] = p.i;
            b.ef[l] = p.ef;
            b.alpha[l] = p.alpha;
            b.m[l] = p.m;
        }
        let n = b.len;
        for l in 0..n {
            b.dr[l] = b.d[l] + b.r_rec[l];
        }
        for l in 0..n {
            b.inv_mu_p[l] = if b.recall[l] == 0.0 {
                0.0
            } else {
                b.recall[l] / (b.precision[l] * b.mu[l])
            };
        }
        for l in 0..n {
            b.inv_mu_np[l] = (1.0 - b.recall[l]) / b.mu[l];
        }
        for l in 0..n {
            let inv = b.inv_mu_p[l] + b.inv_mu_np[l];
            b.mu_e[l] = if inv == 0.0 { f64::INFINITY } else { 1.0 / inv };
        }
        for l in 0..n {
            b.i1[l] = (1.0 - b.precision[l]) * b.i[l] + b.precision[l] * b.ef[l];
        }
        for l in 0..n {
            b.frac_reg[l] = (1.0 - b.i1[l] * b.inv_mu_p[l]).clamp(0.0, 1.0);
        }
        for l in 0..n {
            b.tp[l] = tp_opt_lane(b.i[l], b.c[l], b.precision[l], b.i1[l]);
        }
        b
    }

    /// Lane mirror of [`super::t_extr`].
    fn t_extr(&self, l: usize, q: f64) -> f64 {
        let denom = 1.0 - self.recall[l] * q;
        if denom <= 0.0 {
            f64::INFINITY
        } else {
            (2.0 * self.mu[l] * self.c[l] / denom).sqrt()
        }
    }

    /// Lane mirror of [`super::t_cap`].
    fn t_cap(&self, l: usize, kind: StrategyKind) -> f64 {
        match kind {
            StrategyKind::Young => self.alpha[l] * self.mu[l],
            StrategyKind::ExactPrediction | StrategyKind::Migration => {
                self.alpha[l] * self.mu_e[l]
            }
            StrategyKind::Instant | StrategyKind::NoCkptI | StrategyKind::WithCkptI => {
                self.alpha[l] * self.mu_e[l] - self.i[l]
            }
        }
    }

    /// Lane mirror of [`super::waste_exact_q`].
    fn waste_exact_q(&self, l: usize, t: f64, q: f64) -> f64 {
        let rq = self.recall[l] * q;
        self.c[l] / t
            + (1.0 / self.mu[l])
                * ((1.0 - rq) * t / 2.0
                    + self.dr[l]
                    + rq / self.precision[l].max(1e-12) * self.c[l])
    }

    /// Lane mirror of [`super::waste_of`] (q = 1, q = 0 for Young; the
    /// block's snapped `tp` column feeds WithCkptI).
    fn waste_of(&self, l: usize, kind: StrategyKind, t: f64) -> f64 {
        match kind {
            StrategyKind::Young => {
                self.c[l] / t + (t / 2.0 + self.dr[l]) / self.mu[l]
            }
            StrategyKind::ExactPrediction => self.waste_exact_q(l, t, 1.0),
            StrategyKind::Instant => {
                self.waste_exact_q(l, t, 1.0)
                    + self.recall[l] / self.mu[l] * self.ef[l].min(t / 2.0)
            }
            StrategyKind::NoCkptI => {
                let inv_mup = self.inv_mu_p[l];
                let inv_munp = self.inv_mu_np[l];
                let frac_reg = self.frac_reg[l];
                (frac_reg / t + inv_mup) * self.c[l]
                    + self.precision[l] * inv_mup * self.ef[l]
                    + frac_reg * inv_munp * t / 2.0
                    + (self.precision[l] * inv_mup + frac_reg * inv_munp) * self.dr[l]
            }
            StrategyKind::WithCkptI => {
                let inv_mup = self.inv_mu_p[l];
                let inv_munp = self.inv_mu_np[l];
                let frac_reg = self.frac_reg[l];
                (frac_reg / t + self.i1[l] * inv_mup / self.tp[l] + inv_mup) * self.c[l]
                    + self.precision[l] * inv_mup * self.tp[l]
                    + frac_reg * inv_munp * t / 2.0
                    + (self.precision[l] * inv_mup + frac_reg * inv_munp) * self.dr[l]
            }
            StrategyKind::Migration => {
                let rq = self.recall[l] * 1.0;
                self.c[l] / t
                    + (1.0 / self.mu[l])
                        * ((1.0 - rq) * (t / 2.0 + self.dr[l])
                            + rq / self.precision[l].max(1e-12) * self.m[l])
            }
        }
    }

    /// Fill `(t_out, w_out)` for one strategy over the block — the
    /// lane-wise mirror of [`super::optimize`], including Instant's
    /// piecewise three-candidate argmin and the inadmissibility masks.
    fn optimize_kind(
        &self,
        kind: StrategyKind,
        capping: Capping,
        t_out: &mut [f64],
        w_out: &mut [f64],
    ) {
        for l in 0..self.len {
            if kind == StrategyKind::Instant && self.ef[l] > 0.0 {
                let cap = self.t_cap(l, kind);
                let clamp = |t: f64| match capping {
                    Capping::Uncapped => t.max(self.c[l]),
                    Capping::Capped => t.max(self.c[l]).min(cap).max(self.c[l]),
                };
                let kink = 2.0 * self.ef[l];
                let candidates = [
                    clamp(self.t_extr(l, 1.0)),
                    clamp(self.t_extr(l, 0.0)),
                    clamp(kink),
                ];
                let (mut best_t, mut best_w) = (candidates[0], f64::INFINITY);
                for t in candidates {
                    let w = self.waste_of(l, kind, t);
                    if w < best_w {
                        best_w = w;
                        best_t = t;
                    }
                }
                let mut w = best_w;
                if capping == Capping::Capped && cap < self.c[l] {
                    w = 1.0;
                }
                t_out[l] = best_t;
                w_out[l] = w.min(1.0);
                continue;
            }
            let q = if kind == StrategyKind::Young { 0.0 } else { 1.0 };
            let extr = self.t_extr(l, q);
            let t = match capping {
                Capping::Uncapped => extr.max(self.c[l]).min(1e18),
                Capping::Capped => {
                    let cap = self.t_cap(l, kind);
                    extr.max(self.c[l]).min(cap).max(self.c[l])
                }
            };
            let mut w = self.waste_of(l, kind, t);
            if capping == Capping::Capped && self.t_cap(l, kind) < self.c[l] {
                w = 1.0;
            }
            if kind == StrategyKind::WithCkptI && self.i[l] < self.c[l] {
                w = 1.0;
            }
            t_out[l] = t;
            w_out[l] = w.min(1.0);
        }
    }
}

/// Lane mirror of [`super::tp_opt`] (via [`super::tp_extr`] and
/// [`super::tp_share`]) over raw column values.
fn tp_opt_lane(i: f64, c: f64, precision: f64, i1: f64) -> f64 {
    let extr = (i1 / precision.max(1e-12) * c).max(0.0).sqrt().max(1e-9);
    if i <= 0.0 {
        return c.max(extr);
    }
    let share = |tp: f64| i1 / precision.max(1e-12) * c / tp + tp;
    let k = (i / extr).floor().max(1.0);
    let cand1 = i / k;
    let cand2 = i / (k + 1.0);
    let mut tp = if share(cand1) <= share(cand2) { cand1 } else { cand2 };
    if tp < c {
        tp = cand1.max(c);
    }
    tp.max(c)
}

/// The full (strategy × scenario) optimum grid as flat row-major
/// arrays: `period[row * NSTRAT + kind]` / `waste[row * NSTRAT + kind]`.
#[derive(Debug, Clone)]
pub struct WasteGrid {
    /// Scenario rows evaluated.
    pub n: usize,
    /// Row-major optimal period per (scenario, strategy).
    pub period: Vec<f64>,
    /// Row-major waste at the optimal period, clamped to 1.
    pub waste: Vec<f64>,
}

/// Evaluate the full (strategy × period × scenario) grid in chunked
/// struct-of-arrays passes: for each scenario row, every strategy's
/// optimal period and the waste there. One call replaces
/// `params.len() × 6` scalar [`super::optimize`] calls; the outputs
/// agree with the scalar path within the documented tolerance
/// (≤ 1e-12 relative; bit-identical in practice).
pub fn waste_grid_batched(params: &[Params], capping: Capping) -> WasteGrid {
    let n = params.len();
    let mut period = vec![0.0; n * NSTRAT_USIZE];
    let mut waste = vec![1.0; n * NSTRAT_USIZE];
    let mut t_col = [0.0; GRID_CHUNK];
    let mut w_col = [0.0; GRID_CHUNK];
    for (ci, chunk) in params.chunks(GRID_CHUNK).enumerate() {
        let block = ParamsBlock::load(chunk);
        let base = ci * GRID_CHUNK;
        for kind in StrategyKind::ALL {
            block.optimize_kind(kind, capping, &mut t_col[..block.len], &mut w_col[..block.len]);
            for l in 0..block.len {
                period[(base + l) * NSTRAT_USIZE + kind as usize] = t_col[l];
                waste[(base + l) * NSTRAT_USIZE + kind as usize] = w_col[l];
            }
        }
    }
    WasteGrid { n, period, waste }
}

/// Batched [`super::optimize`] for a single strategy: per-row
/// `(period, waste)` pairs. The figure grids use this to evaluate one
/// strategy across a whole scenario axis in chunked flat passes.
pub fn optimize_batched(
    params: &[Params],
    kind: StrategyKind,
    capping: Capping,
) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(params.len());
    let mut t_col = [0.0; GRID_CHUNK];
    let mut w_col = [0.0; GRID_CHUNK];
    for chunk in params.chunks(GRID_CHUNK) {
        let block = ParamsBlock::load(chunk);
        block.optimize_kind(kind, capping, &mut t_col[..block.len], &mut w_col[..block.len]);
        for l in 0..block.len {
            out.push((t_col[l], w_col[l]));
        }
    }
    out
}

/// Batched [`super::plan`]: one [`OptimalPlan`] per scenario row, with
/// the same winner rule (argmin by `total_cmp`, migration filtered
/// unless requested) applied to the batched grid.
pub fn plan_batched(
    params: &[Params],
    capping: Capping,
    include_migration: bool,
) -> Vec<OptimalPlan> {
    let grid = waste_grid_batched(params, capping);
    (0..grid.n)
        .map(|row| {
            let mut period = [0.0; 6];
            let mut waste = [1.0; 6];
            let base = row * NSTRAT_USIZE;
            period.copy_from_slice(&grid.period[base..base + NSTRAT_USIZE]);
            waste.copy_from_slice(&grid.waste[base..base + NSTRAT_USIZE]);
            let winner = StrategyKind::ALL
                .into_iter()
                .filter(|k| include_migration || *k != StrategyKind::Migration)
                .min_by(|a, b| waste[*a as usize].total_cmp(&waste[*b as usize]))
                .unwrap();
            let q = if winner == StrategyKind::Young { 0 } else { 1 };
            OptimalPlan { period, waste, winner, q }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{paper_proc_counts, Predictor, Scenario};
    use crate::model::{optimize, plan};

    /// The §5 study grid: every paper platform size × both predictors ×
    /// exact/short-window/long-window, under both cappings.
    fn study_params() -> Vec<Params> {
        let mut out = Vec::new();
        for n in paper_proc_counts() {
            for (recall, precision) in [(0.85, 0.82), (0.7, 0.4)] {
                for window in [0.0, 300.0, 3000.0] {
                    let pred = if window > 0.0 {
                        Predictor::windowed(recall, precision, window)
                    } else {
                        Predictor::exact(recall, precision)
                    };
                    out.push(Params::from_scenario(&Scenario::paper(n, pred)));
                }
            }
        }
        // Degenerate corners: no predictor, perfect predictor.
        out.push(Params::from_scenario(&Scenario::paper(1 << 16, Predictor::none())));
        out.push(Params::from_scenario(&Scenario::paper(1 << 16, Predictor::exact(1.0, 1.0))));
        out
    }

    #[test]
    fn batched_grid_is_bit_identical_to_scalar_optimize() {
        // The documented contract is ≤ 1e-12 relative; the pin is the
        // stronger property the mirrored expressions actually deliver.
        let params = study_params();
        for capping in [Capping::Uncapped, Capping::Capped] {
            let grid = waste_grid_batched(&params, capping);
            for (row, p) in params.iter().enumerate() {
                for kind in StrategyKind::ALL {
                    let (t, w) = optimize(p, kind, capping);
                    let bt = grid.period[row * NSTRAT_USIZE + kind as usize];
                    let bw = grid.waste[row * NSTRAT_USIZE + kind as usize];
                    assert_eq!(bt.to_bits(), t.to_bits(), "{kind} row {row} {capping:?}");
                    assert_eq!(bw.to_bits(), w.to_bits(), "{kind} row {row} {capping:?}");
                }
            }
        }
    }

    #[test]
    fn plan_batched_matches_scalar_plan() {
        let params = study_params();
        for capping in [Capping::Uncapped, Capping::Capped] {
            for include_migration in [false, true] {
                let batched = plan_batched(&params, capping, include_migration);
                assert_eq!(batched.len(), params.len());
                for (p, b) in params.iter().zip(&batched) {
                    let s = plan(p, capping, include_migration);
                    assert_eq!(b.winner, s.winner, "{capping:?}");
                    assert_eq!(b.q, s.q);
                    for k in 0..NSTRAT_USIZE {
                        assert_eq!(b.waste[k].to_bits(), s.waste[k].to_bits());
                        assert_eq!(b.period[k].to_bits(), s.period[k].to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn optimize_batched_single_kind_matches() {
        let params = study_params();
        let rows = optimize_batched(&params, StrategyKind::WithCkptI, Capping::Capped);
        for (p, (t, w)) in params.iter().zip(&rows) {
            let (st, sw) = optimize(p, StrategyKind::WithCkptI, Capping::Capped);
            assert_eq!(t.to_bits(), st.to_bits());
            assert_eq!(w.to_bits(), sw.to_bits());
        }
    }

    #[test]
    fn chunk_boundaries_do_not_perturb_rows() {
        // More rows than one chunk: the block split is invisible.
        let one = Params::from_scenario(&Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82)));
        let many = vec![one; GRID_CHUNK + 7];
        let grid = waste_grid_batched(&many, Capping::Uncapped);
        let first = &grid.waste[..NSTRAT_USIZE];
        for row in 1..many.len() {
            let w = &grid.waste[row * NSTRAT_USIZE..(row + 1) * NSTRAT_USIZE];
            assert_eq!(w, first, "row {row}");
        }
    }
}
