//! Time-unit helpers. The canonical internal unit is the **second** (f64);
//! the paper quotes minutes (C = R = 10 mn) and the predictor literature
//! quotes seconds (I = 300 s) — conversions live here so call sites stay
//! unit-honest.

/// Seconds per minute.
pub const MIN: f64 = 60.0;
/// Seconds per hour.
pub const HOUR: f64 = 3_600.0;
/// Seconds per day.
pub const DAY: f64 = 86_400.0;
/// Seconds per (Julian) year, used for the paper's mu_ind = 125 years.
pub const YEAR: f64 = 365.25 * DAY;

/// Convert seconds to days (for the paper's execution-time tables).
pub fn to_days(seconds: f64) -> f64 {
    seconds / DAY
}

/// Convert minutes to seconds.
pub fn minutes(m: f64) -> f64 {
    m * MIN
}

/// Human-readable duration, e.g. "2d 3h 04m".
pub fn human(seconds: f64) -> String {
    if !seconds.is_finite() {
        return format!("{seconds}");
    }
    let total = seconds.max(0.0);
    let d = (total / DAY).floor() as u64;
    let rem = total - d as f64 * DAY;
    let h = (rem / HOUR).floor() as u64;
    let m = ((rem - h as f64 * HOUR) / MIN).floor() as u64;
    if d > 0 {
        format!("{d}d {h}h {m:02}m")
    } else if h > 0 {
        format!("{h}h {m:02}m")
    } else if total >= MIN {
        format!("{m}m {:02.0}s", total - m as f64 * MIN)
    } else {
        format!("{total:.1}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants() {
        assert_eq!(minutes(10.0), 600.0);
        assert_eq!(to_days(DAY * 2.5), 2.5);
    }

    #[test]
    fn human_format() {
        assert_eq!(human(30.0), "30.0s");
        assert_eq!(human(90.0), "1m 30s");
        assert_eq!(human(HOUR * 2.0 + 120.0), "2h 02m");
        assert_eq!(human(DAY + HOUR * 3.0 + 240.0), "1d 3h 04m");
    }

    #[test]
    fn human_handles_nonfinite() {
        assert_eq!(human(f64::INFINITY), "inf");
    }
}
