//! Leader/worker parallelism over std::thread (substrate: no tokio/rayon
//! offline). Scoped threads + an atomic work index give dynamic load
//! balancing without channels — replication workloads are embarrassingly
//! parallel but very uneven (BestPeriod candidates differ by 10x in
//! simulated events), so static chunking would waste cores.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count: `CKPTFP_WORKERS` env override, else available
/// parallelism, else 4.
pub fn available_workers() -> usize {
    if let Ok(v) = std::env::var("CKPTFP_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every item on `workers` threads; returns results in
/// input order. Panics in `f` propagate after all workers stop.
pub fn run_parallel<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slot_ptr = SlotsPtr(slots.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let items = &items;
            let f = &f;
            let slot_ptr = &slot_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index i is claimed by exactly one worker
                // (fetch_add is unique), and `slots` outlives the scope.
                unsafe { *slot_ptr.0.add(i) = Some(r) };
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker failed to fill slot")).collect()
}

/// Send+Sync wrapper for the raw result pointer; soundness argument in
/// `run_parallel` (disjoint writes, scoped lifetime).
struct SlotsPtr<R>(*mut Option<R>);
unsafe impl<R: Send> Send for SlotsPtr<R> {}
unsafe impl<R: Send> Sync for SlotsPtr<R> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = run_parallel(items, 8, |x| x * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 2);
        }
    }

    #[test]
    fn single_worker_path() {
        let out = run_parallel(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<u32> = run_parallel(Vec::<u32>::new(), 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_is_balanced() {
        // Tasks with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).collect();
        let out = run_parallel(items, 8, |x| {
            let mut acc = 0u64;
            for i in 0..(x % 7) * 10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn workers_env_override() {
        assert!(available_workers() >= 1);
    }
}
