//! Policy *specifications* — the config-level form of a checkpoint
//! policy, mirroring the [`crate::dist::DistSpec`] / [`crate::dist::Dist`]
//! split: [`PolicySpec`] is typed data with `FromStr`/`Display` at the
//! wire edge (JSONL `policy` field, TOML `[policy]` tables, the CLI
//! `--policy` flag), and [`resolve_policy`] materializes the runtime
//! [`Policy`] against a concrete [`Scenario`].
//!
//! Spec strings:
//!
//! * any [`StrategyKind`] name (`"Young"`, `"ExactPrediction"`, …,
//!   case-insensitive) — the paper strategy with its closed-form
//!   period;
//! * `"adaptive"` or `"adaptive:GAIN"` — [`Policy::AdaptivePeriod`]
//!   with the scenario MTBF as prior and the given period gain
//!   (default 1);
//! * `"risk"` or `"risk:KAPPA"` — [`Policy::RiskThreshold`]
//!   checkpointing when the accumulated risk of the unprotected work
//!   reaches `KAPPA * C` (default 1).

use crate::config::Scenario;
use crate::model::{Capping, StrategyKind};
use crate::sim::Policy;
use crate::strategies::{exactify, spec_for, ProactiveMode};

/// A checkpoint policy as configuration data, resolvable against any
/// scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicySpec {
    /// One of the paper's strategies; the regular period comes from the
    /// closed form ([`spec_for`], §5 `Uncapped` convention).
    Strategy(StrategyKind),
    /// Young's period re-derived online from the observed fault rate,
    /// scaled by `gain`. Ignores the predictor (q = 0), like Young.
    AdaptivePeriod { gain: f64 },
    /// Checkpoint when the expected loss of the unprotected work
    /// (`vol^2 / 2mu` under constant hazard) reaches `kappa * C`.
    /// Trusts every prediction (q = 1, `CkptBefore` response).
    RiskThreshold { kappa: f64 },
}

impl PolicySpec {
    /// Reject parameterizations the simulator cannot honor. `FromStr`
    /// already enforces this; direct construction goes through here via
    /// [`resolve_policy`].
    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            PolicySpec::Strategy(_) => Ok(()),
            PolicySpec::AdaptivePeriod { gain } => {
                anyhow::ensure!(
                    gain.is_finite() && *gain > 0.0,
                    "adaptive gain must be finite and positive in policy spec '{self}'"
                );
                Ok(())
            }
            PolicySpec::RiskThreshold { kappa } => {
                anyhow::ensure!(
                    kappa.is_finite() && *kappa > 0.0,
                    "risk threshold kappa must be finite and positive in policy spec '{self}'"
                );
                Ok(())
            }
        }
    }
}

impl std::fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PolicySpec::Strategy(k) => f.write_str(k.name()),
            PolicySpec::AdaptivePeriod { gain } => write!(f, "adaptive:{gain}"),
            PolicySpec::RiskThreshold { kappa } => write!(f, "risk:{kappa}"),
        }
    }
}

impl std::str::FromStr for PolicySpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<PolicySpec> {
        let t = s.trim();
        if let Ok(kind) = t.parse::<StrategyKind>() {
            return Ok(PolicySpec::Strategy(kind));
        }
        let (head, param) = match t.split_once(':') {
            Some((h, p)) => (h, Some(p)),
            None => (t, None),
        };
        let number = |name: &str| -> anyhow::Result<f64> {
            match param {
                None => Ok(1.0),
                Some(raw) => raw.parse::<f64>().map_err(|_| {
                    anyhow::anyhow!("bad {name} in policy spec '{s}' (expected a number)")
                }),
            }
        };
        let spec = match head.to_ascii_lowercase().as_str() {
            "adaptive" | "adaptiveperiod" => PolicySpec::AdaptivePeriod { gain: number("gain")? },
            "risk" | "riskthreshold" => PolicySpec::RiskThreshold { kappa: number("kappa")? },
            _ => anyhow::bail!(
                "unknown policy '{s}' (expected a strategy name — Young, ExactPrediction, \
                 Instant, NoCkptI, WithCkptI, Migration — or adaptive[:gain] / risk[:kappa])"
            ),
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// A [`PolicySpec`] resolved against one scenario: the effective
/// scenario (EXACTPREDICTION runs against the exact-date variant of
/// the trace, per §5), the runtime [`Policy`], and a display name for
/// reports and wire responses.
#[derive(Debug, Clone)]
pub struct ResolvedPolicy {
    pub scenario: Scenario,
    pub policy: Policy,
    pub name: String,
}

/// Materialize a policy spec for one scenario. For paper strategies
/// the result is bit-identical to the classic
/// `scenario_for` + [`spec_for`] path (pinned in
/// `tests/test_policies.rs`).
pub fn resolve_policy(spec: &PolicySpec, scenario: &Scenario) -> anyhow::Result<ResolvedPolicy> {
    spec.validate()?;
    scenario.validate()?;
    let c = scenario.platform.c;
    Ok(match *spec {
        PolicySpec::Strategy(kind) => {
            let s = if kind == StrategyKind::ExactPrediction {
                exactify(scenario)
            } else {
                scenario.clone()
            };
            let sspec = spec_for(kind, &s, Capping::Uncapped);
            let policy = Policy::from_spec(&sspec, c);
            ResolvedPolicy { scenario: s, policy, name: sspec.name }
        }
        PolicySpec::AdaptivePeriod { gain } => ResolvedPolicy {
            scenario: scenario.clone(),
            policy: Policy::AdaptivePeriod {
                mu0: scenario.mu(),
                gain,
                q: 0.0,
                proactive: ProactiveMode::Ignore,
            },
            name: spec.to_string(),
        },
        PolicySpec::RiskThreshold { kappa } => {
            // Risk kappa*C is reached at vol = sqrt(2 kappa mu C);
            // floored at 1 s so progress is always possible.
            let w_star = (2.0 * kappa * scenario.mu() * c).sqrt().max(1.0);
            ResolvedPolicy {
                scenario: scenario.clone(),
                policy: Policy::RiskThreshold {
                    w_star,
                    q: 1.0,
                    proactive: ProactiveMode::CkptBefore,
                },
                name: spec.to_string(),
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;

    fn scenario() -> Scenario {
        Scenario::paper(1 << 16, Predictor::windowed(0.85, 0.82, 300.0))
    }

    #[test]
    fn spec_strings_round_trip() {
        let specs = [
            PolicySpec::Strategy(StrategyKind::Young),
            PolicySpec::Strategy(StrategyKind::WithCkptI),
            PolicySpec::AdaptivePeriod { gain: 1.0 },
            PolicySpec::AdaptivePeriod { gain: 0.5 },
            PolicySpec::RiskThreshold { kappa: 1.0 },
            PolicySpec::RiskThreshold { kappa: 2.25 },
        ];
        for spec in specs {
            let s = spec.to_string();
            assert_eq!(s.parse::<PolicySpec>().unwrap(), spec, "round-trip of '{s}'");
        }
        // Case-insensitive and defaulted forms.
        assert_eq!("young".parse::<PolicySpec>().unwrap(), PolicySpec::Strategy(StrategyKind::Young));
        assert_eq!("adaptive".parse::<PolicySpec>().unwrap(), PolicySpec::AdaptivePeriod { gain: 1.0 });
        assert_eq!("RISK".parse::<PolicySpec>().unwrap(), PolicySpec::RiskThreshold { kappa: 1.0 });
        assert_eq!(
            "risk:0.5".parse::<PolicySpec>().unwrap(),
            PolicySpec::RiskThreshold { kappa: 0.5 }
        );
    }

    #[test]
    fn invalid_specs_are_rejected() {
        assert!("daly".parse::<PolicySpec>().is_err());
        assert!("risk:zero".parse::<PolicySpec>().is_err());
        assert!("risk:-1".parse::<PolicySpec>().is_err());
        assert!("adaptive:0".parse::<PolicySpec>().is_err());
        assert!(PolicySpec::RiskThreshold { kappa: f64::NAN }.validate().is_err());
        assert!(resolve_policy(&PolicySpec::AdaptivePeriod { gain: -1.0 }, &scenario()).is_err());
    }

    #[test]
    fn strategy_resolution_matches_spec_for() {
        let s = scenario();
        for kind in StrategyKind::ALL {
            let rp = resolve_policy(&PolicySpec::Strategy(kind), &s).unwrap();
            let expected_scenario =
                if kind == StrategyKind::ExactPrediction { exactify(&s) } else { s.clone() };
            assert_eq!(rp.scenario, expected_scenario, "{kind}");
            let sspec = spec_for(kind, &expected_scenario, Capping::Uncapped);
            assert_eq!(rp.policy, Policy::from_spec(&sspec, s.platform.c), "{kind}");
            assert_eq!(rp.name, sspec.name);
        }
    }

    #[test]
    fn risk_threshold_scale() {
        let s = scenario();
        let rp = resolve_policy(&PolicySpec::RiskThreshold { kappa: 1.0 }, &s).unwrap();
        match rp.policy {
            Policy::RiskThreshold { w_star, q, .. } => {
                let expected = (2.0 * s.mu() * s.platform.c).sqrt();
                assert!((w_star - expected).abs() < 1e-9);
                assert_eq!(q, 1.0);
            }
            other => panic!("wrong policy: {other:?}"),
        }
        // kappa scales the threshold by sqrt(kappa).
        let rp4 = resolve_policy(&PolicySpec::RiskThreshold { kappa: 4.0 }, &s).unwrap();
        match (rp.policy, rp4.policy) {
            (Policy::RiskThreshold { w_star: w1, .. }, Policy::RiskThreshold { w_star: w4, .. }) => {
                assert!((w4 / w1 - 2.0).abs() < 1e-9);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn adaptive_prior_is_the_scenario_mtbf() {
        let s = scenario();
        let rp = resolve_policy(&PolicySpec::AdaptivePeriod { gain: 1.0 }, &s).unwrap();
        match rp.policy {
            Policy::AdaptivePeriod { mu0, gain, q, proactive } => {
                assert_eq!(mu0, s.mu());
                assert_eq!(gain, 1.0);
                assert_eq!(q, 0.0);
                assert_eq!(proactive, ProactiveMode::Ignore);
            }
            other => panic!("wrong policy: {other:?}"),
        }
        assert_eq!(rp.name, "adaptive:1");
    }
}
