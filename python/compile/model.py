"""L2 JAX model: the batched checkpoint planner.

Consumes *raw* user-facing parameters, derives the fault rates of §2.3,
precomputes the proactive period T_P (Eq. 7) with its integer snapping,
invokes the L1 Pallas kernel for the six waste surfaces, applies the
admissible-domain masks of §3.2 / §4.1, and reduces to the optimal
period / strategy / trust decision.

Raw parameter layout (f32[B, NRAW], shared with the Rust runtime —
``rust/src/runtime/planner_exec.rs`` must match):

    0: mu     platform MTBF (s)          5: p      predictor precision
    1: C      checkpoint duration (s)    6: I      prediction-window length (s)
    2: D      downtime (s)               7: Ef     E_I^(f) (s), I/2 if uniform
    3: R      recovery duration (s)      8: alpha  period-cap tuning (0.27)
    4: r      predictor recall           9: M      migration duration (s)

Everything here is lowered once by ``aot.py``; nothing in this module
runs at serving time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.waste_grid import COLS, NPARAM, NSTRAT, waste_grid

NRAW = 10
RAW = {"mu": 0, "C": 1, "D": 2, "R": 3, "r": 4, "p": 5, "I": 6, "Ef": 7,
       "alpha": 8, "M": 9}

_EPS = 1e-6
# Sentinel for "strategy inadmissible at this grid point".
_INVALID = jnp.float32(3.0e38)


def snap_tp(tp_extr, i_win, c):
    """Integer-snap T_P so that I / T_P is integral (§4.3).

    Candidates are I/k and I/(k+1) with k = floor(I / T_P^extr); the one
    minimizing the T_P-dependent waste share  (I1/p) C / T_P + T_P  wins
    (evaluated through its proxy: both candidates bracket the extremum of
    a convex function, so comparing the true share at the two candidates
    is exact).  If both candidates fall below C, T_P = C (paper §4.3).
    """
    tp_extr = jnp.maximum(tp_extr, _EPS)
    k = jnp.floor(i_win / tp_extr)
    k = jnp.maximum(k, 1.0)
    cand1 = i_win / k
    cand2 = i_win / (k + 1.0)
    # share(T_P) ∝ tp_extr^2 / T_P + T_P  (Eq. 7: extremum at tp_extr).
    share = lambda tp: tp_extr * tp_extr / jnp.maximum(tp, _EPS) + tp
    tp = jnp.where(share(cand1) <= share(cand2), cand1, cand2)
    tp = jnp.where(tp < c, jnp.maximum(cand1, c), tp)
    # Degenerate windows (I < C): s4 is masked out, keep T_P well-formed.
    return jnp.maximum(tp, jnp.maximum(c, _EPS))


def expand_params(raw):
    """f32[B, NRAW] -> f32[B, NPARAM] kernel parameter matrix."""
    g = lambda name: raw[:, RAW[name]]
    mu, c, d, rr = g("mu"), g("C"), g("D"), g("R")
    r, p, i_win, ef = g("r"), g("p"), g("I"), g("Ef")
    alpha, m = g("alpha"), g("M")

    p_safe = jnp.clip(p, _EPS, 1.0)
    r = jnp.clip(r, 0.0, 1.0)
    inv_mu = 1.0 / mu
    inv_mup = r / (p_safe * mu)            # 1/mu_P   (§2.3)
    inv_munp = (1.0 - r) / mu              # 1/mu_NP  (§2.3)
    i1 = (1.0 - p) * i_win + p * ef        # I' at q=1 (§4.1)
    frac_reg = jnp.clip(1.0 - i1 * inv_mup, 0.0, 1.0)
    tp = snap_tp(jnp.sqrt(jnp.maximum(i1 / p_safe * c, 0.0)), i_win, c)
    # Shared grid upper end: the widest admissible domain is Young's
    # [C, alpha*mu] (mu_e <= mu).  Keep the grid non-degenerate.
    tmax = jnp.maximum(alpha * mu, c * (1.0 + 1e-3))

    out = jnp.zeros((raw.shape[0], NPARAM), raw.dtype)
    sets = {
        "C": c, "DR": d + rr, "inv_mu": inv_mu, "r": r, "p": p_safe,
        "I": i_win, "Ef": ef, "M": m, "inv_muP": inv_mup,
        "inv_muNP": inv_munp, "frac_reg": frac_reg, "I1": i1, "TP": tp,
        "Tmax": tmax, "r_over_p": r / p_safe,
    }
    for name, val in sets.items():
        out = out.at[:, COLS[name]].set(val)
    return out


def _grid_and_masks(raw, u):
    """Period grid T[B,G] and per-strategy admissibility masks [B,S,G]."""
    g = lambda name: raw[:, RAW[name]][:, None]
    mu, c, r, p = g("mu"), g("C"), g("r"), g("p")
    i_win, alpha = g("I"), g("alpha")
    p_safe = jnp.clip(p, _EPS, 1.0)
    inv_mue = r / (p_safe * mu) + (1.0 - r) / mu
    mue = 1.0 / jnp.maximum(inv_mue, _EPS)

    tmax = jnp.maximum(alpha * mu, c * (1.0 + 1e-3))
    t = c + u[None, :] * (tmax - c)                     # [B, G]

    lim = jnp.stack(
        [
            alpha * mu,                 # s0 Young:          T <= alpha mu
            alpha * mue,                # s1 ExactPrediction T <= alpha mu_e
            alpha * mue - i_win,        # s2 Instant:   T + I <= alpha mu_e
            alpha * mue - i_win,        # s3 NoCkptI
            alpha * mue - i_win,        # s4 WithCkptI
            alpha * mue,                # s5 Migration
        ],
        axis=1,
    )                                                   # [B, S, 1]
    valid = t[:, None, :] <= lim
    # WithCkptI requires at least one proactive checkpoint: C <= I (§4).
    fits = c <= i_win                                   # [B, 1]
    s4_only = jnp.arange(NSTRAT)[None, :, None] == 4
    valid = valid & (~s4_only | fits[:, :, None])
    return t, valid


def masked_surfaces(raw, u):
    """(waste[B,S,G] with inadmissible points at +INVALID, T[B,G])."""
    w = waste_grid(expand_params(raw), u)
    t, valid = _grid_and_masks(raw, u)
    return jnp.where(valid, w, _INVALID), t


def plan(raw, u):
    """The planner: optimal period & waste per strategy + overall winner.

    Returns (best_waste[B,S], best_T[B,S], win_s i32[B], win_waste[B],
    win_T[B]).  Wastes are clamped to 1.0 — waste 1 means "no progress"
    (§3.2), and inadmissible strategies surface as exactly 1.0.
    """
    w, t = masked_surfaces(raw, u)
    j = jnp.argmin(w, axis=2)                                  # [B, S]
    best_w = jnp.take_along_axis(w, j[:, :, None], axis=2)[..., 0]
    best_t = jnp.take_along_axis(
        jnp.broadcast_to(t[:, None, :], w.shape), j[:, :, None], axis=2
    )[..., 0]
    best_w = jnp.minimum(best_w, 1.0)
    win_s = jnp.argmin(best_w, axis=1).astype(jnp.int32)       # [B]
    win_w = jnp.take_along_axis(best_w, win_s[:, None], axis=1)[:, 0]
    win_t = jnp.take_along_axis(best_t, win_s[:, None], axis=1)[:, 0]
    return best_w, best_t, win_s, win_w, win_t


def surfaces(raw, u):
    """Figure-generation entry: masked waste surfaces + the period grid."""
    w, t = masked_surfaces(raw, u)
    return jnp.minimum(w, 1.0), t


__all__ = [
    "NRAW", "RAW", "NPARAM", "NSTRAT",
    "expand_params", "snap_tp", "masked_surfaces", "plan", "surfaces",
]
