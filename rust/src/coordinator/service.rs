//! The job service: a TCP listener speaking the JSONL job protocol
//! (v2, with the v1 planner dialect adapted transparently), one thread
//! per connection, every request dispatched through a shared
//! [`Executor`] — the same entry points the CLI and the experiment
//! harness use in-process.
//!
//! The service practices what the paper preaches about fault
//! tolerance:
//!
//! * **Admission control** — connection and in-flight-job gates shed
//!   load with a structured `overloaded` error (carrying
//!   `retry_after_ms`) instead of queueing without bound.
//! * **Request guards** — a per-request deadline rides the executor's
//!   [`crate::util::cancel::CancelToken`]; oversized lines are
//!   rejected without decoding; idle connections time out.
//! * **Panic isolation** — `catch_unwind` at the request and
//!   connection boundaries turns a poisoned request into an `internal`
//!   error on that one response, never a dead service.
//! * **Graceful drain** — [`ServiceHandle::stop`] stops accepting,
//!   lets in-flight jobs finish up to a drain deadline, then cancels
//!   cooperatively and joins every connection thread.

use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::metrics::lock_unpoisoned;
use crate::api::{wire, ApiError, ErrorCode, Executor, JobRequest, JobResponse};
use crate::util::cancel::CancelToken;
use crate::util::json::Json;

/// How often blocked reads wake to check the stop flags and the idle
/// budget. Bounds both shutdown latency and idle-check granularity.
const POLL_INTERVAL: Duration = Duration::from_millis(100);

/// Reads hard-close past this much buffered line data: beyond it there
/// is no trustworthy message boundary to resync on. Lines between
/// [`wire::MAX_LINE_BYTES`] and this bound still get a structured
/// `bad_request` and a surviving connection.
const HARD_LINE_LIMIT: usize = wire::MAX_LINE_BYTES * 4;

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address, e.g. "127.0.0.1:7471". Port 0 picks a free port.
    pub addr: String,
    /// Connection gate: accepts past this many live connections are
    /// answered `overloaded` and closed.
    pub max_conns: usize,
    /// Job gate: requests (other than `ping`/`stats`) past this many
    /// concurrently executing jobs are answered `overloaded`; the
    /// connection survives.
    pub max_inflight: usize,
    /// Per-request wall-clock budget threaded into the executor.
    /// `None` disables the guard.
    pub deadline: Option<Duration>,
    /// How long [`ServiceHandle::stop`] waits for in-flight jobs
    /// before cancelling them cooperatively.
    pub drain: Duration,
    /// Connections with no traffic for this long are closed.
    pub idle_timeout: Duration,
    /// Retry hint carried by `overloaded` responses.
    pub retry_after_ms: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:7471".into(),
            max_conns: 256,
            max_inflight: 32,
            deadline: None,
            drain: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(300),
            retry_after_ms: 250,
        }
    }
}

/// State shared by the accept loop, every connection thread and the
/// handle.
struct Shared {
    /// Graceful-stop flag: stop accepting, close idle connections.
    stop: AtomicBool,
    /// Hard-cancel flag, set once the drain deadline passes; also the
    /// cancel flag threaded into executing jobs.
    hard_cancel: Arc<AtomicBool>,
    /// Live connection threads (admission gate).
    active: AtomicUsize,
    /// Currently executing gated jobs (drain + in-flight gate).
    inflight: AtomicUsize,
    /// Connection thread handles, joined on stop.
    conns: Mutex<Vec<std::thread::JoinHandle<()>>>,
    cfg: ServiceConfig,
}

impl Shared {
    fn try_admit(&self, gate: &AtomicUsize, limit: usize) -> bool {
        gate.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
            (n < limit).then_some(n + 1)
        })
        .is_ok()
    }

    fn register(&self, handle: std::thread::JoinHandle<()>) {
        let mut conns = lock_unpoisoned(&self.conns);
        conns.retain(|h| !h.is_finished());
        conns.push(handle);
    }
}

/// Decrements a [`Shared`] counter on drop — panic-safe accounting for
/// connections and in-flight jobs.
struct CountGuard<'a>(&'a AtomicUsize);

impl Drop for CountGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Running service handle: local address + shutdown control.
pub struct ServiceHandle {
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    /// Graceful drain: stop accepting, let in-flight jobs finish up to
    /// the configured drain deadline, then cancel cooperatively and
    /// join every connection thread.
    pub fn stop(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop with a dummy connection. The bound
        // address may be unconnectable (0.0.0.0 / ::), so aim the nudge
        // at the loopback of the same family, same port.
        let mut nudge = self.addr;
        if nudge.ip().is_unspecified() {
            nudge.set_ip(match nudge.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect_timeout(&nudge, Duration::from_millis(250));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        let deadline = Instant::now() + self.shared.cfg.drain;
        while self.shared.inflight.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.shared.hard_cancel.store(true, Ordering::SeqCst);
        let handles = std::mem::take(&mut *lock_unpoisoned(&self.shared.conns));
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Start serving in background threads. The executor (its batcher
/// handle and metrics) is shared across connections.
pub fn serve(executor: Executor, cfg: ServiceConfig) -> anyhow::Result<ServiceHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let shared = Arc::new(Shared {
        stop: AtomicBool::new(false),
        hard_cancel: Arc::new(AtomicBool::new(false)),
        active: AtomicUsize::new(0),
        inflight: AtomicUsize::new(0),
        conns: Mutex::new(Vec::new()),
        cfg,
    });
    let shared2 = Arc::clone(&shared);
    let join = std::thread::Builder::new().name("ckptfp-accept".into()).spawn(move || {
        for conn in listener.incoming() {
            if shared2.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => break,
            };
            let executor = executor.clone();
            let shared3 = Arc::clone(&shared2);
            if shared2.try_admit(&shared2.active, shared2.cfg.max_conns) {
                let spawned = std::thread::Builder::new().name("ckptfp-conn".into()).spawn(
                    move || {
                        let _guard = CountGuard(&shared3.active);
                        let caught = catch_unwind(AssertUnwindSafe(|| {
                            handle_connection(stream, &executor, &shared3)
                        }));
                        if caught.is_err() {
                            executor.note_panic_contained();
                        }
                    },
                );
                match spawned {
                    Ok(h) => shared2.register(h),
                    // The closure never ran: undo the admission.
                    Err(_) => {
                        shared2.active.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            } else {
                // Over the connection gate: a short-lived thread reads
                // one line (to answer in its dialect) and sheds the
                // load with a structured `overloaded`.
                let spawned = std::thread::Builder::new().name("ckptfp-shed".into()).spawn(
                    move || reject_connection(stream, &executor, &shared3),
                );
                if let Ok(h) = spawned {
                    shared2.register(h);
                }
            }
        }
    })?;
    Ok(ServiceHandle { addr, shared, join: Some(join) })
}

fn overloaded_error(cfg: &ServiceConfig, what: &str, limit: usize) -> ApiError {
    ApiError::overloaded(
        format!(
            "service at capacity ({limit} {what}); retry after {} ms",
            cfg.retry_after_ms
        ),
        cfg.retry_after_ms,
    )
}

/// Shed one over-limit connection: read a single line (briefly) so the
/// rejection can speak the caller's dialect, answer `overloaded`,
/// close.
fn reject_connection(stream: TcpStream, executor: &Executor, shared: &Shared) {
    executor.note_overloaded();
    let _ = stream.set_read_timeout(Some(Duration::from_secs(1)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    let legacy = match reader.read_until(b'\n', &mut buf) {
        Ok(n) if n > 0 => wire::line_is_legacy(&String::from_utf8_lossy(&buf)),
        _ => false,
    };
    let e = overloaded_error(&shared.cfg, "connections", shared.cfg.max_conns);
    let line = wire::encode_response(&JobResponse::Error(e), legacy);
    let _ = writer.write_all(line.as_bytes());
    let _ = writer.write_all(b"\n");
    let _ = writer.flush();
}

/// What one poll-driven line read produced.
enum ReadOutcome {
    /// A complete line, trailing `\n` (and `\r`) stripped — raw bytes,
    /// because the length guard must run before UTF-8 validation.
    Line(Vec<u8>),
    /// Peer closed, connection errored, or the line outgrew
    /// [`HARD_LINE_LIMIT`].
    Closed,
    /// A stop flag tripped between requests, or the idle budget ran
    /// out.
    Done,
}

/// Read one `\n`-terminated line, waking every [`POLL_INTERVAL`] to
/// check the stop flags and the idle budget. `read_until` keeps
/// already-consumed bytes in `buf` across timeout ticks, so a slow
/// (or slow-loris) sender costs patience, not correctness.
fn read_line_polled(reader: &mut BufReader<TcpStream>, shared: &Shared) -> ReadOutcome {
    let mut buf: Vec<u8> = Vec::new();
    let idle_deadline = Instant::now() + shared.cfg.idle_timeout;
    loop {
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => return ReadOutcome::Closed,
            Ok(_) => {
                if buf.last() == Some(&b'\n') {
                    buf.pop();
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    return ReadOutcome::Line(buf);
                }
                // Delimiter not found but bytes arrived: EOF mid-line.
                return ReadOutcome::Closed;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                if shared.hard_cancel.load(Ordering::SeqCst) {
                    return ReadOutcome::Done;
                }
                if shared.stop.load(Ordering::SeqCst) && buf.is_empty() {
                    return ReadOutcome::Done;
                }
                if buf.len() > HARD_LINE_LIMIT {
                    return ReadOutcome::Closed;
                }
                if buf.is_empty() && Instant::now() >= idle_deadline {
                    return ReadOutcome::Done;
                }
            }
            Err(_) => return ReadOutcome::Closed,
        }
    }
}

fn handle_connection(stream: TcpStream, executor: &Executor, shared: &Shared) {
    if stream.set_read_timeout(Some(POLL_INTERVAL)).is_err() {
        return;
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let raw = match read_line_polled(&mut reader, shared) {
            ReadOutcome::Line(raw) => raw,
            ReadOutcome::Closed | ReadOutcome::Done => return,
        };
        if raw.len() > wire::MAX_LINE_BYTES {
            // Reject before decoding (and before requiring valid
            // UTF-8); sniff the dialect from the prefix only.
            executor.note_rejected();
            let head = String::from_utf8_lossy(&raw[..raw.len().min(256)]).into_owned();
            let e = ApiError::bad_request(format!(
                "request line of {} bytes exceeds the {} byte limit",
                raw.len(),
                wire::MAX_LINE_BYTES
            ));
            let resp = wire::encode_response(&JobResponse::Error(e), wire::line_is_legacy(&head));
            if !write_response(&mut writer, &resp) {
                return;
            }
            continue;
        }
        let line = match String::from_utf8(raw) {
            Ok(l) => l,
            Err(_) => {
                executor.note_rejected();
                let e = ApiError::invalid_json("request line is not valid UTF-8");
                let resp = wire::encode_response(&JobResponse::Error(e), false);
                if !write_response(&mut writer, &resp) {
                    return;
                }
                continue;
            }
        };
        #[cfg(any(test, feature = "chaos"))]
        let line = crate::chaos::mangle_service_read(line);
        if line.trim().is_empty() {
            continue;
        }
        let response = match wire::decode_request(&line) {
            Err(e) => {
                executor.note_rejected();
                // Answer in the dialect the line arrived in: a v1 line
                // that failed validation still gets the legacy error
                // shape (no "v" marker). Unparseable lines default to
                // the v2 shape — both dialects read ok:false + error.
                wire::encode_response(&JobResponse::Error(e), wire::line_is_legacy(&line))
            }
            Ok(decoded) => {
                let resp = dispatch(executor, shared, &decoded.request);
                wire::encode_response(&resp, decoded.legacy)
            }
        };
        if !write_response(&mut writer, &response) {
            return;
        }
    }
}

/// Run one decoded request through the gates: in-flight admission,
/// cooperative cancellation, per-request panic containment.
fn dispatch(executor: &Executor, shared: &Shared, req: &JobRequest) -> JobResponse {
    // `ping` and `stats` stay answerable under full load — they are
    // the probes an operator uses to see *why* the service is shedding.
    let gated = !matches!(req, JobRequest::Ping | JobRequest::Stats);
    if gated && !shared.try_admit(&shared.inflight, shared.cfg.max_inflight) {
        executor.note_overloaded();
        return JobResponse::Error(overloaded_error(
            &shared.cfg,
            "jobs in flight",
            shared.cfg.max_inflight,
        ));
    }
    let _guard = gated.then(|| CountGuard(&shared.inflight));
    let cancel = CancelToken::with_flag(Arc::clone(&shared.hard_cancel));
    let caught = catch_unwind(AssertUnwindSafe(|| executor.execute_cancellable(req, &cancel)));
    match caught {
        Ok(resp) => resp,
        Err(payload) => {
            executor.note_panic_contained();
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            JobResponse::Error(ApiError::new(
                ErrorCode::Internal,
                format!("request handler panicked: {msg}"),
            ))
        }
    }
}

fn write_response(writer: &mut TcpStream, response: &str) -> bool {
    #[cfg(any(test, feature = "chaos"))]
    crate::chaos::on_service_write();
    writer.write_all(response.as_bytes()).is_ok()
        && writer.write_all(b"\n").is_ok()
        && writer.flush().is_ok()
}

/// Minimal blocking *raw-line* client, for tests and tools that need
/// byte-level control over what goes on the wire (e.g. the v1
/// back-compat pins). Typed callers should use
/// [`crate::api::ServiceClient`] instead.
pub struct PlannerClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl PlannerClient {
    /// Read timeout applied to every [`PlannerClient`] connection — a
    /// wedged server is a clear error, not a hang.
    pub const READ_TIMEOUT: Duration = Duration::from_secs(30);

    pub fn connect(addr: &str) -> anyhow::Result<PlannerClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Self::READ_TIMEOUT))?;
        let writer = stream.try_clone()?;
        Ok(PlannerClient { reader: BufReader::new(stream), writer })
    }

    /// Send one JSONL request, read one JSONL response.
    pub fn call(&mut self, request: &str) -> anyhow::Result<Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| {
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) {
                anyhow::anyhow!(
                    "no response within the {:.0}s read timeout",
                    Self::READ_TIMEOUT.as_secs_f64()
                )
            } else {
                anyhow::Error::from(e)
            }
        })?;
        anyhow::ensure!(!line.is_empty(), "server closed the connection");
        crate::util::json::parse(line.trim())
    }
}
