"""AOT artifact emission: HLO text is produced, well-formed, and complete."""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def planner_hlo():
    return aot.lower_artifact("planner_b1", g=128)


class TestLowering:
    def test_emits_hlo_module(self, planner_hlo):
        assert planner_hlo.startswith("HloModule")

    def test_entry_layout_shapes(self, planner_hlo):
        # f32[1,10] raw params + f32[128] grid -> 5-tuple.
        assert "f32[1,10]" in planner_hlo
        assert "f32[128]" in planner_hlo

    def test_no_custom_calls(self, planner_hlo):
        """interpret=True must lower pallas to plain HLO: a Mosaic
        custom-call would be unloadable by the CPU PJRT runtime."""
        assert "custom-call" not in planner_hlo

    def test_surface_artifact(self):
        text = aot.lower_artifact("surface_b16", g=128)
        assert "f32[16,10]" in text and text.startswith("HloModule")

    def test_batch64_artifact(self):
        text = aot.lower_artifact("planner_b64", g=128)
        assert "f32[64,10]" in text

    def test_all_artifact_names_lower(self):
        for name in aot.ARTIFACTS:
            assert aot.lower_artifact(name, g=128).startswith("HloModule")


class TestManifest:
    def test_main_writes_all(self, tmp_path, monkeypatch):
        monkeypatch.setattr(
            "sys.argv",
            ["aot", "--out-dir", str(tmp_path), "--grid", "128"],
        )
        aot.main()
        for name in aot.ARTIFACTS:
            assert (tmp_path / f"{name}.hlo.txt").exists()
        manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
        assert len(manifest) == len(aot.ARTIFACTS)
        assert all(f"nraw={model.NRAW}" in line for line in manifest)
