//! Discrete-event simulation of a checkpointed execution under faults
//! and predictions.
//!
//! [`Engine`] is the discrete-event *core*: it replays one job against
//! one trace, delegating every strategic decision (regular period,
//! prediction trust, window response) to a monomorphized [`Policy`] —
//! the paper's strategies are the [`Policy::Paper`] variant, built
//! from a [`crate::strategies::StrategySpec`]. [`SimSession`]
//! amortizes the per-replication setup (spec parsing, validation,
//! buffers) across a whole batch; [`runner`] replicates across seeds
//! and streams the aggregation. [`platform`] generalizes the fault
//! process to a multi-node platform (per-node streams, coordinated
//! checkpoints, correlated failures) behind the same engine.
//! [`batch`] advances a block of replications in lockstep over a
//! shared trace-bank arena, pinned bit-identical to the scalar replay
//! path; [`wide`] goes further and keeps the whole chunk's engine
//! state in struct-of-arrays columns, sweeping every lane one
//! event-phase at a time under a lane mask (same bit-identity pin).

pub mod batch;
mod engine;
mod outcome;
pub mod platform;
pub mod policy;
mod runner;
mod session;
pub mod wide;

pub use batch::{
    fold_waste_grid, fold_waste_grid_retaining, run_replication_range_batched, BatchEngine,
    BatchOptions, BatchRunner,
};
pub use wide::WideKernel;
pub use engine::Engine;
pub use outcome::Outcome;
pub use platform::{PlatformSource, PlatformSpec, RestartScope};
pub use policy::{Policy, PolicyCtx};
pub use runner::{
    fold_waste_product, fold_waste_product_retaining, rep_blocks,
    run_replication_range_with, run_replication_range_with_cancel, run_replications,
    run_replications_parallel, run_replications_parallel_with, run_replications_with,
    simulate_once, ReplicationAgg, ReplicationReport, Retain,
};
pub use session::SimSession;

use crate::config::Scenario;

/// Immutable per-run simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Total useful work W of the job (s).
    pub work: f64,
    /// Checkpoint duration C (s).
    pub c: f64,
    /// Downtime D (s).
    pub d: f64,
    /// Recovery duration R (s).
    pub r: f64,
    /// Abort guard: a run whose makespan exceeds this is reported
    /// incomplete (`Outcome::completed == false`).
    pub max_makespan: f64,
}

impl SimConfig {
    pub fn from_scenario(s: &Scenario) -> SimConfig {
        SimConfig {
            work: s.work,
            c: s.platform.c,
            d: s.platform.d,
            r: s.platform.r,
            max_makespan: s.work * 250.0,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.work > 0.0, "work must be positive");
        anyhow::ensure!(self.c > 0.0, "checkpoint duration must be positive");
        anyhow::ensure!(self.d >= 0.0 && self.r >= 0.0, "D and R must be >= 0");
        anyhow::ensure!(self.max_makespan > self.work, "max_makespan below work");
        Ok(())
    }
}
