//! Typed experiment configuration: platform, predictor and scenario,
//! plus a minimal TOML-subset loader and the paper's §5 presets.

mod presets;
pub mod toml;
mod types;

pub use presets::*;
pub use types::*;
