//! Engine edge cases the golden tests skip: zero-length prediction
//! windows, degenerate predictors (recall = 0 / precision = 0),
//! predictions arriving mid-checkpoint, and `RiskThreshold` at the
//! kappa extremes (the progress floor must hold).

use ckptfp::config::{Predictor, Scenario};
use ckptfp::model::{Capping, StrategyKind};
use ckptfp::sim::{simulate_once, Engine, Policy, SimConfig, SimSession};
use ckptfp::strategies::{resolve_policy, spec_for, PolicySpec, ProactiveMode, StrategySpec};
use ckptfp::trace::{Fault, Prediction, VecSource};

fn cfg(work: f64) -> SimConfig {
    SimConfig { work, c: 10.0, d: 2.0, r: 5.0, max_makespan: 1e12 }
}

fn spec(t_r: f64, proactive: ProactiveMode) -> StrategySpec {
    let q = if matches!(proactive, ProactiveMode::Ignore) { 0.0 } else { 1.0 };
    StrategySpec { name: "edge".into(), t_r, q, proactive }
}

fn run(
    c: &SimConfig,
    s: &StrategySpec,
    faults: Vec<Fault>,
    preds: Vec<Prediction>,
) -> ckptfp::sim::Outcome {
    Engine::new(c, s, VecSource::new(faults, preds), 7).run()
}

fn small_scenario(pred: Predictor) -> Scenario {
    let mut s = Scenario::paper(1 << 16, pred);
    s.fault_dist = ckptfp::dist::DistSpec::Exp;
    s.work = 2.0e5;
    s
}

// ---------------------------------------------------------------------------
// Zero-length prediction windows
// ---------------------------------------------------------------------------

#[test]
fn zero_window_skipwindow_equals_ckptbefore() {
    // A window of length 0 makes the SkipWindow excursion empty: the
    // engine must behave exactly like CkptBefore, event for event.
    let c = cfg(1000.0);
    let faults = vec![Fault::predicted(500.0, 0)];
    let preds = vec![Prediction::windowed(500.0, 0.0, 10.0, Some(0))];
    let skip = run(&c, &spec(1e6, ProactiveMode::SkipWindow), faults.clone(), preds.clone());
    let before = run(&c, &spec(1e6, ProactiveMode::CkptBefore), faults, preds);
    assert!(skip.completed && before.completed);
    assert_eq!(skip.makespan.to_bits(), before.makespan.to_bits());
    assert_eq!(skip.n_segments, before.n_segments);
    assert_eq!(skip.n_proactive_ckpts, before.n_proactive_ckpts);
    assert_eq!(skip.lost_work.to_bits(), before.lost_work.to_bits());
}

#[test]
fn zero_window_scenario_nockpt_equals_instant() {
    // Through the full stack (generator included): with I = 0 the
    // NoCkptI and Instant strategies are the same machine — §4.2's
    // "Eqs. (5) and (6) coincide at I = 0", executable form.
    let s = small_scenario(Predictor { recall: 0.85, precision: 0.82, window: 0.0, ef: 0.0 });
    let instant = spec_for(StrategyKind::Instant, &s, Capping::Uncapped);
    let nockpt = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    assert_eq!(instant.t_r, nockpt.t_r, "same closed-form period at I = 0");
    for rep in 0..5 {
        let a = simulate_once(&s, &instant, rep).unwrap();
        let b = simulate_once(&s, &nockpt, rep).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "rep {rep}");
        assert_eq!(a.n_segments, b.n_segments, "rep {rep}");
        assert_eq!(a.n_ckpts, b.n_ckpts, "rep {rep}");
        assert_eq!(a.n_proactive_ckpts, b.n_proactive_ckpts, "rep {rep}");
    }
}

#[test]
fn zero_window_job_finishing_at_t0_terminates() {
    // Work runs out exactly at the window-start slot of a zero-length
    // window: no infinite loop, no trailing segment.
    let c = cfg(490.0);
    let o = run(
        &c,
        &spec(1e6, ProactiveMode::SkipWindow),
        vec![],
        vec![Prediction::windowed(500.0, 0.0, 10.0, None)],
    );
    assert!(o.completed);
    // 490 work + one proactive ckpt [490, 500] never happens (vol
    // persisted? No — work ends at 490 with all work done).
    assert!((o.makespan - 490.0).abs() < 1e-6, "makespan {}", o.makespan);
}

// ---------------------------------------------------------------------------
// Degenerate predictors
// ---------------------------------------------------------------------------

#[test]
fn recall_zero_trusting_strategy_equals_young() {
    // recall = 0: the predictor never fires, so a trusting strategy
    // with the same period is bit-identical to Young.
    let s = small_scenario(Predictor::none());
    let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let exact = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
    // 1 - rq = 1 at r = 0: both closed forms give the same period.
    assert_eq!(young.t_r, exact.t_r);
    for rep in 0..5 {
        let a = simulate_once(&s, &young, rep).unwrap();
        let b = simulate_once(&s, &exact, rep).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "rep {rep}");
        assert_eq!(a.n_segments, b.n_segments, "rep {rep}");
        assert_eq!(b.n_preds, 0, "no predictor may fire at r = 0");
        assert_eq!(b.n_trusted, 0);
    }
}

#[test]
fn precision_zero_degenerate_predictor_runs() {
    // The r = 0, p = 0 predictor the validation layer explicitly
    // allows: the whole stack (scenario -> generator -> engine) must
    // accept it and produce a prediction-free run.
    let pred = Predictor { recall: 0.0, precision: 0.0, window: 0.0, ef: 0.0 };
    pred.validate().unwrap();
    let s = small_scenario(pred);
    s.validate().unwrap();
    let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
    let o = simulate_once(&s, &spec, 0).unwrap();
    assert!(o.completed);
    assert_eq!(o.n_preds, 0);
    assert_eq!(o.n_faults_unpredicted, o.n_faults);
}

#[test]
fn perfect_recall_perfect_precision_avoids_all_unpredicted_faults() {
    // r = p = 1 with exact dates: every fault is predicted, no false
    // alarms — the opposite degenerate corner.
    let mut s = small_scenario(Predictor::exact(1.0, 1.0));
    s.work = 6.0e5; // several MTBFs of work: faults occur w.h.p.
    let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
    let mut total_preds = 0;
    for rep in 0..3 {
        let o = simulate_once(&s, &spec, rep).unwrap();
        assert!(o.completed, "rep {rep}");
        assert_eq!(o.n_preds, o.n_true_preds, "p = 1: no false positives (rep {rep})");
        assert_eq!(o.n_faults_unpredicted, 0, "r = 1: no surprises (rep {rep})");
        total_preds += o.n_preds;
    }
    assert!(total_preds > 0, "a perfect predictor must have fired");
}

// ---------------------------------------------------------------------------
// Prediction arriving mid-checkpoint
// ---------------------------------------------------------------------------

#[test]
fn prediction_arriving_mid_checkpoint_is_honored_after_it() {
    // Regular ckpt spans [100, 110]; the prediction for t0 = 140
    // becomes known at 104, mid-checkpoint. The engine drains it after
    // the segment, works to the action point (130), and the proactive
    // checkpoint completes exactly at t0 — no work is lost.
    let c = cfg(300.0);
    let o = run(
        &c,
        &spec(110.0, ProactiveMode::CkptBefore),
        vec![Fault::predicted(140.0, 0)],
        vec![Prediction { avail: 104.0, t0: 140.0, window: 0.0, fault_id: Some(0) }],
    );
    assert!(o.completed);
    assert_eq!(o.n_proactive_ckpts, 1);
    assert!((o.lost_work - 0.0).abs() < 1e-9, "lost {}", o.lost_work);
    // Timeline: 100 work + ckpt(10) + 20 work + pro-ckpt [130,140] +
    // fault at 140 -> D+R (7) + remaining 180 work + its final ckpt
    // never needed: 147 + 180 = 327... plus one more regular ckpt at
    // W_reg = 100 inside the tail.
    assert!(o.makespan > 300.0 && o.makespan < 400.0, "makespan {}", o.makespan);
}

#[test]
fn prediction_arriving_mid_proactive_checkpoint_waits_its_turn() {
    // A second prediction becomes available while the proactive
    // checkpoint for the first is running; its own action point is
    // still ahead, so it must be handled — not dropped.
    let c = cfg(1000.0);
    let o = run(
        &c,
        &spec(1e6, ProactiveMode::CkptBefore),
        vec![Fault::predicted(500.0, 0), Fault::predicted(600.0, 1)],
        vec![
            Prediction::exact(500.0, 10.0, Some(0)),
            // avail = 495: inside the [490, 500] proactive checkpoint.
            Prediction { avail: 495.0, t0: 600.0, window: 0.0, fault_id: Some(1) },
        ],
    );
    assert!(o.completed);
    assert_eq!(o.n_proactive_ckpts, 2, "both predictions act");
    assert_eq!(o.n_faults_avoided, 0);
    assert!((o.lost_work - 0.0).abs() < 1e-9, "lost {}", o.lost_work);
}

// ---------------------------------------------------------------------------
// RiskThreshold at the kappa extremes
// ---------------------------------------------------------------------------

#[test]
fn risk_threshold_kappa_extremes_respect_the_progress_floor() {
    let s = {
        let mut s = small_scenario(Predictor::none());
        s.work = 5.0e3; // tiny job: even a 1-second threshold finishes fast
        // A 1-second threshold against the paper's C = 600 s would pay
        // 600 s of checkpoint per second of work and trip the makespan
        // guard — the floor behavior itself is what's under test, so
        // shrink C to keep the run inside the guard.
        s.platform.c = 1.0;
        s
    };
    // kappa -> 0: w_star collapses onto the 1-second floor; the run
    // must still complete (checkpointing every second, not stalling).
    let tiny = resolve_policy(&PolicySpec::RiskThreshold { kappa: 1e-30 }, &s).unwrap();
    match tiny.policy {
        Policy::RiskThreshold { w_star, .. } => assert_eq!(w_star, 1.0, "floor"),
        ref other => panic!("wrong policy {other:?}"),
    }
    let mut session = SimSession::from_policy(&tiny.scenario, tiny.policy).unwrap();
    let o = session.run(0);
    assert!(o.completed, "kappa -> 0 must not stall the core");
    assert!(o.n_ckpts > 100, "a 1 s threshold checkpoints constantly: {}", o.n_ckpts);

    // kappa -> infinity (large finite): no regular checkpoint ever.
    let huge = resolve_policy(&PolicySpec::RiskThreshold { kappa: 1e30 }, &s).unwrap();
    let mut session = SimSession::from_policy(&huge.scenario, huge.policy).unwrap();
    let o = session.run(0);
    assert!(o.completed);
    assert_eq!(o.n_ckpts, 0, "infinite threshold: no regular checkpoints");
}

#[test]
fn risk_threshold_rejects_non_finite_kappa() {
    for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
        let spec = PolicySpec::RiskThreshold { kappa: bad };
        assert!(spec.validate().is_err(), "kappa {bad} must be rejected");
    }
    // And the raw degenerate policy cannot stall the engine either —
    // `Engine::with_policy` sanitizes the boundary.
    let c = cfg(50.0);
    let o = Engine::with_policy(
        &c,
        Policy::RiskThreshold { w_star: 0.0, q: 1.0, proactive: ProactiveMode::CkptBefore },
        VecSource::new(vec![], vec![]),
        7,
    )
    .run();
    assert!(o.completed);
}
