//! PCG-XSL-RR 128/64: 128-bit LCG state, xorshift-low + random rotate
//! output. Reference: M. O'Neill, "PCG: A Family of Simple Fast
//! Space-Efficient Statistically Good Algorithms for Random Number
//! Generation" (HMC-CS-2014-0905).

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

/// 64-bit-output PCG with 128-bit state and a selectable stream.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // odd stream selector
}

impl Pcg64 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let initstate = (seed as u128) << 64 | seed.wrapping_mul(0xda3e39cb94b95bdb) as u128;
        let initseq = (stream as u128) << 64 | stream.wrapping_add(0x853c49e6748fea9b) as u128;
        let mut rng = Pcg64 { state: 0, inc: (initseq << 1) | 1 };
        rng.step();
        rng.state = rng.state.wrapping_add(initstate);
        rng.step();
        rng
    }

    /// Seed from a single u64 (stream 0xcafe).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xcafe)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in the half-open interval (0, 1] — never returns 0,
    /// safe as the argument of `ln()` for inverse-CDF sampling.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        // 53 random mantissa bits; map 0 -> 1.0 by using (x + 1) / 2^53.
        let x = self.next_u64() >> 11;
        (x as f64 + 1.0) * (1.0 / 9007199254740992.0)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        let x = self.next_u64() >> 11;
        x as f64 * (1.0 / 9007199254740992.0)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            // Rejection zone: only when lo < n do we need the threshold test.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Take `n` raw outputs (test helper).
    pub fn take_u64(mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.next_u64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Pcg64::new(1, 2).take_u64(16);
        let b = Pcg64::new(1, 2).take_u64(16);
        assert_eq!(a, b);
    }

    #[test]
    fn streams_differ() {
        let a = Pcg64::new(1, 2).take_u64(16);
        let b = Pcg64::new(1, 3).take_u64(16);
        assert_ne!(a, b);
    }

    #[test]
    fn f64_bounds() {
        let mut rng = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = rng.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn uniformity_rough() {
        // Mean of U[0,1) over 100k draws within 1%.
        let mut rng = Pcg64::seeded(11);
        let mean: f64 = (0..100_000).map(|_| rng.next_f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_support() {
        let mut rng = Pcg64::seeded(3);
        let mut seen = [0u32; 7];
        for _ in 0..7_000 {
            seen[rng.below(7) as usize] += 1;
        }
        for (i, c) in seen.iter().enumerate() {
            assert!(*c > 700, "bucket {i} count {c}");
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg64::seeded(5);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }
}
