//! Fallback runtime when the crate is built without the `pjrt` feature:
//! the API surface the coordinator, service and CLI compile against
//! exists, but every entry point reports the missing backend. The
//! analytical planner, the simulator and all experiments are fully
//! functional without PJRT — only HLO-artifact execution needs it.

use std::path::Path;

use super::{Manifest, PlanOutput, SurfaceOutput};
use crate::model::Params;

const NO_PJRT: &str = "this build has no PJRT backend — rebuild with `--features pjrt` \
    (requires the `xla` crate) to load HLO artifacts";

/// Stand-in for the PJRT runtime; cannot be constructed.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn open(_dir: &Path) -> anyhow::Result<Runtime> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn open_default() -> anyhow::Result<Runtime> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn manifest(&self) -> &Manifest {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn platform_name(&self) -> String {
        unreachable!("stub Runtime cannot be constructed")
    }
}

/// Stand-in for the HLO planner; construction always fails, so the
/// method bodies after `open_default` are unreachable.
pub struct HloPlanner {
    _private: (),
}

impl HloPlanner {
    pub fn new(_runtime: Runtime) -> HloPlanner {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn open_default() -> anyhow::Result<HloPlanner> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn platform_name(&self) -> String {
        unreachable!("stub HloPlanner cannot be constructed")
    }

    pub fn warmup(&mut self) -> anyhow::Result<()> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn plan_batch(&mut self, _configs: &[Params]) -> anyhow::Result<Vec<PlanOutput>> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn surfaces(&mut self, _configs: &[Params]) -> anyhow::Result<Vec<SurfaceOutput>> {
        anyhow::bail!(NO_PJRT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_backend() {
        let err = HloPlanner::open_default().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
        assert!(Runtime::open_default().is_err());
    }
}
