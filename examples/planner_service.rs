//! Planner-service demo: starts the TCP/JSONL service backed by the
//! AOT-compiled XLA planner, fires a burst of concurrent client
//! requests through it, and prints the dynamic-batching statistics.
//!
//! ```bash
//! make artifacts && cargo run --release --example planner_service
//! ```

use std::time::Duration;

use ckptfp::api::{Executor, ExecutorConfig};
use ckptfp::coordinator::{serve, Batcher, BatcherConfig, PlannerClient, ServiceConfig};
use ckptfp::runtime::HloPlanner;

fn main() -> anyhow::Result<()> {
    let batcher = Batcher::spawn(
        HloPlanner::open_default,
        BatcherConfig { max_batch: 64, max_delay: Duration::from_millis(2), ..Default::default() },
    )?;
    let executor = Executor::with_batcher(batcher.clone(), ExecutorConfig::default());
    let handle = serve(executor, ServiceConfig { addr: "127.0.0.1:0".into() })?;
    let addr = handle.addr.to_string();
    println!("service on {addr}");

    // Concurrent clients: every (N, predictor) combination of the paper.
    let mut requests = Vec::new();
    for e in 14..=19 {
        let n = 1u64 << e;
        let mu = 125.0 * 365.25 * 86400.0 / n as f64;
        for (r, p) in [(0.85, 0.82), (0.7, 0.4)] {
            for window in [0.0, 300.0, 3000.0] {
                requests.push(format!(
                    r#"{{"mu": {mu}, "recall": {r}, "precision": {p}, "window": {window}}}"#
                ));
            }
        }
    }
    let started = std::time::Instant::now();
    let responses: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = requests
            .iter()
            .map(|req| {
                let addr = addr.clone();
                scope.spawn(move || -> anyhow::Result<String> {
                    let mut client = PlannerClient::connect(&addr)?;
                    let v = client.call(req)?;
                    anyhow::ensure!(
                        v.get("ok").and_then(|b| b.as_bool()) == Some(true),
                        "request failed: {}",
                        v.to_string()
                    );
                    Ok(format!(
                        "winner={} waste={:.4} T={:.0}s",
                        v.get("winner").and_then(|s| s.as_str()).unwrap_or("?"),
                        v.num_or("winner_waste", f64::NAN),
                        v.num_or("winner_period", f64::NAN),
                    ))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect()
    });
    let elapsed = started.elapsed();

    for (req, resp) in requests.iter().zip(&responses).take(6) {
        println!("  {req}\n    -> {resp}");
    }
    println!("  ... ({} requests total)", requests.len());

    let stats = batcher.stats();
    let (p50, p95, p99, n) = batcher.metrics().latency_quantiles();
    println!(
        "\n{} requests in {:.1} ms ({:.0} req/s) across {} batches (max batch {})",
        stats.requests,
        elapsed.as_secs_f64() * 1e3,
        stats.requests as f64 / elapsed.as_secs_f64(),
        stats.batches,
        stats.max_batch_seen
    );
    println!(
        "latency p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms (n={n})",
        p50 * 1e3,
        p95 * 1e3,
        p99 * 1e3
    );

    // Stats verb over the wire.
    let mut client = PlannerClient::connect(&addr)?;
    let stats_json = client.call(r#"{"op": "stats"}"#)?;
    println!("service stats: {}", stats_json.to_string());

    handle.stop();
    Ok(())
}
