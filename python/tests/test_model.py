"""L2 planner tests: derived rates, T_P snapping, masking, argmin."""

import math

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.waste_grid import COLS

from .test_kernel import MIN, expand, grid, paper_config


class TestExpandParams:
    def test_derived_rates(self):
        raw, kp = expand([paper_config(mu_mn=1000.0, r=0.7, p=0.4)])
        mu = 1000.0 * MIN
        kp = np.asarray(kp)[0]
        assert math.isclose(kp[COLS["inv_mu"]], 1 / mu, rel_tol=1e-6)
        assert math.isclose(kp[COLS["inv_muP"]], 0.7 / (0.4 * mu), rel_tol=1e-6)
        assert math.isclose(kp[COLS["inv_muNP"]], 0.3 / mu, rel_tol=1e-6)

    def test_i1_and_frac_reg(self):
        raw, kp = expand([paper_config(r=0.85, p=0.82, I=3000.0)])
        kp = np.asarray(kp)[0]
        i1 = (1 - 0.82) * 3000 + 0.82 * 1500
        assert math.isclose(kp[COLS["I1"]], i1, rel_tol=1e-6)
        assert 0.0 <= kp[COLS["frac_reg"]] <= 1.0

    def test_r_zero_guards(self):
        _, kp = expand([paper_config(r=0.0)])
        kp = np.asarray(kp)[0]
        assert kp[COLS["inv_muP"]] == 0.0
        assert kp[COLS["frac_reg"]] == 1.0

    def test_tp_divides_window(self):
        """T_P must partition I into an integer number of periods (§4.3)."""
        for i_win in (1200.0, 3000.0, 6000.0):
            _, kp = expand([paper_config(I=i_win, Ef=i_win / 2)])
            tp = float(np.asarray(kp)[0, COLS["TP"]])
            k = i_win / tp
            assert abs(k - round(k)) < 1e-3, (i_win, tp, k)
            assert tp >= 600.0 - 1e-3  # >= C

    def test_tp_at_least_c_for_small_window(self):
        _, kp = expand([paper_config(I=300.0)])
        assert float(np.asarray(kp)[0, COLS["TP"]]) >= 600.0 - 1e-3

    @settings(max_examples=40, deadline=None)
    @given(i_win=st.floats(700.0, 20000.0), p=st.floats(0.1, 1.0),
           ef_frac=st.floats(0.1, 1.0))
    def test_tp_snapping_optimal_among_divisors(self, i_win, p, ef_frac):
        """Snapped T_P beats every other divisor of I on the Eq.-7 share."""
        c = 600.0
        _, kp = expand([paper_config(I=i_win, Ef=i_win * ef_frac, p=p)])
        kp0 = np.asarray(kp)[0]
        i1, tp = kp0[COLS["I1"]], kp0[COLS["TP"]]
        share = lambda t: (i1 / p) * c / t + t
        best = min(
            (share(i_win / k) for k in range(1, 64) if i_win / k >= c),
            default=share(max(i_win, c)),
        )
        assert share(tp) <= best * (1 + 1e-4)


class TestPlan:
    def test_young_matches_closed_form(self):
        """Planner's s0 period ≈ min(alpha*mu, sqrt(2 mu C)) (§3.3)."""
        for mu_mn in (125.0, 500.0, 1000.0, 4000.0):
            raw = jnp.asarray([paper_config(mu_mn=mu_mn)], jnp.float32)
            _, bt, *_ = model.plan(raw, grid(2048))
            mu = mu_mn * MIN
            t_y = min(0.27 * mu, max(math.sqrt(2 * mu * 600.0), 600.0))
            assert abs(float(bt[0, 0]) - t_y) / t_y < 5e-3, (mu_mn, float(bt[0, 0]), t_y)

    def test_exact_matches_case_analysis(self):
        """s1 period ≈ min(alpha*mu_e, max(sqrt(2 mu C/(1-r)), C))."""
        for mu_mn, r, p in [(125.0, 0.85, 0.82), (1000.0, 0.7, 0.4), (4000.0, 0.5, 0.5)]:
            raw = jnp.asarray([paper_config(mu_mn=mu_mn, r=r, p=p)], jnp.float32)
            _, bt, *_ = model.plan(raw, grid(2048))
            mu = mu_mn * MIN
            mue = mu / ((1 - r) + r / p)
            t_1 = min(0.27 * mue, max(math.sqrt(2 * mu * 600.0 / (1 - r)), 600.0))
            assert abs(float(bt[0, 1]) - t_1) / t_1 < 5e-3

    def test_prediction_reduces_waste(self):
        # mu = 1000 mn: the capped domain [C, alpha*mu_e] still contains the
        # s1 extremum, so trusting a good predictor must beat Young.  (At
        # mu = 125 mn the cap makes Young win — the paper's §5.1 remark that
        # the capped model overestimates waste at scale; see test below.)
        raw = jnp.asarray([paper_config(mu_mn=1000.0)], jnp.float32)
        bw, *_ = model.plan(raw, grid(512))
        assert float(bw[0, 1]) < float(bw[0, 0])

    def test_capped_model_overestimates_at_scale(self):
        """Paper §5.1: at mu = 125 mn the cap alpha*mu_e binds and capped
        ExactPrediction can exceed capped Young; the planner must therefore
        report Young (q=0) as the winner among s0/s1."""
        raw = jnp.asarray([paper_config(mu_mn=125.0)], jnp.float32)
        bw, *_ = model.plan(raw, grid(512))
        assert float(bw[0, 1]) > float(bw[0, 0])

    def test_winner_consistency(self):
        raw = jnp.asarray([paper_config(), paper_config(r=0.0)], jnp.float32)
        bw, bt, ws, ww, wt = model.plan(raw, grid(512))
        bw = np.asarray(bw)
        for b in range(2):
            s = int(ws[b])
            assert math.isclose(float(ww[b]), bw[b].min(), rel_tol=1e-6)
            assert math.isclose(float(ww[b]), bw[b, s], rel_tol=1e-6)

    def test_waste_capped_at_one(self):
        # Hopeless platform: MTBF shorter than the checkpoint itself.
        raw = jnp.asarray([paper_config(mu_mn=5.0)], jnp.float32)
        bw, *_ = model.plan(raw, grid(512))
        assert (np.asarray(bw) <= 1.0 + 1e-6).all()

    def test_withckpt_masked_when_window_small(self):
        raw = jnp.asarray([paper_config(I=300.0)], jnp.float32)  # I < C
        bw, *_ = model.plan(raw, grid(512))
        assert float(bw[0, 4]) == 1.0

    def test_batch_order_independence(self):
        rows = [paper_config(mu_mn=m) for m in (125.0, 250.0, 500.0, 1000.0)]
        u = grid(512)
        fwd = model.plan(jnp.asarray(rows, jnp.float32), u)
        rev = model.plan(jnp.asarray(rows[::-1], jnp.float32), u)
        np.testing.assert_allclose(np.asarray(fwd[0]), np.asarray(rev[0])[::-1],
                                   rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(mu_mn=st.floats(50.0, 8000.0), r=st.floats(0.0, 0.99),
           p=st.floats(0.1, 0.99))
    def test_q_choice_endpoint(self, mu_mn, r, p):
        """WASTE(q) is affine in q (§3.3) => trusted (q=1) strategies either
        beat Young or Young wins; no interior q can beat both endpoints."""
        raw = jnp.asarray([paper_config(mu_mn=mu_mn, r=r, p=p)], jnp.float32)
        bw, *_ = model.plan(raw, grid(512))
        bw = np.asarray(bw)[0]
        # Winner is one of the endpoints by construction; sanity: all wastes
        # well-formed.
        assert (bw > 0).all() and (bw <= 1.0 + 1e-6).all()


class TestSurfaces:
    def test_masking_applied(self):
        raw = jnp.asarray([paper_config(mu_mn=125.0, I=3000.0)], jnp.float32)
        w, t = model.surfaces(raw, grid(512))
        w, t = np.asarray(w), np.asarray(t)
        mu = 125.0 * MIN
        mue = mu / ((1 - 0.85) + 0.85 / 0.82)
        lim = 0.27 * mue - 3000.0
        over = t[0] > lim
        # Window strategies are clamped to 1.0 beyond their domain.
        assert (w[0, 2, over] == 1.0).all()

    def test_grid_endpoints(self):
        raw = jnp.asarray([paper_config(mu_mn=1000.0)], jnp.float32)
        _, t = model.surfaces(raw, grid(512))
        t = np.asarray(t)[0]
        assert math.isclose(t[0], 600.0, rel_tol=1e-6)
        assert math.isclose(t[-1], 0.27 * 1000.0 * MIN, rel_tol=1e-5)
