//! Cooperative cancellation for long-running jobs.
//!
//! A [`CancelToken`] carries an optional wall-clock deadline and an
//! optional shared stop flag. Work that may run for a long time (the
//! replication loop in `sim::runner`, most importantly) polls
//! [`CancelToken::cancelled`] between units of work and winds down
//! early instead of hanging a worker thread on a runaway request.
//!
//! Tokens are cheap to clone and purely cooperative: nothing is
//! interrupted, the running code simply stops picking up new units
//! once the token trips. This lives in `util` (the lowest layer) so
//! `api`, `sim`, and `coordinator` can all share the same type
//! without a dependency cycle.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A cooperative cancellation handle: deadline, stop flag, both, or
/// neither (the default token never cancels).
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    deadline: Option<Instant>,
    flag: Option<Arc<AtomicBool>>,
}

impl CancelToken {
    /// A token that never cancels.
    pub fn unbounded() -> Self {
        Self::default()
    }

    /// A token that trips once `budget` of wall-clock time has elapsed.
    pub fn with_deadline(budget: Duration) -> Self {
        CancelToken { deadline: Some(Instant::now() + budget), flag: None }
    }

    /// A token that trips when `flag` becomes true (e.g. service
    /// shutdown ordering every in-flight job to wind down).
    pub fn with_flag(flag: Arc<AtomicBool>) -> Self {
        CancelToken { deadline: None, flag: Some(flag) }
    }

    /// Derive a child token sharing this token's stop flag, with the
    /// tighter of this token's deadline and a fresh `budget` (when
    /// given). Used to scope a per-request deadline under a
    /// service-wide shutdown flag.
    pub fn child_with_deadline(&self, budget: Option<Duration>) -> Self {
        let fresh = budget.map(|b| Instant::now() + b);
        let deadline = match (self.deadline, fresh) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        CancelToken { deadline, flag: self.flag.clone() }
    }

    /// True once the deadline has passed or the stop flag is set.
    pub fn cancelled(&self) -> bool {
        self.deadline_exceeded()
            || self.flag.as_ref().is_some_and(|f| f.load(Ordering::Relaxed))
    }

    /// True once the deadline (if any) has passed, regardless of the
    /// stop flag. Lets callers distinguish "ran out of budget" from
    /// "service shutting down" when classifying a partial result.
    pub fn deadline_exceeded(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_never_cancels() {
        let t = CancelToken::unbounded();
        assert!(!t.cancelled());
        assert!(!t.deadline_exceeded());
    }

    #[test]
    fn deadline_trips_after_budget() {
        let t = CancelToken::with_deadline(Duration::from_millis(10));
        assert!(!t.cancelled());
        std::thread::sleep(Duration::from_millis(25));
        assert!(t.cancelled());
        assert!(t.deadline_exceeded());
    }

    #[test]
    fn flag_trips_without_deadline() {
        let flag = Arc::new(AtomicBool::new(false));
        let t = CancelToken::with_flag(flag.clone());
        assert!(!t.cancelled());
        flag.store(true, Ordering::Relaxed);
        assert!(t.cancelled());
        assert!(!t.deadline_exceeded(), "flag cancellation is not a deadline");
    }

    #[test]
    fn child_takes_tighter_deadline_and_shares_flag() {
        let flag = Arc::new(AtomicBool::new(false));
        let parent = CancelToken::with_flag(flag.clone());
        let child = parent.child_with_deadline(Some(Duration::from_secs(3600)));
        assert!(!child.cancelled());
        flag.store(true, Ordering::Relaxed);
        assert!(child.cancelled(), "child must observe the parent flag");

        let wide = CancelToken::with_deadline(Duration::from_secs(3600));
        let tight = wide.child_with_deadline(Some(Duration::from_millis(5)));
        std::thread::sleep(Duration::from_millis(20));
        assert!(tight.deadline_exceeded());
        assert!(!wide.deadline_exceeded());
    }
}
