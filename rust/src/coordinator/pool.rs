//! Leader/worker parallelism over std::thread (substrate: no tokio/rayon
//! offline). Scoped threads + an atomic work index give dynamic load
//! balancing without channels — replication workloads are embarrassingly
//! parallel but very uneven (BestPeriod candidates differ by 10x in
//! simulated events), so static chunking would waste cores.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count: `CKPTFP_WORKERS` env override, else available
/// parallelism, else 4.
pub fn available_workers() -> usize {
    if let Ok(v) = std::env::var("CKPTFP_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every item on `workers` threads; returns results in
/// input order. Panics in `f` propagate after all workers stop.
pub fn run_parallel<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slot_ptr = SlotsPtr(slots.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let items = &items;
            let f = &f;
            let slot_ptr = &slot_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index i is claimed by exactly one worker
                // (fetch_add is unique), and `slots` outlives the scope.
                unsafe { *slot_ptr.0.add(i) = Some(r) };
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker failed to fill slot")).collect()
}

/// Send+Sync wrapper for the raw result pointer; soundness argument in
/// `run_parallel` (disjoint writes, scoped lifetime).
struct SlotsPtr<R>(*mut Option<R>);
unsafe impl<R: Send> Send for SlotsPtr<R> {}
unsafe impl<R: Send> Sync for SlotsPtr<R> {}

/// Streaming parallel reduction: fold `items` into per-worker
/// accumulators, then merge the partials — no `Vec<Option<R>>` slot
/// array, no per-item result allocation. This is the right shape for
/// replication workloads, where the caller only wants the aggregate
/// (and where the per-worker accumulator can carry reusable scratch
/// such as a [`crate::sim::SimSession`]).
///
/// Work distribution is a deterministic stride: worker `w` folds items
/// `w, w + W, w + 2W, …` in order, and partials merge in worker order.
/// Unlike the atomic-claim loop in [`run_parallel`] this keeps the
/// reduction reproducible for a fixed worker count (counters exactly,
/// floating-point accumulations bit-for-bit), while replication costs —
/// random by construction — still average out across the stride.
///
/// Panics in `fold` propagate after all workers stop, matching
/// [`run_parallel`]. Empty input returns `init()` untouched.
pub fn run_parallel_fold<T, A, I, F, M>(
    items: &[T],
    workers: usize,
    init: I,
    fold: F,
    merge: M,
) -> A
where
    T: Sync,
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(A, &T) -> A + Sync,
    M: Fn(A, A) -> A,
{
    let n = items.len();
    if n == 0 {
        return init();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().fold(init(), &fold);
    }
    let mut partials: Vec<A> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let init = &init;
                let fold = &fold;
                scope.spawn(move || {
                    let mut acc = init();
                    let mut i = w;
                    while i < n {
                        acc = fold(acc, &items[i]);
                        i += workers;
                    }
                    acc
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(a) => partials.push(a),
                // Re-raise the worker's payload; the scope joins the
                // remaining workers before unwinding past it.
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    let mut iter = partials.into_iter();
    let first = iter.next().expect("at least one worker ran");
    iter.fold(first, merge)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = run_parallel(items, 8, |x| x * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 2);
        }
    }

    #[test]
    fn single_worker_path() {
        let out = run_parallel(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<u32> = run_parallel(Vec::<u32>::new(), 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_is_balanced() {
        // Tasks with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).collect();
        let out = run_parallel(items, 8, |x| {
            let mut acc = 0u64;
            for i in 0..(x % 7) * 10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn workers_env_override() {
        assert!(available_workers() >= 1);
    }

    #[test]
    fn fold_matches_sequential_sum() {
        let items: Vec<u64> = (0..1000).collect();
        let total = run_parallel_fold(&items, 8, || 0u64, |a, x| a + x, |a, b| a + b);
        assert_eq!(total, items.iter().sum::<u64>());
    }

    #[test]
    fn fold_empty_input_returns_init() {
        let out = run_parallel_fold(&Vec::<u32>::new(), 4, || 41u32, |a, x| a + x, |a, b| a + b);
        assert_eq!(out, 41);
    }

    #[test]
    fn fold_single_worker_is_plain_fold() {
        let items = vec![1u64, 2, 3, 4];
        let out = run_parallel_fold(
            &items,
            1,
            Vec::new,
            |mut acc: Vec<u64>, &x| {
                acc.push(x);
                acc
            },
            |mut a, b| {
                a.extend(b);
                a
            },
        );
        // One worker folds in input order.
        assert_eq!(out, items);
    }

    #[test]
    fn fold_is_deterministic_for_fixed_workers() {
        // Floating-point accumulation order is a fixed stride + fixed
        // merge order, so two runs agree bit for bit.
        let items: Vec<f64> = (0..501).map(|i| (i as f64).sin()).collect();
        let run = || {
            run_parallel_fold(&items, 5, || 0.0f64, |a, x| a + x, |a, b| a + b)
        };
        let a = run();
        let b = run();
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn fold_more_workers_than_items_clamps() {
        let items = vec![10u64, 20];
        let total = run_parallel_fold(&items, 64, || 0u64, |a, x| a + x, |a, b| a + b);
        assert_eq!(total, 30);
    }

    #[test]
    #[should_panic(expected = "boom at 17")]
    fn fold_propagates_worker_panics() {
        let items: Vec<u64> = (0..64).collect();
        let _ = run_parallel_fold(
            &items,
            4,
            || 0u64,
            |a, &x| {
                if x == 17 {
                    panic!("boom at 17");
                }
                a + x
            },
            |a, b| a + b,
        );
    }
}
