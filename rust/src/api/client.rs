//! Blocking typed client for the job service: encodes [`JobRequest`]s
//! as protocol-v2 JSONL over TCP and decodes typed responses. One
//! request in flight per connection (the protocol is strictly
//! line-for-line); open more clients for concurrency — the service is
//! one thread per connection.
//!
//! The client is resilient by default: connects are bounded by
//! [`ClientConfig::connect_timeout`], reads by
//! [`ClientConfig::read_timeout`], and transport failures retry with
//! seeded exponential backoff ([`ClientConfig::retries`] attempts,
//! reconnecting each time). Retries re-send the request, which is safe
//! here because every job is a pure computation — callers wiring this
//! to side-effecting jobs should set `retries: 0`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::types::*;
use super::wire;
use crate::rng::Pcg64;

/// Process-wide count of transport-level retries across all
/// [`ServiceClient`]s — surfaced as `client_retries` in
/// [`ServiceStats`] so a service that also acts as a client (planner
/// fan-out) reports its own flakiness.
static CLIENT_RETRIES: AtomicU64 = AtomicU64::new(0);

pub fn client_retries() -> u64 {
    CLIENT_RETRIES.load(Ordering::Relaxed)
}

/// Timeouts and retry policy for a [`ServiceClient`].
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// Bound on establishing the TCP connection.
    pub connect_timeout: Duration,
    /// Bound on waiting for a response line; expiry is a transport
    /// error (and thus retried), not a hang.
    pub read_timeout: Duration,
    /// Transport-level retries after the first attempt. `0` disables.
    pub retries: u32,
    /// First backoff sleep; doubles per retry, with seeded jitter in
    /// `[0.5, 1.0)` of the doubled value.
    pub backoff_base: Duration,
    /// Seed for the jitter stream — fixed seed, reproducible schedule.
    pub seed: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(30),
            retries: 2,
            backoff_base: Duration::from_millis(50),
            seed: 0,
        }
    }
}

pub struct ServiceClient {
    addr: String,
    cfg: ClientConfig,
    rng: Pcg64,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ServiceClient {
    pub fn connect(addr: &str) -> anyhow::Result<ServiceClient> {
        ServiceClient::connect_with(addr, ClientConfig::default())
    }

    pub fn connect_with(addr: &str, cfg: ClientConfig) -> anyhow::Result<ServiceClient> {
        let (reader, writer) = open(addr, &cfg)?;
        let rng = Pcg64::new(cfg.seed, 0x636c69);
        Ok(ServiceClient { addr: addr.to_string(), cfg, rng, reader, writer })
    }

    /// Send one job, wait for its response. Server-reported failures
    /// come back as `Ok(JobResponse::Error(_))`; transport failures
    /// retry per [`ClientConfig`] and surface as `Err` once exhausted.
    pub fn call(&mut self, req: &JobRequest) -> anyhow::Result<JobResponse> {
        let mut last_err = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                CLIENT_RETRIES.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.backoff(attempt));
                match open(&self.addr, &self.cfg) {
                    Ok((reader, writer)) => {
                        self.reader = reader;
                        self.writer = writer;
                    }
                    Err(e) => {
                        last_err = Some(e);
                        continue;
                    }
                }
            }
            match self.transact(req) {
                Ok(resp) => return Ok(resp),
                Err(e) => last_err = Some(e),
            }
        }
        let e = last_err.expect("at least one attempt always runs");
        Err(e.context(format!("request failed after {} retries", self.cfg.retries)))
    }

    fn transact(&mut self, req: &JobRequest) -> anyhow::Result<JobResponse> {
        let line = wire::encode_request(req);
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp).map_err(|e| {
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) {
                anyhow::anyhow!(
                    "no response within the {:.1}s read timeout",
                    self.cfg.read_timeout.as_secs_f64()
                )
            } else {
                e.into()
            }
        })?;
        anyhow::ensure!(!resp.is_empty(), "server closed the connection");
        wire::decode_response(resp.trim()).map_err(Into::into)
    }

    /// Exponential backoff with multiplicative jitter in `[0.5, 1.0)`,
    /// deterministic for a fixed [`ClientConfig::seed`].
    fn backoff(&mut self, attempt: u32) -> Duration {
        let doubled = self.cfg.backoff_base.saturating_mul(1u32 << (attempt - 1).min(16));
        let jitter = 0.5 + 0.5 * self.rng.next_f64();
        Duration::from_secs_f64(doubled.as_secs_f64() * jitter)
    }

    pub fn plan(&mut self, job: PlanJob) -> anyhow::Result<PlanResult> {
        match self.call(&JobRequest::Plan(job))? {
            JobResponse::Plan(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to plan: {other:?}"),
        }
    }

    pub fn simulate(&mut self, job: SimulateJob) -> anyhow::Result<SimulateResult> {
        match self.call(&JobRequest::Simulate(job))? {
            JobResponse::Simulate(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to simulate: {other:?}"),
        }
    }

    pub fn best_period(&mut self, job: BestPeriodJob) -> anyhow::Result<BestPeriodOutcome> {
        match self.call(&JobRequest::BestPeriod(job))? {
            JobResponse::BestPeriod(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to best_period: {other:?}"),
        }
    }

    pub fn sweep(&mut self, job: SweepJob) -> anyhow::Result<SweepResult> {
        match self.call(&JobRequest::Sweep(job))? {
            JobResponse::Sweep(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to sweep: {other:?}"),
        }
    }

    pub fn verify(&mut self, job: VerifyJob) -> anyhow::Result<crate::verify::VerifyReport> {
        match self.call(&JobRequest::Verify(job))? {
            JobResponse::Verify(r) => Ok(r),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to verify: {other:?}"),
        }
    }

    pub fn stats(&mut self) -> anyhow::Result<ServiceStats> {
        match self.call(&JobRequest::Stats)? {
            JobResponse::Stats(s) => Ok(s),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to stats: {other:?}"),
        }
    }

    pub fn ping(&mut self) -> anyhow::Result<()> {
        match self.call(&JobRequest::Ping)? {
            JobResponse::Pong => Ok(()),
            JobResponse::Error(e) => Err(e.into()),
            other => anyhow::bail!("unexpected response to ping: {other:?}"),
        }
    }
}

/// Open one bounded connection: resolve, connect with the configured
/// timeout (first address that answers wins), arm the read timeout.
fn open(
    addr: &str,
    cfg: &ClientConfig,
) -> anyhow::Result<(BufReader<TcpStream>, TcpStream)> {
    let addrs = addr
        .to_socket_addrs()
        .map_err(|e| anyhow::anyhow!("resolving {addr}: {e}"))?;
    let mut last_err = None;
    let mut stream = None;
    for sa in addrs {
        match TcpStream::connect_timeout(&sa, cfg.connect_timeout) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(e) => last_err = Some(e),
        }
    }
    let stream = match stream {
        Some(s) => s,
        None => match last_err {
            Some(e) => anyhow::bail!("connecting to {addr}: {e}"),
            None => anyhow::bail!("{addr} resolved to no addresses"),
        },
    };
    if cfg.read_timeout > Duration::ZERO {
        stream.set_read_timeout(Some(cfg.read_timeout))?;
    }
    let writer = stream.try_clone()?;
    Ok((BufReader::new(stream), writer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn connect_timeout_is_a_clear_error() {
        // Connecting to an address nobody listens on fails within the
        // budget, naming the address.
        let cfg = ClientConfig {
            connect_timeout: Duration::from_millis(200),
            retries: 0,
            ..Default::default()
        };
        // A bound-then-dropped listener yields a port that refuses.
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let err = ServiceClient::connect_with(&format!("127.0.0.1:{port}"), cfg).unwrap_err();
        assert!(err.to_string().contains("connecting to"), "{err:#}");
    }

    #[test]
    fn transport_failure_retries_reconnect_and_recover() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // First connection: accept and hang up before answering.
            drop(listener.accept().unwrap());
            // Second connection (the retry): answer one request.
            let (s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let req = wire::decode_request(line.trim()).unwrap();
            assert!(!req.legacy);
            let resp = wire::encode_response(&JobResponse::Pong, false);
            let mut w = s;
            w.write_all(resp.as_bytes()).unwrap();
            w.write_all(b"\n").unwrap();
        });
        let cfg = ClientConfig {
            retries: 2,
            backoff_base: Duration::from_millis(5),
            read_timeout: Duration::from_secs(5),
            ..Default::default()
        };
        let before = client_retries();
        let mut client = ServiceClient::connect_with(&addr, cfg).unwrap();
        let resp = client.call(&JobRequest::Ping).unwrap();
        assert_eq!(resp, JobResponse::Pong);
        assert!(client_retries() > before, "the recovery must count as a retry");
        server.join().unwrap();
    }

    #[test]
    fn backoff_schedule_is_seeded_and_bounded() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mk = || {
            // Keep the listener alive so connects succeed; nothing reads.
            let cfg = ClientConfig { seed: 7, ..Default::default() };
            ServiceClient::connect_with(&addr, cfg).unwrap()
        };
        let mut a = mk();
        let mut b = mk();
        for attempt in 1..=3u32 {
            let da = a.backoff(attempt);
            assert_eq!(da, b.backoff(attempt), "same seed, same schedule");
            let doubled = Duration::from_millis(50).saturating_mul(1 << (attempt - 1));
            assert!(da >= doubled / 2 && da < doubled, "attempt {attempt}: {da:?}");
        }
    }
}
