//! Batch-engine acceptance tests: the lane width of a bank-backed
//! sweep must be unobservable in the results. Scalar replay, lockstep
//! lanes 1/8 and the wide SoA kernel at widths 1/8/16 produce
//! bit-identical aggregates for every policy the repo ships, on
//! Exponential and Weibull faults, and the contract survives
//! mid-batch underrun fallbacks (lockstep) and lane evictions (wide).

use std::sync::Arc;

use ckptfp::config::{Predictor, Scenario};
use ckptfp::dist::DistSpec;
use ckptfp::model::{Capping, StrategyKind};
use ckptfp::sim::{BatchEngine, BatchRunner, Policy, ReplicationAgg, SimSession, WideKernel};
use ckptfp::strategies::{resolve_policy, spec_for, PolicySpec};
use ckptfp::trace::TraceBank;

fn study(dist: DistSpec, predictor: Predictor) -> Scenario {
    let mut s = Scenario::paper(1 << 16, predictor);
    s.fault_dist = dist;
    s.work = 2.0e5;
    s
}

/// Run `0..reps` through one runner, folding into a fresh aggregate.
fn agg_of(mut runner: BatchRunner, reps: u64) -> ReplicationAgg {
    let ids: Vec<u64> = (0..reps).collect();
    let mut agg = ReplicationAgg::default();
    runner.run_reps(&ids, |_, out| agg.push(out));
    agg
}

/// Everything except wall-clock `sim_seconds` must match to the bit.
fn assert_bit_identical(a: &ReplicationAgg, b: &ReplicationAgg, label: &str) {
    assert_eq!(a.n_reps, b.n_reps, "{label}: n_reps");
    assert_eq!(a.n_completed, b.n_completed, "{label}: n_completed");
    assert_eq!(a.n_faults, b.n_faults, "{label}: n_faults");
    assert_eq!(a.n_faults_unpredicted, b.n_faults_unpredicted, "{label}: n_faults_unpredicted");
    assert_eq!(a.n_preds, b.n_preds, "{label}: n_preds");
    assert_eq!(a.n_true_preds, b.n_true_preds, "{label}: n_true_preds");
    assert_eq!(a.n_trusted, b.n_trusted, "{label}: n_trusted");
    assert_eq!(a.n_ckpts, b.n_ckpts, "{label}: n_ckpts");
    assert_eq!(a.n_proactive_ckpts, b.n_proactive_ckpts, "{label}: n_proactive_ckpts");
    assert_eq!(a.n_migrations, b.n_migrations, "{label}: n_migrations");
    assert_eq!(a.n_faults_avoided, b.n_faults_avoided, "{label}: n_faults_avoided");
    assert_eq!(a.n_segments, b.n_segments, "{label}: n_segments");
    assert_eq!(a.lost_work.to_bits(), b.lost_work.to_bits(), "{label}: lost_work");
    assert_eq!(a.waste.mean().to_bits(), b.waste.mean().to_bits(), "{label}: waste mean");
    assert_eq!(a.waste.ci95().to_bits(), b.waste.ci95().to_bits(), "{label}: waste ci95");
    assert_eq!(a.makespan.mean().to_bits(), b.makespan.mean().to_bits(), "{label}: makespan");
}

/// Compare scalar replay vs lockstep (lanes 1, 8) vs the wide SoA
/// kernel (widths 1, 8, 16) on one bank.
fn assert_lane_invariant(s: &Scenario, policy: Policy, reps: u64, bank_reps: u64, label: &str) {
    let lead = policy.required_lead(s.platform.c);
    let bank =
        Arc::new(TraceBank::try_build(s, lead, bank_reps).unwrap().expect("study bank fits"));
    let scalar = agg_of(
        BatchRunner::Scalar(SimSession::replay(bank.clone(), s, policy).expect("replay")),
        reps,
    );
    for lanes in [1usize, 8] {
        let lockstep = agg_of(
            BatchRunner::Lockstep(
                BatchEngine::new(bank.clone(), s, policy, lanes).expect("batch engine"),
            ),
            reps,
        );
        assert_bit_identical(&scalar, &lockstep, &format!("{label} lanes={lanes}"));
    }
    for width in [1usize, 8, 16] {
        let wide = agg_of(
            BatchRunner::Wide(
                WideKernel::new(bank.clone(), s, policy, width).expect("wide kernel"),
            ),
            reps,
        );
        assert_bit_identical(&scalar, &wide, &format!("{label} wide={width}"));
    }
}

/// The golden: all five paper strategies, exp + Weibull faults, lane
/// widths 1 and 8 vs the scalar replay loop — every aggregate field
/// (except wall-clock) identical to the bit. The window is 3000 s so
/// WithCkptI has room for its in-window checkpoint.
#[test]
fn paper_strategies_are_lane_invariant() {
    for dist in [DistSpec::Exp, DistSpec::weibull(0.7)] {
        let s = study(dist, Predictor::windowed(0.85, 0.82, 3000.0));
        for kind in [
            StrategyKind::Young,
            StrategyKind::ExactPrediction,
            StrategyKind::Instant,
            StrategyKind::NoCkptI,
            StrategyKind::WithCkptI,
        ] {
            // resolve_policy applies the §5 EXACTPREDICTION rule (the
            // exact-date trace variant) exactly as the sweeps do.
            let rp = resolve_policy(&PolicySpec::Strategy(kind), &s).unwrap();
            assert_lane_invariant(&rp.scenario, rp.policy, 10, 10, &format!("{kind:?}/{dist}"));
        }
    }
}

/// The non-paper policies ride the same contract: adaptive re-derives
/// its period online, risk draws on volume-at-risk — both fold through
/// the lockstep chunks bit-identically.
#[test]
fn adaptive_and_risk_policies_are_lane_invariant() {
    for dist in [DistSpec::Exp, DistSpec::weibull(0.7)] {
        let s = study(dist, Predictor::windowed(0.85, 0.82, 300.0));
        for spec in ["adaptive:0.75", "risk:2"] {
            let pspec: PolicySpec = spec.parse().unwrap();
            let rp = resolve_policy(&pspec, &s).unwrap();
            assert_lane_invariant(&rp.scenario, rp.policy, 10, 10, &format!("{spec}/{dist}"));
        }
    }
}

/// Forced mid-batch fallback: a bank that covers only 5 of 12
/// replications leaves uncovered lanes *inside* a lanes=8 chunk. The
/// fallback lanes re-run live and the aggregate still matches the
/// scalar path (whose per-rep fallback is the reference), and the
/// process-global batch counters move accordingly.
#[test]
fn mid_batch_underrun_falls_back_bit_identically() {
    let s = study(DistSpec::weibull(0.7), Predictor::exact(0.85, 0.82));
    let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
    let policy = Policy::from_spec(&spec, s.platform.c);
    let lead = policy.required_lead(s.platform.c);
    let bank = Arc::new(TraceBank::try_build(&s, lead, 5).unwrap().expect("study bank fits"));

    let before = ckptfp::sim::batch::counters();
    let scalar =
        agg_of(BatchRunner::Scalar(SimSession::replay(bank.clone(), &s, policy).unwrap()), 12);
    let lockstep = agg_of(
        BatchRunner::Lockstep(BatchEngine::new(bank, &s, policy, 8).unwrap()),
        12,
    );
    assert_bit_identical(&scalar, &lockstep, "underrun lanes=8");
    let after = ckptfp::sim::batch::counters();
    // Counters are process-global and other tests run concurrently, so
    // assert monotone movement: 12 lanes ran, 7 of them fell back.
    assert!(after.lanes_run >= before.lanes_run + 12, "lanes_run moved");
    assert!(after.lane_fallbacks >= before.lane_fallbacks + 7, "lane_fallbacks moved");
}

/// Forced mid-chunk eviction in the wide kernel: a bank that covers
/// only 5 of 12 replications leaves uncovered lanes *inside* a
/// width-8 chunk. Evicted lanes re-run on the scalar live fallback
/// and the aggregate still matches the scalar path, while the
/// process-global wide counters move accordingly.
#[test]
fn wide_mid_chunk_eviction_falls_back_bit_identically() {
    let s = study(DistSpec::weibull(0.7), Predictor::exact(0.85, 0.82));
    let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
    let policy = Policy::from_spec(&spec, s.platform.c);
    let lead = policy.required_lead(s.platform.c);
    let bank = Arc::new(TraceBank::try_build(&s, lead, 5).unwrap().expect("study bank fits"));

    let before = ckptfp::sim::wide::counters();
    let scalar =
        agg_of(BatchRunner::Scalar(SimSession::replay(bank.clone(), &s, policy).unwrap()), 12);
    let wide = agg_of(BatchRunner::Wide(WideKernel::new(bank, &s, policy, 8).unwrap()), 12);
    assert_bit_identical(&scalar, &wide, "eviction width=8");
    let after = ckptfp::sim::wide::counters();
    // Counters are process-global and other tests run concurrently, so
    // assert monotone movement: 12 lanes ran, 7 of them evicted.
    assert!(after.lanes_run >= before.lanes_run + 12, "wide_lanes_run moved");
    assert!(after.evictions >= before.evictions + 7, "wide_evictions moved");
}

/// Chaos-forced eviction: with the `chaos` feature on, every
/// `BankReplay` span lookup is forced to report an underrun, so every
/// wide lane evicts at reset — *mid-chunk*, not just past the bank's
/// coverage — and the aggregate still matches the clean scalar
/// reference. (Probability-1.0 injection keeps the test immune to
/// concurrent tests consuming hits from the shared chaos plan; forced
/// underruns are harmless to them by the same fallback contract.)
#[cfg(feature = "chaos")]
#[test]
fn chaos_forced_wide_eviction_keeps_aggregates_unchanged() {
    use ckptfp::chaos::{self, Action, ChaosPlan, Point};
    let s = study(DistSpec::weibull(0.7), Predictor::exact(0.85, 0.82));
    let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
    let policy = Policy::from_spec(&spec, s.platform.c);
    let lead = policy.required_lead(s.platform.c);
    let bank = Arc::new(TraceBank::try_build(&s, lead, 8).unwrap().expect("study bank fits"));

    // Clean scalar reference first, before any plan is installed.
    let scalar =
        agg_of(BatchRunner::Scalar(SimSession::replay(bank.clone(), &s, policy).unwrap()), 8);

    let before = ckptfp::sim::wide::counters();
    chaos::install(ChaosPlan::new().with_prob(Point::BankReplay, 7, 1.0, Action::Underrun));
    let wide = agg_of(BatchRunner::Wide(WideKernel::new(bank, &s, policy, 4).unwrap()), 8);
    chaos::reset();

    assert_bit_identical(&scalar, &wide, "chaos eviction width=4");
    let after = ckptfp::sim::wide::counters();
    assert!(after.lanes_run >= before.lanes_run + 8, "wide_lanes_run moved");
    assert!(after.evictions >= before.evictions + 8, "every lane evicted");
}

/// Default-option `best_period_with` (the wide SoA kernel) is
/// bit-identical to both the explicit lockstep search and the
/// scalar-pinned one — the end-to-end wiring of the same contract the
/// unit aggregates pin above.
#[test]
fn best_period_default_lanes_match_the_pinned_scalar_path() {
    use ckptfp::sim::BatchOptions;
    use ckptfp::strategies::{best_period_with, BestPeriodOptions};
    let s = study(DistSpec::weibull(0.7), Predictor::windowed(0.85, 0.82, 300.0));
    let base = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    let run = |batch: BatchOptions| {
        best_period_with(
            &s,
            &base,
            8,
            6,
            &BestPeriodOptions { workers: 2, prune: false, replay: true, batch },
        )
        .unwrap()
    };
    let wide = run(BatchOptions::default());
    let lockstep = run(BatchOptions::lockstep(8));
    let scalar = run(BatchOptions::scalar());
    for (got, label) in [(&wide, "wide"), (&lockstep, "lockstep")] {
        assert_eq!(got.t_r.to_bits(), scalar.t_r.to_bits(), "{label}: t_r");
        assert_eq!(got.waste.to_bits(), scalar.waste.to_bits(), "{label}: waste");
        assert_eq!(got.reps_used, scalar.reps_used, "{label}: reps_used");
        for (a, b) in got.sweep.iter().zip(&scalar.sweep) {
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "{label}: sweep waste");
        }
    }
}
