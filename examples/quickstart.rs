//! Quickstart: plan a checkpointing strategy for a platform with a
//! fault predictor, then verify the plan by simulation.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ckptfp::config::{Predictor, Scenario};
use ckptfp::experiments::scenario_for;
use ckptfp::model::{plan, Capping, Params, StrategyKind};
use ckptfp::sim::run_replications;
use ckptfp::strategies::spec_for;
use ckptfp::util::units::MIN;

fn main() -> anyhow::Result<()> {
    // A 65k-node platform (mu ≈ 1000 mn) with the BlueGene/P predictor
    // of Yu et al. [12]: recall 0.85, precision 0.82, exact dates.
    let scenario = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
    println!(
        "platform: N = {}, mu = {:.0} mn, C = R = 10 mn, D = 1 mn",
        scenario.platform.n_procs,
        scenario.mu() / MIN
    );

    // 1. Plan analytically (closed forms, Eqs. 1-7 of the paper).
    let params = Params::from_scenario(&scenario);
    let best = plan(&params, Capping::Uncapped, false);
    println!("\nanalytical plan:");
    for k in StrategyKind::ALL {
        println!(
            "  {:<16} T = {:>8.1} s  waste = {:.4}",
            k.name(),
            best.period[k as usize],
            best.waste[k as usize]
        );
    }
    println!(
        "winner: {} with period {:.1} s (q = {})",
        best.winner.name(),
        best.winner_period(),
        best.q
    );

    // 2. Verify by simulation: Young vs the winner, 40 replications.
    println!("\nsimulation check (Exponential faults, 40 reps):");
    let mut exp = scenario.clone();
    exp.fault_dist = ckptfp::dist::DistSpec::Exp;
    for kind in [StrategyKind::Young, best.winner] {
        let s = scenario_for(kind, &exp);
        let spec = spec_for(kind, &s, Capping::Uncapped);
        let report = run_replications(&s, &spec, 40)?;
        println!(
            "  {:<16} simulated waste = {}  (analytic {:.4})",
            spec.name,
            report.waste,
            best.waste[kind as usize]
        );
    }
    println!("\nPrediction turns waste {:.3} into {:.3} — the paper's headline effect.",
        best.waste[StrategyKind::Young as usize], best.winner_waste());
    Ok(())
}
