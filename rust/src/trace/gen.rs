//! Stochastic trace generator — the §5 simulation-engine front-end.
//!
//! Faults: i.i.d. inter-arrivals from the failure law (scaled to mean
//! mu), each marked predicted with probability r. True predictions:
//! the fault is placed uniformly inside its window (t0 = t_f − U·I),
//! announced at `t0 − lead`. False predictions: an independent stream
//! with inter-arrival expectation p·mu / (r·(1−p)) from either the
//! same law or a uniform one (Figures 5/7).
//!
//! Emission order: faults are trivially monotone; predictions need
//! lookahead because a true prediction for a *later* fault can become
//! available *earlier* (windows shift availability back by up to
//! I + lead). The generator therefore keeps generating faults until the
//! fault clock passes `candidate.avail + window + lead` before emitting
//! a prediction candidate.

use std::collections::VecDeque;

use super::{EventSource, Fault, Prediction};
use crate::config::Scenario;
use crate::dist::Dist;
use crate::rng::{substream, Pcg64};

#[derive(Debug)]
pub struct TraceGen {
    // Monomorphized laws, parsed once per generator — never re-parsed
    // or boxed on the sampling hot path.
    fault_dist: Dist,
    false_dist: Option<Dist>,
    recall: f64,
    window: f64,
    lead: f64,
    rng_fault: Pcg64,
    rng_mark: Pcg64,
    rng_win: Pcg64,
    rng_false: Pcg64,
    clock_fault: f64,
    clock_false: f64,
    next_id: u64,
    fault_buf: VecDeque<Fault>,
    // True-prediction candidates awaiting safe emission, kept sorted by avail.
    true_buf: VecDeque<Prediction>,
    pending_false: Option<Prediction>,
}

impl TraceGen {
    /// Build a generator for one replication of a scenario.
    /// `lead` is the proactive-action lead the consumer needs (>= C for
    /// checkpointing strategies, >= M for migration).
    pub fn new(scenario: &Scenario, lead: f64, seed: u64, rep: u64) -> anyhow::Result<TraceGen> {
        let mu = scenario.mu();
        let pred = &scenario.predictor;
        let fault_dist = scenario.fault_dist.dist()?.with_mean(mu);
        let false_interval = pred.false_pred_interval(mu);
        let false_dist = if false_interval.is_finite() {
            Some(scenario.false_dist_spec().dist()?.with_mean(false_interval))
        } else {
            None
        };
        Ok(TraceGen::from_dists(fault_dist, false_dist, pred.recall, pred.window, lead, seed, rep))
    }

    /// Build from pre-parsed laws (the [`crate::sim::SimSession`] path:
    /// specs are parsed once per session, not once per replication).
    pub fn from_dists(
        fault_dist: Dist,
        false_dist: Option<Dist>,
        recall: f64,
        window: f64,
        lead: f64,
        seed: u64,
        rep: u64,
    ) -> TraceGen {
        TraceGen {
            fault_dist,
            false_dist,
            recall,
            window,
            lead,
            rng_fault: substream(seed, "fault", rep),
            rng_mark: substream(seed, "mark", rep),
            rng_win: substream(seed, "win", rep),
            rng_false: substream(seed, "false", rep),
            clock_fault: 0.0,
            clock_false: 0.0,
            next_id: 0,
            fault_buf: VecDeque::new(),
            true_buf: VecDeque::new(),
            pending_false: None,
        }
    }

    /// Rewind to the start of replication `rep` of `seed`, reusing the
    /// parsed laws and the event buffers' capacity. A reset generator
    /// emits the exact same streams as a freshly built one — the RNG
    /// substreams are re-derived from `(seed, label, rep)`, so there is
    /// no state carry-over between replications.
    pub fn reset(&mut self, seed: u64, rep: u64) {
        self.rng_fault = substream(seed, "fault", rep);
        self.rng_mark = substream(seed, "mark", rep);
        self.rng_win = substream(seed, "win", rep);
        self.rng_false = substream(seed, "false", rep);
        self.clock_fault = 0.0;
        self.clock_false = 0.0;
        self.next_id = 0;
        self.fault_buf.clear();
        self.true_buf.clear();
        self.pending_false = None;
    }

    /// Generate one more fault (and possibly its prediction candidate).
    fn gen_fault(&mut self) {
        self.clock_fault += self.fault_dist.sample(&mut self.rng_fault);
        let predicted = self.rng_mark.bernoulli(self.recall);
        let id = self.next_id;
        self.next_id += 1;
        let t = self.clock_fault;
        self.fault_buf.push_back(Fault { t, id, predicted });
        if predicted {
            // Fault uniform inside its window: t0 = t_f − U·I.
            let offset = if self.window > 0.0 { self.rng_win.next_f64() * self.window } else { 0.0 };
            let t0 = t - offset;
            let p = Prediction::windowed(t0, self.window, self.lead, Some(id));
            // Insert keeping true_buf sorted by avail (windows can invert
            // the order of nearby faults' predictions).
            let pos = self
                .true_buf
                .iter()
                .position(|q| q.avail > p.avail)
                .unwrap_or(self.true_buf.len());
            self.true_buf.insert(pos, p);
        }
    }

    fn peek_false(&mut self) -> Option<&Prediction> {
        if self.pending_false.is_none() {
            let dist = self.false_dist?;
            self.clock_false += dist.sample(&mut self.rng_false);
            self.pending_false = Some(Prediction::windowed(
                self.clock_false,
                self.window,
                self.lead,
                None,
            ));
        }
        self.pending_false.as_ref()
    }
}

impl EventSource for TraceGen {
    fn next_fault(&mut self) -> Option<Fault> {
        if self.fault_buf.is_empty() {
            self.gen_fault();
        }
        self.fault_buf.pop_front()
    }

    fn next_prediction(&mut self) -> Option<Prediction> {
        loop {
            let false_avail = self.peek_false().map(|p| p.avail).unwrap_or(f64::INFINITY);
            let true_avail = self.true_buf.front().map(|p| p.avail).unwrap_or(f64::INFINITY);
            let candidate = true_avail.min(false_avail);
            // The from-parsed-dists form of `Predictor::never_fires`
            // (a None false_dist is exactly an infinite false-pred
            // interval): the only way this stream returns None.
            if candidate.is_infinite() && self.false_dist.is_none() && self.recall == 0.0 {
                return None; // predictor never fires
            }
            // Any not-yet-generated fault lies after clock_fault, so its
            // prediction's avail > clock_fault − window − lead. Emission
            // is safe once that bound passes the candidate.
            if self.clock_fault - self.window - self.lead > candidate {
                return if true_avail <= false_avail {
                    self.true_buf.pop_front()
                } else {
                    self.pending_false.take()
                };
            }
            self.gen_fault();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};

    fn scenario(recall: f64, precision: f64, window: f64, dist: &str) -> Scenario {
        let pred = if window > 0.0 {
            Predictor::windowed(recall, precision, window)
        } else {
            Predictor::exact(recall, precision)
        };
        let mut s = Scenario::paper(1 << 16, pred);
        s.fault_dist = dist.parse().expect("test dist spec");
        s
    }

    fn drain(gen: &mut TraceGen, horizon: f64) -> (Vec<Fault>, Vec<Prediction>) {
        let mut faults = Vec::new();
        let mut preds = Vec::new();
        while let Some(f) = gen.next_fault() {
            if f.t > horizon {
                break;
            }
            faults.push(f);
        }
        while let Some(p) = gen.next_prediction() {
            if p.avail > horizon {
                break;
            }
            preds.push(p);
        }
        (faults, preds)
    }

    #[test]
    fn streams_are_monotone() {
        let s = scenario(0.85, 0.82, 3000.0, "weibull:0.7");
        let mut gen = TraceGen::new(&s, 600.0, 1, 0).unwrap();
        let (faults, preds) = drain(&mut gen, 5e7);
        assert!(faults.len() > 100);
        assert!(preds.len() > 100);
        for w in faults.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
        for w in preds.windows(2) {
            assert!(w[0].avail <= w[1].avail, "{} > {}", w[0].avail, w[1].avail);
        }
    }

    #[test]
    fn empirical_mtbf() {
        let s = scenario(0.85, 0.82, 0.0, "exp");
        let mu = s.mu();
        let mut gen = TraceGen::new(&s, 600.0, 2, 0).unwrap();
        let horizon = mu * 5000.0;
        let (faults, _) = drain(&mut gen, horizon);
        let emp = horizon / faults.len() as f64;
        assert!((emp - mu).abs() / mu < 0.05, "MTBF {emp} vs {mu}");
    }

    #[test]
    fn empirical_recall_and_precision() {
        let s = scenario(0.7, 0.4, 0.0, "exp");
        let mut gen = TraceGen::new(&s, 600.0, 3, 0).unwrap();
        let (faults, preds) = drain(&mut gen, s.mu() * 8000.0);
        let predicted = faults.iter().filter(|f| f.predicted).count();
        let recall = predicted as f64 / faults.len() as f64;
        assert!((recall - 0.7).abs() < 0.03, "recall {recall}");
        let true_preds = preds.iter().filter(|p| p.is_true_positive()).count();
        let precision = true_preds as f64 / preds.len() as f64;
        assert!((precision - 0.4).abs() < 0.03, "precision {precision}");
    }

    #[test]
    fn window_contains_fault() {
        let s = scenario(0.9, 0.8, 3000.0, "weibull:0.5");
        let mut gen = TraceGen::new(&s, 600.0, 4, 0).unwrap();
        let (faults, preds) = drain(&mut gen, 5e7);
        let by_id: std::collections::HashMap<u64, f64> =
            faults.iter().map(|f| (f.id, f.t)).collect();
        let mut checked = 0;
        for p in &preds {
            if let Some(id) = p.fault_id {
                if let Some(&tf) = by_id.get(&id) {
                    assert!(tf >= p.t0 - 1e-9 && tf <= p.t_end() + 1e-9);
                    assert!(p.avail <= p.t0 - 600.0 + 1e-9);
                    checked += 1;
                }
            }
        }
        assert!(checked > 50);
    }

    #[test]
    fn no_predictor_no_predictions() {
        let s = scenario(0.0, 1.0, 0.0, "exp");
        let mut gen = TraceGen::new(&s, 600.0, 5, 0).unwrap();
        assert!(gen.next_prediction().is_none());
        assert!(gen.next_fault().is_some());
    }

    #[test]
    fn perfect_precision_no_false_predictions() {
        let s = scenario(0.8, 1.0, 0.0, "exp");
        let mut gen = TraceGen::new(&s, 600.0, 6, 0).unwrap();
        let (_, preds) = drain(&mut gen, 1e8);
        assert!(!preds.is_empty());
        assert!(preds.iter().all(Prediction::is_true_positive));
    }

    #[test]
    fn reps_produce_distinct_traces() {
        let s = scenario(0.85, 0.82, 0.0, "exp");
        let t1: Vec<f64> = {
            let mut g = TraceGen::new(&s, 600.0, 7, 0).unwrap();
            (0..10).map(|_| g.next_fault().unwrap().t).collect()
        };
        let t2: Vec<f64> = {
            let mut g = TraceGen::new(&s, 600.0, 7, 1).unwrap();
            (0..10).map(|_| g.next_fault().unwrap().t).collect()
        };
        assert_ne!(t1, t2);
        let t1b: Vec<f64> = {
            let mut g = TraceGen::new(&s, 600.0, 7, 0).unwrap();
            (0..10).map(|_| g.next_fault().unwrap().t).collect()
        };
        assert_eq!(t1, t1b);
    }

    #[test]
    fn reset_matches_fresh_generator() {
        // Buffer-reusing reset must be bit-identical to fresh
        // construction, even when the previous replication was left
        // mid-stream with events still buffered.
        let s = scenario(0.85, 0.82, 3000.0, "weibull:0.7");
        let mut reused = TraceGen::new(&s, 600.0, 9, 0).unwrap();
        for rep in [3u64, 0, 7] {
            reused.reset(9, rep);
            let mut fresh = TraceGen::new(&s, 600.0, 9, rep).unwrap();
            for _ in 0..50 {
                assert_eq!(reused.next_fault(), fresh.next_fault());
            }
            for _ in 0..20 {
                assert_eq!(reused.next_prediction(), fresh.next_prediction());
            }
        }
    }

    #[test]
    fn uniform_false_pred_dist() {
        let mut s = scenario(0.7, 0.4, 300.0, "weibull:0.7");
        s.false_pred_dist = Some(crate::dist::DistSpec::Uniform);
        let mut gen = TraceGen::new(&s, 600.0, 8, 0).unwrap();
        let (_, preds) = drain(&mut gen, 3e7);
        let false_count = preds.iter().filter(|p| !p.is_true_positive()).count();
        assert!(false_count > 50);
    }
}
