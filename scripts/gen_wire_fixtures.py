#!/usr/bin/env python3
"""Regenerate the wire-protocol golden fixtures under rust/tests/fixtures/.

Mirrors the canonical JSONL encoding of `rust/src/util/json.rs` +
`rust/src/api/wire.rs` exactly:

* objects serialize with keys in lexicographic (BTreeMap) order;
* numbers that are integral with |x| < 1e15 print as integers;
* other finite numbers print as Python's repr — identical to Rust's
  shortest-round-trip f64 Display for values in [1e-3, 1e15), which is
  why every fixture value stays inside that range.

The fixtures pin the protocol byte-for-byte: `tests/test_wire_golden.rs`
constructs the same typed requests/responses in Rust and asserts both
decode(fixture) == typed and encode(typed) == fixture. Drift in either
direction fails loudly.
"""

import os

OUT = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "fixtures")


def jnum(x):
    if isinstance(x, bool):
        raise TypeError("bools are not numbers here")
    x = float(x)
    if x == int(x) and abs(x) < 1e15:
        return str(int(x))
    return repr(x)


def jval(v):
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return jnum(v)
    if isinstance(v, str):
        # Fixture strings are plain ASCII without escapes by design.
        assert all(32 <= ord(c) < 127 and c not in '"\\' for c in v), v
        return f'"{v}"'
    if isinstance(v, list):
        return "[" + ",".join(jval(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(f'"{k}":{jval(v[k])}' for k in sorted(v)) + "}"
    raise TypeError(type(v))


def scenario(**over):
    """The golden scenario: Scenario::paper(4096, windowed(0.85, 0.82, 300))
    with mu_ind = 60000 * 4096, work 200000, exp faults, seed 42."""
    s = {
        "alpha": 0.27,
        "c": 600,
        "d": 60,
        "ef": 150,
        "fault_dist": "exp",
        "migration": 300,
        "mu_ind": 245760000,
        "n_procs": 4096,
        "precision": 0.82,
        "r": 600,
        "recall": 0.85,
        "seed": 42,
        "window": 300,
        "work": 200000,
    }
    s.update(over)
    return s


# Variant with every optional field exercised: Weibull faults, distinct
# false-prediction law, non-default ef/alpha/migration.
WEIBULL_SCENARIO = scenario(
    alpha=0.3,
    ef=1000,
    fault_dist="weibull:0.7",
    false_pred_dist="uniform",
    migration=450,
    seed=7,
    window=3000,
)

REQUESTS_V2 = [
    {"v": 2, "op": "plan", "scenario": scenario(), "capped": True},
    {"v": 2, "op": "plan", "scenario": scenario(), "capped": False, "policy": "NoCkptI"},
    {"v": 2, "op": "simulate", "scenario": scenario(), "strategy": "NoCkptI", "reps": 17,
     "workers": 3},
    # Additive v2 "platform" field: canonical PlatformSpec display form.
    {"v": 2, "op": "simulate", "scenario": WEIBULL_SCENARIO, "strategy": "Young", "reps": 5,
     "policy": "risk:2.5", "platform": "nodes=4,commit=0.05"},
    {"v": 2, "op": "best_period", "scenario": scenario(), "strategy": "Migration", "reps": 9,
     "candidates": 12, "prune": True, "platform": "nodes=8"},
    {"v": 2, "op": "best_period", "scenario": scenario(), "strategy": "Young", "reps": 3,
     "candidates": 4, "workers": 2, "prune": False, "policy": "adaptive:0.75"},
    {"v": 2, "op": "sweep", "scenario": scenario(), "n_procs": [16384, 65536, 524288],
     "capped": False},
    {"v": 2, "op": "verify", "grid": "quick", "reps": 32, "budget": 128, "workers": 2,
     "policy": "risk:1", "platform": "nodes=4"},
    {"v": 2, "op": "stats"},
    {"v": 2, "op": "ping"},
]

PLAN_PAYLOAD = {
    "winner": "ExactPrediction",
    "q": 1,
    "winner_waste": 0.105,
    "winner_period": 21900.5,
    "strategies": [
        {"name": "Young", "waste": 0.117, "period": 8485.25},
        {"name": "ExactPrediction", "waste": 0.105, "period": 21900.5},
        {"name": "Instant", "waste": 0.11, "period": 21900.5},
        {"name": "NoCkptI", "waste": 0.112, "period": 21900.5},
        {"name": "WithCkptI", "waste": 1, "period": 21900.5},
        {"name": "Migration", "waste": 0.09, "period": 21900.5},
    ],
}

SIMULATE_PAYLOAD = {
    "strategy": "NoCkptI",
    "reps": 40,
    "workers": 4,
    "mean_waste": 0.123456789012345,
    "waste_ci95": 0.01,
    "mean_makespan": 10000000,
    "completion_rate": 1,
    "n_faults": 321,
    "n_preds": 200,
    "n_ckpts": 1000,
    "n_proactive_ckpts": 55,
    "sim_seconds": 1.25,
}

BEST_PERIOD_PAYLOAD = {
    "strategy": "Young",
    "t_r": 8123.4,
    "waste": 0.117,
    "n_pruned": 3,
    "reps": 10,
    # Additive: replications actually simulated after pruning (the
    # honest spend; requested budget would have been reps*candidates).
    "reps_used": 24,
    "candidates": 3,
    "workers": 8,
    "sweep": [[1000, 0.2], [2000, 0.15], [4000, 0.117]],
}

SWEEP_PAYLOAD = {
    "planner": "analytic",
    "rows": [
        {"n_procs": 65536, "mu": 60133, "winner": "ExactPrediction",
         "winner_waste": 0.11, "winner_period": 9000},
        {"n_procs": 524288, "mu": 7516.5, "winner": "Young",
         "winner_waste": 0.4, "winner_period": 3000},
    ],
}

VERIFY_PAYLOAD = {
    "grid": "quick",
    "workers": 4,
    "n_pass": 1,
    "n_fail": 0,
    "n_inconclusive": 1,
    "cases": [
        {"name": "exp-n16-none-Young", "policy": "Young", "analytic": 0.117,
         "band_lo": 0.097, "band_hi": 0.137, "sim_mean": 0.1175, "sim_ci95": 0.004,
         "completion_rate": 1, "reps": 48, "verdict": "pass", "domain": "first_order"},
        {"name": "weibull:0.5-n16-none-Young", "policy": "Young", "analytic": 0.117,
         "band_lo": 0.03, "band_hi": 0.47, "sim_mean": 0.46, "sim_ci95": 0.02,
         "completion_rate": 1, "reps": 384, "verdict": "inconclusive",
         "domain": "out_of_domain", "domain_reason": "weibull:0.5 faults"},
    ],
}

STATS_PAYLOAD = {
    "requests": 10,
    "errors": 2,
    "plans": 3,
    "simulates": 4,
    "best_periods": 1,
    "sweeps": 0,
    "verifies": 2,
    "lat_p50_s": 0.001,
    "lat_p95_s": 0.01,
    "lat_p99_s": 0.02,
    "lat_n": 8,
    # Additive trace-bank reuse counters (v2 only; the legacy stats
    # shape below carries none of these).
    "banks_built": 2,
    "bank_replays": 1536,
    "bank_fallbacks": 3,
    "bank_bytes_resident": 1048576,
    # Additive robustness counters (v2 only): shed load, tripped
    # deadlines, contained panics, client-side transport retries.
    "rejected_overloaded": 5,
    "deadline_exceeded": 1,
    "panics_contained": 2,
    "client_retries": 7,
    # Additive lockstep batch-engine counters (v2 only): lanes run
    # through batch chunks, lanes that fell back on a bank underrun.
    "batch_lanes_run": 512,
    "batch_lane_fallbacks": 4,
    # Additive wide SoA kernel counters (v2 only): lanes swept through
    # the struct-of-arrays kernel, lanes evicted to the scalar fallback.
    "wide_lanes_run": 4096,
    "wide_evictions": 9,
    # Additive plan-cache counters (v2 only): memoized Plan/BestPeriod/
    # Sweep lookups, live entry count, LRU evictions.
    "cache_hits": 6,
    "cache_misses": 4,
    "cache_evictions": 1,
    "cache_entries": 3,
    "batcher": {"requests": 3, "batches": 1, "max_batch": 3},
}

STATS_DEFAULT = {
    "requests": 0, "errors": 0, "plans": 0, "simulates": 0, "best_periods": 0,
    "sweeps": 0, "verifies": 0, "lat_p50_s": 0, "lat_p95_s": 0, "lat_p99_s": 0,
    "lat_n": 0, "banks_built": 0, "bank_replays": 0, "bank_fallbacks": 0,
    "bank_bytes_resident": 0, "rejected_overloaded": 0, "deadline_exceeded": 0,
    "panics_contained": 0, "client_retries": 0, "batch_lanes_run": 0,
    "batch_lane_fallbacks": 0, "wide_lanes_run": 0, "wide_evictions": 0,
    "cache_hits": 0, "cache_misses": 0,
    "cache_evictions": 0, "cache_entries": 0,
}

RESPONSES_V2 = [
    {"v": 2, "ok": True, "job": "plan", "planner": "analytic", **PLAN_PAYLOAD},
    {"v": 2, "ok": True, "job": "simulate", **SIMULATE_PAYLOAD},
    {"v": 2, "ok": True, "job": "best_period", **BEST_PERIOD_PAYLOAD},
    {"v": 2, "ok": True, "job": "sweep", **SWEEP_PAYLOAD},
    {"v": 2, "ok": True, "job": "verify", **VERIFY_PAYLOAD},
    {"v": 2, "ok": True, "job": "stats", **STATS_PAYLOAD},
    {"v": 2, "ok": True, "job": "stats", **STATS_DEFAULT},
    {"v": 2, "ok": True, "job": "ping", "pong": True},
    {"v": 2, "ok": False, "code": "bad_request", "error": "work must be positive"},
    # Robustness errors: `overloaded` carries an additive retry hint;
    # `deadline_exceeded` reports partial progress in its message.
    {"v": 2, "ok": False, "code": "overloaded",
     "error": "service at capacity (32 jobs in flight); retry after 250 ms",
     "retry_after_ms": 250},
    {"v": 2, "ok": False, "code": "deadline_exceeded",
     "error": "simulate finished 96 of 1000000 replications before the deadline"},
]

# Legacy (v1) response shapes: no "v"/"job"/"planner" markers; stats
# keeps the original top-level planner counters.
RESPONSES_V1 = [
    {"ok": True, **PLAN_PAYLOAD},
    {"ok": True, "requests": 3, "batches": 1, "max_batch": 3, "errors": 2,
     "lat_p50_s": 0.001, "lat_p95_s": 0.01, "lat_p99_s": 0.02, "lat_n": 8},
    {"ok": True, "pong": True},
    {"ok": False, "code": "bad_request", "error": "work must be positive"},
]

# Legacy request *inputs* (arbitrary client bytes, not canonical): the
# golden test decodes these and pins the typed result + legacy flag.
REQUESTS_V1 = [
    '{"mu": 60000, "recall": 0.85, "precision": 0.82, "window": 300}',
    '{"op": "ping"}',
    '{"op": "stats"}',
]

# Requests carrying the additive service envelope: `tenant` (queue and
# billing identity, 1..=64 bytes) and `stream` (opt into partial-result
# frames). The fields sort into place like any other key, so tagged
# lines stay canonical.
REQUESTS_TAGGED_V2 = [
    {"v": 2, "op": "sweep", "scenario": scenario(), "n_procs": [16384, 65536, 524288],
     "capped": False, "tenant": "acme", "stream": True},
    {"v": 2, "op": "ping", "tenant": "beta"},
]

# A streamed sweep exchange: one partial frame per row — each `item`
# byte-identical to the row inside the final payload — then the final
# frame, which is the standard v2 response plus frame/seq markers.
STREAM_V2 = [
    {"v": 2, "ok": True, "frame": "partial", "job": "sweep", "seq": 0,
     "item": SWEEP_PAYLOAD["rows"][0]},
    {"v": 2, "ok": True, "frame": "partial", "job": "sweep", "seq": 1,
     "item": SWEEP_PAYLOAD["rows"][1]},
    {"v": 2, "ok": True, "frame": "final", "seq": 2, "job": "sweep", **SWEEP_PAYLOAD},
]


def main():
    os.makedirs(OUT, exist_ok=True)
    files = {
        "requests_v2.jsonl": [jval(r) for r in REQUESTS_V2],
        "responses_v2.jsonl": [jval(r) for r in RESPONSES_V2],
        "responses_v1.jsonl": [jval(r) for r in RESPONSES_V1],
        "requests_v1.jsonl": REQUESTS_V1,
        "requests_tagged_v2.jsonl": [jval(r) for r in REQUESTS_TAGGED_V2],
        "stream_v2.jsonl": [jval(r) for r in STREAM_V2],
    }
    for name, lines in files.items():
        path = os.path.join(OUT, name)
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"wrote {path} ({len(lines)} lines)")


if __name__ == "__main__":
    main()
