//! The job service: a TCP listener speaking the JSONL job protocol
//! (v2, with the v1 planner dialect adapted transparently), one thread
//! per connection, every request dispatched through a shared
//! [`Executor`] — the same entry points the CLI and the experiment
//! harness use in-process.

use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::api::{wire, Executor, JobResponse};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address, e.g. "127.0.0.1:7471". Port 0 picks a free port.
    pub addr: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { addr: "127.0.0.1:7471".into() }
    }
}

/// Running service handle: local address + shutdown flag.
pub struct ServiceHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop with a dummy connection. The bound
        // address may be unconnectable (0.0.0.0 / ::), so aim the nudge
        // at the loopback of the same family, same port.
        let mut nudge = self.addr;
        if nudge.ip().is_unspecified() {
            nudge.set_ip(match nudge.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect_timeout(&nudge, Duration::from_millis(250));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start serving in background threads. The executor (its batcher
/// handle and metrics) is shared across connections.
pub fn serve(executor: Executor, cfg: ServiceConfig) -> anyhow::Result<ServiceHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let join = std::thread::Builder::new().name("ckptfp-accept".into()).spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let executor = executor.clone();
                    let _ = std::thread::Builder::new()
                        .name("ckptfp-conn".into())
                        .spawn(move || handle_connection(stream, executor));
                }
                Err(_) => break,
            }
        }
    })?;
    Ok(ServiceHandle { addr, stop, join: Some(join) })
}

fn handle_connection(stream: TcpStream, executor: Executor) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let response = match wire::decode_request(&line) {
            Err(e) => {
                executor.note_rejected();
                // Answer in the dialect the line arrived in: a v1 line
                // that failed validation still gets the legacy error
                // shape (no "v" marker). Unparseable lines default to
                // the v2 shape — both dialects read ok:false + error.
                wire::encode_response(&JobResponse::Error(e), wire::line_is_legacy(&line))
            }
            Ok(decoded) => {
                wire::encode_response(&executor.execute(&decoded.request), decoded.legacy)
            }
        };
        if writer.write_all(response.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
            || writer.flush().is_err()
        {
            break;
        }
    }
}

/// Minimal blocking *raw-line* client, for tests and tools that need
/// byte-level control over what goes on the wire (e.g. the v1
/// back-compat pins). Typed callers should use
/// [`crate::api::ServiceClient`] instead.
pub struct PlannerClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl PlannerClient {
    pub fn connect(addr: &str) -> anyhow::Result<PlannerClient> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(PlannerClient { reader: BufReader::new(stream), writer })
    }

    /// Send one JSONL request, read one JSONL response.
    pub fn call(&mut self, request: &str) -> anyhow::Result<Json> {
        self.writer.write_all(request.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "server closed the connection");
        crate::util::json::parse(line.trim())
    }
}
