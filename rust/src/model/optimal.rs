//! §3.3 / §4.3 case analysis: optimal periods per strategy, capped
//! (the rigorous domain [C, alpha*mu_e]) and uncapped (the extremum
//! formulas the §5 simulations use).

use super::{
    tp_opt, waste_of, OptimalPlan, Params, StrategyKind,
};

/// How the admissible-period domain is treated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Capping {
    /// T in [C, alpha*mu(_e)] per §3.2 — the rigorous model.
    Capped,
    /// T = max(C, T_extr) — §5's "uncapped" variant, accurate in
    /// practice across the whole study range.
    Uncapped,
}

/// The unified extremum formula of the paper's conclusion:
/// T_extr = sqrt( 2 mu C / (1 - r q) ).
pub fn t_extr(p: &Params, q: f64) -> f64 {
    let denom = 1.0 - p.recall * q;
    if denom <= 0.0 {
        // r = q = 1: predictor catches everything; no periodic
        // checkpoint needed — push the period to the domain's top.
        f64::INFINITY
    } else {
        (2.0 * p.mu * p.c / denom).sqrt()
    }
}

/// Upper cap of the domain for a strategy (§3.2, §4.1).
pub fn t_cap(p: &Params, kind: StrategyKind) -> f64 {
    let mu_e = p.mu_e();
    match kind {
        StrategyKind::Young => p.alpha * p.mu,
        StrategyKind::ExactPrediction | StrategyKind::Migration => p.alpha * mu_e,
        // Window strategies study intervals of length T_R + I.
        StrategyKind::Instant | StrategyKind::NoCkptI | StrategyKind::WithCkptI => {
            p.alpha * mu_e - p.i
        }
    }
}

/// Optimal regular period for a strategy under the given capping.
pub fn optimal_period(p: &Params, kind: StrategyKind, capping: Capping) -> f64 {
    let q = if kind == StrategyKind::Young { 0.0 } else { 1.0 };
    let extr = t_extr(p, q);
    match capping {
        Capping::Uncapped => extr.max(p.c).min(1e18),
        Capping::Capped => {
            let cap = t_cap(p, kind);
            // min(cap, max(extr, C)) — degenerate domains collapse to C.
            extr.max(p.c).min(cap).max(p.c)
        }
    }
}

/// Per-strategy optimum (period, waste at that period, clamped to 1).
///
/// For `Instant` the waste (Eq. 5) is piecewise in T because of the
/// `min(E_I^f, T/2)` loss term: below T = 2 E_I^f the effective slope
/// is 1/(2 mu) (as for Young), above it (1-r)/(2 mu). The paper's
/// formula assumes the second regime; we evaluate both regime extrema
/// plus the kink and keep the best — this matches the true grid argmin
/// the AOT planner computes.
pub fn optimize(p: &Params, kind: StrategyKind, capping: Capping) -> (f64, f64) {
    let tp = tp_opt(p);
    if kind == StrategyKind::Instant && p.ef > 0.0 {
        let clamp = |t: f64| match capping {
            Capping::Uncapped => t.max(p.c),
            Capping::Capped => t.max(p.c).min(t_cap(p, kind)).max(p.c),
        };
        let kink = 2.0 * p.ef;
        let candidates = [
            clamp(t_extr(p, 1.0)), // upper regime (paper's formula)
            clamp(t_extr(p, 0.0)), // lower regime (Young-slope)
            clamp(kink),
        ];
        let (mut best_t, mut best_w) = (candidates[0], f64::INFINITY);
        for t in candidates {
            let w = waste_of(p, kind, t, tp);
            if w < best_w {
                best_w = w;
                best_t = t;
            }
        }
        let mut w = best_w;
        if capping == Capping::Capped && t_cap(p, kind) < p.c {
            w = 1.0;
        }
        return (best_t, w.min(1.0));
    }
    let t = optimal_period(p, kind, capping);
    let mut w = waste_of(p, kind, t, tp);
    // Inadmissible configurations (cap below C, WithCkptI with I < C)
    // make no progress: waste 1.
    if capping == Capping::Capped && t_cap(p, kind) < p.c {
        w = 1.0;
    }
    if kind == StrategyKind::WithCkptI && p.i < p.c {
        w = 1.0;
    }
    (t, w.min(1.0))
}

/// Full plan over all six strategies; winner = argmin of waste.
/// `include_migration = false` restricts the winner to checkpointing
/// strategies (the §3.4 migration digression assumes spare nodes).
pub fn plan(p: &Params, capping: Capping, include_migration: bool) -> OptimalPlan {
    let mut period = [0.0; 6];
    let mut waste = [1.0; 6];
    for kind in StrategyKind::ALL {
        let (t, w) = optimize(p, kind, capping);
        period[kind as usize] = t;
        waste[kind as usize] = w;
    }
    let winner = StrategyKind::ALL
        .into_iter()
        .filter(|k| include_migration || *k != StrategyKind::Migration)
        .min_by(|a, b| waste[*a as usize].total_cmp(&waste[*b as usize]))
        .unwrap();
    let q = if winner == StrategyKind::Young { 0 } else { 1 };
    OptimalPlan { period, waste, winner, q }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::util::approx_eq;
    use crate::util::units::MIN;

    fn params(n: u64, recall: f64, precision: f64, window: f64) -> Params {
        let pred = if window > 0.0 {
            Predictor::windowed(recall, precision, window)
        } else {
            Predictor::exact(recall, precision)
        };
        Params::from_scenario(&Scenario::paper(n, pred))
    }

    #[test]
    fn young_formula() {
        let p = params(1 << 16, 0.0, 1.0, 0.0);
        let t = optimal_period(&p, StrategyKind::Young, Capping::Uncapped);
        assert!(approx_eq(t, (2.0 * p.mu * p.c).sqrt(), 1e-12));
    }

    #[test]
    fn unified_formula() {
        // Conclusion: T_extr = sqrt(2 mu C / (1 - r q)).
        let p = params(1 << 16, 0.85, 0.82, 0.0);
        let t = optimal_period(&p, StrategyKind::ExactPrediction, Capping::Uncapped);
        assert!(approx_eq(t, (2.0 * p.mu * p.c / 0.15).sqrt(), 1e-12));
    }

    #[test]
    fn capped_young_at_scale() {
        // N = 2^19: sqrt(2 mu C) ≈ 3005 s > alpha mu ≈ 2032 s ⇒ capped.
        let p = params(1 << 19, 0.0, 1.0, 0.0);
        let t = optimal_period(&p, StrategyKind::Young, Capping::Capped);
        assert!(approx_eq(t, p.alpha * p.mu, 1e-12), "t={t}");
        assert!(t < (2.0 * p.mu * p.c).sqrt());
    }

    #[test]
    fn perfect_predictor_takes_cap() {
        // r = 1, q = 1: extremum diverges; capped period = cap.
        let p = params(1 << 16, 1.0, 1.0, 0.0);
        let t = optimal_period(&p, StrategyKind::ExactPrediction, Capping::Capped);
        assert!(approx_eq(t, t_cap(&p, StrategyKind::ExactPrediction), 1e-12));
    }

    #[test]
    fn waste_below_one_in_paper_range() {
        for n in crate::config::paper_proc_counts() {
            let p = params(n, 0.85, 0.82, 300.0);
            let plan = plan(&p, Capping::Capped, false);
            assert!(plan.winner_waste() < 1.0, "N={n}");
            assert!(plan.winner_waste() > 0.0);
        }
    }

    #[test]
    fn prediction_helps_mid_scale() {
        // mu = 1000 mn: trusting the good predictor beats Young.
        let p = params(1 << 16, 0.85, 0.82, 0.0);
        let plan = plan(&p, Capping::Uncapped, false);
        assert!(plan.waste[StrategyKind::ExactPrediction as usize]
            < plan.waste[StrategyKind::Young as usize]);
        assert_eq!(plan.q, 1);
    }

    #[test]
    fn capped_model_overestimates_at_scale() {
        // The §5.1 remark: at mu = 125 mn the alpha*mu_e cap makes the
        // capped ExactPrediction worse than capped Young.
        let p = params(1 << 19, 0.85, 0.82, 0.0);
        let capped = plan(&p, Capping::Capped, false);
        assert!(capped.waste[StrategyKind::ExactPrediction as usize]
            > capped.waste[StrategyKind::Young as usize]);
        // ... while the uncapped model keeps the prediction advantage.
        let uncapped = plan(&p, Capping::Uncapped, false);
        assert!(uncapped.waste[StrategyKind::ExactPrediction as usize]
            < uncapped.waste[StrategyKind::Young as usize]);
    }

    #[test]
    fn withckpt_masked_when_window_below_c() {
        let p = params(1 << 16, 0.85, 0.82, 300.0); // I = 300 < C = 600
        let (_, w) = optimize(&p, StrategyKind::WithCkptI, Capping::Capped);
        assert_eq!(w, 1.0);
    }

    #[test]
    fn exact_beats_window_strategies() {
        // Exact dates dominate window-based handling of the same events.
        let p = params(1 << 16, 0.85, 0.82, 3000.0);
        let plan = plan(&p, Capping::Uncapped, false);
        let exact = plan.waste[StrategyKind::ExactPrediction as usize];
        for kind in [StrategyKind::Instant, StrategyKind::NoCkptI, StrategyKind::WithCkptI] {
            assert!(exact <= plan.waste[kind as usize] + 1e-12, "{kind}");
        }
    }

    #[test]
    fn migration_filter() {
        let p = params(1 << 16, 0.85, 0.82, 0.0);
        let without = plan(&p, Capping::Uncapped, false);
        assert_ne!(without.winner, StrategyKind::Migration);
        let with = plan(&p, Capping::Uncapped, true);
        // With M = 300 < C + D + R migration should win here.
        assert_eq!(with.winner, StrategyKind::Migration);
    }

    #[test]
    fn mu_scaling_monotonicity() {
        // Larger platforms (smaller mu) waste more.
        let mut last = 0.0;
        for n in crate::config::paper_proc_counts() {
            let p = params(n, 0.85, 0.82, 300.0);
            let w = plan(&p, Capping::Uncapped, false).winner_waste();
            assert!(w > last, "N={n}: {w} <= {last}");
            last = w;
        }
    }

    #[test]
    fn i300_mu_in_minutes_sanity() {
        let p = params(1 << 19, 0.85, 0.82, 300.0);
        assert!((p.mu / MIN - 125.0).abs() < 1.0);
    }
}
