//! The analytic oracle: what the paper's closed forms predict for one
//! conformance case, and how far the simulator may legitimately stray.
//!
//! The §3/§4 waste model is a *first-order* analysis derived for
//! Exponential inter-arrivals and at most one event per checkpointing
//! interval. The oracle therefore states a validity domain with every
//! prediction:
//!
//! * [`Domain::FirstOrder`] — Exponential faults, a paper strategy and
//!   `(T_R + C) / mu <=` [`FIRST_ORDER_RATIO_CAP`]: the simulated waste
//!   must agree with the closed form within a CI-aware band whose
//!   half-width grows with the first-order parameter
//!   (`slack = w · (0.06 + 0.75 · (T_R + C)/mu)`). `WithCkptI` gets an
//!   asymmetric band because Eq. (4) over-approximates the in-window
//!   loss (it charges T_P where the engine loses only the work since
//!   the last proactive checkpoint).
//! * [`Domain::OutOfDomain`] — Weibull faults, `T_R ~ mu`, or a
//!   non-paper policy with no closed form: the oracle still names an
//!   analytic reference, but the case asserts only a *divergence
//!   bound* around it (the model is expected to be wrong; conformance
//!   means "wrong by a bounded, understood amount").

use super::grid::ConformanceCase;
use crate::dist::DistSpec;
use crate::model::{optimize, tp_opt, waste_of, Capping, Params, StrategyKind};
use crate::strategies::{resolve_policy, spec_for, PolicySpec};

/// Above this (T_R + C)/mu ratio the first-order analysis is no longer
/// trusted for agreement — the case flips to a divergence bound.
pub const FIRST_ORDER_RATIO_CAP: f64 = 0.5;

/// Validity classification of one oracle prediction.
#[derive(Debug, Clone, PartialEq)]
pub enum Domain {
    /// Closed form applies: assert CI-aware agreement.
    FirstOrder,
    /// Closed form is a reference only: assert the divergence bound.
    OutOfDomain {
        /// Why the first-order analysis does not apply here.
        reason: String,
    },
}

impl Domain {
    pub fn is_first_order(&self) -> bool {
        matches!(self, Domain::FirstOrder)
    }
}

/// The oracle's answer for one case.
#[derive(Debug, Clone, PartialEq)]
pub struct Oracle {
    /// The analytic prediction (or reference) for the mean waste.
    pub analytic: f64,
    /// Admissible band for the simulated mean: the case passes when the
    /// 95% CI of the simulated waste lies inside `[band.0, band.1]`.
    pub band: (f64, f64),
    pub domain: Domain,
}

/// Clamp a band into the waste codomain [0, 1] without inverting it.
fn clamp_band(lo: f64, hi: f64) -> (f64, f64) {
    (lo.max(0.0), hi.min(1.0).max(lo.max(0.0)))
}

/// Evaluate the oracle for one conformance case.
pub fn oracle_for(case: &ConformanceCase) -> anyhow::Result<Oracle> {
    let rp = resolve_policy(&case.subject, &case.scenario)?;
    let p = Params::from_scenario(&rp.scenario);
    match case.subject {
        PolicySpec::Strategy(kind) => {
            // The waste the closed form predicts at the period the
            // simulator actually runs (the §5 Uncapped convention).
            let spec = spec_for(kind, &rp.scenario, Capping::Uncapped);
            let w = waste_of(&p, kind, spec.t_r, tp_opt(&p)).min(1.0);
            let ratio = (spec.t_r + p.c) / p.mu;
            // Platform classification. An *uncorrelated, contention-free*
            // multi-node platform stays in domain: K merged per-node
            // exponential streams superpose to the same aggregate law at
            // the same mu, and with commit = 0 the coordinated costs
            // equal the scenario's C/R — so the first-order logic below
            // applies unchanged (the N-node acceptance criterion).
            // Correlation or store contention changes the experiment the
            // closed form describes, so those assert divergence bounds.
            if case.platform.spatial > 0.0 || case.platform.cascade > 0.0 {
                let (lo, hi) = clamp_band(w / 4.0, 6.0 * w);
                return Ok(Oracle {
                    analytic: w,
                    band: (lo, hi),
                    domain: Domain::OutOfDomain {
                        reason: format!(
                            "platform '{}' correlates failures; the closed forms assume \
                             independent streams",
                            case.platform
                        ),
                    },
                });
            }
            if case.platform.commit > 0.0 {
                let (lo, hi) = clamp_band(w / 4.0, 6.0 * w);
                return Ok(Oracle {
                    analytic: w,
                    band: (lo, hi),
                    domain: Domain::OutOfDomain {
                        reason: format!(
                            "platform '{}' contends on the checkpoint store; \
                             C_eff differs from the modeled C",
                            case.platform
                        ),
                    },
                });
            }
            if case.scenario.fault_dist != DistSpec::Exp {
                let (lo, hi) = clamp_band(w / 4.0, 4.0 * w);
                return Ok(Oracle {
                    analytic: w,
                    band: (lo, hi),
                    domain: Domain::OutOfDomain {
                        reason: format!(
                            "{} faults: the closed forms assume Exponential inter-arrivals",
                            case.scenario.fault_dist
                        ),
                    },
                });
            }
            if ratio > FIRST_ORDER_RATIO_CAP {
                let (lo, hi) = clamp_band(0.55 * w, 1.9 * w);
                return Ok(Oracle {
                    analytic: w,
                    band: (lo, hi),
                    domain: Domain::OutOfDomain {
                        reason: format!(
                            "(T_R + C)/mu = {ratio:.2} breaks the first-order regime (T << mu)"
                        ),
                    },
                });
            }
            let slack = w * (0.06 + 0.75 * ratio);
            let band = if kind == StrategyKind::WithCkptI {
                // Eq. (4) upper-bounds the in-window loss: the simulator
                // may come in well below the closed form, never far above.
                clamp_band(0.35 * w, w + slack)
            } else {
                clamp_band(w - slack, w + slack)
            };
            Ok(Oracle { analytic: w, band, domain: Domain::FirstOrder })
        }
        PolicySpec::AdaptivePeriod { .. } | PolicySpec::RiskThreshold { .. } => {
            // No closed form exists for the online policies; bound them
            // against the Young first-order reference (both degenerate
            // to a Young-like fixed period under their default tuning).
            let (_, wy) = optimize(&p, StrategyKind::Young, Capping::Uncapped);
            let trusts_predictions = matches!(case.subject, PolicySpec::RiskThreshold { .. })
                && case.scenario.predictor.recall > 0.0;
            // A prediction-trusting policy can legitimately undercut
            // Young, so its lower divergence bound is looser.
            let lo_factor = if trusts_predictions { 0.3 } else { 0.5 };
            let (lo, hi) = clamp_band(lo_factor * wy, 1.7 * wy);
            Ok(Oracle {
                analytic: wy,
                band: (lo, hi),
                domain: Domain::OutOfDomain {
                    reason: format!(
                        "policy '{}' has no closed form; bounded against the Young reference",
                        case.subject
                    ),
                },
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::grid::{conformance_grid, GridKind};

    fn case_named(name: &str) -> ConformanceCase {
        conformance_grid(GridKind::Full)
            .into_iter()
            .find(|c| c.name == name)
            .unwrap_or_else(|| panic!("no case named {name}"))
    }

    #[test]
    fn exponential_paper_cases_are_first_order() {
        let o = oracle_for(&case_named("exp-n16-none-Young")).unwrap();
        assert_eq!(o.domain, Domain::FirstOrder);
        assert!(o.analytic > 0.0 && o.analytic < 1.0);
        assert!(o.band.0 < o.analytic && o.analytic < o.band.1);
    }

    #[test]
    fn weibull_cases_are_out_of_domain() {
        let o = oracle_for(&case_named("weibull:0.7-n16-none-Young")).unwrap();
        match &o.domain {
            Domain::OutOfDomain { reason } => {
                assert!(reason.contains("weibull:0.7"), "{reason}")
            }
            d => panic!("wrong domain {d:?}"),
        }
        // Divergence bound, not agreement: the band is much wider than
        // the first-order slack.
        assert!(o.band.1 / o.band.0 > 4.0);
    }

    #[test]
    fn regime_break_is_detected_from_the_ratio() {
        // The deliberate T ~ mu case...
        let o = oracle_for(&case_named("exp-n16-none-mu4000-Young")).unwrap();
        match &o.domain {
            Domain::OutOfDomain { reason } => {
                assert!(reason.contains("first-order"), "{reason}")
            }
            d => panic!("wrong domain {d:?}"),
        }
        // ...and the automatic one: ExactPrediction's stretched period
        // at N = 2^18 crosses the cap without any explicit tweak.
        let o = oracle_for(&case_named("exp-n18-yu:exact-ExactPrediction")).unwrap();
        assert!(!o.domain.is_first_order());
    }

    #[test]
    fn withckpt_band_is_asymmetric() {
        let o = oracle_for(&case_named("exp-n16-yu:I3000-WithCkptI")).unwrap();
        assert_eq!(o.domain, Domain::FirstOrder);
        let below = o.analytic - o.band.0;
        let above = o.band.1 - o.analytic;
        assert!(below > above, "Eq. (4) is an upper bound: {:?}", o.band);
    }

    #[test]
    fn policy_cases_reference_young() {
        let o = oracle_for(&case_named("exp-n16-none-risk:1")).unwrap();
        match &o.domain {
            Domain::OutOfDomain { reason } => assert!(reason.contains("risk:1"), "{reason}"),
            d => panic!("wrong domain {d:?}"),
        }
        let with_pred = oracle_for(&case_named("exp-n16-yu:exact-risk:1")).unwrap();
        assert!(
            with_pred.band.0 < o.band.0,
            "a prediction-trusting policy may undercut Young further"
        );
    }

    #[test]
    fn uncorrelated_platforms_stay_first_order() {
        // Poisson superposition: the K-node uncorrelated case keeps the
        // aggregate law, so it is judged by the same agreement band as
        // its single-stream twin.
        let platform = oracle_for(&case_named("exp-n16-none-Young@nodes=4")).unwrap();
        assert_eq!(platform.domain, Domain::FirstOrder);
        let classic = oracle_for(&case_named("exp-n16-none-Young")).unwrap();
        assert_eq!(platform.analytic, classic.analytic);
        assert_eq!(platform.band, classic.band);
    }

    #[test]
    fn correlated_and_contended_platforms_are_out_of_domain() {
        let o = oracle_for(&case_named(
            "exp-n16-none-Young@nodes=8,group=4,spatial=0.25,cascade=0.1",
        ))
        .unwrap();
        match &o.domain {
            Domain::OutOfDomain { reason } => assert!(reason.contains("correlates"), "{reason}"),
            d => panic!("wrong domain {d:?}"),
        }
        let o = oracle_for(&case_named("exp-n16-none-Young@nodes=8,commit=0.1")).unwrap();
        match &o.domain {
            Domain::OutOfDomain { reason } => assert!(reason.contains("store"), "{reason}"),
            d => panic!("wrong domain {d:?}"),
        }
    }

    #[test]
    fn bands_stay_inside_the_waste_codomain() {
        for case in conformance_grid(GridKind::Full) {
            let o = oracle_for(&case).unwrap();
            assert!(o.band.0 >= 0.0 && o.band.1 <= 1.0, "{}: {:?}", case.name, o.band);
            assert!(o.band.0 < o.band.1, "{}: empty band {:?}", case.name, o.band);
            assert!(o.analytic.is_finite() && o.analytic > 0.0, "{}", case.name);
        }
    }
}
