//! Policy comparison — the experiment the monolithic engine could not
//! run: the paper's fixed-period strategies against the non-paper
//! policies (`adaptive`, `risk`) of the pluggable policy layer, as
//! simulated waste curves over the §5 platform sweep.
//!
//! Setting: the Yu predictor (p = 0.82, r = 0.85, I = 300 s) under
//! Weibull k = 0.7 failures — the Figure 4 configuration — so the
//! paper curves here are directly comparable to `fig4`'s.

use super::{sim_policy_grid, ExpOptions, ExperimentResult};
use crate::config::{paper_proc_counts, predictor_yu, Scenario};
use crate::model::StrategyKind;
use crate::report::{FigureData, Table};
use crate::sim::Policy;
use crate::strategies::{resolve_policy, PolicySpec};

/// The policy roster: old (expressible pre-refactor) and new.
pub fn comparison_policies() -> Vec<PolicySpec> {
    vec![
        PolicySpec::Strategy(StrategyKind::Young),
        PolicySpec::Strategy(StrategyKind::ExactPrediction),
        PolicySpec::Strategy(StrategyKind::NoCkptI),
        PolicySpec::AdaptivePeriod { gain: 1.0 },
        PolicySpec::RiskThreshold { kappa: 1.0 },
    ]
}

/// Waste of every roster policy at every §5 platform size, flattened
/// into one pool pass, plus a summary table at N = 2^16.
pub fn policy_comparison(opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let specs = comparison_policies();
    let mut fig = FigureData::new("policy-comparison", "N", "waste");
    let mut keys: Vec<(u64, String)> = Vec::new();
    let mut points: Vec<(Scenario, Policy)> = Vec::new();
    for n in paper_proc_counts() {
        let mut s = Scenario::paper(n, predictor_yu(300.0));
        s.fault_dist = crate::dist::DistSpec::weibull(0.7);
        for pspec in &specs {
            let rp = resolve_policy(pspec, &s)?;
            keys.push((n, rp.name.clone()));
            points.push((rp.scenario, rp.policy));
        }
    }
    let sums = sim_policy_grid(&points, opts.reps, opts.workers);
    for ((n, name), sum) in keys.iter().zip(&sums) {
        fig.series_mut(name).push(*n as f64, sum.mean());
    }

    // Summary table at the paper's headline size.
    let mut t = Table::new(["policy", "waste 2^16", "ci95"]);
    let n16 = 1u64 << 16;
    for ((n, name), sum) in keys.iter().zip(&sums) {
        if *n == n16 {
            t.row([name.clone(), format!("{:.4}", sum.mean()), format!("{:.4}", sum.ci95())]);
        }
    }

    let mut result = ExperimentResult::default();
    result.figures.push(fig);
    result.tables.push(("policy-comparison-2^16".into(), t));
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comparison_covers_old_and_new_policies() {
        let roster = comparison_policies();
        assert!(roster.iter().any(|p| matches!(p, PolicySpec::Strategy(_))));
        assert!(roster.iter().any(|p| matches!(p, PolicySpec::AdaptivePeriod { .. })));
        assert!(roster.iter().any(|p| matches!(p, PolicySpec::RiskThreshold { .. })));
    }

    #[test]
    fn policy_comparison_structure() {
        let opts = ExpOptions { reps: 2, ..ExpOptions::quick() };
        let r = policy_comparison(&opts).unwrap();
        assert_eq!(r.figures.len(), 1);
        let fig = &r.figures[0];
        // One series per roster policy, one point per platform size.
        assert_eq!(fig.series.len(), comparison_policies().len());
        for s in &fig.series {
            assert_eq!(s.points.len(), 6, "{}", s.label);
            for &(_, w) in &s.points {
                assert!((0.0..=1.0).contains(&w), "{}: waste {w}", s.label);
            }
        }
        assert!(fig.get("adaptive:1").is_some());
        assert!(fig.get("risk:1").is_some());
        assert!(fig.get("Young").is_some());
        assert_eq!(r.tables.len(), 1);
    }
}
