//! Deterministic pseudo-random generation (substrate: offline build, no
//! `rand` crate).
//!
//! * [`Pcg64`] — PCG-XSL-RR 128/64 (O'Neill), the workhorse generator.
//! * [`SplitMix64`] — seeding and cheap stream derivation.
//!
//! Every replication of every experiment derives its own independent
//! stream from `(seed, experiment_id, replication)` so results are
//! reproducible regardless of thread scheduling.

mod pcg;
mod splitmix;

pub use pcg::Pcg64;
pub use splitmix::SplitMix64;

/// The per-replication trust-stream seed. One definition shared by the
/// engine-owned trust RNG ([`crate::sim::SimSession`]) and the
/// trace-bank's pre-sampled trust uniforms
/// ([`crate::trace::TraceBank`]) — the two must stay in lockstep for
/// replay to be bit-identical to live generation.
pub fn trust_seed(seed: u64, rep: u64) -> u64 {
    seed ^ (rep << 17) ^ 0xA5
}

/// Derive a child generator for `(label, index)` — stable, collision-
/// resistant stream splitting for parallel replications.
pub fn substream(seed: u64, label: &str, index: u64) -> Pcg64 {
    let mut h = SplitMix64::new(seed);
    let mut acc = h.next_u64();
    for b in label.as_bytes() {
        acc = acc.wrapping_mul(0x100000001b3).wrapping_add(*b as u64);
    }
    let mut m = SplitMix64::new(acc ^ index.wrapping_mul(0x9E3779B97F4A7C15));
    Pcg64::new(m.next_u64(), m.next_u64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn substreams_are_reproducible() {
        let mut a = substream(42, "faults", 7);
        let mut b = substream(42, "faults", 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn substreams_differ_by_index_and_label() {
        let a: Vec<u64> = substream(42, "faults", 0).take_u64(8);
        let b: Vec<u64> = substream(42, "faults", 1).take_u64(8);
        let c: Vec<u64> = substream(42, "preds", 0).take_u64(8);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }
}
