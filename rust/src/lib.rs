//! # ckptfp — fault-prediction-aware checkpointing
//!
//! A reproduction-grade implementation of *"Impact of fault prediction on
//! checkpointing strategies"* (Aupy, Robert, Vivien, Zaidouni, 2012) as a
//! deployable framework:
//!
//! * [`model`] — the paper's analytical waste model (Eqs. 1–12) and the
//!   §3.3/§4.3 optimal-period case analysis, in closed form;
//! * [`runtime`] — the AOT path: loads the JAX/Pallas-compiled planner
//!   (`artifacts/*.hlo.txt`) through PJRT and evaluates waste surfaces /
//!   grid-argmin plans natively;
//! * [`trace`] — stochastic fault + predictor simulation (recall,
//!   precision, exact dates or prediction windows, lead time);
//! * [`sim`] — the discrete-event execution engine that replays a
//!   checkpointing strategy against a trace;
//! * [`strategies`] — Young, Daly, ExactPrediction, Instant, NoCkptI,
//!   WithCkptI, Migration and the brute-force BestPeriod search;
//! * [`coordinator`] — leader/worker experiment orchestration, a dynamic
//!   batcher for planning requests and a TCP/JSONL planner service;
//! * [`experiments`] — the §5 evaluation scenarios (every figure & table).
//!
//! Substrate modules ([`rng`], [`dist`], [`util`], [`config`], [`cli`],
//! [`report`], [`testkit`]) are implemented from scratch — the build is
//! fully offline and depends only on `anyhow` (plus the optional `xla`
//! PJRT bindings behind the `pjrt` feature; without it the [`runtime`]
//! module keeps its API surface but reports the missing backend).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod experiments;
pub mod model;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod strategies;
pub mod testkit;
pub mod trace;
pub mod util;

/// Convenient glob import for examples and binaries.
pub mod prelude {
    pub use crate::config::{Platform, Predictor, Scenario};
    pub use crate::dist::{Dist, Distribution, Exponential, Uniform, Weibull};
    pub use crate::model::{OptimalPlan, StrategyKind};
    pub use crate::rng::Pcg64;
    pub use crate::sim::{Outcome, SimConfig, SimSession};
    pub use crate::strategies::{ProactiveMode, StrategySpec};
    pub use crate::util::stats::Summary;
}
