//! Integration: the AOT HLO planner against the Rust closed-form model.
//!
//! This is the contract between the three layers: the Pallas kernel +
//! JAX planner (compiled at build time) must agree with the native case
//! analysis on every §5 configuration.
//!
//! Requires `make artifacts` and a `pjrt`-enabled build; each test
//! skips (with a notice on stderr) when the artifacts or the backend
//! are unavailable, so the tier-1 suite stays green on bare checkouts.

use ckptfp::config::{paper_proc_counts, predictor_yu, predictor_zheng, Predictor, Scenario};
use ckptfp::model::{optimize, plan, Capping, Params, StrategyKind};
use ckptfp::runtime::{artifacts_dir, HloPlanner, Runtime};

fn planner() -> Option<HloPlanner> {
    match HloPlanner::open_default() {
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("skipping HLO planner test: {e:#} (run `make artifacts` and build with --features pjrt)");
            None
        }
    }
}

/// Skip the test body when the planner is unavailable.
macro_rules! planner_or_skip {
    () => {
        match planner() {
            Some(p) => p,
            None => return,
        }
    };
}

fn paper_params() -> Vec<Params> {
    let mut out = Vec::new();
    for n in paper_proc_counts() {
        for window in [0.0, 300.0, 3000.0] {
            out.push(Params::from_scenario(&Scenario::paper(n, predictor_yu(window))));
            out.push(Params::from_scenario(&Scenario::paper(n, predictor_zheng(window))));
        }
        out.push(Params::from_scenario(&Scenario::paper(n, Predictor::none())));
    }
    out
}

#[test]
fn manifest_and_artifacts_present() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts dir missing (run `make artifacts`)");
        return;
    };
    let rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    assert!(rt.manifest().find("planner_b1").is_some());
    assert!(rt.manifest().find("planner_b64").is_some());
    assert!(rt.manifest().find("surface_b16").is_some());
    assert_eq!(rt.platform_name(), "cpu");
}

#[test]
fn hlo_waste_matches_closed_form_everywhere() {
    let mut planner = planner_or_skip!();
    let params = paper_params();
    let outs = planner.plan_batch(&params).unwrap();
    assert_eq!(outs.len(), params.len());
    let mut worst: (f64, usize, usize) = (0.0, 0, 0);
    for (i, (p, out)) in params.iter().zip(&outs).enumerate() {
        for kind in StrategyKind::ALL {
            let (_, w) = optimize(p, kind, Capping::Capped);
            let diff = (w - out.waste[kind as usize]).abs();
            if diff > worst.0 {
                worst = (diff, i, kind as usize);
            }
        }
    }
    // Grid resolution: 512 quadratically-spaced points over
    // [C, alpha*mu]. Interior optima sit in flat basins (sub-1e-3
    // agreement); configurations whose window cap alpha*mu_e - I is
    // barely above C are boundary-limited and the grid argmin
    // over-approximates by up to a few 1e-3 — always conservative.
    assert!(
        worst.0 < 5e-3,
        "config {} strategy {}: HLO vs closed form differs by {}",
        worst.1,
        worst.2,
        worst.0
    );
}

#[test]
fn hlo_periods_match_case_analysis() {
    let mut planner = planner_or_skip!();
    let params = paper_params();
    let outs = planner.plan_batch(&params).unwrap();
    for (p, out) in params.iter().zip(&outs) {
        for kind in [StrategyKind::Young, StrategyKind::ExactPrediction] {
            let (t, w) = optimize(p, kind, Capping::Capped);
            if w >= 1.0 {
                continue; // masked configuration
            }
            let rel = (t - out.period[kind as usize]).abs() / t;
            assert!(
                rel < 0.02,
                "{}: closed form T={t} vs HLO {}",
                kind.name(),
                out.period[kind as usize]
            );
        }
    }
}

#[test]
fn hlo_winner_agrees_with_model() {
    let mut planner = planner_or_skip!();
    let params = paper_params();
    let outs = planner.plan_batch(&params).unwrap();
    for (p, out) in params.iter().zip(&outs) {
        let native = plan(p, Capping::Capped, true);
        // Winners can differ when two strategies are within grid
        // tolerance of each other; the winning *waste* must agree.
        assert!(
            (native.winner_waste() - out.winner_waste).abs() < 2e-3,
            "native {} ({}) vs hlo {} ({})",
            native.winner_waste(),
            native.winner.name(),
            out.winner_waste,
            out.winner.name()
        );
    }
}

#[test]
fn batch_one_artifact_round_trip() {
    let mut planner = planner_or_skip!();
    let p = Params::from_scenario(&Scenario::paper(1 << 16, predictor_yu(300.0)));
    let single = planner.plan_batch(&[p]).unwrap();
    let batch = planner.plan_batch(&vec![p; 64]).unwrap();
    // The b=1 artifact and the b=64 artifact must agree on identical input.
    for s in 0..6 {
        assert!((single[0].waste[s] - batch[0].waste[s]).abs() < 1e-6);
        assert!((single[0].waste[s] - batch[63].waste[s]).abs() < 1e-6);
    }
}

#[test]
fn surfaces_are_convex_and_masked() {
    let mut planner = planner_or_skip!();
    let p = Params::from_scenario(&Scenario::paper(1 << 16, predictor_yu(3000.0)));
    let surf = planner.surfaces(&[p]).unwrap().remove(0);
    assert_eq!(surf.waste.len(), 6);
    assert_eq!(surf.periods.len(), surf.waste[0].len());
    // Period grid starts at C and increases.
    assert!((surf.periods[0] - 600.0).abs() < 1.0);
    assert!(surf.periods.windows(2).all(|w| w[1] > w[0]));
    // Each surface, below its mask, is convex in T — except Instant
    // (s=2), whose Eq. (5) has one concave kink at T = 2 E_I^f. The
    // grid is non-uniform, so use divided differences in T.
    for s in 0..6 {
        let w = &surf.waste[s];
        let t = &surf.periods;
        let mut violations = 0;
        for j in 1..w.len() - 1 {
            if w[j - 1] >= 1.0 || w[j] >= 1.0 || w[j + 1] >= 1.0 {
                continue; // masked region
            }
            let slope_lo = (w[j] - w[j - 1]) / (t[j] - t[j - 1]);
            let slope_hi = (w[j + 1] - w[j]) / (t[j + 1] - t[j]);
            if slope_hi < slope_lo - 1e-7 {
                violations += 1;
                assert!(s == 2, "s={s} j={j}: slopes {slope_lo} -> {slope_hi}");
            }
        }
        // f32 noise can smear the single analytic kink across a couple
        // of adjacent grid cells.
        assert!(violations <= 3, "s={s}: {violations} kinks");
    }
    // Window strategies masked beyond alpha*mu_e - I.
    let last = surf.waste[2].last().unwrap();
    assert_eq!(*last, 1.0);
}

#[test]
fn oversized_batch_chunks() {
    let mut planner = planner_or_skip!();
    let p = Params::from_scenario(&Scenario::paper(1 << 17, predictor_zheng(300.0)));
    let outs = planner.plan_batch(&vec![p; 130]).unwrap(); // 3 chunks of b=64
    assert_eq!(outs.len(), 130);
    for o in &outs {
        assert!((o.waste[0] - outs[0].waste[0]).abs() < 1e-6);
    }
}
