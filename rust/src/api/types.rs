//! The typed job surface: every operation the crate can perform for a
//! caller — planning, simulation, best-period search, platform sweeps —
//! as one request/response pair of enums, independent of any wire
//! encoding.
//!
//! Invariants:
//!
//! * requests carry fully-typed payloads ([`crate::config::Scenario`],
//!   [`StrategyKind`], [`Capping`]) — strings exist only in
//!   [`crate::api::wire`];
//! * every failure is an [`ApiError`] with a machine-readable
//!   [`ErrorCode`], never a bare string;
//! * responses are plain data with `PartialEq`, so wire round-trips can
//!   be pinned exactly in tests.

use crate::config::Scenario;
use crate::model::{Capping, StrategyKind};
use crate::sim::PlatformSpec;
use crate::strategies::PolicySpec;
use crate::verify::{GridKind, VerifyReport};

/// One job, as accepted by [`crate::api::Executor::execute`] and the
/// TCP service alike.
#[derive(Debug, Clone, PartialEq)]
pub enum JobRequest {
    /// Closed-form (or HLO-compiled) optimal strategy/period planning.
    Plan(PlanJob),
    /// Monte Carlo replication of one strategy on the worker pool.
    Simulate(SimulateJob),
    /// Brute-force §5 best-period search on the worker pool.
    BestPeriod(BestPeriodJob),
    /// Plan across a range of platform sizes in one batch.
    Sweep(SweepJob),
    /// Run the conformance grid: cross-check the analytic model
    /// against the simulator with CI-aware verdicts (the `verify`
    /// subsystem, v2-only).
    Verify(VerifyJob),
    /// Service counters and latency quantiles.
    Stats,
    /// Liveness probe.
    Ping,
}

impl JobRequest {
    /// Canonical op name — the `"op"` field of the wire encoding.
    pub fn op(&self) -> &'static str {
        match self {
            JobRequest::Plan(_) => "plan",
            JobRequest::Simulate(_) => "simulate",
            JobRequest::BestPeriod(_) => "best_period",
            JobRequest::Sweep(_) => "sweep",
            JobRequest::Verify(_) => "verify",
            JobRequest::Stats => "stats",
            JobRequest::Ping => "ping",
        }
    }
}

/// Plan the optimal strategy and period for one configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanJob {
    pub scenario: Scenario,
    /// Period-domain treatment for the analytic path (the HLO planner
    /// bakes its own); defaults to the §5 `Uncapped` convention.
    pub capping: Capping,
    /// Additive v2 field: restrict the plan to one policy. A paper
    /// strategy forces the winner to that strategy; non-paper policies
    /// have no closed form and are answered `unsupported`.
    pub policy: Option<PolicySpec>,
}

impl PlanJob {
    pub fn new(scenario: Scenario) -> PlanJob {
        PlanJob { scenario, capping: Capping::Uncapped, policy: None }
    }
}

/// Replicate one strategy `reps` times and aggregate.
#[derive(Debug, Clone, PartialEq)]
pub struct SimulateJob {
    pub scenario: Scenario,
    pub strategy: StrategyKind,
    /// Replications; 0 = the executor's configured default.
    pub reps: u64,
    /// Pool width; `None` = the executor's configured default.
    pub workers: Option<u64>,
    /// Additive v2 field: run this [`PolicySpec`] instead of
    /// `strategy` (which is ignored when a policy is present). This is
    /// how the non-paper policies (`adaptive`, `risk`) are reached
    /// over the wire.
    pub policy: Option<PolicySpec>,
    /// Additive v2 field: simulate on this multi-node platform
    /// instead of the classic single-stream engine. `None` and the
    /// `single` spec both mean the classic path.
    pub platform: Option<PlatformSpec>,
}

impl SimulateJob {
    pub fn new(scenario: Scenario, strategy: StrategyKind) -> SimulateJob {
        SimulateJob { scenario, strategy, reps: 0, workers: None, policy: None, platform: None }
    }
}

/// Brute-force the best regular period of one strategy by simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct BestPeriodJob {
    pub scenario: Scenario,
    pub strategy: StrategyKind,
    /// Replications per candidate; 0 = the executor's default.
    pub reps: u64,
    /// Period-grid size; 0 = the executor's default.
    pub candidates: u64,
    /// Pool width; `None` = the executor's configured default.
    pub workers: Option<u64>,
    /// Enable the coarse-pass pruning heuristic.
    pub prune: bool,
    /// Additive v2 field: search this policy's parameter instead of
    /// `strategy`'s period (`strategy` is ignored when present). The
    /// response's `t_r`/sweep carry the parameter in the policy's own
    /// units (T_R seconds, adaptive gain, or risk kappa).
    pub policy: Option<PolicySpec>,
    /// Additive v2 field: search on this multi-node platform. Only
    /// plain strategies (and `Strategy(..)` policies) support a
    /// platform search; other policies answer `unsupported`.
    pub platform: Option<PlatformSpec>,
}

impl BestPeriodJob {
    pub fn new(scenario: Scenario, strategy: StrategyKind) -> BestPeriodJob {
        BestPeriodJob {
            scenario,
            strategy,
            reps: 0,
            candidates: 0,
            workers: None,
            prune: false,
            policy: None,
            platform: None,
        }
    }
}

/// Plan the same base scenario across several platform sizes.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepJob {
    /// Base configuration; `platform.n_procs` is overridden per row.
    pub base: Scenario,
    pub n_procs: Vec<u64>,
    pub capping: Capping,
}

/// Run the conformance grid and report CI-aware verdicts.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyJob {
    pub grid: GridKind,
    /// Restrict to cases whose subject equals this policy spec.
    pub policy: Option<PolicySpec>,
    /// Base replications per case; 0 = the grid's default.
    pub reps: u64,
    /// Replication-escalation budget per case; 0 = the grid's default.
    pub budget: u64,
    /// Pool width; `None` = the executor's configured default.
    pub workers: Option<u64>,
    /// Additive v2 field: restrict to cases whose platform equals
    /// this spec (use `single` to keep only the classic cases).
    pub platform: Option<PlatformSpec>,
}

impl VerifyJob {
    pub fn new(grid: GridKind) -> VerifyJob {
        VerifyJob { grid, policy: None, reps: 0, budget: 0, workers: None, platform: None }
    }
}

/// One job's result. `Error` is a first-class variant so the service
/// can answer *every* line with a `JobResponse`.
#[derive(Debug, Clone, PartialEq)]
pub enum JobResponse {
    Plan(PlanResult),
    Simulate(SimulateResult),
    BestPeriod(BestPeriodOutcome),
    Sweep(SweepResult),
    Verify(VerifyReport),
    Stats(ServiceStats),
    Pong,
    Error(ApiError),
}

/// Per-strategy optima plus the winner — the payload the v1 protocol
/// has always carried, now typed.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanResult {
    /// Optimal waste per strategy ([`StrategyKind`] indexing).
    pub waste: [f64; 6],
    /// Optimal period per strategy.
    pub period: [f64; 6],
    pub winner: StrategyKind,
    pub winner_waste: f64,
    pub winner_period: f64,
    /// Trust decision of the winner (0 = ignore predictor, 1 = trust).
    pub q: u8,
    /// Whether the AOT HLO planner produced this (vs the closed form).
    pub via_hlo: bool,
}

/// Aggregated Monte Carlo result of a [`SimulateJob`].
#[derive(Debug, Clone, PartialEq)]
pub struct SimulateResult {
    pub strategy: String,
    /// Replications actually run (defaults resolved).
    pub reps: u64,
    /// Pool width actually used. Means are bit-reproducible only for a
    /// fixed width, so the response echoes it.
    pub workers: u64,
    pub mean_waste: f64,
    /// Half-width of the 95% confidence interval on the mean waste.
    pub waste_ci95: f64,
    pub mean_makespan: f64,
    pub completion_rate: f64,
    pub n_faults: u64,
    pub n_preds: u64,
    pub n_ckpts: u64,
    pub n_proactive_ckpts: u64,
    /// Total engine wall-clock across replications (CPU-seconds).
    pub sim_seconds: f64,
}

/// Result of a [`BestPeriodJob`] search.
#[derive(Debug, Clone, PartialEq)]
pub struct BestPeriodOutcome {
    pub strategy: String,
    /// Winning regular period.
    pub t_r: f64,
    /// Mean waste at the winning period.
    pub waste: f64,
    /// Candidates eliminated by the coarse pass.
    pub n_pruned: u64,
    /// The full `(period, mean waste)` sweep.
    pub sweep: Vec<(f64, f64)>,
    pub reps: u64,
    pub candidates: u64,
    pub workers: u64,
    /// Replications actually simulated (additive v2 field): with
    /// pruning, the coarse pass covers the full grid and only
    /// survivors get the rest, so this is the honest spend — not the
    /// requested `reps × candidates` budget.
    pub reps_used: u64,
}

/// One row of a [`SweepJob`] answer.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRow {
    pub n_procs: u64,
    /// Platform MTBF at this size (s).
    pub mu: f64,
    pub winner: StrategyKind,
    pub winner_waste: f64,
    pub winner_period: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct SweepResult {
    pub rows: Vec<SweepRow>,
    pub via_hlo: bool,
}

/// Batcher counters as exposed through the job surface.
#[derive(Debug, Clone, PartialEq)]
pub struct BatcherSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub max_batch: u64,
}

/// Service-level counters. Latency quantiles are 0 until at least one
/// request has been timed (never NaN — the type round-trips exactly).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub errors: u64,
    pub plans: u64,
    pub simulates: u64,
    pub best_periods: u64,
    pub sweeps: u64,
    pub verifies: u64,
    pub lat_p50_s: f64,
    pub lat_p95_s: f64,
    pub lat_p99_s: f64,
    pub lat_n: u64,
    /// Trace-bank reuse counters (additive v2 fields; process-global,
    /// see [`crate::trace::bank::counters`]): banks built, replications
    /// served from a bank arena, replications that fell back to live
    /// generation, and arena bytes currently resident.
    pub banks_built: u64,
    pub bank_replays: u64,
    pub bank_fallbacks: u64,
    pub bank_bytes_resident: u64,
    /// Robustness counters (additive v2 fields): requests rejected by
    /// admission control, jobs that ran out of wall-clock budget,
    /// worker/connection panics contained as `internal` errors, and
    /// transport retries performed by [`crate::api::ServiceClient`]s in
    /// this process.
    pub rejected_overloaded: u64,
    pub deadline_exceeded: u64,
    pub panics_contained: u64,
    pub client_retries: u64,
    /// Lockstep batch-engine counters (additive v2 fields;
    /// process-global, see [`crate::sim::batch::counters`]): lanes run
    /// through batch chunks, and lanes that fell back to live
    /// generation on a bank underrun.
    pub batch_lanes_run: u64,
    pub batch_lane_fallbacks: u64,
    /// Wide SoA kernel counters (additive v2 fields; process-global,
    /// see [`crate::sim::wide::counters`]): lanes swept through the
    /// struct-of-arrays kernel, and lanes evicted to the scalar
    /// fallback (bank underrun or inexpressible state).
    pub wide_lanes_run: u64,
    pub wide_evictions: u64,
    /// Plan-cache counters (additive v2 fields; per-executor, see
    /// [`crate::coordinator::PlanCache`]): lookups served from the
    /// memoized Plan/BestPeriod/Sweep cache, lookups that missed,
    /// entries evicted by the LRU capacity bound, and entries
    /// currently resident.
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_entries: u64,
    /// Present only when the service runs an HLO batcher.
    pub batcher: Option<BatcherSnapshot>,
}

/// Machine-readable failure category. The wire form is the kebab-free
/// snake_case string of [`ErrorCode::as_str`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The line was not valid JSON.
    InvalidJson,
    /// The `v` field named a protocol version this build cannot speak.
    UnsupportedVersion,
    /// The `op` field named no known job.
    UnknownOp,
    /// The job payload failed validation.
    BadRequest,
    /// The job needs a backend this service does not have.
    Unsupported,
    /// The backend failed while executing a valid job.
    Internal,
    /// The service is at its admission limits; retry after the hinted
    /// delay (additive v2 code, also answered in the v1 dialect).
    Overloaded,
    /// The job's wall-clock budget expired before it finished; the
    /// message names how far it got. Retrying with a larger deadline or
    /// fewer reps is safe — jobs are pure.
    DeadlineExceeded,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::InvalidJson => "invalid_json",
            ErrorCode::UnsupportedVersion => "unsupported_version",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::Unsupported => "unsupported",
            ErrorCode::Internal => "internal",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
        }
    }

    /// Inverse of [`ErrorCode::as_str`]; unknown strings collapse to
    /// `Internal` so old clients survive new server codes.
    pub fn parse(s: &str) -> ErrorCode {
        match s {
            "invalid_json" => ErrorCode::InvalidJson,
            "unsupported_version" => ErrorCode::UnsupportedVersion,
            "unknown_op" => ErrorCode::UnknownOp,
            "bad_request" => ErrorCode::BadRequest,
            "unsupported" => ErrorCode::Unsupported,
            "overloaded" => ErrorCode::Overloaded,
            "deadline_exceeded" => ErrorCode::DeadlineExceeded,
            _ => ErrorCode::Internal,
        }
    }
}

/// A structured job failure: code for machines, message for humans.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
    /// Retry hint in milliseconds (additive v2 field, carried only by
    /// `overloaded` rejections today).
    pub retry_after_ms: Option<u64>,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError { code, message: message.into(), retry_after_ms: None }
    }

    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::BadRequest, message)
    }

    pub fn invalid_json(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::InvalidJson, message)
    }

    pub fn unknown_op(op: &str) -> ApiError {
        ApiError::new(ErrorCode::UnknownOp, format!("unknown op '{op}'"))
    }

    /// An admission-control rejection with a retry hint.
    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> ApiError {
        let mut e = ApiError::new(ErrorCode::Overloaded, message);
        e.retry_after_ms = Some(retry_after_ms);
        e
    }

    /// A deadline expiry; `message` should say how far the job got.
    pub fn deadline_exceeded(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::DeadlineExceeded, message)
    }

    /// Wrap a validation error, keeping the full anyhow context chain.
    pub fn from_invalid(err: anyhow::Error) -> ApiError {
        ApiError::bad_request(format!("{err:#}"))
    }

    /// Wrap a backend failure.
    pub fn from_internal(err: anyhow::Error) -> ApiError {
        ApiError::new(ErrorCode::Internal, format!("{err:#}"))
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_round_trip() {
        for code in [
            ErrorCode::InvalidJson,
            ErrorCode::UnsupportedVersion,
            ErrorCode::UnknownOp,
            ErrorCode::BadRequest,
            ErrorCode::Unsupported,
            ErrorCode::Internal,
            ErrorCode::Overloaded,
            ErrorCode::DeadlineExceeded,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), code);
        }
        assert_eq!(ErrorCode::parse("some_future_code"), ErrorCode::Internal);
    }

    #[test]
    fn overloaded_carries_a_retry_hint() {
        let e = ApiError::overloaded("at capacity", 250);
        assert_eq!(e.code, ErrorCode::Overloaded);
        assert_eq!(e.retry_after_ms, Some(250));
        assert_eq!(ApiError::deadline_exceeded("40/100 reps").retry_after_ms, None);
    }

    #[test]
    fn api_error_displays_code_and_message() {
        let e = ApiError::bad_request("work must be positive");
        assert_eq!(e.to_string(), "bad_request: work must be positive");
        let any: anyhow::Error = e.clone().into();
        assert!(any.to_string().contains("bad_request"));
    }

    #[test]
    fn op_names_are_stable() {
        let s = Scenario::paper(1 << 16, crate::config::Predictor::none());
        assert_eq!(JobRequest::Plan(PlanJob::new(s.clone())).op(), "plan");
        assert_eq!(JobRequest::Simulate(SimulateJob::new(s.clone(), StrategyKind::Young)).op(), "simulate");
        assert_eq!(JobRequest::BestPeriod(BestPeriodJob::new(s, StrategyKind::Young)).op(), "best_period");
        assert_eq!(JobRequest::Verify(VerifyJob::new(GridKind::Quick)).op(), "verify");
        assert_eq!(JobRequest::Stats.op(), "stats");
        assert_eq!(JobRequest::Ping.op(), "ping");
    }
}
