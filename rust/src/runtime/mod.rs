//! The AOT runtime: loads the JAX/Pallas-compiled planner artifacts
//! (HLO text) and executes them on the PJRT CPU client.
//!
//! Python never runs here — `make artifacts` produced the HLO once at
//! build time; this module is the only bridge between the Rust
//! coordinator and the compiled L1/L2 stack.
//!
//! The PJRT client lives behind the `pjrt` cargo feature (it is the
//! crate's only external native dependency). Without the feature the
//! same API compiles against a stub whose constructors report the
//! missing backend — the batcher, service and CLI degrade gracefully.

mod artifact;
#[cfg(feature = "pjrt")]
mod client;
mod output;
#[cfg(feature = "pjrt")]
mod planner_exec;
#[cfg(not(feature = "pjrt"))]
mod stub;

pub use artifact::{ArtifactSpec, Manifest};
#[cfg(feature = "pjrt")]
pub use client::Runtime;
pub use output::{PlanOutput, SurfaceOutput};
#[cfg(feature = "pjrt")]
pub use planner_exec::HloPlanner;
#[cfg(not(feature = "pjrt"))]
pub use stub::{HloPlanner, Runtime};

/// Locate the artifacts directory: `$CKPTFP_ARTIFACTS`, else
/// `./artifacts`, else walking up from the current directory (so tests
/// and examples work from any workspace subdirectory).
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("CKPTFP_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        return p.is_dir().then_some(p);
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let candidate = cur.join("artifacts");
        if candidate.join("manifest.txt").is_file() {
            return Some(candidate);
        }
        if !cur.pop() {
            return None;
        }
    }
}
