//! `ckptfp` — the command-line front end.
//!
//! ```text
//! ckptfp plan       [--n-procs N | --mu-mn M] [--recall R --precision P --window I] [--hlo] [--json]
//! ckptfp simulate   [--strategy NAME] [--n-procs N] [--reps K] [--workers W] [--dist exp|weibull:K]
//! ckptfp experiment <fig4..fig11|tab1|tab2|tab3|all> [--reps K] [--best-period] [--out DIR]
//! ckptfp serve      [--addr HOST:PORT]
//! ckptfp trace      [--out FILE] [--horizon SECONDS] [--n-procs N]
//! ckptfp config     <file.toml> — validate and print a scenario
//! ```

use anyhow::Context;
use ckptfp::cli::Args;
use ckptfp::config::{Predictor, Scenario};
use ckptfp::coordinator::{serve, Batcher, BatcherConfig, ServiceConfig};
use ckptfp::experiments::{all_experiments, run_experiment, ExpOptions};
use ckptfp::model::{plan, Capping, Params, StrategyKind};
use ckptfp::report::Table;
use ckptfp::runtime::HloPlanner;
use ckptfp::sim::run_replications_parallel;
use ckptfp::strategies::spec_for;
use ckptfp::trace::TraceGen;
use ckptfp::util::units::MIN;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn scenario_from_args(args: &mut Args) -> anyhow::Result<Scenario> {
    let n_procs: u64 = args.get("n-procs", 1u64 << 16)?;
    let recall: f64 = args.get("recall", 0.85)?;
    let precision: f64 = args.get("precision", 0.82)?;
    let window: f64 = args.get("window", 0.0)?;
    let pred = if window > 0.0 {
        Predictor::windowed(recall, precision, window)
    } else {
        Predictor::exact(recall, precision)
    };
    let mut s = Scenario::paper(n_procs, pred);
    if let Some(mu_mn) = args.get_opt::<f64>("mu-mn")? {
        // Direct platform-MTBF override (minutes), as in the paper text.
        s.platform.mu_ind = mu_mn * MIN * s.platform.n_procs as f64;
    }
    if let Some(c) = args.get_opt::<f64>("c")? {
        s.platform.c = c;
    }
    if let Some(w) = args.get_opt::<f64>("work")? {
        s.work = w;
    }
    s.fault_dist = args.get_str("dist", &s.fault_dist.clone());
    s.false_pred_dist = args.get_str("false-dist", "");
    s.seed = args.get("seed", s.seed)?;
    s.validate()?;
    Ok(s)
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    match args.command() {
        Some("plan") => cmd_plan(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("trace") => cmd_trace(&mut args),
        Some("config") => cmd_config(&mut args),
        Some(other) => anyhow::bail!("unknown command '{other}' — see `ckptfp help`"),
        None => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
ckptfp — fault-prediction-aware checkpointing (Aupy et al. 2012 reproduction)

commands:
  plan        optimal strategy/period for a platform + predictor
  simulate    discrete-event simulation of one strategy
  experiment  regenerate a paper figure/table (fig4..fig11, tab1..tab3, all)
  serve       TCP/JSONL planner service (AOT XLA planner)
  trace       dump a generated fault/prediction trace
  config      validate a TOML scenario file
";

fn cmd_plan(args: &mut Args) -> anyhow::Result<()> {
    let use_hlo = args.switch("hlo");
    let as_json = args.switch("json");
    let capped = args.switch("capped");
    let s = scenario_from_args(args)?;
    args.finish()?;
    let params = Params::from_scenario(&s);

    let output = if use_hlo {
        let mut planner = HloPlanner::open_default().context("opening HLO planner")?;
        let out = planner.plan_batch(&[params])?.remove(0);
        out
    } else {
        let capping = if capped { Capping::Capped } else { Capping::Uncapped };
        let p = plan(&params, capping, true);
        ckptfp::runtime::PlanOutput {
            waste: p.waste,
            period: p.period,
            winner: p.winner,
            winner_waste: p.winner_waste(),
            winner_period: p.winner_period(),
        }
    };

    if as_json {
        println!("{}", ckptfp::coordinator::protocol::plan_response(&output));
        return Ok(());
    }
    let mut t = Table::new(["strategy", "period (s)", "waste"]);
    for k in StrategyKind::ALL {
        t.row([
            k.name().to_string(),
            format!("{:.1}", output.period[k as usize]),
            format!("{:.4}", output.waste[k as usize]),
        ]);
    }
    println!(
        "platform mu = {:.1} mn (N = {}), predictor r = {} p = {} I = {}s",
        s.mu() / MIN,
        s.platform.n_procs,
        s.predictor.recall,
        s.predictor.precision,
        s.predictor.window
    );
    print!("{t}");
    println!(
        "winner: {} (period {:.1} s, waste {:.4}){}",
        output.winner.name(),
        output.winner_period,
        output.winner_waste,
        if use_hlo { " [via AOT XLA planner]" } else { "" }
    );
    Ok(())
}

fn cmd_simulate(args: &mut Args) -> anyhow::Result<()> {
    let strategy = args.get_str("strategy", "ExactPrediction");
    let reps: u64 = args.get("reps", 20)?;
    let workers: usize = args.get("workers", ckptfp::coordinator::available_workers())?;
    let s = scenario_from_args(args)?;
    args.finish()?;
    let kind = StrategyKind::ALL
        .into_iter()
        .find(|k| k.name().eq_ignore_ascii_case(&strategy))
        .ok_or_else(|| anyhow::anyhow!("unknown strategy '{strategy}'"))?;
    let sk = ckptfp::experiments::scenario_for(kind, &s);
    let spec = spec_for(kind, &sk, Capping::Uncapped);
    let report = run_replications_parallel(&sk, &spec, reps, workers)?;
    println!(
        "{}: waste {} | makespan {:.2} days | completion {:.0}% | {} faults, {} ckpts over {} reps ({:.2} engine-s)",
        spec.name,
        report.agg.waste,
        report.mean_makespan() / 86400.0,
        report.completion_rate() * 100.0,
        report.agg.n_faults,
        report.agg.n_ckpts + report.agg.n_proactive_ckpts,
        report.agg.n_reps,
        report.agg.sim_seconds,
    );
    let p = Params::from_scenario(&sk);
    let analytic = ckptfp::model::waste_of(&p, kind, spec.t_r, ckptfp::model::tp_opt(&p));
    println!("analytic waste at T_R = {:.1}: {:.4}", spec.t_r, analytic);
    Ok(())
}

fn cmd_experiment(args: &mut Args) -> anyhow::Result<()> {
    let mut opts = ExpOptions::default();
    opts.reps = args.get("reps", opts.reps)?;
    opts.workers = args.get("workers", opts.workers)?;
    opts.best_period = args.switch("best-period");
    opts.bp_reps = args.get("bp-reps", opts.bp_reps)?;
    opts.bp_candidates = args.get("bp-candidates", opts.bp_candidates)?;
    let out_dir = args.get_str("out", "results");
    let ids: Vec<String> = if args.positional().is_empty() {
        anyhow::bail!("experiment needs an id: {:?} or 'all'", all_experiments());
    } else if args.positional() == ["all"] {
        all_experiments().into_iter().map(String::from).collect()
    } else {
        args.positional().to_vec()
    };
    args.finish()?;
    for id in &ids {
        let started = std::time::Instant::now();
        let result = run_experiment(id, &opts)?;
        print!("{}", result.render());
        result.write_csvs(std::path::Path::new(&out_dir))?;
        eprintln!("[{id}] done in {:.1}s -> {out_dir}/", started.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_serve(args: &mut Args) -> anyhow::Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7471");
    let max_batch: usize = args.get("max-batch", 64)?;
    let max_delay_ms: u64 = args.get("max-delay-ms", 2)?;
    args.finish()?;
    let batcher = Batcher::spawn_default(BatcherConfig {
        max_batch,
        max_delay: std::time::Duration::from_millis(max_delay_ms),
        eager: max_delay_ms == 0,
        ..Default::default()
    })
    .context("starting batcher (is artifacts/ built?)")?;
    let handle = serve(batcher, ServiceConfig { addr })?;
    println!("ckptfp planner service listening on {}", handle.addr);
    println!("protocol: one JSON object per line; see coordinator::protocol docs");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_trace(args: &mut Args) -> anyhow::Result<()> {
    let out = args.get_str("out", "/dev/stdout");
    let horizon: f64 = args.get("horizon", 1.0e6)?;
    let rep: u64 = args.get("rep", 0)?;
    let s = scenario_from_args(args)?;
    args.finish()?;
    let mut gen = TraceGen::new(&s, s.platform.c, s.seed, rep)?;
    let mut file = std::io::BufWriter::new(std::fs::File::create(&out)?);
    let (nf, np) = ckptfp::trace::io::write_trace(&mut file, &mut gen, horizon)?;
    eprintln!("wrote {nf} faults, {np} predictions to {out}");
    Ok(())
}

fn cmd_config(args: &mut Args) -> anyhow::Result<()> {
    let path = args
        .positional()
        .first()
        .ok_or_else(|| anyhow::anyhow!("config needs a file path"))?
        .clone();
    args.finish()?;
    let table = ckptfp::config::toml::Table::load(std::path::Path::new(&path))?;
    let s = ckptfp::config::toml::scenario_from_table(&table)?;
    println!("{s:#?}");
    println!("platform MTBF: {:.1} mn", s.mu() / MIN);
    Ok(())
}
