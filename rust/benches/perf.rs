//! Performance benches (`cargo bench --bench perf`): the §Perf numbers
//! of EXPERIMENTS.md.
//!
//!   planner   AOT XLA planner latency/throughput, B = 1 vs B = 64
//!   batcher   dynamic batcher under concurrent clients
//!   sim       simulation engine event throughput
//!   pool      worker-pool scaling
//!   model     closed-form planner throughput (the non-AOT baseline)

use std::time::Instant;

use ckptfp::config::{paper_proc_counts, predictor_yu, Scenario};
use ckptfp::coordinator::{run_parallel, Batcher, BatcherConfig};
use ckptfp::model::{plan, Capping, Params, StrategyKind};
use ckptfp::runtime::HloPlanner;
use ckptfp::sim::simulate_once;
use ckptfp::strategies::spec_for;

fn time<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {label:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

fn params_batch(n: usize) -> Vec<Params> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let procs = paper_proc_counts()[i % 6];
        let s = Scenario::paper(procs, predictor_yu(300.0));
        out.push(Params::from_scenario(&s));
    }
    out
}

fn bench_planner() {
    println!("== planner (AOT XLA via PJRT) ==");
    let mut planner = match HloPlanner::open_default() {
        Ok(p) => p,
        Err(e) => {
            println!("  skipped: {e}");
            return;
        }
    };
    let one = params_batch(1);
    let sixty_four = params_batch(64);
    let t1 = time("plan_batch B=1", 50, || {
        planner.plan_batch(&one).expect("plan");
    });
    let t64 = time("plan_batch B=64", 50, || {
        planner.plan_batch(&sixty_four).expect("plan");
    });
    println!(
        "  batching efficiency: {:.1}x per-config speedup (B=64 vs B=1)",
        t1 / (t64 / 64.0)
    );
    println!("  per-config latency at B=64: {:.1} us", t64 / 64.0 * 1e6);
}

fn bench_batcher() {
    println!("== dynamic batcher (concurrent clients) ==");
    let batcher = match Batcher::spawn(
        HloPlanner::open_default,
        BatcherConfig { max_batch: 64, max_delay: std::time::Duration::from_millis(2), ..Default::default() },
    ) {
        Ok(b) => b,
        Err(e) => {
            println!("  skipped: {e}");
            return;
        }
    };
    for clients in [1usize, 8, 64] {
        let reqs = params_batch(clients);
        let t0 = Instant::now();
        let rounds = 20;
        for _ in 0..rounds {
            std::thread::scope(|s| {
                for p in &reqs {
                    let b = batcher.clone();
                    s.spawn(move || b.plan(*p).expect("plan"));
                }
            });
        }
        let total = (clients * rounds) as f64;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {clients:>3} concurrent clients: {:>8.0} plans/s  ({:.2} ms/plan observed)",
            total / dt,
            dt / rounds as f64 * 1e3
        );
    }
    let stats = batcher.stats();
    println!(
        "  batches formed: {} for {} requests (max batch {})",
        stats.batches, stats.requests, stats.max_batch_seen
    );
    batcher.shutdown();
}

fn bench_sim() {
    println!("== simulation engine ==");
    for (label, n, dist) in [
        ("N=2^16 weibull:0.7", 1u64 << 16, "weibull:0.7"),
        ("N=2^19 weibull:0.7", 1u64 << 19, "weibull:0.7"),
        ("N=2^19 exp", 1u64 << 19, "exp"),
    ] {
        let mut s = Scenario::paper(n, predictor_yu(300.0));
        s.fault_dist = dist.into();
        let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
        let mut segments = 0u64;
        let mut rep = 0u64;
        let t0 = Instant::now();
        while t0.elapsed().as_secs_f64() < 1.0 {
            let o = simulate_once(&s, &spec, rep).expect("sim");
            segments += o.n_segments;
            rep += 1;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {label:<24} {:>6.2} M segments/s  ({:.1} sim-years/s, {} runs)",
            segments as f64 / dt / 1e6,
            rep as f64 * s.work / (365.25 * 86400.0) / dt,
            rep
        );
    }
}

fn bench_pool() {
    println!("== worker pool scaling (fixed total work) ==");
    let s = {
        let mut s = Scenario::paper(1 << 19, predictor_yu(300.0));
        s.fault_dist = "weibull:0.7".into();
        s
    };
    let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    let reps: Vec<u64> = (0..2048).collect();
    let mut base = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let _ = run_parallel(reps.clone(), workers, |rep| {
            simulate_once(&s, &spec, *rep).expect("sim").waste()
        });
        let dt = t0.elapsed().as_secs_f64();
        if workers == 1 {
            base = dt;
        }
        println!(
            "  {workers:>2} workers: {dt:>6.2}s  speedup {:>4.2}x  efficiency {:>4.0}%",
            base / dt,
            base / dt / workers as f64 * 100.0
        );
    }
}

fn bench_model() {
    println!("== closed-form planner (Rust baseline) ==");
    let batch = params_batch(64);
    time("plan() x64 closed-form", 200, || {
        for p in &batch {
            std::hint::black_box(plan(p, Capping::Capped, false));
        }
    });
}

fn main() {
    let which: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    let run = |name: &str| which.is_empty() || which.iter().any(|w| w == name);
    println!("ckptfp perf bench (workers available: {})", ckptfp::coordinator::available_workers());
    if run("planner") {
        bench_planner();
    }
    if run("batcher") {
        bench_batcher();
    }
    if run("sim") {
        bench_sim();
    }
    if run("pool") {
        bench_pool();
    }
    if run("model") {
        bench_model();
    }
}
