//! Artifact manifest: what `python/compile/aot.py` produced.

use std::path::{Path, PathBuf};

/// One compiled artifact (one model variant).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// Entry kind: "plan" or "surface".
    pub entry: String,
    /// Batch size B baked into the module.
    pub b: usize,
    /// Period-grid length G.
    pub g: usize,
    /// Raw-parameter row width (must match model::Params::to_raw_row).
    pub nraw: usize,
}

impl ArtifactSpec {
    pub fn hlo_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.hlo.txt", self.name))
    }
}

/// The parsed manifest.txt.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text, dir.to_path_buf())
    }

    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("manifest line {}: empty", lineno + 1))?
                .to_string();
            let mut spec = ArtifactSpec { name, entry: String::new(), b: 0, g: 0, nraw: 0 };
            for kv in parts {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("manifest line {}: bad field {kv}", lineno + 1))?;
                match k {
                    "entry" => spec.entry = v.to_string(),
                    "b" => spec.b = v.parse()?,
                    "g" => spec.g = v.parse()?,
                    "nraw" => spec.nraw = v.parse()?,
                    other => anyhow::bail!("manifest line {}: unknown key {other}", lineno + 1),
                }
            }
            anyhow::ensure!(
                !spec.entry.is_empty() && spec.b > 0 && spec.g > 0 && spec.nraw > 0,
                "manifest line {}: incomplete spec {spec:?}",
                lineno + 1
            );
            artifacts.push(spec);
        }
        Ok(Manifest { artifacts, dir })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Smallest `plan` artifact whose batch is >= `want` (or the
    /// largest available).
    pub fn plan_artifact_for(&self, want: usize) -> Option<&ArtifactSpec> {
        let mut plans: Vec<&ArtifactSpec> =
            self.artifacts.iter().filter(|a| a.entry == "plan").collect();
        plans.sort_by_key(|a| a.b);
        plans.iter().find(|a| a.b >= want).copied().or(plans.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
planner_b1 entry=plan b=1 g=512 nraw=10
planner_b64 entry=plan b=64 g=512 nraw=10
surface_b16 entry=surface b=16 g=512 nraw=10
";

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let p = m.find("planner_b64").unwrap();
        assert_eq!(p.b, 64);
        assert_eq!(p.entry, "plan");
        assert_eq!(p.hlo_path(&m.dir), PathBuf::from("/tmp/planner_b64.hlo.txt"));
    }

    #[test]
    fn plan_selection() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert_eq!(m.plan_artifact_for(1).unwrap().b, 1);
        assert_eq!(m.plan_artifact_for(2).unwrap().b, 64);
        assert_eq!(m.plan_artifact_for(64).unwrap().b, 64);
        assert_eq!(m.plan_artifact_for(500).unwrap().b, 64); // largest
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Manifest::parse("x entry=plan b=0 g=512 nraw=10", ".".into()).is_err());
        assert!(Manifest::parse("x entry=plan b=1 g=512 bogus=1", ".".into()).is_err());
    }
}
