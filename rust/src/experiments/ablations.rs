//! Ablation experiments — claims the paper *states* but never
//! simulates, validated here by direct simulation:
//!
//! * `abl-q`      — §3.3's endpoint theorem: WASTE(q) is affine, so no
//!                  interior trust probability can beat both q = 0 and
//!                  q = 1. We sweep q ∈ {0, ¼, ½, ¾, 1} with the
//!                  matching period √(2μC/(1−rq)).
//! * `abl-daly`   — §5's remark that "Daly's formula [2] leads to the
//!                  same results" as Young's.
//! * `abl-lead`   — §3 assumes predictions arrive ≥ C ahead; the
//!                  related-work predictors advertise lead times from
//!                  32 s to 2 h. We sweep the lead and watch the
//!                  prediction benefit decay to Young as lead → 0.
//! * `abl-cap`    — §3.2's capped domain vs the §5 uncapped periods:
//!                  the price of mathematical rigor at scale.

use super::{replicate_stat, scenario_for, sim_waste, ExpOptions, ExperimentResult};
use crate::config::{paper_proc_counts, predictor_yu, Predictor, Scenario};
use crate::dist::DistSpec;
use crate::model::{Capping, Params, StrategyKind};
use crate::report::FigureData;
use crate::sim::{Outcome, SimSession};
use crate::strategies::{daly_spec, spec_for, ProactiveMode, StrategySpec};

/// q-sweep: simulated waste as a function of the trust probability.
pub fn ablation_q(opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let mut result = ExperimentResult::default();
    let qs = [0.0, 0.25, 0.5, 0.75, 1.0];
    for n in [1u64 << 16, 1u64 << 19] {
        let mut fig = FigureData::new(
            format!("abl-q-N2e{}", n.trailing_zeros()),
            "q",
            "waste",
        );
        for dist in [DistSpec::Exp, DistSpec::weibull(0.7)] {
            let mut s = Scenario::paper(n, Predictor::exact(0.85, 0.82));
            s.fault_dist = dist;
            let p = Params::from_scenario(&s);
            for q in qs {
                let denom = 1.0 - p.recall * q;
                let t_r = (2.0 * p.mu * p.c / denom.max(1e-9)).sqrt();
                let spec = StrategySpec {
                    name: format!("q{q}"),
                    t_r,
                    q,
                    proactive: ProactiveMode::CkptBefore,
                };
                let w = replicate_stat(&s, &spec, opts.reps, opts.workers, Outcome::waste);
                fig.series_mut(&dist.to_string()).push(q, w.mean());
            }
        }
        result.figures.push(fig);
    }
    Ok(result)
}

/// Young vs Daly: T = sqrt(2 mu C) vs sqrt(2 (mu + R) C).
pub fn ablation_daly(opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let mut result = ExperimentResult::default();
    for dist in [DistSpec::Exp, DistSpec::weibull(0.7)] {
        let mut fig = FigureData::new(
            format!("abl-daly-{}", dist.to_string().replace(':', "")),
            "N",
            "waste",
        );
        for n in paper_proc_counts() {
            let mut s = Scenario::paper(n, Predictor::none());
            s.fault_dist = dist;
            let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
            let daly = daly_spec(&s);
            for spec in [&young, &daly] {
                let w = replicate_stat(&s, spec, opts.reps, opts.workers, Outcome::waste);
                fig.series_mut(&spec.name).push(n as f64, w.mean());
            }
        }
        result.figures.push(fig);
    }
    Ok(result)
}

/// Lead-time sweep: ExactPrediction with the predictor announcing
/// faults `lead` seconds ahead. Below C there is no room for the
/// proactive checkpoint and the benefit decays toward Young.
pub fn ablation_lead(opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let mut result = ExperimentResult::default();
    let n = 1u64 << 19;
    let mut s = Scenario::paper(n, Predictor::exact(0.85, 0.82));
    s.fault_dist = DistSpec::weibull(0.7);
    let spec = spec_for(StrategyKind::ExactPrediction, &s, Capping::Uncapped);
    let young = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let c = s.platform.c;
    let mut fig = FigureData::new("abl-lead-N2e19", "lead/C", "waste");

    // Young reference (lead-independent).
    let young_waste =
        replicate_stat(&s, &young, opts.reps, opts.workers, Outcome::waste).mean();

    for frac in [0.0, 0.25, 0.5, 0.75, 1.0, 2.0] {
        let lead = frac * c;
        // Sessions with an explicit trace lead (below the strategy's
        // own requirement — the point of the ablation), reused across
        // each worker's replications.
        let sum = super::replicate_stat_with(
            opts.reps,
            opts.workers,
            || SimSession::with_lead(&s, &spec, lead).expect("valid scenario"),
            Outcome::waste,
        );
        fig.series_mut("ExactPrediction").push(frac, sum.mean());
        fig.series_mut("Young").push(frac, young_waste);
    }
    result.figures.push(fig);
    Ok(result)
}

/// Capped (§3.2-rigorous) vs uncapped (§5) period choice, by simulation.
pub fn ablation_cap(opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let mut result = ExperimentResult::default();
    let mut fig = FigureData::new("abl-cap", "N", "waste");
    for n in paper_proc_counts() {
        let mut s = Scenario::paper(n, predictor_yu(0.0));
        s.fault_dist = DistSpec::Exp;
        for capping in [Capping::Capped, Capping::Uncapped] {
            let sk = scenario_for(StrategyKind::ExactPrediction, &s);
            let spec = spec_for(StrategyKind::ExactPrediction, &sk, capping);
            let w = replicate_stat(&sk, &spec, opts.reps, opts.workers, Outcome::waste);
            let label = match capping {
                Capping::Capped => "capped",
                Capping::Uncapped => "uncapped",
            };
            fig.series_mut(label).push(n as f64, w.mean());
        }
        // Young baseline for context (uses sim_waste's pairing).
        let w = sim_waste(&s, StrategyKind::Young, opts).mean();
        fig.series_mut("Young").push(n as f64, w);
    }
    result.figures.push(fig);
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpOptions {
        ExpOptions { reps: 4, ..ExpOptions::quick() }
    }

    #[test]
    fn q_endpoints_win() {
        let r = ablation_q(&tiny()).unwrap();
        for fig in &r.figures {
            for s in &fig.series {
                let endpoint_best = s.points.first().unwrap().1.min(s.points.last().unwrap().1);
                for (q, w) in &s.points[1..s.points.len() - 1] {
                    // No interior q may *strictly* beat both endpoints
                    // beyond noise.
                    assert!(
                        *w > endpoint_best - 0.02,
                        "{} q={q}: {w} vs endpoint {endpoint_best}",
                        fig.name
                    );
                }
            }
        }
    }

    #[test]
    fn daly_equals_young() {
        let r = ablation_daly(&tiny()).unwrap();
        for fig in &r.figures {
            let young = fig.get("Young").unwrap();
            let daly = fig.get("Daly").unwrap();
            for (y, d) in young.points.iter().zip(&daly.points) {
                assert!((y.1 - d.1).abs() < 0.02, "{}: {y:?} vs {d:?}", fig.name);
            }
        }
    }

    #[test]
    fn lead_zero_removes_benefit() {
        let mut opts = tiny();
        opts.reps = 6;
        let r = ablation_lead(&opts).unwrap();
        let fig = &r.figures[0];
        let exact = fig.get("ExactPrediction").unwrap();
        let young = fig.get("Young").unwrap().points[0].1;
        let at_zero = exact.points.first().unwrap().1;
        let at_full = exact.points.iter().find(|p| p.0 == 1.0).unwrap().1;
        // Full lead clearly beats Young; zero lead gives most of it back.
        assert!(at_full < young, "full lead {at_full} vs young {young}");
        assert!(at_zero > at_full, "lead 0 {at_zero} must be worse than lead C {at_full}");
    }
}
