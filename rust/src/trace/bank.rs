//! Trace banks: materialize each replication's event streams once,
//! replay them across every candidate of a sweep.
//!
//! `TraceGen`'s streams depend only on the *scenario* (laws, predictor,
//! lead, seed, rep) — never on the candidate period or policy being
//! evaluated. Every sweep surface in the repo therefore re-samples the
//! exact same traces once per candidate. A [`TraceBank`] samples each
//! replication exactly once into a contiguous arena (three `Vec`s —
//! faults, predictions, pre-drawn trust uniforms — with per-rep spans),
//! and a [`ReplaySource`] serves a rep's slice back through the
//! [`EventSource`] trait, so the engine cannot tell replay from live
//! generation. Beyond the constant-factor win (sampling dominates the
//! hot path; replay is a pointer walk), the replay discipline makes
//! candidate comparisons *paired* — common random numbers — which is
//! what [`crate::util::stats::PairedDiff`] exploits for narrow CIs.
//!
//! ## Bit-identity contract
//!
//! Replay must be indistinguishable from live generation at a fixed
//! seed, to the bit (pinned by `tests/test_bank.rs`). Three properties
//! make that hold:
//!
//! * `TraceGen`'s two streams are interleaving-independent — draining
//!   all faults, then all predictions, yields exactly the sequences an
//!   engine's arbitrary interleaving would see;
//! * trust decisions consult a uniform only for *fractional* q
//!   (`Policy::trust` short-circuits `Ignore` and the q ∈ {0, 1}
//!   extremes without drawing), and when they do, the engine draws
//!   exactly once per drained prediction in emission order — so the
//!   bank pre-draws the k-th uniform for the k-th prediction from the
//!   same `Pcg64::new(trust_seed(seed, rep), 0x7157)` stream the
//!   engine would have used ([`crate::rng::trust_seed`] is the single
//!   shared definition), and `Policy::trust_with` ignores the uniform
//!   in exactly the cases `trust` would not have drawn one. A future
//!   policy whose draw decision depends on anything *else* (e.g. the
//!   prediction's truth) would break this alignment and must not be
//!   replayed from a bank;
//! * a bank is *finite* where a generator is infinite, so
//!   [`ReplaySource`] raises an **underrun** flag the moment a caller
//!   asks past the materialized horizon, and the session layer falls
//!   back to a live [`TraceGen`] run for that replication. The
//!   fallback is a code path, not a panic — replay is an optimization
//!   whose validity domain is "the run stayed inside the horizon", and
//!   outside it the answer still comes from the reference path.
//!
//! ## Validity domain / declining
//!
//! Two ways a bank declines rather than misbehaving:
//!
//! * **per-replication**: underrun (run outlived `horizon`, e.g. a
//!   pathological waste near 1) → that rep re-runs live;
//! * **whole-bank**: the estimated arena footprint for the requested
//!   replication count exceeds the cap ([`MAX_RESIDENT_BYTES`] by
//!   default; [`BankOptions::max_bytes`] / the `CKPTFP_BANK_MAX_BYTES`
//!   env var to override) → [`TraceBank::try_build`] returns `None`
//!   and the caller keeps the classic live sessions.
//!
//! Event streams whose regeneration would depend on engine decisions
//! (none exist in-tree today — predictions and faults are exogenous)
//! can never be banked; a source with that property must simply not
//! get a bank, which is the same `None` path.
//!
//! Reuse counters (banks built, replays served, fallbacks taken, bytes
//! resident) are process-global atomics surfaced through
//! [`counters`], `coordinator::metrics` and the v2 `stats` job.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::{EventSource, Fault, Prediction, TraceGen};
use crate::config::Scenario;
use crate::rng::{trust_seed, Pcg64};

/// Default materialization horizon as a multiple of the job's work:
/// covers every run with waste below `1 - 1/4 = 0.75`; longer runs hit
/// the underrun fallback (correct, just not accelerated).
pub const HORIZON_FACTOR: f64 = 4.0;

/// Default whole-bank decline threshold on the *estimated* arena
/// footprint. Override per call with [`BankOptions::max_bytes`] or
/// process-wide with the `CKPTFP_BANK_MAX_BYTES` env var.
pub const MAX_RESIDENT_BYTES: u64 = 256 << 20;

/// Build-time knobs for a [`TraceBank`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BankOptions {
    /// Decline threshold: a bank whose *estimated* arena footprint for
    /// the planned replication count exceeds this is never built and
    /// the caller keeps live sessions.
    pub max_bytes: u64,
}

impl Default for BankOptions {
    /// [`MAX_RESIDENT_BYTES`], overridable via the
    /// `CKPTFP_BANK_MAX_BYTES` env var (bytes; same discipline as
    /// `CKPTFP_WORKERS` in the pool). Unparsable values fall back to
    /// the compiled default.
    fn default() -> Self {
        let max_bytes = std::env::var("CKPTFP_BANK_MAX_BYTES")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(MAX_RESIDENT_BYTES);
        BankOptions { max_bytes }
    }
}

// ---------------------------------------------------------------------------
// Reuse counters
// ---------------------------------------------------------------------------

static BANKS_BUILT: AtomicU64 = AtomicU64::new(0);
static REPLAYS_SERVED: AtomicU64 = AtomicU64::new(0);
static FALLBACKS_TAKEN: AtomicU64 = AtomicU64::new(0);
static BYTES_RESIDENT: AtomicU64 = AtomicU64::new(0);

/// Point-in-time snapshot of the process-global bank counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BankCounters {
    /// Banks successfully built (`try_build` returning `Some`).
    pub banks_built: u64,
    /// Replications served from a bank arena without falling back.
    pub replays_served: u64,
    /// Replications that fell back to live generation (underrun,
    /// missing rep) plus whole-bank declines.
    pub fallbacks_taken: u64,
    /// Arena bytes currently resident across all live banks.
    pub bytes_resident: u64,
}

/// Read the process-global bank reuse counters.
pub fn counters() -> BankCounters {
    BankCounters {
        banks_built: BANKS_BUILT.load(Ordering::Relaxed),
        replays_served: REPLAYS_SERVED.load(Ordering::Relaxed),
        fallbacks_taken: FALLBACKS_TAKEN.load(Ordering::Relaxed),
        bytes_resident: BYTES_RESIDENT.load(Ordering::Relaxed),
    }
}

pub(crate) fn note_replay_served() {
    REPLAYS_SERVED.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn note_fallback_taken() {
    FALLBACKS_TAKEN.fetch_add(1, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// TraceBank
// ---------------------------------------------------------------------------

/// Arena span of one materialized replication.
#[derive(Debug, Clone, Copy, Default)]
struct RepSpan {
    fault_lo: u32,
    fault_hi: u32,
    pred_lo: u32,
    pred_hi: u32,
}

/// A set of replications' fault/prediction streams, materialized once
/// into one contiguous arena and replayed many times.
///
/// Build with [`TraceBank::try_build`], grow with
/// [`TraceBank::ensure_reps`] (the verify comparator's replication
/// doubling extends the bank instead of regenerating), hand out as
/// `Arc<TraceBank>` to [`ReplaySource`]s across worker threads. Reuse
/// an existing allocation for a new scenario/seed with
/// [`TraceBank::reset_for`] (the `SimSession` discipline: arenas keep
/// their capacity).
#[derive(Debug)]
pub struct TraceBank {
    seed: u64,
    lead: f64,
    horizon: f64,
    /// True when the scenario's predictor can never fire (recall 0 and
    /// no false-prediction stream): an empty prediction span then
    /// faithfully replays the live `None`, not an underrun.
    preds_never_fire: bool,
    faults: Vec<Fault>,
    preds: Vec<Prediction>,
    /// Pre-sampled per-prediction trust uniforms, aligned with `preds`:
    /// `trust[k]` is the k-th `next_f64` of the engine's per-rep trust
    /// stream, restarting at each rep's `pred_lo`.
    trust: Vec<f64>,
    spans: Vec<RepSpan>,
    /// Reusable generator for materialization (reset per rep).
    gen: TraceGen,
    /// Bytes currently charged against the global residency counter.
    accounted_bytes: u64,
}

impl TraceBank {
    /// Build a bank for `scenario` with the proactive `lead` the
    /// consumer's policy needs, materializing replications `0..reps`.
    ///
    /// Returns `Ok(None)` — the *decline* path — when the estimated
    /// arena footprint for `reps` replications exceeds
    /// [`MAX_RESIDENT_BYTES`]; the caller then keeps live sessions.
    pub fn try_build(
        scenario: &Scenario,
        lead: f64,
        reps: u64,
    ) -> anyhow::Result<Option<TraceBank>> {
        Self::try_build_with(scenario, lead, reps, &BankOptions::default())
    }

    /// [`TraceBank::try_build`] with an explicit footprint cap.
    pub fn try_build_with(
        scenario: &Scenario,
        lead: f64,
        reps: u64,
        opts: &BankOptions,
    ) -> anyhow::Result<Option<TraceBank>> {
        match Self::try_reserve_with(scenario, lead, reps, opts)? {
            Some(mut bank) => {
                bank.ensure_reps(reps);
                Ok(Some(bank))
            }
            None => Ok(None),
        }
    }

    /// [`TraceBank::try_build`] without materializing anything yet:
    /// the decline decision is made against `planned_reps` (the
    /// caller's eventual budget), but the bank comes back empty so an
    /// incremental consumer (the verify comparator's doubling) can
    /// [`TraceBank::ensure_reps`] only as far as each round needs.
    pub fn try_reserve(
        scenario: &Scenario,
        lead: f64,
        planned_reps: u64,
    ) -> anyhow::Result<Option<TraceBank>> {
        Self::try_reserve_with(scenario, lead, planned_reps, &BankOptions::default())
    }

    /// [`TraceBank::try_reserve`] with an explicit footprint cap.
    pub fn try_reserve_with(
        scenario: &Scenario,
        lead: f64,
        planned_reps: u64,
        opts: &BankOptions,
    ) -> anyhow::Result<Option<TraceBank>> {
        let horizon = HORIZON_FACTOR * scenario.work;
        // Chaos: a plan may force the over-budget decline path without
        // needing a genuinely 256 MiB scenario.
        #[cfg(any(test, feature = "chaos"))]
        if crate::chaos::deny_bank_reserve() {
            note_fallback_taken();
            return Ok(None);
        }
        if estimate_bytes(scenario, horizon, planned_reps) > opts.max_bytes {
            note_fallback_taken();
            return Ok(None);
        }
        let gen = TraceGen::new(scenario, lead, scenario.seed, 0)?;
        let bank = TraceBank {
            seed: scenario.seed,
            lead,
            horizon,
            preds_never_fire: scenario.predictor.never_fires(scenario.mu()),
            faults: Vec::new(),
            preds: Vec::new(),
            trust: Vec::new(),
            spans: Vec::new(),
            gen,
            accounted_bytes: 0,
        };
        BANKS_BUILT.fetch_add(1, Ordering::Relaxed);
        Ok(Some(bank))
    }

    /// Re-target an existing allocation at a new scenario/lead/seed:
    /// arenas are cleared but keep their capacity, like
    /// `SimSession`/`TraceGen` resets. Replications must be re-ensured
    /// afterwards.
    pub fn reset_for(&mut self, scenario: &Scenario, lead: f64) -> anyhow::Result<()> {
        self.gen = TraceGen::new(scenario, lead, scenario.seed, 0)?;
        self.seed = scenario.seed;
        self.lead = lead;
        self.horizon = HORIZON_FACTOR * scenario.work;
        self.preds_never_fire = scenario.predictor.never_fires(scenario.mu());
        self.faults.clear();
        self.preds.clear();
        self.trust.clear();
        self.spans.clear();
        self.settle_bytes();
        Ok(())
    }

    /// Materialize replications `spans.len()..reps` (no-op when the
    /// bank already covers them). This is the extension hook the
    /// verify comparator's replication doubling uses: earlier reps'
    /// arenas are never regenerated.
    pub fn ensure_reps(&mut self, reps: u64) {
        while (self.spans.len() as u64) < reps {
            let rep = self.spans.len() as u64;
            self.gen.reset(self.seed, rep);
            let fault_lo = self.faults.len();
            loop {
                // TraceGen's fault stream is infinite by construction.
                let f = self.gen.next_fault().expect("generator fault streams are infinite");
                if f.t > self.horizon {
                    break;
                }
                self.faults.push(f);
            }
            let pred_lo = self.preds.len();
            loop {
                match self.gen.next_prediction() {
                    None => break, // predictor never fires
                    Some(p) if p.avail > self.horizon => break,
                    Some(p) => self.preds.push(p),
                }
            }
            // Pre-draw the trust uniforms from the exact stream the
            // engine's own trust RNG would produce for this rep.
            let mut rng = Pcg64::new(trust_seed(self.seed, rep), 0x7157);
            for _ in pred_lo..self.preds.len() {
                self.trust.push(rng.next_f64());
            }
            self.spans.push(RepSpan {
                fault_lo: fault_lo as u32,
                fault_hi: self.faults.len() as u32,
                pred_lo: pred_lo as u32,
                pred_hi: self.preds.len() as u32,
            });
        }
        self.settle_bytes();
    }

    /// Replications currently materialized.
    pub fn reps(&self) -> u64 {
        self.spans.len() as u64
    }

    pub fn has_rep(&self, rep: u64) -> bool {
        rep < self.spans.len() as u64
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The proactive lead the bank's prediction stream was generated
    /// with; a replaying session must require exactly this lead.
    pub fn lead(&self) -> f64 {
        self.lead
    }

    /// Materialization horizon (s): a replay whose engine asks past it
    /// underruns and falls back to live generation.
    pub fn horizon(&self) -> f64 {
        self.horizon
    }

    /// Arena span of `rep` as `(fault_lo, fault_hi, pred_lo, pred_hi)`
    /// element indices, for consumers that walk the columns directly
    /// (the wide SoA kernel) instead of through a [`ReplaySource`].
    /// `None` when the bank does not cover `rep`.
    pub(crate) fn span_bounds(&self, rep: u64) -> Option<(usize, usize, usize, usize)> {
        self.spans.get(rep as usize).map(|s| {
            (s.fault_lo as usize, s.fault_hi as usize, s.pred_lo as usize, s.pred_hi as usize)
        })
    }

    /// Read one fault out of the arena by element index.
    #[inline]
    pub(crate) fn fault_at(&self, i: usize) -> Fault {
        self.faults[i]
    }

    /// Read one prediction out of the arena by element index.
    #[inline]
    pub(crate) fn pred_at(&self, i: usize) -> Prediction {
        self.preds[i]
    }

    /// Read the pre-drawn trust uniform aligned with `preds[i]`.
    #[inline]
    pub(crate) fn trust_at(&self, i: usize) -> f64 {
        self.trust[i]
    }

    /// Whether an exhausted prediction span faithfully replays the live
    /// `None` (predictor can never fire) instead of meaning underrun.
    #[inline]
    pub(crate) fn preds_never_fire(&self) -> bool {
        self.preds_never_fire
    }

    /// Current arena footprint in bytes.
    pub fn resident_bytes(&self) -> u64 {
        (self.faults.capacity() * std::mem::size_of::<Fault>()
            + self.preds.capacity() * std::mem::size_of::<Prediction>()
            + self.trust.capacity() * std::mem::size_of::<f64>()
            + self.spans.capacity() * std::mem::size_of::<RepSpan>()) as u64
    }

    /// Re-sync the global residency counter with this bank's actual
    /// footprint.
    fn settle_bytes(&mut self) {
        let now = self.resident_bytes();
        if now >= self.accounted_bytes {
            BYTES_RESIDENT.fetch_add(now - self.accounted_bytes, Ordering::Relaxed);
        } else {
            BYTES_RESIDENT.fetch_sub(self.accounted_bytes - now, Ordering::Relaxed);
        }
        self.accounted_bytes = now;
    }
}

impl Drop for TraceBank {
    fn drop(&mut self) {
        BYTES_RESIDENT.fetch_sub(self.accounted_bytes, Ordering::Relaxed);
    }
}

/// Estimate the arena footprint of `reps` replications without
/// sampling anything: expected faults per rep is `horizon / mu`, true
/// predictions scale by recall, false ones by the false-prediction
/// interval.
fn estimate_bytes(scenario: &Scenario, horizon: f64, reps: u64) -> u64 {
    let mu = scenario.mu();
    let faults_per_rep = (horizon / mu.max(1.0)).max(1.0);
    let false_interval = scenario.predictor.false_pred_interval(mu);
    let false_per_rep =
        if false_interval.is_finite() { horizon / false_interval.max(1.0) } else { 0.0 };
    let preds_per_rep = faults_per_rep * scenario.predictor.recall + false_per_rep;
    let per_rep = faults_per_rep * std::mem::size_of::<Fault>() as f64
        + preds_per_rep
            * (std::mem::size_of::<Prediction>() + std::mem::size_of::<f64>()) as f64;
    (per_rep * reps as f64) as u64
}

// ---------------------------------------------------------------------------
// ReplaySource
// ---------------------------------------------------------------------------

/// [`EventSource`] over one replication's bank spans. The engine is
/// oblivious: faults and predictions arrive exactly as from the live
/// generator, and the per-prediction trust uniform rides along through
/// [`EventSource::next_trust_uniform`].
#[derive(Debug, Clone)]
pub struct ReplaySource {
    bank: Arc<TraceBank>,
    fi: usize,
    fhi: usize,
    pi: usize,
    phi: usize,
    /// Trust uniform of the most recently served prediction, consumed
    /// by the engine's immediately following `next_trust_uniform`.
    pending_trust: Option<f64>,
    underrun: bool,
}

impl ReplaySource {
    /// A source positioned on an empty span; call
    /// [`ReplaySource::reset`] before use.
    pub fn new(bank: Arc<TraceBank>) -> ReplaySource {
        ReplaySource { bank, fi: 0, fhi: 0, pi: 0, phi: 0, pending_trust: None, underrun: false }
    }

    pub fn bank(&self) -> &Arc<TraceBank> {
        &self.bank
    }

    /// Point the source at replication `rep`'s spans. Returns false
    /// (leaving the source empty and underrun) when the bank does not
    /// cover `rep` — the caller should fall back to live generation.
    pub fn reset(&mut self, rep: u64) -> bool {
        self.pending_trust = None;
        // Chaos: pretend the span is missing, forcing the underrun
        // (fall-back-to-live) path the consumer must handle.
        #[cfg(any(test, feature = "chaos"))]
        let span = if crate::chaos::force_underrun() {
            None
        } else {
            self.bank.spans.get(rep as usize)
        };
        #[cfg(not(any(test, feature = "chaos")))]
        let span = self.bank.spans.get(rep as usize);
        match span {
            Some(span) => {
                self.fi = span.fault_lo as usize;
                self.fhi = span.fault_hi as usize;
                self.pi = span.pred_lo as usize;
                self.phi = span.pred_hi as usize;
                self.underrun = false;
                true
            }
            None => {
                self.fi = 0;
                self.fhi = 0;
                self.pi = 0;
                self.phi = 0;
                self.underrun = true;
                false
            }
        }
    }

    /// Whether the consumer asked past the materialized horizon: the
    /// replayed outcome can no longer be trusted to match live
    /// generation and the replication must be re-run live.
    pub fn underrun(&self) -> bool {
        self.underrun
    }
}

impl EventSource for ReplaySource {
    fn next_fault(&mut self) -> Option<Fault> {
        if self.fi < self.fhi {
            let f = self.bank.faults[self.fi];
            self.fi += 1;
            Some(f)
        } else {
            // Live fault streams never end: hitting the span end means
            // the run outlived the horizon.
            self.underrun = true;
            None
        }
    }

    fn next_prediction(&mut self) -> Option<Prediction> {
        if self.pi < self.phi {
            let p = self.bank.preds[self.pi];
            self.pending_trust = Some(self.bank.trust[self.pi]);
            self.pi += 1;
            Some(p)
        } else {
            if !self.bank.preds_never_fire {
                self.underrun = true;
            }
            None
        }
    }

    fn next_trust_uniform(&mut self) -> Option<f64> {
        self.pending_trust.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Predictor;

    fn scenario(recall: f64, precision: f64, window: f64, dist: &str) -> Scenario {
        let pred = if window > 0.0 {
            Predictor::windowed(recall, precision, window)
        } else {
            Predictor::exact(recall, precision)
        };
        let mut s = Scenario::paper(1 << 16, pred);
        s.fault_dist = dist.parse().expect("test dist spec");
        s.work = 2.0e5;
        s
    }

    #[test]
    fn replay_matches_live_streams_bit_for_bit() {
        let s = scenario(0.85, 0.82, 3000.0, "weibull:0.7");
        let lead = s.platform.c;
        let bank =
            Arc::new(TraceBank::try_build(&s, lead, 3).unwrap().expect("small bank fits"));
        for rep in [2u64, 0, 1] {
            let mut live = TraceGen::new(&s, lead, s.seed, rep).unwrap();
            let mut replay = ReplaySource::new(bank.clone());
            assert!(replay.reset(rep));
            // Every banked fault/prediction equals the live stream's
            // prefix, in order, to the bit.
            loop {
                match replay.next_fault() {
                    Some(f) => assert_eq!(Some(f), live.next_fault(), "rep {rep}"),
                    None => break,
                }
            }
            assert!(replay.underrun(), "finite spans end in underrun");
            let mut replay = ReplaySource::new(bank.clone());
            assert!(replay.reset(rep));
            loop {
                match replay.next_prediction() {
                    Some(p) => {
                        assert_eq!(Some(p), live.next_prediction(), "rep {rep}");
                        assert!(replay.next_trust_uniform().is_some());
                    }
                    None => break,
                }
            }
        }
    }

    #[test]
    fn trust_uniforms_match_the_engine_stream() {
        let s = scenario(0.7, 0.4, 300.0, "exp");
        let bank = TraceBank::try_build(&s, s.platform.c, 2).unwrap().unwrap();
        for rep in [0u64, 1] {
            let span = bank.spans[rep as usize];
            let mut rng = Pcg64::new(trust_seed(s.seed, rep), 0x7157);
            for k in span.pred_lo..span.pred_hi {
                assert_eq!(bank.trust[k as usize].to_bits(), rng.next_f64().to_bits());
            }
        }
    }

    #[test]
    fn missing_rep_is_a_fallback_not_a_panic() {
        let s = scenario(0.85, 0.82, 0.0, "exp");
        let bank = Arc::new(TraceBank::try_build(&s, s.platform.c, 2).unwrap().unwrap());
        let mut replay = ReplaySource::new(bank);
        assert!(!replay.reset(5));
        assert!(replay.underrun());
        assert!(replay.next_fault().is_none());
    }

    #[test]
    fn never_firing_predictor_replays_none_without_underrun() {
        let s = scenario(0.0, 1.0, 0.0, "exp");
        let bank = Arc::new(TraceBank::try_build(&s, s.platform.c, 1).unwrap().unwrap());
        let mut replay = ReplaySource::new(bank);
        assert!(replay.reset(0));
        assert!(replay.next_prediction().is_none());
        assert!(!replay.underrun(), "empty predictor is faithful, not truncated");
        assert!(replay.next_fault().is_some());
    }

    #[test]
    fn ensure_reps_extends_without_touching_existing_spans() {
        let s = scenario(0.85, 0.82, 300.0, "weibull:0.7");
        let mut bank = TraceBank::try_build(&s, s.platform.c, 2).unwrap().unwrap();
        let before: Vec<Fault> = bank.faults[..bank.spans[1].fault_hi as usize].to_vec();
        bank.ensure_reps(5);
        assert_eq!(bank.reps(), 5);
        assert_eq!(&bank.faults[..before.len()], &before[..], "extension rewrote history");
        // Extended reps match a from-scratch build.
        let fresh = TraceBank::try_build(&s, s.platform.c, 5).unwrap().unwrap();
        assert_eq!(bank.faults.len(), fresh.faults.len());
        for (a, b) in bank.faults.iter().zip(&fresh.faults) {
            assert_eq!(a, b);
        }
        for (a, b) in bank.trust.iter().zip(&fresh.trust) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn oversized_banks_decline() {
        let mut s = scenario(0.85, 0.82, 0.0, "exp");
        s.work = 1.0e9; // horizon 4e9 s, mu ~6e4 s: ~66k faults/rep
        let declined = TraceBank::try_build(&s, s.platform.c, 1_000_000).unwrap();
        assert!(declined.is_none(), "a terabyte-scale bank must decline");
    }

    #[test]
    fn tiny_cap_declines_an_otherwise_small_bank() {
        let s = scenario(0.85, 0.82, 0.0, "exp");
        // The same bank fits comfortably under the default cap...
        assert!(TraceBank::try_build(&s, s.platform.c, 4).unwrap().is_some());
        // ...but declines under a 1 KiB one, taking the fallback path.
        let tiny = BankOptions { max_bytes: 1 << 10 };
        let before = counters().fallbacks_taken;
        let declined = TraceBank::try_build_with(&s, s.platform.c, 4, &tiny).unwrap();
        assert!(declined.is_none(), "a 1 KiB cap must decline");
        assert!(counters().fallbacks_taken > before);
        // A cap explicitly at the default behaves like the default.
        let dflt = BankOptions { max_bytes: MAX_RESIDENT_BYTES };
        assert!(TraceBank::try_build_with(&s, s.platform.c, 4, &dflt).unwrap().is_some());
    }

    #[test]
    fn residency_counter_settles_on_drop() {
        let s = scenario(0.85, 0.82, 0.0, "exp");
        let bank = TraceBank::try_build(&s, s.platform.c, 4).unwrap().unwrap();
        let own = bank.resident_bytes();
        assert!(own > 0);
        // Tests share the process-global counter, so the only race-free
        // claims are monotone ones: while alive, the global footprint
        // includes this bank's bytes...
        assert!(counters().bytes_resident >= own);
        let counted = bank.accounted_bytes;
        assert_eq!(counted, own, "accounting drifted from the arena");
        drop(bank);
        // ...and the drop handler subtracted exactly what was charged
        // (indirectly: building + dropping in a loop must not leak).
        for _ in 0..3 {
            let b = TraceBank::try_build(&s, s.platform.c, 4).unwrap().unwrap();
            assert_eq!(b.accounted_bytes, b.resident_bytes());
        }
    }

    #[test]
    fn reset_for_reuses_the_allocation() {
        let s1 = scenario(0.85, 0.82, 300.0, "weibull:0.7");
        let mut s2 = scenario(0.7, 0.4, 0.0, "exp");
        s2.seed = 99;
        let mut bank = TraceBank::try_build(&s1, s1.platform.c, 3).unwrap().unwrap();
        bank.reset_for(&s2, s2.platform.c).unwrap();
        assert_eq!(bank.reps(), 0);
        assert_eq!(bank.seed(), 99);
        bank.ensure_reps(2);
        let fresh = TraceBank::try_build(&s2, s2.platform.c, 2).unwrap().unwrap();
        assert_eq!(bank.faults.len(), fresh.faults.len());
        for (a, b) in bank.faults.iter().zip(&fresh.faults) {
            assert_eq!(a, b);
        }
    }
}
