//! Trace-bank CRN acceptance tests: replay must be bit-identical to
//! live generation, and common random numbers must actually buy the
//! variance reduction the sweep statistics claim.

use std::sync::Arc;

use ckptfp::api::{Executor, JobRequest, JobResponse, SimulateJob};
use ckptfp::config::{Predictor, Scenario};
use ckptfp::dist::DistSpec;
use ckptfp::model::{Capping, StrategyKind};
use ckptfp::sim::{Policy, SimSession};
use ckptfp::strategies::{best_period_with, spec_for, BestPeriodOptions};
use ckptfp::trace::TraceBank;
use ckptfp::util::stats::PairedDiff;

fn study(dist: DistSpec, predictor: Predictor) -> Scenario {
    let mut s = Scenario::paper(1 << 16, predictor);
    s.fault_dist = dist;
    s.work = 2.0e5;
    s
}

/// The acceptance golden: a replay-backed `best_period_with` returns
/// bit-identical results to the live-generation path at a fixed seed,
/// for Exponential and Weibull faults (with and without a predictor).
#[test]
fn best_period_replay_is_bit_identical_to_live_golden() {
    let cases = [
        (study(DistSpec::Exp, Predictor::none()), StrategyKind::Young),
        (study(DistSpec::weibull(0.7), Predictor::windowed(0.85, 0.82, 300.0)), StrategyKind::NoCkptI),
    ];
    for (s, kind) in cases {
        let base = spec_for(kind, &s, Capping::Uncapped);
        let live = best_period_with(
            &s,
            &base,
            8,
            6,
            &BestPeriodOptions { workers: 2, prune: false, replay: false, ..Default::default() },
        )
        .unwrap();
        let replay = best_period_with(
            &s,
            &base,
            8,
            6,
            &BestPeriodOptions { workers: 2, prune: false, replay: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(live.t_r.to_bits(), replay.t_r.to_bits(), "{kind:?} winner period");
        assert_eq!(live.waste.to_bits(), replay.waste.to_bits(), "{kind:?} winner waste");
        assert_eq!(live.n_pruned, replay.n_pruned);
        assert_eq!(live.reps_used, replay.reps_used);
        for (i, (a, b)) in live.sweep.iter().zip(&replay.sweep).enumerate() {
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "{kind:?} sweep[{i}] period");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "{kind:?} sweep[{i}] waste");
        }
    }
}

/// The CRN variance-reduction claim, measured: on the same replications
/// of the same bank, the paired-difference CI between two adjacent
/// candidate periods is strictly narrower than the unpaired CI.
#[test]
fn paired_ci_is_strictly_narrower_than_unpaired_on_shared_traces() {
    let s = study(DistSpec::weibull(0.7), Predictor::windowed(0.85, 0.82, 300.0));
    let base = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    let c = s.platform.c;
    let bank = Arc::new(
        TraceBank::try_build(&s, base.required_lead(c), 40).unwrap().expect("bank fits"),
    );
    // Two adjacent candidates around the closed-form optimum.
    let mut lo = base.clone();
    lo.t_r *= 0.8;
    let mut hi = base.clone();
    hi.t_r *= 1.25;
    let mut sa = SimSession::replay(bank.clone(), &s, Policy::from_spec(&lo, c)).unwrap();
    let mut sb = SimSession::replay(bank, &s, Policy::from_spec(&hi, c)).unwrap();
    let mut pd = PairedDiff::new();
    for rep in 0..40 {
        pd.push(sa.run(rep).waste(), sb.run(rep).waste());
    }
    assert_eq!(pd.count(), 40);
    assert!(
        pd.ci95_paired() < pd.ci95_unpaired(),
        "paired {} must beat unpaired {}",
        pd.ci95_paired(),
        pd.ci95_unpaired()
    );
    // Not marginal, either: common random numbers on adjacent periods
    // share most of the fault history, so the reduction is large.
    assert!(
        pd.ci95_paired() < 0.8 * pd.ci95_unpaired(),
        "CRN reduction too small: paired {} vs unpaired {}",
        pd.ci95_paired(),
        pd.ci95_unpaired()
    );
}

/// Pruned replay searches stay deterministic and honest about spend.
#[test]
fn pruned_replay_search_is_reproducible_and_reports_spend() {
    let s = study(DistSpec::Exp, Predictor::none());
    let base = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let opts = BestPeriodOptions { workers: 3, prune: true, replay: true, ..Default::default() };
    let a = best_period_with(&s, &base, 12, 8, &opts).unwrap();
    let b = best_period_with(&s, &base, 12, 8, &opts).unwrap();
    assert_eq!(a.t_r, b.t_r);
    assert_eq!(a.n_pruned, b.n_pruned);
    assert_eq!(a.reps_used, b.reps_used);
    assert_eq!(a.sweep, b.sweep);
    assert!(a.reps_used <= 12 * 8, "spend cannot exceed the requested budget");
    assert!(a.reps_used >= 8 * 3, "coarse pass covers the grid");
    // Paired CIs vs the coarse leader came back for the CRN prune.
    assert_eq!(a.paired_ci.len(), 8);
    assert!(a.paired_ci.iter().any(|x| x.is_finite()));
    for (x, y) in a.paired_ci.iter().zip(&b.paired_ci) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// The v2 stats surface exposes the bank reuse counters, and running a
/// replay-backed search moves them.
#[test]
fn bank_counters_surface_through_stats() {
    let exec = Executor::local();
    let before = match exec.execute(&JobRequest::Stats) {
        JobResponse::Stats(s) => s,
        other => panic!("expected stats, got {other:?}"),
    };
    let s = study(DistSpec::Exp, Predictor::none());
    let base = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    best_period_with(
        &s,
        &base,
        4,
        4,
        &BestPeriodOptions { workers: 2, prune: false, replay: true, ..Default::default() },
    )
    .unwrap();
    let after = match exec.execute(&JobRequest::Stats) {
        JobResponse::Stats(s) => s,
        other => panic!("expected stats, got {other:?}"),
    };
    // Counters are process-global and other tests run concurrently, so
    // assert monotone movement, not exact deltas.
    assert!(after.banks_built > before.banks_built, "a bank was built");
    assert!(
        after.bank_replays >= before.bank_replays + 16,
        "4 candidates x 4 reps replayed"
    );
    // And the coordinator-metrics bank snapshot mirrors the same
    // process-global counters (per-instance Metrics stay untouched).
    let snap = ckptfp::coordinator::bank_snapshot();
    assert!(snap["bank.banks_built"] >= after.banks_built);
    assert!(snap.contains_key("bank.replays_served"));
    assert!(snap.contains_key("bank.fallbacks_taken"));
    assert!(snap.contains_key("bank.bytes_resident"));
    assert!(ckptfp::coordinator::Metrics::new().snapshot().is_empty());
}

/// Replay-backed Simulate through the executor is bit-identical to the
/// classic path (the bank is an internal detail of best-period/verify;
/// simulate stays live — this pins that nothing leaked).
#[test]
fn simulate_path_is_unchanged_by_the_bank_subsystem() {
    let exec = Executor::local();
    let mut s = study(DistSpec::Exp, Predictor::exact(0.85, 0.82));
    s.seed = 77;
    let mut job = SimulateJob::new(s.clone(), StrategyKind::ExactPrediction);
    job.reps = 6;
    job.workers = Some(2);
    let a = exec.simulate(&job).unwrap();
    let b = exec.simulate(&job).unwrap();
    assert_eq!(a.mean_waste.to_bits(), b.mean_waste.to_bits());
    assert_eq!(a.n_faults, b.n_faults);
}
