//! ASCII table rendering for terminal reports.

/// Column-aligned table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<I, S>(header: I) -> Table
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<I, S>(&mut self, cells: I) -> &mut Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let sep: String = widths
            .iter()
            .map(|w| format!("-{}-", "-".repeat(*w)))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| format!(" {:width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "waste"]);
        t.row(["Young", "0.152"]);
        t.row(["ExactPrediction", "0.124"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].contains("Young"));
        // All lines equal width.
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }
}
