//! The policy-layer refactor contract.
//!
//! Golden-outcome pinning: every paper strategy, executed through the
//! classic spec path (`SimSession::new` over `spec_for`) and through
//! the policy layer (`SimSession::from_policy` over `resolve_policy`),
//! must produce *identical* `Outcome` structs — every counter equal,
//! every float equal to the bit — across several scenarios and
//! replications. Plus end-to-end coverage of the two non-paper
//! policies through the executor/wire stack the CLI and the TCP
//! service share.

use ckptfp::api::{Executor, SimulateJob};
use ckptfp::config::{Predictor, Scenario};
use ckptfp::dist::DistSpec;
use ckptfp::experiments::scenario_for;
use ckptfp::model::{Capping, StrategyKind};
use ckptfp::sim::SimSession;
use ckptfp::strategies::{resolve_policy, spec_for, PolicySpec};

/// Three §5-flavored scenarios: exact predictor over Exponential
/// faults, small window over Weibull 0.7, large window over
/// Weibull 0.5 with a uniform false-prediction law. The windowed
/// scenarios keep I >= C so WithCkptI is exercised in both.
fn scenarios() -> Vec<Scenario> {
    let mut exact = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
    exact.fault_dist = DistSpec::Exp;
    exact.work = 2.0e5;

    let mut small_window = Scenario::paper(1 << 16, Predictor::windowed(0.85, 0.82, 900.0));
    small_window.fault_dist = DistSpec::weibull(0.7);
    small_window.work = 2.0e5;

    let mut large_window = Scenario::paper(1 << 16, Predictor::windowed(0.7, 0.4, 3000.0));
    large_window.fault_dist = DistSpec::weibull(0.5);
    large_window.false_pred_dist = Some(DistSpec::Uniform);
    large_window.work = 2.0e5;

    vec![exact, small_window, large_window]
}

/// The five paper strategies of the §5 simulations (WithCkptI needs
/// I >= C, which all three scenarios' windowed variants honor or skip).
fn paper_strategies(window: f64, c: f64) -> Vec<StrategyKind> {
    let mut v = vec![
        StrategyKind::Young,
        StrategyKind::ExactPrediction,
        StrategyKind::Instant,
        StrategyKind::NoCkptI,
    ];
    if window >= c {
        v.push(StrategyKind::WithCkptI);
    }
    v
}

#[test]
fn paper_strategies_are_bit_identical_through_the_policy_layer() {
    for (si, scenario) in scenarios().iter().enumerate() {
        let kinds = paper_strategies(scenario.predictor.window, scenario.platform.c);
        for kind in kinds {
            // Seed path: the pre-refactor construction route.
            let s = scenario_for(kind, scenario);
            let spec = spec_for(kind, &s, Capping::Uncapped);
            let mut classic = SimSession::new(&s, &spec).unwrap();
            // Policy path: spec string -> PolicySpec -> resolve -> run.
            let pspec: PolicySpec = kind.name().parse().unwrap();
            let rp = resolve_policy(&pspec, scenario).unwrap();
            assert_eq!(rp.scenario, s, "scenario {si} {kind}: resolution must exactify alike");
            let mut layered = SimSession::from_policy(&rp.scenario, rp.policy).unwrap();

            for rep in [0u64, 1, 4] {
                let a = classic.run(rep);
                let b = layered.run(rep);
                let tag = format!("scenario {si}, {kind}, rep {rep}");
                assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tag}: makespan");
                assert_eq!(a.work.to_bits(), b.work.to_bits(), "{tag}: work");
                assert_eq!(a.lost_work.to_bits(), b.lost_work.to_bits(), "{tag}: lost_work");
                assert_eq!(a.completed, b.completed, "{tag}: completed");
                assert_eq!(a.n_faults, b.n_faults, "{tag}: n_faults");
                assert_eq!(
                    a.n_faults_unpredicted, b.n_faults_unpredicted,
                    "{tag}: n_faults_unpredicted"
                );
                assert_eq!(a.n_preds, b.n_preds, "{tag}: n_preds");
                assert_eq!(a.n_true_preds, b.n_true_preds, "{tag}: n_true_preds");
                assert_eq!(a.n_trusted, b.n_trusted, "{tag}: n_trusted");
                assert_eq!(a.n_ckpts, b.n_ckpts, "{tag}: n_ckpts");
                assert_eq!(a.n_proactive_ckpts, b.n_proactive_ckpts, "{tag}: n_proactive");
                assert_eq!(a.n_migrations, b.n_migrations, "{tag}: n_migrations");
                assert_eq!(a.n_faults_avoided, b.n_faults_avoided, "{tag}: n_avoided");
                assert_eq!(a.n_segments, b.n_segments, "{tag}: n_segments");
            }
        }
    }
}

#[test]
fn migration_strategy_also_survives_the_policy_layer() {
    // Migration has the distinct required-lead rule (M vs C); pin it
    // separately on the exact-predictor scenario.
    let scenario = &scenarios()[0];
    let spec = spec_for(StrategyKind::Migration, scenario, Capping::Uncapped);
    let mut classic = SimSession::new(scenario, &spec).unwrap();
    let rp = resolve_policy(&PolicySpec::Strategy(StrategyKind::Migration), scenario).unwrap();
    let mut layered = SimSession::from_policy(&rp.scenario, rp.policy).unwrap();
    for rep in [0u64, 3] {
        let a = classic.run(rep);
        let b = layered.run(rep);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.n_migrations, b.n_migrations);
        assert_eq!(a.n_segments, b.n_segments);
    }
}

#[test]
fn non_paper_policies_run_end_to_end_and_diverge_from_paper_ones() {
    let scenario = &scenarios()[1];
    let young = resolve_policy(&PolicySpec::Strategy(StrategyKind::Young), scenario).unwrap();
    let adaptive = resolve_policy(&PolicySpec::AdaptivePeriod { gain: 1.0 }, scenario).unwrap();
    let risk = resolve_policy(&PolicySpec::RiskThreshold { kappa: 1.0 }, scenario).unwrap();

    let mut young_s = SimSession::from_policy(&young.scenario, young.policy).unwrap();
    let mut adaptive_s = SimSession::from_policy(&adaptive.scenario, adaptive.policy).unwrap();
    let mut risk_s = SimSession::from_policy(&risk.scenario, risk.policy).unwrap();

    let y = young_s.run(0);
    let a = adaptive_s.run(0);
    let r = risk_s.run(0);
    for (name, o) in [("young", &y), ("adaptive", &a), ("risk", &r)] {
        assert!(o.completed, "{name} must complete");
        assert!(o.waste() > 0.0 && o.waste() < 1.0, "{name} waste {}", o.waste());
        assert!(o.n_ckpts > 0, "{name} must checkpoint");
    }
    // The new policies are genuinely different machines: at least one
    // observable differs from Young on the same trace. (Adaptive moves
    // its period; risk trusts predictions and measures volatile work.)
    assert!(
        a.n_segments != y.n_segments || a.makespan != y.makespan,
        "adaptive ran identically to Young"
    );
    assert!(
        r.n_proactive_ckpts != y.n_proactive_ckpts || r.makespan != y.makespan,
        "risk ran identically to Young"
    );
}

#[test]
fn policy_jobs_flow_through_the_executor_and_wire() {
    use ckptfp::api::{wire, JobRequest, JobResponse};

    let scenario = &scenarios()[0];
    let exec = Executor::local();
    let mut job = SimulateJob::new(scenario.clone(), StrategyKind::Young);
    job.reps = 4;
    job.workers = Some(2);
    job.policy = Some(PolicySpec::AdaptivePeriod { gain: 1.0 });

    // Encode -> decode -> execute: the full remote path in-process.
    let line = wire::encode_request(&JobRequest::Simulate(job.clone()));
    let decoded = wire::decode_request(&line).unwrap();
    assert_eq!(decoded.request, JobRequest::Simulate(job.clone()));
    match exec.execute(&decoded.request) {
        JobResponse::Simulate(res) => {
            assert_eq!(res.strategy, "adaptive:1");
            assert_eq!(res.reps, 4);
            assert_eq!(res.completion_rate, 1.0);
            // The response round-trips the wire too.
            let resp_line = wire::encode_response(&JobResponse::Simulate(res.clone()), false);
            assert_eq!(wire::decode_response(&resp_line).unwrap(), JobResponse::Simulate(res));
        }
        other => panic!("expected simulate result, got {other:?}"),
    }
}

#[test]
fn policy_replications_are_deterministic() {
    let scenario = &scenarios()[2];
    for pspec in [PolicySpec::AdaptivePeriod { gain: 1.0 }, PolicySpec::RiskThreshold { kappa: 1.0 }]
    {
        let rp = resolve_policy(&pspec, scenario).unwrap();
        let mut s1 = SimSession::from_policy(&rp.scenario, rp.policy).unwrap();
        let mut s2 = SimSession::from_policy(&rp.scenario, rp.policy).unwrap();
        for rep in [0u64, 2, 2, 5] {
            let a = s1.run(rep);
            let b = s2.run(rep);
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{pspec} rep {rep}");
            assert_eq!(a.n_segments, b.n_segments, "{pspec} rep {rep}");
            assert_eq!(a.n_ckpts, b.n_ckpts, "{pspec} rep {rep}");
        }
    }
}
