//! Integration: the §5 experiment harness produces paper-shaped output.
//! Uses tiny replication counts — the recorded runs use the bench
//! harness with full settings.

use ckptfp::experiments::{run_experiment, ExpOptions};

fn tiny() -> ExpOptions {
    ExpOptions { reps: 3, ..ExpOptions::quick() }
}

#[test]
fn fig4_structure_and_shape() {
    let r = run_experiment("fig4", &tiny()).unwrap();
    // 2 windows x (2 analytic + 3 simulated) = 10 subfigures (a)-(j).
    assert_eq!(r.figures.len(), 10);
    let names: Vec<&str> = r.figures.iter().map(|f| f.name.as_str()).collect();
    assert!(names.iter().any(|n| n.contains("I300-analytic-capped")));
    assert!(names.iter().any(|n| n.contains("I3000-sim-weibull0.5")));
    // Analytical uncapped subfigure: prediction dominates Young.
    let fig = r
        .figures
        .iter()
        .find(|f| f.name.contains("I300-analytic-uncapped"))
        .unwrap();
    let young = fig.get("Young").unwrap();
    let exact = fig.get("ExactPrediction").unwrap();
    for (y, e) in young.points.iter().zip(&exact.points) {
        assert!(e.1 <= y.1 + 1e-9, "prediction must help: {e:?} vs {y:?}");
    }
    // Simulated subfigure exists with all heuristics and 6 sizes.
    let sim = r.figures.iter().find(|f| f.name.contains("I300-sim-exp")).unwrap();
    assert_eq!(sim.series.len(), 4); // no WithCkptI at I=300 < C
    for s in &sim.series {
        assert_eq!(s.points.len(), 6);
        for (_, w) in &s.points {
            assert!((0.0..=1.0).contains(w));
        }
    }
}

#[test]
fn fig6_large_window_has_withckpt() {
    let r = run_experiment("fig6", &tiny()).unwrap();
    let sim = r.figures.iter().find(|f| f.name.contains("I3000-sim-exp")).unwrap();
    assert!(sim.get("WithCkptI").is_some());
    assert_eq!(sim.series.len(), 5);
}

#[test]
fn sweep_fig10_recall_improves_waste() {
    let mut opts = tiny();
    opts.reps = 4;
    let r = run_experiment("fig10", &opts).unwrap();
    assert_eq!(r.figures.len(), 2); // N = 2^16 and 2^19
    for fig in &r.figures {
        let s = fig.series.iter().find(|s| s.label.contains("p=0.8")).unwrap();
        // Higher recall should not hurt: waste at r=0.99 below r=0.3,
        // with stochastic slack.
        let first = s.points.first().unwrap().1;
        let last = s.points.last().unwrap().1;
        assert!(last < first * 1.05, "{}: {first} -> {last}", fig.name);
    }
}

#[test]
fn tab3_catalog_renders() {
    let r = run_experiment("tab3", &tiny()).unwrap();
    assert_eq!(r.tables.len(), 1);
    let text = r.render();
    assert!(text.contains("Yu et al."));
    assert!(text.contains("winner"));
}

#[test]
fn csv_output_written() {
    let dir = std::env::temp_dir().join(format!("ckptfp-exp-{}", std::process::id()));
    let r = run_experiment("tab3", &tiny()).unwrap();
    r.write_csvs(&dir).unwrap();
    // tab3 has no figures, so no files — use a figure experiment.
    let mut opts = tiny();
    opts.reps = 2;
    let rf = run_experiment("fig8", &opts).unwrap();
    rf.write_csvs(&dir).unwrap();
    let entries: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
    assert!(!entries.is_empty());
    std::fs::remove_dir_all(&dir).unwrap();
}
