//! Minimal leveled logger (substrate: no `log`/`env_logger` runtime dep
//! needed; writes to stderr with a monotonic timestamp).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

#[doc(hidden)]
pub fn emit(l: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:10.3}s {tag} {module}] {args}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
