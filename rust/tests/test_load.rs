//! The deterministic load-test harness (ISSUE 9 acceptance): replay
//! seeded synthetic multi-tenant traces against an in-process service
//! and pin the invariants — every request answered exactly once,
//! repeated requests answered bit-identically (cold or cached), no
//! tenant short-changed its deterministic share, the cache-hot path at
//! least an order of magnitude faster than cold, and a graceful stop
//! delivering every admitted response.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use ckptfp::api::{
    wire, Executor, ExecutorConfig, JobRequest, JobResponse, SimulateJob,
};
use ckptfp::config::{Predictor, Scenario};
use ckptfp::coordinator::{loadgen, serve, ServiceConfig, ServiceHandle, TraceSpec};
use ckptfp::dist::DistSpec;
use ckptfp::model::StrategyKind;

fn start(spec: &TraceSpec) -> (ServiceHandle, String) {
    let executor = Executor::new(ExecutorConfig { reps_default: 4, ..Default::default() });
    let handle = serve(
        executor,
        ServiceConfig {
            addr: "127.0.0.1:0".into(),
            tenant_weights: spec.tenants.clone(),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

/// Sized to stay under every admission gate (3 tenants x window 6 =
/// at most 18 jobs admitted at once, against max_inflight 32), so a
/// clean run must answer every request with a real plan.
fn small_spec(seed: u64) -> TraceSpec {
    TraceSpec {
        seed,
        requests: 60,
        distinct: 6,
        repeat_ratio: 0.7,
        window: 6,
        bench_distinct: 3,
        bench_rounds: 3,
        bench_reps: 200,
        bench_candidates: 10,
        ..TraceSpec::default()
    }
}

#[test]
fn the_invariant_suite_holds_across_seeds() {
    for seed in [11u64, 42, 977] {
        let spec = small_spec(seed);
        let (handle, addr) = start(&spec);
        let report = loadgen::run(&addr, &spec).unwrap();
        handle.stop();

        // Exactly once: one response line per request line, none
        // dropped, none duplicated (a duplicate would surface as an
        // extra line and desynchronize the in-order reader).
        assert_eq!(report.answered, report.requests, "seed {seed}: exactly-once");
        assert_eq!(report.errors, 0, "seed {seed}: trace sized under every gate");
        assert_eq!(
            report.mismatches, 0,
            "seed {seed}: repeated lines must be answered bit-identically"
        );

        // Per-tenant completeness: each tenant receives exactly its
        // deterministic share of the trace — no starvation, no leaks
        // across tenants.
        let trace = loadgen::generate(&spec);
        assert_eq!(report.per_tenant.len(), spec.tenants.len());
        for (tenant, answered) in &report.per_tenant {
            let expected =
                trace.iter().filter(|t| &t.tenant == tenant).count() as u64;
            assert!(expected > 0, "seed {seed}: degenerate trace for {tenant}");
            assert_eq!(
                answered, &expected,
                "seed {seed}: tenant {tenant} answered {answered}/{expected}"
            );
        }

        // Cache acceptance: hot replays byte-identical to their cold
        // twins, and at least 10x the cold throughput.
        assert!(report.bench_bit_identical, "seed {seed}: hot bytes drifted");
        assert!(report.cache_hits > 0, "seed {seed}: replay rounds never hit");
        assert!(
            report.hit_speedup >= 10.0,
            "seed {seed}: cache-hot only {:.1}x faster than cold",
            report.hit_speedup
        );
    }
}

#[test]
fn the_trace_is_identical_across_runs_and_distinct_across_seeds() {
    let a = loadgen::generate(&small_spec(42));
    let b = loadgen::generate(&small_spec(42));
    assert_eq!(a.len(), b.len());
    assert!(a
        .iter()
        .zip(&b)
        .all(|(x, y)| x.tenant == y.tenant && x.line == y.line));
    let c = loadgen::generate(&small_spec(43));
    assert!(a.iter().zip(&c).any(|(x, y)| x.line != y.line));
}

#[test]
fn stop_drains_every_admitted_job() {
    let executor = Executor::new(ExecutorConfig { reps_default: 4, ..Default::default() });
    let handle = serve(
        executor,
        ServiceConfig {
            addr: "127.0.0.1:0".into(),
            drain: Duration::from_secs(30),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = handle.addr.to_string();

    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let mut s = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
    s.fault_dist = DistSpec::Exp;
    s.work = 2.0e5;
    let mut job = SimulateJob::new(s, StrategyKind::Young);
    job.reps = 50;
    let line = wire::encode_request(&JobRequest::Simulate(job));
    for _ in 0..3 {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
    }
    writer.flush().unwrap();
    // Give the event loop time to admit all three, then stop while
    // they are (likely) still queued or executing.
    std::thread::sleep(Duration::from_millis(200));
    let stopper = std::thread::spawn(move || handle.stop());

    for i in 0..3 {
        let mut resp = String::new();
        let n = reader.read_line(&mut resp).unwrap();
        assert!(n > 0, "response {i} lost in drain");
        match wire::decode_stream_event(resp.trim()).unwrap() {
            wire::StreamEvent::Final { response: JobResponse::Simulate(r), .. } => {
                assert_eq!(r.reps, 50, "response {i} truncated");
            }
            other => panic!("response {i}: expected a simulate result, got {other:?}"),
        }
    }
    stopper.join().unwrap();
}
