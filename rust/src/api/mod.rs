//! The crate's one public job surface.
//!
//! Everything the system can do for a caller — closed-form/HLO
//! **planning**, pool-parallel Monte Carlo **simulation**, brute-force
//! **best-period** search, platform **sweeps**, model-vs-simulation
//! **conformance** ([`VerifyJob`]) — is a [`JobRequest`]
//! answered by a [`JobResponse`], with structured [`ApiError`]s in
//! place of stringly failures. The same [`Executor`] serves every
//! caller:
//!
//! ```text
//!   CLI (`ckptfp plan|simulate|best-period`)  ─┐
//!   experiments / in-process users            ─┼─▶ Executor::execute ─▶ model | batcher | sim pool
//!   TCP service (JSONL v2, v1 adapter)        ─┘        ▲
//!   remote callers ── ServiceClient ── wire ────────────┘
//! ```
//!
//! so local and remote execution share one code path, and a `Simulate`
//! job served over TCP is bit-identical to the same replication run
//! in-process (pinned in `tests/test_api.rs`).
//!
//! Submodules:
//!
//! * [`types`] — `JobRequest` / `JobResponse` / `ApiError`;
//! * [`wire`] — the versioned JSONL v2 encoding and the v1 adapter
//!   (documented with examples in `docs/PROTOCOL.md`);
//! * [`Executor`] — job execution (HLO batcher when attached, analytic
//!   fallback; simulation on the worker pool with session reuse);
//! * [`ServiceClient`] — blocking typed TCP client.

mod client;
mod exec;
pub mod types;
pub mod wire;

pub use client::{client_retries, ClientConfig, ServiceClient};
pub use exec::{Executor, ExecutorConfig};
pub use types::*;
