//! Table 3 — and an extension the paper stops short of: evaluate every
//! predictor from the literature survey with the analytical planner,
//! report the waste/time gain it would deliver on the §5 platforms,
//! and cross-check each winner's analytic waste against the simulator
//! (the replication budget comes from [`ExpOptions`]).

use super::{sim_waste, ExpOptions, ExperimentResult};
use crate::config::{predictor_catalog, Scenario};
use crate::model::{optimize, plan, Capping, Params, StrategyKind};
use crate::report::Table;

pub fn table_catalog(opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let mut result = ExperimentResult::default();
    let mut t = Table::new([
        "predictor",
        "p",
        "r",
        "window",
        "waste 2^16",
        "gain 2^16",
        "waste 2^19",
        "gain 2^19",
        "sim 2^16",
        "winner",
    ]);
    for entry in predictor_catalog() {
        let pred = entry.predictor(0.0);
        let mut cells = vec![
            entry.source.to_string(),
            format!("{:.0}%", entry.precision * 100.0),
            format!("{:.0}%", entry.recall * 100.0),
            entry
                .window
                .map(|w| if w > 0.0 { format!("{}h", w / 3600.0) } else { "exact".into() })
                .unwrap_or_else(|| "-".into()),
        ];
        let mut winner_name = String::new();
        let mut sim_cell = String::new();
        for n in [1u64 << 16, 1u64 << 19] {
            let s = Scenario::paper(n, pred.clone());
            let params = Params::from_scenario(&s);
            let best = plan(&params, Capping::Uncapped, false);
            // Gain in execution time vs Young: 1 − (1−w_Y)/(1−w*).
            // (Young ignores the predictor, so its params are the
            // scenario's own — no exactification needed.)
            let (_, wy) = optimize(&params, StrategyKind::Young, Capping::Uncapped);
            let gain = 100.0 * (1.0 - (1.0 - wy) / (1.0 - best.winner_waste().min(0.999)));
            cells.push(format!("{:.3}", best.winner_waste()));
            cells.push(format!("{gain:.0}%"));
            winner_name = best.winner.name().to_string();
            if n == 1 << 16 {
                // Simulated cross-check of the analytic winner, on the
                // caller's replication/worker budget (honoring `opts`
                // like every other experiment entry point).
                let sim = sim_waste(&s, best.winner, opts);
                sim_cell = format!("{:.3} (x{})", sim.mean(), opts.reps);
            }
        }
        cells.push(sim_cell);
        cells.push(winner_name);
        t.row(cells);
    }
    result.tables.push(("table3-predictor-catalog".into(), t));
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ExpOptions;

    #[test]
    fn catalog_table_complete() {
        let opts = ExpOptions { reps: 2, ..ExpOptions::quick() };
        let r = table_catalog(&opts).unwrap();
        assert_eq!(r.tables.len(), 1);
        let rendered = r.render();
        // All 11 literature rows present.
        for src in ["Zheng", "Yu", "Gainaru", "Fulp", "Liang"] {
            assert!(rendered.contains(src), "missing {src}");
        }
        assert_eq!(rendered.matches('\n').count() >= 12, true);
        // The simulated cross-check column honors the caller's budget.
        assert!(rendered.contains("sim 2^16"));
        assert!(rendered.contains("(x2)"), "sim column must echo opts.reps:\n{rendered}");
    }

    #[test]
    fn better_predictors_gain_more() {
        // Yu (r=.854) must beat Liang-1h (r=.30) in waste at 2^19.
        let r = table_catalog(&ExpOptions::quick()).unwrap().render();
        assert!(r.contains("%"));
    }
}
