//! Summary statistics for replicated simulation experiments: mean,
//! standard deviation, standard error and normal-approximation
//! confidence intervals. (Substrate module: no external stats crate.)

/// Streaming summary via Welford's algorithm — numerically stable for
/// the long waste/makespan accumulations the experiment runner produces.
#[derive(Debug, Clone)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

/// `Default` must match [`Summary::new`] — a derived default would
/// zero `min`/`max` and pin the extrema of every aggregate built via
/// `..Default::default()` (e.g. `ReplicationAgg`) at 0.
impl Default for Summary {
    fn default() -> Self {
        Summary::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut s = Self::new();
        for x in iter {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.stddev() / (self.n as f64).sqrt() }
    }

    /// Half-width of the ~95% CI (normal approximation, z = 1.96).
    pub fn ci95(&self) -> f64 {
        1.96 * self.stderr()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge two summaries (parallel reduction from worker threads).
    pub fn merge(&self, other: &Summary) -> Summary {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * self.n as f64 * other.n as f64 / n as f64;
        Summary { n, mean, m2, min: self.min.min(other.min), max: self.max.max(other.max) }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.6} ± {:.6} (n={})", self.mean(), self.ci95(), self.n)
    }
}

/// Paired-difference aggregator for common-random-number comparisons:
/// Welford statistics over per-replication deltas `a - b`, alongside
/// the two marginal summaries.
///
/// When two candidates are replicated on the *same* traces (the
/// [`crate::trace::TraceBank`] replay discipline), their wastes are
/// strongly positively correlated, so the variance of the per-rep
/// difference is far below `var(a) + var(b)` — the paired CI
/// ([`PairedDiff::ci95_paired`]) is correspondingly narrower than the
/// unpaired one ([`PairedDiff::ci95_unpaired`]) at the same
/// replication count. The best-period pruning pass uses this to
/// separate candidates with a fraction of the replications an
/// independent-samples comparison would need.
#[derive(Debug, Clone, Default)]
pub struct PairedDiff {
    a: Summary,
    b: Summary,
    diff: Summary,
}

impl PairedDiff {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one replication's paired observations.
    pub fn push(&mut self, a: f64, b: f64) {
        self.a.push(a);
        self.b.push(b);
        self.diff.push(a - b);
    }

    pub fn count(&self) -> u64 {
        self.diff.count()
    }

    /// Mean of the per-replication differences `a - b`.
    pub fn mean_diff(&self) -> f64 {
        self.diff.mean()
    }

    pub fn mean_a(&self) -> f64 {
        self.a.mean()
    }

    pub fn mean_b(&self) -> f64 {
        self.b.mean()
    }

    /// 95% CI half-width of the mean difference, using the *paired*
    /// variance (the deltas' own spread).
    pub fn ci95_paired(&self) -> f64 {
        self.diff.ci95()
    }

    /// 95% CI half-width the same comparison would have if the two
    /// samples were treated as independent: `1.96 * sqrt(se_a^2 + se_b^2)`.
    pub fn ci95_unpaired(&self) -> f64 {
        let (sa, sb) = (self.a.stderr(), self.b.stderr());
        1.96 * (sa * sa + sb * sb).sqrt()
    }

    /// Merge a partial aggregator (parallel reduction).
    pub fn merge(&self, other: &PairedDiff) -> PairedDiff {
        PairedDiff {
            a: self.a.merge(&other.a),
            b: self.b.merge(&other.b),
            diff: self.diff.merge(&other.diff),
        }
    }
}

/// Exact percentile of a sample (linear interpolation); used by the
/// service latency metrics.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn mean_and_variance() {
        let s = Summary::from_iter([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!(approx_eq(s.mean(), 5.0, 1e-12));
        assert!(approx_eq(s.variance(), 32.0 / 7.0, 1e-12));
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let full = Summary::from_iter(xs.iter().copied());
        let a = Summary::from_iter(xs[..37].iter().copied());
        let b = Summary::from_iter(xs[37..].iter().copied());
        let merged = a.merge(&b);
        assert!(approx_eq(full.mean(), merged.mean(), 1e-12));
        assert!(approx_eq(full.variance(), merged.variance(), 1e-9));
        assert_eq!(full.count(), merged.count());
    }

    #[test]
    fn merge_with_empty() {
        let a = Summary::from_iter([1.0, 2.0]);
        let e = Summary::new();
        assert!(approx_eq(a.merge(&e).mean(), 1.5, 1e-12));
        assert!(approx_eq(e.merge(&a).mean(), 1.5, 1e-12));
    }

    #[test]
    fn empty_summary() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn default_matches_new() {
        // Regression: a derived Default once zeroed min/max, pinning
        // aggregate extrema at 0 for all-positive samples.
        let mut s = Summary::default();
        s.push(0.3);
        s.push(0.5);
        assert_eq!(s.min(), 0.3);
        assert_eq!(s.max(), 0.5);
        let mut neg = Summary::default();
        neg.push(-2.0);
        assert_eq!(neg.max(), -2.0);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!(approx_eq(percentile(&v, 0.5), 3.0, 1e-12));
        assert!(approx_eq(percentile(&v, 0.0), 1.0, 1e-12));
        assert!(approx_eq(percentile(&v, 1.0), 5.0, 1e-12));
        assert!(approx_eq(percentile(&v, 0.25), 2.0, 1e-12));
    }

    #[test]
    fn paired_diff_tracks_correlated_samples() {
        // a and b share a large common component; the paired CI must
        // collapse while the unpaired CI stays wide.
        let mut pd = PairedDiff::new();
        for i in 0..200 {
            let common = ((i * 37 % 101) as f64) / 101.0; // shared "trace" noise
            let a = 0.20 + common;
            let b = 0.18 + common;
            pd.push(a, b);
        }
        assert_eq!(pd.count(), 200);
        assert!(approx_eq(pd.mean_diff(), 0.02, 1e-12));
        assert!(approx_eq(pd.mean_a() - pd.mean_b(), pd.mean_diff(), 1e-12));
        // The deltas are constant here, so the paired CI is ~0 while
        // the unpaired one sees the full common-component variance.
        assert!(pd.ci95_paired() < 1e-9, "paired {}", pd.ci95_paired());
        assert!(pd.ci95_unpaired() > 0.01, "unpaired {}", pd.ci95_unpaired());
    }

    #[test]
    fn paired_diff_merge_matches_sequential() {
        let xs: Vec<(f64, f64)> =
            (0..60).map(|i| ((i as f64).sin(), (i as f64).cos())).collect();
        let mut full = PairedDiff::new();
        for &(a, b) in &xs {
            full.push(a, b);
        }
        let mut left = PairedDiff::new();
        let mut right = PairedDiff::new();
        for &(a, b) in &xs[..23] {
            left.push(a, b);
        }
        for &(a, b) in &xs[23..] {
            right.push(a, b);
        }
        let merged = left.merge(&right);
        assert_eq!(merged.count(), full.count());
        assert!(approx_eq(merged.mean_diff(), full.mean_diff(), 1e-12));
        assert!(approx_eq(merged.ci95_paired(), full.ci95_paired(), 1e-12));
        assert!(approx_eq(merged.ci95_unpaired(), full.ci95_unpaired(), 1e-12));
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::from_iter((0..10).map(|i| i as f64 % 2.0));
        let b = Summary::from_iter((0..1000).map(|i| i as f64 % 2.0));
        assert!(b.ci95() < a.ci95());
    }
}
