//! The job-surface contract: wire round-trips for every request and
//! response variant, v1 back-compat, structured error shapes, and the
//! live-service acceptance pins — a v1 plan and its v2 equivalent
//! answer identically, and a `Simulate` job served over TCP reproduces
//! the in-process pool run bit for bit. None of this needs the PJRT
//! backend: the executor falls back to the closed-form planner.

use ckptfp::api::{
    wire, ApiError, BestPeriodJob, ErrorCode, Executor, ExecutorConfig, JobRequest, JobResponse,
    PlanJob, ServiceClient, SimulateJob, SweepJob,
};
use ckptfp::api::{BatcherSnapshot, BestPeriodOutcome, PlanResult, ServiceStats, SimulateResult, SweepResult, SweepRow};
use ckptfp::config::{Predictor, Scenario};
use ckptfp::coordinator::{serve, PlannerClient, ServiceConfig, ServiceHandle};
use ckptfp::dist::DistSpec;
use ckptfp::experiments::{replicate_stat, scenario_for};
use ckptfp::model::{Capping, StrategyKind};
use ckptfp::sim::Outcome;
use ckptfp::strategies::{spec_for, PolicySpec};
use ckptfp::util::json::Json;

fn small_scenario() -> Scenario {
    let mut s = Scenario::paper(1 << 16, Predictor::exact(0.85, 0.82));
    s.fault_dist = DistSpec::Exp;
    s.work = 2.0e5;
    s
}

fn start_local_service() -> (ServiceHandle, String) {
    let executor = Executor::new(ExecutorConfig { reps_default: 4, ..Default::default() });
    let handle = serve(
        executor,
        ServiceConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

// ---------------------------------------------------------------------------
// Wire round-trips
// ---------------------------------------------------------------------------

#[test]
fn every_request_variant_round_trips() {
    let s = small_scenario();
    let requests = vec![
        JobRequest::Plan(PlanJob { scenario: s.clone(), capping: Capping::Capped, policy: None }),
        JobRequest::Plan(PlanJob {
            scenario: s.clone(),
            capping: Capping::Uncapped,
            policy: Some(PolicySpec::Strategy(StrategyKind::NoCkptI)),
        }),
        JobRequest::Plan(PlanJob::new(s.clone())),
        JobRequest::Simulate(SimulateJob {
            scenario: s.clone(),
            strategy: StrategyKind::NoCkptI,
            reps: 17,
            workers: Some(3),
            policy: None,
            platform: None,
        }),
        JobRequest::Simulate(SimulateJob {
            scenario: s.clone(),
            strategy: StrategyKind::Young,
            reps: 5,
            workers: None,
            policy: Some(PolicySpec::RiskThreshold { kappa: 2.5 }),
            platform: None,
        }),
        JobRequest::Simulate(SimulateJob::new(s.clone(), StrategyKind::Young)),
        JobRequest::BestPeriod(BestPeriodJob {
            scenario: s.clone(),
            strategy: StrategyKind::Migration,
            reps: 9,
            candidates: 12,
            workers: None,
            prune: true,
            policy: None,
            platform: None,
        }),
        JobRequest::BestPeriod(BestPeriodJob {
            scenario: s.clone(),
            strategy: StrategyKind::Young,
            reps: 3,
            candidates: 4,
            workers: Some(2),
            prune: false,
            policy: Some(PolicySpec::AdaptivePeriod { gain: 0.75 }),
            platform: None,
        }),
        JobRequest::Sweep(SweepJob {
            base: s.clone(),
            n_procs: vec![1 << 14, 1 << 16, 1 << 19],
            capping: Capping::Uncapped,
        }),
        JobRequest::Stats,
        JobRequest::Ping,
    ];
    for req in requests {
        let line = wire::encode_request(&req);
        let decoded = wire::decode_request(&line)
            .unwrap_or_else(|e| panic!("decode of {line} failed: {e}"));
        assert!(!decoded.legacy, "v2 encoding must not decode as legacy");
        assert_eq!(decoded.request, req, "round-trip of {line}");
    }
}

#[test]
fn scenario_with_all_fields_round_trips() {
    // Window predictor, explicit ef, distinct false-prediction law —
    // every field must survive the wire exactly.
    let mut s = Scenario::paper(1 << 19, Predictor::windowed(0.7, 0.4, 3000.0));
    s.predictor.ef = 1000.0; // not the window/2 default
    s.fault_dist = DistSpec::weibull(0.5);
    s.false_pred_dist = Some(DistSpec::Uniform);
    s.alpha = 0.3;
    s.migration = 450.0;
    s.seed = 123456789;
    let req = JobRequest::Plan(PlanJob::new(s));
    let decoded = wire::decode_request(&wire::encode_request(&req)).unwrap();
    assert_eq!(decoded.request, req);
}

#[test]
fn every_response_variant_round_trips() {
    let responses = vec![
        JobResponse::Plan(PlanResult {
            waste: [0.2, 0.1, 0.12, 0.13, 0.14, 0.09],
            period: [1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            winner: StrategyKind::Migration,
            winner_waste: 0.09,
            winner_period: 6.0,
            q: 1,
            via_hlo: false,
        }),
        JobResponse::Simulate(SimulateResult {
            strategy: "NoCkptI".into(),
            reps: 40,
            workers: 4,
            mean_waste: 0.123456789012345,
            waste_ci95: 0.01,
            mean_makespan: 1.0e7,
            completion_rate: 1.0,
            n_faults: 321,
            n_preds: 200,
            n_ckpts: 1000,
            n_proactive_ckpts: 55,
            sim_seconds: 1.25,
        }),
        JobResponse::BestPeriod(BestPeriodOutcome {
            strategy: "Young".into(),
            t_r: 8123.4,
            waste: 0.117,
            n_pruned: 3,
            sweep: vec![(1000.0, 0.2), (2000.0, 0.15), (4000.0, 0.117)],
            reps: 10,
            candidates: 3,
            workers: 8,
            reps_used: 24,
        }),
        JobResponse::Sweep(SweepResult {
            rows: vec![
                SweepRow {
                    n_procs: 1 << 16,
                    mu: 60133.0,
                    winner: StrategyKind::ExactPrediction,
                    winner_waste: 0.11,
                    winner_period: 9000.0,
                },
                SweepRow {
                    n_procs: 1 << 19,
                    mu: 7516.0,
                    winner: StrategyKind::Young,
                    winner_waste: 0.4,
                    winner_period: 3000.0,
                },
            ],
            via_hlo: false,
        }),
        JobResponse::Stats(ServiceStats {
            requests: 10,
            errors: 2,
            plans: 3,
            simulates: 4,
            best_periods: 1,
            sweeps: 0,
            verifies: 2,
            lat_p50_s: 0.001,
            lat_p95_s: 0.01,
            lat_p99_s: 0.02,
            lat_n: 8,
            banks_built: 2,
            bank_replays: 1536,
            bank_fallbacks: 3,
            bank_bytes_resident: 1 << 20,
            rejected_overloaded: 5,
            deadline_exceeded: 1,
            panics_contained: 2,
            client_retries: 7,
            batch_lanes_run: 1024,
            batch_lane_fallbacks: 2,
            wide_lanes_run: 2048,
            wide_evictions: 3,
            cache_hits: 6,
            cache_misses: 4,
            cache_evictions: 1,
            cache_entries: 3,
            batcher: Some(BatcherSnapshot { requests: 3, batches: 1, max_batch: 3 }),
        }),
        JobResponse::Stats(ServiceStats::default()),
        JobResponse::Pong,
        JobResponse::Error(ApiError::bad_request("work must be positive")),
    ];
    for resp in responses {
        let line = wire::encode_response(&resp, false);
        let decoded = wire::decode_response(&line)
            .unwrap_or_else(|e| panic!("decode of {line} failed: {e}"));
        assert_eq!(decoded, resp, "round-trip of {line}");
    }
}

// ---------------------------------------------------------------------------
// v1 back-compat + error shapes
// ---------------------------------------------------------------------------

#[test]
fn policy_field_is_additive_and_optional() {
    // A hand-written v2 simulate with a policy and no strategy decodes
    // (the strategy field is only required on the classic path).
    let d = wire::decode_request(
        r#"{"v": 2, "op": "simulate", "scenario": {"work": 200000, "fault_dist": "exp"}, "policy": "risk:2", "reps": 5}"#,
    )
    .unwrap();
    match d.request {
        JobRequest::Simulate(job) => {
            assert_eq!(job.policy, Some(PolicySpec::RiskThreshold { kappa: 2.0 }));
            assert_eq!(job.reps, 5);
        }
        other => panic!("wrong request: {other:?}"),
    }
    // best_period takes the same field.
    let d = wire::decode_request(
        r#"{"v": 2, "op": "best_period", "scenario": {}, "policy": "adaptive"}"#,
    )
    .unwrap();
    match d.request {
        JobRequest::BestPeriod(job) => {
            assert_eq!(job.policy, Some(PolicySpec::AdaptivePeriod { gain: 1.0 }))
        }
        other => panic!("wrong request: {other:?}"),
    }
    // A bad policy spec is a bad_request naming the offender.
    let err = wire::decode_request(
        r#"{"v": 2, "op": "simulate", "scenario": {}, "policy": "bogus"}"#,
    )
    .unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("bogus"), "{}", err.message);
    // Without either strategy or policy, simulate still demands one.
    let err =
        wire::decode_request(r#"{"v": 2, "op": "simulate", "scenario": {}}"#).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("strategy"), "{}", err.message);
}

#[test]
fn v1_plan_request_decodes_through_the_adapter() {
    let d = wire::decode_request(
        r#"{"mu": 60000, "recall": 0.85, "precision": 0.82, "window": 300}"#,
    )
    .unwrap();
    assert!(d.legacy);
    match d.request {
        JobRequest::Plan(job) => {
            assert_eq!(job.scenario.platform.n_procs, 1);
            assert!((job.scenario.mu() - 60000.0).abs() < 1e-9);
            assert_eq!(job.scenario.predictor.recall, 0.85);
            assert_eq!(job.scenario.predictor.window, 300.0);
            assert_eq!(job.scenario.predictor.ef, 150.0); // window/2 default
            assert_eq!(job.scenario.platform.c, 600.0);
            assert_eq!(job.capping, Capping::Uncapped);
        }
        other => panic!("wrong request: {other:?}"),
    }
    // Bare verbs decode too, flagged legacy.
    assert!(matches!(
        wire::decode_request(r#"{"op": "ping"}"#).unwrap(),
        wire::Decoded { request: JobRequest::Ping, legacy: true }
    ));
    assert!(matches!(
        wire::decode_request(r#"{"op": "stats"}"#).unwrap().request,
        JobRequest::Stats
    ));
}

#[test]
fn v1_degenerate_predictor_is_accepted() {
    // recall = 0, precision = 0: the no-predictor case `Predictor::
    // validate` allows — the wire must not be stricter (satellite fix).
    let d = wire::decode_request(r#"{"mu": 60000, "recall": 0, "precision": 0}"#).unwrap();
    match d.request {
        JobRequest::Plan(job) => {
            assert_eq!(job.scenario.predictor.recall, 0.0);
            assert_eq!(job.scenario.predictor.precision, 0.0);
        }
        other => panic!("wrong request: {other:?}"),
    }
}

#[test]
fn decode_errors_carry_machine_readable_codes() {
    let cases: Vec<(&str, ErrorCode)> = vec![
        ("this is not json", ErrorCode::InvalidJson),
        ("[1, 2, 3]", ErrorCode::BadRequest),
        (r#"{"v": 3, "op": "plan"}"#, ErrorCode::UnsupportedVersion),
        (r#"{"v": 2, "op": "destroy"}"#, ErrorCode::UnknownOp),
        (r#"{"v": 2}"#, ErrorCode::UnknownOp),
        (r#"{"op": "destroy"}"#, ErrorCode::UnknownOp),
        (r#"{"v": 2, "op": "plan"}"#, ErrorCode::BadRequest), // missing scenario
        (r#"{"v": 2, "op": "simulate", "scenario": {"work": -1}}"#, ErrorCode::BadRequest),
        (
            r#"{"v": 2, "op": "simulate", "scenario": {}, "strategy": "Daly"}"#,
            ErrorCode::BadRequest,
        ),
        (
            r#"{"v": 2, "op": "plan", "scenario": {"fault_dist": "bogus"}}"#,
            ErrorCode::BadRequest,
        ),
        (r#"{"mu": -5}"#, ErrorCode::BadRequest), // v1 adapter validation
    ];
    for (line, code) in cases {
        let err = wire::decode_request(line).unwrap_err();
        assert_eq!(err.code, code, "line {line} -> {err}");
        // The error encodes to the wire shape both dialects can read.
        let encoded = wire::encode_response(&JobResponse::Error(err), false);
        let v = ckptfp::util::json::parse(&encoded).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(v.get("code").and_then(Json::as_str), Some(code.as_str()));
        assert!(v.get("error").is_some());
    }
}

#[test]
fn legacy_responses_keep_the_v1_shape() {
    // Stats, legacy dialect: the original top-level planner counters
    // survive (requests = batcher plan count, batches, max_batch).
    let stats = JobResponse::Stats(ServiceStats {
        requests: 10,
        errors: 1,
        plans: 3,
        batcher: Some(BatcherSnapshot { requests: 3, batches: 2, max_batch: 2 }),
        ..Default::default()
    });
    let v = ckptfp::util::json::parse(&wire::encode_response(&stats, true)).unwrap();
    assert!(v.get("v").is_none());
    assert_eq!(v.num_or("requests", -1.0), 3.0, "legacy requests = batcher plan count");
    assert_eq!(v.num_or("batches", -1.0), 2.0);
    assert_eq!(v.num_or("max_batch", -1.0), 2.0);
    assert!(v.get("job").is_none());

    // Error replies to a failed v1 line use the legacy shape too.
    assert!(wire::line_is_legacy(r#"{"mu": -5}"#));
    assert!(wire::line_is_legacy(r#"{"op": "destroy"}"#));
    assert!(!wire::line_is_legacy(r#"{"v": 2, "op": "destroy"}"#));
    assert!(!wire::line_is_legacy("not json"));
    let err = wire::decode_request(r#"{"mu": -5}"#).unwrap_err();
    let v = ckptfp::util::json::parse(&wire::encode_response(&JobResponse::Error(err), true)).unwrap();
    assert!(v.get("v").is_none());
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert!(v.get("error").is_some());
}

// ---------------------------------------------------------------------------
// Live service
// ---------------------------------------------------------------------------

/// Acceptance pin: a v1 `{"op":"plan",...}` request and its v2
/// `JobRequest::Plan` equivalent return identical plan payloads from
/// the same service.
#[test]
fn v1_and_v2_plan_payloads_are_identical() {
    let (handle, addr) = start_local_service();
    let mut client = PlannerClient::connect(&addr).unwrap();
    let v1 = client
        .call(r#"{"mu": 60000, "recall": 0.85, "precision": 0.82, "window": 300}"#)
        .unwrap();
    let v2 = client
        .call(
            r#"{"v": 2, "op": "plan", "scenario": {"n_procs": 1, "mu": 60000, "recall": 0.85, "precision": 0.82, "window": 300}}"#,
        )
        .unwrap();
    assert_eq!(v1.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(v2.get("ok").and_then(Json::as_bool), Some(true));
    // Dialect markers differ...
    assert!(v1.get("v").is_none());
    assert_eq!(v2.num_or("v", 0.0), 2.0);
    assert_eq!(v2.get("job").and_then(Json::as_str), Some("plan"));
    // ...the plan payload must not.
    for field in ["winner", "q", "winner_waste", "winner_period", "strategies"] {
        assert_eq!(v1.get(field), v2.get(field), "payload field '{field}' diverges");
    }
    handle.stop();
}

/// Acceptance pin: a v2 `Simulate` job served over TCP reproduces the
/// in-process pool replication bit for bit for the same
/// (scenario, strategy, seed, reps, workers).
#[test]
fn simulate_over_tcp_is_bit_identical_to_in_process() {
    let (handle, addr) = start_local_service();
    let scenario = small_scenario();
    let strategy = StrategyKind::ExactPrediction;
    let (reps, workers) = (6u64, 2u64);

    let mut client = ServiceClient::connect(&addr).unwrap();
    let served = client
        .simulate(SimulateJob {
            scenario: scenario.clone(),
            strategy,
            reps,
            workers: Some(workers),
            policy: None,
            platform: None,
        })
        .unwrap();

    let s = scenario_for(strategy, &scenario);
    let spec = spec_for(strategy, &s, Capping::Uncapped);
    let local = replicate_stat(&s, &spec, reps, workers as usize, Outcome::waste);

    assert_eq!(served.reps, reps);
    assert_eq!(served.workers, workers);
    assert_eq!(
        served.mean_waste.to_bits(),
        local.mean().to_bits(),
        "served {} vs local {}",
        served.mean_waste,
        local.mean()
    );
    handle.stop();
}

#[test]
fn concurrent_clients_simulate_against_one_service() {
    let (handle, addr) = start_local_service();
    let n_clients = 8;
    let results: Vec<SimulateResult> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|_| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut client = ServiceClient::connect(&addr).unwrap();
                    client
                        .simulate(SimulateJob {
                            scenario: small_scenario(),
                            strategy: StrategyKind::Young,
                            reps: 4,
                            workers: Some(2),
                            policy: None,
                            platform: None,
                        })
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Identical jobs are deterministic — every client sees the same
    // aggregate, regardless of interleaving. (`sim_seconds` is
    // wall-clock and excluded from the comparison.)
    for r in &results[1..] {
        let mut a = r.clone();
        let mut b = results[0].clone();
        a.sim_seconds = 0.0;
        b.sim_seconds = 0.0;
        assert_eq!(a, b);
    }
    assert!(results[0].n_faults > 0);
    assert_eq!(results[0].completion_rate, 1.0);

    let mut client = ServiceClient::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.simulates, n_clients as u64);
    assert!(stats.requests >= n_clients as u64);
    assert!(stats.batcher.is_none(), "local service has no batcher");
    handle.stop();
}

#[test]
fn typed_client_runs_plan_best_period_and_sweep() {
    let (handle, addr) = start_local_service();
    let mut client = ServiceClient::connect(&addr).unwrap();

    let plan = client.plan(PlanJob::new(small_scenario())).unwrap();
    assert!(!plan.via_hlo);
    assert!(plan.winner_waste > 0.0 && plan.winner_waste < 1.0);

    let bp = client
        .best_period(BestPeriodJob {
            scenario: small_scenario(),
            strategy: StrategyKind::Young,
            reps: 4,
            candidates: 6,
            workers: Some(2),
            prune: false,
            policy: None,
            platform: None,
        })
        .unwrap();
    assert_eq!(bp.sweep.len(), 6);
    assert!(bp.t_r > 0.0 && bp.waste > 0.0);
    assert!(bp.sweep.iter().any(|&(t, w)| t == bp.t_r && w == bp.waste));

    let sweep = client
        .sweep(SweepJob {
            base: small_scenario(),
            n_procs: vec![1 << 16, 1 << 19],
            capping: Capping::Uncapped,
        })
        .unwrap();
    assert_eq!(sweep.rows.len(), 2);
    assert!(sweep.rows[0].winner_waste < sweep.rows[1].winner_waste);

    client.ping().unwrap();

    // Server-side failures surface as typed errors through the client.
    let mut bad = small_scenario();
    bad.work = -1.0;
    let err = client.plan(PlanJob::new(bad)).unwrap_err();
    let api_err = err.downcast_ref::<ApiError>().expect("typed ApiError");
    assert_eq!(api_err.code, ErrorCode::BadRequest);
    handle.stop();
}

/// Satellite fix: stopping a service bound to an unspecified address
/// must not hang — the shutdown nudge targets loopback.
#[test]
fn stop_works_when_bound_to_unspecified_address() {
    let executor = Executor::new(ExecutorConfig::default());
    let handle = serve(
        executor,
        ServiceConfig { addr: "0.0.0.0:0".into(), ..Default::default() },
    )
    .unwrap();
    assert!(handle.addr.ip().is_unspecified());
    // Connectable via loopback even though 0.0.0.0 itself is not.
    let mut client = ServiceClient::connect(&format!("127.0.0.1:{}", handle.addr.port())).unwrap();
    client.ping().unwrap();
    handle.stop(); // would block forever before the loopback nudge
}
