//! JSONL wire encoding of the job surface — protocol **v2** — plus the
//! adapter that keeps the flat v1 planner dialect
//! ([`crate::coordinator::protocol`]) working on the same socket.
//!
//! One JSON object per line, both directions. Requests carry
//! `"v": 2` and an `"op"`; a line without `v` (or with `v = 1`) is
//! decoded through the v1 adapter and answered in the legacy response
//! shape, so pre-v2 clients never notice the redesign. Full examples
//! live in `docs/PROTOCOL.md`.
//!
//! Every decode failure is an [`ApiError`] with a machine-readable
//! code (`invalid_json`, `unsupported_version`, `unknown_op`,
//! `bad_request`), already shaped for the error response.

use super::types::*;
use crate::config::{Predictor, Scenario};
use crate::dist::DistSpec;
use crate::model::{Capping, StrategyKind};
use crate::strategies::PolicySpec;
use crate::util::json::{parse, Json};
use crate::verify::{self, GridKind};

/// The protocol version this build speaks natively.
pub const PROTOCOL_VERSION: f64 = 2.0;

/// Maximum accepted request-line length (bytes, excluding the newline).
/// Longer lines are rejected with `bad_request` before any parsing —
/// a guard against hostile or broken peers streaming unbounded bytes
/// into the decoder. 1 MiB is ~100x the largest legitimate job line.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// A decoded request plus the dialect it arrived in: legacy (v1)
/// requests must be answered in the legacy response shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Decoded {
    pub request: JobRequest,
    pub legacy: bool,
}

/// Additive v2 envelope fields the *service* cares about but the job
/// itself does not: the tenant a request bills to (fair scheduling)
/// and whether the caller opted into streaming partial-result frames.
/// Kept out of [`Decoded`] so its two-field shape (exhaustively
/// matched by clients and tests) never changes.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RequestMeta {
    /// `"tenant"`: queue/billing identity; `None` = the default
    /// tenant. Validated to 1..=64 bytes when present.
    pub tenant: Option<String>,
    /// `"stream"`: ask for partial-result frames on sweep/verify.
    /// Ignored (harmlessly) on every other op.
    pub stream: bool,
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Decode one request line (either dialect), dropping the service
/// envelope. Typed clients and tests use this; the service itself
/// uses [`decode_request_meta`].
pub fn decode_request(line: &str) -> Result<Decoded, ApiError> {
    decode_request_meta(line).map(|(decoded, _)| decoded)
}

/// Envelope fields of an already-parsed v2 request object. Validation
/// runs *after* op dispatch so op-level errors keep their pre-envelope
/// shapes.
fn meta_from_json(v: &Json) -> Result<RequestMeta, ApiError> {
    let tenant = match v.get("tenant") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => {
            if s.is_empty() || s.len() > 64 {
                return Err(ApiError::bad_request(
                    "tenant must be a string of 1 to 64 bytes",
                ));
            }
            Some(s.clone())
        }
        Some(_) => return Err(ApiError::bad_request("tenant must be a string")),
    };
    let stream = match v.get("stream") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err(ApiError::bad_request("stream must be a boolean")),
    };
    Ok(RequestMeta { tenant, stream })
}

/// Decode one request line plus its service envelope ([`RequestMeta`]).
/// v1 lines get the default envelope: no tenant, no streaming.
pub fn decode_request_meta(line: &str) -> Result<(Decoded, RequestMeta), ApiError> {
    if line.len() > MAX_LINE_BYTES {
        return Err(ApiError::bad_request(format!(
            "request line of {} bytes exceeds the {} byte limit",
            line.len(),
            MAX_LINE_BYTES
        )));
    }
    let v = parse(line).map_err(|e| ApiError::invalid_json(format!("{e:#}")))?;
    if !matches!(v, Json::Obj(_)) {
        return Err(ApiError::bad_request("request must be a JSON object"));
    }
    let version = v.num_or("v", 1.0);
    if version == 1.0 {
        return Ok((
            Decoded { request: decode_v1(&v)?, legacy: true },
            RequestMeta::default(),
        ));
    }
    if version != PROTOCOL_VERSION {
        return Err(ApiError::new(
            ErrorCode::UnsupportedVersion,
            format!("protocol version {version} not supported (this build speaks v1 and v2)"),
        ));
    }
    let op = match v.get("op").and_then(Json::as_str) {
        Some(op) => op,
        None => return Err(ApiError::unknown_op("<missing>")),
    };
    let request = match op {
        "plan" => JobRequest::Plan(PlanJob {
            scenario: scenario_from_json(require(&v, "scenario")?)?,
            capping: capping_from_json(&v),
            policy: policy_from_json(&v)?,
        }),
        "simulate" => {
            let policy = policy_from_json(&v)?;
            JobRequest::Simulate(SimulateJob {
                scenario: scenario_from_json(require(&v, "scenario")?)?,
                strategy: strategy_from_json(&v, policy.is_some())?,
                reps: u64_or(&v, "reps", 0),
                workers: opt_u64(&v, "workers"),
                policy,
                platform: platform_from_json(&v)?,
            })
        }
        "best_period" | "best-period" => {
            let policy = policy_from_json(&v)?;
            JobRequest::BestPeriod(BestPeriodJob {
                scenario: scenario_from_json(require(&v, "scenario")?)?,
                strategy: strategy_from_json(&v, policy.is_some())?,
                reps: u64_or(&v, "reps", 0),
                candidates: u64_or(&v, "candidates", 0),
                workers: opt_u64(&v, "workers"),
                prune: v.get("prune").and_then(Json::as_bool).unwrap_or(false),
                policy,
                platform: platform_from_json(&v)?,
            })
        }
        "sweep" => {
            let n_procs = match v.get("n_procs") {
                Some(Json::Arr(xs)) => xs
                    .iter()
                    .map(|x| x.as_f64().map(|f| f as u64))
                    .collect::<Option<Vec<u64>>>()
                    .ok_or_else(|| ApiError::bad_request("sweep n_procs must be numbers"))?,
                _ => return Err(ApiError::bad_request("sweep needs an 'n_procs' array")),
            };
            JobRequest::Sweep(SweepJob {
                base: scenario_from_json(require(&v, "scenario")?)?,
                n_procs,
                capping: capping_from_json(&v),
            })
        }
        "verify" => {
            let grid = match v.get("grid").and_then(Json::as_str) {
                None => GridKind::Quick,
                Some(g) => g.parse::<GridKind>().map_err(ApiError::from_invalid)?,
            };
            JobRequest::Verify(VerifyJob {
                grid,
                policy: policy_from_json(&v)?,
                reps: u64_or(&v, "reps", 0),
                budget: u64_or(&v, "budget", 0),
                workers: opt_u64(&v, "workers"),
                platform: platform_from_json(&v)?,
            })
        }
        "stats" => JobRequest::Stats,
        "ping" => JobRequest::Ping,
        other => return Err(ApiError::unknown_op(other)),
    };
    Ok((Decoded { request, legacy: false }, meta_from_json(&v)?))
}

/// Dialect sniff for lines that failed [`decode_request`]: a
/// parseable object without `"v": 2` is the legacy dialect, so its
/// error reply must use the legacy shape. Unparseable lines have no
/// dialect and get the v2 error shape.
pub fn line_is_legacy(line: &str) -> bool {
    match parse(line) {
        Ok(v @ Json::Obj(_)) => v.num_or("v", 1.0) == 1.0,
        _ => false,
    }
}

/// The v1 adapter: flat planner-dialect fields become a one-processor
/// [`Scenario`] whose platform MTBF is the request's `mu`. Parsing and
/// validation are delegated to [`crate::coordinator::protocol`] so the
/// two dialects cannot drift.
fn decode_v1(v: &Json) -> Result<JobRequest, ApiError> {
    use crate::coordinator::protocol::{parse_request, Request};
    // Re-serialize the already-parsed object rather than re-parsing the
    // raw line: byte-level concerns stay in one place.
    let req = parse_request(&v.to_string()).map_err(|e| {
        let msg = format!("{e:#}");
        if msg.contains("unknown op") {
            ApiError::new(ErrorCode::UnknownOp, msg)
        } else {
            ApiError::bad_request(msg)
        }
    })?;
    Ok(match req {
        Request::Ping => JobRequest::Ping,
        Request::Stats => JobRequest::Stats,
        Request::Plan(p) => {
            let predictor =
                Predictor { recall: p.recall, precision: p.precision, window: p.i, ef: p.ef };
            let scenario = Scenario::builder()
                .n_procs(1)
                .mu(p.mu)
                .checkpoint(p.c)
                .downtime(p.d)
                .recovery(p.r_rec)
                .predictor(predictor)
                .alpha(p.alpha)
                .migration(p.m)
                .build()
                .map_err(ApiError::from_invalid)?;
            JobRequest::Plan(PlanJob { scenario, capping: Capping::Uncapped, policy: None })
        }
    })
}

/// Encode one request line (always v2).
pub fn encode_request(req: &JobRequest) -> String {
    let mut fields: Vec<(&str, Json)> = vec![
        ("v", Json::Num(PROTOCOL_VERSION)),
        ("op", Json::Str(req.op().into())),
    ];
    match req {
        JobRequest::Plan(job) => {
            fields.push(("scenario", scenario_to_json(&job.scenario)));
            fields.push(("capped", Json::Bool(job.capping == Capping::Capped)));
            if let Some(p) = &job.policy {
                fields.push(("policy", Json::Str(p.to_string())));
            }
        }
        JobRequest::Simulate(job) => {
            fields.push(("scenario", scenario_to_json(&job.scenario)));
            fields.push(("strategy", Json::Str(job.strategy.name().into())));
            fields.push(("reps", Json::Num(job.reps as f64)));
            if let Some(w) = job.workers {
                fields.push(("workers", Json::Num(w as f64)));
            }
            if let Some(p) = &job.policy {
                fields.push(("policy", Json::Str(p.to_string())));
            }
            if let Some(p) = &job.platform {
                fields.push(("platform", Json::Str(p.to_string())));
            }
        }
        JobRequest::BestPeriod(job) => {
            fields.push(("scenario", scenario_to_json(&job.scenario)));
            fields.push(("strategy", Json::Str(job.strategy.name().into())));
            fields.push(("reps", Json::Num(job.reps as f64)));
            fields.push(("candidates", Json::Num(job.candidates as f64)));
            if let Some(w) = job.workers {
                fields.push(("workers", Json::Num(w as f64)));
            }
            fields.push(("prune", Json::Bool(job.prune)));
            if let Some(p) = &job.policy {
                fields.push(("policy", Json::Str(p.to_string())));
            }
            if let Some(p) = &job.platform {
                fields.push(("platform", Json::Str(p.to_string())));
            }
        }
        JobRequest::Sweep(job) => {
            fields.push(("scenario", scenario_to_json(&job.base)));
            fields.push((
                "n_procs",
                Json::Arr(job.n_procs.iter().map(|&n| Json::Num(n as f64)).collect()),
            ));
            fields.push(("capped", Json::Bool(job.capping == Capping::Capped)));
        }
        JobRequest::Verify(job) => {
            fields.push(("grid", Json::Str(job.grid.name().into())));
            fields.push(("reps", Json::Num(job.reps as f64)));
            fields.push(("budget", Json::Num(job.budget as f64)));
            if let Some(w) = job.workers {
                fields.push(("workers", Json::Num(w as f64)));
            }
            if let Some(p) = &job.policy {
                fields.push(("policy", Json::Str(p.to_string())));
            }
            if let Some(p) = &job.platform {
                fields.push(("platform", Json::Str(p.to_string())));
            }
        }
        JobRequest::Stats | JobRequest::Ping => {}
    }
    Json::obj(fields).to_string()
}

/// Encode one request line with its service envelope: `tenant` and/or
/// `stream` ride along as additive v2 fields. With a default
/// [`RequestMeta`] this is byte-identical to [`encode_request`] (the
/// sorted-object encoding makes field *pushes* order-free).
pub fn encode_request_tagged(req: &JobRequest, meta: &RequestMeta) -> String {
    let bare = encode_request(req);
    if meta.tenant.is_none() && !meta.stream {
        return bare;
    }
    // Re-parse and extend rather than duplicating the field tables:
    // requests are encoded off the hot path.
    let mut v = parse(&bare).expect("encode_request emits valid JSON");
    if let Json::Obj(map) = &mut v {
        if let Some(t) = &meta.tenant {
            map.insert("tenant".into(), Json::Str(t.clone()));
        }
        if meta.stream {
            map.insert("stream".into(), Json::Bool(true));
        }
    }
    v.to_string()
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// Encode one response line. `legacy` selects the v1 shape (no `v` /
/// `job` markers — exactly what pre-v2 clients parse today).
pub fn encode_response(resp: &JobResponse, legacy: bool) -> String {
    encode_response_framed(resp, legacy, None)
}

/// Encode the **final frame** of a streamed response: the complete
/// standard v2 payload plus `"frame": "final"` and the frame sequence
/// number. Non-streamed responses never carry a `frame` field, so
/// their bytes are untouched by the streaming feature.
pub fn encode_stream_final(resp: &JobResponse, seq: u64) -> String {
    encode_response_framed(resp, false, Some(seq))
}

fn encode_response_framed(resp: &JobResponse, legacy: bool, final_seq: Option<u64>) -> String {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if let Some(seq) = final_seq {
        fields.push(("frame", Json::Str("final".into())));
        fields.push(("seq", Json::Num(seq as f64)));
    }
    if !legacy {
        fields.push(("v", Json::Num(PROTOCOL_VERSION)));
    }
    match resp {
        JobResponse::Error(e) => {
            fields.push(("ok", Json::Bool(false)));
            fields.push(("code", Json::Str(e.code.as_str().into())));
            fields.push(("error", Json::Str(e.message.clone())));
            // Additive in both dialects: only new codes carry it, so v1
            // response shapes for pre-existing errors are unchanged.
            if let Some(ms) = e.retry_after_ms {
                fields.push(("retry_after_ms", Json::Num(ms as f64)));
            }
        }
        JobResponse::Pong => {
            fields.push(("ok", Json::Bool(true)));
            if !legacy {
                fields.push(("job", Json::Str("ping".into())));
            }
            fields.push(("pong", Json::Bool(true)));
        }
        JobResponse::Plan(r) => {
            fields.push(("ok", Json::Bool(true)));
            if !legacy {
                fields.push(("job", Json::Str("plan".into())));
                fields.push((
                    "planner",
                    Json::Str(if r.via_hlo { "hlo" } else { "analytic" }.into()),
                ));
            }
            fields.extend(plan_payload(r));
        }
        JobResponse::Simulate(r) => {
            fields.push(("ok", Json::Bool(true)));
            if !legacy {
                fields.push(("job", Json::Str("simulate".into())));
            }
            fields.extend(vec![
                ("strategy", Json::Str(r.strategy.clone())),
                ("reps", Json::Num(r.reps as f64)),
                ("workers", Json::Num(r.workers as f64)),
                ("mean_waste", Json::Num(r.mean_waste)),
                ("waste_ci95", Json::Num(r.waste_ci95)),
                ("mean_makespan", Json::Num(r.mean_makespan)),
                ("completion_rate", Json::Num(r.completion_rate)),
                ("n_faults", Json::Num(r.n_faults as f64)),
                ("n_preds", Json::Num(r.n_preds as f64)),
                ("n_ckpts", Json::Num(r.n_ckpts as f64)),
                ("n_proactive_ckpts", Json::Num(r.n_proactive_ckpts as f64)),
                ("sim_seconds", Json::Num(r.sim_seconds)),
            ]);
        }
        JobResponse::BestPeriod(r) => {
            fields.push(("ok", Json::Bool(true)));
            if !legacy {
                fields.push(("job", Json::Str("best_period".into())));
            }
            fields.extend(vec![
                ("strategy", Json::Str(r.strategy.clone())),
                ("t_r", Json::Num(r.t_r)),
                ("waste", Json::Num(r.waste)),
                ("n_pruned", Json::Num(r.n_pruned as f64)),
                ("reps", Json::Num(r.reps as f64)),
                ("reps_used", Json::Num(r.reps_used as f64)),
                ("candidates", Json::Num(r.candidates as f64)),
                ("workers", Json::Num(r.workers as f64)),
                (
                    "sweep",
                    Json::Arr(
                        r.sweep
                            .iter()
                            .map(|&(t, w)| Json::Arr(vec![Json::Num(t), Json::Num(w)]))
                            .collect(),
                    ),
                ),
            ]);
        }
        JobResponse::Sweep(r) => {
            fields.push(("ok", Json::Bool(true)));
            if !legacy {
                fields.push(("job", Json::Str("sweep".into())));
            }
            fields.push((
                "planner",
                Json::Str(if r.via_hlo { "hlo" } else { "analytic" }.into()),
            ));
            fields.push(("rows", Json::Arr(r.rows.iter().map(sweep_row_json).collect())));
        }
        JobResponse::Verify(r) => {
            fields.push(("ok", Json::Bool(true)));
            if !legacy {
                fields.push(("job", Json::Str("verify".into())));
            }
            fields.extend(verify::report_fields(r));
        }
        JobResponse::Stats(s) => {
            fields.push(("ok", Json::Bool(true)));
            if legacy {
                // The v1 stats shape: top-level planner counters —
                // `requests` has always meant "plans that reached the
                // batcher", with `batches`/`max_batch` beside it. Keep
                // those fields (and their semantics) intact for pre-v2
                // monitoring clients; `errors` rides along as a purely
                // additive extra.
                let (req, batches, max_batch) = match &s.batcher {
                    Some(b) => (b.requests, b.batches, b.max_batch),
                    None => (s.requests, 0, 0),
                };
                fields.extend(vec![
                    ("requests", Json::Num(req as f64)),
                    ("batches", Json::Num(batches as f64)),
                    ("max_batch", Json::Num(max_batch as f64)),
                    ("errors", Json::Num(s.errors as f64)),
                    ("lat_p50_s", Json::Num(s.lat_p50_s)),
                    ("lat_p95_s", Json::Num(s.lat_p95_s)),
                    ("lat_p99_s", Json::Num(s.lat_p99_s)),
                    ("lat_n", Json::Num(s.lat_n as f64)),
                ]);
            } else {
                fields.push(("job", Json::Str("stats".into())));
                fields.extend(vec![
                    ("requests", Json::Num(s.requests as f64)),
                    ("errors", Json::Num(s.errors as f64)),
                    ("plans", Json::Num(s.plans as f64)),
                    ("simulates", Json::Num(s.simulates as f64)),
                    ("best_periods", Json::Num(s.best_periods as f64)),
                    ("sweeps", Json::Num(s.sweeps as f64)),
                    ("verifies", Json::Num(s.verifies as f64)),
                    ("lat_p50_s", Json::Num(s.lat_p50_s)),
                    ("lat_p95_s", Json::Num(s.lat_p95_s)),
                    ("lat_p99_s", Json::Num(s.lat_p99_s)),
                    ("lat_n", Json::Num(s.lat_n as f64)),
                    ("banks_built", Json::Num(s.banks_built as f64)),
                    ("bank_replays", Json::Num(s.bank_replays as f64)),
                    ("bank_fallbacks", Json::Num(s.bank_fallbacks as f64)),
                    ("bank_bytes_resident", Json::Num(s.bank_bytes_resident as f64)),
                    ("rejected_overloaded", Json::Num(s.rejected_overloaded as f64)),
                    ("deadline_exceeded", Json::Num(s.deadline_exceeded as f64)),
                    ("panics_contained", Json::Num(s.panics_contained as f64)),
                    ("client_retries", Json::Num(s.client_retries as f64)),
                    ("batch_lanes_run", Json::Num(s.batch_lanes_run as f64)),
                    ("batch_lane_fallbacks", Json::Num(s.batch_lane_fallbacks as f64)),
                    ("wide_lanes_run", Json::Num(s.wide_lanes_run as f64)),
                    ("wide_evictions", Json::Num(s.wide_evictions as f64)),
                    ("cache_hits", Json::Num(s.cache_hits as f64)),
                    ("cache_misses", Json::Num(s.cache_misses as f64)),
                    ("cache_evictions", Json::Num(s.cache_evictions as f64)),
                    ("cache_entries", Json::Num(s.cache_entries as f64)),
                ]);
                if let Some(b) = &s.batcher {
                    fields.push((
                        "batcher",
                        Json::obj(vec![
                            ("requests", Json::Num(b.requests as f64)),
                            ("batches", Json::Num(b.batches as f64)),
                            ("max_batch", Json::Num(b.max_batch as f64)),
                        ]),
                    ));
                }
            }
        }
    }
    Json::obj(fields).to_string()
}

/// One sweep row as it appears in the `rows` array — and, verbatim,
/// as the `item` of a streamed partial frame (one encoder, so the two
/// shapes cannot diverge).
fn sweep_row_json(row: &SweepRow) -> Json {
    Json::obj(vec![
        ("n_procs", Json::Num(row.n_procs as f64)),
        ("mu", Json::Num(row.mu)),
        ("winner", Json::Str(row.winner.name().into())),
        ("winner_waste", Json::Num(row.winner_waste)),
        ("winner_period", Json::Num(row.winner_period)),
    ])
}

// ---------------------------------------------------------------------------
// Streaming frames (additive v2)
// ---------------------------------------------------------------------------

/// Encode one **partial frame** of a streamed response:
/// `{"v":2,"ok":true,"frame":"partial","job":...,"seq":k,"item":{...}}`.
/// `item` is one element of the final response's own array (a sweep
/// row, a verify case) — byte-identical to how it appears there.
pub fn encode_stream_partial(job: &str, seq: u64, item: Json) -> String {
    Json::obj(vec![
        ("v", Json::Num(PROTOCOL_VERSION)),
        ("ok", Json::Bool(true)),
        ("frame", Json::Str("partial".into())),
        ("job", Json::Str(job.into())),
        ("seq", Json::Num(seq as f64)),
        ("item", item),
    ])
    .to_string()
}

/// The per-item payloads a response yields as partial frames before
/// its final frame: sweep rows and verify cases. `None` marks the
/// response non-streamable — the service answers it as a single
/// ordinary line even when the caller asked to stream.
pub fn stream_items(resp: &JobResponse) -> Option<(&'static str, Vec<Json>)> {
    match resp {
        JobResponse::Sweep(r) => Some(("sweep", r.rows.iter().map(sweep_row_json).collect())),
        JobResponse::Verify(r) => {
            let items = verify::report_fields(r)
                .into_iter()
                .find_map(|(k, v)| match (k, v) {
                    ("cases", Json::Arr(xs)) => Some(xs),
                    _ => None,
                })
                .unwrap_or_default();
            Some(("verify", items))
        }
        _ => None,
    }
}

/// One decoded line of a streamed exchange.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// A partial frame: one item of the in-progress response.
    Partial { job: String, seq: u64, item: Json },
    /// The final frame (or a plain, unframed response — every
    /// non-streamed line decodes as `Final { seq: None, .. }`).
    Final { seq: Option<u64>, response: JobResponse },
}

/// Decode one line of a streamed exchange. Hostile frames (a `frame`
/// marker that is not `"partial"`/`"final"`, a partial missing its
/// `seq` or `item`) are structured errors, not panics.
pub fn decode_stream_event(line: &str) -> Result<StreamEvent, ApiError> {
    let v = parse(line).map_err(|e| ApiError::invalid_json(format!("{e:#}")))?;
    match v.get("frame") {
        Some(Json::Str(f)) if f == "partial" => {
            let job = v
                .get("job")
                .and_then(Json::as_str)
                .ok_or_else(|| ApiError::bad_request("partial frame missing 'job'"))?
                .to_string();
            let seq = opt_u64(&v, "seq")
                .ok_or_else(|| ApiError::bad_request("partial frame missing 'seq'"))?;
            let item = v
                .get("item")
                .cloned()
                .ok_or_else(|| ApiError::bad_request("partial frame missing 'item'"))?;
            Ok(StreamEvent::Partial { job, seq, item })
        }
        Some(Json::Str(f)) if f == "final" => Ok(StreamEvent::Final {
            seq: opt_u64(&v, "seq"),
            response: decode_response(line)?,
        }),
        Some(_) => Err(ApiError::bad_request(
            "frame must be the string \"partial\" or \"final\"",
        )),
        None => Ok(StreamEvent::Final { seq: None, response: decode_response(line)? }),
    }
}

/// The plan payload fields shared by both dialects — one builder so the
/// v1 and v2 shapes cannot diverge (acceptance-pinned in
/// `tests/test_api.rs`).
fn plan_payload(r: &PlanResult) -> Vec<(&'static str, Json)> {
    let strategies: Vec<Json> = StrategyKind::ALL
        .iter()
        .map(|k| {
            Json::obj(vec![
                ("name", Json::Str(k.name().into())),
                ("waste", Json::Num(r.waste[*k as usize])),
                ("period", Json::Num(r.period[*k as usize])),
            ])
        })
        .collect();
    vec![
        ("winner", Json::Str(r.winner.name().into())),
        ("q", Json::Num(r.q as f64)),
        ("winner_waste", Json::Num(r.winner_waste)),
        ("winner_period", Json::Num(r.winner_period)),
        ("strategies", Json::Arr(strategies)),
    ]
}

/// Decode one (v2) response line back into a typed [`JobResponse`] —
/// the client half of the protocol.
pub fn decode_response(line: &str) -> Result<JobResponse, ApiError> {
    let v = parse(line).map_err(|e| ApiError::invalid_json(format!("{e:#}")))?;
    match v.get("ok").and_then(Json::as_bool) {
        Some(true) => {}
        Some(false) => {
            let code = ErrorCode::parse(v.get("code").and_then(Json::as_str).unwrap_or(""));
            let message =
                v.get("error").and_then(Json::as_str).unwrap_or("unknown error").to_string();
            let retry_after_ms = opt_u64(&v, "retry_after_ms");
            return Ok(JobResponse::Error(ApiError { code, message, retry_after_ms }));
        }
        None => return Err(ApiError::bad_request("response missing 'ok'")),
    }
    match v.get("job").and_then(Json::as_str) {
        Some("ping") => Ok(JobResponse::Pong),
        Some("plan") => {
            let mut waste = [0.0; 6];
            let mut period = [0.0; 6];
            if let Some(Json::Arr(xs)) = v.get("strategies") {
                for x in xs {
                    let name = x.get("name").and_then(Json::as_str).unwrap_or("");
                    if let Ok(k) = name.parse::<StrategyKind>() {
                        waste[k as usize] = x.num_or("waste", f64::NAN);
                        period[k as usize] = x.num_or("period", f64::NAN);
                    }
                }
            }
            let winner = v
                .get("winner")
                .and_then(Json::as_str)
                .unwrap_or("")
                .parse::<StrategyKind>()
                .map_err(ApiError::from_invalid)?;
            Ok(JobResponse::Plan(PlanResult {
                waste,
                period,
                winner,
                winner_waste: v.num_or("winner_waste", f64::NAN),
                winner_period: v.num_or("winner_period", f64::NAN),
                q: v.num_or("q", 0.0) as u8,
                via_hlo: v.get("planner").and_then(Json::as_str) == Some("hlo"),
            }))
        }
        Some("simulate") => Ok(JobResponse::Simulate(SimulateResult {
            strategy: v.get("strategy").and_then(Json::as_str).unwrap_or("").to_string(),
            reps: u64_or(&v, "reps", 0),
            workers: u64_or(&v, "workers", 0),
            mean_waste: v.num_or("mean_waste", f64::NAN),
            waste_ci95: v.num_or("waste_ci95", f64::NAN),
            mean_makespan: v.num_or("mean_makespan", f64::NAN),
            completion_rate: v.num_or("completion_rate", f64::NAN),
            n_faults: u64_or(&v, "n_faults", 0),
            n_preds: u64_or(&v, "n_preds", 0),
            n_ckpts: u64_or(&v, "n_ckpts", 0),
            n_proactive_ckpts: u64_or(&v, "n_proactive_ckpts", 0),
            sim_seconds: v.num_or("sim_seconds", 0.0),
        })),
        Some("best_period") => {
            let sweep = match v.get("sweep") {
                Some(Json::Arr(xs)) => xs
                    .iter()
                    .map(|x| match x {
                        Json::Arr(pair) if pair.len() == 2 => {
                            match (pair[0].as_f64(), pair[1].as_f64()) {
                                (Some(t), Some(w)) => Ok((t, w)),
                                _ => Err(ApiError::bad_request("sweep entries must be numbers")),
                            }
                        }
                        _ => Err(ApiError::bad_request("sweep entries must be [t, w] pairs")),
                    })
                    .collect::<Result<Vec<_>, _>>()?,
                _ => Vec::new(),
            };
            Ok(JobResponse::BestPeriod(BestPeriodOutcome {
                strategy: v.get("strategy").and_then(Json::as_str).unwrap_or("").to_string(),
                t_r: v.num_or("t_r", f64::NAN),
                waste: v.num_or("waste", f64::NAN),
                n_pruned: u64_or(&v, "n_pruned", 0),
                sweep,
                reps: u64_or(&v, "reps", 0),
                candidates: u64_or(&v, "candidates", 0),
                workers: u64_or(&v, "workers", 0),
                reps_used: u64_or(&v, "reps_used", 0),
            }))
        }
        Some("sweep") => {
            let rows = match v.get("rows") {
                Some(Json::Arr(xs)) => xs
                    .iter()
                    .map(|x| {
                        let winner = x
                            .get("winner")
                            .and_then(Json::as_str)
                            .unwrap_or("")
                            .parse::<StrategyKind>()
                            .map_err(ApiError::from_invalid)?;
                        Ok(SweepRow {
                            n_procs: u64_or(x, "n_procs", 0),
                            mu: x.num_or("mu", f64::NAN),
                            winner,
                            winner_waste: x.num_or("winner_waste", f64::NAN),
                            winner_period: x.num_or("winner_period", f64::NAN),
                        })
                    })
                    .collect::<Result<Vec<_>, ApiError>>()?,
                _ => Vec::new(),
            };
            Ok(JobResponse::Sweep(SweepResult {
                rows,
                via_hlo: v.get("planner").and_then(Json::as_str) == Some("hlo"),
            }))
        }
        Some("verify") => verify::report_from_json(&v)
            .map(JobResponse::Verify)
            .map_err(|e| ApiError::bad_request(format!("{e:#}"))),
        Some("stats") => {
            let batcher = v.get("batcher").map(|b| BatcherSnapshot {
                requests: u64_or(b, "requests", 0),
                batches: u64_or(b, "batches", 0),
                max_batch: u64_or(b, "max_batch", 0),
            });
            Ok(JobResponse::Stats(ServiceStats {
                requests: u64_or(&v, "requests", 0),
                errors: u64_or(&v, "errors", 0),
                plans: u64_or(&v, "plans", 0),
                simulates: u64_or(&v, "simulates", 0),
                best_periods: u64_or(&v, "best_periods", 0),
                sweeps: u64_or(&v, "sweeps", 0),
                verifies: u64_or(&v, "verifies", 0),
                lat_p50_s: v.num_or("lat_p50_s", 0.0),
                lat_p95_s: v.num_or("lat_p95_s", 0.0),
                lat_p99_s: v.num_or("lat_p99_s", 0.0),
                lat_n: u64_or(&v, "lat_n", 0),
                banks_built: u64_or(&v, "banks_built", 0),
                bank_replays: u64_or(&v, "bank_replays", 0),
                bank_fallbacks: u64_or(&v, "bank_fallbacks", 0),
                bank_bytes_resident: u64_or(&v, "bank_bytes_resident", 0),
                rejected_overloaded: u64_or(&v, "rejected_overloaded", 0),
                deadline_exceeded: u64_or(&v, "deadline_exceeded", 0),
                panics_contained: u64_or(&v, "panics_contained", 0),
                client_retries: u64_or(&v, "client_retries", 0),
                batch_lanes_run: u64_or(&v, "batch_lanes_run", 0),
                batch_lane_fallbacks: u64_or(&v, "batch_lane_fallbacks", 0),
                wide_lanes_run: u64_or(&v, "wide_lanes_run", 0),
                wide_evictions: u64_or(&v, "wide_evictions", 0),
                cache_hits: u64_or(&v, "cache_hits", 0),
                cache_misses: u64_or(&v, "cache_misses", 0),
                cache_evictions: u64_or(&v, "cache_evictions", 0),
                cache_entries: u64_or(&v, "cache_entries", 0),
                batcher,
            }))
        }
        Some(other) => Err(ApiError::bad_request(format!("unknown job kind '{other}'"))),
        None => Err(ApiError::bad_request("response missing 'job' (v1 server?)")),
    }
}

// ---------------------------------------------------------------------------
// Scenario <-> JSON
// ---------------------------------------------------------------------------

/// Encode a scenario fully and explicitly — decode of this object is
/// the identity (pinned in the round-trip tests). Seeds above 2^53 lose
/// precision in JSON's number model; the practical seed space is far
/// below that.
pub fn scenario_to_json(s: &Scenario) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("n_procs", Json::Num(s.platform.n_procs as f64)),
        ("mu_ind", Json::Num(s.platform.mu_ind)),
        ("c", Json::Num(s.platform.c)),
        ("d", Json::Num(s.platform.d)),
        ("r", Json::Num(s.platform.r)),
        ("recall", Json::Num(s.predictor.recall)),
        ("precision", Json::Num(s.predictor.precision)),
        ("window", Json::Num(s.predictor.window)),
        ("ef", Json::Num(s.predictor.ef)),
        ("alpha", Json::Num(s.alpha)),
        ("work", Json::Num(s.work)),
        ("fault_dist", Json::Str(s.fault_dist.to_string())),
        ("migration", Json::Num(s.migration)),
        ("seed", Json::Num(s.seed as f64)),
    ];
    if let Some(d) = &s.false_pred_dist {
        fields.push(("false_pred_dist", Json::Str(d.to_string())));
    }
    Json::obj(fields)
}

/// Decode a scenario object. Missing fields inherit the §5 paper preset
/// for the given `n_procs` (mirroring the TOML loader); the result is
/// validated before it crosses into the typed world.
pub fn scenario_from_json(v: &Json) -> Result<Scenario, ApiError> {
    if !matches!(v, Json::Obj(_)) {
        return Err(ApiError::bad_request("'scenario' must be a JSON object"));
    }
    let n_procs = u64_or(v, "n_procs", 1 << 16);
    let window = v.num_or("window", 0.0);
    let mut pb = Predictor::builder()
        .recall(v.num_or("recall", 0.0))
        .precision(v.num_or("precision", 1.0))
        .window(window);
    if let Some(ef) = v.get("ef").and_then(Json::as_f64) {
        pb = pb.ef(ef);
    }
    let predictor = pb.build().map_err(ApiError::from_invalid)?;
    let mut s = Scenario::paper(n_procs.max(1), predictor);
    s.platform.n_procs = n_procs; // n_procs = 0 caught by validate below
    if let Some(x) = v.get("mu_ind").and_then(Json::as_f64) {
        s.platform.mu_ind = x;
    } else if let Some(x) = v.get("mu").and_then(Json::as_f64) {
        // Direct platform-MTBF override, v1-style.
        s.platform.mu_ind = x * n_procs as f64;
    }
    if let Some(x) = v.get("c").and_then(Json::as_f64) {
        s.platform.c = x;
    }
    if let Some(x) = v.get("d").and_then(Json::as_f64) {
        s.platform.d = x;
    }
    if let Some(x) = v.get("r").and_then(Json::as_f64) {
        s.platform.r = x;
    }
    if let Some(x) = v.get("alpha").and_then(Json::as_f64) {
        s.alpha = x;
    }
    if let Some(x) = v.get("work").and_then(Json::as_f64) {
        s.work = x;
    }
    if let Some(x) = v.get("migration").and_then(Json::as_f64) {
        s.migration = x;
    }
    if let Some(x) = v.get("seed").and_then(Json::as_f64) {
        s.seed = x as u64;
    }
    if let Some(x) = v.get("fault_dist").and_then(Json::as_str) {
        s.fault_dist = x.parse::<DistSpec>().map_err(ApiError::from_invalid)?;
    }
    match v.get("false_pred_dist").and_then(Json::as_str) {
        Some("") | None => {}
        Some(x) => {
            s.false_pred_dist = Some(x.parse::<DistSpec>().map_err(ApiError::from_invalid)?)
        }
    }
    s.validate().map_err(ApiError::from_invalid)?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Small field helpers
// ---------------------------------------------------------------------------

fn require<'a>(v: &'a Json, key: &str) -> Result<&'a Json, ApiError> {
    v.get(key).ok_or_else(|| ApiError::bad_request(format!("missing '{key}'")))
}

fn capping_from_json(v: &Json) -> Capping {
    if v.get("capped").and_then(Json::as_bool).unwrap_or(false) {
        Capping::Capped
    } else {
        Capping::Uncapped
    }
}

/// The `strategy` field; optional (defaulting to Young, which the
/// executor then ignores) when a `policy` field is standing in for it.
fn strategy_from_json(v: &Json, has_policy: bool) -> Result<StrategyKind, ApiError> {
    match v.get("strategy").and_then(Json::as_str) {
        Some(s) => s.parse::<StrategyKind>().map_err(ApiError::from_invalid),
        None if has_policy => Ok(StrategyKind::Young),
        None => Err(ApiError::bad_request("missing 'strategy'")),
    }
}

/// The additive v2 `policy` field: a policy spec string
/// (`"Young"`, `"adaptive:0.8"`, `"risk:2"`, …); absent means the
/// classic `strategy` path.
fn policy_from_json(v: &Json) -> Result<Option<PolicySpec>, ApiError> {
    match v.get("policy") {
        None => Ok(None),
        Some(j) => match j.as_str() {
            Some(s) => s.parse::<PolicySpec>().map(Some).map_err(ApiError::from_invalid),
            None => Err(ApiError::bad_request("'policy' must be a policy spec string")),
        },
    }
}

/// The additive v2 `platform` field: a platform spec string
/// (`"single"`, `"nodes=4"`, `"nodes=8,commit=0.1"`, …); absent means
/// the classic single-stream engine.
fn platform_from_json(v: &Json) -> Result<Option<crate::sim::PlatformSpec>, ApiError> {
    match v.get("platform") {
        None => Ok(None),
        Some(j) => match j.as_str() {
            Some(s) => s
                .parse::<crate::sim::PlatformSpec>()
                .map(Some)
                .map_err(ApiError::from_invalid),
            None => Err(ApiError::bad_request("'platform' must be a platform spec string")),
        },
    }
}

fn u64_or(v: &Json, key: &str, default: u64) -> u64 {
    v.num_or(key, default as f64) as u64
}

fn opt_u64(v: &Json, key: &str) -> Option<u64> {
    v.get(key).and_then(Json::as_f64).map(|x| x as u64)
}
