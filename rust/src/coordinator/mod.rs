//! The L3 coordinator: leader/worker experiment orchestration, dynamic
//! batching of planning requests onto the PJRT executable, and the
//! TCP/JSONL job service (protocol v2 via [`crate::api`]; the v1
//! planner dialect lives on in [`protocol`] behind an adapter).
//!
//! The service layer is an async multiplexed server ([`service`]): one
//! event loop owns every connection, a stride scheduler spreads the
//! executor pool fairly across tenants, and a bounded LRU ([`cache`])
//! memoizes the pure job responses under canonical keys ([`canon`]).

mod batcher;
pub mod cache;
pub mod canon;
pub mod loadgen;
mod metrics;
mod pool;
pub mod protocol;
mod service;

pub use batcher::{Batcher, BatcherConfig, BatcherStats};
pub use cache::{CacheSnapshot, PlanCache};
pub use loadgen::{LoadReport, TraceSpec};
pub use metrics::{bank_snapshot, Metrics};
pub use pool::{
    available_workers, run_parallel, run_parallel_fold, try_run_parallel, try_run_parallel_fold,
    PoolPanic,
};
pub use service::{serve, PlannerClient, ServiceConfig, ServiceHandle};
