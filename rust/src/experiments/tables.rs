//! Tables 1 and 2: job execution times (days) under Weibull failures
//! (k = 0.7 and 0.5), with the percentage gain of each prediction-aware
//! heuristic over Young.

use super::{scenario_for, sim_makespan, ExpOptions, ExperimentResult};
use crate::config::{predictor_yu, predictor_zheng, Scenario};
use crate::model::{Capping, StrategyKind};
use crate::report::Table;
use crate::util::units::to_days;

/// Heuristic rows, in paper order, for a window size.
fn table_rows(i_win: f64) -> Vec<StrategyKind> {
    let mut rows = vec![StrategyKind::Young, StrategyKind::ExactPrediction, StrategyKind::NoCkptI];
    if i_win >= 600.0 {
        rows.push(StrategyKind::WithCkptI);
    }
    rows.push(StrategyKind::Instant);
    rows
}

/// One (Table 1 or Table 2) reproduction: Weibull shape `k`.
pub fn table_exec(k: f64, opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let dist = crate::dist::DistSpec::weibull(k);
    let mut result = ExperimentResult::default();
    for i_win in [300.0, 3000.0] {
        let mut t = Table::new([
            "strategy".to_string(),
            "yu 2^16 days (gain)".to_string(),
            "yu 2^19 days (gain)".to_string(),
            "zheng 2^16 days (gain)".to_string(),
            "zheng 2^19 days (gain)".to_string(),
        ]);
        // Column setup: (predictor name, N).
        let mut columns: Vec<(String, Scenario)> = Vec::new();
        for (pname, make) in [("yu", true), ("zheng", false)] {
            for n in [1u64 << 16, 1u64 << 19] {
                let pred = if make { predictor_yu(i_win) } else { predictor_zheng(i_win) };
                let mut s = Scenario::paper(n, pred);
                s.fault_dist = dist;
                columns.push((format!("{pname}-{n}"), s));
            }
        }
        // Young execution time per column (the gain baseline).
        let youngs: Vec<f64> = columns
            .iter()
            .map(|(_, s)| sim_makespan(s, StrategyKind::Young, opts).mean())
            .collect();

        for kind in table_rows(i_win) {
            let mut cells = vec![kind.name().to_string()];
            for (ci, (_, s)) in columns.iter().enumerate() {
                let span = if kind == StrategyKind::Young {
                    youngs[ci]
                } else {
                    sim_makespan(s, kind, opts).mean()
                };
                let days = to_days(span);
                if kind == StrategyKind::Young {
                    cells.push(format!("{days:.1}"));
                } else {
                    let gain = 100.0 * (1.0 - span / youngs[ci]);
                    cells.push(format!("{days:.1} ({gain:.0}%)"));
                }
            }
            t.row(cells);
        }
        result.tables.push((format!("table-weibull{k}-I{i_win}"), t));
    }
    Ok(result)
}

/// Analytic preview of the same table (no simulation; used by the
/// quick bench mode and the planner CLI).
pub fn table_exec_analytic(k: f64) -> ExperimentResult {
    let _ = k; // the analytic model is distribution-free (uses mu only)
    let mut result = ExperimentResult::default();
    for i_win in [300.0, 3000.0] {
        let mut t = Table::new(["strategy", "yu 2^16", "yu 2^19", "zheng 2^16", "zheng 2^19"]);
        let mut columns = Vec::new();
        for yu in [true, false] {
            for n in [1u64 << 16, 1u64 << 19] {
                let pred = if yu { predictor_yu(i_win) } else { predictor_zheng(i_win) };
                columns.push(Scenario::paper(n, pred));
            }
        }
        for kind in table_rows(i_win) {
            let mut cells = vec![kind.name().to_string()];
            for s in &columns {
                let sk = scenario_for(kind, s);
                let p = crate::model::Params::from_scenario(&sk);
                let (_, w) = crate::model::optimize(&p, kind, Capping::Uncapped);
                let days = to_days(s.work / (1.0 - w.min(0.999)));
                cells.push(format!("{days:.1}"));
            }
            t.row(cells);
        }
        result.tables.push((format!("table-analytic-I{i_win}"), t));
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_sets() {
        assert_eq!(table_rows(300.0).len(), 4);
        assert_eq!(table_rows(3000.0).len(), 5);
        assert_eq!(table_rows(300.0)[0], StrategyKind::Young);
    }

    #[test]
    fn analytic_table_renders() {
        let r = table_exec_analytic(0.7);
        assert_eq!(r.tables.len(), 2);
        let rendered = r.render();
        assert!(rendered.contains("Young"));
        assert!(rendered.contains("table-analytic-I300"));
    }
}
