//! Lightweight metrics: named atomic counters + a latency reservoir.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, tolerating poison. Metrics and batcher state must
/// survive a panicking request thread (the service catches the panic
/// and answers `internal`); the guarded data here is a counter map /
/// sample vector that stays structurally valid at every await-free
/// point, so adopting a poisoned lock is safe.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    latencies: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let map = lock_unpoisoned(&self.counters);
        if let Some(c) = map.get(name) {
            c.fetch_add(by, Ordering::Relaxed);
            return;
        }
        drop(map);
        let mut map = lock_unpoisoned(&self.counters);
        map.entry(name.to_string()).or_insert_with(|| AtomicU64::new(0)).fetch_add(by, Ordering::Relaxed);
    }

    pub fn get(&self, name: &str) -> u64 {
        lock_unpoisoned(&self.counters)
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record one request latency (seconds). Bounded reservoir: the
    /// most recent 65536 samples.
    pub fn observe_latency(&self, seconds: f64) {
        let mut v = lock_unpoisoned(&self.latencies);
        if v.len() >= 65536 {
            let len = v.len();
            v.copy_within(len / 2.., 0);
            v.truncate(len / 2);
        }
        v.push(seconds);
    }

    /// (p50, p95, p99, count) of recorded latencies.
    pub fn latency_quantiles(&self) -> (f64, f64, f64, usize) {
        let mut v = lock_unpoisoned(&self.latencies).clone();
        if v.is_empty() {
            return (f64::NAN, f64::NAN, f64::NAN, 0);
        }
        v.sort_by(f64::total_cmp);
        let q = |p: f64| crate::util::stats::percentile(&v, p);
        (q(0.50), q(0.95), q(0.99), v.len())
    }

    /// Render this instance's counters for the service `stats` verb.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        lock_unpoisoned(&self.counters)
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }
}

/// The trace-bank reuse counters (banks built, replays served,
/// fallbacks taken, bytes resident) as `bank.*` metric entries. These
/// are *process-global* — the bank subsystem is shared by every
/// executor in the process — so they are deliberately not part of any
/// per-instance [`Metrics::snapshot`]; stats renderers splice them in
/// beside their own counters (the v2 `stats` job does exactly that
/// with dedicated fields).
pub fn bank_snapshot() -> BTreeMap<String, u64> {
    let bank = crate::trace::bank::counters();
    BTreeMap::from([
        ("bank.banks_built".to_string(), bank.banks_built),
        ("bank.replays_served".to_string(), bank.replays_served),
        ("bank.fallbacks_taken".to_string(), bank.fallbacks_taken),
        ("bank.bytes_resident".to_string(), bank.bytes_resident),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let m = Metrics::new();
        m.incr("a", 2);
        m.incr("a", 3);
        m.incr("b", 1);
        assert_eq!(m.get("a"), 5);
        assert_eq!(m.get("b"), 1);
        assert_eq!(m.get("missing"), 0);
        assert_eq!(m.snapshot().len(), 2);
    }

    #[test]
    fn bank_snapshot_carries_the_global_reuse_counters() {
        let snap = bank_snapshot();
        assert_eq!(snap.len(), 4);
        for key in [
            "bank.banks_built",
            "bank.replays_served",
            "bank.fallbacks_taken",
            "bank.bytes_resident",
        ] {
            assert!(snap.contains_key(key), "missing {key}");
        }
        // The entries mirror the bank module's own monotone counters
        // (a later read can only be >= an earlier snapshot).
        let ctr = crate::trace::bank::counters();
        assert!(ctr.banks_built >= snap["bank.banks_built"]);
        assert!(ctr.replays_served >= snap["bank.replays_served"]);
    }

    #[test]
    fn latency_quantiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_latency(i as f64);
        }
        let (p50, p95, _p99, n) = m.latency_quantiles();
        assert_eq!(n, 100);
        assert!((p50 - 50.5).abs() < 1.0);
        assert!(p95 > 90.0);
    }

    #[test]
    fn reservoir_bounded() {
        let m = Metrics::new();
        for i in 0..70_000 {
            m.observe_latency(i as f64);
        }
        let (_, _, _, n) = m.latency_quantiles();
        assert!(n <= 65536);
    }

    #[test]
    fn survives_poisoned_locks() {
        let m = std::sync::Arc::new(Metrics::new());
        m.incr("x", 1);
        let mc = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _counters = mc.counters.lock().unwrap();
            let _latencies = mc.latencies.lock().unwrap();
            panic!("poison both metric locks");
        })
        .join();
        // Every accessor keeps working on the poisoned mutexes.
        m.incr("x", 1);
        assert_eq!(m.get("x"), 2);
        m.observe_latency(0.5);
        let (_, _, _, n) = m.latency_quantiles();
        assert_eq!(n, 1);
        assert_eq!(m.snapshot()["x"], 2);
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.incr("x", 1);
                    }
                });
            }
        });
        assert_eq!(m.get("x"), 8000);
    }
}
