//! Leader/worker parallelism over std::thread (substrate: no tokio/rayon
//! offline). Scoped threads + an atomic work index give dynamic load
//! balancing without channels — replication workloads are embarrassingly
//! parallel but very uneven (BestPeriod candidates differ by 10x in
//! simulated events), so static chunking would waste cores.
//!
//! Worker panics are captured at the pool boundary: the `try_*` variants
//! return a structured [`PoolPanic`] naming the worker, while the plain
//! variants re-raise the original payload after all workers stop. A
//! panicked worker never turns into a second, misleading panic about an
//! unfilled result slot.

use std::any::Any;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count: `CKPTFP_WORKERS` env override, else available
/// parallelism, else 4.
pub fn available_workers() -> usize {
    if let Ok(v) = std::env::var("CKPTFP_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// A worker panic captured at the pool boundary: which worker died and
/// what it said, as a value instead of a propagating unwind.
#[derive(Debug, Clone)]
pub struct PoolPanic {
    /// Index of the worker (spawn order) whose task panicked first.
    pub worker: usize,
    /// The panic payload rendered as text (`&str`/`String` payloads;
    /// anything else becomes a placeholder).
    pub message: String,
}

impl PoolPanic {
    fn from_payload(worker: usize, payload: &(dyn Any + Send)) -> Self {
        let message = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".to_string());
        PoolPanic { worker, message }
    }
}

impl std::fmt::Display for PoolPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker {} panicked: {}", self.worker, self.message)
    }
}

impl std::error::Error for PoolPanic {}

type Caught = (usize, Box<dyn Any + Send>);

/// Apply `f` to every item on `workers` threads; returns results in
/// input order. Panics in `f` propagate after all workers stop.
pub fn run_parallel<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    match run_parallel_impl(items, workers, f) {
        Ok(out) => out,
        Err((_, payload)) => std::panic::resume_unwind(payload),
    }
}

/// [`run_parallel`] with panic isolation: a worker panic becomes
/// `Err(PoolPanic)` naming the worker instead of unwinding the caller.
pub fn try_run_parallel<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Result<Vec<R>, PoolPanic>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    run_parallel_impl(items, workers, f)
        .map_err(|(w, payload)| PoolPanic::from_payload(w, payload.as_ref()))
}

fn run_parallel_impl<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Result<Vec<R>, Caught>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            #[cfg(any(test, feature = "chaos"))]
            crate::chaos::on_pool_task();
            items.iter().map(|t| f(t)).collect()
        }))
        .map_err(|payload| (0, payload));
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slot_ptr = SlotsPtr(slots.as_mut_ptr());
    let mut first_panic: Option<Caught> = None;

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let items = &items;
                let f = &f;
                let slot_ptr = &slot_ptr;
                scope.spawn(move || {
                    #[cfg(any(test, feature = "chaos"))]
                    crate::chaos::on_pool_task();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = f(&items[i]);
                        // SAFETY: each index i is claimed by exactly one worker
                        // (fetch_add is unique), and `slots` outlives the scope.
                        unsafe { *slot_ptr.0.add(i) = Some(r) };
                    }
                })
            })
            .collect();
        for (w, h) in handles.into_iter().enumerate() {
            if let Err(payload) = h.join() {
                first_panic.get_or_insert((w, payload));
            }
        }
    });
    if let Some(p) = first_panic {
        return Err(p);
    }
    // All workers exited cleanly, so every claimed index was filled.
    Ok(slots.into_iter().map(|s| s.expect("clean workers fill every slot")).collect())
}

/// Send+Sync wrapper for the raw result pointer; soundness argument in
/// `run_parallel_impl` (disjoint writes, scoped lifetime).
struct SlotsPtr<R>(*mut Option<R>);
unsafe impl<R: Send> Send for SlotsPtr<R> {}
unsafe impl<R: Send> Sync for SlotsPtr<R> {}

/// Streaming parallel reduction: fold `items` into per-worker
/// accumulators, then merge the partials — no `Vec<Option<R>>` slot
/// array, no per-item result allocation. This is the right shape for
/// replication workloads, where the caller only wants the aggregate
/// (and where the per-worker accumulator can carry reusable scratch
/// such as a [`crate::sim::SimSession`]).
///
/// Work distribution is a deterministic stride: worker `w` folds items
/// `w, w + W, w + 2W, …` in order, and partials merge in worker order.
/// Unlike the atomic-claim loop in [`run_parallel`] this keeps the
/// reduction reproducible for a fixed worker count (counters exactly,
/// floating-point accumulations bit-for-bit), while replication costs —
/// random by construction — still average out across the stride.
///
/// Panics in `fold` propagate after all workers stop, matching
/// [`run_parallel`]. Empty input returns `init()` untouched.
pub fn run_parallel_fold<T, A, I, F, M>(
    items: &[T],
    workers: usize,
    init: I,
    fold: F,
    merge: M,
) -> A
where
    T: Sync,
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(A, &T) -> A + Sync,
    M: Fn(A, A) -> A,
{
    match run_parallel_fold_impl(items, workers, init, fold, merge) {
        Ok(a) => a,
        Err((_, payload)) => std::panic::resume_unwind(payload),
    }
}

/// [`run_parallel_fold`] with panic isolation: a worker panic becomes
/// `Err(PoolPanic)` naming the worker instead of unwinding the caller.
/// Partial accumulators from surviving workers are discarded — the
/// reduction either completes exactly or reports the failure.
pub fn try_run_parallel_fold<T, A, I, F, M>(
    items: &[T],
    workers: usize,
    init: I,
    fold: F,
    merge: M,
) -> Result<A, PoolPanic>
where
    T: Sync,
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(A, &T) -> A + Sync,
    M: Fn(A, A) -> A,
{
    run_parallel_fold_impl(items, workers, init, fold, merge)
        .map_err(|(w, payload)| PoolPanic::from_payload(w, payload.as_ref()))
}

fn run_parallel_fold_impl<T, A, I, F, M>(
    items: &[T],
    workers: usize,
    init: I,
    fold: F,
    merge: M,
) -> Result<A, Caught>
where
    T: Sync,
    A: Send,
    I: Fn() -> A + Sync,
    F: Fn(A, &T) -> A + Sync,
    M: Fn(A, A) -> A,
{
    let n = items.len();
    if n == 0 {
        return Ok(init());
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            #[cfg(any(test, feature = "chaos"))]
            crate::chaos::on_pool_task();
            items.iter().fold(init(), &fold)
        }))
        .map_err(|payload| (0, payload));
    }
    let mut partials: Vec<A> = Vec::with_capacity(workers);
    let mut first_panic: Option<Caught> = None;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let init = &init;
                let fold = &fold;
                scope.spawn(move || {
                    #[cfg(any(test, feature = "chaos"))]
                    crate::chaos::on_pool_task();
                    let mut acc = init();
                    let mut i = w;
                    while i < n {
                        acc = fold(acc, &items[i]);
                        i += workers;
                    }
                    acc
                })
            })
            .collect();
        for (w, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(a) => partials.push(a),
                Err(payload) => {
                    first_panic.get_or_insert((w, payload));
                }
            }
        }
    });
    if let Some(p) = first_panic {
        return Err(p);
    }
    let mut iter = partials.into_iter();
    let first = iter.next().expect("at least one worker ran");
    Ok(iter.fold(first, merge))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = run_parallel(items, 8, |x| x * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 2);
        }
    }

    #[test]
    fn single_worker_path() {
        let out = run_parallel(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<u32> = run_parallel(Vec::<u32>::new(), 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_is_balanced() {
        // Tasks with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).collect();
        let out = run_parallel(items, 8, |x| {
            let mut acc = 0u64;
            for i in 0..(x % 7) * 10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn workers_env_override() {
        assert!(available_workers() >= 1);
    }

    #[test]
    #[should_panic(expected = "map boom")]
    fn run_parallel_propagates_original_payload() {
        let items: Vec<u64> = (0..32).collect();
        let _ = run_parallel(items, 4, |&x| {
            if x == 9 {
                panic!("map boom");
            }
            x
        });
    }

    #[test]
    fn try_run_parallel_names_the_failure() {
        let items: Vec<u64> = (0..32).collect();
        let err = try_run_parallel(items, 4, |&x| {
            if x == 9 {
                panic!("map boom");
            }
            x
        })
        .unwrap_err();
        assert!(err.message.contains("map boom"), "{err}");
        assert!(err.worker < 4);
        assert!(err.to_string().contains("panicked"), "{err}");
    }

    #[test]
    fn try_run_parallel_single_worker_catches() {
        let err = try_run_parallel(vec![1u64], 1, |_| -> u64 { panic!("solo boom") })
            .unwrap_err();
        assert_eq!(err.worker, 0);
        assert!(err.message.contains("solo boom"));
    }

    #[test]
    fn fold_matches_sequential_sum() {
        let items: Vec<u64> = (0..1000).collect();
        let total = run_parallel_fold(&items, 8, || 0u64, |a, x| a + x, |a, b| a + b);
        assert_eq!(total, items.iter().sum::<u64>());
    }

    #[test]
    fn fold_empty_input_returns_init() {
        let out = run_parallel_fold(&Vec::<u32>::new(), 4, || 41u32, |a, x| a + x, |a, b| a + b);
        assert_eq!(out, 41);
    }

    #[test]
    fn fold_single_worker_is_plain_fold() {
        let items = vec![1u64, 2, 3, 4];
        let out = run_parallel_fold(
            &items,
            1,
            Vec::new,
            |mut acc: Vec<u64>, &x| {
                acc.push(x);
                acc
            },
            |mut a, b| {
                a.extend(b);
                a
            },
        );
        // One worker folds in input order.
        assert_eq!(out, items);
    }

    #[test]
    fn fold_is_deterministic_for_fixed_workers() {
        // Floating-point accumulation order is a fixed stride + fixed
        // merge order, so two runs agree bit for bit.
        let items: Vec<f64> = (0..501).map(|i| (i as f64).sin()).collect();
        let run = || {
            run_parallel_fold(&items, 5, || 0.0f64, |a, x| a + x, |a, b| a + b)
        };
        let a = run();
        let b = run();
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn fold_more_workers_than_items_clamps() {
        let items = vec![10u64, 20];
        let total = run_parallel_fold(&items, 64, || 0u64, |a, x| a + x, |a, b| a + b);
        assert_eq!(total, 30);
    }

    #[test]
    #[should_panic(expected = "boom at 17")]
    fn fold_propagates_worker_panics() {
        let items: Vec<u64> = (0..64).collect();
        let _ = run_parallel_fold(
            &items,
            4,
            || 0u64,
            |a, &x| {
                if x == 17 {
                    panic!("boom at 17");
                }
                a + x
            },
            |a, b| a + b,
        );
    }

    #[test]
    fn try_fold_reports_structured_panic() {
        let items: Vec<u64> = (0..64).collect();
        let err = try_run_parallel_fold(
            &items,
            4,
            || 0u64,
            |a, &x| {
                if x == 17 {
                    panic!("boom at 17");
                }
                a + x
            },
            |a, b| a + b,
        )
        .unwrap_err();
        // Item 17 lands on worker 17 % 4 = 1 under the stride schedule.
        assert_eq!(err.worker, 1);
        assert!(err.message.contains("boom at 17"), "{err}");
    }

    #[test]
    fn try_fold_clean_path_matches_plain_fold() {
        let items: Vec<u64> = (0..100).collect();
        let a = try_run_parallel_fold(&items, 4, || 0u64, |a, x| a + x, |a, b| a + b).unwrap();
        let b = run_parallel_fold(&items, 4, || 0u64, |a, x| a + x, |a, b| a + b);
        assert_eq!(a, b);
    }
}
