//! Output formatting: ASCII tables, CSV emission, figure series.

mod csv;
mod series;
mod table;

pub use csv::{write_csv, write_figure_csv};
pub use series::{FigureData, Series};
pub use table::Table;
