//! The L3 coordinator: leader/worker experiment orchestration, dynamic
//! batching of planning requests onto the PJRT executable, and the
//! TCP/JSONL job service (protocol v2 via [`crate::api`]; the v1
//! planner dialect lives on in [`protocol`] behind an adapter).

mod batcher;
mod metrics;
mod pool;
pub mod protocol;
mod service;

pub use batcher::{Batcher, BatcherConfig, BatcherStats};
pub use metrics::{bank_snapshot, Metrics};
pub use pool::{
    available_workers, run_parallel, run_parallel_fold, try_run_parallel, try_run_parallel_fold,
    PoolPanic,
};
pub use service::{serve, PlannerClient, ServiceConfig, ServiceHandle};
