//! Batched planner execution: pack [`Params`] rows into the artifact's
//! f32 layout, run, unpack.

use super::{PlanOutput, Runtime, SurfaceOutput};
use crate::model::{Params, StrategyKind, NSTRAT_USIZE};

/// High-level planner on top of [`Runtime`].
pub struct HloPlanner {
    runtime: Runtime,
    /// Normalized grid coordinates (cached literal is rebuilt per call —
    /// see perf notes; the grid itself is fixed per planner).
    u: Vec<f32>,
}

impl HloPlanner {
    pub fn new(runtime: Runtime) -> HloPlanner {
        HloPlanner { runtime, u: Vec::new() }
    }

    pub fn open_default() -> anyhow::Result<HloPlanner> {
        Ok(HloPlanner::new(Runtime::open_default()?))
    }

    pub fn platform_name(&self) -> String {
        self.runtime.platform_name()
    }

    /// Compile the plan artifacts and run one dummy execution so the
    /// first real request does not pay PJRT compilation (~300 ms per
    /// artifact on this CPU).
    pub fn warmup(&mut self) -> anyhow::Result<()> {
        let dummy = crate::model::Params {
            mu: 60_000.0,
            c: 600.0,
            d: 60.0,
            r_rec: 600.0,
            recall: 0.85,
            precision: 0.82,
            i: 300.0,
            ef: 150.0,
            alpha: 0.27,
            m: 300.0,
        };
        let sizes: Vec<usize> = self
            .runtime
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.entry == "plan")
            .map(|a| a.b)
            .collect();
        for b in sizes {
            self.plan_batch(&vec![dummy; b])?;
        }
        Ok(())
    }

    fn grid(&mut self, g: usize) -> &[f32] {
        if self.u.len() != g {
            // Quadratic spacing in [0, 1]: the artifact maps u to
            // [C, alpha*mu], and window strategies are capped at
            // alpha*mu_e - I which can sit very close to C — denser
            // sampling near the bottom keeps the argmin sharp there,
            // while interior optima are second-order flat and tolerate
            // the coarser top end. (The kernel takes the grid as an
            // input precisely so the host can pick the spacing.)
            self.u = (0..g)
                .map(|j| {
                    let x = j as f32 / (g - 1) as f32;
                    x * x
                })
                .collect();
        }
        &self.u
    }

    /// Plan a batch of configurations. Splits into artifact-sized
    /// chunks (padding the tail with copies of the last row).
    pub fn plan_batch(&mut self, configs: &[Params]) -> anyhow::Result<Vec<PlanOutput>> {
        anyhow::ensure!(!configs.is_empty(), "empty batch");
        let spec = self
            .runtime
            .manifest()
            .plan_artifact_for(configs.len())
            .ok_or_else(|| anyhow::anyhow!("no plan artifact in manifest"))?
            .clone();
        let mut out = Vec::with_capacity(configs.len());
        for chunk in configs.chunks(spec.b) {
            out.extend(self.plan_chunk(&spec.name, spec.b, spec.g, spec.nraw, chunk)?);
        }
        Ok(out)
    }

    fn plan_chunk(
        &mut self,
        artifact: &str,
        b: usize,
        g: usize,
        nraw: usize,
        chunk: &[Params],
    ) -> anyhow::Result<Vec<PlanOutput>> {
        anyhow::ensure!(chunk.len() <= b, "chunk larger than artifact batch");
        anyhow::ensure!(nraw == 10, "artifact raw width {nraw} != 10");
        let mut rows = Vec::with_capacity(b * nraw);
        for cfg in chunk {
            rows.extend_from_slice(&cfg.to_raw_row());
        }
        // Pad with the last row: harmless, discarded after unpacking.
        let last = chunk.last().unwrap().to_raw_row();
        for _ in chunk.len()..b {
            rows.extend_from_slice(&last);
        }
        let raw = xla::Literal::vec1(&rows).reshape(&[b as i64, nraw as i64])?;
        let u = xla::Literal::vec1(self.grid(g));
        let parts = self.runtime.execute(artifact, &[raw, u])?;
        anyhow::ensure!(parts.len() == 5, "plan artifact returned {} parts", parts.len());
        let best_w = parts[0].to_vec::<f32>()?;
        let best_t = parts[1].to_vec::<f32>()?;
        let win_s = parts[2].to_vec::<i32>()?;
        let win_w = parts[3].to_vec::<f32>()?;
        let win_t = parts[4].to_vec::<f32>()?;
        anyhow::ensure!(best_w.len() == b * 6, "unexpected best_w size");
        let mut out = Vec::with_capacity(chunk.len());
        for i in 0..chunk.len() {
            let mut waste = [0.0; 6];
            let mut period = [0.0; 6];
            for s in 0..NSTRAT_USIZE {
                waste[s] = best_w[i * 6 + s] as f64;
                period[s] = best_t[i * 6 + s] as f64;
            }
            let winner = StrategyKind::from_index(win_s[i] as usize)
                .ok_or_else(|| anyhow::anyhow!("bad winner index {}", win_s[i]))?;
            out.push(PlanOutput {
                waste,
                period,
                winner,
                winner_waste: win_w[i] as f64,
                winner_period: win_t[i] as f64,
            });
        }
        Ok(out)
    }

    /// Raw waste surfaces for up to the surface artifact's batch size.
    pub fn surfaces(&mut self, configs: &[Params]) -> anyhow::Result<Vec<SurfaceOutput>> {
        anyhow::ensure!(!configs.is_empty(), "empty batch");
        let spec = self
            .runtime
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.entry == "surface")
            .ok_or_else(|| anyhow::anyhow!("no surface artifact in manifest"))?
            .clone();
        let mut out = Vec::with_capacity(configs.len());
        for chunk in configs.chunks(spec.b) {
            let (b, g) = (spec.b, spec.g);
            let mut rows = Vec::with_capacity(b * spec.nraw);
            for cfg in chunk {
                rows.extend_from_slice(&cfg.to_raw_row());
            }
            let last = chunk.last().unwrap().to_raw_row();
            for _ in chunk.len()..b {
                rows.extend_from_slice(&last);
            }
            let raw = xla::Literal::vec1(&rows).reshape(&[b as i64, spec.nraw as i64])?;
            let u = xla::Literal::vec1(self.grid(g));
            let parts = self.runtime.execute(&spec.name, &[raw, u])?;
            anyhow::ensure!(parts.len() == 2, "surface artifact returned {} parts", parts.len());
            let w = parts[0].to_vec::<f32>()?; // [b, 6, g]
            let t = parts[1].to_vec::<f32>()?; // [b, g]
            for i in 0..chunk.len() {
                let mut waste = Vec::with_capacity(6);
                for s in 0..6 {
                    let off = (i * 6 + s) * g;
                    waste.push(w[off..off + g].iter().map(|x| *x as f64).collect());
                }
                let periods = t[i * g..(i + 1) * g].iter().map(|x| *x as f64).collect();
                out.push(SurfaceOutput { waste, periods });
            }
        }
        Ok(out)
    }
}
