//! Dynamic batching of planning requests onto the PJRT executable.
//!
//! The HLO planner is compiled for fixed batch sizes (B = 1 and B = 64);
//! PJRT execution has per-call overhead, so concurrent callers get far
//! better throughput when their requests ride the same execution. The
//! batcher owns the (non-Sync) [`HloPlanner`] on a dedicated thread and
//! exposes a cloneable, blocking [`Batcher::plan`] front-end:
//!
//! * requests accumulate until `max_batch` are waiting or the oldest
//!   exceeds `max_delay` — the standard dynamic-batching policy of
//!   serving systems (vLLM-style);
//! * responses travel back over per-request oneshot channels.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::model::batched::WasteGrid;
use crate::model::Params;
use crate::runtime::{HloPlanner, PlanOutput};

use super::metrics::lock_unpoisoned;
use super::Metrics;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many requests are queued (<= artifact batch).
    pub max_batch: usize,
    /// Flush when the oldest queued request is this old (only when
    /// `eager` is off).
    pub max_delay: Duration,
    /// Eager policy (default): execute whatever is queued *right now*
    /// instead of waiting out `max_delay`. Single clients see pure
    /// execution latency; concurrent clients still coalesce because
    /// requests arriving during an execution form the next batch.
    pub eager: bool,
    /// Pre-compile the artifacts at spawn so the first request does
    /// not pay PJRT compilation.
    pub warmup: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            eager: true,
            warmup: true,
        }
    }
}

/// Counters exposed for tests and the service's `stats` verb.
#[derive(Debug, Clone, Default)]
pub struct BatcherStats {
    pub requests: u64,
    pub batches: u64,
    pub max_batch_seen: u64,
}

enum Msg {
    Plan(Params, Sender<anyhow::Result<PlanOutput>>),
    Shutdown,
}

/// Cloneable handle to the batching thread.
#[derive(Clone)]
pub struct Batcher {
    tx: Sender<Msg>,
    stats: Arc<Mutex<BatcherStats>>,
    metrics: Arc<Metrics>,
}

impl Batcher {
    /// Spawn the owner thread; the planner is constructed *inside* it
    /// because the PJRT client is not `Send` (it holds a thread-local
    /// `Rc` into the C API). `factory` failures surface here.
    pub fn spawn<F>(factory: F, cfg: BatcherConfig) -> anyhow::Result<Batcher>
    where
        F: FnOnce() -> anyhow::Result<HloPlanner> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        let metrics = Arc::new(Metrics::new());
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        {
            let stats = Arc::clone(&stats);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("ckptfp-batcher".into())
                .spawn(move || match factory() {
                    Ok(mut planner) => {
                        if cfg.warmup {
                            if let Err(e) = planner.warmup() {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        }
                        let _ = ready_tx.send(Ok(()));
                        owner_loop(planner, cfg, rx, stats, metrics);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                })
                .expect("spawn batcher thread");
        }
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("batcher thread died during startup"))??;
        Ok(Batcher { tx, stats, metrics })
    }

    /// Spawn against the default artifacts directory.
    pub fn spawn_default(cfg: BatcherConfig) -> anyhow::Result<Batcher> {
        Self::spawn(HloPlanner::open_default, cfg)
    }

    /// Plan one configuration (blocking).
    pub fn plan(&self, params: Params) -> anyhow::Result<PlanOutput> {
        let started = Instant::now();
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Plan(params, rtx))
            .map_err(|_| anyhow::anyhow!("batcher thread is gone"))?;
        let out = rrx.recv().map_err(|_| anyhow::anyhow!("batcher dropped the request"))?;
        self.metrics.observe_latency(started.elapsed().as_secs_f64());
        out
    }

    /// Plan many configurations from one caller (rides one batch
    /// directly, no delay).
    pub fn plan_many(&self, params: Vec<Params>) -> anyhow::Result<Vec<PlanOutput>> {
        let mut receivers = Vec::with_capacity(params.len());
        for p in params {
            let (rtx, rrx) = channel();
            self.tx.send(Msg::Plan(p, rtx)).map_err(|_| anyhow::anyhow!("batcher gone"))?;
            receivers.push(rrx);
        }
        receivers
            .into_iter()
            .map(|r| r.recv().map_err(|_| anyhow::anyhow!("batcher dropped request"))?)
            .collect()
    }

    /// Evaluate the full (strategy × scenario) optimum grid through
    /// the HLO path: one batched plan per row, repacked into the
    /// [`WasteGrid`] row-major layout (`StrategyKind` index order —
    /// the same layout [`crate::model::batched::waste_grid_batched`]
    /// produces, so callers can swap backends without reshaping).
    /// The HLO pipeline computes in f32, so the closed-form CPU pass
    /// stays the bit-equality reference; this path trades precision
    /// for device throughput exactly like [`Batcher::plan`].
    pub fn waste_grid(&self, params: Vec<Params>) -> anyhow::Result<WasteGrid> {
        let n = params.len();
        let outputs = self.plan_many(params)?;
        let mut period = Vec::with_capacity(n * 6);
        let mut waste = Vec::with_capacity(n * 6);
        for out in &outputs {
            period.extend_from_slice(&out.period);
            waste.extend_from_slice(&out.waste);
        }
        Ok(WasteGrid { n, period, waste })
    }

    pub fn stats(&self) -> BatcherStats {
        // Poison-tolerant: a panicking request thread must not take the
        // stats surface down with it.
        lock_unpoisoned(&self.stats).clone()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Ask the owner thread to exit (pending requests still served).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

fn owner_loop(
    mut planner: HloPlanner,
    cfg: BatcherConfig,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<BatcherStats>>,
    metrics: Arc<Metrics>,
) {
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(Msg::Plan(p, tx)) => (p, tx),
            Ok(Msg::Shutdown) | Err(_) => return,
        };
        let mut batch = vec![first];
        let mut shutdown = false;
        if cfg.eager {
            // Take everything already queued, no waiting: requests that
            // arrive during the upcoming execution form the next batch.
            while batch.len() < cfg.max_batch {
                match rx.try_recv() {
                    Ok(Msg::Plan(p, tx)) => batch.push((p, tx)),
                    Ok(Msg::Shutdown) => {
                        shutdown = true;
                        break;
                    }
                    Err(_) => break,
                }
            }
        } else {
            let deadline = Instant::now() + cfg.max_delay;
            while batch.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Plan(p, tx)) => batch.push((p, tx)),
                    Ok(Msg::Shutdown) => {
                        shutdown = true;
                        break;
                    }
                    Err(_) => break, // timeout or disconnect
                }
            }
        }

        let params: Vec<Params> = batch.iter().map(|(p, _)| *p).collect();
        {
            let mut s = lock_unpoisoned(&stats);
            s.requests += batch.len() as u64;
            s.batches += 1;
            s.max_batch_seen = s.max_batch_seen.max(batch.len() as u64);
        }
        metrics.incr("batches", 1);
        metrics.incr("requests", batch.len() as u64);
        match planner.plan_batch(&params) {
            Ok(outputs) => {
                for ((_, tx), out) in batch.into_iter().zip(outputs) {
                    let _ = tx.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (_, tx) in batch {
                    let _ = tx.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
        if shutdown {
            return;
        }
    }
}
