//! The coordinated checkpoint store component.
//!
//! Checkpoint commits are *coordinated*: every node quiesces, the K
//! node images are committed together, and the commit contends on the
//! shared store. The contention model is linear in the extra nodes —
//! a commit (or a full restart) of a K-node platform costs
//!
//! ```text
//! C_eff = C · (1 + γ · (K − 1))      γ = PlatformSpec::commit
//! R_eff = R · (1 + γ · (K − 1))      (restart = full)
//! R_eff = R                          (restart = partial)
//! ```
//!
//! `γ = 0` is a perfectly parallel store (commit cost independent of
//! K); `γ = 1` is a fully serialized one (cost linear in K). Partial
//! restart models the scenario where only the *failed* nodes reload
//! their images from the last coordinated checkpoint while the
//! survivors roll back in place — the rollback itself is still global
//! (coordinated checkpointing has no message logging), so only the
//! recovery *cost* changes, not the lost work.
//!
//! Both effects are static scalings of the engine's `C`/`R`
//! parameters, applied once at session build (the engine's event loop
//! is unchanged). At K = 1 every mode collapses to the scenario's own
//! C and R — part of the 1-node bit-identity contract.

use super::{PlatformSpec, RestartScope};

/// The store's coordination cost model for one platform spec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointStore {
    nodes: u64,
    commit: f64,
    restart: RestartScope,
}

impl CheckpointStore {
    pub fn new(spec: &PlatformSpec) -> CheckpointStore {
        CheckpointStore { nodes: spec.nodes, commit: spec.commit, restart: spec.restart }
    }

    /// Contention factor for a coordinated K-node commit.
    fn factor(&self) -> f64 {
        1.0 + self.commit * (self.nodes.saturating_sub(1)) as f64
    }

    /// Effective duration of one coordinated checkpoint commit.
    pub fn commit_cost(&self, c: f64) -> f64 {
        c * self.factor()
    }

    /// Effective recovery duration after a fault.
    pub fn restart_cost(&self, r: f64) -> f64 {
        match self.restart {
            RestartScope::Full => r * self.factor(),
            // Only the failed nodes reload their images; the store
            // serves a constant number of readers regardless of K.
            RestartScope::Partial => r,
        }
    }
}

/// The `(C_eff, R_eff)` pair a platform session installs into its
/// [`crate::sim::SimConfig`].
pub fn effective_costs(spec: &PlatformSpec, c: f64, r: f64) -> (f64, f64) {
    let store = CheckpointStore::new(spec);
    (store.commit_cost(c), store.restart_cost(r))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_is_cost_neutral() {
        // K = 1: every (γ, restart) combination collapses to (C, R).
        for commit in [0.0, 0.3, 1.0] {
            for restart in [RestartScope::Full, RestartScope::Partial] {
                let spec = PlatformSpec { nodes: 1, commit, restart, ..PlatformSpec::default() };
                assert_eq!(effective_costs(&spec, 600.0, 450.0), (600.0, 450.0));
            }
        }
    }

    #[test]
    fn commit_contention_scales_linearly() {
        let spec = PlatformSpec { nodes: 5, commit: 0.25, ..PlatformSpec::default() };
        let (c_eff, r_eff) = effective_costs(&spec, 600.0, 600.0);
        assert_eq!(c_eff, 600.0 * 2.0); // 1 + 0.25 * 4
        assert_eq!(r_eff, 600.0 * 2.0); // full restart pays the same factor
    }

    #[test]
    fn partial_restart_only_reloads_the_failed_nodes() {
        let spec = PlatformSpec {
            nodes: 8,
            commit: 0.5,
            restart: RestartScope::Partial,
            ..PlatformSpec::default()
        };
        let (c_eff, r_eff) = effective_costs(&spec, 600.0, 450.0);
        assert_eq!(c_eff, 600.0 * 4.5); // commits still coordinate all 8
        assert_eq!(r_eff, 450.0); // recovery reads one image
    }

    #[test]
    fn zero_gamma_is_a_parallel_store() {
        let spec = PlatformSpec { nodes: 64, ..PlatformSpec::default() };
        assert_eq!(effective_costs(&spec, 600.0, 600.0), (600.0, 600.0));
    }
}
