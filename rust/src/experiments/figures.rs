//! Figures 4–7: waste of every heuristic vs platform size N, for both
//! literature predictors, both window sizes, analytical (capped and
//! uncapped) and simulated (Exponential, Weibull k = 0.7 and 0.5),
//! with the false-prediction trace drawn from the failure law
//! (Figs. 4/6) or a uniform law (Figs. 5/7).

use super::{paper_heuristics, scenario_for, sim_waste_grid, ExpOptions, ExperimentResult};
use crate::config::{paper_proc_counts, Predictor, Scenario};
use crate::model::{optimize_batched, Capping, Params, StrategyKind};
use crate::report::FigureData;
use crate::strategies::{best_period_with, spec_for, BestPeriodOptions, StrategySpec};

/// Predictor/false-trace parameters of each waste figure.
pub fn figure_params(id: &str) -> anyhow::Result<(f64, f64, bool)> {
    // (precision, recall, uniform false predictions)
    Ok(match id {
        "fig4" => (0.82, 0.85, false),
        "fig5" => (0.82, 0.85, true),
        "fig6" => (0.4, 0.7, false),
        "fig7" => (0.4, 0.7, true),
        other => anyhow::bail!("not a waste figure: {other}"),
    })
}

fn base_scenario(n: u64, precision: f64, recall: f64, i_win: f64, uniform_false: bool) -> Scenario {
    let mut s = Scenario::paper(n, Predictor::windowed(recall, precision, i_win));
    if uniform_false {
        s.false_pred_dist = Some(crate::dist::DistSpec::Uniform);
    }
    s
}

/// Analytical subfigure: per-strategy optimal waste vs N.
fn analytic_figure(
    id: &str,
    precision: f64,
    recall: f64,
    i_win: f64,
    capping: Capping,
) -> FigureData {
    let tag = match capping {
        Capping::Capped => "capped",
        Capping::Uncapped => "uncapped",
    };
    let mut fig = FigureData::new(format!("{id}-I{i_win}-analytic-{tag}"), "N", "waste");
    // One batched evaluation per heuristic across the whole N axis —
    // bit-identical to the per-point scalar `optimize` (model::batched).
    let c = 600.0;
    let ns = paper_proc_counts();
    for kind in paper_heuristics(i_win, c) {
        let params: Vec<Params> = ns
            .iter()
            .map(|&n| {
                let s = base_scenario(n, precision, recall, i_win, false);
                Params::from_scenario(&scenario_for(kind, &s))
            })
            .collect();
        for (n, (_, w)) in ns.iter().zip(optimize_batched(&params, kind, capping)) {
            fig.series_mut(kind.name()).push(*n as f64, w);
        }
    }
    fig
}

/// Simulated subfigure for one failure distribution.
fn simulated_figure(
    id: &str,
    precision: f64,
    recall: f64,
    i_win: f64,
    uniform_false: bool,
    dist: crate::dist::DistSpec,
    opts: &ExpOptions,
) -> FigureData {
    let mut fig = FigureData::new(
        format!("{id}-I{i_win}-sim-{}", dist.to_string().replace(':', "")),
        "N",
        "waste",
    );
    // One flattened (N, heuristic) × rep pool pass: the grid runner
    // strides the product across workers (the N = 2^19 runs process
    // ~30x more events than N = 2^14, so striding matters) and each
    // worker reuses one simulation session per point.
    let c = 600.0;
    let mut keys: Vec<(u64, StrategyKind)> = Vec::new();
    let mut points: Vec<(Scenario, StrategySpec)> = Vec::new();
    for n in paper_proc_counts() {
        for kind in paper_heuristics(i_win, c) {
            let mut s = base_scenario(n, precision, recall, i_win, uniform_false);
            s.fault_dist = dist;
            let sk = scenario_for(kind, &s);
            let spec = spec_for(kind, &sk, Capping::Uncapped);
            keys.push((n, kind));
            points.push((sk, spec));
        }
    }
    let sums = sim_waste_grid(&points, opts.reps, opts.workers);
    for ((n, kind), sum) in keys.iter().zip(&sums) {
        fig.series_mut(kind.name()).push(*n as f64, sum.mean());
    }
    // BestPeriod counterparts (brute-force; §5's quality check). Each
    // search parallelizes its own (candidate × rep) product internally.
    if opts.best_period {
        let bp_opts = BestPeriodOptions {
            workers: opts.workers,
            prune: true,
            replay: true,
            ..Default::default()
        };
        for ((n, kind), (s, spec)) in keys.iter().zip(&points) {
            let res = best_period_with(s, spec, opts.bp_reps, opts.bp_candidates, &bp_opts)
                .expect("best-period search failed");
            fig.series_mut(&format!("BestPeriod:{}", kind.name()))
                .push(*n as f64, res.waste);
        }
    }
    fig
}

/// One of Figures 4–7: ten subfigures ((a)–(j) in the paper).
pub fn figure_waste(id: &str, opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let (precision, recall, uniform_false) = figure_params(id)?;
    let mut result = ExperimentResult::default();
    for i_win in [300.0, 3000.0] {
        result.figures.push(analytic_figure(id, precision, recall, i_win, Capping::Capped));
        result.figures.push(analytic_figure(id, precision, recall, i_win, Capping::Uncapped));
        for dist in [
            crate::dist::DistSpec::Exp,
            crate::dist::DistSpec::weibull(0.7),
            crate::dist::DistSpec::weibull(0.5),
        ] {
            result.figures.push(simulated_figure(
                id,
                precision,
                recall,
                i_win,
                uniform_false,
                dist,
                opts,
            ));
        }
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_params_table() {
        assert_eq!(figure_params("fig4").unwrap(), (0.82, 0.85, false));
        assert_eq!(figure_params("fig7").unwrap(), (0.4, 0.7, true));
        assert!(figure_params("fig8").is_err());
    }

    #[test]
    fn analytic_figure_shapes() {
        let fig = analytic_figure("fig4", 0.82, 0.85, 300.0, Capping::Uncapped);
        // 4 heuristics (I < C: no WithCkptI), 6 platform sizes each.
        assert_eq!(fig.series.len(), 4);
        for s in &fig.series {
            assert_eq!(s.points.len(), 6);
        }
        // Waste increases with N for every strategy.
        for s in &fig.series {
            for w in s.points.windows(2) {
                assert!(w[1].1 >= w[0].1 - 1e-9, "{}: {:?}", s.label, s.points);
            }
        }
    }

    #[test]
    fn analytic_prediction_dominates_uncapped() {
        let fig = analytic_figure("fig4", 0.82, 0.85, 300.0, Capping::Uncapped);
        let young = fig.get("Young").unwrap();
        let exact = fig.get("ExactPrediction").unwrap();
        for (y, e) in young.points.iter().zip(&exact.points) {
            assert!(e.1 <= y.1 + 1e-9);
        }
    }
}
