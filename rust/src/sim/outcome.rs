//! Result of one simulated execution.

/// Everything the experiment harness wants to know about one run.
#[derive(Debug, Clone, Default)]
pub struct Outcome {
    /// Wall-clock time to complete the job (s).
    pub makespan: f64,
    /// Useful work completed (== the configured W when `completed`).
    pub work: f64,
    /// Whether the job finished before the makespan guard.
    pub completed: bool,

    /// Faults that struck the application (excluding migrated-away ones).
    pub n_faults: u64,
    /// ... of which were unpredicted (false negatives).
    pub n_faults_unpredicted: u64,
    /// Predictions seen (true + false positives).
    pub n_preds: u64,
    /// ... of which were true positives.
    pub n_true_preds: u64,
    /// Predictions the policy decided to trust.
    pub n_trusted: u64,
    /// Regular-mode checkpoints completed.
    pub n_ckpts: u64,
    /// Proactive checkpoints completed (pre-window + in-window).
    pub n_proactive_ckpts: u64,
    /// Successful preventive migrations.
    pub n_migrations: u64,
    /// Faults avoided by migration.
    pub n_faults_avoided: u64,
    /// Work lost to faults (volatile work destroyed), total (s).
    pub lost_work: f64,
    /// Engine segments processed — the simulator's own throughput unit.
    pub n_segments: u64,

    /// Wall-clock seconds the engine itself spent (set by the runner).
    pub sim_seconds: f64,
}

impl Outcome {
    /// WASTE = fraction of time not spent on useful work (§2.1).
    pub fn waste(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            1.0 - self.work / self.makespan
        }
    }

    /// Conservation check: total time = work + waste components.
    /// (Exact identity; used by property tests.)
    pub fn overhead(&self) -> f64 {
        self.makespan - self.work
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waste_formula() {
        let o = Outcome { makespan: 200.0, work: 150.0, completed: true, ..Default::default() };
        assert!((o.waste() - 0.25).abs() < 1e-12);
        assert!((o.overhead() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn zero_makespan_guard() {
        let o = Outcome::default();
        assert_eq!(o.waste(), 0.0);
    }
}
