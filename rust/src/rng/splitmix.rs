//! SplitMix64 (Steele, Lea, Flood 2014) — used only to expand seeds and
//! derive independent streams; never for the simulation draws themselves.

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // First outputs for seed 0 (cross-checked against the reference
        // implementation in the Vigna/SplitMix literature).
        let mut s = SplitMix64::new(0);
        assert_eq!(s.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(s.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(s.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
