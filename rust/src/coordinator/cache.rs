//! Bounded LRU memoization of pure job responses.
//!
//! Plan, BestPeriod and Sweep answers are pure functions of their
//! canonicalized request ([`super::canon`]): the closed forms are
//! deterministic arithmetic, and the Monte Carlo searches are seeded
//! and keyed on every reproducibility knob (seed, reps, fold width).
//! **Staleness is therefore impossible** — a cached response can never
//! disagree with a recomputed one — so the only thing this cache
//! manages is capacity. Eviction is plain least-recently-used.
//!
//! Shared across [`crate::api::Executor`] clones (one cache per
//! service), panic-safe (a poisoned inner lock is taken over rather
//! than propagated, like every other coordinator lock), and counted:
//! hits, misses and evictions feed `ServiceStats` and the CLI.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::metrics::lock_unpoisoned;
use crate::api::JobResponse;

/// Point-in-time cache counters, as reported on `stats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: u64,
}

struct Entry {
    resp: JobResponse,
    /// Logical timestamp of the last touch; the smallest one is the
    /// LRU victim.
    used: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    /// Monotone logical clock for recency stamps.
    tick: u64,
}

/// The memoized response store. `capacity == 0` disables it: every
/// lookup misses without counting, every insert is dropped.
pub struct PlanCache {
    inner: Mutex<Inner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Look one key up, refreshing its recency on a hit. Counts the
    /// hit or miss (a disabled cache counts nothing — it is absent,
    /// not cold).
    pub fn get(&self, key: &str) -> Option<JobResponse> {
        if !self.enabled() {
            return None;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.resp.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) one entry, evicting the least-recently-used
    /// entry if the capacity bound would be exceeded.
    pub fn put(&self, key: String, resp: JobResponse) {
        if !self.enabled() {
            return;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if !inner.map.contains_key(&key) && inner.map.len() >= self.capacity {
            // O(n) victim scan: evictions only happen on misses past
            // capacity, and the map is small (hundreds of entries), so
            // a scan beats the bookkeeping of an intrusive LRU list.
            if let Some(victim) =
                inner.map.iter().min_by_key(|(_, e)| e.used).map(|(k, _)| k.clone())
            {
                inner.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.map.insert(key, Entry { resp, used: tick });
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        let entries = lock_unpoisoned(&self.inner).map.len() as u64;
        CacheSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(tag: &str) -> JobResponse {
        JobResponse::Error(crate::api::ApiError::bad_request(tag))
    }

    #[test]
    fn hit_returns_the_inserted_response_and_counts() {
        let c = PlanCache::new(4);
        assert!(c.get("a").is_none());
        c.put("a".into(), resp("a"));
        assert_eq!(c.get("a"), Some(resp("a")));
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (1, 1, 0, 1));
    }

    #[test]
    fn eviction_removes_the_least_recently_used_entry() {
        let c = PlanCache::new(2);
        c.put("a".into(), resp("a"));
        c.put("b".into(), resp("b"));
        // Touch "a" so "b" becomes the LRU victim.
        assert!(c.get("a").is_some());
        c.put("c".into(), resp("c"));
        assert!(c.get("a").is_some(), "recently used survives");
        assert!(c.get("b").is_none(), "LRU entry evicted");
        assert!(c.get("c").is_some());
        let s = c.snapshot();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn refreshing_an_existing_key_does_not_evict() {
        let c = PlanCache::new(2);
        c.put("a".into(), resp("a"));
        c.put("b".into(), resp("b"));
        c.put("a".into(), resp("a2"));
        let s = c.snapshot();
        assert_eq!(s.evictions, 0);
        assert_eq!(s.entries, 2);
        assert_eq!(c.get("a"), Some(resp("a2")), "refresh replaces the payload");
    }

    #[test]
    fn zero_capacity_disables_everything() {
        let c = PlanCache::new(0);
        c.put("a".into(), resp("a"));
        assert!(c.get("a").is_none());
        assert_eq!(c.snapshot(), CacheSnapshot::default());
    }
}
