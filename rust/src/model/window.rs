//! Prediction-window machinery: the proactive period T_P (Eq. 7) with
//! its integer snapping, and the Eq. (12) dominance condition between
//! NoCkptI and WithCkptI.

use super::Params;

/// Unsnapped extremum of the proactive period (Eq. 7):
/// T_P^extr = sqrt( ((1-p) I + p E_I^f) / p * C ).
pub fn tp_extr(p: &Params) -> f64 {
    (p.i1() / p.precision.max(1e-12) * p.c).max(0.0).sqrt()
}

/// The T_P-dependent share of WASTE_WithCkptI (up to the rq/mu factor):
/// (I1/p) C / T_P + T_P. Convex with minimum at [`tp_extr`].
pub fn tp_share(p: &Params, tp: f64) -> f64 {
    p.i1() / p.precision.max(1e-12) * p.c / tp + tp
}

/// Snapped optimal proactive period (§4.3): choose between I/k and
/// I/(k+1) with k = floor(I / T_P^extr), subject to T_P >= C.
pub fn tp_opt(p: &Params) -> f64 {
    let extr = tp_extr(p).max(1e-9);
    if p.i <= 0.0 {
        return p.c.max(extr);
    }
    let k = (p.i / extr).floor().max(1.0);
    let cand1 = p.i / k;
    let cand2 = p.i / (k + 1.0);
    let mut tp = if tp_share(p, cand1) <= tp_share(p, cand2) { cand1 } else { cand2 };
    if tp < p.c {
        // Both candidates below C ⇒ T_P = C (paper); if only cand2 is,
        // cand1 is the wider divisor and already >= C.
        tp = cand1.max(p.c);
    }
    tp.max(p.c)
}

/// Eq. (12): sufficient condition under which NoCkptI dominates
/// WithCkptI (it is *not* worth checkpointing inside the window):
/// 2 sqrt( (I1/p) C ) >= E_I^f.
///
/// (The paper's display squares the right-hand side; the derivation —
/// evaluate Eq. (11) at T_P^extr — gives the unsquared form used here.)
pub fn nockpt_dominates(p: &Params) -> bool {
    2.0 * tp_extr(p) >= p.ef
}

/// The uniform-fault specialization quoted by the paper:
/// with E_I^f = I/2 the condition becomes I <= 16 (1 - p/2)/p * C.
pub fn nockpt_dominates_uniform(p: &Params) -> bool {
    p.i <= 16.0 * (1.0 - p.precision / 2.0) / p.precision.max(1e-12) * p.c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Predictor, Scenario};
    use crate::model::waste::{waste_nockpt, waste_withckpt};
    use crate::util::approx_eq;

    fn params(recall: f64, precision: f64, window: f64) -> Params {
        Params::from_scenario(&Scenario::paper(
            1 << 16,
            Predictor::windowed(recall, precision, window),
        ))
    }

    #[test]
    fn tp_opt_divides_window() {
        for window in [1200.0, 3000.0, 6000.0, 14400.0] {
            let p = params(0.85, 0.82, window);
            let tp = tp_opt(&p);
            let k = window / tp;
            assert!((k - k.round()).abs() < 1e-9, "I={window} tp={tp} k={k}");
            assert!(tp >= p.c - 1e-9);
        }
    }

    #[test]
    fn tp_opt_beats_other_divisors() {
        let p = params(0.7, 0.4, 6000.0);
        let tp = tp_opt(&p);
        let best_share = tp_share(&p, tp);
        for k in 1..20 {
            let cand = 6000.0 / k as f64;
            if cand >= p.c {
                assert!(best_share <= tp_share(&p, cand) + 1e-9, "k={k}");
            }
        }
    }

    #[test]
    fn tp_small_window_clamps_to_c() {
        let p = params(0.85, 0.82, 700.0); // barely above C = 600
        let tp = tp_opt(&p);
        assert!(approx_eq(tp, 700.0, 1e-9), "tp={tp}"); // I/1, >= C
    }

    #[test]
    fn eq12_consistent_with_direct_comparison() {
        // When Eq. (12) holds, WithCkptI at its *optimal* T_P is no
        // better than NoCkptI (compare the T_R-independent difference).
        for (r, p_, i) in [(0.85, 0.82, 3000.0), (0.7, 0.4, 3000.0), (0.85, 0.82, 300.0)] {
            let p = params(r, p_, i);
            let tp = tp_opt(&p);
            let diff = waste_withckpt(&p, 5000.0, tp) - waste_nockpt(&p, 5000.0);
            if nockpt_dominates(&p) {
                assert!(diff >= -1e-9, "r={r} p={p_} I={i}: diff={diff}");
            }
        }
    }

    #[test]
    fn uniform_condition_matches_general_form() {
        // With Ef = I/2 both formulations must agree.
        for (p_, i) in [(0.4, 3000.0), (0.82, 3000.0), (0.82, 200000.0), (0.9, 80000.0)] {
            let p = params(0.8, p_, i);
            assert_eq!(
                nockpt_dominates(&p),
                nockpt_dominates_uniform(&p),
                "p={p_} I={i}"
            );
        }
    }

    #[test]
    fn paper_i300_and_i3000_satisfy_eq12() {
        // For both §5 predictors at I = 300 s and 3000 s the uniform
        // condition holds (I <= 16 (1-p/2)/p C with C = 600).
        for (r, p_) in [(0.85, 0.82), (0.7, 0.4)] {
            for i in [300.0, 3000.0] {
                assert!(nockpt_dominates(&params(r, p_, i)), "r={r} p={p_} I={i}");
            }
        }
    }
}
