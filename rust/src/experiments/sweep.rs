//! Figures 8–11: the recall-vs-precision study. One predictor parameter
//! is fixed while the other sweeps 0.3 → 0.99, at N = 2^16 and 2^19,
//! I = 300 s, Weibull failures (k = 0.7 for Figs. 8/10, 0.5 for 9/11).
//!
//! The paper's headline conclusion — recall matters far more than
//! precision — falls out of these plots.

use super::{scenario_for, sim_waste, sim_waste_grid, ExpOptions, ExperimentResult};
use crate::config::{Predictor, Scenario};
use crate::model::{Capping, StrategyKind};
use crate::report::FigureData;
use crate::strategies::{spec_for, StrategySpec};

/// Which sweep a figure id denotes.
pub fn sweep_params(id: &str) -> anyhow::Result<(f64, bool)> {
    // (weibull shape, sweep_precision?) — sweep_precision=true fixes r
    // and varies p (Figs. 8/9); false fixes p and varies r (Figs. 10/11).
    Ok(match id {
        "fig8" => (0.7, true),
        "fig9" => (0.5, true),
        "fig10" => (0.7, false),
        "fig11" => (0.5, false),
        other => anyhow::bail!("not a sweep figure: {other}"),
    })
}

/// The swept axis values.
pub fn sweep_axis() -> Vec<f64> {
    vec![0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.99]
}

pub fn figure_sweep(id: &str, opts: &ExpOptions) -> anyhow::Result<ExperimentResult> {
    let (k, sweep_precision) = sweep_params(id)?;
    let dist = crate::dist::DistSpec::weibull(k);
    let fixed_values = [0.4, 0.8];
    let i_win = 300.0;
    let mut result = ExperimentResult::default();
    for n in [1u64 << 16, 1u64 << 19] {
        let axis_name = if sweep_precision { "precision" } else { "recall" };
        let mut fig = FigureData::new(
            format!("{id}-N2e{}", n.trailing_zeros()),
            axis_name,
            "waste",
        );
        // Young reference: independent of the predictor.
        {
            let mut s = Scenario::paper(n, Predictor::none());
            s.fault_dist = dist;
            let w = sim_waste(&s, StrategyKind::Young, opts).mean();
            for x in sweep_axis() {
                fig.series_mut("Young").push(x, w);
            }
        }
        // Flatten every (fixed, x) predictor point of this subfigure
        // into one grid pass so the pool sees the whole product at once
        // instead of a barrier per point.
        let mut labels: Vec<(String, f64)> = Vec::new();
        let mut points: Vec<(Scenario, StrategySpec)> = Vec::new();
        for fixed in fixed_values {
            let label = if sweep_precision {
                format!("NoCkptI r={fixed}")
            } else {
                format!("NoCkptI p={fixed}")
            };
            for x in sweep_axis() {
                let (recall, precision) =
                    if sweep_precision { (fixed, x) } else { (x, fixed) };
                let mut s = Scenario::paper(n, Predictor::windowed(recall, precision, i_win));
                s.fault_dist = dist;
                let sk = scenario_for(StrategyKind::NoCkptI, &s);
                let spec = spec_for(StrategyKind::NoCkptI, &sk, Capping::Uncapped);
                labels.push((label.clone(), x));
                points.push((sk, spec));
            }
        }
        let sums = sim_waste_grid(&points, opts.reps, opts.workers);
        for ((label, x), sum) in labels.iter().zip(&sums) {
            fig.series_mut(label).push(*x, sum.mean());
        }
        result.figures.push(fig);
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_table() {
        assert_eq!(sweep_params("fig8").unwrap(), (0.7, true));
        assert_eq!(sweep_params("fig11").unwrap(), (0.5, false));
        assert!(sweep_params("fig4").is_err());
    }

    #[test]
    fn axis_range() {
        let axis = sweep_axis();
        assert_eq!(axis.first(), Some(&0.3));
        assert_eq!(axis.last(), Some(&0.99));
        assert!(axis.windows(2).all(|w| w[0] < w[1]));
    }
}
