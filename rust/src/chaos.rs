//! Deterministic, seeded fault-injection harness.
//!
//! The paper argues fault-handling discipline must be *analyzed, not
//! assumed*; this module applies the same standard to the service's own
//! degradation paths. Production code registers named injection points
//! (`Point`) at the spots where the outside world can hurt us — the
//! service read/write path, pool task entry, trace-bank reservation and
//! replay — and a test installs a [`ChaosPlan`] describing which hits of
//! which point misbehave and how ([`Action`]). Everything is counted
//! and seeded, so a failing chaos test replays exactly.
//!
//! The whole module (and every call site, via the same `cfg`) compiles
//! only under `cfg(any(test, feature = "chaos"))`: release builds carry
//! zero chaos code and the clean path stays bit-identical.
//!
//! With no plan installed every hook is a no-op, which is what the
//! clean-path golden test in `tests/test_chaos.rs` pins.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use crate::rng::Pcg64;

/// A named injection point in production code.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Point {
    /// A full line received by the service, before decoding.
    ServiceRead,
    /// Just before the service writes a response line.
    ServiceWrite,
    /// Entry of a pool worker task (`run_parallel*` closures).
    PoolTask,
    /// `TraceBank::try_reserve` admission decision.
    BankReserve,
    /// `ReplaySource::reset` span lookup.
    BankReplay,
}

impl Point {
    fn id(self) -> u64 {
        match self {
            Point::ServiceRead => 1,
            Point::ServiceWrite => 2,
            Point::PoolTask => 3,
            Point::BankReserve => 4,
            Point::BankReplay => 5,
        }
    }
}

/// What a tripped injection point does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Truncate the line mid-byte (ServiceRead).
    TornLine,
    /// Pad the line past `wire::MAX_LINE_BYTES` (ServiceRead).
    OversizedLine,
    /// Sleep this many milliseconds first (ServiceRead/ServiceWrite):
    /// a slow-loris peer.
    SlowRead(u64),
    /// Panic at the point (PoolTask, ServiceRead).
    Panic,
    /// Refuse the reservation as if over the 256 MiB budget
    /// (BankReserve).
    DeclineBank,
    /// Report a missing span, forcing the underrun path (BankReplay).
    Underrun,
}

#[derive(Debug, Clone)]
enum HitSpec {
    /// Fire on these exact hit indices (0-based).
    At(Vec<u64>),
    /// Fire on each hit independently with probability `p`, from a
    /// PCG stream keyed on (seed, point, hit) — deterministic across
    /// runs and independent of thread interleaving.
    Prob { seed: u64, p: f64 },
}

#[derive(Debug, Clone)]
struct Rule {
    point: Point,
    hits: HitSpec,
    action: Action,
}

/// A schedule of injections: which hits of which points misbehave.
#[derive(Debug, Clone, Default)]
pub struct ChaosPlan {
    rules: Vec<Rule>,
}

impl ChaosPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fire `action` on the given 0-based hit indices of `point`.
    pub fn at(mut self, point: Point, hits: &[u64], action: Action) -> Self {
        self.rules.push(Rule { point, hits: HitSpec::At(hits.to_vec()), action });
        self
    }

    /// Fire `action` on each hit of `point` independently with
    /// probability `p`, deterministically derived from `seed`.
    pub fn with_prob(mut self, point: Point, seed: u64, p: f64, action: Action) -> Self {
        self.rules.push(Rule { point, hits: HitSpec::Prob { seed, p }, action });
        self
    }

    fn action_for(&self, point: Point, hit: u64) -> Option<Action> {
        self.rules.iter().find_map(|r| {
            if r.point != point {
                return None;
            }
            let fire = match &r.hits {
                HitSpec::At(idxs) => idxs.contains(&hit),
                HitSpec::Prob { seed, p } => {
                    Pcg64::new(seed ^ point.id().wrapping_mul(0x9e3779b97f4a7c15), hit).next_f64()
                        < *p
                }
            };
            fire.then_some(r.action)
        })
    }
}

struct ChaosState {
    plan: ChaosPlan,
    hits: BTreeMap<Point, u64>,
    fired: Vec<(Point, u64, Action)>,
}

static STATE: Mutex<Option<ChaosState>> = Mutex::new(None);

fn state() -> MutexGuard<'static, Option<ChaosState>> {
    // A panic injected *while holding* this lock never happens (hooks
    // release it before acting), but a panicking test elsewhere must
    // not wedge the harness: tolerate poison.
    STATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Install a plan, replacing any previous one and zeroing hit counters.
pub fn install(plan: ChaosPlan) {
    *state() = Some(ChaosState { plan, hits: BTreeMap::new(), fired: Vec::new() });
}

/// Remove the plan: every hook becomes a no-op again.
pub fn reset() {
    *state() = None;
}

/// The injections that actually fired, in order: (point, hit, action).
pub fn fired() -> Vec<(Point, u64, Action)> {
    state().as_ref().map(|s| s.fired.clone()).unwrap_or_default()
}

/// Record a hit at `point` and return the scheduled action, if any.
/// With no plan installed this is a no-op returning `None`.
pub fn hit(point: Point) -> Option<Action> {
    let mut guard = state();
    let s = guard.as_mut()?;
    let n = s.hits.entry(point).or_insert(0);
    let idx = *n;
    *n += 1;
    let action = s.plan.action_for(point, idx)?;
    s.fired.push((point, idx, action));
    Some(action)
}

// ---------------------------------------------------------------------------
// Convenience wrappers, one per production call site.
// ---------------------------------------------------------------------------

/// ServiceRead hook: possibly mangle (or stall on, or panic over) a
/// decoded request line.
pub fn mangle_service_read(line: String) -> String {
    match hit(Point::ServiceRead) {
        None => line,
        Some(Action::TornLine) => {
            let cut = line.len() / 2;
            let mut cut_at = cut.min(line.len());
            // Tear on a char boundary so the result is still a String.
            while cut_at > 0 && !line.is_char_boundary(cut_at) {
                cut_at -= 1;
            }
            line[..cut_at].to_string()
        }
        Some(Action::OversizedLine) => {
            let mut big = line;
            let target = crate::api::wire::MAX_LINE_BYTES + 1;
            while big.len() <= target {
                big.push(' ');
            }
            big
        }
        Some(Action::SlowRead(ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            line
        }
        Some(Action::Panic) => panic!("chaos: injected panic at ServiceRead"),
        Some(_) => line,
    }
}

/// ServiceWrite hook: stall or panic just before a response goes out.
pub fn on_service_write() {
    match hit(Point::ServiceWrite) {
        Some(Action::SlowRead(ms)) => std::thread::sleep(Duration::from_millis(ms)),
        Some(Action::Panic) => panic!("chaos: injected panic at ServiceWrite"),
        _ => {}
    }
}

/// PoolTask hook: panic inside a worker task.
pub fn on_pool_task() {
    if let Some(Action::Panic) = hit(Point::PoolTask) {
        panic!("chaos: injected panic at PoolTask");
    }
}

/// BankReserve hook: true means "pretend the 256 MiB budget is blown".
pub fn deny_bank_reserve() -> bool {
    matches!(hit(Point::BankReserve), Some(Action::DeclineBank))
}

/// BankReplay hook: true forces the missing-span (underrun) path.
pub fn force_underrun() -> bool {
    matches!(hit(Point::BankReplay), Some(Action::Underrun))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unit tests share the process-global plan with every other test in
    /// this binary (pool/bank tests hit `PoolTask`/`BankReserve`/
    /// `BankReplay` concurrently), so the plans installed here touch only
    /// the `ServiceRead`/`ServiceWrite` points, which nothing else in the
    /// lib test binary exercises. Chaos tests themselves serialize on a
    /// gate. (`tests/test_chaos.rs` is a separate process, so no
    /// cross-talk there.)
    static GATE: Mutex<()> = Mutex::new(());

    fn locked() -> MutexGuard<'static, ()> {
        GATE.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn no_plan_is_a_noop() {
        let _g = locked();
        reset();
        assert_eq!(hit(Point::ServiceWrite), None);
        assert_eq!(mangle_service_read("hello".into()), "hello");
        assert!(!deny_bank_reserve());
        assert!(!force_underrun());
        reset();
    }

    #[test]
    fn explicit_hits_fire_in_order() {
        let _g = locked();
        install(ChaosPlan::new().at(Point::ServiceWrite, &[1, 3], Action::Panic));
        assert_eq!(hit(Point::ServiceWrite), None); // hit 0
        assert_eq!(hit(Point::ServiceWrite), Some(Action::Panic)); // hit 1
        assert_eq!(hit(Point::ServiceWrite), None); // hit 2
        assert_eq!(hit(Point::ServiceWrite), Some(Action::Panic)); // hit 3
        let service_fires: Vec<_> =
            fired().into_iter().filter(|(p, _, _)| *p == Point::ServiceWrite).collect();
        assert_eq!(
            service_fires,
            vec![
                (Point::ServiceWrite, 1, Action::Panic),
                (Point::ServiceWrite, 3, Action::Panic),
            ]
        );
        reset();
    }

    #[test]
    fn probabilistic_schedule_is_reproducible() {
        let _g = locked();
        let run = || {
            install(ChaosPlan::new().with_prob(Point::ServiceWrite, 42, 0.3, Action::Panic));
            let pattern: Vec<bool> =
                (0..64).map(|_| hit(Point::ServiceWrite).is_some()).collect();
            reset();
            pattern
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed must give the same schedule");
        let fires = a.iter().filter(|x| **x).count();
        assert!(fires > 5 && fires < 40, "p=0.3 over 64 hits fired {fires} times");
        reset();
    }

    #[test]
    fn torn_and_oversized_lines() {
        let _g = locked();
        install(
            ChaosPlan::new()
                .at(Point::ServiceRead, &[0], Action::TornLine)
                .at(Point::ServiceRead, &[1], Action::OversizedLine),
        );
        let torn = mangle_service_read(r#"{"op":"ping"}"#.into());
        assert!(torn.len() < 13, "torn: {torn:?}");
        let big = mangle_service_read("{}".into());
        assert!(big.len() > crate::api::wire::MAX_LINE_BYTES);
        reset();
    }
}
