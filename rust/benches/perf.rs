//! Performance benches (`cargo bench --bench perf`): the §Perf numbers
//! of EXPERIMENTS.md.
//!
//!   planner              AOT XLA planner latency/throughput, B = 1 vs B = 64
//!   batcher              dynamic batcher under concurrent clients
//!   sim                  simulation engine event throughput (session path)
//!   session_vs_oneshot   SimSession reuse vs naive per-rep construction
//!   bank_replay_vs_live  TraceBank replay vs live trace generation
//!   pool                 worker-pool scaling (streaming fold + sessions)
//!   best_period          brute-force period search, 1 worker vs all
//!   best_period_crn      replay-backed sweep vs live sweep at equal reps
//!   lockstep_vs_scalar   lockstep batch engine vs scalar replay over one bank
//!   wide_vs_lockstep     wide SoA kernel vs lockstep vs scalar over one bank
//!   platform_step        multi-node platform source vs the classic engine
//!   model                closed-form planner throughput (the non-AOT baseline)
//!   waste_grid_batched   batched closed-form grid vs the per-row plan loop
//!   waste_grid_accel     HLO-batcher waste grid vs the batched CPU pass
//!
//! Every run also emits `BENCH_perf.json` (one object per executed
//! bench, schema documented in EXPERIMENTS.md §Perf) so the perf
//! trajectory is machine-readable across PRs.

use std::time::Instant;

use ckptfp::config::{paper_proc_counts, predictor_yu, Predictor, Scenario};
use ckptfp::dist::DistSpec;
use ckptfp::coordinator::{run_parallel_fold, Batcher, BatcherConfig};
use ckptfp::model::{plan, Capping, Params, StrategyKind};
use ckptfp::runtime::HloPlanner;
use ckptfp::sim::{simulate_once, BatchEngine, BatchOptions, BatchRunner, SimSession, WideKernel};
use ckptfp::strategies::{best_period_with, spec_for, BestPeriodOptions};
use ckptfp::util::json::Json;
use ckptfp::util::stats::Summary;

/// Collects per-bench results for the BENCH_perf.json dump.
#[derive(Default)]
struct Recorder {
    entries: Vec<(String, Json)>,
}

impl Recorder {
    fn push(&mut self, bench: &str, fields: Vec<(&str, Json)>) {
        self.entries.push((bench.to_string(), Json::obj(fields)));
    }

    fn write(&self, path: &str) {
        let mut top = vec![
            ("schema".to_string(), Json::Str("ckptfp-perf-v1".into())),
            (
                "workers_available".to_string(),
                Json::Num(ckptfp::coordinator::available_workers() as f64),
            ),
        ];
        for (k, v) in &self.entries {
            top.push((k.clone(), v.clone()));
        }
        let json = Json::Obj(top.into_iter().collect());
        match std::fs::write(path, json.to_string() + "\n") {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn time<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  {label:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

/// Run `f(rep)` (returning engine segments) repeatedly for ~`secs`
/// wall-clock; yields (M segments/s, runs, seconds).
fn segment_throughput<F: FnMut(u64) -> u64>(mut f: F, secs: f64) -> (f64, u64, f64) {
    f(0); // warmup
    let t0 = Instant::now();
    let mut segments = 0u64;
    let mut rep = 0u64;
    while t0.elapsed().as_secs_f64() < secs {
        segments += f(rep);
        rep += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    (segments as f64 / dt / 1e6, rep, dt)
}

fn params_batch(n: usize) -> Vec<Params> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let procs = paper_proc_counts()[i % 6];
        let s = Scenario::paper(procs, predictor_yu(300.0));
        out.push(Params::from_scenario(&s));
    }
    out
}

fn bench_planner(rec: &mut Recorder) {
    println!("== planner (AOT XLA via PJRT) ==");
    let mut planner = match HloPlanner::open_default() {
        Ok(p) => p,
        Err(e) => {
            println!("  skipped: {e}");
            rec.push("planner", vec![("skipped", Json::Bool(true))]);
            return;
        }
    };
    let one = params_batch(1);
    let sixty_four = params_batch(64);
    let t1 = time("plan_batch B=1", 50, || {
        planner.plan_batch(&one).expect("plan");
    });
    let t64 = time("plan_batch B=64", 50, || {
        planner.plan_batch(&sixty_four).expect("plan");
    });
    let efficiency = t1 / (t64 / 64.0);
    println!("  batching efficiency: {efficiency:.1}x per-config speedup (B=64 vs B=1)");
    println!("  per-config latency at B=64: {:.1} us", t64 / 64.0 * 1e6);
    rec.push(
        "planner",
        vec![
            ("b1_ms", Json::Num(t1 * 1e3)),
            ("b64_ms", Json::Num(t64 * 1e3)),
            ("batching_efficiency", Json::Num(efficiency)),
        ],
    );
}

fn bench_batcher(rec: &mut Recorder) {
    println!("== dynamic batcher (concurrent clients) ==");
    let batcher = match Batcher::spawn(
        HloPlanner::open_default,
        BatcherConfig { max_batch: 64, max_delay: std::time::Duration::from_millis(2), ..Default::default() },
    ) {
        Ok(b) => b,
        Err(e) => {
            println!("  skipped: {e}");
            rec.push("batcher", vec![("skipped", Json::Bool(true))]);
            return;
        }
    };
    let mut fields: Vec<(&str, Json)> = Vec::new();
    let labels = ["plans_per_s_c1", "plans_per_s_c8", "plans_per_s_c64"];
    for (clients, label) in [1usize, 8, 64].into_iter().zip(labels) {
        let reqs = params_batch(clients);
        let t0 = Instant::now();
        let rounds = 20;
        for _ in 0..rounds {
            std::thread::scope(|s| {
                for p in &reqs {
                    let b = batcher.clone();
                    s.spawn(move || b.plan(*p).expect("plan"));
                }
            });
        }
        let total = (clients * rounds) as f64;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {clients:>3} concurrent clients: {:>8.0} plans/s  ({:.2} ms/plan observed)",
            total / dt,
            dt / rounds as f64 * 1e3
        );
        fields.push((label, Json::Num(total / dt)));
    }
    let stats = batcher.stats();
    println!(
        "  batches formed: {} for {} requests (max batch {})",
        stats.batches, stats.requests, stats.max_batch_seen
    );
    rec.push("batcher", fields);
    batcher.shutdown();
}

fn bench_sim(rec: &mut Recorder) {
    println!("== simulation engine (session path) ==");
    let mut fields: Vec<(&str, Json)> = Vec::new();
    for (label, key, n, dist) in [
        ("N=2^16 weibull:0.7", "msegs_n16_weibull07", 1u64 << 16, DistSpec::weibull(0.7)),
        ("N=2^19 weibull:0.7", "msegs_n19_weibull07", 1u64 << 19, DistSpec::weibull(0.7)),
        ("N=2^19 exp", "msegs_n19_exp", 1u64 << 19, DistSpec::Exp),
    ] {
        let mut s = Scenario::paper(n, predictor_yu(300.0));
        s.fault_dist = dist;
        let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
        let mut session = SimSession::new(&s, &spec).expect("session");
        let (msegs, runs, dt) = segment_throughput(|rep| session.run(rep).n_segments, 1.0);
        println!(
            "  {label:<24} {:>6.2} M segments/s  ({:.1} sim-years/s, {} runs)",
            msegs,
            runs as f64 * s.work / (365.25 * 86400.0) / dt,
            runs
        );
        fields.push((key, Json::Num(msegs)));
    }
    rec.push("sim", fields);
}

fn bench_session_vs_oneshot(rec: &mut Recorder) {
    println!("== session reuse vs one-shot construction ==");
    // The BestPeriod-shaped workload: many replications of one
    // (scenario, spec) pair. The one-shot path re-parses the spec
    // strings and rebuilds generator + engine (and their buffers) every
    // replication; the session path pays that once.
    let mut s = Scenario::paper(1 << 19, predictor_yu(300.0));
    s.fault_dist = DistSpec::weibull(0.7);
    let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);

    let (oneshot_msegs, oneshot_runs, _) =
        segment_throughput(|rep| simulate_once(&s, &spec, rep).expect("sim").n_segments, 1.5);
    let mut session = SimSession::new(&s, &spec).expect("session");
    let (session_msegs, session_runs, _) =
        segment_throughput(|rep| session.run(rep).n_segments, 1.5);
    let speedup = session_msegs / oneshot_msegs;
    println!("  one-shot simulate_once loop  {oneshot_msegs:>6.2} M segments/s ({oneshot_runs} runs)");
    println!("  SimSession::run loop         {session_msegs:>6.2} M segments/s ({session_runs} runs)");
    println!("  session speedup: {speedup:.2}x");
    rec.push(
        "session_vs_oneshot",
        vec![
            ("oneshot_msegments_per_s", Json::Num(oneshot_msegs)),
            ("session_msegments_per_s", Json::Num(session_msegs)),
            ("speedup", Json::Num(speedup)),
        ],
    );
}

fn bench_bank_replay(rec: &mut Recorder) {
    println!("== trace-bank replay vs live generation ==");
    // Same (scenario, policy) replicated two ways: a live session
    // re-samples the fault/prediction streams every run; a replay
    // session walks the bank's arena. The outcomes are bit-identical
    // (pinned by tests); the delta is pure sampling cost.
    let mut s = Scenario::paper(1 << 19, predictor_yu(300.0));
    s.fault_dist = DistSpec::weibull(0.7);
    let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    let policy = ckptfp::sim::Policy::from_spec(&spec, s.platform.c);
    let lead = spec.required_lead(s.platform.c);

    let mut live = SimSession::new(&s, &spec).expect("session");
    let (live_msegs, live_runs, _) = segment_throughput(|rep| live.run(rep).n_segments, 1.5);

    // Bank sized to stay inside the arena cap at this platform's fault
    // rate; replays cycle through its reps.
    let bank_reps = 256u64;
    let t0 = Instant::now();
    let bank = match ckptfp::trace::TraceBank::try_build(&s, lead, bank_reps).expect("bank build")
    {
        Some(b) => std::sync::Arc::new(b),
        None => {
            println!("  skipped: bank declined (arena cap)");
            rec.push("bank_replay_vs_live", vec![("skipped", Json::Bool(true))]);
            return;
        }
    };
    let build_s = t0.elapsed().as_secs_f64();
    let mut replay = SimSession::replay(bank.clone(), &s, policy).expect("replay session");
    let (replay_msegs, replay_runs, _) =
        segment_throughput(|rep| replay.run(rep % bank_reps).n_segments, 1.5);
    let speedup = replay_msegs / live_msegs;
    let ctr = ckptfp::trace::bank::counters();
    println!("  live TraceGen session        {live_msegs:>6.2} M segments/s ({live_runs} runs)");
    println!("  bank ReplaySource session    {replay_msegs:>6.2} M segments/s ({replay_runs} runs)");
    println!(
        "  replay speedup: {speedup:.2}x  (bank build {build_s:.2}s, {:.1} MB resident, {} fallbacks so far)",
        bank.resident_bytes() as f64 / 1e6,
        ctr.fallbacks_taken
    );
    rec.push(
        "bank_replay_vs_live",
        vec![
            ("live_msegments_per_s", Json::Num(live_msegs)),
            ("replay_msegments_per_s", Json::Num(replay_msegs)),
            ("speedup", Json::Num(speedup)),
            ("bank_build_s", Json::Num(build_s)),
        ],
    );
}

fn bench_pool(rec: &mut Recorder) {
    println!("== worker pool scaling (streaming fold, fixed total work) ==");
    let s = {
        let mut s = Scenario::paper(1 << 19, predictor_yu(300.0));
        s.fault_dist = DistSpec::weibull(0.7);
        s
    };
    let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    let reps: Vec<u64> = (0..2048).collect();
    let mut base = 0.0;
    let mut fields: Vec<(&str, Json)> = Vec::new();
    let keys = ["speedup_w1", "speedup_w2", "speedup_w4", "speedup_w8"];
    for (workers, key) in [1usize, 2, 4, 8].into_iter().zip(keys) {
        let t0 = Instant::now();
        let (_, sum) = run_parallel_fold(
            &reps,
            workers,
            || (None::<SimSession>, Summary::new()),
            |(mut sess, mut sum), &rep| {
                let sref = sess
                    .get_or_insert_with(|| SimSession::new(&s, &spec).expect("session"));
                sum.push(sref.run(rep).waste());
                (sess, sum)
            },
            |(_, a), (_, b)| (None, a.merge(&b)),
        );
        std::hint::black_box(sum.mean());
        let dt = t0.elapsed().as_secs_f64();
        if workers == 1 {
            base = dt;
        }
        println!(
            "  {workers:>2} workers: {dt:>6.2}s  speedup {:>4.2}x  efficiency {:>4.0}%",
            base / dt,
            base / dt / workers as f64 * 100.0
        );
        fields.push((key, Json::Num(base / dt)));
    }
    rec.push("pool", fields);
}

fn bench_best_period(rec: &mut Recorder) {
    println!("== best-period search (candidate x rep product) ==");
    // The `best_period_close_to_formula` test configuration.
    let mut s = Scenario::paper(1 << 16, Predictor::none());
    s.fault_dist = DistSpec::Exp;
    s.work = 2.0e5;
    let base = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let mut fields: Vec<(&str, Json)> = Vec::new();
    let mut serial = 0.0;
    let all = ckptfp::coordinator::available_workers();
    for (label, key, workers, prune) in [
        ("1 worker, no prune", "serial_s", 1usize, false),
        ("1 worker, pruned", "serial_pruned_s", 1, true),
        ("all workers, pruned", "parallel_pruned_s", all, true),
    ] {
        let t0 = Instant::now();
        let res = best_period_with(
            &s,
            &base,
            12,
            12,
            &BestPeriodOptions { workers, prune, replay: true, ..Default::default() },
        )
        .expect("search");
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {label:<22} {dt:>6.2}s  (T* = {:.0}, {} pruned)",
            res.t_r, res.n_pruned
        );
        if key == "serial_s" {
            serial = dt;
        }
        fields.push((key, Json::Num(dt)));
        std::hint::black_box(res.waste);
        if key == "parallel_pruned_s" && serial > 0.0 {
            println!("  end-to-end speedup vs serial exhaustive: {:.2}x", serial / dt);
            fields.push(("speedup", Json::Num(serial / dt)));
        }
    }
    rec.push("best_period", fields);
}

fn bench_best_period_crn(rec: &mut Recorder) {
    println!("== best-period: replay-backed sweep vs live sweep (equal reps) ==");
    // The acceptance bench: the same search budget, with and without
    // the trace bank. No pruning, so both runs execute the identical
    // candidate × rep product and the wall-clock delta is the
    // sampling work the bank amortizes across candidates.
    let mut s = Scenario::paper(1 << 16, Predictor::none());
    s.fault_dist = DistSpec::Exp;
    s.work = 2.0e5;
    let base = spec_for(StrategyKind::Young, &s, Capping::Uncapped);
    let workers = ckptfp::coordinator::available_workers();
    let mut fields: Vec<(&str, Json)> = Vec::new();
    let mut live_s = 0.0;
    for (label, key, replay) in
        [("live generation", "live_s", false), ("bank replay", "replay_s", true)]
    {
        let t0 = Instant::now();
        let res = best_period_with(
            &s,
            &base,
            24,
            12,
            // Scalar lanes on both arms: this bench isolates the CRN
            // sampling win; the lockstep delta has its own bench below.
            &BestPeriodOptions { workers, prune: false, replay, batch: BatchOptions::scalar() },
        )
        .expect("search");
        let dt = t0.elapsed().as_secs_f64();
        println!("  {label:<16} {dt:>6.2}s  (T* = {:.0}, {} reps simulated)", res.t_r, res.reps_used);
        fields.push((key, Json::Num(dt)));
        std::hint::black_box(res.waste);
        if replay {
            println!("  CRN speedup at equal reps: {:.2}x", live_s / dt);
            fields.push(("speedup", Json::Num(live_s / dt)));
        } else {
            live_s = dt;
        }
    }
    rec.push("best_period_crn", fields);
}

fn bench_lockstep(rec: &mut Recorder) {
    println!("== lockstep batch engine vs scalar replay (one shared bank) ==");
    // The BestPeriod inner loop in isolation: the same banked
    // replications advanced by a scalar replay session and by the
    // lockstep engine at 1/4/16 lanes. Outcomes are bit-identical
    // (pinned by tests/test_batch.rs), so the deltas are pure driver
    // cost; lanes=1 vs scalar is the chunked driver's abstraction tax.
    let mut s = Scenario::paper(1 << 19, predictor_yu(300.0));
    s.fault_dist = DistSpec::weibull(0.7);
    let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    let policy = ckptfp::sim::Policy::from_spec(&spec, s.platform.c);
    let lead = spec.required_lead(s.platform.c);
    let bank_reps = 256u64;
    let bank = match ckptfp::trace::TraceBank::try_build(&s, lead, bank_reps).expect("bank build")
    {
        Some(b) => std::sync::Arc::new(b),
        None => {
            println!("  skipped: bank declined (arena cap)");
            rec.push("lockstep_vs_scalar", vec![("skipped", Json::Bool(true))]);
            return;
        }
    };
    let reps: Vec<u64> = (0..bank_reps).collect();
    let mut fields: Vec<(&str, Json)> = Vec::new();

    // Replications per second over repeated full passes of the bank.
    let mut rate_of = |runner: &mut BatchRunner| -> f64 {
        runner.run_reps(&reps, |_, out| {
            std::hint::black_box(out.n_segments);
        }); // warmup
        let t0 = Instant::now();
        let mut passes = 0u64;
        while t0.elapsed().as_secs_f64() < 1.0 {
            runner.run_reps(&reps, |_, out| {
                std::hint::black_box(out.n_segments);
            });
            passes += 1;
        }
        passes as f64 * bank_reps as f64 / t0.elapsed().as_secs_f64()
    };

    let mut scalar = BatchRunner::Scalar(
        SimSession::replay(bank.clone(), &s, policy).expect("replay session"),
    );
    let scalar_rate = rate_of(&mut scalar);
    println!("  scalar replay session        {scalar_rate:>8.0} reps/s");
    fields.push(("scalar_reps_per_s", Json::Num(scalar_rate)));

    for (lanes, key) in
        [(1usize, "reps_per_s_lanes1"), (4, "reps_per_s_lanes4"), (16, "reps_per_s_lanes16")]
    {
        let mut runner = BatchRunner::Lockstep(
            BatchEngine::new(bank.clone(), &s, policy, lanes).expect("batch engine"),
        );
        let r = rate_of(&mut runner);
        println!(
            "  lockstep lanes={lanes:<2}           {r:>8.0} reps/s  ({:.2}x vs scalar)",
            r / scalar_rate
        );
        fields.push((key, Json::Num(r)));
        if lanes == 1 {
            let tax = (1.0 - r / scalar_rate) * 100.0;
            println!("  lanes=1 abstraction tax: {tax:.1}%");
            fields.push(("abstraction_tax_pct", Json::Num(tax)));
        }
        if lanes == 16 {
            fields.push(("speedup_lanes16", Json::Num(r / scalar_rate)));
        }
    }
    rec.push("lockstep_vs_scalar", fields);
}

fn bench_wide(rec: &mut Recorder) {
    println!("== wide SoA kernel vs lockstep vs scalar (one shared bank) ==");
    // The tentpole comparison: the same banked replications advanced by
    // the scalar replay session, the lockstep engine and the wide
    // struct-of-arrays kernel at matching widths. Outcomes are
    // bit-identical (pinned by tests/test_batch.rs), so the deltas are
    // pure time-accounting layout: lockstep pays per-lane engine
    // structs and a chunk driver; wide keeps every lane's clock,
    // segment progress and accumulators in contiguous columns and
    // sweeps them one event-phase at a time.
    let mut s = Scenario::paper(1 << 19, predictor_yu(300.0));
    s.fault_dist = DistSpec::weibull(0.7);
    let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    let policy = ckptfp::sim::Policy::from_spec(&spec, s.platform.c);
    let lead = spec.required_lead(s.platform.c);
    let bank_reps = 256u64;
    let bank = match ckptfp::trace::TraceBank::try_build(&s, lead, bank_reps).expect("bank build")
    {
        Some(b) => std::sync::Arc::new(b),
        None => {
            println!("  skipped: bank declined (arena cap)");
            rec.push("wide_vs_lockstep", vec![("skipped", Json::Bool(true))]);
            return;
        }
    };
    let reps: Vec<u64> = (0..bank_reps).collect();
    let mut fields: Vec<(&str, Json)> = Vec::new();

    let mut rate_of = |runner: &mut BatchRunner| -> f64 {
        runner.run_reps(&reps, |_, out| {
            std::hint::black_box(out.n_segments);
        }); // warmup
        let t0 = Instant::now();
        let mut passes = 0u64;
        while t0.elapsed().as_secs_f64() < 1.0 {
            runner.run_reps(&reps, |_, out| {
                std::hint::black_box(out.n_segments);
            });
            passes += 1;
        }
        passes as f64 * bank_reps as f64 / t0.elapsed().as_secs_f64()
    };

    let mut scalar = BatchRunner::Scalar(
        SimSession::replay(bank.clone(), &s, policy).expect("replay session"),
    );
    let scalar_rate = rate_of(&mut scalar);
    println!("  scalar replay session        {scalar_rate:>8.0} reps/s");
    fields.push(("scalar_reps_per_s", Json::Num(scalar_rate)));

    let mut lockstep = BatchRunner::Lockstep(
        BatchEngine::new(bank.clone(), &s, policy, 16).expect("batch engine"),
    );
    let lockstep_rate = rate_of(&mut lockstep);
    println!(
        "  lockstep lanes=16            {lockstep_rate:>8.0} reps/s  ({:.2}x vs scalar)",
        lockstep_rate / scalar_rate
    );
    fields.push(("lockstep_reps_per_s", Json::Num(lockstep_rate)));

    for (width, key) in
        [(8usize, "wide_reps_per_s_w8"), (16, "wide_reps_per_s_w16"), (32, "wide_reps_per_s_w32")]
    {
        let mut runner = BatchRunner::Wide(
            WideKernel::new(bank.clone(), &s, policy, width).expect("wide kernel"),
        );
        let r = rate_of(&mut runner);
        println!(
            "  wide width={width:<2}                {r:>8.0} reps/s  ({:.2}x vs scalar, {:.2}x vs lockstep)",
            r / scalar_rate,
            r / lockstep_rate
        );
        fields.push((key, Json::Num(r)));
        if width == 16 {
            fields.push(("speedup_vs_scalar", Json::Num(r / scalar_rate)));
            fields.push(("speedup_vs_lockstep", Json::Num(r / lockstep_rate)));
        }
    }
    rec.push("wide_vs_lockstep", fields);
}

fn bench_platform_step(rec: &mut Recorder) {
    println!("== platform layer (multi-node event merge overhead) ==");
    // The same NoCkptI workload as `sim`, stepped through the platform
    // source at K = 1, 4 and 16 nodes. K = 1 vs the classic session is
    // the abstraction tax (bit-identical outcomes, so the delta is pure
    // heap/indirection cost); K > 1 adds the per-node stream merge.
    let mut s = Scenario::paper(1 << 19, predictor_yu(300.0));
    s.fault_dist = DistSpec::Exp;
    let spec = spec_for(StrategyKind::NoCkptI, &s, Capping::Uncapped);
    let mut fields: Vec<(&str, Json)> = Vec::new();

    let mut classic = SimSession::new(&s, &spec).expect("session");
    let (classic_msegs, classic_runs, _) =
        segment_throughput(|rep| classic.run(rep).n_segments, 1.0);
    println!("  classic engine               {classic_msegs:>6.2} M segments/s ({classic_runs} runs)");
    fields.push(("classic_msegments_per_s", Json::Num(classic_msegs)));

    for (k, key) in [(1u64, "msegs_k1"), (4, "msegs_k4"), (16, "msegs_k16")] {
        let pspec = ckptfp::sim::PlatformSpec { nodes: k, ..Default::default() };
        let mut session =
            SimSession::new_on_platform(&s, &spec, &pspec).expect("platform session");
        let (msegs, runs, _) = segment_throughput(|rep| session.run(rep).n_segments, 1.0);
        println!("  platform K={k:<2}                {msegs:>6.2} M segments/s ({runs} runs)");
        fields.push((key, Json::Num(msegs)));
        if k == 1 {
            println!("  K=1 abstraction tax: {:.1}%", (1.0 - msegs / classic_msegs) * 100.0);
        }
    }
    rec.push("platform_step", fields);
}

fn bench_model(rec: &mut Recorder) {
    println!("== closed-form planner (Rust baseline) ==");
    let batch = params_batch(64);
    let per = time("plan() x64 closed-form", 200, || {
        for p in &batch {
            std::hint::black_box(plan(p, Capping::Capped, false));
        }
    });
    rec.push("model", vec![("plan64_ms", Json::Num(per * 1e3))]);
}

fn bench_waste_grid_batched(rec: &mut Recorder) {
    println!("== batched waste grid vs per-row plan loop ==");
    // A §5-scale analytic grid: 4096 Params rows × all six strategies.
    // The scalar baseline calls model::plan once per row; the batched
    // pass evaluates GRID_CHUNK-row blocks over flat columns in one
    // sweep. Results are bit-identical (pinned in model::batched).
    let rows = params_batch(4096);
    let t_scalar = time("model::plan per-row x4096", 20, || {
        for p in &rows {
            std::hint::black_box(plan(p, Capping::Capped, true));
        }
    });
    let t_batched = time("model::plan_batched x4096", 20, || {
        std::hint::black_box(ckptfp::model::plan_batched(&rows, Capping::Capped, true));
    });
    let speedup = t_scalar / t_batched;
    println!(
        "  batched speedup: {speedup:.2}x  ({:.0} rows/s batched)",
        rows.len() as f64 / t_batched
    );
    rec.push(
        "waste_grid_batched",
        vec![
            ("scalar_s", Json::Num(t_scalar)),
            ("batched_s", Json::Num(t_batched)),
            ("rows_per_s_scalar", Json::Num(rows.len() as f64 / t_scalar)),
            ("rows_per_s_batched", Json::Num(rows.len() as f64 / t_batched)),
            ("speedup", Json::Num(speedup)),
        ],
    );
}

fn bench_waste_grid_accel(rec: &mut Recorder) {
    println!("== accelerated waste grid (HLO batcher) vs batched CPU pass ==");
    // The Executor::waste_grid routing in isolation: the same 4096-row
    // grid served by the pjrt-gated HLO batcher and by the vectorized
    // CPU pass. The CPU pass stays the bit-equality reference (the HLO
    // pipeline computes in f32); the delta is device throughput. On a
    // build without PJRT artifacts the batcher fails to spawn and the
    // bench records skipped, like `planner`/`batcher` above.
    let batcher = match Batcher::spawn(HloPlanner::open_default, BatcherConfig::default()) {
        Ok(b) => b,
        Err(e) => {
            println!("  skipped: {e}");
            rec.push("waste_grid_accel", vec![("skipped", Json::Bool(true))]);
            return;
        }
    };
    let rows = params_batch(4096);
    let t_cpu = time("waste_grid_batched x4096 (CPU)", 20, || {
        std::hint::black_box(ckptfp::model::waste_grid_batched(&rows, Capping::Uncapped));
    });
    let t_hlo = time("batcher.waste_grid x4096 (HLO)", 20, || {
        std::hint::black_box(batcher.waste_grid(rows.clone()).expect("hlo grid"));
    });
    let speedup = t_cpu / t_hlo;
    println!(
        "  accel speedup: {speedup:.2}x  ({:.0} rows/s via HLO)",
        rows.len() as f64 / t_hlo
    );
    rec.push(
        "waste_grid_accel",
        vec![
            ("cpu_s", Json::Num(t_cpu)),
            ("hlo_s", Json::Num(t_hlo)),
            ("rows_per_s_cpu", Json::Num(rows.len() as f64 / t_cpu)),
            ("rows_per_s_hlo", Json::Num(rows.len() as f64 / t_hlo)),
            ("speedup", Json::Num(speedup)),
        ],
    );
    batcher.shutdown();
}

fn main() {
    let which: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    let run = |name: &str| which.is_empty() || which.iter().any(|w| w == name);
    println!("ckptfp perf bench (workers available: {})", ckptfp::coordinator::available_workers());
    let mut rec = Recorder::default();
    if run("planner") {
        bench_planner(&mut rec);
    }
    if run("batcher") {
        bench_batcher(&mut rec);
    }
    if run("sim") {
        bench_sim(&mut rec);
    }
    if run("session_vs_oneshot") {
        bench_session_vs_oneshot(&mut rec);
    }
    if run("bank_replay_vs_live") {
        bench_bank_replay(&mut rec);
    }
    if run("pool") {
        bench_pool(&mut rec);
    }
    if run("best_period") {
        bench_best_period(&mut rec);
    }
    if run("best_period_crn") {
        bench_best_period_crn(&mut rec);
    }
    if run("lockstep_vs_scalar") {
        bench_lockstep(&mut rec);
    }
    if run("wide_vs_lockstep") {
        bench_wide(&mut rec);
    }
    if run("platform_step") {
        bench_platform_step(&mut rec);
    }
    if run("model") {
        bench_model(&mut rec);
    }
    if run("waste_grid_batched") {
        bench_waste_grid_batched(&mut rec);
    }
    if run("waste_grid_accel") {
        bench_waste_grid_accel(&mut rec);
    }
    if which.is_empty() {
        rec.write("BENCH_perf.json");
    } else {
        // A filtered run records only a subset; overwriting would
        // clobber the last full baseline.
        println!("\n(filtered run — BENCH_perf.json left untouched; run with no bench names to record)");
    }
}
