//! Property-based integration tests (via the in-tree testkit, now part
//! of the `verify` subsystem).

use ckptfp::config::{Predictor, Scenario};
use ckptfp::dist::{Dist, DistSpec};
use ckptfp::model::{
    optimal_period, optimize, t_cap, tp_opt, waste_exact_q, waste_of, Capping, Params,
    StrategyKind,
};
use ckptfp::rng::substream;
use ckptfp::sim::{simulate_once, SimConfig};
use ckptfp::strategies::{spec_for, PolicySpec, ProactiveMode, StrategySpec};
use ckptfp::testkit::{check, Config};
use ckptfp::trace::{EventSource, TraceGen};

fn random_params(g: &mut ckptfp::testkit::Gen<'_>) -> Params {
    let window = *g.choose(&[0.0, 300.0, 3000.0, 7200.0]);
    let pred = if window > 0.0 {
        Predictor::windowed(g.f64(0.0, 1.0), g.f64(0.05, 1.0), window)
    } else {
        Predictor::exact(g.f64(0.0, 1.0), g.f64(0.05, 1.0))
    };
    let mut s = Scenario::paper(1 << g.u64(14, 19), pred);
    s.platform.c = g.f64(60.0, 1200.0);
    Params::from_scenario(&s)
}

#[test]
fn prop_q_endpoint_optimality() {
    // §3.3: WASTE(q) affine in q ⇒ for any T, no interior q beats both
    // endpoints.
    check(Config { cases: 200, seed: 11 }, |g| {
        let p = random_params(g);
        let t = g.log_f64(p.c + 1.0, 20.0 * p.c + 50_000.0);
        let q = g.f64(0.01, 0.99);
        let w0 = waste_exact_q(&p, t, 0.0);
        let w1 = waste_exact_q(&p, t, 1.0);
        let wq = waste_exact_q(&p, t, q);
        assert!(wq >= w0.min(w1) - 1e-12, "interior q beat endpoints");
        assert!(wq <= w0.max(w1) + 1e-12, "affinity violated");
    });
}

#[test]
fn prop_optimal_period_is_argmin() {
    // The closed-form period must beat any other admissible period.
    check(Config { cases: 150, seed: 12 }, |g| {
        let p = random_params(g);
        let kind = *g.choose(&StrategyKind::ALL);
        let cap = t_cap(&p, kind);
        if cap <= p.c {
            return; // inadmissible configuration
        }
        let t_star = optimal_period(&p, kind, Capping::Capped);
        let tp = tp_opt(&p);
        let w_star = waste_of(&p, kind, t_star, tp);
        let t_other = g.f64(p.c, cap);
        let w_other = waste_of(&p, kind, t_other, tp);
        assert!(
            w_star <= w_other + 1e-9,
            "{}: T*={t_star} w*={w_star} beaten by T={t_other} w={w_other}",
            kind.name()
        );
    });
}

#[test]
fn prop_tp_divides_window() {
    check(Config { cases: 150, seed: 13 }, |g| {
        let mut p = random_params(g);
        p.i = g.f64(p.c, 20.0 * p.c);
        p.ef = p.i / 2.0;
        let tp = tp_opt(&p);
        let k = p.i / tp;
        assert!(
            (k - k.round()).abs() < 1e-6 || (tp - p.c).abs() < 1e-9,
            "I={} tp={tp} k={k}",
            p.i
        );
        assert!(tp >= p.c - 1e-9);
    });
}

#[test]
fn prop_waste_in_unit_interval() {
    check(Config { cases: 200, seed: 14 }, |g| {
        let p = random_params(g);
        for kind in StrategyKind::ALL {
            let (_, w) = optimize(&p, kind, Capping::Capped);
            assert!((0.0..=1.0).contains(&w), "{}: {w}", kind.name());
        }
    });
}

#[test]
fn prop_engine_conservation() {
    // makespan == useful work + checkpoints + (D+R per fault) + lost
    // work + migrations — on random generated traces, every strategy.
    check(Config { cases: 25, seed: 15 }, |g| {
        let window = *g.choose(&[0.0, 300.0, 3000.0]);
        let pred = if window > 0.0 {
            Predictor::windowed(g.f64(0.2, 0.95), g.f64(0.3, 0.95), window)
        } else {
            Predictor::exact(g.f64(0.2, 0.95), g.f64(0.3, 0.95))
        };
        let mut s = Scenario::paper(1 << 16, pred);
        s.fault_dist = *g.choose(&[
            ckptfp::dist::DistSpec::Exp,
            ckptfp::dist::DistSpec::weibull(0.7),
            ckptfp::dist::DistSpec::Uniform,
        ]);
        s.work = g.f64(1.0e5, 5.0e5);
        s.seed = g.u64(0, u64::MAX / 2);
        let kind = *g.choose(&StrategyKind::ALL);
        let sk = ckptfp::experiments::scenario_for(kind, &s);
        let spec = spec_for(kind, &sk, Capping::Uncapped);
        let o = simulate_once(&sk, &spec, g.u64(0, 10)).expect("sim");
        assert!(o.completed);
        let cfg = SimConfig::from_scenario(&sk);
        // Hard components of the overhead: completed checkpoints,
        // destroyed volatile work, completed migrations.
        let lower = (o.n_ckpts + o.n_proactive_ckpts) as f64 * cfg.c
            + o.n_migrations as f64
                * match spec.proactive {
                    ProactiveMode::Migrate { m } => m,
                    _ => 0.0,
                }
            + o.lost_work;
        let overhead = o.overhead();
        // Each fault adds at most D + R (less when a later fault
        // truncates the outage); each trusted prediction can add up to
        // C of fill slack (Fig. 1b) plus a partially-wasted checkpoint.
        let upper = lower
            + o.n_faults as f64 * (cfg.d + cfg.r)
            + o.n_trusted as f64 * 2.0 * cfg.c
            + 1.0;
        assert!(
            overhead >= lower - 1e-3 && overhead <= upper,
            "{}: overhead {overhead} outside [{lower}, {upper}]",
            spec.name
        );
    });
}

#[test]
fn prop_trace_recall_precision() {
    check(Config { cases: 12, seed: 16 }, |g| {
        let recall = g.f64(0.2, 0.95);
        let precision = g.f64(0.3, 0.95);
        let mut s = Scenario::paper(1 << 18, Predictor::exact(recall, precision));
        s.fault_dist = ckptfp::dist::DistSpec::Exp;
        s.seed = g.u64(0, 1 << 40);
        let mut gen = TraceGen::new(&s, s.platform.c, s.seed, 0).unwrap();
        let mut faults = 0u64;
        let mut predicted = 0u64;
        let horizon = s.mu() * 4000.0;
        loop {
            let f = gen.next_fault().unwrap();
            if f.t > horizon {
                break;
            }
            faults += 1;
            if f.predicted {
                predicted += 1;
            }
        }
        let emp = predicted as f64 / faults as f64;
        assert!(
            (emp - recall).abs() < 0.06,
            "recall {recall} vs empirical {emp} over {faults} faults"
        );
    });
}

#[test]
fn prop_period_monotone_in_recall() {
    // T_extr = sqrt(2 mu C / (1 - r)): higher recall ⇒ longer period.
    check(Config { cases: 80, seed: 17 }, |g| {
        let base = random_params(g);
        let r1 = g.f64(0.0, 0.5);
        let r2 = g.f64(r1 + 0.01, 0.99);
        let mut p1 = base;
        p1.recall = r1;
        let mut p2 = base;
        p2.recall = r2;
        let t1 = optimal_period(&p1, StrategyKind::ExactPrediction, Capping::Uncapped);
        let t2 = optimal_period(&p2, StrategyKind::ExactPrediction, Capping::Uncapped);
        assert!(t2 >= t1, "r {r1}->{r2} but T {t1}->{t2}");
    });
}

#[test]
fn prop_dist_sampler_mean_matches_closed_form() {
    // Fixed-seed empirical means of every law vs Dist::mean. Weibull
    // k = 0.5 has variance 5·mean², so the 20k-sample mean carries a
    // ~1.6% standard error — a 7% gate sits beyond 4 sigma.
    check(Config { cases: 10, seed: 21 }, |g| {
        let spec = *g.choose(&[
            DistSpec::Exp,
            DistSpec::Uniform,
            DistSpec::weibull(0.5),
            DistSpec::weibull(0.7),
            DistSpec::weibull(1.5),
        ]);
        let mean = g.log_f64(50.0, 5.0e4);
        let d = spec.dist().expect("valid spec").with_mean(mean);
        assert!(ckptfp::util::approx_eq(d.mean(), mean, 1e-9), "{spec}");
        let mut rng = substream(g.u64(0, 1 << 40), "dist-mean", 0);
        let n = 20_000;
        let emp = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!(
            (emp - mean).abs() / mean < 0.07,
            "{spec} mean {mean}: empirical {emp}"
        );
    });
}

#[test]
fn prop_dist_cdf_matches_closed_form() {
    // Empirical CDF at a random quantile point vs the closed form, for
    // the laws with simple CDFs. Binomial noise at n = 20k is < 0.4%
    // per point; the 2.5% gate is ~7 sigma.
    check(Config { cases: 10, seed: 22 }, |g| {
        let mean = g.log_f64(10.0, 1.0e4);
        let x = mean * g.f64(0.2, 2.5);
        let (d, cdf): (Dist, f64) = match g.u64(0, 2) {
            0 => (Dist::Exponential { mean }, 1.0 - (-x / mean).exp()),
            1 => {
                let shape = *g.choose(&[0.5, 0.7, 1.0, 2.0]);
                let d = DistSpec::weibull(shape).dist().unwrap().with_mean(mean);
                let scale = match d {
                    Dist::Weibull { scale, .. } => scale,
                    _ => unreachable!(),
                };
                (d, 1.0 - (-(x / scale).powf(shape)).exp())
            }
            _ => (Dist::Uniform { lo: 0.0, hi: 2.0 * mean }, (x / (2.0 * mean)).min(1.0)),
        };
        let mut rng = substream(g.u64(0, 1 << 40), "dist-cdf", 1);
        let n = 20_000;
        let hits = (0..n).filter(|_| d.sample(&mut rng) <= x).count();
        let emp = hits as f64 / n as f64;
        assert!((emp - cdf).abs() < 0.025, "{d:?} at {x}: empirical {emp} vs {cdf}");
    });
}

#[test]
fn prop_dist_spec_round_trips_for_arbitrary_shapes() {
    // Display -> FromStr is the identity for every valid spec: Rust's
    // f64 Display is shortest-round-trip, so no precision is lost.
    check(Config { cases: 200, seed: 23 }, |g| {
        let spec = match g.u64(0, 2) {
            0 => DistSpec::Exp,
            1 => DistSpec::Uniform,
            _ => DistSpec::weibull(g.log_f64(0.05, 50.0)),
        };
        let s = spec.to_string();
        assert_eq!(s.parse::<DistSpec>().expect(&s), spec, "round-trip of '{s}'");
    });
}

#[test]
fn prop_policy_spec_round_trips_for_arbitrary_parameters() {
    check(Config { cases: 200, seed: 24 }, |g| {
        let spec = match g.u64(0, 3) {
            0 => PolicySpec::Strategy(*g.choose(&StrategyKind::ALL)),
            1 => PolicySpec::AdaptivePeriod { gain: g.log_f64(0.01, 100.0) },
            _ => PolicySpec::RiskThreshold { kappa: g.log_f64(0.01, 100.0) },
        };
        let s = spec.to_string();
        assert_eq!(s.parse::<PolicySpec>().expect(&s), spec, "round-trip of '{s}'");
    });
}

#[test]
fn prop_substream_independence() {
    // Stream-splitting smoke test: distinct (label, index) substreams
    // of one seed must not correlate. For independent U[0,1) pairs
    // E[xy] = 0.25 with sd ≈ 0.083/√n; at n = 4096 the 0.02 gate is
    // ~15 sigma. Identity of the first outputs is checked exactly.
    check(Config { cases: 16, seed: 25 }, |g| {
        let seed = g.u64(0, u64::MAX / 2);
        let i = g.u64(0, 1 << 20);
        let j = i + 1 + g.u64(0, 1 << 20);
        let mut a = substream(seed, "faults", i);
        let mut b = substream(seed, "faults", j);
        let mut c = substream(seed, "preds", i);
        // No shared prefix across indices or labels.
        let head_a: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let head_b: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let head_c: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(head_a, head_b, "index collision");
        assert_ne!(head_a, head_c, "label collision");
        // Low cross-correlation between the uniform streams.
        let n = 4096;
        let mut mean_prod = 0.0;
        for _ in 0..n {
            mean_prod += a.next_f64() * b.next_f64();
        }
        mean_prod /= n as f64;
        assert!(
            (mean_prod - 0.25).abs() < 0.02,
            "substreams ({i}, {j}) correlate: E[xy] = {mean_prod}"
        );
    });
}

#[test]
fn prop_platform_superposition_preserves_the_aggregate_law() {
    // Poisson superposition: K per-node Exponential streams at MTBF
    // mu·K merged by the platform layer must look like one stream at
    // mu — same inter-arrival mean AND variance (an Exponential has
    // var = mu², so matching both pins the law, not just the rate).
    // Gates sit at ~4 sigma for the sample sizes used.
    check(Config { cases: 8, seed: 26 }, |g| {
        use ckptfp::sim::{PlatformSource, PlatformSpec};
        let k = *g.choose(&[2u64, 4, 8, 16]);
        let mut s = Scenario::paper(1 << 16, Predictor::none());
        s.fault_dist = DistSpec::Exp;
        s.seed = g.u64(0, 1 << 40);
        let spec = PlatformSpec { nodes: k, ..PlatformSpec::default() };
        let mut src = PlatformSource::new(&s, &spec, s.platform.c, s.seed, 0).unwrap();
        let n = 6000u64;
        let mut inter = |next: &mut dyn FnMut() -> f64| -> (f64, f64) {
            let (mut prev, mut sum, mut sum2) = (0.0, 0.0, 0.0);
            for _ in 0..n {
                let t = next();
                let dt = t - prev;
                prev = t;
                sum += dt;
                sum2 += dt * dt;
            }
            let mean = sum / n as f64;
            (mean, sum2 / n as f64 - mean * mean)
        };
        let (m_merged, v_merged) = inter(&mut || src.next_fault().unwrap().t);
        let mu = s.mu();
        assert!(
            (m_merged - mu).abs() / mu < 0.05,
            "K={k}: merged mean {m_merged} vs mu {mu}"
        );
        assert!(
            (v_merged / (mu * mu) - 1.0).abs() < 0.15,
            "K={k}: merged var {v_merged} vs mu^2 {}",
            mu * mu
        );
        // And against the single-stream generator at the same aggregate
        // MTBF (an independent fixed-seed sample of the same law).
        let mut single = TraceGen::new(&s, s.platform.c, s.seed, 0).unwrap();
        let (m_single, v_single) = inter(&mut || single.next_fault().unwrap().t);
        assert!(
            (m_merged - m_single).abs() / mu < 0.07,
            "K={k}: merged mean {m_merged} vs single {m_single}"
        );
        assert!(
            (v_merged - v_single).abs() / (mu * mu) < 0.25,
            "K={k}: merged var {v_merged} vs single {v_single}"
        );
    });
}

#[test]
fn prop_simulation_seed_determinism() {
    check(Config { cases: 8, seed: 18 }, |g| {
        let mut s = Scenario::paper(1 << 16, Predictor::windowed(0.7, 0.4, 300.0));
        s.work = 2.0e5;
        s.seed = g.u64(0, 1 << 40);
        let spec = StrategySpec {
            name: "t".into(),
            t_r: g.log_f64(s.platform.c + 10.0, 40_000.0),
            q: 1.0,
            proactive: ProactiveMode::CkptBefore,
        };
        let a = simulate_once(&s, &spec, 2).unwrap();
        let b = simulate_once(&s, &spec, 2).unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.n_segments, b.n_segments);
    });
}

// ---------------------------------------------------------------------------
// Cache-key canonicalization (coordinator::canon)
// ---------------------------------------------------------------------------

fn random_dist(g: &mut ckptfp::testkit::Gen<'_>) -> DistSpec {
    match g.u64(0, 2) {
        0 => DistSpec::Exp,
        1 => DistSpec::Uniform,
        _ => DistSpec::Weibull { shape: g.f64(0.05, 4.0) },
    }
}

fn random_policy(g: &mut ckptfp::testkit::Gen<'_>) -> PolicySpec {
    match g.u64(0, 2) {
        0 => PolicySpec::Strategy(*g.choose(&StrategyKind::ALL)),
        1 => PolicySpec::AdaptivePeriod { gain: g.f64(0.01, 5.0) },
        _ => PolicySpec::RiskThreshold { kappa: g.f64(0.01, 5.0) },
    }
}

fn random_platform(g: &mut ckptfp::testkit::Gen<'_>) -> ckptfp::sim::PlatformSpec {
    ckptfp::sim::PlatformSpec {
        nodes: g.u64(2, 16),
        commit: g.f64(0.0, 0.5),
        restart: *g.choose(&[
            ckptfp::sim::RestartScope::Full,
            ckptfp::sim::RestartScope::Partial,
        ]),
        group: g.u64(1, 4),
        spatial: g.f64(0.0, 0.9),
        cascade: g.f64(0.0, 0.9),
        delta: g.f64(1.0, 600.0),
    }
}

#[test]
fn prop_cache_keys_survive_display_round_trips() {
    // The cache key of a spec must be invariant under Display ->
    // FromStr: the wire and the CLI both speak the Display form, so a
    // drifting round-trip would split one logical job across cache
    // entries (never unsound, but silently useless).
    use ckptfp::coordinator::canon;
    check(Config { cases: 300, seed: 71 }, |g| {
        let d = random_dist(g);
        let d2: DistSpec = d.to_string().parse().expect("dist Display must parse");
        assert_eq!(canon::dist_key(&d), canon::dist_key(&d2), "dist {d}");

        let p = random_policy(g);
        let p2: PolicySpec = p.to_string().parse().expect("policy Display must parse");
        assert_eq!(canon::policy_key(&p), canon::policy_key(&p2), "policy {p}");

        let pf = random_platform(g);
        let pf2: ckptfp::sim::PlatformSpec =
            pf.to_string().parse().expect("platform Display must parse");
        assert_eq!(canon::platform_key(&pf), canon::platform_key(&pf2), "platform {pf}");
    });
}

#[test]
fn prop_cache_keys_survive_wire_round_trips() {
    // A full plan request decoded from its own wire encoding must key
    // identically — the canonical key sits *behind* the decoder, so
    // this is exactly the service's cold-request / repeat-request pair.
    use ckptfp::api::{wire, JobRequest, PlanJob};
    use ckptfp::coordinator::canon;
    check(Config { cases: 150, seed: 72 }, |g| {
        let pred = Predictor::exact(g.f64(0.05, 0.99), g.f64(0.05, 0.99));
        let mut s = Scenario::paper(1 << g.u64(14, 19), pred);
        s.platform.c = g.f64(60.0, 1200.0);
        s.work = g.log_f64(1.0e4, 1.0e7);
        s.fault_dist = random_dist(g);
        s.seed = g.u64(0, 1 << 40);
        let req = JobRequest::Plan(PlanJob::new(s));
        let line = wire::encode_request(&req);
        let decoded = wire::decode_request(&line).expect("own encoding decodes");
        assert_eq!(
            canon::request_key(&req, 0, 0, 0),
            canon::request_key(&decoded.request, 0, 0, 0),
            "wire round-trip changed the cache key: {line}"
        );
    });
}

#[test]
fn prop_unequal_keys_plan_observably_differently() {
    // Perturbing a dimension the closed-form planner actually reads
    // (checkpoint cost, platform size, predictor quality) must change
    // both the canonical key AND the encoded plan bytes — i.e. keys
    // don't collapse distinguishable jobs, and distinguishable jobs
    // really are distinguishable on a probe scenario.
    use ckptfp::api::{wire, Executor, JobRequest, JobResponse, PlanJob};
    use ckptfp::coordinator::canon;
    let exec = Executor::local();
    let plan_bytes = |s: &Scenario| -> String {
        let out = exec.plan(&PlanJob::new(s.clone())).expect("closed-form plan");
        wire::encode_response(&JobResponse::Plan(out), false)
    };
    check(Config { cases: 40, seed: 73 }, |g| {
        let pred = Predictor::exact(g.f64(0.3, 0.9), g.f64(0.3, 0.9));
        let mut base = Scenario::paper(1 << g.u64(15, 18), pred);
        base.platform.c = g.f64(120.0, 900.0);
        base.work = 2.0e5;
        let mut other = base.clone();
        match g.u64(0, 2) {
            0 => other.platform.c *= g.f64(1.5, 3.0),
            1 => other.platform.n_procs *= 2,
            _ => {
                other.predictor.recall = (base.predictor.recall * 0.5).max(0.01);
            }
        }
        let key_a = canon::request_key(&JobRequest::Plan(PlanJob::new(base.clone())), 0, 0, 0);
        let key_b = canon::request_key(&JobRequest::Plan(PlanJob::new(other.clone())), 0, 0, 0);
        assert_ne!(key_a, key_b, "perturbed scenario must key differently");
        assert_ne!(
            plan_bytes(&base),
            plan_bytes(&other),
            "different keys, byte-identical plans: cache keys are finer than needed"
        );
    });
}
